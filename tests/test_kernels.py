"""Per-kernel allclose validation: Pallas (interpret=True on CPU) vs ref.py.

Per the assignment: sweep shapes/dtypes for each kernel and assert_allclose
against the pure-jnp oracle.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

jax.config.update("jax_enable_x64", False)


def _spd(key, n, dtype=jnp.float32):
    a = jax.random.normal(key, (n, n), jnp.float32)
    k = a @ a.T / n + 2.0 * jnp.eye(n)
    return k.astype(dtype)


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


# ---------------------------------------------------------------------------
# Matérn covariance build
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n,m,d", [(128, 128, 128), (256, 128, 128),
                                   (128, 384, 256), (100, 77, 5),
                                   (1, 1, 1), (130, 257, 20)])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_matern_gram_matches_ref(n, m, d, dtype):
    kx, ky = jax.random.split(jax.random.PRNGKey(n * 1000 + m))
    x = jax.random.uniform(kx, (n, d), dtype, minval=-3, maxval=3)
    y = jax.random.uniform(ky, (m, d), dtype, minval=-3, maxval=3)
    got = ops.matern52_gram(x, y, 1.3, 0.7, implementation="pallas")
    want = ref.matern52_gram_ref(x, y, 1.3, 0.7)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=TOL[dtype])


def test_matern_gram_bf16():
    kx = jax.random.PRNGKey(7)
    x = jax.random.uniform(kx, (128, 128), jnp.bfloat16, minval=-2, maxval=2)
    got = ops.matern52_gram(x, x, 1.0, 1.0, implementation="pallas")
    want = ref.matern52_gram_ref(x.astype(jnp.float32),
                                 x.astype(jnp.float32), 1.0, 1.0)
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want),
                               rtol=2e-2, atol=2e-2)


# ---------------------------------------------------------------------------
# Blocked triangular solve (the paper's O(n^2) append hot path)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n", [128, 256, 512, 200, 1000])
@pytest.mark.parametrize("trans", [False, True])
def test_trsv_vector_matches_ref(n, trans):
    key = jax.random.PRNGKey(n + int(trans))
    l = jnp.linalg.cholesky(_spd(key, n))
    b = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    got = ops.trsv(l, b, trans=trans, implementation="pallas")
    want = ref.trsv_ref(l, b, trans=trans)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("n,r", [(128, 128), (256, 64), (384, 200), (129, 1)])
@pytest.mark.parametrize("trans", [False, True])
def test_trsv_matrix_rhs_matches_ref(n, r, trans):
    key = jax.random.PRNGKey(n * 7 + r)
    l = jnp.linalg.cholesky(_spd(key, n))
    b = jax.random.normal(jax.random.fold_in(key, 2), (n, r))
    got = ops.trsv(l, b, trans=trans, implementation="pallas")
    want = ref.trsv_ref(l, b, trans=trans)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# Blocked Cholesky (the lag-event refactorization)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n", [128, 256, 512, 100, 300])
def test_cholesky_matches_ref(n):
    k = _spd(jax.random.PRNGKey(n), n)
    got = ops.cholesky(k, implementation="pallas")
    want = ref.cholesky_ref(k)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=5e-4, atol=5e-4)


def test_cholesky_reconstructs():
    n = 384
    k = _spd(jax.random.PRNGKey(0), n)
    l = ops.cholesky(k, implementation="pallas")
    np.testing.assert_allclose(np.asarray(l @ l.T), np.asarray(k),
                               rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# Fused ops
# ---------------------------------------------------------------------------
def test_chol_append_matches_ref():
    n = 256
    key = jax.random.PRNGKey(3)
    l = jnp.linalg.cholesky(_spd(key, n))
    p = jax.random.normal(jax.random.fold_in(key, 1), (n,)) * 0.1
    c = jnp.asarray(3.0)
    q1, d1 = ops.chol_append(l, p, c, implementation="pallas")
    q2, d2 = ref.chol_append_ref(l, p, c)
    np.testing.assert_allclose(np.asarray(q1), np.asarray(q2), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(float(d1), float(d2), rtol=1e-5)


def test_gp_posterior_solve_matches_ref():
    n, m = 256, 33
    key = jax.random.PRNGKey(5)
    l = jnp.linalg.cholesky(_spd(key, n))
    resid = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    k_star = jax.random.uniform(jax.random.fold_in(key, 2), (n, m))
    k_ss = jnp.full((m,), 2.0)
    m1, v1 = ops.gp_posterior_solve(l, resid, k_star, k_ss,
                                    implementation="pallas")
    m2, v2 = ref.gp_posterior_solve_ref(l, resid, k_star, k_ss)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m2), rtol=1e-3,
                               atol=1e-3)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-3,
                               atol=1e-3)


# ---------------------------------------------------------------------------
# Envelope / fallback behaviour
# ---------------------------------------------------------------------------
def test_large_n_falls_back_to_xla():
    n = ops.MAX_PALLAS_N + 128
    k = jnp.eye(n) * 2.0
    l = ops.cholesky(k, implementation="pallas")  # falls back, still correct
    np.testing.assert_allclose(np.asarray(jnp.diag(l)),
                               np.full(n, np.sqrt(2.0)), rtol=1e-6)
