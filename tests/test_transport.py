"""Cross-host transport suite: real shard worker PROCESSES behind the
socket RPC front end (repro.hpo.transport, DESIGN.md §14).

Covers the fault matrix the in-process federation cannot: connection
drops mid-tell, truncated/oversized frames, heartbeat-driven death during
an in-flight migration, SIGKILL mid-`copy_study_version` — plus the
cross-deployment acceptance bar: a 2-process federation serves the same
suggestions as ONE in-process pool, bitwise (streams, ledgers, GP-state
digests, telemetry), and SIGKILL+respawn of a worker loses exactly its
uncommitted round while the survivor keeps serving."""
import asyncio
import json
import os
import signal
import socket
import struct
import tempfile

import numpy as np
import pytest

from _traffic import drive_serial, drive_serial_rpc
from _traffic import make_cfg as _cfg
from _traffic import objective as obj
from repro import checkpoint as ckpt_mod
from repro.core import GPCapacityError
from repro.hpo import (FederatedGateway, FederationConfig, GatewayConfig,
                       StudyGateway, TransportConfig, TransportError,
                       TransportFederation)
from repro.hpo import transport as tx
from repro.hpo.space import RESNET_SPACE


def _mk_tf(root, n_shards=2, slots=4, n_max=24, **tkw):
    """2-worker transport federation with test-sized budgets; health
    checks are explicit (`heartbeat_s=0`) so failover is deterministic."""
    return TransportFederation(
        RESNET_SPACE, _cfg(root, n_max=n_max),
        GatewayConfig(slots=slots),
        FederationConfig(n_shards=n_shards),
        TransportConfig(heartbeat_s=0.0, **tkw))


async def _create_on_both(tf, n=4):
    """Create n studies and sanity-check both shards got at least one
    (rendezvous placement of sids 0..n-1 — deterministic)."""
    sids = [await tf.create_study(name=f"s{i}") for i in range(n)]
    by_shard = {i: [s for s in sids if tf.shard_of(s) == i]
                for i in range(tf.fed.n_shards)}
    assert all(by_shard.values()), f"one-sided placement: {by_shard}"
    return sids, by_shard


# ---------------------------------------------------------------------------
# Frame codec (no processes)
# ---------------------------------------------------------------------------
def test_frame_roundtrip():
    msg = {"id": 7, "op": "tell",
           "args": {"sid": 3, "trial": {"unit": [0.25, 1.0]}, "value": -2.5}}
    buf = tx.encode_frame(msg)
    size = struct.unpack(">I", buf[:4])[0]
    assert size == len(buf) - 4

    async def main():
        reader = asyncio.StreamReader()
        reader.feed_data(buf)
        assert await tx.read_frame(reader) == msg
    asyncio.run(main())


def test_frame_truncation_and_oversize_are_connection_errors():
    async def main():
        # peer died mid-frame: header promises more bytes than arrive
        reader = asyncio.StreamReader()
        reader.feed_data(tx.encode_frame({"op": "ping"})[:-3])
        reader.feed_eof()
        with pytest.raises(asyncio.IncompleteReadError):
            await tx.read_frame(reader)
        # desynchronized stream: an absurd length prefix must fail before
        # any attempt to buffer it
        reader = asyncio.StreamReader()
        reader.feed_data(struct.pack(">I", 1 << 30) + b"x" * 16)
        with pytest.raises(TransportError, match="desynchronized"):
            await tx.read_frame(reader)
        # garbled body: not JSON
        reader = asyncio.StreamReader()
        reader.feed_data(struct.pack(">I", 4) + b"\xff\xfe\x00\x01")
        with pytest.raises(TransportError, match="undecodable"):
            await tx.read_frame(reader)
    asyncio.run(main())


def test_spec_roundtrip_rebuilds_the_same_gateway_shape(tmp_path):
    cfg = _cfg(str(tmp_path / "a"), n_max=24, seed=11)
    gwc = GatewayConfig(slots=3, max_inflight=2)
    spec = json.loads(json.dumps(tx.build_spec(RESNET_SPACE, cfg, gwc)))
    gw = tx.gateway_from_spec(spec, str(tmp_path / "b"))
    assert gw.cfg == _cfg(str(tmp_path / "b"), n_max=24, seed=11)
    assert gw.gw == gwc
    assert [d.name for d in gw._template_space.dims] == \
        [d.name for d in RESNET_SPACE.dims]


# ---------------------------------------------------------------------------
# Cross-deployment equivalence: 2 worker processes == 1 in-process pool
# ---------------------------------------------------------------------------
def test_two_process_federation_matches_single_pool_bitwise():
    """The acceptance bar of DESIGN.md §13 extended across process
    boundaries: WHERE a study is served (one pool, or 2 shard processes
    over sockets) never changes WHAT it is suggested.  Streams, ledgers,
    per-study GP-state digests, and telemetry totals must all match the
    single-pool twin bitwise."""
    async def main(root, twin_dir):
        tf = _mk_tf(os.path.join(root, "fed"))
        await tf.start()
        sids, _ = await _create_on_both(tf, 4)
        solo = StudyGateway(RESNET_SPACE, _cfg(twin_dir, n_max=24),
                            GatewayConfig(slots=8))
        assert [solo.create_study(name=f"s{i}") for i in range(4)] == sids

        st_tf = await drive_serial_rpc(tf, sids, 3)
        st_solo = await drive_serial(solo, sids, 3)
        assert st_tf == st_solo, "suggestion streams diverged"

        fed_sum = await tf.summary()
        solo_sum = solo.summary()
        assert fed_sum["asks_served"] == solo_sum["asks_served"] == 12
        assert fed_sum["absorbed"] == solo_sum["absorbed"] == 12

        stable = ("trial_id", "unit", "value", "status", "error")
        for s in sids:
            i_tf, i_solo = await tf.study_info(s), solo.study_info(s)
            assert i_tf["n_obs"] == i_solo["n_obs"] == 3
            assert i_tf["best_value"] == i_solo["best_value"]
            # ledgers: every stable field identical row for row
            led = await tf._client_for(s).call("ledger", sid=s)
            twin = solo.pool.history(solo._studies[s].slot)
            assert led is not None and len(led) == len(twin)
            for a, b in zip(led, twin):
                for k in stable:
                    assert a[k] == b[k], f"ledger[{k}] of study {s}"
            # the GP state itself, bitwise, across the process boundary
            dig = await tf._client_for(s).call("state_digest", sid=s)
            assert dig == tx.study_state_digest(
                solo.pool, solo._studies[s].slot), \
                f"study {s}: GP state diverged from the single pool"
        await tf.aclose()
        await solo.aclose()
    with tempfile.TemporaryDirectory() as root, \
            tempfile.TemporaryDirectory() as twin:
        asyncio.run(main(root, twin))


# ---------------------------------------------------------------------------
# SIGKILL + respawn: the federation-level crash acceptance bar
# ---------------------------------------------------------------------------
def test_sigkill_respawn_loses_exactly_the_uncommitted_round():
    """SIGKILL one worker process mid-traffic: the survivor keeps serving
    without a hiccup, and the respawned process comes back at its last
    committed epoch — the uncommitted round is lost, nothing pre-crash
    replays, and the retried round re-derives the lost suggestions
    bitwise from the persisted PRNG streams."""
    async def main(root):
        tf = _mk_tf(root)
        await tf.start()
        sids, by_shard = await _create_on_both(tf, 4)
        victim = tf.shard_of(sids[0])
        survivor = 1 - victim

        pre = await drive_serial_rpc(tf, sids, 2)
        await tf.checkpoint()                 # commits round 1-2
        lost = await drive_serial_rpc(tf, sids, 1)   # round 3: uncommitted

        pid = tf.procs[victim].pid
        tf.kill_shard(victim)                 # real SIGKILL
        with pytest.raises(ProcessLookupError):
            os.kill(pid, 0)

        # the survivor is undisturbed mid-crash
        s_surv = by_shard[survivor][0]
        tr = await tf.ask(s_surv)
        await tf.tell(s_surv, tr, obj(s_surv, tr.unit))
        await tf.drain()
        assert (await tf.study_info(s_surv))["n_obs"] == 4

        await tf.revive_shard(victim)
        for s in by_shard[victim]:
            assert (await tf.study_info(s))["n_obs"] == 2, \
                "a committed tell was lost in the crash"

        post = await drive_serial_rpc(tf, sids, 2)
        for s in sids:
            assert set(pre[s]).isdisjoint(post[s]), \
                "revived worker replayed a pre-crash suggestion"
            if tf.shard_of(s) == victim:
                assert post[s][0] == lost[s][0], \
                    "the lost round did not re-derive bitwise"
        await tf.aclose()
    with tempfile.TemporaryDirectory() as root:
        asyncio.run(main(root))


# ---------------------------------------------------------------------------
# Fault matrix: dropped connections, parked asks, garbage frames
# ---------------------------------------------------------------------------
def test_connection_faults_cancel_asks_fail_tells_survive_garbage():
    """One federation, three faults.  A worker SIGKILLed with calls in
    flight: the parked ask future CANCELS (kill_shard semantics — the
    client re-asks later) while the in-flight tell fails LOUDLY with
    ShardConnectionError (a lost result must never vanish silently).
    Garbage frames on a raw socket must not disturb the worker.  The
    revived worker then serves both studies again."""
    async def main(root):
        tf = _mk_tf(root)
        await tf.start()
        sids, by_shard = await _create_on_both(tf, 4)
        victim = tf.shard_of(sids[0])
        survivor = 1 - victim
        s_vic = by_shard[victim][0]
        s_surv = by_shard[survivor][0]
        await drive_serial_rpc(tf, sids, 1)
        await tf.checkpoint()

        # hold a live suggestion, then freeze the worker so the next
        # calls park on the wire
        held = await tf.ask(s_vic)
        os.kill(tf.procs[victim].pid, signal.SIGSTOP)
        ask_fut = asyncio.ensure_future(tf.ask(s_vic))
        tell_fut = asyncio.ensure_future(
            tf.tell(s_vic, held, obj(s_vic, held.unit)))
        await asyncio.sleep(0.3)              # both frames sent, parked
        assert not ask_fut.done() and not tell_fut.done()
        tf.kill_shard(victim)                 # SIGKILL severs the socket
        with pytest.raises(asyncio.CancelledError):
            await ask_fut
        with pytest.raises(tx.ShardConnectionError):
            await tell_fut
        # routed calls to a dead shard fail fast until revival
        with pytest.raises(RuntimeError, match="down"):
            await tf.ask(s_vic)

        # garbage on a raw socket: truncated frame, then an absurd length
        # prefix — the SURVIVOR worker must shrug both off
        with open(os.path.join(tf.shard_dir(survivor),
                               tx.ENDPOINT_FILE)) as f:
            ep = json.load(f)
        for garbage in (struct.pack(">I", 100) + b"short",
                        struct.pack(">I", 1 << 30) + b"x" * 32):
            raw = socket.create_connection((ep["host"], ep["port"]))
            raw.sendall(garbage)
            raw.close()
        tr = await tf.ask(s_surv)
        await tf.tell(s_surv, tr, obj(s_surv, tr.unit))
        await tf.drain()

        await tf.revive_shard(victim)
        # the held suggestion died with the worker's outstanding map and
        # its tell never committed: the study is back at the epoch
        assert (await tf.study_info(s_vic))["n_obs"] == 1
        tr = await tf.ask(s_vic)
        await tf.tell(s_vic, tr, obj(s_vic, tr.unit))
        await tf.drain()
        assert (await tf.study_info(s_vic))["n_obs"] == 2
        await tf.aclose()
    with tempfile.TemporaryDirectory() as root:
        asyncio.run(main(root))


def test_tell_replay_and_capacity_errors_cross_the_wire():
    """Error types that are part of the gateway contract must round-trip
    the RPC boundary: a replayed tell raises the same RuntimeError as
    in-process, and an impossible ask width raises GPCapacityError."""
    async def main(root):
        tf = _mk_tf(root)
        await tf.start()
        sid = await tf.create_study(name="s")
        tr = await tf.ask(sid)
        await tf.tell(sid, tr, 0.5)
        with pytest.raises(RuntimeError, match="exactly one tell"):
            await tf.tell(sid, tr, 0.5)
        # ... and the server-side outstanding map catches a replay even
        # when the client-side status is forged back
        tr.status = "running"
        with pytest.raises(RuntimeError, match="exactly one tell"):
            await tf.tell(sid, tr, 0.5)
        with pytest.raises(GPCapacityError, match="max_inflight"):
            await tf.ask(sid, q=99)
        with pytest.raises(KeyError, match="unknown study"):
            await tf.ask(777)
        await tf.aclose()
    with tempfile.TemporaryDirectory() as root:
        asyncio.run(main(root))


# ---------------------------------------------------------------------------
# Heartbeat flap during an in-flight migration
# ---------------------------------------------------------------------------
def test_heartbeat_flap_mid_migration_aborts_all_or_nothing():
    """The destination worker stops answering (SIGSTOP) with an adopt RPC
    in flight: health checks mark it dead at miss_limit, the migration
    aborts LOUDLY, and the study is fully intact on its source shard —
    adopt-before-detach means no fault before the final detach can lose
    it.  After revival the SAME migration retries to completion (the
    copy is idempotent on a committed version)."""
    async def main(root):
        tf = _mk_tf(root, heartbeat_timeout_s=0.25, miss_limit=2)
        await tf.start()
        sids, _ = await _create_on_both(tf, 4)
        sid = sids[0]
        src = tf.shard_of(sid)
        dst = 1 - src
        await drive_serial_rpc(tf, sids, 2)

        os.kill(tf.procs[dst].pid, signal.SIGSTOP)
        mig = asyncio.ensure_future(tf.migrate_study(sid, dst))
        await asyncio.sleep(0.4)   # export+copy done, adopt parked on dst
        died = []
        for _ in range(4):
            died += await tf.check_health()
            if dst in died:
                break
        assert dst in died, "flapping shard was never marked dead"
        with pytest.raises(RuntimeError):   # ShardConnectionError or
            await mig                        # routed-to-dead, both loud
        # all-or-nothing: still owned and servable on the source
        assert tf.shard_of(sid) == src
        tr = await tf.ask(sid)
        await tf.tell(sid, tr, obj(sid, tr.unit))
        await tf.drain()
        assert (await tf.study_info(sid))["n_obs"] == 3

        os.kill(tf.procs[dst].pid, signal.SIGCONT)
        await tf.revive_shard(dst)   # kills the zombie first, respawns
        await tf.migrate_study(sid, dst)
        assert tf.shard_of(sid) == dst
        info = await tf.study_info(sid)
        assert info["n_obs"] == 3 and info["shard"] == dst
        tr = await tf.ask(sid)
        await tf.tell(sid, tr, obj(sid, tr.unit))
        await tf.drain()
        assert (await tf.study_info(sid))["n_obs"] == 4
        await tf.aclose()
    with tempfile.TemporaryDirectory() as root:
        asyncio.run(main(root))


# ---------------------------------------------------------------------------
# SIGKILL during copy_study_version: no debris is ever adoptable
# ---------------------------------------------------------------------------
def _copy_then_die(src, dst, key, version):
    """Child process: SIGKILL itself after the first snapshot file lands
    in the migration staging dir — a front end dying mid-copy."""
    from repro.checkpoint import store as store_mod
    real = store_mod.shutil.copy2

    def die_after_one(a, b):
        real(a, b)
        os.kill(os.getpid(), signal.SIGKILL)
    store_mod.shutil.copy2 = die_after_one
    store_mod.copy_study_version(src, dst, key, version)


def test_sigkill_during_copy_leaves_no_adoptable_debris():
    """A SIGKILLed copier leaves only `.tmp_migrate_*` staging debris on
    the destination — never a COMMITTED version.  Adoption refuses the
    record, the age-guarded sweep reclaims the debris, and the retried
    copy publishes cleanly (all-or-nothing, DESIGN.md §14)."""
    import multiprocessing as mp
    with tempfile.TemporaryDirectory() as src_d, \
            tempfile.TemporaryDirectory() as dst_d:
        async def seed(d):
            gw = StudyGateway(RESNET_SPACE, _cfg(d), GatewayConfig(slots=2))
            sid = gw.create_study()
            tr = await gw.ask(sid)
            gw.tell(sid, tr, obj(sid, tr.unit))
            await gw.drain()
            record = gw.export_for_migration(sid)   # commits version 1
            await gw.aclose()
            return record
        record = asyncio.run(seed(src_d))
        key, version = record["key"], record["version"]
        assert version in ckpt_mod.study_versions(src_d, key)

        ctx = mp.get_context("spawn")
        p = ctx.Process(target=_copy_then_die,
                        args=(src_d, dst_d, key, version), daemon=True)
        p.start()
        p.join(timeout=120)
        assert p.exitcode == -signal.SIGKILL

        sdir = ckpt_mod.study_dir(dst_d, key)
        debris = [f for f in os.listdir(sdir)
                  if f.startswith(".tmp_migrate_")]
        assert debris, "the SIGKILL arrived after publication?"
        # nothing committed -> a migration-grade adopt refuses the record
        assert not ckpt_mod.study_versions(dst_d, key)
        dst_gw = StudyGateway(RESNET_SPACE, _cfg(dst_d),
                              GatewayConfig(slots=2))
        with pytest.raises(RuntimeError, match="not.*committed"):
            dst_gw.adopt_study(record)
        # age-guarded sweep: fresh debris survives the default TTL, a
        # zero-TTL sweep (or an aged mtime) reclaims it
        assert ckpt_mod.sweep_tmp(sdir) == []
        swept = ckpt_mod.sweep_tmp(sdir, ttl_s=0.0)
        assert [os.path.basename(s) for s in swept] == debris
        # the retry publishes, and the adopt goes through
        ckpt_mod.copy_study_version(src_d, dst_d, key, version)
        assert version in ckpt_mod.study_versions(dst_d, key)
        dst_gw.adopt_study(record)
        assert dst_gw.study_info(int(record["sid"]))["n_obs"] == 1


# ---------------------------------------------------------------------------
# Multi-process soak (REPRO_SOAK gate, like tests/test_soak.py)
# ---------------------------------------------------------------------------
@pytest.mark.skipif(not os.environ.get("REPRO_SOAK"),
                    reason="multi-process fault soak; set REPRO_SOAK=1 "
                           "(dedicated CI job runs it)")
def test_soak_transport_twin_of_inmemory_federation_under_faults():
    """Long-haul twin run: a 2-process TransportFederation and the
    in-memory FederatedGateway driven through the SAME trace with the
    SAME fault schedule (checkpoint every 3 rounds, SIGKILL/revive of
    one shard mid-run) must stay bitwise twins end to end — streams,
    n_obs, best values.  The survivor serves through the crash; the
    revived process loses exactly the uncommitted round on both sides."""
    async def main(root_tf, root_fg):
        tf = _mk_tf(root_tf, slots=3, n_max=64)
        await tf.start()
        fg = FederatedGateway(RESNET_SPACE, _cfg(root_fg, n_max=64),
                              GatewayConfig(slots=3),
                              FederationConfig(n_shards=2))
        sids, by_shard = await _create_on_both(tf, 6)
        assert [fg.create_study(name=f"s{i}") for i in range(6)] == sids
        victim = tf.shard_of(sids[0])

        st_tf, st_fg = {s: [] for s in sids}, {s: [] for s in sids}
        for r in range(12):
            await drive_serial_rpc(tf, sids, 1, streams=st_tf)
            await drive_serial(fg, sids, 1, streams=st_fg)
            if r % 3 == 2:
                await tf.checkpoint()
                fg.checkpoint()
            if r == 6:
                tf.kill_shard(victim)
                fg.kill_shard(victim)
                # survivors keep serving mid-crash on both deployments
                s_surv = by_shard[1 - victim][0]
                tr = await tf.ask(s_surv)
                await tf.tell(s_surv, tr, obj(s_surv, tr.unit))
                await tf.drain()
                tr2 = await fg.ask(s_surv)
                fg.tell(s_surv, tr2, obj(s_surv, tr2.unit))
                await fg.drain()
                assert tuple(np.asarray(tr.unit).tolist()) == \
                    tuple(np.asarray(tr2.unit).tolist())
                st_tf[s_surv].append(tuple(np.asarray(tr.unit).tolist()))
                st_fg[s_surv].append(tuple(np.asarray(tr2.unit).tolist()))
                await tf.revive_shard(victim)
                fg.revive_shard(victim)
                # the uncommitted round is gone on BOTH: re-derive it
                for s in by_shard[victim]:
                    assert (await tf.study_info(s))["n_obs"] == \
                        fg.study_info(s)["n_obs"]
        assert st_tf == st_fg, "transport diverged from in-memory twin"
        for s in sids:
            i_tf, i_fg = await tf.study_info(s), fg.study_info(s)
            assert i_tf["n_obs"] == i_fg["n_obs"]
            assert i_tf["best_value"] == i_fg["best_value"]
        fed_sum, solo_sum = await tf.summary(), fg.summary()
        assert fed_sum["asks_served"] == solo_sum["asks_served"]
        assert fed_sum["absorbed"] == solo_sum["absorbed"]
        await tf.aclose()
        await fg.aclose()
    with tempfile.TemporaryDirectory() as a, \
            tempfile.TemporaryDirectory() as b:
        asyncio.run(main(a, b))
