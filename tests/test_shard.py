"""Device-mesh suggest path (DESIGN.md §8): spec parsing, placement, parity.

The load-bearing contract: `mesh="none"` and every sharded mesh spec are
the SAME computation — `suggest_all`, `absorb_round`, and the fused
`advance` must agree to float32 tolerance on every substrate.  On a
single device the `"1x1"` spec still exercises the full shard_map code
path, so the parity tests run everywhere; multi-shard specs are covered
when the suite runs under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the CI mesh job).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.acquisition import AcqConfig
from repro.hpo import mesh as mesh_mod
from repro.hpo.pool import SchedulerConfig, StudyPool
from repro.hpo.space import RESNET_SPACE

N_DEVICES = len(jax.devices())
IMPLEMENTATIONS = ["xla", "ref", "pallas"]

multi_device = pytest.mark.skipif(
    N_DEVICES < 2,
    reason="needs >= 2 devices (run with "
           "XLA_FLAGS=--xla_force_host_platform_device_count=8)")


def _cfg(mesh: str, implementation: str = "auto", **kw) -> SchedulerConfig:
    kw.setdefault("n_max", 16)
    kw.setdefault("acq", AcqConfig(restarts=8, ascent_steps=4))
    return SchedulerConfig(seed=0, mesh=mesh, implementation=implementation,
                           **kw)


def _pool(mesh: str, n_studies: int = 4, **kw) -> StudyPool:
    return StudyPool([RESNET_SPACE] * n_studies, _cfg(mesh, **kw))


def _drive(pool: StudyPool, rounds: int = 3) -> list[np.ndarray]:
    """Run fused advance rounds with a deterministic objective; collect
    every round's suggested units."""
    seen = []
    out = pool.advance_round([])                       # seeds every study
    for _ in range(rounds):
        events = [(s, out[s][0],
                   float(-np.sum((out[s][0].unit - 0.3 - 0.1 * s) ** 2)))
                  for s in range(pool.n_studies)]
        out = pool.advance_round(events)
        seen.append(np.stack([out[s][0].unit for s in range(pool.n_studies)]))
    return seen


# ---------------------------------------------------------------------------
# Spec parsing and mesh construction
# ---------------------------------------------------------------------------
def test_parse_spec():
    assert mesh_mod.parse_spec("none") is None
    assert mesh_mod.parse_spec("") is None
    assert mesh_mod.parse_spec("auto") == "auto"
    assert mesh_mod.parse_spec("4x2") == (4, 2)
    assert mesh_mod.parse_spec("8") == (8, 1)
    with pytest.raises(ValueError, match="mesh spec"):
        mesh_mod.parse_spec("4x2x1")
    with pytest.raises(ValueError, match="mesh spec"):
        mesh_mod.parse_spec("fast")


def test_build_none_and_auto_single_device():
    assert mesh_mod.build("none", 4, 8) is None
    # auto on one device degenerates to the unsharded path
    assert mesh_mod.build("auto", 4, 8,
                          devices=jax.devices()[:1]) is None


def test_build_explicit_1x1():
    m = mesh_mod.build("1x1", 4, 8)
    assert m is not None and m.n_devices == 1
    assert m.mesh.axis_names == (mesh_mod.STUDY_AXIS, mesh_mod.RESTART_AXIS)


def test_build_rejects_non_divisible_and_oversized():
    with pytest.raises(ValueError, match="divide n_studies"):
        mesh_mod.build("3x1", 4, 8, devices=jax.devices() * 4)
    with pytest.raises(ValueError, match="divide acq.restarts"):
        mesh_mod.build("1x3", 4, 8, devices=jax.devices() * 4)
    with pytest.raises(ValueError, match="devices"):
        mesh_mod.build(f"{N_DEVICES + 1}x1", N_DEVICES + 1, 8)


@multi_device
def test_build_auto_factors_devices():
    m = mesh_mod.build("auto", 4, 8)
    assert m is not None
    assert 4 % m.study_shards == 0
    assert 8 % m.restart_shards == 0
    assert m.n_devices <= N_DEVICES


# ---------------------------------------------------------------------------
# Parity: mesh=none == sharded, per substrate (acceptance criterion)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("implementation", IMPLEMENTATIONS)
def test_sharded_path_matches_unsharded_per_substrate(implementation):
    """`mesh="1x1"` runs the full shard_map path on one device; its rounds
    must match `mesh="none"` bit-for-tolerance on every substrate."""
    a = _pool("none", implementation=implementation)
    b = _pool("1x1", implementation=implementation)
    got_a = _drive(a)
    got_b = _drive(b)
    for ua, ub in zip(got_a, got_b):
        np.testing.assert_allclose(ua, ub, atol=2e-5)
    np.testing.assert_allclose(np.asarray(a.engine.state.l_buf),
                               np.asarray(b.engine.state.l_buf),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(a.engine.state.alpha),
                               np.asarray(b.engine.state.alpha),
                               rtol=1e-4, atol=1e-5)


@multi_device
@pytest.mark.parametrize("spec", ["2x1", "1x2", "2x2"])
def test_multi_shard_parity(spec):
    """Study sharding, restart sharding, and both at once reproduce the
    unsharded rounds (the all_gather reassembles the exact restart set)."""
    a = _pool("none")
    b = _pool(spec)
    for ua, ub in zip(_drive(a), _drive(b)):
        np.testing.assert_allclose(ua, ub, atol=2e-5)


@multi_device
def test_sharded_state_is_actually_sharded():
    pool = _pool("2x1")
    shards = pool.engine.state.l_buf.sharding
    assert shards.is_fully_replicated is False


def test_advance_matches_absorb_plus_suggest():
    """The fused round == absorb_round then suggest_all (same keys)."""
    a = _pool("none", n_studies=3)
    b = _pool("none", n_studies=3)
    out_a = a.advance_round([])
    out_b = b.advance_round([])
    events_a = [(s, out_a[s][0], 0.1 * s) for s in range(3)]
    events_b = [(s, out_b[s][0], 0.1 * s) for s in range(3)]
    # fused path
    fused = a.advance_round(events_a)
    # split path: masked absorb, then batched suggest with the same stream
    b.absorb_many(events_b)
    split = b.suggest_all(t=1)
    for s in range(3):
        np.testing.assert_allclose(fused[s][0].unit, split[s][0].unit,
                                   atol=2e-5)
    np.testing.assert_allclose(np.asarray(a.engine.state.l_buf),
                               np.asarray(b.engine.state.l_buf),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# advance_round semantics (ledger, seeding, multiplicity, capacity)
# ---------------------------------------------------------------------------
def test_advance_round_seeds_empty_studies_then_suggests():
    pool = _pool("none", n_studies=2)
    out = pool.advance_round([])
    assert set(out) == {0, 1}
    # no observations yet: these are seed trials, pending in the ledger
    assert all(tr.status == "pending" for trs in out.values() for tr in trs)
    assert pool.engine.n(0) == 0
    events = [(0, out[0][0], 1.0)]          # only study 0 completes
    out2 = pool.advance_round(events)
    assert out[0][0].status == "done" and out[0][0].value == 1.0
    assert pool.engine.n(0) == 1 and pool.engine.n(1) == 0
    # study 0 suggestion now comes from EI; study 1 is re-seeded
    assert len(out2[0]) == 1 and len(out2[1]) == 1


def test_advance_round_drains_multiplicity_overflow():
    pool = _pool("none", n_studies=2)
    out = pool.advance_round([])
    extra = pool.seed_trials(0, 2)
    events = [(0, out[0][0], 0.5), (0, extra[0], 0.6), (0, extra[1], 0.7)]
    pool.advance_round(events)
    assert pool.engine.n(0) == 3
    assert [t.status for t in pool.studies[0].trials[:1]] == ["done"]
    done_vals = sorted(t.value for t in pool.studies[0].trials
                       if t.status == "done")
    assert done_vals == [0.5, 0.6, 0.7]


def test_advance_round_studies_filter_absorbs_without_suggesting():
    """Tenants at budget absorb their completion but draw no new trial."""
    pool = _pool("none", n_studies=3)
    out = pool.advance_round([])
    events = [(s, out[s][0], 0.2 * s) for s in range(3)]
    out2 = pool.advance_round(events, studies=[0, 2])
    assert set(out2) == {0, 2}
    assert all(pool.engine.n(s) == 1 for s in range(3))   # all absorbed
    # study 1 got no new trial: its only ledger entry is the done seed
    assert [t.status for t in pool.studies[1].trials] == ["done"]
    # absorb-only round (no suggest targets) also works
    e2 = [(0, out2[0][0], 0.9)]
    assert pool.advance_round(e2, studies=[]) == {}
    assert pool.engine.n(0) == 2


def test_advance_round_capacity_is_all_or_nothing():
    from repro.core.gp import GPCapacityError
    pool = _pool("none", n_studies=2, n_max=2)
    out = pool.advance_round([])
    e0 = [(0, out[0][0], 0.1), (1, out[1][0], 0.2)]
    out = pool.advance_round(e0)
    overfull = [(0, out[0][0], 0.3), (0, pool.seed_trials(0, 1)[0], 0.4),
                (1, out[1][0], 0.5)]
    with pytest.raises(GPCapacityError):
        pool.advance_round(overfull)
    # nothing was absorbed, no trial marked done by the failed round
    assert pool.engine.n(0) == 1 and pool.engine.n(1) == 1
    assert all(t.status != "done" for t in pool.studies[1].trials[1:])


def test_advance_round_prng_stream_matches_suggest_all():
    """advance_round's batched key split draws the same per-study stream
    as suggest_all, so fused and unfused serving loops are reproducible."""
    a = _pool("none", n_studies=3)
    b = _pool("none", n_studies=3)
    oa = a.advance_round([])
    ob = b.suggest_all(t=1)
    for s in range(3):
        np.testing.assert_allclose(oa[s][0].unit, ob[s][0].unit)
    ea = [(s, oa[s][0], float(s)) for s in range(3)]
    eb = [(s, ob[s][0], float(s)) for s in range(3)]
    oa2 = a.advance_round(ea)
    b.absorb_many(eb)
    ob2 = b.suggest_all(t=1)
    for s in range(3):
        np.testing.assert_allclose(oa2[s][0].unit, ob2[s][0].unit, atol=2e-5)


def test_engine_counter_mirrors_track_device_state():
    """The host mirrors of n/since_refit must agree with the device state
    through fused rounds, routed absorbs, and external state assignment."""
    pool = _pool("none", n_studies=2)
    out = pool.advance_round([])
    pool.advance_round([(s, out[s][0], 0.1) for s in range(2)])
    pool.absorb(1, pool.seed_trials(1, 1)[0], 0.2)
    eng = pool.engine
    np.testing.assert_array_equal(
        np.asarray([eng.n(0), eng.n(1)]), np.asarray(eng.state.n))
    np.testing.assert_array_equal(
        np.asarray([eng.since_refit(0), eng.since_refit(1)]),
        np.asarray(eng.state.since_refit))
    # external assignment re-syncs
    eng.state = eng.state
    assert eng.n(0) == int(eng.state.n[0])


def test_checkpoint_restore_with_mesh(tmp_path):
    """A pool restored onto a mesh resumes the identical posterior."""
    cfg = dict(n_studies=2, ckpt_dir=str(tmp_path))
    a = _pool("1x1", **cfg)
    out = a.advance_round([])
    a.advance_round([(s, out[s][0], 0.3 * (s + 1)) for s in range(2)])
    a.checkpoint()
    b = _pool("1x1", **cfg)
    assert b.restore()
    assert b.engine.n(0) == a.engine.n(0) == 1
    np.testing.assert_allclose(np.asarray(a.engine.state.l_buf),
                               np.asarray(b.engine.state.l_buf))
    # restored pool continues the same PRNG streams
    sa = a.suggest_all(t=1)
    sb = b.suggest_all(t=1)
    for s in range(2):
        np.testing.assert_allclose(sa[s][0].unit, sb[s][0].unit, atol=2e-5)


def test_lag_refit_triggers_through_advance():
    """The per-study lag policy still fires on the fused path."""
    pool = _pool("none", n_studies=2, lag=2,
                 acq=AcqConfig(restarts=4, ascent_steps=2))
    out = pool.advance_round([])
    for _ in range(3):
        events = [(s, out[s][0], float(np.random.default_rng(0).uniform()))
                  for s in range(2)]
        out = pool.advance_round(events)
    # 3 absorbs with lag=2: a refit fired and reset the counter below 2
    assert pool.engine.since_refit(0) < 2
    assert int(pool.engine.state.since_refit[0]) == pool.engine.since_refit(0)


def test_bad_mesh_spec_rejected_at_pool_construction():
    # restarts=8 not divisible by 3 (or, on a 1-device host, too few
    # devices) — either way the pool must refuse the spec up front.
    with pytest.raises(ValueError, match="divide|devices"):
        _pool("1x3")


@multi_device
def test_suggest_all_sharded_matches_unsharded_direct():
    """Engine-level suggest_all parity under real multi-device sharding."""
    a = _pool("none")
    b = _pool("2x2" if N_DEVICES >= 4 else "2x1")
    out_a = _drive(a, rounds=1)
    out_b = _drive(b, rounds=1)
    np.testing.assert_allclose(out_a[0], out_b[0], atol=2e-5)
    keys = jnp.stack([jax.random.PRNGKey(7)] * 4)
    ua, va = a.engine.suggest_all(keys, top_t=2)
    ub, vb = b.engine.suggest_all(keys, top_t=2)
    np.testing.assert_allclose(np.asarray(ua), np.asarray(ub), atol=2e-5)
    np.testing.assert_allclose(np.asarray(va), np.asarray(vb),
                               rtol=1e-4, atol=1e-5)
