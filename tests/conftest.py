"""Test-session bootstrap: property tests run with or without `hypothesis`.

The property tests are tier-1 — they must RUN in every environment, not
skip.  When the real `hypothesis` package is installed (requirements-dev /
CI) it is used as-is, with a deterministic "ci" profile (fixed budget, no
wall-clock deadline, derandomized) selectable via HYPOTHESIS_PROFILE=ci.
When it is absent (the runtime image), `tests/_hypothesis_fallback.py`
installs a minimal deterministic implementation of the same API so the
property suite still executes real examples.

The fallback engages ONLY on `ModuleNotFoundError` for `hypothesis` itself;
a broken install (ImportError raised from inside the package, or a missing
dependency of it) propagates — masking that as "not installed" would
silently skip the property examples CI thinks it is running.
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.dirname(__file__))

from _hypothesis_fallback import ensure_hypothesis  # noqa: E402

_hyp = ensure_hypothesis()

if not getattr(_hyp, "__is_fallback__", False):
    # Real hypothesis: deterministic CI profile (fixed seed via derandomize,
    # bounded examples, no deadline — jit compiles blow any wall-clock
    # budget on the first example of each shape).
    _hyp.settings.register_profile(
        "ci", max_examples=25, deadline=None, derandomize=True,
        print_blob=True)
    _hyp.settings.register_profile("dev", max_examples=10, deadline=None)
    _hyp.settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "dev"))
