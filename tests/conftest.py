"""Test-session bootstrap: graceful degradation when `hypothesis` is absent.

The property tests in this suite use hypothesis, which is not part of the
runtime environment (see pyproject.toml's `test` extra).  When the real
package is unavailable we install a minimal stub into `sys.modules` whose
`@given` marks the decorated test as skipped — the deterministic tests keep
running and collection never errors out.
"""
from __future__ import annotations

import sys
import types

try:
    import hypothesis  # noqa: F401  (real package available: nothing to do)
except ImportError:
    import pytest

    def _strategy(*args, **kwargs):
        return None

    strategies = types.ModuleType("hypothesis.strategies")
    for _name in ("integers", "floats", "booleans", "text", "lists",
                  "tuples", "sampled_from", "one_of", "just"):
        setattr(strategies, _name, _strategy)

    def given(*args, **kwargs):
        def decorate(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed; property test skipped")(fn)
        return decorate

    def settings(*args, **kwargs):
        def decorate(fn):
            return fn
        return decorate

    settings.register_profile = lambda *a, **k: None
    settings.load_profile = lambda *a, **k: None

    stub = types.ModuleType("hypothesis")
    stub.given = given
    stub.settings = settings
    stub.strategies = strategies
    stub.HealthCheck = types.SimpleNamespace(
        too_slow=None, data_too_large=None, filter_too_much=None)
    stub.assume = lambda *a, **k: True
    stub.note = lambda *a, **k: None
    stub.__is_stub__ = True

    sys.modules["hypothesis"] = stub
    sys.modules["hypothesis.strategies"] = strategies
