"""Model-zoo tests: per-arch smoke, equivalence of attention/SSM variants,
and prefill→decode consistency against the full forward pass.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import (decode_step, forward, init_params, lm_loss, prefill)
from repro.models import attention as attn_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models.model import logits_from_hidden

KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=64, key=KEY):
    if cfg.frontend == "frames":
        inputs = jax.random.normal(key, (b, s, cfg.d_model), jnp.float32)
    else:
        inputs = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    targets = jax.random.randint(jax.random.fold_in(key, 1), (b, s), 0,
                                 cfg.vocab_size)
    return {"inputs": inputs, "targets": targets}


# ---------------------------------------------------------------------------
# Per-arch smoke: reduced config, one forward/train step, shapes + finiteness
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_grad(arch):
    cfg = get_config(arch, reduced=True)
    params, specs = init_params(cfg, KEY)
    assert jax.tree.structure(params) == jax.tree.structure(
        specs, is_leaf=lambda x: isinstance(x, tuple))
    batch = _batch(cfg)

    def loss_fn(p):
        loss, metrics = lm_loss(p, cfg, batch)
        return loss, metrics

    (loss, metrics), grads = jax.jit(
        jax.value_and_grad(loss_fn, has_aux=True))(params)
    assert np.isfinite(float(loss))
    assert np.isfinite(float(metrics["ce"]))
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS
                                  if get_config(a).supports_decode
                                  and get_config(a).frontend == "none"])
def test_arch_decode_consistent_with_forward(arch):
    """Prefill + decode must reproduce the full forward logits.

    MoE archs run with a no-drop capacity factor: with dropping enabled the
    token-drop pattern legitimately depends on row composition (documented
    Switch/GShard semantics), so exact consistency is only defined dropless.
    """
    cfg = get_config(arch, reduced=True)
    cfg = dataclasses.replace(cfg, dtype="float32", capacity_factor=4.0)
    params, _ = init_params(cfg, KEY)
    b, s, extra = 2, 32, 3
    toks = jax.random.randint(KEY, (b, s + extra), 0, cfg.vocab_size)
    x, _, _ = forward(params, cfg, toks)
    full_logits = logits_from_hidden(params, cfg, x)
    lp, cache = jax.jit(lambda p, t: prefill(p, cfg, t, s + extra))(
        params, toks[:, :s])
    np.testing.assert_allclose(np.asarray(lp[:, 0]),
                               np.asarray(full_logits[:, s - 1]),
                               atol=1e-4, rtol=1e-4)
    step = jax.jit(lambda p, c, t: decode_step(p, cfg, c, t))
    for i in range(extra):
        ld, cache = step(params, cache, toks[:, s + i:s + i + 1])
        np.testing.assert_allclose(np.asarray(ld[:, 0]),
                                   np.asarray(full_logits[:, s + i]),
                                   atol=1e-4, rtol=1e-4)


def test_encoder_arch_is_bidirectional():
    cfg = get_config("hubert-xlarge", reduced=True)
    params, _ = init_params(cfg, KEY)
    frames = jax.random.normal(KEY, (1, 16, cfg.d_model))
    x1, _, _ = forward(params, cfg, frames)
    # Perturb the LAST frame; for a bidirectional encoder the FIRST position
    # must change too.
    frames2 = frames.at[:, -1].add(1.0)
    x2, _, _ = forward(params, cfg, frames2)
    assert float(jnp.max(jnp.abs(x1[:, 0] - x2[:, 0]))) > 1e-6


def test_causal_arch_is_causal():
    cfg = get_config("granite-3-2b", reduced=True)
    cfg = dataclasses.replace(cfg, dtype="float32")
    params, _ = init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (1, 16), 0, cfg.vocab_size)
    x1, _, _ = forward(params, cfg, toks)
    toks2 = toks.at[:, -1].set((toks[:, -1] + 1) % cfg.vocab_size)
    x2, _, _ = forward(params, cfg, toks2)
    np.testing.assert_allclose(np.asarray(x1[:, :-1]), np.asarray(x2[:, :-1]),
                               atol=1e-5)


# ---------------------------------------------------------------------------
# Attention implementation equivalences
# ---------------------------------------------------------------------------
def _qkv(b=2, s=256, h=4, kv=2, dh=16, key=KEY):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, h, dh))
    k = jax.random.normal(ks[1], (b, s, kv, dh))
    v = jax.random.normal(ks[2], (b, s, kv, dh))
    return q, k, v


@pytest.mark.parametrize("causal", [True, False])
def test_chunked_attention_matches_full(causal):
    q, k, v = _qkv()
    full = attn_mod.full_attention(q, k, v, causal=causal)
    chunked = attn_mod.chunked_attention(q, k, v, causal=causal, q_chunk=64,
                                         kv_chunk=64)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(full),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("window", [16, 64, 100])
def test_banded_attention_matches_masked_full(window):
    q, k, v = _qkv(s=256)
    full = attn_mod.full_attention(q, k, v, causal=True, window=window)
    banded = attn_mod.banded_attention(q, k, v, window=window, q_chunk=64)
    np.testing.assert_allclose(np.asarray(banded), np.asarray(full),
                               atol=2e-5, rtol=2e-5)


def test_chunked_attention_windowed_matches_full():
    q, k, v = _qkv(s=256)
    full = attn_mod.full_attention(q, k, v, causal=True, window=32)
    chunked = attn_mod.chunked_attention(q, k, v, causal=True, window=32,
                                         q_chunk=64, kv_chunk=64)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(full),
                               atol=2e-5, rtol=2e-5)


def test_decode_attention_matches_full_last_position():
    q, k, v = _qkv(s=64)
    full = attn_mod.full_attention(q, k, v, causal=True)
    out = attn_mod.decode_attention(q[:, -1:], k, v,
                                    jnp.asarray(63, jnp.int32))
    np.testing.assert_allclose(np.asarray(out[:, 0]),
                               np.asarray(full[:, -1]), atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# SSD (Mamba2) and mLSTM chunked == recurrent
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("l,chunk", [(64, 16), (100, 32), (128, 128)])
def test_ssd_chunked_matches_recurrent(l, chunk):
    b, h, p, g, n = 2, 4, 8, 2, 16
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    bm = jax.random.normal(ks[3], (b, l, g, n)) * 0.3
    cm = jax.random.normal(ks[4], (b, l, g, n)) * 0.3
    y_chunk, hc = ssm_mod.ssd_chunked(x, dt, a, bm, cm, chunk=chunk,
                                      return_final_state=True)
    y_rec, hr = ssm_mod.ssd_recurrent_ref(x, dt, a, bm, cm)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_rec),
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(hc), np.asarray(hr), atol=2e-4,
                               rtol=2e-4)


def test_ssd_chunked_with_initial_state():
    b, l, h, p, g, n = 1, 32, 2, 4, 1, 8
    ks = jax.random.split(KEY, 6)
    x = jax.random.normal(ks[0], (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    bm = jax.random.normal(ks[3], (b, l, g, n)) * 0.3
    cm = jax.random.normal(ks[4], (b, l, g, n)) * 0.3
    h0 = jax.random.normal(ks[5], (b, h, p, n)) * 0.1
    y_chunk = ssm_mod.ssd_chunked(x, dt, a, bm, cm, chunk=8, h0=h0)
    y_rec, _ = ssm_mod.ssd_recurrent_ref(x, dt, a, bm, cm, h0=h0)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_rec),
                               atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("l,chunk", [(64, 16), (96, 32)])
def test_mlstm_chunked_matches_recurrent(l, chunk):
    b, h, dh = 2, 2, 8
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (b, l, h, dh))
    k = jax.random.normal(ks[1], (b, l, h, dh)) / (dh ** 0.5)
    v = jax.random.normal(ks[2], (b, l, h, dh))
    logi = jax.random.normal(ks[3], (b, l, h))
    logf = jax.nn.log_sigmoid(jax.random.normal(ks[4], (b, l, h)) + 3.0)
    y_chunk, (c1, n1, m1) = xlstm_mod.mlstm_chunked(
        q, k, v, logi, logf, chunk=chunk, return_final_state=True)
    y_rec, (c2, n2, m2) = xlstm_mod.mlstm_recurrent_ref(q, k, v, logi, logf)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_rec),
                               atol=2e-4, rtol=2e-4)
    # States agree up to the stabilizer gauge: compare C / exp(m) etc.
    np.testing.assert_allclose(np.asarray(c1 * jnp.exp(m1)[..., None, None]),
                               np.asarray(c2 * jnp.exp(m2)[..., None, None]),
                               atol=2e-3, rtol=2e-3)


def test_mlstm_decode_continues_chunked():
    """Chunked prefill state must seed the recurrent decode exactly."""
    b, l, h, dh = 1, 32, 2, 8
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (b, l + 1, h, dh))
    k = jax.random.normal(ks[1], (b, l + 1, h, dh)) / (dh ** 0.5)
    v = jax.random.normal(ks[2], (b, l + 1, h, dh))
    logi = jax.random.normal(ks[3], (b, l + 1, h))
    logf = jax.nn.log_sigmoid(jax.random.normal(ks[4], (b, l + 1, h)) + 3.0)
    y_all, _ = xlstm_mod.mlstm_recurrent_ref(q, k, v, logi, logf)
    _, state = xlstm_mod.mlstm_chunked(q[:, :l], k[:, :l], v[:, :l],
                                       logi[:, :l], logf[:, :l], chunk=8,
                                       return_final_state=True)
    y_last, _ = xlstm_mod.mlstm_recurrent_ref(
        q[:, l:], k[:, l:], v[:, l:], logi[:, l:], logf[:, l:], state)
    np.testing.assert_allclose(np.asarray(y_last[:, 0]),
                               np.asarray(y_all[:, l]), atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------------------------
# MoE behaviour
# ---------------------------------------------------------------------------
def test_moe_no_drop_matches_dense_combination():
    """With capacity >= tokens, MoE output = sum_k gate_k * expert_k(x)."""
    from repro.models import moe as moe_mod
    d, e, ff = 16, 4, 8
    params, _ = moe_mod.init_moe_params(KEY, d, ff, e, jnp.float32)
    x = jax.random.normal(KEY, (1, 8, d))
    out, aux = moe_mod.moe_ffn(params, x, top_k=2, capacity_factor=8.0)
    # manual dense evaluation
    logits = x.reshape(-1, d) @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    gv, ei = jax.lax.top_k(probs, 2)
    gv = gv / gv.sum(-1, keepdims=True)
    want = jnp.zeros((8, d))
    for t in range(8):
        for j in range(2):
            eidx = int(ei[t, j])
            h = (jax.nn.silu(x.reshape(-1, d)[t] @ params["wg"][eidx])
                 * (x.reshape(-1, d)[t] @ params["wi"][eidx]))
            want = want.at[t].add(gv[t, j] * (h @ params["wo"][eidx]))
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(want),
                               atol=1e-4, rtol=1e-3)
    assert float(aux) > 0.0


def test_moe_capacity_drops_tokens_to_residual():
    from repro.models import moe as moe_mod
    d, e, ff = 8, 2, 8
    params, _ = moe_mod.init_moe_params(KEY, d, ff, e, jnp.float32)
    x = jax.random.normal(KEY, (1, 64, d))
    out_tight, _ = moe_mod.moe_ffn(params, x, top_k=2, capacity_factor=0.25)
    out_loose, _ = moe_mod.moe_ffn(params, x, top_k=2, capacity_factor=8.0)
    # tight capacity must change (drop) some outputs
    assert float(jnp.max(jnp.abs(out_tight - out_loose))) > 1e-6


# ---------------------------------------------------------------------------
# Config metadata
# ---------------------------------------------------------------------------
def test_param_counts_match_family_scale():
    """Full configs should land in the advertised parameter range."""
    expect = {
        "granite-3-2b": (2.0e9, 3.4e9),
        "deepseek-coder-33b": (30e9, 36e9),
        "qwen3-moe-30b-a3b": (20e9, 36e9),
        "chameleon-34b": (30e9, 38e9),
        "minicpm3-4b": (3.2e9, 5.5e9),
        "gemma3-4b": (3.0e9, 5.5e9),
        "zamba2-1.2b": (0.9e9, 1.9e9),
        "xlstm-1.3b": (0.9e9, 1.9e9),
        "hubert-xlarge": (0.8e9, 1.3e9),
        "granite-moe-3b-a800m": (2.4e9, 4.2e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).n_params()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"


def test_long_context_support_flags():
    runs_500k = {a: get_config(a).supports_long_context for a in ARCH_IDS}
    assert runs_500k["xlstm-1.3b"] and runs_500k["zamba2-1.2b"] \
        and runs_500k["gemma3-4b"]
    assert not runs_500k["deepseek-coder-33b"]
    assert not runs_500k["chameleon-34b"]
