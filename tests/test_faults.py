"""Fault-injection suite for StudyPool + StudyGateway + the federation:
trials raising mid-round, capacity overflow mid-drain, checkpoint/eviction
write failures, kill/restore, shard crashes (in-process AND real SIGKILLed
worker processes via repro.hpo.shard_worker), and migration IO faults —
asserting the all-or-nothing contracts and that recovery never replays a
pre-crash batch (DESIGN.md §9, §13).  The socket-transport fault matrix
lives in tests/test_transport.py; shared helpers in tests/_traffic.py."""
import asyncio
import json
import os
import signal
import subprocess
import sys
import tempfile
import time

import numpy as np
import pytest

from _traffic import assert_slots_equal, assert_streams_identical, \
    drive_serial
from _traffic import foreign_trial as _foreign_trial
from _traffic import make_cfg as _cfg
from _traffic import objective as obj
from _traffic import slot_bytes as _slot_bytes
from repro import checkpoint as ckpt_mod
from repro.checkpoint import store as store_mod
from repro.core import GPCapacityError
from repro.hpo import (FederatedGateway, FederationConfig, GatewayConfig,
                       StudyGateway, StudyPool)
from repro.hpo import transport as tx
from repro.hpo.space import RESNET_SPACE


# ---------------------------------------------------------------------------
# Trials raising mid-round
# ---------------------------------------------------------------------------
def test_trial_raising_mid_round_penalizes_and_isolates():
    """A client whose training run throws reports tell_failure: the trial
    ledger records the fault, the penalty pseudo-observation rides the same
    coalesced absorb path, and neighbors advance undisturbed."""
    async def main(d):
        gw = StudyGateway(RESNET_SPACE, _cfg(d, failure_penalty=-9.0),
                          GatewayConfig(slots=2))
        bad, good = gw.create_study(), gw.create_study()
        t_bad, t_good = await asyncio.gather(gw.ask(bad), gw.ask(good))
        gw.tell_failure(bad, t_bad, "OOM: node lost")
        gw.tell(good, t_good, 0.7)
        await gw.drain()
        assert t_bad.status == "failed" and "OOM" in t_bad.error
        # penalty absorbed into the owning study only
        slot_bad = gw._studies[bad].slot
        assert gw._studies[bad].n_obs == 1
        assert float(gw.pool.state(slot_bad).y_buf[0]) == pytest.approx(-9.0)
        assert gw._studies[good].n_obs == 1
        # a penalty pseudo-observation is never reported as the best
        assert gw.study_info(bad)["best_value"] is None
        assert gw.study_info(good)["best_value"] == pytest.approx(0.7)
        # the failed study keeps serving
        t2 = await gw.ask(bad)
        gw.tell(bad, t2, 0.1)
        await gw.drain()
        assert gw._studies[bad].n_obs == 2
        await gw.aclose()
    with tempfile.TemporaryDirectory() as d:
        asyncio.run(main(d))


def test_trial_failure_without_penalty_is_ledger_only():
    async def main(d):
        gw = StudyGateway(RESNET_SPACE, _cfg(d), GatewayConfig(slots=1))
        s = gw.create_study()
        tr = await gw.ask(s)
        gw.tell_failure(s, tr, "SIGKILL")
        await gw.drain()
        assert tr.status == "failed" and gw._studies[s].n_obs == 0
        await gw.aclose()
    with tempfile.TemporaryDirectory() as d:
        asyncio.run(main(d))


# ---------------------------------------------------------------------------
# Capacity overflow mid-drain (gateway layer over absorb_many's contract)
# ---------------------------------------------------------------------------
def test_capacity_overflow_mid_drain_absorbs_nothing_then_recovers():
    """A tick whose tell queue overflows a study must absorb NOTHING
    (advance_round capacity-checks the whole round first); the absorbable
    prefix requeues and lands next tick, the rest dead-letters."""
    with tempfile.TemporaryDirectory() as d:
        gw = StudyGateway(RESNET_SPACE, _cfg(d, n_max=2),
                          GatewayConfig(slots=2, max_inflight=8))
        s = gw.create_study()
        rng = np.random.default_rng(0)
        gw.tell(s, _foreign_trial(rng.uniform(size=3)), 0.5)
        gw.tick()
        assert gw._studies[s].n_obs == 1
        a, b = (_foreign_trial(rng.uniform(size=3)) for _ in range(2))
        gw.tell(s, a, 0.1)
        gw.tell(s, b, 0.2)           # 1 + 2 > n_max=2: the round must abort
        with pytest.raises(GPCapacityError):
            gw.tick()
        # all-or-nothing: neither observation entered the GP or the ledger
        assert gw._studies[s].n_obs == 1
        slot = gw._studies[s].slot
        assert gw.pool.engine.n(slot) == 1
        # the fitting tell requeued; the unfittable one dead-lettered
        assert len(gw._tells) == 1 and gw._tells[0][1] is a
        assert len(gw.dead_tells) == 1 and gw.dead_tells[0][1] is b
        assert b.status == "failed" and "capacity" in b.error
        gw.tick()                    # recovery: the requeued tell absorbs
        assert gw._studies[s].n_obs == 2 and a.status == "done"


def test_capacity_abort_fails_coalesced_asks_but_spares_neighbors():
    """Asks coalesced into an aborted round get the error at their future;
    a neighbor study keeps serving on the next tick."""
    async def main(d):
        gw = StudyGateway(RESNET_SPACE, _cfg(d, n_max=1),
                          GatewayConfig(slots=2, max_inflight=8))
        full, ok = gw.create_study(), gw.create_study()
        gw.tell(full, _foreign_trial(np.full(3, 0.5)), 0.4)
        await asyncio.sleep(0)       # no ticker yet: queue is still cold
        gw.tick()
        assert gw._studies[full].n_obs == 1
        # overflow tell + a concurrent ask for the healthy neighbor
        gw.tell(full, _foreign_trial(np.full(3, 0.25)), 0.1)
        ask = asyncio.ensure_future(gw.ask(ok))
        with pytest.raises(GPCapacityError):
            await ask
        # neighbor recovers with a plain re-ask
        tr = await gw.ask(ok)
        gw.tell(ok, tr, 0.3)
        await gw.drain()
        assert gw._studies[ok].n_obs == 1
        await gw.aclose()
    with tempfile.TemporaryDirectory() as d:
        asyncio.run(main(d))


# ---------------------------------------------------------------------------
# Checkpoint / eviction write failures
# ---------------------------------------------------------------------------
def test_checkpoint_write_failure_leaves_previous_snapshot(monkeypatch):
    with tempfile.TemporaryDirectory() as d:
        cfg = _cfg(d)
        pool = StudyPool([RESNET_SPACE] * 2, cfg)
        rng = np.random.default_rng(0)
        pool.absorb(0, pool._make_trial(0, rng.uniform(size=3).astype(
            np.float32)), 0.5)
        pool.checkpoint()
        good_step = ckpt_mod.latest_step(d)
        pool.absorb(1, pool._make_trial(1, rng.uniform(size=3).astype(
            np.float32)), 0.7)

        def boom(*a, **k):
            raise OSError("disk full")
        monkeypatch.setattr(store_mod.np, "savez", boom)
        with pytest.raises(OSError, match="disk full"):
            pool.checkpoint()
        monkeypatch.undo()
        # no committed garbage, no uncommitted debris, old snapshot intact
        assert ckpt_mod.latest_step(d) == good_step
        assert not [f for f in os.listdir(d) if f.startswith(".tmp_ckpt_")]
        # the pool itself is unharmed: a retry commits the current state
        pool.checkpoint()
        assert ckpt_mod.latest_step(d) > good_step
        fresh = StudyPool([RESNET_SPACE] * 2, cfg)
        assert fresh.restore()
        assert fresh.engine.n(0) == 1 and fresh.engine.n(1) == 1


def test_eviction_write_failure_keeps_study_resident(monkeypatch):
    async def main(d):
        gw = StudyGateway(RESNET_SPACE, _cfg(d), GatewayConfig(slots=1))
        a, b = gw.create_study(), gw.create_study()
        tr = await gw.ask(a)
        gw.tell(a, tr, 0.5)
        await gw.drain()

        def boom(*args, **kw):
            raise OSError("evict store down")
        monkeypatch.setattr(store_mod.np, "savez", boom)
        # b's ask needs a's slot; the eviction snapshot fails to commit →
        # the tick surfaces the IO error, requeues the ask untouched, and
        # a stays resident and serving
        gw.ask_nowait(b)
        with pytest.raises(OSError):
            gw.tick()
        monkeypatch.undo()
        log_a = gw._studies[a]
        assert log_a.slot is not None and log_a.version == 0
        assert not ckpt_mod.list_studies(d)
        # store back up: the deferred ask now succeeds via a real eviction
        gw.tick()
        assert gw._studies[b].slot is not None
        assert log_a.slot is None and log_a.version == 1
        await gw.aclose()
    with tempfile.TemporaryDirectory() as d:
        asyncio.run(main(d))


def test_tell_with_malformed_unit_rejected_at_caller():
    """A wrong-dim unit must fail the offending tell() immediately — inside
    the fused dispatch it would abort the whole coalesced tick, losing the
    round's tells and stranding every other study's futures."""
    with tempfile.TemporaryDirectory() as d:
        gw = StudyGateway(RESNET_SPACE, _cfg(d), GatewayConfig(slots=2))
        s = gw.create_study()
        with pytest.raises(ValueError, match="unit shape"):
            gw.tell(s, _foreign_trial(np.zeros(5)), 0.1)
        with pytest.raises(ValueError, match="finite"):
            gw.tell(s, _foreign_trial(np.full(3, np.nan)), 0.1)
        with pytest.raises(ValueError, match="finite"):
            gw.tell(s, _foreign_trial(np.full(3, 5.0)), 0.1)
        assert not gw._tells and gw._studies[s].pending_tells == 0


def test_io_fault_fails_parked_asks_instead_of_hanging(monkeypatch):
    """An eviction-store IO fault during an async tick must surface at the
    parked ask() futures, not silently kill the ticker with the clients
    still awaiting (regression: the ticker died, the asks were requeued
    unresolved, and the gateway hung forever).  Queued tells survive and
    the gateway keeps serving once the store recovers."""
    async def main(d):
        gw = StudyGateway(RESNET_SPACE, _cfg(d), GatewayConfig(slots=1))
        a, b = gw.create_study(), gw.create_study()
        tr = await gw.ask(a)
        gw.tell(a, tr, 0.5)
        await gw.drain()

        def boom(*args, **kw):
            raise OSError("evict store down")
        monkeypatch.setattr(store_mod.np, "savez", boom)
        # b's ask forces an eviction of a; the snapshot write fails → the
        # error lands on b's future instead of hanging it
        with pytest.raises(OSError, match="evict store down"):
            await asyncio.wait_for(gw.ask(b), timeout=30)
        monkeypatch.undo()
        assert gw._studies[a].slot is not None   # a stayed resident
        # store back up: a fresh ask re-creates the ticker and serves
        tb = await asyncio.wait_for(gw.ask(b), timeout=30)
        gw.tell(b, tb, 0.2)
        await gw.drain()
        assert gw.study_info(b)["n_obs"] == 1
        await gw.aclose()
    with tempfile.TemporaryDirectory() as d:
        asyncio.run(main(d))


# ---------------------------------------------------------------------------
# Kill / restore
# ---------------------------------------------------------------------------
def test_gateway_restore_replays_no_pre_crash_batch():
    """Extends PR 2's PRNG-persistence guarantee to the gateway + eviction:
    nothing suggested before the crash is ever suggested again after
    restore, and the restored run re-derives post-checkpoint work
    identically to an uninterrupted gateway."""
    async def drive(gw, sids, rounds, streams):
        for _ in range(rounds):
            for s in sids:
                tr = await gw.ask(s)
                streams[s].append(tuple(np.asarray(tr.unit).tolist()))
                gw.tell(s, tr, obj(s, tr.unit))
                await gw.drain()

    async def main(d_ref, d_crash):
        # uninterrupted reference
        ref = StudyGateway(RESNET_SPACE, _cfg(d_ref), GatewayConfig(slots=2))
        ref_sids = [ref.create_study() for _ in range(3)]
        ref_streams = {s: [] for s in ref_sids}
        await drive(ref, ref_sids, 4, ref_streams)
        await ref.aclose()

        gw = StudyGateway(RESNET_SPACE, _cfg(d_crash), GatewayConfig(slots=2))
        sids = [gw.create_study() for _ in range(3)]
        pre = {s: [] for s in sids}
        await drive(gw, sids, 2, pre)
        gw.checkpoint()              # quiescent snapshot
        await drive(gw, sids, 1, {s: [] for s in sids})  # lost to the crash
        await gw.aclose()            # CRASH (post-checkpoint work discarded)

        gw2 = StudyGateway(RESNET_SPACE, _cfg(d_crash), GatewayConfig(slots=2))
        assert gw2.restore()
        post = {s: [] for s in sids}
        await drive(gw2, sids, 2, post)
        await gw2.aclose()

        for s in sids:
            assert set(pre[s]).isdisjoint(post[s]), \
                "restored gateway replayed a pre-crash suggestion"
            # restored == uninterrupted, bitwise, through eviction churn
            assert pre[s] + post[s] == ref_streams[s]
    with tempfile.TemporaryDirectory() as d_ref, \
            tempfile.TemporaryDirectory() as d_crash:
        asyncio.run(main(d_ref, d_crash))


def test_restored_gateway_checkpoints_never_regress_step():
    """The pool's snapshot step must resume from the restored snapshot's
    own step, not from the resident ledgers: with studies evicted, the
    absorbed observations live in partial snapshots, so a ledger count
    under-counts and a post-restore checkpoint written at a LOWER step
    would be shadowed forever by the pre-crash one (restore_latest picks
    the max) — silently losing the whole resumed run."""
    async def drive(gw, s, rounds):
        for _ in range(rounds):
            tr = await gw.ask(s)
            gw.tell(s, tr, obj(s, tr.unit))
            await gw.drain()

    async def main(d):
        gw = StudyGateway(RESNET_SPACE, _cfg(d), GatewayConfig(slots=1))
        a, b = gw.create_study(), gw.create_study()
        await drive(gw, a, 2)
        await drive(gw, b, 2)        # evicts a: its 2 obs leave the ledgers
        gw.checkpoint()
        step1 = ckpt_mod.latest_step(d)
        await gw.aclose()

        gw2 = StudyGateway(RESNET_SPACE, _cfg(d), GatewayConfig(slots=1))
        assert gw2.restore()
        await drive(gw2, a, 1)       # restores a on demand (evicting b)
        gw2.checkpoint()
        assert ckpt_mod.latest_step(d) > step1, \
            "post-restore checkpoint regressed the snapshot step"
        await gw2.aclose()

        # the run-2 checkpoint is the recovery point and is self-consistent
        gw3 = StudyGateway(RESNET_SPACE, _cfg(d), GatewayConfig(slots=1))
        assert gw3.restore()
        assert gw3._studies[a].n_obs == 3 and gw3._studies[b].n_obs == 2
        # its registry's study versions survived the commit-time prune:
        # restore-on-demand of the evicted tenant must still succeed
        evicted = a if gw3._studies[a].slot is None else b
        await drive(gw3, evicted, 1)
        await gw3.aclose()
    with tempfile.TemporaryDirectory() as d:
        asyncio.run(main(d))


def test_restore_with_mismatched_n_max_raises():
    """A checkpoint taken at one n_max must not load into a pool built with
    another: the buffers are fixed-size, and a silent load would let the
    capacity guards (reading the new cfg) drive appends past the restored
    rows — JAX clamps the out-of-bounds index and overwrites the last row
    (regression: only the study COUNT was validated, not the shapes)."""
    async def main(d):
        gw = StudyGateway(RESNET_SPACE, _cfg(d, n_max=10),
                          GatewayConfig(slots=2))
        s = gw.create_study()
        tr = await gw.ask(s)
        gw.tell(s, tr, 0.5)
        await gw.drain()
        gw.checkpoint()
        await gw.aclose()
        gw2 = StudyGateway(RESNET_SPACE, _cfg(d, n_max=13),
                           GatewayConfig(slots=2))
        with pytest.raises(ValueError, match="shape mismatch"):
            gw2.restore()
    with tempfile.TemporaryDirectory() as d:
        asyncio.run(main(d))


def test_pool_kill_mid_round_restores_to_last_commit():
    """A crash between checkpoints rewinds to the last committed snapshot;
    the replayed round re-derives the same state it would have had."""
    with tempfile.TemporaryDirectory() as d:
        cfg = _cfg(d, ckpt_every=1)
        pool = StudyPool([RESNET_SPACE] * 2, cfg)
        rng = np.random.default_rng(3)
        units = [rng.uniform(size=3).astype(np.float32) for _ in range(4)]
        pool.absorb(0, pool._make_trial(0, units[0]), 0.1)
        pool.absorb(1, pool._make_trial(1, units[1]), 0.2)
        alpha_commit = np.asarray(pool.state(0).alpha).copy()
        # round 2 completes on the GP but the process dies before its
        # checkpoint commits: simulate by absorbing with cadence disabled
        pool.cfg = _cfg(d, ckpt_every=10_000)
        pool.absorb(0, pool._make_trial(0, units[2]), 0.3)

        fresh = StudyPool([RESNET_SPACE] * 2, _cfg(d, ckpt_every=1))
        assert fresh.restore()
        assert fresh.engine.n(0) == 1 and fresh.engine.n(1) == 1
        np.testing.assert_array_equal(np.asarray(fresh.state(0).alpha),
                                      alpha_commit)
        # replaying the lost round lands on the same posterior
        fresh.absorb(0, fresh._make_trial(0, units[2]), 0.3)
        np.testing.assert_array_equal(np.asarray(fresh.state(0).alpha),
                                      np.asarray(pool.state(0).alpha))


# ---------------------------------------------------------------------------
# qEI fantasy rollback exactness (DESIGN.md §12)
# ---------------------------------------------------------------------------
def _twin_pools(d1, d2, n_max=48):
    pa = StudyPool([RESNET_SPACE], _cfg(d1, n_max=n_max))
    pb = StudyPool([RESNET_SPACE], _cfg(d2, n_max=n_max))
    rng = np.random.RandomState(7)
    for _ in range(3):
        u = rng.rand(RESNET_SPACE.dim).astype(np.float32)
        v = obj(0, u)
        pa.absorb(0, _foreign_trial(u), v)
        pb.absorb(0, _foreign_trial(u), v)
    return pa, pb


@pytest.mark.parametrize("order", [
    [0, 1, 2, 3],          # tell all, in suggestion order
    [2, 0, 3, 1],          # out of order
    [1, 3],                # partial — the rest told after MORE q-asks
])
def test_ask_q_rollback_bitwise_equals_never_fantasized(order):
    """ask(q) appends fantasy rows; as the real tells arrive (any order,
    any subset) the rollback must be exact: a twin pool fed the identical
    real observations and no fantasies ends in a BITWISE-identical state."""
    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2:
        pa, pb = _twin_pools(d1, d2)
        trials = pa.ask_q(0, 4)
        assert pa.fantasy_active(0) == 4 and pa.n_real(0) == 3
        told = []
        for i in order:
            tr = trials[i]
            v = obj(0, tr.unit)
            pa.absorb(0, tr, v)
            pb.absorb(0, _foreign_trial(tr.unit), v)
            told.append(i)
        rest = [i for i in range(4) if i not in told]
        if rest:
            # keep fantasies live across another q-ask, then drain fully
            more = pa.ask_q(0, 2)
            for tr in [trials[i] for i in rest] + list(more):
                v = obj(0, tr.unit)
                pa.absorb(0, tr, v)
                pb.absorb(0, _foreign_trial(tr.unit), v)
        assert pa.fantasy_active(0) == 0
        assert pa.engine.n(0) == pb.engine.n(0)
        a, b = _slot_bytes(pa, 0), _slot_bytes(pb, 0)
        for leaf in a:
            assert a[leaf] == b[leaf], f"{leaf} differs after rollback"


def test_ask_q_checkpoint_mid_fantasy_snapshots_only_real_state():
    """A pool checkpoint taken with fantasy rows outstanding must write
    only the real ledger (rollback → snapshot → re-fantasize): the
    restored pool is bitwise the never-fantasized twin, while the live
    pool keeps serving its pending fantasies."""
    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2:
        pa, pb = _twin_pools(d1, d2)
        trials = pa.ask_q(0, 3)
        rb0 = pa.fantasy_rollbacks
        assert pa.checkpoint() is not None
        # the live pool still has its fantasies (re-appended post-snapshot)
        assert pa.fantasy_active(0) == 3
        assert pa.fantasy_rollbacks == rb0 + 1
        # kill/recover: the restored pool sees only real observations
        pr = StudyPool([RESNET_SPACE], _cfg(d1, n_max=48))
        assert pr.restore()
        assert pr.fantasy_active(0) == 0 and pr.engine.n(0) == 3
        a, b = _slot_bytes(pr, 0), _slot_bytes(pb, 0)
        for leaf in a:
            assert a[leaf] == b[leaf], f"{leaf} differs after restore"
        # the orphaned suggestions are re-served, never replayed: telling
        # their units into the restored pool works as plain observations
        for tr in trials:
            pr.absorb(0, _foreign_trial(tr.unit), obj(0, tr.unit))
        assert pr.engine.n(0) == 6


def test_export_refuses_fantasy_active_slot_and_eviction_pins():
    """Eviction snapshots must see only real state: `export_study` refuses
    a fantasy-active slot, and the gateway never selects one for LRU
    eviction (fantasy-pinned) even with its counters artificially idle."""
    async def main(d):
        gw = StudyGateway(RESNET_SPACE, _cfg(d, n_max=32),
                          GatewayConfig(slots=2, max_inflight=8))
        a, b, c = (gw.create_study() for _ in range(3))
        for sid in (a, b):
            tr = await gw.ask(sid)
            gw.tell(sid, tr, obj(sid, tr.unit))
        await gw.drain()
        batch = await gw.ask(a, q=2)
        slot_a = gw._studies[a].slot
        with pytest.raises(RuntimeError, match="fantasy"):
            gw.pool.export_study(slot_a)
        # white-box: even with in-flight bookkeeping zeroed, the fantasy
        # rows alone pin the study
        log = gw._studies[a]
        saved = log.inflight
        log.inflight = 0
        assert not gw._evictable(log)
        log.inflight = saved
        # study c's first ask must evict b (idle), never a
        tr_c = await gw.ask(c)
        assert gw._studies[a].slot == slot_a
        assert gw._studies[b].slot is None and gw._studies[b].evicted_ever
        for tr in batch:
            gw.tell(a, tr, obj(a, tr.unit))
        gw.tell(c, tr_c, obj(c, tr_c.unit))
        await gw.drain()
        assert gw.summary()["fantasy_active"] == 0
        await gw.aclose()
    with tempfile.TemporaryDirectory() as d:
        asyncio.run(main(d))


def test_gateway_kill_recover_with_fantasies_equals_real_ledger():
    """Kill/recover through the GATEWAY with q-ask fantasies outstanding:
    the recovered gateway serves from the real ledger only — bitwise the
    state of a twin pool that absorbed the same real observations."""
    async def main(d, d2):
        gw = StudyGateway(RESNET_SPACE, _cfg(d, n_max=48),
                          GatewayConfig(slots=1, max_inflight=8))
        sid = gw.create_study()
        pb = StudyPool([RESNET_SPACE], _cfg(d2, n_max=48))
        for _ in range(3):
            tr = await gw.ask(sid)
            v = obj(sid, tr.unit)
            gw.tell(sid, tr, v)
            await gw.drain()
            pb.absorb(0, _foreign_trial(tr.unit), v)
        batch = await gw.ask(sid, q=3)
        told = batch[1]
        v = obj(sid, told.unit)
        gw.tell(sid, told, v)
        await gw.drain()
        pb.absorb(0, _foreign_trial(told.unit), v)
        assert gw.pool.fantasy_active(0) == 2
        gw.checkpoint()     # rolls back around the snapshot
        await gw.aclose()   # crash: 2 suggestions die with their clients

        gw2 = StudyGateway(RESNET_SPACE, _cfg(d, n_max=48),
                           GatewayConfig(slots=1, max_inflight=8))
        assert gw2.restore()
        assert gw2.study_info(sid)["n_obs"] == 4
        assert gw2.summary()["fantasy_active"] == 0
        # lifetime q telemetry survived
        assert gw2.summary()["q_width_hist"].get("3") == 1
        tr = await gw2.ask(sid)   # slot re-residency replays real state
        a, b = _slot_bytes(gw2.pool, 0), _slot_bytes(pb, 0)
        for leaf in a:
            assert a[leaf] == b[leaf], f"{leaf} differs after recovery"
        gw2.tell(sid, tr, obj(sid, tr.unit))
        await gw2.drain()
        await gw2.aclose()
    with tempfile.TemporaryDirectory() as d, \
            tempfile.TemporaryDirectory() as d2:
        asyncio.run(main(d, d2))


def test_failed_q_trial_releases_its_fantasy_row():
    """tell_failure without a penalty must release the failed trial's
    fantasy row (no tell will ever come), unpinning the study."""
    async def main(d):
        gw = StudyGateway(RESNET_SPACE, _cfg(d, n_max=32),
                          GatewayConfig(slots=1, max_inflight=8))
        sid = gw.create_study()
        tr = await gw.ask(sid)
        gw.tell(sid, tr, obj(sid, tr.unit))
        await gw.drain()
        batch = await gw.ask(sid, q=3)
        assert gw.pool.fantasy_active(0) == 3
        gw.tell_failure(sid, batch[0], "diverged")
        assert gw.pool.fantasy_active(0) == 2
        for tr in batch[1:]:
            gw.tell(sid, tr, obj(sid, tr.unit))
        await gw.drain()
        assert gw.pool.fantasy_active(0) == 0
        assert gw.study_info(sid)["n_obs"] == 3   # the failure absorbed no row
        assert gw._evictable(gw._studies[sid])
        await gw.aclose()
    with tempfile.TemporaryDirectory() as d:
        asyncio.run(main(d))


# ---------------------------------------------------------------------------
# Federation: shard crashes and migration faults (DESIGN.md §13)
# ---------------------------------------------------------------------------
def _mk_fed(root, n_shards=2, slots=2, n_max=24):
    return FederatedGateway(RESNET_SPACE, _cfg(root, n_max=n_max),
                            GatewayConfig(slots=slots),
                            FederationConfig(n_shards=n_shards))


def test_fed_shard_kill_restore_keeps_committed_loses_uncommitted():
    """Kill one shard mid-traffic (no checkpoint at the crash): revive
    restores it from ITS latest epoch — every committed tell survives, the
    uncommitted round is gone, and NOTHING pre-crash is ever replayed (the
    lost round re-derives bitwise from the persisted PRNG streams).  The
    surviving shard keeps its uncommitted work untouched."""
    async def main(root):
        fg = _mk_fed(root)
        sids = [fg.create_study(name=f"s{i}") for i in range(4)]
        by_shard = {i: [s for s in sids if fg.shard_of(s) == i]
                    for i in (0, 1)}
        assert by_shard[0] and by_shard[1]   # the ring populated both
        victim = 0
        pre = await drive_serial(fg, sids, 2)
        fg.checkpoint()                      # epoch: 2 obs/study committed
        lost = await drive_serial(fg, sids, 1)
        fg.kill_shard(victim)
        fg.revive_shard(victim)
        for s in sids:
            n = fg.study_info(s)["n_obs"]
            assert n == (2 if fg.shard_of(s) == victim else 3), \
                f"study {s}: {n} obs after revive"
        post = await drive_serial(fg, sids, 2)
        for s in sids:
            assert set(pre[s]).isdisjoint(post[s]), \
                "revived shard replayed a pre-crash suggestion"
            if fg.shard_of(s) == victim:
                # the lost round re-derives exactly from the epoch's PRNG
                assert post[s][0] == lost[s][0]
            else:
                assert set(lost[s]).isdisjoint(post[s])
        await fg.aclose()
    with tempfile.TemporaryDirectory() as root:
        asyncio.run(main(root))


def test_fed_shard_kill_cancels_parked_asks():
    """A crash severs parked clients: their futures cancel instead of
    hanging forever, and the revived shard serves fresh asks."""
    async def main(root):
        fg = _mk_fed(root)
        sids = [fg.create_study(name=f"s{i}") for i in range(4)]
        victim_sid = next(s for s in sids if fg.shard_of(s) == 0)
        await drive_serial(fg, [victim_sid], 1)
        fg.checkpoint()
        fut = asyncio.ensure_future(fg.ask(victim_sid))
        await asyncio.sleep(0)               # parked, tick not yet run
        fg.kill_shard(0)
        with pytest.raises(asyncio.CancelledError):
            await fut
        fg.revive_shard(0)
        tr = await fg.ask(victim_sid)
        fg.tell(victim_sid, tr, obj(victim_sid, tr.unit))
        await fg.drain()
        assert fg.study_info(victim_sid)["n_obs"] == 2
        await fg.aclose()
    with tempfile.TemporaryDirectory() as root:
        asyncio.run(main(root))


def test_fed_migration_io_fault_is_all_or_nothing(monkeypatch):
    """A migration whose snapshot copy dies mid-transfer must leave the
    study fully intact on its SOURCE shard — still owned, still servable,
    bitwise the state of an unmigrated twin — and leave no committed (or
    half-copied) version on the destination."""
    async def main(d_a, d_b):
        fa, fb = _mk_fed(d_a), _mk_fed(d_b)
        sids = [fa.create_study(name=f"s{i}") for i in range(2)]
        for s in sids:
            assert fb.create_study(name=f"s{s}") == s
        streams_a = await drive_serial(fa, sids, 2)
        streams_b = await drive_serial(fb, sids, 2)
        sid = sids[0]
        src = fa.shard_of(sid)
        dst = 1 - src

        def boom(*a, **k):
            raise OSError("migration link down")
        monkeypatch.setattr(store_mod.shutil, "copy2", boom)
        with pytest.raises(OSError, match="migration link down"):
            fa.migrate_study(sid, dst)
        monkeypatch.undo()
        # still owned by the source; the destination saw nothing durable
        assert fa.shard_of(sid) == src
        src_gw, dst_gw = fa.shards[src], fa.shards[dst]
        key = src_gw._study_key(src_gw._studies[sid])
        assert not ckpt_mod.study_versions(dst_gw.cfg.ckpt_dir, key)
        sdir = store_mod.study_dir(dst_gw.cfg.ckpt_dir, key)
        if os.path.exists(sdir):
            assert not [f for f in os.listdir(sdir)
                        if f.startswith(".tmp_migrate_")], \
                "aborted migration left debris on the destination"
        # the study keeps serving from the source, identically to the twin
        # federation that never attempted the migration
        await drive_serial(fa, sids, 2, streams=streams_a)
        await drive_serial(fb, sids, 2, streams=streams_b)
        assert_streams_identical(streams_a, streams_b)
        la = fa.shards[src]._studies[sid]
        lb = fb.shards[src]._studies[sid]
        assert la.slot is not None and lb.slot is not None
        assert_slots_equal(fa.shards[src].pool, la.slot,
                           fb.shards[src].pool, lb.slot,
                           ctx="after aborted migration")
        await fa.aclose()
        await fb.aclose()
    with tempfile.TemporaryDirectory() as d_a, \
            tempfile.TemporaryDirectory() as d_b:
        asyncio.run(main(d_a, d_b))


def test_fed_retried_migration_succeeds_after_io_fault(monkeypatch):
    """The abort is recoverable: once the link is back, retrying the SAME
    migration completes and the study serves from the destination with its
    ledger intact."""
    async def main(root):
        fg = _mk_fed(root)
        sids = [fg.create_study(name=f"s{i}") for i in range(2)]
        await drive_serial(fg, sids, 2)
        sid = sids[0]
        src = fg.shard_of(sid)
        dst = 1 - src

        def boom(*a, **k):
            raise OSError("migration link down")
        monkeypatch.setattr(store_mod.shutil, "copy2", boom)
        with pytest.raises(OSError):
            fg.migrate_study(sid, dst)
        monkeypatch.undo()
        fg.migrate_study(sid, dst)           # retry on a healthy link
        assert fg.shard_of(sid) == dst
        info = fg.study_info(sid)
        assert info["n_obs"] == 2 and info["shard"] == dst
        post = await drive_serial(fg, [sid], 1)
        assert len(post[sid]) == 1
        await fg.aclose()
    with tempfile.TemporaryDirectory() as root:
        asyncio.run(main(root))


# ---------------------------------------------------------------------------
# Cross-process shard crash: a real SIGKILL against the PRODUCTION worker
# (repro.hpo.shard_worker + ShardClient — no federation front end, so
# this exercises the worker CLI, spec/endpoint publishing, and the bare
# self-restore path; the front-end orchestration of the same crash lives
# in tests/test_transport.py)
# ---------------------------------------------------------------------------
def _spawn_worker(d):
    import repro
    os.makedirs(d, exist_ok=True)
    with open(os.path.join(d, tx.SPEC_FILE), "w") as f:
        json.dump(tx.build_spec(RESNET_SPACE, _cfg(d, n_max=16)), f)
    ep = os.path.join(d, tx.ENDPOINT_FILE)
    if os.path.exists(ep):
        os.unlink(ep)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.dirname(os.path.dirname(repro.__file__)) \
        + os.pathsep + env.get("PYTHONPATH", "")
    p = subprocess.Popen([sys.executable, "-m", "repro.hpo.shard_worker",
                          "--ckpt-dir", d], env=env)
    deadline = time.time() + 180
    while not os.path.exists(ep):
        assert p.poll() is None, \
            f"worker exited rc={p.returncode} during startup"
        assert time.time() < deadline, "worker never published endpoint"
        time.sleep(0.05)
    with open(ep) as f:
        return p, json.load(f)


async def _worker_round(c, sid):
    (w,) = await c.call("ask", sid=sid, q=1)
    unit = tx.trial_from_wire(w).unit
    await c.call("tell", sid=sid, trial=w, value=obj(sid, unit))
    await c.call("drain")
    return tuple(unit)


def test_crossproc_shard_sigkill_restores_from_epoch():
    """Two real shard worker PROCESSES over one federation root.  SIGKILL
    one mid-traffic: the survivor never notices, and a fresh process
    started over the dead shard's store restores from its epoch —
    committed tells survive, nothing pre-crash replays, and the round the
    crash destroyed re-derives bitwise (the in-process analogue is
    FederatedGateway.kill_shard/revive_shard)."""
    async def main(d0, d1):
        p0, ep0 = _spawn_worker(d0)
        assert not ep0["restored"]
        p1, ep1 = _spawn_worker(d1)
        c0 = await tx.ShardClient.connect(ep0["host"], ep0["port"])
        c1 = await tx.ShardClient.connect(ep1["host"], ep1["port"])
        s0a = await c0.call("create_study", name="a")
        s0b = await c0.call("create_study", name="b")
        s1a = await c1.call("create_study", name="c")
        pre = {s: [] for s in (s0a, s0b)}
        for _ in range(2):
            for s in pre:
                pre[s].append(await _worker_round(c0, s))
            await _worker_round(c1, s1a)
        await c0.call("checkpoint")
        await c1.call("checkpoint")
        lost = {}
        for s in pre:
            lost[s] = await _worker_round(c0, s)
        await _worker_round(c1, s1a)         # survivor's round 3 (kept)

        os.kill(p0.pid, signal.SIGKILL)      # the real thing
        assert p0.wait(timeout=30) == -signal.SIGKILL
        c0.close()

        # the survivor is undisturbed mid-crash
        await _worker_round(c1, s1a)
        assert (await c1.call("study_info", sid=s1a))["n_obs"] == 4

        # restart over the SAME store: epoch restore, not a fresh shard
        p0b, ep0b = _spawn_worker(d0)
        assert ep0b["restored"]
        c0b = await tx.ShardClient.connect(ep0b["host"], ep0b["port"])
        for s in pre:
            assert (await c0b.call("study_info", sid=s))["n_obs"] == 2, \
                "a committed tell was lost in the crash"
        post = {s: [] for s in pre}
        for _ in range(2):
            for s in pre:
                post[s].append(await _worker_round(c0b, s))
        for s in pre:
            assert set(pre[s]).isdisjoint(post[s]), \
                "restarted shard replayed a pre-crash suggestion"
            assert post[s][0] == lost[s], \
                "the crashed round did not re-derive from the epoch's PRNG"
        for c in (c0b, c1):
            await c.call("shutdown")
            c.close()
        p0b.wait(timeout=30)
        p1.wait(timeout=30)
    with tempfile.TemporaryDirectory() as d0, \
            tempfile.TemporaryDirectory() as d1:
        asyncio.run(main(d0, d1))


# ---------------------------------------------------------------------------
# Federation restore/store regressions (found moving shards cross-process)
# ---------------------------------------------------------------------------
def test_fed_restore_refuses_shard_count_mismatch():
    """A federation registry written with N shards must refuse to restore
    under a different count: fewer live shards would strand placements on
    out-of-range indices, more would silently split routing between old
    placements and the new ring.  The error names both counts."""
    async def main(root):
        fg = _mk_fed(root, n_shards=2)
        sids = [fg.create_study(name=f"s{i}") for i in range(3)]
        await drive_serial(fg, sids, 1)
        fg.checkpoint()
        await fg.aclose()

        fg3 = _mk_fed(root, n_shards=3)
        with pytest.raises(ValueError, match=r"n_shards=2.*n_shards=3"):
            fg3.restore()
        # the recorded count restores fine (the registry is intact)
        fg2 = _mk_fed(root, n_shards=2)
        assert fg2.restore()
        assert fg2.study_ids() == sids
        for s in sids:
            assert fg2.study_info(s)["n_obs"] == 1
        await fg2.aclose()
    with tempfile.TemporaryDirectory() as root:
        asyncio.run(main(root))


def test_store_sweeps_stale_tmp_dirs_not_inflight_ones():
    """A writer SIGKILLed mid-save leaks its `.tmp_ckpt_*`/`.tmp_migrate_*`
    staging dir.  The sweep is age-guarded: stale debris goes (directly
    and on the `save` path), a concurrent writer's fresh in-flight dir
    stays."""
    with tempfile.TemporaryDirectory() as d:
        stale_a = os.path.join(d, ".tmp_ckpt_dead0")
        stale_b = os.path.join(d, ".tmp_migrate_dead1")
        fresh = os.path.join(d, ".tmp_ckpt_inflight")
        for p in (stale_a, stale_b, fresh):
            os.makedirs(p)
            with open(os.path.join(p, "arrays.npz"), "wb") as f:
                f.write(b"partial")
        old = time.time() - 7200.0           # default TTL is 3600s
        for p in (stale_a, stale_b):
            os.utime(p, (old, old))
        swept = ckpt_mod.sweep_tmp(d)
        assert sorted(swept) == sorted([stale_a, stale_b])
        assert os.path.isdir(fresh), "swept a concurrent writer's tmp dir"

        # the save path GCs the same way: plant new stale debris and let
        # a committed save reclaim it while the fresh dir still survives
        stale_c = os.path.join(d, ".tmp_migrate_dead2")
        os.makedirs(stale_c)
        os.utime(stale_c, (old, old))
        ckpt_mod.save(d, 1, {"x": np.zeros(2)})
        assert not os.path.exists(stale_c), "_gc skipped stale tmp debris"
        assert os.path.isdir(fresh)
        assert ckpt_mod.restore_latest(d, {"x": np.zeros(2)}) is not None
