"""Multi-tenant StudyPool tests: batched suggest, routed/queued absorption,
per-study isolation (capacity, faults, lag, telemetry), pool checkpointing,
and the one-code-path contract with TrialScheduler."""
import tempfile

import numpy as np
import pytest

from repro.core import GPCapacityError
from repro.hpo.pool import SchedulerConfig, StudyPool
from repro.hpo.scheduler import TrialScheduler
from repro.hpo.space import LENET_SPACE, RESNET_SPACE


def quad(center):
    """Smooth per-study objective on the unit cube (maximize)."""
    def f(unit):
        return float(-np.sum((np.asarray(unit) - center) ** 2))
    return f


CENTERS = [np.asarray([0.3, 0.6, 0.5]), np.asarray([0.8, 0.2, 0.4]),
           np.asarray([0.5, 0.5, 0.9])]


def _drive(pool, rounds, t=1):
    """suggest_all -> evaluate -> absorb_many, completion-order shuffled."""
    rng = np.random.default_rng(0)
    for _ in range(rounds):
        suggestions = pool.suggest_all(t=t)
        events = [(sid, tr, quad(CENTERS[sid])(tr.unit))
                  for sid, trs in suggestions.items() for tr in trs]
        rng.shuffle(events)
        pool.absorb_many(events)


def test_pool_round_advances_every_study():
    cfg = SchedulerConfig(n_max=32, seed=0)
    pool = StudyPool([RESNET_SPACE] * 3, cfg)
    _drive(pool, rounds=4)
    for s in range(3):
        assert pool.engine.n(s) == 4
        assert pool.best(s) is not None
        units = np.stack([t.unit for t in pool.studies[s].trials])
        assert units.min() >= 0.0 and units.max() <= 1.0
    # ledgers are independent: ids restart per study
    assert [t.trial_id for t in pool.studies[1].trials[:2]] == [0, 1]


def test_pool_matches_independent_schedulers():
    """One code path, S-way: absorbing the same observations through the
    pool and through S independent TrialSchedulers yields identical
    posteriors (the batched-parity contract at the orchestration layer)."""
    cfg = SchedulerConfig(n_max=16, seed=0)
    pool = StudyPool([RESNET_SPACE] * 2, cfg)
    scheds = [TrialScheduler(RESNET_SPACE, cfg) for _ in range(2)]
    rng = np.random.default_rng(3)
    for k in range(5):
        for s in range(2):
            unit = rng.uniform(size=3).astype(np.float32)
            val = quad(CENTERS[s])(unit)
            pool.absorb(s, pool._make_trial(s, unit), val)
            scheds[s].absorb(scheds[s]._make_trial(unit), val)
    for s in range(2):
        got, want = pool.state(s), scheds[s].state
        assert int(got.n) == int(want.n) == 5
        np.testing.assert_allclose(np.asarray(got.l_buf),
                                   np.asarray(want.l_buf), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(got.alpha),
                                   np.asarray(want.alpha), rtol=1e-5,
                                   atol=1e-7)


def test_absorb_many_matches_routed_absorbs():
    """Masked batched rounds == per-event routed appends, including events
    with per-study multiplicity > 1 (spillover rounds)."""
    cfg = SchedulerConfig(n_max=16, seed=0)
    a = StudyPool([RESNET_SPACE] * 3, cfg)
    b = StudyPool([RESNET_SPACE] * 3, cfg)
    rng = np.random.default_rng(7)
    events_a, events_b = [], []
    # interleaved completion order, study 1 completes twice in the queue
    for sid in (1, 0, 1, 2, 0):
        unit = rng.uniform(size=3).astype(np.float32)
        val = quad(CENTERS[sid])(unit)
        events_a.append((sid, a._make_trial(sid, unit), val))
        events_b.append((sid, b._make_trial(sid, unit), val))
    a.absorb_many(events_a)
    for sid, tr, val in events_b:
        b.absorb(sid, tr, val)
    for s in range(3):
        np.testing.assert_allclose(np.asarray(a.state(s).l_buf),
                                   np.asarray(b.state(s).l_buf), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(a.state(s).alpha),
                                   np.asarray(b.state(s).alpha), rtol=1e-5,
                                   atol=1e-7)
        assert int(a.state(s).n) == int(b.state(s).n)
        assert a.studies[s].trials[-1].clamp_count is not None


def test_pool_capacity_fault_is_per_study():
    """Filling one tenant must raise for that tenant only and leave its
    neighbors absorbing normally."""
    cfg = SchedulerConfig(n_max=2, seed=0)
    pool = StudyPool([RESNET_SPACE] * 2, cfg)
    rng = np.random.default_rng(0)
    for _ in range(2):
        u = rng.uniform(size=3).astype(np.float32)
        pool.absorb(1, pool._make_trial(1, u), 0.5)
    with pytest.raises(GPCapacityError):
        pool.absorb(1, pool._make_trial(
            1, rng.uniform(size=3).astype(np.float32)), 0.1)
    # study 1 state not corrupted; study 0 unaffected
    assert pool.engine.n(1) == 2
    pool.absorb(0, pool._make_trial(
        0, rng.uniform(size=3).astype(np.float32)), 0.3)
    assert pool.engine.n(0) == 1


def test_absorb_many_capacity_fault_leaves_neighbors_consistent():
    """A GPCapacityError inside an absorb_many round must not mark a healthy
    neighbor's trial done without absorbing its observation (the round is
    capacity-checked before any ledger mutation)."""
    cfg = SchedulerConfig(n_max=2, seed=0)
    pool = StudyPool([RESNET_SPACE] * 2, cfg)
    rng = np.random.default_rng(0)
    for _ in range(2):
        u = rng.uniform(size=3).astype(np.float32)
        pool.absorb(0, pool._make_trial(0, u), 0.5)  # study 0 now full
    t_full = pool._make_trial(0, rng.uniform(size=3).astype(np.float32))
    t_ok = pool._make_trial(1, rng.uniform(size=3).astype(np.float32))
    with pytest.raises(GPCapacityError):
        pool.absorb_many([(1, t_ok, 0.7), (0, t_full, 0.9)])
    # neither trial entered the ledger-done/GP-absorbed state inconsistently
    assert t_ok.status == "pending" and pool.engine.n(1) == 0
    assert t_full.status == "pending" and pool.engine.n(0) == 2
    assert pool.best(1) is None
    # the healthy study keeps absorbing normally afterwards
    pool.absorb_many([(1, t_ok, 0.7)])
    assert t_ok.status == "done" and pool.engine.n(1) == 1


def test_absorb_many_whole_queue_capacity_check_covers_later_rounds():
    """Overflow queued for a LATER round (per-study multiplicity) must also
    raise before anything is absorbed — the drain is all-or-nothing with
    respect to capacity, so no event is ever silently dropped."""
    cfg = SchedulerConfig(n_max=2, seed=0)
    pool = StudyPool([RESNET_SPACE] * 2, cfg)
    rng = np.random.default_rng(0)
    u = lambda: rng.uniform(size=3).astype(np.float32)  # noqa: E731
    pool.absorb(0, pool._make_trial(0, u()), 0.5)  # study 0 at n_max - 1
    a, b = pool._make_trial(0, u()), pool._make_trial(0, u())
    c, d = pool._make_trial(1, u()), pool._make_trial(1, u())
    with pytest.raises(GPCapacityError):
        pool.absorb_many([(0, a, 0.1), (1, c, 0.2), (0, b, 0.3),
                          (1, d, 0.4)])
    # nothing from the queue was absorbed — no partial round, no lost event
    assert [t.status for t in (a, b, c, d)] == ["pending"] * 4
    assert pool.engine.n(0) == 1 and pool.engine.n(1) == 0


def test_pool_lag_refit_is_per_study():
    cfg = SchedulerConfig(n_max=16, seed=0, lag=2)
    pool = StudyPool([RESNET_SPACE] * 2, cfg)
    rng = np.random.default_rng(1)
    for k in range(2):
        u = rng.uniform(size=3).astype(np.float32)
        pool.absorb(0, pool._make_trial(0, u), float(k))
    # study 0 tripped its lag counter and refit; study 1 never absorbed
    assert pool.engine.since_refit(0) == 0
    assert pool.engine.n(0) == 2
    assert pool.engine.since_refit(1) == 0 and pool.engine.n(1) == 0
    # params diverge per study after the refit
    p = pool.engine.state.params
    assert p.rho.shape == (2,)
    assert float(p.rho[0]) != pytest.approx(float(p.rho[1])) or \
        float(p.sigma2[0]) != pytest.approx(float(p.sigma2[1]))


def test_pool_failure_policy_routed_to_owner():
    cfg = SchedulerConfig(n_max=16, seed=0, max_retries=1,
                          failure_penalty=-50.0)
    pool = StudyPool([RESNET_SPACE] * 2, cfg)
    tr = pool.seed_trials(1, 1)[0]
    retry = pool.record_failure(1, tr, "node lost")
    assert tr.status == "failed"
    assert retry is not None and retry.retries == 1
    # penalty pseudo-observation landed in study 1 only
    assert pool.engine.n(1) == 1 and pool.engine.n(0) == 0
    assert float(pool.state(1).y_buf[0]) == pytest.approx(-50.0)


def test_pool_checkpoint_restore_roundtrip():
    with tempfile.TemporaryDirectory() as d:
        cfg = SchedulerConfig(n_max=16, seed=0, ckpt_dir=d)
        pool = StudyPool([RESNET_SPACE] * 3, cfg)
        _drive(pool, rounds=3)
        states = [np.asarray(pool.state(s).alpha) for s in range(3)]

        pool2 = StudyPool([RESNET_SPACE] * 3, cfg)
        assert pool2.restore()
        for s in range(3):
            assert pool2.engine.n(s) == 3
            np.testing.assert_allclose(np.asarray(pool2.state(s).alpha),
                                       states[s], rtol=1e-6)
            assert len(pool2.studies[s].trials) == \
                len(pool.studies[s].trials)
            assert pool2.studies[s].next_id == pool.studies[s].next_id
        # restored pool keeps absorbing + suggesting
        _drive(pool2, rounds=1)
        assert all(pool2.engine.n(s) == 4 for s in range(3))


def test_restore_resumes_prng_streams_no_replayed_batches():
    """The per-study seed/EI PRNG streams ride the checkpoint: a restored
    pool must not re-draw random batches already drawn pre-crash."""
    with tempfile.TemporaryDirectory() as d:
        cfg = SchedulerConfig(n_max=16, seed=0, ckpt_dir=d)
        pool = StudyPool([RESNET_SPACE] * 2, cfg)
        # study 1 absorbs (firing a checkpoint) while study 0 is still at
        # n == 0 with its seed batch only in the ledger
        drawn = {tuple(t.unit.tolist()) for t in pool.seed_trials(0, 2)}
        tr = pool.seed_trials(1, 1)[0]
        pool.absorb(1, tr, 0.5)

        pool2 = StudyPool([RESNET_SPACE] * 2, cfg)
        assert pool2.restore()
        again = {tuple(t.unit.tolist()) for t in pool2.seed_trials(0, 2)}
        assert drawn.isdisjoint(again), \
            "restored pool replayed a pre-crash seed batch"


def test_pool_rejects_mismatched_dims_and_study_counts():
    with pytest.raises(ValueError, match="dimensionality"):
        StudyPool([RESNET_SPACE, LENET_SPACE], SchedulerConfig(n_max=8))
    with tempfile.TemporaryDirectory() as d:
        cfg = SchedulerConfig(n_max=8, seed=0, ckpt_dir=d)
        pool = StudyPool([RESNET_SPACE] * 2, cfg)
        pool.checkpoint()
        # the stacked-buffer shape guard fires before the registry count
        # check: the S axis is part of every leaf's shape
        with pytest.raises(ValueError, match="studies"):
            StudyPool([RESNET_SPACE] * 3, cfg).restore()
        # same-shape pool with a different n_max is also refused
        with pytest.raises(ValueError, match="shape mismatch"):
            StudyPool([RESNET_SPACE] * 2,
                      SchedulerConfig(n_max=12, seed=0, ckpt_dir=d)).restore()


def test_repeated_seeding_draws_fresh_points():
    """The per-study seed stream is persistent: a second seeding round (or
    a width top-up at n == 0) must not replay the same random batch."""
    pool = StudyPool([RESNET_SPACE], SchedulerConfig(n_max=16, seed=0))
    first = pool.suggest(0, 2)
    second = pool.suggest(0, 2)
    units = {tuple(t.unit.tolist()) for t in first + second}
    assert len(units) == 4, "seed batches repeated"


def test_parallel_width_topup_at_n0_has_no_duplicate_points():
    """run(parallel=4, n_seed=1): the pre-absorb top-up used to launch the
    identical seed point width times."""
    from repro.hpo.scheduler import TrialScheduler as TS
    sched = TS(RESNET_SPACE, SchedulerConfig(n_max=32, seed=0, parallel=4))
    sched.run(lambda hp: quad(CENTERS[0])(
        RESNET_SPACE.to_unit(hp)), budget=6, n_seed=1)
    launched = [tuple(t.unit.tolist()) for t in sched.trials]
    assert len(set(launched)) == len(launched), "duplicate launches"


def test_fully_lazy_inverse_reanchor_keeps_params():
    """lag=0 + inv_refresh: the drift guard refactors (since_refit resets)
    without touching the kernel params."""
    cfg = SchedulerConfig(n_max=16, seed=0, lag=0, inv_refresh=3)
    pool = StudyPool([RESNET_SPACE] * 2, cfg)
    rho_before = float(pool.engine.state.params.rho[0])
    rng = np.random.default_rng(0)
    for k in range(3):
        u = rng.uniform(size=3).astype(np.float32)
        pool.absorb(0, pool._make_trial(0, u), float(k) * 0.1)
    assert pool.engine.since_refit(0) == 0          # re-anchored
    assert pool.engine.since_refit(1) == 0 and pool.engine.n(1) == 0
    assert float(pool.engine.state.params.rho[0]) == pytest.approx(
        rho_before)                                  # params untouched
    assert pool.engine.n(0) == 3


def test_checkpoint_cadence_batches_snapshots():
    with tempfile.TemporaryDirectory() as d:
        from repro import checkpoint as ckpt_mod
        cfg = SchedulerConfig(n_max=16, seed=0, ckpt_dir=d, ckpt_every=3)
        pool = StudyPool([RESNET_SPACE], cfg)
        rng = np.random.default_rng(0)
        for k in range(2):
            u = rng.uniform(size=3).astype(np.float32)
            pool.absorb(0, pool._make_trial(0, u), float(k))
        assert ckpt_mod.latest_step(d) is None       # below cadence
        u = rng.uniform(size=3).astype(np.float32)
        pool.absorb(0, pool._make_trial(0, u), 0.9)
        assert ckpt_mod.latest_step(d) == 3          # cadence hit


def test_scheduler_is_one_study_pool():
    """The one-code-path contract: the scheduler's suggest/absorb ARE the
    pool's (same engine object, same ledger list)."""
    sched = TrialScheduler(RESNET_SPACE, SchedulerConfig(n_max=16, seed=0))
    assert isinstance(sched.pool, StudyPool)
    assert sched.trials is sched.pool.studies[0].trials
    tr = sched._make_trial(np.full(3, 0.4, np.float32))
    sched.absorb(tr, 1.0)
    assert sched.pool.engine.n(0) == 1
    assert int(sched.state.n) == 1
