"""A minimal, deterministic stand-in for `hypothesis` (see conftest.py).

The runtime image does not ship `hypothesis` (it is a dev extra,
requirements-dev.txt), but the property tests are tier-1: they must RUN,
not skip.  `ensure_hypothesis()` imports the real package when present and
otherwise installs this fallback, which implements the exact API subset the
suite uses:

  * `@given(**kwargs)` with keyword strategies — the wrapped test runs
    `max_examples` times against examples drawn from a PRNG seeded by the
    test's qualified name (bitwise-reproducible run to run, machine to
    machine; no example database, no shrinking),
  * `@settings(max_examples=, deadline=, ...)` incl. profile registration,
  * `strategies.integers/floats/booleans/sampled_from/lists/tuples/one_of/
    just/text` plus `.map`/`.filter`,
  * `assume` / `note` / `HealthCheck`.

The fallback engages ONLY on `ModuleNotFoundError` for `hypothesis` itself;
a *broken* install (ImportError from inside the package, or a missing
dependency of it) re-raises so CI never silently downgrades coverage.

`REPRO_FALLBACK_MAX_EXAMPLES` caps examples per test (0 = use each test's
declared budget) — the knob the quick local loop and the CI fallback job
share.
"""
from __future__ import annotations

import functools
import inspect
import os
import random
import sys
import types

_SEED_TAG = os.environ.get("REPRO_FALLBACK_SEED", "repro-fallback-v1")


class _Unsatisfied(Exception):
    """Raised by `assume(False)`; the example is discarded, not failed."""


class Unsatisfiable(Exception):
    """No example satisfied assume()/filter — mirrors
    hypothesis.errors.Unsatisfiable: a property test that executed zero
    examples must FAIL, not silently pass as a no-op."""


class _Strategy:
    def __init__(self, draw_fn, label="strategy"):
        self._draw = draw_fn
        self._label = label

    def example(self, rng: random.Random):
        return self._draw(rng)

    def map(self, fn):
        return _Strategy(lambda rng: fn(self._draw(rng)),
                         f"{self._label}.map")

    def filter(self, pred):
        def draw(rng):
            for _ in range(100):
                v = self._draw(rng)
                if pred(v):
                    return v
            raise _Unsatisfied(f"filter on {self._label} too strict")
        return _Strategy(draw, f"{self._label}.filter")

    def __repr__(self):
        return f"<fallback {self._label}>"


def _mk_strategies() -> types.ModuleType:
    st = types.ModuleType("hypothesis.strategies")

    def integers(min_value=-(2 ** 16), max_value=2 ** 16):
        return _Strategy(lambda rng: rng.randint(min_value, max_value),
                         f"integers({min_value},{max_value})")

    def floats(min_value=0.0, max_value=1.0, **_kw):
        lo, hi = float(min_value), float(max_value)

        def draw(rng):
            # hit the endpoints occasionally — they are where paddings and
            # clamps break
            r = rng.random()
            if r < 0.05:
                return lo
            if r < 0.10:
                return hi
            return rng.uniform(lo, hi)
        return _Strategy(draw, f"floats({lo},{hi})")

    def booleans():
        return _Strategy(lambda rng: rng.random() < 0.5, "booleans")

    def sampled_from(elements):
        elems = list(elements)
        return _Strategy(lambda rng: elems[rng.randrange(len(elems))],
                         f"sampled_from(len={len(elems)})")

    def lists(elem, min_size=0, max_size=None, unique=False):
        hi = max_size if max_size is not None else min_size + 8

        def draw(rng):
            size = rng.randint(min_size, hi)
            out, seen = [], set()
            for _ in range(size * 20 + 20):
                if len(out) == size:
                    break
                v = elem.example(rng)
                if unique:
                    if v in seen:
                        continue
                    seen.add(v)
                out.append(v)
            if len(out) < size:
                # unique element domain too small (or retry budget spent):
                # never hand back a list below the declared min_size —
                # discard the example (real hypothesis never undershoots)
                raise _Unsatisfied(
                    f"lists(unique=True): only {len(out)}/{size} distinct "
                    "elements drawn")
            return out
        return _Strategy(draw, f"lists[{min_size},{hi}]")

    def tuples(*strats):
        return _Strategy(lambda rng: tuple(s.example(rng) for s in strats),
                         f"tuples(x{len(strats)})")

    def one_of(*strats):
        flat = strats[0] if len(strats) == 1 and isinstance(
            strats[0], (list, tuple)) else strats
        return _Strategy(
            lambda rng: flat[rng.randrange(len(flat))].example(rng),
            f"one_of(x{len(flat)})")

    def just(value):
        return _Strategy(lambda rng: value, f"just({value!r})")

    def text(alphabet="abcdefghijklmnopqrstuvwxyz", min_size=0, max_size=12):
        return _Strategy(
            lambda rng: "".join(rng.choice(alphabet) for _ in range(
                rng.randint(min_size, max_size))), "text")

    for fn in (integers, floats, booleans, sampled_from, lists, tuples,
               one_of, just, text):
        setattr(st, fn.__name__, fn)
    return st


def _build_fallback() -> types.ModuleType:
    mod = types.ModuleType("hypothesis")
    mod.__is_fallback__ = True
    mod.strategies = _mk_strategies()
    mod.HealthCheck = types.SimpleNamespace(
        too_slow=None, data_too_large=None, filter_too_much=None,
        function_scoped_fixture=None)

    profiles: dict[str, dict] = {"default": {}}
    active = {"name": "default"}

    def _active_profile() -> dict:
        return profiles.get(active["name"], {})

    def assume(condition):
        if not condition:
            raise _Unsatisfied("assume() failed")
        return True

    def note(_msg):
        return None

    class settings:  # noqa: N801 — mirrors hypothesis' class-as-decorator
        def __init__(self, max_examples=None, deadline=None, **_kw):
            self.max_examples = max_examples

        def __call__(self, fn):
            if self.max_examples is not None:
                fn._fallback_max_examples = self.max_examples
            return fn

        @staticmethod
        def register_profile(name, max_examples=None, **_kw):
            profiles[name] = {} if max_examples is None else {
                "max_examples": max_examples}

        @staticmethod
        def load_profile(name):
            active["name"] = name

    def given(*args, **kwargs):
        if args:
            raise TypeError(
                "the hypothesis fallback supports keyword strategies only "
                "(install the real package for positional @given)")

        def decorate(fn):
            @functools.wraps(fn)
            def runner(*a, **k):
                budget = getattr(runner, "_fallback_max_examples", None) \
                    or _active_profile().get("max_examples") or 20
                # Default cap keeps the no-deps tier-1 loop fast (each fresh
                # shape drawn is a jit recompile); CI's real-hypothesis jobs
                # run the full declared budgets.  0 = uncapped.
                cap = int(os.environ.get(
                    "REPRO_FALLBACK_MAX_EXAMPLES", "12"))
                if cap:
                    budget = min(budget, cap)
                rng = random.Random(
                    f"{_SEED_TAG}:{fn.__module__}.{fn.__qualname__}")
                ran = 0
                for _ in range(budget * 5):
                    if ran >= budget:
                        break
                    draw = None
                    try:
                        # drawing INSIDE the try: a .filter that exhausts
                        # its retries discards the example like assume(),
                        # instead of erroring out with the private
                        # _Unsatisfied
                        draw = {name: s.example(rng)
                                for name, s in kwargs.items()}
                        fn(*a, **draw, **k)
                    except _Unsatisfied:
                        continue
                    except BaseException:
                        print(f"\nFalsifying example ({fn.__qualname__}): "
                              f"{draw}", file=sys.stderr)
                        raise
                    ran += 1
                if ran == 0:
                    raise Unsatisfiable(
                        f"{fn.__qualname__}: no example satisfied assume()/"
                        f"filter in {budget * 5} draws (the fallback's "
                        "strategy defaults may be narrower than real "
                        "hypothesis)")

            # pytest resolves fixtures from the *visible* signature; the
            # strategy kwargs are bound here, so hide them but KEEP the
            # rest (real hypothesis preserves non-strategy params so
            # fixtures like tmp_path still inject).
            runner.__signature__ = inspect.Signature([
                p for name, p in
                inspect.signature(fn).parameters.items()
                if name not in kwargs])
            runner.__wrapped__ = None
            del runner.__wrapped__
            runner.hypothesis = types.SimpleNamespace(inner_test=fn)
            return runner
        return decorate

    mod.given = given
    mod.settings = settings
    mod.assume = assume
    mod.note = note
    mod._Unsatisfied = _Unsatisfied
    mod.errors = types.SimpleNamespace(Unsatisfiable=Unsatisfiable)
    return mod


def ensure_hypothesis() -> types.ModuleType:
    """Import real hypothesis, or install the fallback when (only) absent."""
    try:
        import hypothesis
        return hypothesis
    except ModuleNotFoundError as e:
        if e.name != "hypothesis":
            # hypothesis is installed but one of ITS dependencies is missing
            # — that is a broken environment, not an absent optional extra.
            raise
    mod = _build_fallback()
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = mod.strategies
    return mod
