"""Substrate tests: data pipeline, optimizers, checkpoint store, train loop."""
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import checkpoint as ckpt
from repro.configs import get_config
from repro.data import DataConfig, DataIterator, host_local_batch, synth_tokens
from repro.optim import (OptimizerConfig, apply_updates, clip_by_global_norm,
                         ef_compress_grads, global_norm, init_opt_state,
                         schedule)
from repro.training import TrainConfig, init_train_state, make_train_step


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------
def test_data_deterministic_across_restart():
    cfg = DataConfig(vocab_size=128, seq_len=32, global_batch=4, seed=3)
    a = synth_tokens(cfg, 7)
    b = synth_tokens(cfg, 7)
    np.testing.assert_array_equal(np.asarray(a["inputs"]),
                                  np.asarray(b["inputs"]))
    it = DataIterator(cfg)
    for _ in range(5):
        next(it)
    state = it.state_dict()
    x1 = next(it)
    it2 = DataIterator(cfg)
    it2.load_state_dict(state)
    x2 = next(it2)
    np.testing.assert_array_equal(np.asarray(x1["targets"]),
                                  np.asarray(x2["targets"]))


def test_data_host_sharding_disjoint():
    cfg = DataConfig(vocab_size=128, seq_len=16, global_batch=8, seed=0)
    b0 = host_local_batch(cfg, 0, host_id=0, num_hosts=4)
    b1 = host_local_batch(cfg, 0, host_id=1, num_hosts=4)
    assert b0["inputs"].shape == (2, 16)
    assert not np.array_equal(np.asarray(b0["inputs"]),
                              np.asarray(b1["inputs"]))


def test_data_has_learnable_signal():
    cfg = DataConfig(vocab_size=128, seq_len=64, global_batch=8,
                     pattern_frac=1.0)
    batch = synth_tokens(cfg, 0)
    want = (batch["inputs"] * 31 + 7) % 128
    np.testing.assert_array_equal(np.asarray(batch["targets"]),
                                  np.asarray(want))


def test_frames_frontend_batch():
    cfg = DataConfig(vocab_size=32, seq_len=16, global_batch=2,
                     frontend="frames", d_model=24)
    b = synth_tokens(cfg, 0)
    assert b["inputs"].shape == (2, 16, 24)
    assert b["targets"].shape == (2, 16)
    assert int(b["targets"].max()) < 32


# ---------------------------------------------------------------------------
# Optimizers
# ---------------------------------------------------------------------------
def _params(key):
    return {"w": jax.random.normal(key, (8, 8)),
            "b": jnp.zeros((8,))}


def test_schedule_warmup_and_cosine():
    cfg = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=110,
                          min_lr_frac=0.1)
    assert float(schedule(cfg, jnp.asarray(0))) == 0.0
    assert float(schedule(cfg, jnp.asarray(10))) == pytest.approx(1.0)
    assert float(schedule(cfg, jnp.asarray(110))) == pytest.approx(0.1)


def test_adamw_reduces_quadratic():
    cfg = OptimizerConfig(name="adamw", lr=0.05, weight_decay=0.0,
                          warmup_steps=0, total_steps=100)
    params = _params(jax.random.PRNGKey(0))
    state = init_opt_state(cfg, params)
    loss = lambda p: jnp.sum(p["w"] ** 2) + jnp.sum((p["b"] - 1) ** 2)
    l0 = float(loss(params))
    for _ in range(50):
        grads = jax.grad(loss)(params)
        params, state, _ = apply_updates(cfg, params, grads, state)
    assert float(loss(params)) < 0.2 * l0


def test_sgdm_momentum_accumulates():
    cfg = OptimizerConfig(name="sgdm", lr=0.01, momentum=0.9,
                          weight_decay=0.0, warmup_steps=0, total_steps=10)
    params = {"w": jnp.ones((4,))}
    state = init_opt_state(cfg, params)
    grads = {"w": jnp.ones((4,))}
    p1, state, _ = apply_updates(cfg, params, grads, state)
    p2, state, _ = apply_updates(cfg, p1, grads, state)
    step1 = float(params["w"][0] - p1["w"][0])
    step2 = float(p1["w"][0] - p2["w"][0])
    assert step2 > step1 * 1.5  # momentum compounding


def test_clip_by_global_norm():
    grads = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(grads, 1.0)
    assert float(norm) == pytest.approx(20.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**16), scale=st.floats(0.01, 100.0))
def test_ef_compression_error_feedback_is_lossless_over_time(seed, scale):
    """Sum of compressed grads + final residual == sum of true grads."""
    key = jax.random.PRNGKey(seed)
    grads = [jax.random.normal(jax.random.fold_in(key, i), (16,)) * scale
             for i in range(8)]
    residual = {"g": jnp.zeros((16,))}
    sent_total = jnp.zeros((16,))
    for g in grads:
        sent, residual = ef_compress_grads({"g": g}, residual)
        sent_total = sent_total + sent["g"]
    true_total = sum(grads)
    np.testing.assert_allclose(np.asarray(sent_total + residual["g"]),
                               np.asarray(true_total), rtol=1e-4, atol=1e-3)


def test_compressed_training_still_converges():
    cfg = OptimizerConfig(name="adamw", lr=0.05, weight_decay=0.0,
                          warmup_steps=0, total_steps=100,
                          compress_grads=True)
    params = _params(jax.random.PRNGKey(1))
    state = init_opt_state(cfg, params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    l0 = float(loss(params))
    for _ in range(50):
        grads = jax.grad(loss)(params)
        params, state, _ = apply_updates(cfg, params, grads, state)
    assert float(loss(params)) < 0.3 * l0


# ---------------------------------------------------------------------------
# Checkpoint store
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip_and_gc():
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.ones((2,), jnp.bfloat16)}}
    with tempfile.TemporaryDirectory() as d:
        for step in (1, 2, 3, 4, 5):
            ckpt.save(d, step, tree, keep=2)
        assert ckpt.committed_steps(d) == [4, 5]
        step, tree2, _ = ckpt.restore_latest(d, tree)
        assert step == 5
        for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(tree2)):
            np.testing.assert_allclose(np.asarray(x, np.float32),
                                       np.asarray(y, np.float32))


def test_checkpoint_ignores_uncommitted():
    import os
    tree = {"a": jnp.zeros((2,))}
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 1, tree)
        # simulate a crash mid-save: directory without COMMITTED
        os.makedirs(os.path.join(d, "step_000000099"))
        assert ckpt.latest_step(d) == 1


def test_checkpoint_rejects_tree_mismatch():
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 1, {"a": jnp.zeros((2,))})
        with pytest.raises(ValueError, match="mismatch"):
            ckpt.restore(d, 1, {"b": jnp.zeros((2,))})


# ---------------------------------------------------------------------------
# Train loop integration
# ---------------------------------------------------------------------------
def test_train_step_reduces_loss_and_microbatch_matches():
    cfg = get_config("tiny-lm", reduced=True)
    ocfg = OptimizerConfig(lr=3e-3, warmup_steps=5, total_steps=100)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8)
    params, opt_state, _ = init_train_state(cfg, ocfg, jax.random.PRNGKey(0))
    step1 = jax.jit(make_train_step(cfg, ocfg))
    step4 = jax.jit(make_train_step(cfg, ocfg, TrainConfig(microbatches=4)))

    it = DataIterator(dcfg)
    losses = []
    for _ in range(20):
        params, opt_state, m = step1(params, opt_state, next(it))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]

    # microbatched gradient == full-batch gradient (same update direction)
    pa, oa, _ = init_train_state(cfg, ocfg, jax.random.PRNGKey(1))
    pb = jax.tree.map(lambda x: x, pa)
    ob = init_opt_state(ocfg, pb)
    batch = next(it)
    pa2, _, ma = step1(pa, oa, batch)
    pb2, _, mb = step4(pb, ob, batch)
    da = jax.tree.leaves(jax.tree.map(lambda a, b: jnp.max(jnp.abs(a - b)),
                                      pa2, pb2))
    assert max(float(x) for x in da) < 5e-5


def test_train_cli_checkpoints_and_resumes():
    from repro.launch import train as train_mod
    with tempfile.TemporaryDirectory() as d:
        args = train_mod.parse_args([
            "--arch", "tiny-lm", "--reduced", "--steps", "12",
            "--seq-len", "32", "--global-batch", "4",
            "--ckpt-dir", d, "--ckpt-every", "5", "--log-every", "50"])
        out1 = train_mod.run(args)
        assert ckpt.latest_step(d) == 12
        # resume: runs only the remaining steps (none) and returns
        args2 = train_mod.parse_args([
            "--arch", "tiny-lm", "--reduced", "--steps", "14",
            "--seq-len", "32", "--global-batch", "4",
            "--ckpt-dir", d, "--ckpt-every", "5", "--log-every", "50"])
        out2 = train_mod.run(args2)
        assert ckpt.latest_step(d) == 14
