"""Tests for the test harness itself: the hypothesis fallback must engage
ONLY when hypothesis is absent — a broken install re-raises — and its
example streams must be deterministic (the property jobs rely on that)."""
import importlib
import random
import sys

import pytest

from _hypothesis_fallback import _build_fallback, ensure_hypothesis


class _BrokenHypothesisFinder:
    """Meta-path hook simulating a present-but-broken hypothesis install."""

    def __init__(self, exc):
        self.exc = exc

    def find_module(self, fullname, path=None):
        return self if fullname == "hypothesis" else None

    def find_spec(self, fullname, path=None, target=None):
        if fullname == "hypothesis":
            return importlib.util.spec_from_loader("hypothesis", self)
        return None

    def create_module(self, spec):
        raise self.exc

    def exec_module(self, module):  # pragma: no cover — create_module raises
        raise self.exc


def _without_hypothesis(exc):
    saved = {k: sys.modules.pop(k) for k in list(sys.modules)
             if k == "hypothesis" or k.startswith("hypothesis.")}
    finder = _BrokenHypothesisFinder(exc)
    sys.meta_path.insert(0, finder)
    return saved, finder


def _restore(saved, finder):
    sys.meta_path.remove(finder)
    for k in list(sys.modules):
        if k == "hypothesis" or k.startswith("hypothesis."):
            del sys.modules[k]
    sys.modules.update(saved)


def test_broken_hypothesis_install_reraises():
    """ImportError from INSIDE the package must propagate, not silently
    downgrade the property suite to the fallback."""
    saved, finder = _without_hypothesis(
        ImportError("hypothesis is installed but its extension is broken"))
    try:
        with pytest.raises(ImportError, match="extension is broken"):
            ensure_hypothesis()
    finally:
        _restore(saved, finder)


def test_missing_hypothesis_dependency_reraises():
    """ModuleNotFoundError for a DEPENDENCY of hypothesis (e.g. attrs) is a
    broken environment, not an absent optional extra."""
    saved, finder = _without_hypothesis(
        ModuleNotFoundError("No module named 'attrs'", name="attrs"))
    try:
        with pytest.raises(ModuleNotFoundError, match="attrs"):
            ensure_hypothesis()
    finally:
        _restore(saved, finder)


def test_absent_hypothesis_installs_fallback():
    saved, finder = _without_hypothesis(
        ModuleNotFoundError("No module named 'hypothesis'",
                            name="hypothesis"))
    try:
        mod = ensure_hypothesis()
        assert getattr(mod, "__is_fallback__", False)
        assert sys.modules["hypothesis"] is mod
    finally:
        _restore(saved, finder)


def test_fallback_draws_are_deterministic():
    """Two runs of the same fallback-decorated test draw identical example
    streams (the no-deps tier-1 jobs must be reproducible)."""
    fb = _build_fallback()
    st = fb.strategies

    def collect():
        seen = []

        @fb.settings(max_examples=8)
        @fb.given(n=st.integers(0, 1000), x=st.floats(-1.0, 1.0),
                  tag=st.sampled_from("abcd"))
        def probe(n, x, tag):
            seen.append((n, x, tag))

        probe()
        return seen

    a, b = collect(), collect()
    assert a == b and len(a) == 8


def test_fallback_assume_discards_examples():
    fb = _build_fallback()
    st = fb.strategies
    ran = []

    @fb.settings(max_examples=10)
    @fb.given(n=st.integers(0, 9))
    def probe(n):
        fb.assume(n % 2 == 0)
        ran.append(n)

    probe()
    assert ran and all(n % 2 == 0 for n in ran)


def test_fallback_unsatisfiable_assume_fails_not_passes():
    """A property whose assume() rejects every draw must FAIL — zero
    examples executed is a no-op, not a passing test (real hypothesis
    raises errors.Unsatisfiable; the fallback must not silently
    downgrade that to green)."""
    fb = _build_fallback()
    st = fb.strategies

    @fb.settings(max_examples=5)
    @fb.given(n=st.integers(0, 9))
    def probe(n):
        fb.assume(False)

    with pytest.raises(fb.errors.Unsatisfiable, match="no example"):
        probe()


def test_fallback_exhausted_filter_discards_not_errors():
    """A .filter that rejects every draw must behave like assume(): the
    example is discarded and the run ends in Unsatisfiable — the private
    _Unsatisfied must never escape the runner (regression: draws happened
    outside the try block)."""
    fb = _build_fallback()
    st = fb.strategies

    @fb.settings(max_examples=3)
    @fb.given(n=st.integers(0, 9).filter(lambda v: False))
    def probe(n):
        pass  # pragma: no cover — no example can ever be drawn

    with pytest.raises(fb.errors.Unsatisfiable):
        probe()


def test_fallback_unique_lists_never_undershoot_min_size():
    """lists(unique=True) must discard rather than hand back fewer than
    min_size elements when the domain is too small."""
    fb = _build_fallback()
    st = fb.strategies
    rng = random.Random(0)
    with pytest.raises(fb._Unsatisfied):
        st.lists(st.booleans(), min_size=4, max_size=6,
                 unique=True).example(rng)
    ok = [st.lists(st.integers(0, 50), min_size=3, max_size=5,
                   unique=True).example(rng) for _ in range(20)]
    assert all(3 <= len(v) <= 5 and len(set(v)) == len(v) for v in ok)


def test_fallback_given_preserves_fixture_params():
    """@given must hide only the strategy kwargs from the visible
    signature: non-strategy params (pytest fixtures like tmp_path) stay
    visible and are forwarded to the test (real hypothesis preserves
    them; an empty Signature() made fixture-using property tests fail
    only under the fallback)."""
    import inspect

    fb = _build_fallback()
    st = fb.strategies
    seen = []

    @fb.settings(max_examples=4)
    @fb.given(x=st.integers(0, 5))
    def probe(tmp_path, x):
        seen.append((tmp_path, x))

    assert list(inspect.signature(probe).parameters) == ["tmp_path"]
    probe(tmp_path="T")
    assert len(seen) == 4 and all(t == "T" for t, _ in seen)


def test_fallback_strategies_respect_bounds():
    fb = _build_fallback()
    st = fb.strategies
    rng = random.Random(0)
    ints = [st.integers(3, 7).example(rng) for _ in range(50)]
    assert all(3 <= v <= 7 for v in ints)
    floats = [st.floats(0.5, 2.5).example(rng) for _ in range(50)]
    assert all(0.5 <= v <= 2.5 for v in floats)
    lists = [st.lists(st.integers(0, 1), min_size=2, max_size=4).example(rng)
             for _ in range(20)]
    assert all(2 <= len(v) <= 4 for v in lists)
