"""Mixed search spaces through the whole stack (DESIGN.md §10).

Four layers under test:
  * the round-and-repair projection (`core.descriptor.project_units`) —
    feasibility, idempotence, host/device agreement;
  * the mixed kernel — gram parity across ref/xla/pallas (≤1e-5, the
    acceptance bar, at whatever device count the suite runs under), PSD,
    the Hamming-factor semantics on the lattice, and the
    continuous-block-only gradient contract;
  * the engine/pool — heterogeneous type layouts stacked in one program,
    mesh=none vs sharded parity, routed vs batched agreement;
  * the gateway — mixed tenants end-to-end with eviction/restore and the
    off-lattice tell reject.
"""
import asyncio
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import descriptor as desc_mod
from repro.core import gp as gp_mod
from repro.core.acquisition import AcqConfig, optimize_acquisition
from repro.core.kernels import KernelParams, make_mixed_kernel
from repro.hpo.gateway import GatewayConfig, StudyGateway
from repro.hpo.pool import SchedulerConfig, StudyPool
from repro.hpo.space import (Categorical, Dim, MIXED_DEMO_SPACE,
                             SearchSpace)
from repro.kernels import ops

IMPLEMENTATIONS = ["ref", "xla", "pallas"]
N_DEVICES = len(jax.devices())

MIXED = MIXED_DEMO_SPACE          # Float log + Int(7) + Cat(3) + Conditional
SMALL = SearchSpace((Dim("a", 0.0, 1.0),
                     Categorical("c", ("p", "q", "r"))))  # width 4
FLOAT4 = SearchSpace(tuple(Dim(f"f{i}", 0.0, 1.0) for i in range(4)))


def _cfg(**kw) -> SchedulerConfig:
    kw.setdefault("n_max", 16)
    kw.setdefault("acq", AcqConfig(restarts=8, ascent_steps=4))
    kw.setdefault("seed", 0)
    return SchedulerConfig(**kw)


# ---------------------------------------------------------------------------
# Projection
# ---------------------------------------------------------------------------
def test_project_feasible_and_idempotent():
    desc = MIXED.descriptor()
    rng = np.random.default_rng(0)
    u = jnp.asarray(rng.uniform(size=(64, MIXED.dim)), jnp.float32)
    p = desc_mod.project_units(u, desc)
    p2 = desc_mod.project_units(p, desc)
    np.testing.assert_array_equal(np.asarray(p), np.asarray(p2))
    # device projection == host projection (one definition of feasible)
    np.testing.assert_allclose(np.asarray(p), MIXED.project(np.asarray(u)),
                               atol=1e-6)
    for row in np.asarray(p):
        # exactly one hot per categorical group
        assert row[2:5].sum() == 1.0 and set(row[2:5]) <= {0.0, 1.0}
        # int on the 7-point lattice
        assert round(row[1] * 6) == pytest.approx(row[1] * 6, abs=1e-5)
        # conditional momentum zeroed unless optimizer == "sgd"
        if row[2] != 1.0:
            assert row[5] == 0.0


def test_project_is_identity_on_continuous():
    desc = desc_mod.all_continuous(5)
    u = jnp.linspace(0, 1, 5)
    np.testing.assert_array_equal(np.asarray(desc_mod.project_units(u, desc)),
                                  np.asarray(u))
    assert not desc.has_discrete


def test_project_tie_break_is_first_index():
    desc = SMALL.descriptor()
    u = jnp.asarray([0.3, 0.7, 0.7, 0.1], jnp.float32)   # cat tie at q == p
    p = np.asarray(desc_mod.project_units(u, desc))
    np.testing.assert_allclose(p, [0.3, 1.0, 0.0, 0.0])


# ---------------------------------------------------------------------------
# Mixed kernel: parity, PSD, Hamming semantics, gradient contract
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("implementation", IMPLEMENTATIONS)
def test_mixed_gram_substrate_parity(implementation):
    """Acceptance bar: ≤1e-5 vs the ref substrate on every implementation
    (runs at 1 device everywhere and at 8 under the CI mesh job)."""
    desc = MIXED.descriptor()
    rng = np.random.default_rng(1)
    x = jnp.asarray(MIXED.sample(rng, 24))
    y = jnp.asarray(MIXED.sample(rng, 17))
    want = ops.mixed_gram(x, y, 1.3, 0.4, desc.cont_mask, desc.cat_mask,
                          implementation="ref")
    got = ops.mixed_gram(x, y, 1.3, 0.4, desc.cont_mask, desc.cat_mask,
                         implementation=implementation)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_mixed_gram_psd():
    desc = MIXED.descriptor()
    rng = np.random.default_rng(2)
    x = jnp.asarray(MIXED.sample(rng, 40))
    k = np.asarray(ops.mixed_gram(x, x, 1.0, 0.3, desc.cont_mask,
                                  desc.cat_mask, implementation="ref"))
    w = np.linalg.eigvalsh(k + 1e-5 * np.eye(40))
    assert w.min() > 0.0


def test_mixed_gram_hamming_semantics():
    """On the lattice the categorical factor is exp(-h/rho), h = number of
    differing groups; identical continuous blocks isolate it."""
    desc = SMALL.descriptor()
    rho = 0.7
    same = jnp.asarray([[0.5, 1.0, 0.0, 0.0]], jnp.float32)
    diff = jnp.asarray([[0.5, 0.0, 1.0, 0.0]], jnp.float32)
    k_same = float(ops.mixed_gram(same, same, 1.0, rho, desc.cont_mask,
                                  desc.cat_mask, implementation="ref")[0, 0])
    k_diff = float(ops.mixed_gram(same, diff, 1.0, rho, desc.cont_mask,
                                  desc.cat_mask, implementation="ref")[0, 0])
    assert k_same == pytest.approx(1.0, abs=1e-6)
    assert k_diff == pytest.approx(np.exp(-1.0 / rho), abs=1e-6)


def test_mixed_kernel_reduces_to_matern_on_continuous():
    from repro.core.kernels import matern52
    desc = desc_mod.all_continuous(3)
    kern = make_mixed_kernel(desc.cont_mask, desc.cat_mask)
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.uniform(size=(9, 3)), jnp.float32)
    p = KernelParams(sigma2=1.0, rho=0.5, noise2=1e-6)
    np.testing.assert_allclose(np.asarray(kern(x, x, p)),
                               np.asarray(matern52(x, x, p)), atol=1e-6)


@pytest.mark.parametrize("implementation", IMPLEMENTATIONS)
def test_mixed_gradient_continuous_block_only(implementation):
    """The categorical block gets zero cotangent on every substrate, and
    the continuous gradients agree across substrates."""
    desc = MIXED.descriptor()
    rng = np.random.default_rng(4)
    x = jnp.asarray(MIXED.sample(rng, 12))
    y = jnp.asarray(MIXED.sample(rng, 12))

    def total(xx):
        return jnp.sum(ops.mixed_gram(xx, y, 1.0, 0.4, desc.cont_mask,
                                      desc.cat_mask,
                                      implementation=implementation))

    g = jax.grad(total)(x)
    assert float(jnp.max(jnp.abs(g * desc.cat_mask))) == 0.0
    g_ref = jax.grad(lambda xx: jnp.sum(ops.mixed_gram(
        xx, y, 1.0, 0.4, desc.cont_mask, desc.cat_mask,
        implementation="ref")))(x)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref), atol=1e-4)


# ---------------------------------------------------------------------------
# Acquisition: round-and-repair inside the ascent
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("top_t", [1, 3])
def test_acquisition_lands_on_lattice(top_t):
    desc = MIXED.descriptor()
    kern = make_mixed_kernel(desc.cont_mask, desc.cat_mask)
    cfg = gp_mod.GPConfig(n_max=16, dim=MIXED.dim, desc=desc)
    state = gp_mod.init_state(cfg)
    rng = np.random.default_rng(5)
    xs = jnp.asarray(MIXED.sample(rng, 6))
    ys = jnp.asarray(rng.normal(size=6), jnp.float32)
    state = gp_mod.append_batch(state, kern, xs, ys)
    pts, _ = optimize_acquisition(
        state, kern, jnp.zeros(MIXED.dim), jnp.ones(MIXED.dim),
        jax.random.PRNGKey(0), AcqConfig(restarts=8, ascent_steps=5),
        top_t=top_t, desc=desc)
    pts = np.asarray(pts)
    assert pts.shape == (top_t, MIXED.dim)
    np.testing.assert_allclose(MIXED.project(pts), pts, atol=1e-6)


def test_gpconfig_mixed_requires_matern():
    with pytest.raises(ValueError, match="matern52"):
        gp_mod.GPConfig(n_max=8, dim=MIXED.dim, kernel="rbf",
                        desc=MIXED.descriptor())


# ---------------------------------------------------------------------------
# Engine/pool: heterogeneous layouts, batched == routed, mesh parity
# ---------------------------------------------------------------------------
def _drive(pool: StudyPool, rounds: int = 3) -> list[np.ndarray]:
    seen = []
    out = pool.advance_round([])
    for _ in range(rounds):
        events = [(s, out[s][0],
                   float(-np.sum((out[s][0].unit - 0.3 - 0.1 * s) ** 2)))
                  for s in range(pool.n_studies)]
        out = pool.advance_round(events)
        seen.append(np.stack([out[s][0].unit for s in range(pool.n_studies)]))
    return seen


@pytest.mark.parametrize("implementation", IMPLEMENTATIONS)
def test_pool_heterogeneous_layouts_feasible(implementation):
    """A mixed study and an all-float study share one stacked program;
    every suggestion stays on its OWN study's lattice."""
    pool = StudyPool([SMALL, FLOAT4], _cfg(implementation=implementation))
    assert pool.engine.mixed
    for units in _drive(pool, rounds=3):
        np.testing.assert_allclose(SMALL.project(units[0]), units[0],
                                   atol=1e-6)
        # the float study is unconstrained (projection must not leak)
        assert (units[1] >= 0.0).all() and (units[1] <= 1.0).all()
    assert pool.engine.n(0) == pool.engine.n(1) == 3


def test_pool_routed_matches_batched():
    """suggest_at (routed) and suggest_all (batched) draw identical points
    for identical states/keys in mixed mode."""
    mk = lambda: StudyPool([SMALL, SMALL], _cfg())
    a, b = mk(), mk()
    for pool in (a, b):
        out = pool.advance_round([])
        pool.absorb_many([(s, out[s][0], float(s) - 0.5) for s in (0, 1)])
    sa = a.suggest_all(t=1)
    for s in (0, 1):
        rb = b.suggest(s, 1)
        np.testing.assert_allclose(np.asarray(sa[s][0].unit),
                                   np.asarray(rb[0].unit), atol=1e-5)


def test_pool_mixed_mesh_parity():
    """mesh='none' and the 1x1 shard_map path agree on mixed suggestions
    (multi-device specs covered by the CI mesh job via test_shard's own
    parametrization plus this one when devices allow)."""
    base = _drive(StudyPool([SMALL] * 4, _cfg(mesh="none")))
    one = _drive(StudyPool([SMALL] * 4, _cfg(mesh="1x1")))
    for u, v in zip(base, one):
        np.testing.assert_allclose(u, v, atol=1e-5)


@pytest.mark.skipif(N_DEVICES < 8, reason="needs 8 devices (CI mesh job)")
def test_pool_mixed_mesh_multi_device_invariants():
    """What sharding guarantees for mixed rounds across device layouts:
    feasibility, per-mesh bitwise determinism, acquisition-VALUE parity
    with the unsharded round — and, since the tie-break quantization in
    `optimize_acquisition` (layout-stable top-t selection), cell
    IDENTITY: restarts whose EI values differ only by cross-layout ulps
    land in the same quantization bucket, so every layout picks the same
    winning restart and the chosen cell matches mesh='none' exactly."""
    import jax

    def suggest(mesh):
        pool = StudyPool([SMALL] * 4, _cfg(mesh=mesh))
        out = pool.advance_round([])
        pool.absorb_many([(s, out[s][0],
                           float(-np.sum((out[s][0].unit - 0.3) ** 2)))
                          for s in range(4)])
        u, v = pool.engine.suggest_all(
            jax.vmap(jax.random.PRNGKey)(np.arange(4)), top_t=1)
        return np.asarray(u)[:, 0, :], np.asarray(v)[:, 0]

    u_none, v_none = suggest("none")
    for spec in ("auto", "4x1", "2x2"):
        u, v = suggest(spec)
        u2, v2 = suggest(spec)
        np.testing.assert_allclose(SMALL.project(u), u, atol=1e-6)
        np.testing.assert_array_equal(u, u2)      # deterministic per mesh
        np.testing.assert_array_equal(v, v2)
        np.testing.assert_allclose(v, v_none, atol=1e-4)  # value parity
        # Hard cell-identity assertion (closed ROADMAP item): the same
        # restart wins under every layout, so the suggestion — discrete
        # cell included — matches the unsharded one to ascent round-off.
        np.testing.assert_allclose(u, u_none, atol=2e-5)


def test_engine_lag_refit_mixed():
    """The lag-event grid refit runs through the mixed kernel (per-study
    params diverge, factor stays consistent)."""
    pool = StudyPool([SMALL], _cfg(lag=3, n_max=16))
    out = pool.advance_round([])
    for r in range(5):
        ev = [(0, out[0][0], float(-r))]
        out = pool.advance_round(ev)
    assert pool.engine.n(0) == 5
    assert pool.engine.since_refit(0) < 5   # a refit fired
    u = out[0][0].unit
    np.testing.assert_allclose(SMALL.project(u), u, atol=1e-6)


def test_set_desc_rejects_discrete_on_continuous_engine():
    pool = StudyPool([FLOAT4], _cfg())
    assert not pool.engine.mixed
    with pytest.raises(ValueError, match="mixed"):
        pool.engine.set_desc(0, SMALL.descriptor())


def test_cfg_mixed_flag_forces_mixed_closures():
    pool = StudyPool([FLOAT4], _cfg(mixed=True))
    assert pool.engine.mixed
    pool.reset_study(0, space=SMALL)          # discrete tenant lands fine
    tr = pool.suggest(0, 1)[0]
    pool.absorb(0, tr, 0.5)
    u = pool.suggest(0, 1)[0].unit
    np.testing.assert_allclose(SMALL.project(u), u, atol=1e-6)


# ---------------------------------------------------------------------------
# Gateway: mixed tenants end-to-end
# ---------------------------------------------------------------------------
def test_gateway_mixed_tenant_eviction_restore(tmp_path):
    cfg = _cfg(n_max=32, ckpt_dir=str(tmp_path))
    gw = StudyGateway(SMALL, cfg, GatewayConfig(slots=1))

    async def drive():
        mixed_sid = gw.create_study(name="mixed")
        float_sid = gw.create_study(space=FLOAT4, name="float")
        for _ in range(3):
            for sid, space in ((mixed_sid, SMALL), (float_sid, FLOAT4)):
                tr = await gw.ask(sid)       # slot churn: 1 slot, 2 tenants
                u = np.asarray(tr.unit)
                np.testing.assert_allclose(space.project(u), u, atol=1e-6)
                gw.tell(sid, tr, float(-np.sum((u - 0.4) ** 2)))
        await gw.drain()
        return mixed_sid, float_sid

    mixed_sid, float_sid = asyncio.run(drive())
    assert gw.study_info(mixed_sid)["n_obs"] == 3
    assert gw.study_info(float_sid)["n_obs"] == 3
    assert gw.summary()["evictions"] >= 4    # 1 slot, alternating tenants


def test_gateway_rejects_discrete_tenant_without_mixed(tmp_path):
    cfg = _cfg(n_max=16, ckpt_dir=str(tmp_path))
    gw = StudyGateway(FLOAT4, cfg, GatewayConfig(slots=1))
    with pytest.raises(ValueError, match="mixed"):
        gw.create_study(space=SMALL)


def test_gateway_rejects_off_lattice_tell(tmp_path):
    cfg = _cfg(n_max=16, ckpt_dir=str(tmp_path))
    gw = StudyGateway(SMALL, cfg, GatewayConfig(slots=1))

    async def drive():
        sid = gw.create_study()
        tr = await gw.ask(sid)
        bad = dataclasses.replace(tr, unit=np.asarray(
            [0.5, 0.4, 0.3, 0.3], np.float32))
        with pytest.raises(ValueError, match="lattice"):
            gw.tell(sid, bad, 0.0)
        gw.tell(sid, tr, 0.0)                # the real one still lands
        await gw.drain()
        return sid

    sid = asyncio.run(drive())
    assert gw.study_info(sid)["n_obs"] == 1


def test_gateway_mixed_registry_restore_round_trip(tmp_path):
    """Typed spaces (incl. conditionals) survive the registry snapshot."""
    cfg = _cfg(n_max=32, ckpt_dir=str(tmp_path))
    gw = StudyGateway(MIXED, cfg, GatewayConfig(slots=2))

    async def drive(g, sid=None):
        if sid is None:
            sid = g.create_study(name="t0")
        tr = await g.ask(sid)
        g.tell(sid, tr, 1.25)
        await g.drain()
        return sid

    sid = asyncio.run(drive(gw))
    gw.checkpoint()
    gw2 = StudyGateway(MIXED, cfg, GatewayConfig(slots=2))
    assert gw2.restore()
    log_space = gw2._studies[sid].space
    assert log_space == MIXED
    assert gw2.study_info(sid)["best_value"] == 1.25
    asyncio.run(drive(gw2, sid))             # serving continues post-restore
    assert gw2.study_info(sid)["n_obs"] == 2


def test_gateway_restore_reapplies_resident_mixed_descriptor(tmp_path):
    """Regression: a RESIDENT mixed tenant on an all-float template must
    get its type descriptor re-installed by restore() — not just its
    bounds — or post-restore suggestions leave the lattice."""
    cfg = _cfg(n_max=32, ckpt_dir=str(tmp_path), mixed=True)
    gw = StudyGateway(FLOAT4, cfg, GatewayConfig(slots=2))

    async def one(g, sid):
        tr = await g.ask(sid)
        g.tell(sid, tr, float(-np.sum(np.asarray(tr.unit) ** 2)))
        await g.drain()
        return np.asarray(tr.unit)

    sid = gw.create_study(space=SMALL, name="mixed")   # custom layout
    asyncio.run(one(gw, sid))
    assert gw.study_info(sid)["resident"]
    gw.checkpoint()
    gw2 = StudyGateway(FLOAT4, cfg, GatewayConfig(slots=2))
    assert gw2.restore()
    u = asyncio.run(one(gw2, sid))
    np.testing.assert_allclose(SMALL.project(u), u, atol=1e-6)


def test_mixed_suggestions_deterministic_across_pools():
    """Same seeds, same spaces -> identical mixed suggestion streams (the
    restore/replay contract extends to discrete layouts)."""
    a = _drive(StudyPool([MIXED] * 2, _cfg()))
    b = _drive(StudyPool([MIXED] * 2, _cfg()))
    for u, v in zip(a, b):
        np.testing.assert_array_equal(u, v)
