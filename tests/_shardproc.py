"""Cross-process shard worker: hosts one StudyGateway over a
multiprocessing Pipe so the fault suite can SIGKILL a real shard process
mid-traffic and restart a fresh one over the same checkpoint store
(DESIGN.md §13; the in-process analogue is
`FederatedGateway.kill_shard`/`revive_shard`).

Protocol — one request tuple in, one response tuple out:

  ("create", name)  -> ("ok", sid)
  ("round", sid)    -> ("ok", unit)    # ask -> tell(objective) -> drain
  ("checkpoint",)   -> ("ok", None)    # quiescent epoch commit
  ("info", sid)     -> ("ok", study_info dict)
  ("close",)        -> ("ok", None), then the process exits cleanly

The worker sends ("ready", restored) once its gateway is up; `restored`
reports whether a previous incarnation's epoch was found in the store.
The parent never shuts the worker down on the crash path — that is the
point: it SIGKILLs the pid and restarts over the same directory.
"""
import numpy as np


def shard_main(conn, ckpt_dir, slots=2, n_max=24):
    import asyncio

    # tests/ rides sys.path into the spawned child (multiprocessing
    # forwards the parent's sys.path), so the shared helpers resolve
    from _traffic import make_cfg, objective
    from repro.hpo import GatewayConfig, StudyGateway
    from repro.hpo.space import RESNET_SPACE

    async def serve():
        gw = StudyGateway(RESNET_SPACE, make_cfg(ckpt_dir, n_max=n_max),
                          GatewayConfig(slots=slots))
        conn.send(("ready", gw.restore()))
        while True:
            cmd, *args = conn.recv()
            if cmd == "create":
                conn.send(("ok", gw.create_study(name=args[0])))
            elif cmd == "round":
                sid = args[0]
                tr = await gw.ask(sid)
                gw.tell(sid, tr, objective(sid, tr.unit))
                await gw.drain()
                conn.send(("ok", tuple(np.asarray(tr.unit).tolist())))
            elif cmd == "checkpoint":
                gw.checkpoint()
                conn.send(("ok", None))
            elif cmd == "info":
                conn.send(("ok", gw.study_info(args[0])))
            elif cmd == "close":
                await gw.aclose()
                conn.send(("ok", None))
                return
            else:
                conn.send(("err", f"unknown command {cmd!r}"))

    asyncio.run(serve())
