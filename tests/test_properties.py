"""Property-based suite (hypothesis; falls back to the deterministic
conftest shim when the package is absent — either way these RUN, they do
not skip).

Five families:
  * search-space round-trips under *random* specs (not just the presets),
  * append→posterior invariants against the ref substrate's dense GP,
  * an `li_buf` drift bound across random append/re-anchor interleavings —
    the state-machine property guarding the matmul-only batched path (the
    maintained inverse must track the factor through ANY op sequence),
  * mixed-space invariants under *random typed* specs (DESIGN.md §10):
    encode∘decode round-trips for every dim type, one-hot argmax
    stability, mixed-gram PSD + substrate parity, and round-and-repair
    feasibility,
  * federation observational equivalence (DESIGN.md §13): ANY interleaving
    of asks, tells, migrations, and shard kill/revive over a 2-shard
    federation is observably a single-pool run of the same event order,
    and routing is a deterministic pure function of (sid, shard count).
"""
import asyncio
import dataclasses
import hashlib
import tempfile
import types

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from _traffic import make_cfg, objective
from repro.core import (GPConfig, append, dense_posterior, init_state,
                        matern52, posterior, refactor)
from repro.core import descriptor as desc_mod
from repro.hpo import (FederatedGateway, FederationConfig, GatewayConfig,
                       StudyGateway)
from repro.hpo.space import (Categorical, Conditional, Dim, Int,
                             RESNET_SPACE, SearchSpace)
from repro.kernels import ops as kops


# ---------------------------------------------------------------------------
# Search-space round-trips under random specs
# ---------------------------------------------------------------------------
def _space_from_spec(spec) -> SearchSpace:
    dims = []
    for i, (lo, width, is_log) in enumerate(spec):
        if is_log:
            lo_v = abs(lo) + 1e-3          # log dims need lo > 0
            dims.append(Dim(f"d{i}", lo_v, lo_v * (1.0 + width), "log"))
        else:
            dims.append(Dim(f"d{i}", lo, lo + width))
    return SearchSpace(tuple(dims))


_SPEC = st.lists(st.tuples(st.floats(-5.0, 5.0), st.floats(0.1, 50.0),
                           st.booleans()), min_size=1, max_size=6)


@settings(max_examples=25, deadline=None)
@given(spec=_SPEC, u=st.floats(0.0, 1.0))
def test_space_value_of_unit_roundtrips(spec, u):
    """to_unit(to_value(u)) == u for any random spec, on both scales."""
    space = _space_from_spec(spec)
    unit = np.full(space.dim, u, np.float32)
    back = space.to_unit(space.to_hparams(unit))
    np.testing.assert_allclose(back, np.clip(unit, 0.0, 1.0),
                               rtol=1e-4, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(spec=_SPEC, f=st.floats(0.0, 1.0))
def test_space_unit_of_value_roundtrips(spec, f):
    """to_value(to_unit(v)) == v for any in-range value."""
    space = _space_from_spec(spec)
    hp = {d.name: d.to_value(f) for d in space.dims}
    unit = space.to_unit(hp)
    hp_back = space.to_hparams(unit)
    for d in space.dims:
        np.testing.assert_allclose(hp_back[d.name], hp[d.name],
                                   rtol=1e-4, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(spec=_SPEC, u=st.floats(-2.0, 3.0))
def test_space_out_of_range_units_clamp(spec, u):
    """to_value clamps units outside [0, 1] to the dim bounds."""
    space = _space_from_spec(spec)
    hp = space.to_hparams(np.full(space.dim, u, np.float32))
    for d in space.dims:
        lo, hi = min(d.lo, d.hi), max(d.lo, d.hi)
        assert lo - 1e-6 * abs(lo) <= hp[d.name] <= hi + 1e-6 * abs(hi)


# ---------------------------------------------------------------------------
# Append → posterior invariants vs the ref substrate's dense GP
# ---------------------------------------------------------------------------
@settings(max_examples=10, deadline=None)
@given(n=st.integers(2, 10), seed=st.integers(0, 500))
def test_append_posterior_matches_ref_dense(n, seed):
    """A state built purely by lazy appends matches the textbook dense GP
    computed by the reference substrate, and the posterior is well-formed
    (nonnegative variance, near-interpolation at observed points)."""
    d = 3
    rng = np.random.default_rng(seed)
    xs = rng.uniform(size=(n, d)).astype(np.float32)
    ys = np.sin(3.0 * xs[:, 0]) + xs[:, 1] - 0.5 * xs[:, 2]
    state = init_state(GPConfig(n_max=16, dim=d, noise2=1e-5,
                                implementation="ref"))
    for x, y in zip(xs, ys):
        state = append(state, matern52, jnp.asarray(x),
                       jnp.asarray(y, jnp.float32), implementation="ref")
    xq = rng.uniform(size=(7, d)).astype(np.float32)
    mean, var = posterior(state, matern52, jnp.asarray(xq),
                          implementation="ref")
    mean_d, var_d = dense_posterior(jnp.asarray(xs), jnp.asarray(ys),
                                    jnp.asarray(xq), matern52, state.params,
                                    implementation="ref")
    np.testing.assert_allclose(np.asarray(mean), np.asarray(mean_d),
                               rtol=1e-3, atol=2e-4)
    np.testing.assert_allclose(np.asarray(var), np.asarray(var_d),
                               rtol=1e-2, atol=2e-4)
    assert np.all(np.asarray(var) >= 0.0)
    mean_obs, var_obs = posterior(state, matern52, jnp.asarray(xs),
                                  implementation="ref")
    np.testing.assert_allclose(np.asarray(mean_obs), ys, atol=2e-2)
    assert np.all(np.asarray(var_obs) < 1e-2)


# ---------------------------------------------------------------------------
# li_buf drift bound under random append/re-anchor interleavings
# ---------------------------------------------------------------------------
def _inverse_drift(state) -> float:
    n = int(state.n)
    if n == 0:
        return 0.0
    l_act = np.asarray(state.l_buf)[:n, :n]
    li_act = np.asarray(state.li_buf)[:n, :n]
    return float(np.abs(li_act @ l_act - np.eye(n)).max())


@settings(max_examples=10, deadline=None)
@given(ops=st.lists(st.sampled_from(["append", "append", "append",
                                     "reanchor"]),
                    min_size=1, max_size=24),
       seed=st.integers(0, 999))
def test_li_buf_tracks_factor_under_any_interleaving(ops, seed):
    """State-machine property: through ANY interleaving of lazy appends and
    re-anchor refactors, the maintained inverse stays within a tight drift
    bound of the true factor inverse, and the padding block stays exactly
    identity (measured drift over 36-append chains is ~1e-5; the bound
    leaves two orders of slack for unlucky conditioning)."""
    rng = np.random.default_rng(seed)
    state = init_state(GPConfig(n_max=32, dim=2, noise2=1e-4))
    for op in ops:
        if op == "append":
            x = rng.uniform(size=2).astype(np.float32)
            y = float(np.sin(3.0 * x[0]) + x[1])
            state = append(state, matern52, jnp.asarray(x),
                           jnp.asarray(y, jnp.float32))
        else:
            state = refactor(state, matern52)
            assert int(state.since_refit) == 0
        assert _inverse_drift(state) < 5e-3
        n = int(state.n)
        pad_l = np.asarray(state.l_buf)[n:, n:]
        pad_li = np.asarray(state.li_buf)[n:, n:]
        eye = np.eye(state.n_max - n)
        np.testing.assert_array_equal(pad_l, eye)
        np.testing.assert_allclose(pad_li, eye, atol=1e-6)
    # the interleaving never corrupts the observation count
    assert int(state.n) == sum(op == "append" for op in ops)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 99), k=st.integers(8, 20))
def test_reanchor_after_drift_restores_exact_inverse(seed, k):
    """A re-anchor refactor collapses whatever drift a long lazy chain
    accumulated back to (near) solver precision — the inv_refresh guard's
    actual contract."""
    rng = np.random.default_rng(seed)
    state = init_state(GPConfig(n_max=32, dim=2, noise2=1e-4))
    for _ in range(k):
        x = rng.uniform(size=2).astype(np.float32)
        state = append(state, matern52, jnp.asarray(x),
                       jnp.asarray(float(x.sum()), jnp.float32))
    refreshed = refactor(state, matern52)
    assert _inverse_drift(refreshed) <= max(1e-5, _inverse_drift(state))
    # params untouched by the re-anchor (it is not a refit)
    for f in dataclasses.fields(state.params):
        np.testing.assert_array_equal(
            np.asarray(getattr(state.params, f.name)),
            np.asarray(getattr(refreshed.params, f.name)))


# ---------------------------------------------------------------------------
# Mixed-space invariants under random typed specs (DESIGN.md §10)
# ---------------------------------------------------------------------------
_MIXED_DIM = st.one_of(
    st.tuples(st.just("float"), st.floats(-3.0, 3.0), st.floats(0.1, 10.0),
              st.booleans()),
    st.tuples(st.just("int"), st.integers(-5, 5), st.integers(0, 7)),
    st.tuples(st.just("cat"), st.integers(2, 4)),
)
_MIXED_SPEC = st.lists(_MIXED_DIM, min_size=1, max_size=5)


def _mixed_space_from_spec(spec, conditional: bool) -> SearchSpace:
    dims = []
    first_cat = None
    for i, s in enumerate(spec):
        if s[0] == "float":
            _, lo, width, is_log = s
            if is_log:
                lo = abs(lo) + 1e-3
                dims.append(Dim(f"d{i}", lo, lo * (1.0 + width), "log"))
            else:
                dims.append(Dim(f"d{i}", lo, lo + width))
        elif s[0] == "int":
            _, lo, span = s
            dims.append(Int(f"d{i}", lo, lo + span))
        else:
            cat = Categorical(f"d{i}", tuple(f"c{j}" for j in range(s[1])))
            dims.append(cat)
            first_cat = first_cat or cat
    if conditional and first_cat is not None:
        dims.append(Conditional(Dim("child", 0.0, 1.0),
                                first_cat.name, first_cat.choices[0]))
    return SearchSpace(tuple(dims))


@settings(max_examples=20, deadline=None)
@given(spec=_MIXED_SPEC, conditional=st.booleans(), seed=st.integers(0, 999))
def test_mixed_encode_decode_roundtrips(spec, conditional, seed):
    """encode∘decode is the identity on feasible points for EVERY dim type,
    including gated conditionals (inactive children re-encode to the
    neutral block, so the unit vector round-trips exactly)."""
    space = _mixed_space_from_spec(spec, conditional)
    rng = np.random.default_rng(seed)
    for row in space.sample(rng, 8):
        hp = space.to_hparams(row)
        back = space.to_unit(hp)
        np.testing.assert_allclose(back, row, atol=1e-5)
        # decoded values are in-range and of the right type
        for d in space.dims:
            v = hp[d.name]
            inner = d.inner if isinstance(d, Conditional) else d
            if v is None:
                assert isinstance(d, Conditional)
                assert hp[d.parent] != d.when
            elif isinstance(inner, Int):
                assert inner.lo <= v <= inner.hi and float(v).is_integer()
            elif isinstance(inner, Categorical):
                assert v in inner.choices


@settings(max_examples=20, deadline=None)
@given(n_choices=st.integers(2, 6), seed=st.integers(0, 999))
def test_one_hot_argmax_stable_under_perturbation(n_choices, seed):
    """Decoding survives sub-0.5 perturbations of a one-hot block (argmax
    cannot flip while the hot coordinate stays dominant), and ties break
    to the first index on both the host and device paths."""
    cat = Categorical("c", tuple(f"c{j}" for j in range(n_choices)))
    space = SearchSpace((cat,))
    desc = space.descriptor()
    rng = np.random.default_rng(seed)
    for j, choice in enumerate(cat.choices):
        u = cat.encode(choice)
        noisy = np.clip(u + rng.uniform(-0.49, 0.49, u.shape), 0.0, 1.0)
        noisy[j] = max(noisy[j], 0.51)       # hot stays dominant
        assert cat.decode(noisy.astype(np.float32)) == choice
        repaired = np.asarray(desc_mod.project_units(
            jnp.asarray(noisy, jnp.float32), desc))
        np.testing.assert_array_equal(repaired, u)


@settings(max_examples=8, deadline=None)
@given(spec=_MIXED_SPEC, conditional=st.booleans(), seed=st.integers(0, 999))
def test_mixed_gram_psd_and_parity(spec, conditional, seed):
    """For ANY typed layout: the mixed gram is PSD on feasible points and
    the three substrates agree to 1e-5 (the acceptance bar)."""
    space = _mixed_space_from_spec(spec, conditional)
    desc = space.descriptor()
    rng = np.random.default_rng(seed)
    x = jnp.asarray(space.sample(rng, 12))
    want = np.asarray(kops.mixed_gram(x, x, 1.0, 0.5, desc.cont_mask,
                                      desc.cat_mask, implementation="ref"))
    for impl in ("xla", "pallas"):
        got = np.asarray(kops.mixed_gram(x, x, 1.0, 0.5, desc.cont_mask,
                                         desc.cat_mask,
                                         implementation=impl))
        np.testing.assert_allclose(got, want, atol=1e-5)
    w = np.linalg.eigvalsh(want + 1e-5 * np.eye(12))
    assert w.min() > 0.0
    np.testing.assert_allclose(np.diag(want), 1.0, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(spec=_MIXED_SPEC, conditional=st.booleans(), seed=st.integers(0, 999))
def test_round_and_repair_always_feasible(spec, conditional, seed):
    """project_units of ANY cube point lands on the feasible lattice
    (host round-trip agrees), is idempotent, and leaves continuous
    coordinates untouched."""
    space = _mixed_space_from_spec(spec, conditional)
    desc = space.descriptor()
    rng = np.random.default_rng(seed)
    u = jnp.asarray(rng.uniform(size=(6, space.dim)), jnp.float32)
    p = desc_mod.project_units(u, desc)
    p_np = np.asarray(p)
    np.testing.assert_allclose(space.project(np.asarray(u)), p_np,
                               atol=1e-6)
    np.testing.assert_array_equal(
        np.asarray(desc_mod.project_units(p, desc)), p_np)
    cont = np.asarray(desc.cont_mask) * (np.asarray(desc.levels) == 0) \
        * (np.asarray(desc.parent) < 0)
    np.testing.assert_array_equal(p_np * cont, np.asarray(u) * cont)
    # every projected row encodes a decodable, re-encodable point
    for row in p_np:
        np.testing.assert_allclose(space.to_unit(space.to_hparams(row)),
                                   row, atol=1e-5)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 999), n=st.integers(3, 8))
def test_mixed_append_posterior_matches_dense(seed, n):
    """The lazy append/posterior machinery under the mixed kernel matches
    the textbook dense GP with the same kernel."""
    from repro.core import dense_posterior as dense
    from repro.core.kernels import make_mixed_kernel
    space = SearchSpace((Dim("a", 0.0, 1.0), Int("k", 0, 4),
                         Categorical("c", ("p", "q"))))
    desc = space.descriptor()
    kern = make_mixed_kernel(desc.cont_mask, desc.cat_mask)
    rng = np.random.default_rng(seed)
    xs = space.sample(rng, n)
    ys = (xs[:, 0] + xs[:, 1] - xs[:, 2]).astype(np.float32)
    state = init_state(GPConfig(n_max=16, dim=space.dim, noise2=1e-5,
                                desc=desc))
    for x, y in zip(xs, ys):
        state = append(state, kern, jnp.asarray(x),
                       jnp.asarray(y, jnp.float32))
    xq = jnp.asarray(space.sample(rng, 5))
    mean, var = posterior(state, kern, xq)
    mean_d, var_d = dense(jnp.asarray(xs), jnp.asarray(ys), xq, kern,
                          state.params)
    np.testing.assert_allclose(np.asarray(mean), np.asarray(mean_d),
                               rtol=1e-3, atol=5e-4)
    np.testing.assert_allclose(np.asarray(var), np.asarray(var_d),
                               rtol=1e-2, atol=5e-4)


# ---------------------------------------------------------------------------
# qEI fantasy rollback exactness under random interleavings (DESIGN.md §12)
# ---------------------------------------------------------------------------
# One shared pool pair, reset per example: every example would otherwise
# pay the jitted ask_q/absorb compile, and the family is about STATE, not
# construction.  Pool A serves through the fantasy path; pool B is the
# never-fantasized control fed the identical real observations.
_FANTASY_POOLS: list = []


def _fantasy_pools():
    from repro.core.acquisition import AcqConfig
    from repro.hpo.pool import SchedulerConfig, StudyPool
    from repro.hpo.space import RESNET_SPACE
    if not _FANTASY_POOLS:
        cfg = SchedulerConfig(n_max=48, seed=0, ckpt_every=10_000,
                              acq=AcqConfig(restarts=8, ascent_steps=4))
        _FANTASY_POOLS.append(StudyPool([RESNET_SPACE], cfg))
        _FANTASY_POOLS.append(StudyPool([RESNET_SPACE], cfg))
    pa, pb = _FANTASY_POOLS
    pa.reset_study(0)
    pb.reset_study(0)
    return pa, pb


@settings(max_examples=8, deadline=None)
@given(script=st.lists(st.sampled_from(["ask1", "ask2", "ask3",
                                        "tell", "foreign", "release"]),
                       min_size=3, max_size=10),
       seed=st.integers(0, 2 ** 31 - 1))
def test_fantasy_rollback_bitwise_under_random_interleavings(script, seed):
    """Any interleaving of q-asks, (out-of-order) tells, foreign tells and
    fantasy releases ends — once every pending row is drained — in a state
    BITWISE equal to a control pool that absorbed the same real
    observations and never fantasized (DESIGN.md §12 rollback contract)."""
    from repro.hpo.pool import Trial
    pa, pb = _fantasy_pools()
    rng = np.random.RandomState(seed)

    def value(u):
        return float(-np.sum((np.asarray(u) - 0.3) ** 2))

    # two real seed observations so the first ask_q works off a posterior
    pending: list = []           # trials awaiting their real tell, pool A
    for _ in range(2):
        u = rng.rand(pa.studies[0].space.dim).astype(np.float32)
        v = value(u)
        pa.absorb(0, Trial(10_000, u, {}), v)
        pb.absorb(0, Trial(10_000, u, {}), v)

    for op in script:
        if op.startswith("ask"):
            q = int(op[3:])
            if pa.n_real(0) + pa.fantasy_active(0) + q > 40:
                continue
            pending.extend(pa.ask_q(0, q))
        elif op == "tell" and pending:
            tr = pending.pop(rng.randint(len(pending)))
            v = value(tr.unit)
            pa.absorb(0, tr, v)
            pb.absorb(0, Trial(10_000, np.asarray(tr.unit), {}), v)
        elif op == "foreign":
            u = rng.rand(pa.studies[0].space.dim).astype(np.float32)
            v = value(u)
            pa.absorb(0, Trial(10_000, u, {}), v)
            pb.absorb(0, Trial(10_000, u, {}), v)
        elif op == "release" and pending:
            tr = pending.pop(rng.randint(len(pending)))
            assert pa.release_fantasies(0, [np.asarray(tr.unit)]) == 1
    # drain: tell every survivor in random order
    while pending:
        tr = pending.pop(rng.randint(len(pending)))
        v = value(tr.unit)
        pa.absorb(0, tr, v)
        pb.absorb(0, Trial(10_000, np.asarray(tr.unit), {}), v)

    assert pa.fantasy_active(0) == 0
    assert pa.engine.n(0) == pb.engine.n(0) == pa.n_real(0)
    import jax
    for (path, la), (_, lb) in zip(
            jax.tree_util.tree_flatten_with_path(pa.engine.study_state(0))[0],
            jax.tree_util.tree_flatten_with_path(pb.engine.study_state(0))[0]):
        assert np.asarray(la).tobytes() == np.asarray(lb).tobytes(), \
            f"{jax.tree_util.keystr(path)} differs after drain"


# ---------------------------------------------------------------------------
# Federation: routing determinism + single-pool equivalence (DESIGN.md §13)
# ---------------------------------------------------------------------------
def _route(sid: int, n_shards: int) -> int:
    # route() reads only self.fed — a shim avoids building n_shards pools
    # per hypothesis example
    shim = types.SimpleNamespace(fed=FederationConfig(n_shards=n_shards))
    return FederatedGateway.route(shim, sid)


@settings(max_examples=25, deadline=None)
@given(sid=st.integers(0, 100_000), n_shards=st.integers(1, 16))
def test_routing_deterministic_pure_function(sid, n_shards):
    """route(sid) is a pure function of (sid, shard count): repeated calls
    agree, and the winner IS the rendezvous argmax recomputed from first
    principles — no process state (PYTHONHASHSEED, dict order) leaks in."""
    got = _route(sid, n_shards)
    assert got == _route(sid, n_shards)
    assert 0 <= got < n_shards
    want = max(range(n_shards), key=lambda s: hashlib.sha256(
        f"{s}:{sid}".encode()).digest())
    assert got == want


def test_routing_stable_and_spread_under_fixed_shard_count():
    """Under a fixed shard count the ring never reroutes an existing study
    (pure function ⇒ later creates cannot move earlier sids), and the hash
    actually spreads a contiguous sid block over every shard."""
    for n_shards in (2, 3, 4):
        first = [_route(s, n_shards) for s in range(64)]
        assert first == [_route(s, n_shards) for s in range(64)]
        assert set(first) == set(range(n_shards)), \
            f"{n_shards} shards: some shard never routed"


_FED_OPS = st.lists(
    st.one_of(
        st.tuples(st.just("round"), st.integers(0, 3)),
        st.tuples(st.just("migrate"), st.integers(0, 3)),
        st.tuples(st.just("kill"), st.integers(0, 1)),
    ), min_size=4, max_size=12)


@settings(max_examples=5, deadline=None)
@given(script=_FED_OPS)
def test_fed_random_interleavings_equal_single_pool(script):
    """ANY random interleaving of ask/tell rounds, migrations, and shard
    kill/revive cycles (checkpointed at the kill point, i.e. a crash at a
    durable instant) over a 2-shard federation is OBSERVABLY a single-pool
    run of the same per-study event order: identical suggestion streams,
    ledgers (n_obs, best_value), and absorb telemetry.  The federation
    shards run 2 slots each (eviction churn + migrations); the reference
    holds everything resident."""
    async def run_fed(root):
        fg = FederatedGateway(RESNET_SPACE, make_cfg(root, n_max=24),
                              GatewayConfig(slots=2),
                              FederationConfig(n_shards=2))
        sids = [fg.create_study(name=f"s{i}") for i in range(4)]
        streams = {s: [] for s in sids}
        for op in script:
            if op[0] == "round":
                s = sids[op[1]]
                tr = await fg.ask(s)
                streams[s].append(tuple(np.asarray(tr.unit).tolist()))
                fg.tell(s, tr, objective(s, tr.unit))
                await fg.drain()
            elif op[0] == "migrate":
                s = sids[op[1]]
                fg.migrate_study(s, 1 - fg.shard_of(s))
            else:
                fg.checkpoint()
                fg.kill_shard(op[1])
                fg.revive_shard(op[1])
        info = {s: (fg.study_info(s)["n_obs"],
                    fg.study_info(s)["best_value"]) for s in sids}
        absorbed = fg.summary()["absorbed"]
        await fg.aclose()
        return streams, info, absorbed

    async def run_single(d):
        gw = StudyGateway(RESNET_SPACE, make_cfg(d, n_max=24),
                          GatewayConfig(slots=4))
        sids = [gw.create_study(name=f"s{i}") for i in range(4)]
        streams = {s: [] for s in sids}
        for op in script:
            if op[0] != "round":
                continue             # migrations/kills are fed-internal
            s = sids[op[1]]
            tr = await gw.ask(s)
            streams[s].append(tuple(np.asarray(tr.unit).tolist()))
            gw.tell(s, tr, objective(s, tr.unit))
            await gw.drain()
        info = {s: (gw.study_info(s)["n_obs"],
                    gw.study_info(s)["best_value"]) for s in sids}
        absorbed = gw.summary()["absorbed"]
        await gw.aclose()
        return streams, info, absorbed

    with tempfile.TemporaryDirectory() as root, \
            tempfile.TemporaryDirectory() as d_ref:
        fed = asyncio.run(run_fed(root))
        ref = asyncio.run(run_single(d_ref))
    assert fed[0] == ref[0], "suggestion streams diverged"
    assert fed[1] == ref[1], "study ledgers diverged"
    assert fed[2] == ref[2], "absorb telemetry diverged"
