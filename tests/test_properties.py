"""Property-based suite (hypothesis; falls back to the deterministic
conftest shim when the package is absent — either way these RUN, they do
not skip).

Three families, per the PR-4 testing-debt payoff:
  * search-space round-trips under *random* specs (not just the presets),
  * append→posterior invariants against the ref substrate's dense GP,
  * an `li_buf` drift bound across random append/re-anchor interleavings —
    the state-machine property guarding the matmul-only batched path (the
    maintained inverse must track the factor through ANY op sequence).
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import (GPConfig, append, dense_posterior, init_state,
                        matern52, posterior, refactor)
from repro.hpo.space import Dim, SearchSpace


# ---------------------------------------------------------------------------
# Search-space round-trips under random specs
# ---------------------------------------------------------------------------
def _space_from_spec(spec) -> SearchSpace:
    dims = []
    for i, (lo, width, is_log) in enumerate(spec):
        if is_log:
            lo_v = abs(lo) + 1e-3          # log dims need lo > 0
            dims.append(Dim(f"d{i}", lo_v, lo_v * (1.0 + width), "log"))
        else:
            dims.append(Dim(f"d{i}", lo, lo + width))
    return SearchSpace(tuple(dims))


_SPEC = st.lists(st.tuples(st.floats(-5.0, 5.0), st.floats(0.1, 50.0),
                           st.booleans()), min_size=1, max_size=6)


@settings(max_examples=25, deadline=None)
@given(spec=_SPEC, u=st.floats(0.0, 1.0))
def test_space_value_of_unit_roundtrips(spec, u):
    """to_unit(to_value(u)) == u for any random spec, on both scales."""
    space = _space_from_spec(spec)
    unit = np.full(space.dim, u, np.float32)
    back = space.to_unit(space.to_hparams(unit))
    np.testing.assert_allclose(back, np.clip(unit, 0.0, 1.0),
                               rtol=1e-4, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(spec=_SPEC, f=st.floats(0.0, 1.0))
def test_space_unit_of_value_roundtrips(spec, f):
    """to_value(to_unit(v)) == v for any in-range value."""
    space = _space_from_spec(spec)
    hp = {d.name: d.to_value(f) for d in space.dims}
    unit = space.to_unit(hp)
    hp_back = space.to_hparams(unit)
    for d in space.dims:
        np.testing.assert_allclose(hp_back[d.name], hp[d.name],
                                   rtol=1e-4, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(spec=_SPEC, u=st.floats(-2.0, 3.0))
def test_space_out_of_range_units_clamp(spec, u):
    """to_value clamps units outside [0, 1] to the dim bounds."""
    space = _space_from_spec(spec)
    hp = space.to_hparams(np.full(space.dim, u, np.float32))
    for d in space.dims:
        lo, hi = min(d.lo, d.hi), max(d.lo, d.hi)
        assert lo - 1e-6 * abs(lo) <= hp[d.name] <= hi + 1e-6 * abs(hi)


# ---------------------------------------------------------------------------
# Append → posterior invariants vs the ref substrate's dense GP
# ---------------------------------------------------------------------------
@settings(max_examples=10, deadline=None)
@given(n=st.integers(2, 10), seed=st.integers(0, 500))
def test_append_posterior_matches_ref_dense(n, seed):
    """A state built purely by lazy appends matches the textbook dense GP
    computed by the reference substrate, and the posterior is well-formed
    (nonnegative variance, near-interpolation at observed points)."""
    d = 3
    rng = np.random.default_rng(seed)
    xs = rng.uniform(size=(n, d)).astype(np.float32)
    ys = np.sin(3.0 * xs[:, 0]) + xs[:, 1] - 0.5 * xs[:, 2]
    state = init_state(GPConfig(n_max=16, dim=d, noise2=1e-5,
                                implementation="ref"))
    for x, y in zip(xs, ys):
        state = append(state, matern52, jnp.asarray(x),
                       jnp.asarray(y, jnp.float32), implementation="ref")
    xq = rng.uniform(size=(7, d)).astype(np.float32)
    mean, var = posterior(state, matern52, jnp.asarray(xq),
                          implementation="ref")
    mean_d, var_d = dense_posterior(jnp.asarray(xs), jnp.asarray(ys),
                                    jnp.asarray(xq), matern52, state.params,
                                    implementation="ref")
    np.testing.assert_allclose(np.asarray(mean), np.asarray(mean_d),
                               rtol=1e-3, atol=2e-4)
    np.testing.assert_allclose(np.asarray(var), np.asarray(var_d),
                               rtol=1e-2, atol=2e-4)
    assert np.all(np.asarray(var) >= 0.0)
    mean_obs, var_obs = posterior(state, matern52, jnp.asarray(xs),
                                  implementation="ref")
    np.testing.assert_allclose(np.asarray(mean_obs), ys, atol=2e-2)
    assert np.all(np.asarray(var_obs) < 1e-2)


# ---------------------------------------------------------------------------
# li_buf drift bound under random append/re-anchor interleavings
# ---------------------------------------------------------------------------
def _inverse_drift(state) -> float:
    n = int(state.n)
    if n == 0:
        return 0.0
    l_act = np.asarray(state.l_buf)[:n, :n]
    li_act = np.asarray(state.li_buf)[:n, :n]
    return float(np.abs(li_act @ l_act - np.eye(n)).max())


@settings(max_examples=10, deadline=None)
@given(ops=st.lists(st.sampled_from(["append", "append", "append",
                                     "reanchor"]),
                    min_size=1, max_size=24),
       seed=st.integers(0, 999))
def test_li_buf_tracks_factor_under_any_interleaving(ops, seed):
    """State-machine property: through ANY interleaving of lazy appends and
    re-anchor refactors, the maintained inverse stays within a tight drift
    bound of the true factor inverse, and the padding block stays exactly
    identity (measured drift over 36-append chains is ~1e-5; the bound
    leaves two orders of slack for unlucky conditioning)."""
    rng = np.random.default_rng(seed)
    state = init_state(GPConfig(n_max=32, dim=2, noise2=1e-4))
    for op in ops:
        if op == "append":
            x = rng.uniform(size=2).astype(np.float32)
            y = float(np.sin(3.0 * x[0]) + x[1])
            state = append(state, matern52, jnp.asarray(x),
                           jnp.asarray(y, jnp.float32))
        else:
            state = refactor(state, matern52)
            assert int(state.since_refit) == 0
        assert _inverse_drift(state) < 5e-3
        n = int(state.n)
        pad_l = np.asarray(state.l_buf)[n:, n:]
        pad_li = np.asarray(state.li_buf)[n:, n:]
        eye = np.eye(state.n_max - n)
        np.testing.assert_array_equal(pad_l, eye)
        np.testing.assert_allclose(pad_li, eye, atol=1e-6)
    # the interleaving never corrupts the observation count
    assert int(state.n) == sum(op == "append" for op in ops)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 99), k=st.integers(8, 20))
def test_reanchor_after_drift_restores_exact_inverse(seed, k):
    """A re-anchor refactor collapses whatever drift a long lazy chain
    accumulated back to (near) solver precision — the inv_refresh guard's
    actual contract."""
    rng = np.random.default_rng(seed)
    state = init_state(GPConfig(n_max=32, dim=2, noise2=1e-4))
    for _ in range(k):
        x = rng.uniform(size=2).astype(np.float32)
        state = append(state, matern52, jnp.asarray(x),
                       jnp.asarray(float(x.sum()), jnp.float32))
    refreshed = refactor(state, matern52)
    assert _inverse_drift(refreshed) <= max(1e-5, _inverse_drift(state))
    # params untouched by the re-anchor (it is not a refit)
    for f in dataclasses.fields(state.params):
        np.testing.assert_array_equal(
            np.asarray(getattr(state.params, f.name)),
            np.asarray(getattr(refreshed.params, f.name)))
