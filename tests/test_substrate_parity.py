"""Substrate-dispatch parity: every `implementation` computes the same GP.

Covers the contract of DESIGN.md §6: the lazy GP posterior routed through
each substrate ("xla", "ref", and "pallas" in interpret mode on CPU) matches
the textbook dense GP; the deferred-alpha `append_batch` matches sequential
appends; the fused `lazy_append` matches the unfused row-append + alpha
recompute; and the observability/safety satellites (conditioning-floor
counter, capacity guard) behave.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (BayesOpt, BOConfig, GPCapacityError, GPConfig,
                        KernelParams, append, append_batch, dense_posterior,
                        ensure_capacity, gram, init_state, matern52,
                        posterior, refactor)
from repro.core import cholesky as chol
from repro.core import gp as gp_mod
from repro.hpo.scheduler import SchedulerConfig, TrialScheduler
from repro.hpo.space import RESNET_SPACE
from repro.kernels import ops

IMPLEMENTATIONS = ["xla", "ref", "pallas"]


def _seed_state(key, n0, d, n_max, noise2=1e-6, implementation="auto"):
    xs = jax.random.uniform(key, (n0, d), minval=-2.0, maxval=2.0)
    ys = jnp.sin(xs.sum(-1)) + 0.1 * xs[:, 0]
    cfg = GPConfig(n_max=n_max, dim=d, noise2=noise2,
                   implementation=implementation)
    st = init_state(cfg)
    st = dataclasses.replace(
        st, x_buf=st.x_buf.at[:n0].set(xs),
        y_buf=st.y_buf.at[:n0].set(ys), n=jnp.asarray(n0, jnp.int32))
    return refactor(st, matern52, implementation=implementation), xs, ys


# ---------------------------------------------------------------------------
# Posterior parity across substrates
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("implementation", IMPLEMENTATIONS)
def test_posterior_matches_dense_per_implementation(implementation):
    d = 3
    key = jax.random.PRNGKey(7)
    st, xs, ys = _seed_state(key, 6, d, n_max=16,
                             implementation=implementation)
    extra_x = jax.random.uniform(jax.random.fold_in(key, 1), (3, d),
                                 minval=-2.0, maxval=2.0)
    extra_y = jnp.cos(extra_x.sum(-1))
    for i in range(3):
        st = append(st, matern52, extra_x[i], extra_y[i],
                    implementation=implementation)
    xq = jax.random.uniform(jax.random.fold_in(key, 2), (5, d),
                            minval=-2.0, maxval=2.0)
    m1, v1 = posterior(st, matern52, xq, implementation=implementation)
    all_x = jnp.concatenate([xs, extra_x])
    all_y = jnp.concatenate([ys, extra_y])
    m2, v2 = dense_posterior(all_x, all_y, xq, matern52, st.params)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m2), rtol=2e-3,
                               atol=5e-4)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-2,
                               atol=5e-4)


@pytest.mark.parametrize("implementation", IMPLEMENTATIONS)
def test_padded_trsv_per_implementation(implementation):
    key = jax.random.PRNGKey(3)
    n, n_max = 9, 16
    a = jax.random.normal(key, (n, n))
    k = a @ a.T / n + 2 * jnp.eye(n)
    l = jnp.linalg.cholesky(k)
    l_pad = chol.identity_pad_factor(l, n_max)
    b = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    b_pad = jnp.zeros(n_max).at[:n].set(b)
    for trans in (False, True):
        got = chol.padded_trsv(l_pad, b_pad, trans=trans,
                               implementation=implementation)
        want = jax.scipy.linalg.solve_triangular(
            l, b, lower=True, trans=1 if trans else 0)
        np.testing.assert_allclose(np.asarray(got[:n]), np.asarray(want),
                                   rtol=5e-4, atol=5e-4)
        assert np.allclose(np.asarray(got[n:]), 0.0)


def test_masked_gram_matches_gram_plus_identity():
    key = jax.random.PRNGKey(11)
    n, n_max, d = 7, 12, 4
    x = jax.random.uniform(key, (n, d))
    params = KernelParams(sigma2=1.3, rho=0.6, noise2=1e-4)
    x_buf = jnp.zeros((n_max, d)).at[:n].set(x)
    got = ops.masked_gram(x_buf, jnp.asarray(n, jnp.int32), matern52, params)
    want = chol.pad_gram(gram(matern52, x, params), n_max)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("implementation", IMPLEMENTATIONS)
def test_acquisition_gradient_parity(implementation):
    """The EI ascent differentiates through the substrate: grad must exist
    for every implementation (Pallas via the custom VJPs) and agree."""
    from repro.core.acquisition import AcqConfig, _acq_value, _f_best
    key = jax.random.PRNGKey(9)
    st, _, _ = _seed_state(key, 6, 2, n_max=8, implementation=implementation)
    x = jnp.asarray([0.3, -0.4])
    cfg = AcqConfig()
    g = jax.grad(lambda q: _acq_value(st, matern52, q, _f_best(st), cfg,
                                      implementation))(x)
    g_ref = jax.grad(lambda q: _acq_value(st, matern52, q, _f_best(st), cfg,
                                          "xla"))(x)
    assert np.all(np.isfinite(np.asarray(g)))
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref),
                               rtol=2e-3, atol=2e-4)


def test_matern_gram_pallas_grad_matches_ref():
    """Analytic Matérn VJP vs autodiff of the jnp oracle, all four inputs."""
    from repro.kernels import ref
    key = jax.random.PRNGKey(4)
    x = jax.random.uniform(key, (128, 128), minval=-2.0, maxval=2.0)
    y = jax.random.uniform(jax.random.fold_in(key, 1), (128, 128),
                           minval=-2.0, maxval=2.0)
    s2, rho = jnp.asarray(1.3), jnp.asarray(0.7)

    def loss_pallas(x, y, s2, rho):
        return jnp.sum(jnp.sin(ops.matern52_gram(
            x, y, s2, rho, implementation="pallas")))

    def loss_ref(x, y, s2, rho):
        return jnp.sum(jnp.sin(ref.matern52_gram_ref(x, y, s2, rho)))

    got = jax.grad(loss_pallas, argnums=(0, 1, 2, 3))(x, y, s2, rho)
    want = jax.grad(loss_ref, argnums=(0, 1, 2, 3))(x, y, s2, rho)
    for a, b in zip(got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("trans", [False, True])
def test_trsv_pallas_grad_matches_ref(trans):
    from repro.kernels import ref
    key = jax.random.PRNGKey(6)
    n = 128
    a = jax.random.normal(key, (n, n))
    l = jnp.linalg.cholesky(a @ a.T / n + 2 * jnp.eye(n))
    b = jax.random.normal(jax.random.fold_in(key, 1), (n,))

    def loss(solver):
        return lambda l, b: jnp.sum(
            jnp.tanh(solver(l, b, trans=trans)))

    got = jax.grad(loss(lambda l, b, trans: ops.trsv(
        l, b, trans=trans, implementation="pallas")), argnums=(0, 1))(l, b)
    want = jax.grad(loss(ref.trsv_ref), argnums=(0, 1))(l, b)
    for a_, b_ in zip(got, want):
        np.testing.assert_allclose(np.asarray(a_), np.asarray(b_),
                                   rtol=5e-3, atol=5e-3)


# ---------------------------------------------------------------------------
# Fused append == unfused append, deferred batch == sequential
# ---------------------------------------------------------------------------
def test_fused_append_matches_refactor_alpha():
    key = jax.random.PRNGKey(5)
    st, _, _ = _seed_state(key, 5, 3, n_max=16)
    x_new = jax.random.uniform(jax.random.fold_in(key, 1), (3,))
    y_new = jnp.asarray(0.7)
    lazy = append(st, matern52, x_new, y_new)
    full = refactor(lazy, matern52)
    np.testing.assert_allclose(np.asarray(lazy.l_buf),
                               np.asarray(full.l_buf), rtol=2e-3, atol=2e-4)
    np.testing.assert_allclose(np.asarray(lazy.alpha),
                               np.asarray(full.alpha), rtol=2e-2, atol=2e-3)


@pytest.mark.parametrize("implementation", ["xla", "ref"])
def test_deferred_alpha_batch_matches_sequential(implementation):
    key = jax.random.PRNGKey(42)
    st, _, _ = _seed_state(key, 5, 3, n_max=32,
                           implementation=implementation)
    xs = jax.random.uniform(jax.random.fold_in(key, 1), (4, 3))
    ys = jnp.tanh(xs.sum(-1))
    seq = st
    for i in range(4):
        seq = append(seq, matern52, xs[i], ys[i],
                     implementation=implementation)
    bat = append_batch(st, matern52, xs, ys, implementation=implementation)
    assert int(bat.n) == int(seq.n) == 9
    np.testing.assert_allclose(np.asarray(bat.l_buf), np.asarray(seq.l_buf),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(bat.alpha), np.asarray(seq.alpha),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(bat.x_buf), np.asarray(seq.x_buf))


# ---------------------------------------------------------------------------
# Batched study axis (DESIGN.md §7): stacked states match independent runs
# ---------------------------------------------------------------------------
def _hetero_stack(implementation, n0s=(3, 5, 7), d=3, n_max=16):
    """Stacked state over studies with heterogeneous active counts."""
    singles = [
        _seed_state(jax.random.PRNGKey(20 + i), n0, d, n_max,
                    implementation=implementation)[0]
        for i, n0 in enumerate(n0s)]
    return gp_mod.stack_states(singles), singles


@pytest.mark.parametrize("implementation", IMPLEMENTATIONS)
def test_batched_append_matches_independent(implementation):
    """One vmapped append over S studies (per-study n) == S single appends."""
    stacked, singles = _hetero_stack(implementation)
    key = jax.random.PRNGKey(77)
    xs = jax.random.uniform(key, (len(singles), 3), minval=-2.0, maxval=2.0)
    ys = jnp.tanh(xs.sum(-1))
    got = append(stacked, matern52, xs, ys, implementation=implementation)
    assert got.is_batched and got.n_studies == len(singles)
    for i, st in enumerate(singles):
        want = append(st, matern52, xs[i], ys[i],
                      implementation=implementation)
        view = gp_mod.unstack_state(got, i)
        assert int(view.n) == int(want.n)
        np.testing.assert_allclose(np.asarray(view.l_buf),
                                   np.asarray(want.l_buf), rtol=1e-5,
                                   atol=1e-6)
        np.testing.assert_allclose(np.asarray(view.alpha),
                                   np.asarray(want.alpha), rtol=1e-4,
                                   atol=1e-5)


@pytest.mark.parametrize("implementation", IMPLEMENTATIONS)
def test_batched_posterior_and_refactor_match_independent(implementation):
    stacked, singles = _hetero_stack(implementation)
    key = jax.random.PRNGKey(78)
    xq = jax.random.uniform(key, (len(singles), 4, 3), minval=-2.0,
                            maxval=2.0)
    m, v = posterior(stacked, matern52, xq, implementation=implementation)
    assert m.shape == v.shape == (len(singles), 4)
    ref = refactor(stacked, matern52, implementation=implementation)
    for i, st in enumerate(singles):
        mi, vi = posterior(st, matern52, xq[i],
                           implementation=implementation)
        np.testing.assert_allclose(np.asarray(m[i]), np.asarray(mi),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(v[i]), np.asarray(vi),
                                   rtol=1e-3, atol=1e-5)
        ri = refactor(st, matern52, implementation=implementation)
        np.testing.assert_allclose(
            np.asarray(gp_mod.unstack_state(ref, i).l_buf),
            np.asarray(ri.l_buf), rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("implementation", IMPLEMENTATIONS)
def test_batched_suggest_matches_independent(implementation):
    """Vmapped acquisition over the stack == per-study optimization under
    the same keys (the StudyPool suggest_all contract)."""
    from repro.core.acquisition import AcqConfig, optimize_acquisition
    stacked, singles = _hetero_stack(implementation)
    cfg = AcqConfig(restarts=8, ascent_steps=5)
    lo, hi = jnp.zeros(3), jnp.ones(3)
    keys = jax.random.split(jax.random.PRNGKey(5), len(singles))
    pts, vals = optimize_acquisition(stacked, matern52, lo, hi, keys, cfg,
                                     2, implementation=implementation)
    assert pts.shape == (len(singles), 2, 3)
    for i, st in enumerate(singles):
        pi, vi = optimize_acquisition(st, matern52, lo, hi, keys[i], cfg,
                                      2, implementation=implementation)
        np.testing.assert_allclose(np.asarray(pts[i]), np.asarray(pi),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(vals[i]), np.asarray(vi),
                                   rtol=1e-3, atol=1e-5)


@pytest.mark.parametrize("implementation", IMPLEMENTATIONS)
def test_batched_append_batch_matches_independent(implementation):
    stacked, singles = _hetero_stack(implementation, n0s=(2, 4), n_max=32)
    key = jax.random.PRNGKey(79)
    xs = jax.random.uniform(key, (2, 3, 3), minval=-2.0, maxval=2.0)
    ys = jnp.sin(xs.sum(-1))
    got = append_batch(stacked, matern52, xs, ys,
                       implementation=implementation)
    for i, st in enumerate(singles):
        want = append_batch(st, matern52, xs[i], ys[i],
                            implementation=implementation)
        np.testing.assert_allclose(
            np.asarray(gp_mod.unstack_state(got, i).alpha),
            np.asarray(want.alpha), rtol=1e-4, atol=1e-5)
        assert int(got.n[i]) == int(want.n)


# ---------------------------------------------------------------------------
# Conditioning telemetry (the d^2 clamp counter)
# ---------------------------------------------------------------------------
def test_clamp_counter_increments_on_degenerate_append():
    key = jax.random.PRNGKey(1)
    st, xs, _ = _seed_state(key, 4, 2, n_max=8, noise2=1e-12)
    assert int(st.clamp_count) == 0
    healthy = append(st, matern52, jnp.asarray([0.5, -0.5]), jnp.asarray(0.1))
    assert int(healthy.clamp_count) == 0
    # Duplicate an existing point with ~zero noise: d^2 -> 0 under float32.
    degenerate = append(st, matern52, xs[0], jnp.asarray(0.1))
    assert int(degenerate.clamp_count) == 1


def test_scheduler_surfaces_clamp_count_in_ledger():
    cfg = SchedulerConfig(n_max=8, seed=0, noise2=1e-12)
    sched = TrialScheduler(RESNET_SPACE, cfg)
    unit = np.full((RESNET_SPACE.dim,), 0.5, np.float32)
    t1 = sched._make_trial(unit)
    sched.absorb(t1, 0.3)
    t2 = sched._make_trial(unit)   # exact duplicate: degenerate append
    sched.absorb(t2, 0.3)
    assert t1.clamp_count == 0
    assert t2.clamp_count == 1
    assert sched.history()[-1]["clamp_count"] == 1


def test_bo_history_surfaces_clamp_counts():
    from repro.core import levy_bounds, neg_levy, run_bo
    obj = lambda x: np.asarray(neg_levy(jnp.asarray(x)))
    lo, hi = levy_bounds(2)
    _, hist = run_bo(obj, lo, hi, iterations=3, dim=2, n_max=16, n_seed=2,
                     seed=0)
    assert len(hist.clamp_counts) == 3
    assert all(c == 0 for c in hist.clamp_counts)  # healthy run: no clamps


# ---------------------------------------------------------------------------
# Capacity guard
# ---------------------------------------------------------------------------
def test_ensure_capacity_raises_with_clear_message():
    ensure_capacity(3, 4, 1)          # fits exactly: ok
    with pytest.raises(GPCapacityError, match="n_max=4"):
        ensure_capacity(4, 4, 1)
    with pytest.raises(GPCapacityError, match="2 incoming"):
        ensure_capacity(3, 4, 2)


def test_bayesopt_step_raises_at_capacity():
    from repro.core import levy_bounds, neg_levy
    obj = lambda x: np.asarray(neg_levy(jnp.asarray(x)))
    lo, hi = levy_bounds(2)
    cfg = BOConfig(dim=2, n_max=3, seed=0)
    bo = BayesOpt(cfg, lo, hi)
    key = jax.random.PRNGKey(0)
    x0 = np.asarray(lo) + (np.asarray(hi) - np.asarray(lo)) * \
        np.asarray(jax.random.uniform(key, (3, 2)))
    y0 = obj(x0)
    state = bo.init(jnp.asarray(x0), jnp.asarray(y0, jnp.float32))
    from repro.core.bayesopt import BOHistory
    with pytest.raises(GPCapacityError):
        bo.step(state, key, obj, BOHistory())


def test_bayesopt_init_raises_when_seeds_exceed_capacity():
    from repro.core import levy_bounds
    lo, hi = levy_bounds(2)
    bo = BayesOpt(BOConfig(dim=2, n_max=2, seed=0), lo, hi)
    x0 = jnp.zeros((3, 2))
    with pytest.raises(GPCapacityError):
        bo.init(x0, jnp.zeros((3,)))


def test_scheduler_absorb_raises_at_capacity():
    cfg = SchedulerConfig(n_max=2, seed=0)
    sched = TrialScheduler(RESNET_SPACE, cfg)
    for i in range(2):
        tr = sched._make_trial(
            np.full((RESNET_SPACE.dim,), 0.2 + 0.3 * i, np.float32))
        sched.absorb(tr, float(i))
    tr = sched._make_trial(np.full((RESNET_SPACE.dim,), 0.9, np.float32))
    with pytest.raises(GPCapacityError):
        sched.absorb(tr, 2.0)
    # the failed absorb must not have corrupted the factor
    assert int(sched.state.n) == 2


# ---------------------------------------------------------------------------
# Dispatch-knob validation
# ---------------------------------------------------------------------------
def test_invalid_implementation_rejected():
    with pytest.raises(ValueError, match="implementation"):
        GPConfig(implementation="cuda")
    with pytest.raises(ValueError, match="implementation"):
        gp_mod.GPConfig(n_max=8, dim=2, implementation="")


def test_config_threading_reaches_gp_state():
    cfg = BOConfig(dim=2, n_max=8, implementation="ref")
    from repro.core import levy_bounds
    lo, hi = levy_bounds(2)
    bo = BayesOpt(cfg, lo, hi)
    assert bo.gp_cfg.implementation == "ref"
    scfg = SchedulerConfig(n_max=8, implementation="xla")
    sched = TrialScheduler(RESNET_SPACE, scfg)
    assert sched.cfg.implementation == "xla"
