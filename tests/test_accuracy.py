"""Paper-fidelity accuracy regression: lazy GP vs the exact-GP baseline.

The paper's core claim (abstract, Sec. 4) is that the lazy GP reaches the
baseline's optimization accuracy — "outperforming the previous approach
regarding optimization accuracy" — while decoupling the O(n^3)
factorization from the iteration loop.  This suite pins that claim as a
tier-1 regression on the paper's own synthetic benchmark (Levy, Sec. 4.1):

  * `mode="lazy"`  — frozen kernel params, O(n^2) incremental appends
    (the contribution);
  * `mode="naive"` — per-iteration full refactorization with kernel
    hyper-parameter refit (the "previous approach" baseline).

Both run the identical suggestion machinery with pinned seeds, so the runs
are deterministic (plain pytest asserts, no property machinery — they pass
identically under real hypothesis or the conftest fallback).  Regret is
measured against the known optimum f* = 0 at x* = 1 (maximization of the
negative Levy function).

Budgets are tuned to keep the whole file in single-digit seconds of
tier-1 time while separating the two modes' behavior.
"""
import numpy as np
import pytest

from repro.core import levy_bounds, neg_levy, run_bo
from repro.core.acquisition import AcqConfig

DIM = 4
SEEDS = (0, 1, 2)
ITERATIONS = 30
N_SEED = 8                 # random seed trials before BO rounds
OPTIMUM = 0.0              # max of -levy at the all-ones vector
ACQ = AcqConfig(restarts=24, ascent_steps=12)


def _objective(x: np.ndarray) -> np.ndarray:
    return np.asarray(neg_levy(x))


def _regret(mode: str, seed: int, lag: int = 0) -> float:
    lo, hi = levy_bounds(DIM)
    _, hist = run_bo(_objective, lo, hi, iterations=ITERATIONS, dim=DIM,
                     mode=mode, lag=lag, n_max=ITERATIONS + N_SEED + 2,
                     n_seed=N_SEED, seed=seed, acq=ACQ)
    return OPTIMUM - hist.best_y[-1]


@pytest.fixture(scope="module")
def regrets():
    """One (mode x seed) sweep shared by every assertion below."""
    return {mode: [_regret(mode, s) for s in SEEDS]
            for mode in ("lazy", "naive")}


def test_lazy_matches_exact_gp_accuracy_per_seed(regrets):
    """The paper's accuracy claim, per pinned seed: the lazy GP's best-value
    regret at a fixed step budget is no worse than the exact baseline's,
    up to a float/trajectory tolerance."""
    for lz, nv in zip(regrets["lazy"], regrets["naive"]):
        assert lz <= nv + 0.75, (regrets["lazy"], regrets["naive"])


def test_lazy_matches_exact_gp_accuracy_mean(regrets):
    """Aggregate form (tighter): mean regret over the pinned seeds."""
    mean_lazy = float(np.mean(regrets["lazy"]))
    mean_naive = float(np.mean(regrets["naive"]))
    assert mean_lazy <= mean_naive + 0.25, (mean_lazy, mean_naive)


def test_lazy_absolute_quality(regrets):
    """The lazy GP actually optimizes (regret far below a random-search
    floor — random uniform on [-10,10]^4 leaves regret ~15+ at this
    budget), so the comparative test above can't pass vacuously."""
    assert float(np.mean(regrets["lazy"])) < 3.0, regrets["lazy"]
    assert min(regrets["lazy"]) < 1.5, regrets["lazy"]


def test_lagged_refit_tracks_fully_lazy():
    """Lag-l refits (the paper's middle ground) stay within the same
    accuracy envelope as the fully lazy run on a pinned seed."""
    lazy = _regret("lazy", seed=0)
    lagged = _regret("lazy", seed=0, lag=10)
    assert lagged <= lazy + 1.5, (lagged, lazy)


def test_runs_are_deterministic():
    """Pinned seeds => bitwise-identical best values (the regression is
    meaningful because reruns cannot drift)."""
    assert _regret("lazy", seed=0) == _regret("lazy", seed=0)
