"""Distribution-layer tests.

Multi-device tests run in SUBPROCESSES with
XLA_FLAGS=--xla_force_host_platform_device_count=8 so the main pytest
process keeps seeing 1 device (per the assignment: never set the flag
globally).
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, devices: int = 8, timeout: int = 420) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src") + ":" + REPO
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env, cwd=REPO)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


def test_sharding_rules_tables():
    """Pure-python rule logic (no devices needed)."""
    import jax

    from repro.launch.sharding import logical_to_spec, rules_for
    from jax.sharding import PartitionSpec as P

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 4, "model": 2}

    rules = rules_for("granite-3-2b", FakeMesh(), seq_parallel=True)
    assert rules["batch"] == "data"
    assert rules["seq"] == "model"
    spec = logical_to_spec(("batch", "seq", "embed"), rules)
    # embed->data already used by batch: deduped to None
    assert spec == P("data", "model", None)
    # gemma3 override removes head sharding
    rules_g = rules_for("gemma3-4b", FakeMesh())
    assert rules_g["heads"] is None


def test_sharded_train_step_matches_single_device():
    """Same tiny model, same batch: 2x4 mesh result == 1-device result."""
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.data import DataConfig, synth_tokens
        from repro.launch.mesh import make_mesh
        from repro.launch import sharding as sh
        from repro.optim import OptimizerConfig
        from repro.training import init_train_state, make_train_step

        cfg = get_config("tiny-lm", reduced=True)
        ocfg = OptimizerConfig(lr=1e-3, warmup_steps=0, total_steps=10)
        dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                          global_batch=8)
        batch = synth_tokens(dcfg, 0)
        params, opt, _ = init_train_state(cfg, ocfg, jax.random.PRNGKey(0))
        raw = make_train_step(cfg, ocfg)

        # single device
        p1, _, m1 = jax.jit(raw)(params, opt, batch)

        # 2x4 mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        rules = sh.rules_for("tiny-lm", mesh)
        def step(p, o, b):
            with sh.use_rules(mesh, rules):
                return raw(p, o, b)
        with mesh:
            p2, _, m2 = jax.jit(step)(params, opt, batch)
        d = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                      - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
        print("LOSS", float(m1["loss"]), float(m2["loss"]), "PDIFF", d)
        assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-2
        assert d < 2e-2
        print("OK")
        """)
    assert "OK" in out


def test_dryrun_cell_on_small_mesh():
    """The dry-run machinery end-to-end on an 8-device 2x4 mesh."""
    out = run_sub("""
        import repro.launch.dryrun as dr
        import repro.launch.mesh as mesh_mod
        # shrink the production mesh to the test host
        mesh_mod.SINGLE_POD = (2, 4)
        mesh_mod.MULTI_POD = (2, 2, 2)
        import json
        for multi in (False, True):
            r = dr.run_cell("granite-3-2b", "train_4k", multi,
                            seq_parallel=True,
                            cfg_overrides={"num_layers": 2, "d_model": 256,
                                           "num_heads": 8, "num_kv_heads": 4,
                                           "d_ff": 512, "vocab_size": 512})
            assert r["status"] == "ok", r.get("error")
            assert r["memory"]["peak_per_device_bytes"] > 0
            if not multi:
                assert r["cost"]["flops_per_device"] > 0
                assert r["collectives"]["total_link_bytes"] > 0
        print("OK")
        """, devices=8)
    assert "OK" in out


def test_dryrun_decode_cell_on_small_mesh():
    out = run_sub("""
        import repro.launch.dryrun as dr
        import repro.launch.mesh as mesh_mod
        mesh_mod.SINGLE_POD = (2, 4)
        r = dr.run_cell("granite-3-2b", "decode_32k", False,
                        cfg_overrides={"num_layers": 2, "d_model": 256,
                                       "num_heads": 8, "num_kv_heads": 4,
                                       "d_ff": 512, "vocab_size": 512})
        assert r["status"] == "ok", r.get("error")
        print("OK")
        """, devices=8)
    assert "OK" in out


def test_collective_census_parses_shapes():
    import os
    saved = os.environ.get("XLA_FLAGS")
    from repro.launch.dryrun import collective_census
    if saved is None:
        os.environ.pop("XLA_FLAGS", None)
    else:
        os.environ["XLA_FLAGS"] = saved
    hlo = """
  %ag = bf16[16,512]{1,0} all-gather-start(%p0), replica_groups=[2,8]<=[16]
  %ag2 = bf16[16,512]{1,0} all-gather-done(%ag)
  %ar = f32[128,128]{1,0} all-reduce(%x), replica_groups={{0,1,2,3}}
  %cp = f32[4,4]{1,0} collective-permute(%y), source_target_pairs={{0,1}}
"""
    c = collective_census(hlo, n_devices=16)
    assert c["all-gather"]["count"] == 1          # -done not double counted
    assert c["all-gather"]["operand_bytes"] == 16 * 512 * 2 // 8
    assert c["all-reduce"]["count"] == 1
    assert c["all-reduce"]["operand_bytes"] == 128 * 128 * 4
    assert c["collective-permute"]["link_bytes"] == 4 * 4 * 4
    assert c["total_link_bytes"] > 0


def test_production_mesh_requires_devices():
    """On the 1-device main process, the production mesh must refuse."""
    import pytest as _pytest

    from repro.launch.mesh import make_production_mesh
    with _pytest.raises(RuntimeError, match="XLA_FLAGS"):
        make_production_mesh()


def test_roofline_analysis_math():
    from benchmarks.roofline import analyse
    rec = {
        "status": "ok", "arch": "a", "shape": "train_4k", "mesh": "16x16",
        "n_devices": 256,
        "cost": {"flops_per_device": 197e12,
                 "bytes_accessed_per_device": 819e9,
                 "transcendentals": 0},
        "collectives": {"total_link_bytes": 100e9},
        "model": {"n_params": 1e9, "n_active_params": 1e9},
        "memory": {"peak_per_device_bytes": 1e9},
    }
    row = analyse(rec)
    assert row["t_compute_s"] == pytest.approx(1.0)
    assert row["t_memory_s"] == pytest.approx(1.0)
    assert row["t_collective_s"] == pytest.approx(2.0)
    assert row["dominant"] == "collective"
