"""HPO orchestrator tests: suggestion flow, async absorption, fault
tolerance, elastic width, GP-state checkpoint/restore."""
import tempfile
import threading
import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np
import pytest

from repro.hpo.scheduler import SchedulerConfig, TrialScheduler
from repro.hpo.space import LM_SPACE, RESNET_SPACE


def quad_objective(hp: dict) -> float:
    """Smooth 3-D objective with optimum at known hparams (maximize)."""
    x = np.log10(hp["lr"]) + 2.5          # optimum lr = 10^-2.5
    y = np.log10(hp["weight_decay"]) + 4.5
    z = hp["momentum"] - 0.9
    return float(-(x ** 2 + 0.5 * y ** 2 + 2 * z ** 2))


def test_space_roundtrip():
    rng = np.random.default_rng(0)
    u = RESNET_SPACE.sample(rng, 5)
    for row in u:
        hp = RESNET_SPACE.to_hparams(row)
        back = RESNET_SPACE.to_unit(hp)
        np.testing.assert_allclose(back, row, atol=1e-5)
    hp = RESNET_SPACE.to_hparams(np.zeros(3))
    assert hp["lr"] == pytest.approx(1e-4)
    assert hp["momentum"] == pytest.approx(0.0)


def test_sequential_scheduler_improves():
    sched = TrialScheduler(RESNET_SPACE, SchedulerConfig(n_max=64, seed=0))
    best = sched.run(quad_objective, budget=25, n_seed=4)
    assert best is not None
    seeds = [t.value for t in sched.trials[:4] if t.value is not None]
    assert best.value >= max(seeds)
    assert best.value > -1.5


def test_parallel_scheduler_async_absorption():
    """Stragglers must not block absorption of faster trials."""
    call_log = []
    lock = threading.Lock()

    def slow_objective(hp):
        # every 4th call is a straggler
        with lock:
            idx = len(call_log)
            call_log.append(idx)
        time.sleep(0.8 if idx % 4 == 0 else 0.02)
        return quad_objective(hp)

    sched = TrialScheduler(RESNET_SPACE,
                           SchedulerConfig(n_max=64, parallel=4, seed=1))
    best = sched.run(slow_objective, budget=12, n_seed=4)
    assert best is not None
    assert int(sched.state.n) == 12
    # async proof: some trial that STARTED after a straggler FINISHED before
    # it (i.e. absorption happened out of start order).
    done = [t for t in sched.trials if t.status == "done"]
    overtook = any(
        b.started > a.started and b.finished < a.finished
        for a in done for b in done if a is not b)
    assert overtook, "no out-of-order absorption observed"


def test_failed_trial_retries_and_gp_consistent():
    calls = {"n": 0}

    def flaky(hp):
        calls["n"] += 1
        if calls["n"] % 3 == 0:
            raise RuntimeError("node lost")
        return quad_objective(hp)

    sched = TrialScheduler(RESNET_SPACE,
                           SchedulerConfig(n_max=64, seed=2, max_retries=2))
    best = sched.run(flaky, budget=10, n_seed=2)
    assert best is not None
    n_done = sum(t.status == "done" for t in sched.trials)
    n_fail = sum(t.status == "failed" for t in sched.trials)
    assert n_done == 10 and n_fail >= 1
    # GP absorbed exactly the done trials
    assert int(sched.state.n) == n_done


def test_failure_penalty_mode_appends_pseudo_observation():
    def always_fails(hp):
        raise RuntimeError("boom")

    sched = TrialScheduler(
        RESNET_SPACE, SchedulerConfig(n_max=32, seed=3, max_retries=0,
                                      failure_penalty=-100.0))
    tr = sched.seed_trials(1)[0]
    sched._run_one(always_fails, tr)
    assert tr.status == "failed"
    assert int(sched.state.n) == 1  # penalty observation recorded


def test_elastic_width():
    widths = iter([4, 2, 1, 1, 1, 1, 1, 1, 1, 1, 1, 1])
    seen = []

    def width():
        w = next(widths, 1)
        seen.append(w)
        return w

    sched = TrialScheduler(RESNET_SPACE,
                           SchedulerConfig(n_max=64, parallel=4, seed=4))
    with ThreadPoolExecutor(4) as pool:
        best = sched.run(lambda hp: quad_objective(hp), budget=10, n_seed=2,
                         executor=pool, parallel=width)
    assert best is not None and len(seen) >= 1


def test_gp_state_checkpoint_restore():
    with tempfile.TemporaryDirectory() as d:
        cfg = SchedulerConfig(n_max=32, seed=5, ckpt_dir=d)
        sched = TrialScheduler(RESNET_SPACE, cfg)
        sched.run(quad_objective, budget=6, n_seed=2)
        n_before = int(sched.state.n)
        alpha_before = np.asarray(sched.state.alpha)

        sched2 = TrialScheduler(RESNET_SPACE, cfg)
        assert sched2.restore()
        assert int(sched2.state.n) == n_before
        np.testing.assert_allclose(np.asarray(sched2.state.alpha),
                                   alpha_before, rtol=1e-6)
        assert len(sched2.trials) == len(sched.trials)
        # restarted controller can continue suggesting + absorbing
        best = sched2.run(quad_objective, budget=n_before + 2, n_seed=0)
        assert best is not None


def test_restore_resume_identical_state_no_duplicate_seeds():
    """A restored scheduler resumes the exact posterior + ledger and must
    NOT re-run its random seed trials (they are already in the GP)."""
    with tempfile.TemporaryDirectory() as d:
        cfg = SchedulerConfig(n_max=32, seed=7, ckpt_dir=d)
        s1 = TrialScheduler(RESNET_SPACE, cfg)
        s1.run(quad_objective, budget=5, n_seed=3)
        n_before = int(s1.state.n)
        ledger_before = [(t.trial_id, t.status, t.value) for t in s1.trials]

        s2 = TrialScheduler(RESNET_SPACE, cfg)
        assert s2.restore()
        # identical posterior
        assert int(s2.state.n) == n_before
        np.testing.assert_allclose(np.asarray(s2.state.alpha),
                                   np.asarray(s1.state.alpha), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(s2.state.l_buf),
                                   np.asarray(s1.state.l_buf), rtol=1e-6)
        # identical trial ledger
        assert [(t.trial_id, t.status, t.value)
                for t in s2.trials] == ledger_before

        # resume with the same n_seed: the resumed run must go straight to
        # EI suggestions, not absorb the seed batch a second time (budget
        # counts absorptions per run() call, same as the parallel path)
        s2.run(quad_objective, budget=2, n_seed=3)
        assert int(s2.state.n) == n_before + 2
        seed_units = {tuple(t.unit.tolist()) for t in s1.trials[:3]}
        new_trials = s2.trials[len(ledger_before):]
        assert len(new_trials) == 2
        assert all(tuple(t.unit.tolist()) not in seed_units
                   for t in new_trials), "seed trials were re-run on resume"


def test_restore_resume_parallel_path_no_duplicate_seeds():
    """Same contract through the thread-pool (parallel) run path."""
    with tempfile.TemporaryDirectory() as d:
        cfg = SchedulerConfig(n_max=32, seed=8, parallel=2, ckpt_dir=d)
        s1 = TrialScheduler(RESNET_SPACE, cfg)
        s1.run(quad_objective, budget=4, n_seed=2)

        s2 = TrialScheduler(RESNET_SPACE, cfg)
        assert s2.restore()
        n_restored = int(s2.state.n)
        ledger_len = len(s2.trials)
        s2.run(quad_objective, budget=2, n_seed=2)
        # parallel path counts absorptions per run: exactly 2 more, and the
        # new trials are EI suggestions, not a re-seeded random batch
        assert int(s2.state.n) == n_restored + 2
        seed_units = {tuple(t.unit.tolist()) for t in s1.trials[:2]}
        new_trials = s2.trials[ledger_len:]
        assert all(tuple(t.unit.tolist()) not in seed_units
                   for t in new_trials), "seed trials were re-run on resume"


def test_suggestions_within_bounds_and_distinct():
    sched = TrialScheduler(LM_SPACE, SchedulerConfig(n_max=64, seed=6))
    sched.run(quad_lm, budget=5, n_seed=3)
    trs = sched.suggest(4)
    units = np.stack([t.unit for t in trs])
    assert units.min() >= 0.0 and units.max() <= 1.0
    d01 = np.linalg.norm(units[0] - units[1])
    assert d01 > 1e-4


def quad_lm(hp):
    return -((np.log10(hp["lr"]) + 3) ** 2 + hp["warmup_frac"])
