"""Saturation escalation tier (DESIGN.md §15): when a study's lazy-GP slot
fills to n_max, the gateway promotes it to the neural-basis tier (MLP
feature map + exact Bayesian linear head) instead of rejecting asks
forever.  This suite pins the tier's contracts:

  * the capacity error taxonomy — terminal `StudySaturatedError` vs
    retryable `BackpressureError`, preserved across the transport wire;
  * clean terminal rejection at the ask(q) saturation boundary (no
    partially fantasized state, bitwise no-leak vs a twin);
  * serving THROUGH saturation: a study driven past 2x n_max keeps
    answering asks and its best value never regresses below the
    truncated-at-n_max lazy-GP baseline (Levy-4d);
  * promotion -> eviction -> restore -> q-ask bitwise stream parity,
    and pool checkpoint/restore of escalated state (NB ledger + cost
    rows travel exactly);
  * the cost axis — tell(cost=) threads gateway -> pool -> engine
    ledger and rides the trial wire form; EI-per-unit-cost acquisition
    (FABOLAS-style) steers the ascent away from expensive regions;
  * saturation observability merged through federation summaries.

Everything is seeded and deterministic; comparisons are bitwise where
the contract is bitwise (rollback, eviction, restore).
"""
import asyncio
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _traffic import assert_slots_equal
from _traffic import foreign_trial as _foreign_trial
from _traffic import make_cfg as _cfg
from _traffic import objective as obj
from repro.core import (BackpressureError, GPCapacityError, GPConfig,
                        NeuralConfig, StudySaturatedError, init_state,
                        levy_bounds, matern52, neg_levy, refactor)
from repro.core import neural_basis as nb_mod
from repro.core.acquisition import AcqConfig, optimize_acquisition
from repro.hpo import (FederatedGateway, FederationConfig, GatewayConfig,
                       StudyGateway, StudyPool)
from repro.hpo import transport as tx
from repro.hpo.pool import Trial
from repro.hpo.space import RESNET_SPACE, Dim, SearchSpace

# Small neural tier for test budgets: tiny MLP, short refits, small
# initial ledger capacity (growth doubling still exercised).
NB = NeuralConfig(hidden=16, features=8, refit_every=8, refit_steps=40,
                  cap0=16)


# ---------------------------------------------------------------------------
# Error taxonomy: terminal saturation vs retryable backpressure
# ---------------------------------------------------------------------------
def test_capacity_error_taxonomy():
    """Both split errors ARE GPCapacityError (existing handlers keep
    working); clients distinguish them by type / `retryable`, not by
    message parsing."""
    assert issubclass(StudySaturatedError, GPCapacityError)
    assert issubclass(BackpressureError, GPCapacityError)
    assert StudySaturatedError("full").retryable is False
    assert BackpressureError("busy").retryable is True
    assert GPCapacityError("generic").retryable is False


def test_taxonomy_survives_the_wire():
    """The transport re-raises the exact subclass client-side: a remote
    client can retry backpressure and terminally stop on saturation."""
    for name, cls, retryable in (
            ("StudySaturatedError", StudySaturatedError, False),
            ("BackpressureError", BackpressureError, True),
            ("GPCapacityError", GPCapacityError, False)):
        err = tx._decode_error({"etype": name, "error": "m"})
        assert type(err) is cls
        assert isinstance(err, GPCapacityError)
        assert err.retryable is retryable


def test_admission_raises_the_right_type():
    """Gateway admission: inflight-cap overrun is retryable backpressure;
    capacity exhaustion (escalation off) is terminal saturation."""
    async def main(d):
        gw = StudyGateway(RESNET_SPACE, _cfg(d, n_max=4),
                          GatewayConfig(slots=1, max_inflight=2,
                                        escalate=False))
        sid = gw.create_study()
        batch = await gw.ask(sid, q=2)
        with pytest.raises(BackpressureError, match="in flight"):
            await gw.ask(sid)            # 2 inflight + 1 > max_inflight=2
        for tr in batch:
            gw.tell(sid, tr, obj(sid, tr.unit))
        await gw.drain()
        for _ in range(2):
            tr = await gw.ask(sid)
            gw.tell(sid, tr, obj(sid, tr.unit))
        await gw.drain()
        with pytest.raises(StudySaturatedError, match="n_max"):
            await gw.ask(sid)            # 4 committed == n_max, no tier
        await gw.aclose()
    with tempfile.TemporaryDirectory() as d:
        asyncio.run(main(d))


# ---------------------------------------------------------------------------
# ask(q) at the saturation boundary: clean rejection or clean escalation
# ---------------------------------------------------------------------------
def test_ask_q_boundary_rejects_without_partial_fantasies():
    """n = n_max - k committed with k < q: terminal rejection happens at
    admission — BEFORE any fantasy row is appended.  Bitwise no-leak: the
    rejected gateway's slot is identical to a twin that never asked."""
    async def main(d1, d2):
        def mk(d):
            gw = StudyGateway(RESNET_SPACE, _cfg(d, n_max=8),
                              GatewayConfig(slots=1, max_inflight=8,
                                            escalate=False))
            return gw, gw.create_study()
        (ga, sa), (gb, sb) = mk(d1), mk(d2)
        rng = np.random.RandomState(3)
        for _ in range(6):                   # n = n_max - 2
            u = rng.rand(3).astype(np.float32)
            v = obj(0, u)
            ga.tell(sa, _foreign_trial(u), v)
            gb.tell(sb, _foreign_trial(u), v)
        ga.tick(), gb.tick()
        with pytest.raises(StudySaturatedError, match="n_max"):
            await ga.ask(sa, q=4)            # k=2 < q=4: can never fit
        slot_a, slot_b = ga._studies[sa].slot, gb._studies[sb].slot
        assert ga.pool.fantasy_active(slot_a) == 0
        assert ga._studies[sa].pending_asks == 0
        assert_slots_equal(ga.pool, slot_a, gb.pool, slot_b,
                           "after q-ask rejection")
        batch = await ga.ask(sa, q=2)        # k=2 == q=2 still serves
        assert len(batch) == 2
        await ga.aclose(), await gb.aclose()
    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2:
        asyncio.run(main(d1, d2))


def test_ask_q_boundary_escalates_when_enabled():
    """Same boundary with escalation on: the oversized q-ask promotes the
    study and serves all q suggestions from the neural tier."""
    async def main(d):
        gw = StudyGateway(RESNET_SPACE, _cfg(d, n_max=8, neural=NB),
                          GatewayConfig(slots=1, max_inflight=8))
        sid = gw.create_study()
        rng = np.random.RandomState(3)
        for _ in range(6):
            u = rng.rand(3).astype(np.float32)
            gw.tell(sid, _foreign_trial(u), obj(0, u))
        gw.tick()
        batch = await gw.ask(sid, q=4)       # 6 + 4 > 8 -> promote, serve
        assert len(batch) == 4
        assert gw.study_info(sid)["tier"] == 1
        assert gw.study_info(sid)["saturated"] is True
        for tr in batch:
            gw.tell(sid, tr, obj(0, tr.unit))
        await gw.drain()
        assert gw.pool.n_real(gw._studies[sid].slot) == 10   # past n_max
        await gw.aclose()
    with tempfile.TemporaryDirectory() as d:
        asyncio.run(main(d))


# ---------------------------------------------------------------------------
# Serving through saturation: Levy-4d accuracy vs the truncated baseline
# ---------------------------------------------------------------------------
LEVY_SPACE = SearchSpace(tuple(Dim(f"x{i}", 0.0, 1.0) for i in range(4)))
_LO, _HI = (np.asarray(b, np.float64) for b in levy_bounds(4))


def _levy_obj(unit) -> float:
    x = _LO + np.asarray(unit, np.float64) * (_HI - _LO)
    return float(neg_levy(x))


async def _levy_run(d, *, escalate, asks, n_max=10):
    gw = StudyGateway(
        LEVY_SPACE,
        _cfg(d, n_max=n_max, neural=NB,
             acq=AcqConfig(restarts=16, ascent_steps=8)),
        GatewayConfig(slots=1, escalate=escalate))
    sid = gw.create_study()
    best, hist = -np.inf, []
    try:
        for _ in range(asks):
            tr = await gw.ask(sid)
            v = _levy_obj(tr.unit)
            best = max(best, v)
            hist.append(best)
            gw.tell(sid, tr, v)
            await gw.drain()
    except StudySaturatedError:
        pass
    info, summ = gw.study_info(sid), gw.summary()
    await gw.aclose()
    return best, hist, info, summ


def test_levy4d_escalated_no_worse_than_truncated_gp():
    """The acceptance regression: driven to >= 2x n_max through the
    gateway, the escalated study keeps serving and its best value is no
    worse than the lazy GP truncated at n_max.  The first n_max asks are
    the SAME code path in both runs (escalation changes nothing until the
    ask that would overflow), so the comparison is exact, not tolerant."""
    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2:
        esc, esc_hist, esc_info, esc_summ = asyncio.run(
            _levy_run(d1, escalate=True, asks=24))
        trunc, trunc_hist, trunc_info, _ = asyncio.run(
            _levy_run(d2, escalate=False, asks=24))
        assert len(trunc_hist) == 10          # terminal at n_max
        assert len(esc_hist) == 24            # kept serving past 2x n_max
        # identical machinery before the promotion point
        assert esc_hist[:10] == trunc_hist
        # best value monotone, never below the truncated baseline
        assert esc >= trunc
        assert esc_info["tier"] == 1 and esc_info["saturated"] is True
        assert trunc_info["tier"] == 0
        assert esc_summ["escalated"] == 1 and esc_summ["saturated"] >= 1


# ---------------------------------------------------------------------------
# Promotion -> eviction -> restore -> q-ask: bitwise stream parity
# ---------------------------------------------------------------------------
def test_promoted_study_evicts_and_restores_bitwise():
    """A promoted study churned through eviction/restore produces the
    BITWISE-identical suggestion stream (q=1 and q=2 asks interleaved) as
    the same study in a gateway with enough slots to never evict — the
    NB ledger, its cost rows, and the fantasy shadow all travel exactly."""
    async def probe(d, slots):
        gw = StudyGateway(RESNET_SPACE, _cfg(d, n_max=5, neural=NB),
                          GatewayConfig(slots=slots))
        sids = [gw.create_study(name=f"t{i}") for i in range(3)]
        out = []
        for r in range(9):
            res = await gw.ask(sids[0], q=2 if r % 2 else 1)
            for tr in (res if isinstance(res, list) else [res]):
                out.append(np.asarray(tr.unit).copy())
                gw.tell(sids[0], tr, obj(0, tr.unit), cost=1.0 + 0.1 * r)
            await gw.drain()
            for s in sids[1:]:    # churn: forces sids[0] out when slots=2
                tr2 = await gw.ask(s)
                gw.tell(s, tr2, obj(s, tr2.unit))
                await gw.drain()
        tier0 = gw.study_info(sids[0])["tier"]
        log = gw._studies[sids[0]]
        n0 = log.n_obs
        await gw.aclose()
        return out, tier0, n0, log
    async def main(d1, d2):
        resident, tier_a, n_a, log_a = await probe(d1, slots=3)
        churned, tier_b, n_b, log_b = await probe(d2, slots=2)
        assert tier_a == 1 and tier_b == 1           # both promoted
        assert n_a == n_b == 13                      # 13 > 2x n_max=10
        assert not log_a.evicted_ever
        assert log_b.evicted_ever
        assert len(resident) == len(churned) == 13
        for k, (x, y) in enumerate(zip(resident, churned)):
            assert np.array_equal(x, y), \
                f"suggestion {k} diverged through eviction churn"
    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2:
        asyncio.run(main(d1, d2))


def test_escalated_pool_checkpoint_restore_is_exact():
    """Pool checkpoint with an escalated study (fantasies outstanding):
    the snapshot holds only real NB state (rollback -> snapshot ->
    re-fantasize), cost rows travel, and the restored pool is bitwise the
    never-fantasized twin — then keeps serving q-asks."""
    def mk(d):
        return StudyPool([RESNET_SPACE], _cfg(d, n_max=6, neural=NB))
    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2:
        pa, pb = mk(d1), mk(d2)
        rng = np.random.RandomState(11)
        for i in range(6):                       # fill to n_max, twinned
            u = rng.rand(3).astype(np.float32)
            v = obj(0, u)
            pa.absorb(0, _foreign_trial(u), v, cost=1.0 + 0.25 * i)
            pb.absorb(0, _foreign_trial(u), v, cost=1.0 + 0.25 * i)
        pa.promote(0), pb.promote(0)
        assert pa.tier(0) == 1 and pa.engine.nb_n(0) == 6
        for i in range(2):                       # NB-tier absorbs, twinned
            u = rng.rand(3).astype(np.float32)
            v = obj(0, u)
            pa.absorb(0, _foreign_trial(u), v, cost=3.0)
            pb.absorb(0, _foreign_trial(u), v, cost=3.0)
        # q-ask on the escalated tier, tells drain in full: rollback must
        # leave pa bitwise equal to the never-fantasized twin
        trials = pa.ask_q(0, 3)
        assert pa.fantasy_active(0) == 3 and pa.n_real(0) == 8
        for tr in trials:
            v = obj(0, tr.unit)
            pa.absorb(0, tr, v)
            pb.absorb(0, _foreign_trial(tr.unit), v)
        assert pa.fantasy_active(0) == 0
        assert nb_mod.nb_to_json(pa.engine.nb_state(0)) == \
            nb_mod.nb_to_json(pb.engine.nb_state(0))
        # checkpoint mid-fantasy: snapshot is real-state only
        pending = pa.ask_q(0, 2)
        assert pa.checkpoint() is not None
        assert pa.fantasy_active(0) == 2         # live pool re-fantasized
        pr = mk(d1)
        assert pr.restore()
        assert pr.tier(0) == 1 and pr.engine.nb_n(0) == 11
        assert pr.fantasy_active(0) == 0
        np.testing.assert_array_equal(pr.engine.cost_row(0),
                                      pb.engine.cost_row(0))
        assert nb_mod.nb_to_json(pr.engine.nb_state(0)) == \
            nb_mod.nb_to_json(pb.engine.nb_state(0))
        # the restored escalated study keeps serving
        more = pr.ask_q(0, 2)
        assert len(more) == 2 and pr.fantasy_active(0) == 2
        for tr in more + pending:
            pr.absorb(0, _foreign_trial(tr.unit), obj(0, tr.unit))
        assert pr.engine.nb_n(0) == 15


# ---------------------------------------------------------------------------
# The cost axis: tell(cost=) -> ledger -> wire; EI-per-unit-cost ascent
# ---------------------------------------------------------------------------
def test_cost_threads_gateway_to_ledger():
    async def main(d):
        gw = StudyGateway(RESNET_SPACE, _cfg(d, n_max=16),
                          GatewayConfig(slots=1))
        sid = gw.create_study()
        costs = [2.0, 0.5, 1.0]                  # third tell: default
        for i, c in enumerate(costs):
            tr = await gw.ask(sid)
            if i == 2:
                gw.tell(sid, tr, obj(sid, tr.unit))
            else:
                gw.tell(sid, tr, obj(sid, tr.unit), cost=c)
            await gw.drain()
        row = gw.pool.engine.cost_row(gw._studies[sid].slot)
        np.testing.assert_array_equal(row[:3],
                                      np.asarray(costs, np.float32))
        for bad in (-1.0, 0.0, float("nan"), float("inf")):
            with pytest.raises(ValueError, match="cost"):
                gw.tell(sid, _foreign_trial(np.full(3, 0.5)), 0.1,
                        cost=bad)
        await gw.aclose()
    with tempfile.TemporaryDirectory() as d:
        asyncio.run(main(d))


def test_cost_rides_the_trial_wire_form():
    tr = Trial(7, np.asarray([0.1, 0.2, 0.3], np.float32), {}, cost=2.5)
    back = tx.trial_from_wire(tx.trial_to_wire(tr))
    assert back.cost == 2.5
    # hand-built frames from pre-cost clients default to 1.0
    legacy = tx.trial_from_wire({"trial_id": 1, "unit": [0.5, 0.5, 0.5]})
    assert legacy.cost == 1.0


def _unit_gp_state(n0=6, d=2, seed=0):
    key = jax.random.PRNGKey(seed)
    xs = jax.random.uniform(key, (n0, d))
    ys = -jnp.sum((xs - 0.5) ** 2, axis=-1)
    cfg = GPConfig(n_max=16, dim=d, noise2=1e-6)
    st = init_state(cfg)
    st = dataclasses.replace(
        st, x_buf=st.x_buf.at[:n0].set(xs),
        y_buf=st.y_buf.at[:n0].set(ys), n=jnp.asarray(n0, jnp.int32))
    return refactor(st, matern52)


def test_ei_per_cost_steers_away_from_expensive_region():
    """FABOLAS-style acquisition: with a log-cost head that makes the
    x0 > 0.5 half-box exponentially expensive, the cost-scaled ascent
    lands its argmax in the cheap half; without a cost head the mode
    degrades bitwise to plain EI."""
    st = _unit_gp_state()
    lo, hi = jnp.zeros(2), jnp.ones(2)
    key = jax.random.PRNGKey(42)
    acq = AcqConfig(name="ei_per_cost", restarts=16, ascent_steps=12,
                    fused="off")

    def log_cost(x):
        return 12.0 * jnp.maximum(x[..., 0] - 0.5, 0.0)

    x_cheap, _ = optimize_acquisition(st, matern52, lo, hi, key, acq,
                                      log_cost_fn=log_cost)
    assert float(x_cheap[0, 0]) <= 0.5 + 1e-3
    # no cost head -> plain EI, bitwise
    x_plain, v_plain = optimize_acquisition(
        st, matern52, lo, hi, key,
        AcqConfig(name="ei", restarts=16, ascent_steps=12, fused="off"))
    x_none, v_none = optimize_acquisition(st, matern52, lo, hi, key, acq)
    np.testing.assert_array_equal(np.asarray(x_none), np.asarray(x_plain))
    np.testing.assert_array_equal(np.asarray(v_none), np.asarray(v_plain))


# ---------------------------------------------------------------------------
# Observability: saturation gauges persist and merge through federation
# ---------------------------------------------------------------------------
def test_saturation_gauges_persist_across_gateway_restart():
    async def main(d):
        gw = StudyGateway(RESNET_SPACE, _cfg(d, n_max=4, neural=NB),
                          GatewayConfig(slots=1))
        sid = gw.create_study()
        for _ in range(9):                       # past 2x n_max
            tr = await gw.ask(sid)
            gw.tell(sid, tr, obj(sid, tr.unit))
            await gw.drain()
        assert gw.study_info(sid)["tier"] == 1
        assert gw.summary()["escalated"] == 1
        assert gw.checkpoint() is not None
        await gw.aclose()
        g2 = StudyGateway(RESNET_SPACE, _cfg(d, n_max=4, neural=NB),
                          GatewayConfig(slots=1))
        assert g2.restore()
        info = g2.study_info(sid)
        assert info["tier"] == 1 and info["saturated"] is True
        s = g2.summary()
        assert s["escalated"] == 1 and s["saturated"] >= 1
        tr = await g2.ask(sid)                   # still serving post-restore
        g2.tell(sid, tr, obj(sid, tr.unit))
        await g2.drain()
        await g2.aclose()
    with tempfile.TemporaryDirectory() as d:
        asyncio.run(main(d))


def test_federation_summary_merges_saturation_gauges():
    async def main(root):
        fg = FederatedGateway(RESNET_SPACE, _cfg(root, n_max=4, neural=NB),
                              GatewayConfig(slots=2),
                              FederationConfig(n_shards=2))
        sids = [fg.create_study(name=f"s{i}") for i in range(2)]
        for _ in range(9):                       # drive ONE study past cap
            tr = await fg.ask(sids[0])
            fg.tell(sids[0], tr, obj(sids[0], tr.unit), cost=2.0)
            await fg.drain()
        assert fg.study_info(sids[0])["tier"] == 1
        s = fg.summary()
        assert s["escalated"] == 1
        assert s["saturated"] >= 1
        await fg.aclose()
    with tempfile.TemporaryDirectory() as root:
        asyncio.run(main(root))
