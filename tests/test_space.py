"""`hpo/space.py`: unit-cube round-trips on typed dimensions.

The GP only ever sees the encoded unit cube; these tests pin the contract
that `to_unit` and `to_value` invert each other (including at the box
edges), that out-of-range values and unit coordinates CLAMP instead of
extrapolating (both directions — a restored trial at `hi + eps` must not
map outside the cube), that typed dims (Int / Categorical / Conditional)
encode to the feasible lattice and decode back, and that the preset spaces
map named hyper-parameters consistently.
"""
import math

import numpy as np
import pytest

from repro.hpo.space import (LENET_SPACE, LM_SPACE, MIXED_DEMO_SPACE,
                             RESNET_SPACE, Categorical, Conditional, Dim,
                             Float, Int, SearchSpace, space_from_dicts,
                             space_to_dicts)

LIN = Dim("momentum", 0.0, 0.99)
LOG = Dim("lr", 1e-4, 1e-1, "log")


@pytest.mark.parametrize("dim", [LIN, LOG], ids=["linear", "log"])
@pytest.mark.parametrize("u", [0.0, 0.25, 0.5, 0.75, 1.0])
def test_unit_value_round_trip(dim, u):
    v = dim.to_value(u)
    assert dim.lo <= v <= dim.hi or math.isclose(v, dim.lo) \
        or math.isclose(v, dim.hi)
    assert dim.to_unit(v) == pytest.approx(u, abs=1e-12)


@pytest.mark.parametrize("dim", [LIN, LOG], ids=["linear", "log"])
def test_edges_map_exactly(dim):
    assert dim.to_value(0.0) == pytest.approx(dim.lo, rel=1e-12)
    assert dim.to_value(1.0) == pytest.approx(dim.hi, rel=1e-12)
    assert dim.to_unit(dim.lo) == pytest.approx(0.0, abs=1e-12)
    assert dim.to_unit(dim.hi) == pytest.approx(1.0, abs=1e-12)


@pytest.mark.parametrize("dim", [LIN, LOG], ids=["linear", "log"])
def test_out_of_range_unit_clamps(dim):
    """EI ascent output is clipped to [0,1], but to_value must still be
    safe against float spill beyond the box."""
    assert dim.to_value(-0.25) == pytest.approx(dim.to_value(0.0))
    assert dim.to_value(1.25) == pytest.approx(dim.to_value(1.0))


def test_log_dim_is_geometric():
    mid = LOG.to_value(0.5)
    assert mid == pytest.approx(math.sqrt(LOG.lo * LOG.hi), rel=1e-9)


def test_value_unit_round_trip_on_values():
    for v in (1e-4, 3e-4, 1e-3, 0.05, 1e-1):
        assert LOG.to_value(LOG.to_unit(v)) == pytest.approx(v, rel=1e-9)
    for v in (0.0, 0.1, 0.42, 0.99):
        assert LIN.to_value(LIN.to_unit(v)) == pytest.approx(v, abs=1e-12)


@pytest.mark.parametrize("space", [LENET_SPACE, RESNET_SPACE, LM_SPACE],
                         ids=["lenet", "resnet", "lm"])
def test_space_hparams_round_trip(space):
    rng = np.random.default_rng(0)
    u = rng.uniform(size=space.dim).astype(np.float32)
    hp = space.to_hparams(u)
    assert list(hp) == space.names
    back = space.to_unit(hp)
    np.testing.assert_allclose(back, u, atol=1e-5)


def test_space_sample_shape_dtype_and_range():
    rng = np.random.default_rng(1)
    s = RESNET_SPACE.sample(rng, 7)
    assert s.shape == (7, RESNET_SPACE.dim)
    assert s.dtype == np.float32
    assert (s >= 0.0).all() and (s <= 1.0).all()


def test_custom_space_dim_property():
    sp = SearchSpace((LIN, LOG))
    assert sp.dim == 2
    assert sp.names == ["momentum", "lr"]


# ---------------------------------------------------------------------------
# Regression: out-of-range VALUES clamp in to_unit (the tell-tick abort).
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dim", [LIN, LOG], ids=["linear", "log"])
def test_out_of_range_value_clamps(dim):
    """A restored/external trial whose value sits at hi + eps (float spill)
    must map to the cube edge — an out-of-cube unit used to abort the
    gateway's coalesced tell() tick."""
    eps = abs(dim.hi) * 1e-6 + 1e-9
    assert dim.to_unit(dim.hi + eps) == pytest.approx(1.0, abs=1e-5)
    assert dim.to_unit(dim.hi * 10.0) == 1.0
    # below lo clamps to 0 — on a log dim this used to raise (log of a
    # non-positive value) before it could even produce a bad unit
    assert dim.to_unit(dim.lo - 1.0) == 0.0


def test_space_to_unit_of_spilled_hparams_stays_in_cube():
    hp = RESNET_SPACE.to_hparams(np.ones(RESNET_SPACE.dim, np.float32))
    hp = {k: v * (1.0 + 1e-6) for k, v in hp.items()}   # spill past hi
    u = RESNET_SPACE.to_unit(hp)
    assert (u >= 0.0).all() and (u <= 1.0).all()


# ---------------------------------------------------------------------------
# Typed dims: Int / Categorical / Conditional (DESIGN.md §10)
# ---------------------------------------------------------------------------
INT = Int("depth", 2, 8)
CAT = Categorical("opt", ("sgd", "adam", "rmsprop"))


def test_float_aliases_dim():
    assert Float is Dim


def test_int_lattice_round_trip():
    assert INT.levels == 7
    for v in range(2, 9):
        u = INT.to_unit(v)
        assert 0.0 <= u <= 1.0
        assert INT.to_value(u) == v
    # off-lattice units round to the nearest integer
    assert INT.to_value(INT.to_unit(5) + 0.01) == 5
    # out-of-range values clamp
    assert INT.to_unit(100) == 1.0
    assert INT.to_unit(-3) == 0.0


def test_int_single_level():
    d = Int("k", 3, 3)
    assert d.levels == 1
    assert d.to_unit(3) == 0.0
    assert d.to_value(0.7) == 3


def test_categorical_one_hot_round_trip():
    for c in CAT.choices:
        u = CAT.encode(c)
        assert u.sum() == 1.0 and u.max() == 1.0
        assert CAT.decode(u) == c
    # argmax decode is deterministic on ties (first index wins)
    assert CAT.decode(np.asarray([0.5, 0.5, 0.0])) == "sgd"


def test_categorical_validation():
    with pytest.raises(ValueError):
        Categorical("c", ("only",))
    with pytest.raises(ValueError):
        Categorical("c", ("a", "a"))


def test_categorical_choices_must_survive_json_round_trip():
    """A composite choice (e.g. a tuple) would serialize into the gateway
    registry as a JSON list and make the committed checkpoint unrestorable
    (Categorical rebuild dedups via set()) — reject it at construction,
    not at crash recovery."""
    with pytest.raises(ValueError, match="JSON"):
        Categorical("filter", ((3, 3), (5, 5)))
    # primitives of every JSON scalar kind are fine and round-trip
    sp = SearchSpace((Categorical("k", (1, 2, 3)),))
    assert space_from_dicts(space_to_dicts(sp)) == sp


def test_conditional_gating_round_trip():
    sp = MIXED_DEMO_SPACE
    # active branch: optimizer == sgd carries momentum
    hp = {"lr": 1e-2, "depth": 4, "optimizer": "sgd", "momentum": 0.5}
    u = sp.to_unit(hp)
    back = sp.to_hparams(u)
    assert back["optimizer"] == "sgd"
    assert back["momentum"] == pytest.approx(0.5, abs=1e-5)
    # inactive branch: momentum encodes to the neutral 0, decodes to None
    hp2 = {"lr": 1e-2, "depth": 4, "optimizer": "adam", "momentum": 0.9}
    u2 = sp.to_unit(hp2)
    assert u2[-1] == 0.0
    assert sp.to_hparams(u2)["momentum"] is None


def test_conditional_validation():
    with pytest.raises(ValueError, match="parent"):
        SearchSpace((Conditional(Dim("m", 0.0, 1.0), "nope", "x"),))
    with pytest.raises(ValueError, match="choice"):
        SearchSpace((CAT, Conditional(Dim("m", 0.0, 1.0), "opt", "bad")))
    with pytest.raises(ValueError, match="nest"):
        Conditional(Conditional(Dim("m", 0.0, 1.0), "a", "b"), "c", "d")


def test_mixed_space_sample_is_feasible():
    sp = MIXED_DEMO_SPACE
    rng = np.random.default_rng(3)
    s = sp.sample(rng, 32)
    assert s.shape == (32, sp.dim)
    np.testing.assert_allclose(sp.project(s), s, atol=1e-6)
    # every row decodes to a consistent hparam dict and re-encodes exactly
    for row in s:
        np.testing.assert_allclose(sp.to_unit(sp.to_hparams(row)), row,
                                   atol=1e-5)


def test_all_float_sample_stream_unchanged():
    """Typed-space sampling must not perturb the seed streams of existing
    all-Float studies (restored pools replay these streams)."""
    rng = np.random.default_rng(7)
    want = np.random.default_rng(7).uniform(
        0.0, 1.0, (5, RESNET_SPACE.dim)).astype(np.float32)
    np.testing.assert_array_equal(RESNET_SPACE.sample(rng, 5), want)


def test_space_serialization_round_trip():
    sp = MIXED_DEMO_SPACE
    assert space_from_dicts(space_to_dicts(sp)) == sp
    # legacy dicts (no "type" tag) rebuild as float Dims
    legacy = [{"name": "lr", "lo": 1e-4, "hi": 1e-1, "scale": "log"}]
    sp2 = space_from_dicts(legacy)
    assert sp2.dims[0] == Dim("lr", 1e-4, 1e-1, "log")


def test_descriptor_matches_layout():
    desc = MIXED_DEMO_SPACE.descriptor()
    np.testing.assert_array_equal(np.asarray(desc.cont_mask),
                                  [1, 1, 0, 0, 0, 1])
    np.testing.assert_array_equal(np.asarray(desc.cat_mask),
                                  [0, 0, 1, 1, 1, 0])
    np.testing.assert_array_equal(np.asarray(desc.levels),
                                  [0, 7, 0, 0, 0, 0])
    np.testing.assert_array_equal(np.asarray(desc.group),
                                  [-1, -1, 2, 2, 2, -1])
    # momentum is gated by optimizer == "sgd" (one-hot coordinate 2)
    np.testing.assert_array_equal(np.asarray(desc.parent),
                                  [-1, -1, -1, -1, -1, 2])
    assert desc.has_discrete
    assert not RESNET_SPACE.descriptor().has_discrete
