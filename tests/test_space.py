"""`hpo/space.py`: unit-cube round-trips on linear and log dimensions.

The GP only ever sees the unit cube; these tests pin the contract that
`to_unit` and `to_value` invert each other (including at the box edges),
that out-of-range unit coordinates clamp instead of extrapolating, and
that the preset spaces map named hyper-parameters consistently.
"""
import math

import numpy as np
import pytest

from repro.hpo.space import (LENET_SPACE, LM_SPACE, RESNET_SPACE, Dim,
                             SearchSpace)

LIN = Dim("momentum", 0.0, 0.99)
LOG = Dim("lr", 1e-4, 1e-1, "log")


@pytest.mark.parametrize("dim", [LIN, LOG], ids=["linear", "log"])
@pytest.mark.parametrize("u", [0.0, 0.25, 0.5, 0.75, 1.0])
def test_unit_value_round_trip(dim, u):
    v = dim.to_value(u)
    assert dim.lo <= v <= dim.hi or math.isclose(v, dim.lo) \
        or math.isclose(v, dim.hi)
    assert dim.to_unit(v) == pytest.approx(u, abs=1e-12)


@pytest.mark.parametrize("dim", [LIN, LOG], ids=["linear", "log"])
def test_edges_map_exactly(dim):
    assert dim.to_value(0.0) == pytest.approx(dim.lo, rel=1e-12)
    assert dim.to_value(1.0) == pytest.approx(dim.hi, rel=1e-12)
    assert dim.to_unit(dim.lo) == pytest.approx(0.0, abs=1e-12)
    assert dim.to_unit(dim.hi) == pytest.approx(1.0, abs=1e-12)


@pytest.mark.parametrize("dim", [LIN, LOG], ids=["linear", "log"])
def test_out_of_range_unit_clamps(dim):
    """EI ascent output is clipped to [0,1], but to_value must still be
    safe against float spill beyond the box."""
    assert dim.to_value(-0.25) == pytest.approx(dim.to_value(0.0))
    assert dim.to_value(1.25) == pytest.approx(dim.to_value(1.0))


def test_log_dim_is_geometric():
    mid = LOG.to_value(0.5)
    assert mid == pytest.approx(math.sqrt(LOG.lo * LOG.hi), rel=1e-9)


def test_value_unit_round_trip_on_values():
    for v in (1e-4, 3e-4, 1e-3, 0.05, 1e-1):
        assert LOG.to_value(LOG.to_unit(v)) == pytest.approx(v, rel=1e-9)
    for v in (0.0, 0.1, 0.42, 0.99):
        assert LIN.to_value(LIN.to_unit(v)) == pytest.approx(v, abs=1e-12)


@pytest.mark.parametrize("space", [LENET_SPACE, RESNET_SPACE, LM_SPACE],
                         ids=["lenet", "resnet", "lm"])
def test_space_hparams_round_trip(space):
    rng = np.random.default_rng(0)
    u = rng.uniform(size=space.dim).astype(np.float32)
    hp = space.to_hparams(u)
    assert list(hp) == space.names
    back = space.to_unit(hp)
    np.testing.assert_allclose(back, u, atol=1e-5)


def test_space_sample_shape_dtype_and_range():
    rng = np.random.default_rng(1)
    s = RESNET_SPACE.sample(rng, 7)
    assert s.shape == (7, RESNET_SPACE.dim)
    assert s.dtype == np.float32
    assert (s >= 0.0).all() and (s <= 1.0).all()


def test_custom_space_dim_property():
    sp = SearchSpace((LIN, LOG))
    assert sp.dim == 2
    assert sp.names == ["momentum", "lr"]
