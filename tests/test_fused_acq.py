"""Fused EI-ascent megakernel: parity, autotuner, and hoist contracts.

Covers DESIGN.md §11: the fused value+gradient step (`ops.fused_ei_grad`,
hand-derived adjoint in `kernels/acq.py`) must match the unfused autodiff
oracle to <= 1e-5 on every substrate, for float-only and mixed descriptors,
single states and heterogeneous stacked states; the block-size autotuner
must be deterministic per cache key and inert under REPRO_ACQ_AUTOTUNE=off;
and the loop-invariant hoists (`_f_best`, `_ymean` once per suggest call)
are pinned by a trace-count test so a refactor can't silently re-inline
them into the ascent loop.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import GPConfig, append_batch, init_state, matern52
from repro.core import acquisition as acq_mod
from repro.core import gp as gp_mod
from repro.core.acquisition import (AcqConfig, ei_value_and_grad,
                                    optimize_acquisition)
from repro.core.kernels import make_mixed_kernel
from repro.kernels import ops

IMPLEMENTATIONS = ["xla", "ref", "pallas"]
CONT_MASK = jnp.asarray([1.0, 1.0, 1.0, 0.0, 0.0])
CAT_MASK = jnp.asarray([0.0, 0.0, 0.0, 1.0, 1.0])
MIXED_KERNEL = make_mixed_kernel(CONT_MASK, CAT_MASK)


@pytest.fixture(autouse=True)
def _fresh_tune_cache():
    ops._ACQ_TUNE_CACHE.clear()
    yield
    ops._ACQ_TUNE_CACHE.clear()


def _seed_state(key, n0, d, n_max, kernel=matern52, implementation="xla"):
    cfg = GPConfig(n_max=n_max, dim=d, implementation=implementation)
    xs = jax.random.uniform(key, (n0, d))
    ys = jnp.sin(3.0 * xs.sum(-1)) + 0.1 * xs[:, 0]
    return append_batch(init_state(cfg), kernel, xs, ys,
                        implementation=implementation)


def _hetero_stack(kernel=matern52, n0s=(3, 6, 9), d=3, n_max=16):
    singles = [_seed_state(jax.random.PRNGKey(20 + i), n0, d, n_max,
                           kernel=kernel) for i, n0 in enumerate(n0s)]
    return gp_mod.stack_states(singles), singles


# ---------------------------------------------------------------------------
# Fused vs unfused parity (value AND gradient), per substrate
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("implementation", IMPLEMENTATIONS)
def test_fused_matches_unfused_float(implementation):
    st = _seed_state(jax.random.PRNGKey(0), 9, 4, 16)
    x = jax.random.uniform(jax.random.PRNGKey(1), (13, 4))
    v_f, g_f = ei_value_and_grad(st, matern52, x,
                                 implementation=implementation, fused=True)
    for oracle in ("xla", "ref"):
        v_u, g_u = ei_value_and_grad(st, matern52, x,
                                     implementation=oracle, fused=False)
        np.testing.assert_allclose(np.asarray(v_f), np.asarray(v_u),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(g_f), np.asarray(g_u),
                                   rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("implementation", IMPLEMENTATIONS)
def test_fused_matches_unfused_mixed(implementation):
    st = _seed_state(jax.random.PRNGKey(2), 8, 5, 16, kernel=MIXED_KERNEL)
    x = jax.random.uniform(jax.random.PRNGKey(3), (11, 5))
    v_f, g_f = ei_value_and_grad(st, MIXED_KERNEL, x,
                                 implementation=implementation, fused=True)
    v_u, g_u = ei_value_and_grad(st, MIXED_KERNEL, x,
                                 implementation="xla", fused=False)
    np.testing.assert_allclose(np.asarray(v_f), np.asarray(v_u),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(g_f), np.asarray(g_u),
                               rtol=1e-4, atol=1e-5)
    # The categorical factor is stop_gradient'd: the fused adjoint must
    # report exactly zero gradient on the cat coordinates, like autodiff.
    np.testing.assert_array_equal(
        np.asarray(g_f * CAT_MASK), np.zeros_like(np.asarray(g_f)))


@pytest.mark.parametrize("implementation", IMPLEMENTATIONS)
def test_fused_stacked_heterogeneous_matches_per_study(implementation):
    """Vmapped fused step over a het-n stack == per-study unfused oracle."""
    stacked, singles = _hetero_stack()
    x = jax.random.uniform(jax.random.PRNGKey(4), (len(singles), 7, 3))
    v, g = jax.vmap(lambda st, xi: ei_value_and_grad(
        st, matern52, xi, implementation=implementation, fused=True,
        tune_s=len(singles)))(stacked, x)
    assert v.shape == (len(singles), 7) and g.shape == x.shape
    for i, st in enumerate(singles):
        v_u, g_u = ei_value_and_grad(st, matern52, x[i],
                                     implementation="xla", fused=False)
        np.testing.assert_allclose(np.asarray(v[i]), np.asarray(v_u),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(g[i]), np.asarray(g_u),
                                   rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("implementation", IMPLEMENTATIONS)
def test_fused_suggest_matches_unfused_suggest(implementation):
    """End to end: the whole ascent lands on the same point either way."""
    st = _seed_state(jax.random.PRNGKey(5), 9, 3, 16)
    lo, hi = jnp.zeros(3), jnp.ones(3)
    key = jax.random.PRNGKey(6)
    cfg_on = AcqConfig(restarts=8, ascent_steps=6, fused="on")
    cfg_off = AcqConfig(restarts=8, ascent_steps=6, fused="off")
    p_on, v_on = optimize_acquisition(st, matern52, lo, hi, key, cfg_on, 2,
                                      implementation=implementation)
    p_off, v_off = optimize_acquisition(st, matern52, lo, hi, key, cfg_off,
                                        2, implementation=implementation)
    np.testing.assert_allclose(np.asarray(p_on), np.asarray(p_off),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(v_on), np.asarray(v_off),
                               rtol=1e-4, atol=1e-5)


def test_fused_suggest_deterministic():
    st = _seed_state(jax.random.PRNGKey(7), 6, 3, 16)
    lo, hi = jnp.zeros(3), jnp.ones(3)
    cfg = AcqConfig(restarts=8, ascent_steps=4)
    args = (st, matern52, lo, hi, jax.random.PRNGKey(8), cfg, 2)
    p1, v1 = optimize_acquisition(*args)
    p2, v2 = optimize_acquisition(*args)
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))


def test_unsupported_acquisition_falls_back_unfused():
    """fused="on" with a non-EI acquisition silently runs the generic
    path (fused_supported gates on the acquisition name)."""
    assert not ops.fused_supported(matern52, "ucb")
    assert ops.fused_supported(matern52, "ei")
    assert ops.fused_supported(MIXED_KERNEL, "ei")
    st = _seed_state(jax.random.PRNGKey(9), 6, 3, 16)
    lo, hi = jnp.zeros(3), jnp.ones(3)
    cfg = AcqConfig(name="ucb", restarts=4, ascent_steps=3, fused="on")
    pts, vals = optimize_acquisition(st, matern52, lo, hi,
                                     jax.random.PRNGKey(10), cfg, 1)
    assert pts.shape == (1, 3) and vals.shape == (1,)


def test_invalid_fused_mode_raises():
    st = _seed_state(jax.random.PRNGKey(11), 4, 2, 8)
    cfg = AcqConfig(fused="maybe")
    with pytest.raises(ValueError, match="fused"):
        optimize_acquisition(st, matern52, jnp.zeros(2), jnp.ones(2),
                             jax.random.PRNGKey(12), cfg, 1)


# ---------------------------------------------------------------------------
# Block-size autotuner (ops.acq_tile_config)
# ---------------------------------------------------------------------------
def test_autotuner_same_key_same_config_no_remeasure(monkeypatch):
    monkeypatch.setenv("REPRO_ACQ_AUTOTUNE", "on")   # CI pins it off
    calls = []

    def fake_measure(block_r, d_pad, n_pad, s):
        calls.append(block_r)
        return float(abs(block_r - 64))       # 64 wins, deterministically

    cfg1 = ops.acq_tile_config(256, 5, 1, True, measure_fn=fake_measure)
    n_first = len(calls)
    assert n_first == len(ops.ACQ_BLOCK_R_CANDIDATES)
    assert cfg1.block_r == 64 and cfg1.measured
    cfg2 = ops.acq_tile_config(256, 5, 1, True, measure_fn=fake_measure)
    assert cfg2 == cfg1
    assert len(calls) == n_first              # cache hit: no re-measure
    ops.acq_tile_config(256, 7, 1, True, measure_fn=fake_measure)
    assert len(calls) == 2 * n_first          # new key does re-measure


def test_autotuner_env_off_pins_heuristic(monkeypatch):
    monkeypatch.setenv("REPRO_ACQ_AUTOTUNE", "off")
    called = []
    cfg = ops.acq_tile_config(
        256, 5, 1, False,
        measure_fn=lambda *a: called.append(a) or 0.0)
    assert not called and not cfg.measured
    assert cfg.block_r == ops.ACQ_DEFAULT_BLOCK_R
    assert cfg.d_pad == 128
    assert not ops._ACQ_TUNE_CACHE            # bypasses the cache entirely


def test_autotuner_interpret_defaults_to_heuristic():
    cfg = ops.acq_tile_config(256, 5, 1, True)
    assert not cfg.measured
    assert cfg.block_r == ops.ACQ_DEFAULT_BLOCK_R
    assert ops.acq_tile_config(256, 5, 1, True) == cfg


def test_next_power_of_2():
    assert [ops.next_power_of_2(v) for v in (1, 2, 3, 5, 8, 9, 129)] == [
        1, 2, 4, 8, 8, 16, 256]


# ---------------------------------------------------------------------------
# Selection tie-break quantization (layout-stable top-t)
# ---------------------------------------------------------------------------
def test_tiebreak_quantization_collapses_ulp_ties():
    v = jnp.float32(0.7)
    near = jnp.asarray([v, jnp.nextafter(v, jnp.float32(1.0))])
    q = acq_mod._quantize_for_tiebreak(near)
    assert q[0] == q[1]                       # 1-ulp apart -> same bucket
    # argmax of the quantized values picks the FIRST of a tied pair, so
    # every device layout agrees on the winning restart.
    vals = jnp.asarray([jnp.nextafter(v, jnp.float32(1.0)), v, 0.2])
    assert int(jnp.argmax(acq_mod._quantize_for_tiebreak(vals))) == 0
    vals = jnp.asarray([v, jnp.nextafter(v, jnp.float32(1.0)), 0.2])
    assert int(jnp.argmax(acq_mod._quantize_for_tiebreak(vals))) == 0


def test_tiebreak_quantization_is_monotone():
    vs = jnp.sort(jax.random.normal(jax.random.PRNGKey(13), (64,)) * 100.0)
    q = np.asarray(acq_mod._quantize_for_tiebreak(vs))
    assert (np.diff(q) >= 0).all()            # order-preserving
    np.testing.assert_allclose(q, np.asarray(vs), rtol=2e-3, atol=1e-30)


# ---------------------------------------------------------------------------
# Loop-invariant hoists pinned by trace count (f_best / ymean once per call)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("fused", ["auto", "off"])
def test_f_best_and_ymean_hoisted_once_per_trace(monkeypatch, fused):
    st = _seed_state(jax.random.PRNGKey(14), 6, 3, 16)
    lo, hi = jnp.zeros(3), jnp.ones(3)
    counts = {"f_best": 0, "ymean": 0}
    real_fb, real_ym = acq_mod._f_best, gp_mod._ymean

    def counting_fb(s):
        counts["f_best"] += 1
        return real_fb(s)

    def counting_ym(s):
        counts["ymean"] += 1
        return real_ym(s)

    monkeypatch.setattr(acq_mod, "_f_best", counting_fb)
    monkeypatch.setattr(gp_mod, "_ymean", counting_ym)
    cfg = AcqConfig(restarts=4, ascent_steps=3, fused=fused)
    jax.make_jaxpr(lambda k: optimize_acquisition(
        st, matern52, lo, hi, k, cfg, 1))(jax.random.PRNGKey(15))
    assert counts == {"f_best": 1, "ymean": 1}

    # Batched path: vmap traces the per-study body exactly once too.
    stacked, singles = _hetero_stack()
    keys = jax.random.split(jax.random.PRNGKey(16), len(singles))
    counts["f_best"] = counts["ymean"] = 0
    jax.make_jaxpr(lambda ks: optimize_acquisition(
        stacked, matern52, lo, hi, ks, cfg, 1))(keys)
    assert counts == {"f_best": 1, "ymean": 1}
