"""Unit tests for benchmark/dry-run utilities (pure python, fast)."""
import pytest

import os as _os

# Importing repro.launch.dryrun sets XLA_FLAGS (its required first lines);
# restore the environment immediately so the main pytest process keeps
# seeing 1 device (the assignment forbids setting the flag globally).
_saved_xla_flags = _os.environ.get("XLA_FLAGS")
from benchmarks.roofline import SHAPE_FACTOR, SHAPE_TOKENS, analyse
from repro.configs import ARCH_IDS, get_config
from repro.launch.dryrun import _lin_combine, _pattern_period
from repro.launch.specs import SHAPES, cell_applicable

if _saved_xla_flags is None:
    _os.environ.pop("XLA_FLAGS", None)
else:
    _os.environ["XLA_FLAGS"] = _saved_xla_flags



def test_lin_combine_exact_for_linear_costs():
    c1 = {"cost": {"flops": 10.0, "bytes": 100.0}, "n": 3}
    c2 = {"cost": {"flops": 16.0, "bytes": 160.0}, "n": 5}
    out = _lin_combine(c1, c2, 1, 2, 10)   # f(L) = 4 + 6L, b(L) = 40+60L
    assert out["cost"]["flops"] == pytest.approx(4 + 6 * 10)
    assert out["cost"]["bytes"] == pytest.approx(40 + 60 * 10)


def test_pattern_period_per_arch():
    assert _pattern_period(get_config("gemma3-4b")) == 6
    assert _pattern_period(get_config("zamba2-1.2b")) == 6
    assert _pattern_period(get_config("granite-3-2b")) == 1


def test_cell_applicability_matrix():
    """32 runnable + 8 documented skips = 40 assigned cells."""
    runnable = skipped = 0
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES:
            ok, reason = cell_applicable(cfg, shape)
            if ok:
                runnable += 1
            else:
                skipped += 1
                assert reason
    assert runnable == 32
    assert skipped == 8


def test_skips_match_design_doc():
    full_attn = ["granite-moe-3b-a800m", "qwen3-moe-30b-a3b",
                 "deepseek-coder-33b", "minicpm3-4b", "granite-3-2b",
                 "chameleon-34b"]
    for arch in full_attn:
        ok, reason = cell_applicable(get_config(arch), "long_500k")
        assert not ok and "full-attention" in reason
    for shape in ("decode_32k", "long_500k"):
        ok, reason = cell_applicable(get_config("hubert-xlarge"), shape)
        assert not ok and "encoder" in reason
    for arch in ("gemma3-4b", "zamba2-1.2b", "xlstm-1.3b"):
        ok, _ = cell_applicable(get_config(arch), "long_500k")
        assert ok


def test_shape_grid_matches_assignment():
    assert SHAPES["train_4k"].seq == 4096 and SHAPES["train_4k"].batch == 256
    assert SHAPES["prefill_32k"].seq == 32768
    assert SHAPES["decode_32k"].batch == 128
    assert SHAPES["long_500k"].seq == 524288
    assert SHAPE_TOKENS["train_4k"] == 4096 * 256
    assert SHAPE_FACTOR["train_4k"] == 6.0


def test_analyse_skips_non_ok():
    assert analyse({"status": "skipped"}) is None
    assert analyse({"status": "error"}) is None
