"""Long-soak determinism: a gateway serving random client traffic with
slot-eviction churn AND periodic kill/restore must produce bitwise-
identical per-study suggestion streams to an uninterrupted gateway with
every study resident.

The tier-1 copy runs a short soak; the full 500+-tick soak is slow-marked
and gated behind REPRO_SOAK=1 (a dedicated CI job runs it — see
.github/workflows/ci.yml `soak`).
"""
import asyncio
import os
import tempfile

import numpy as np
import pytest

from repro.core.acquisition import AcqConfig
from repro.hpo import GatewayConfig, SchedulerConfig, StudyGateway
from repro.hpo.space import RESNET_SPACE


def _objective(sid, unit):
    c = 0.15 + 0.7 * ((sid * 0.37) % 1.0)
    return float(-np.sum((np.asarray(unit) - c) ** 2))


def _mk(d, slots, n_max):
    cfg = SchedulerConfig(n_max=n_max, seed=0, ckpt_dir=d,
                          ckpt_every=10_000,
                          acq=AcqConfig(restarts=8, ascent_steps=4))
    return StudyGateway(RESNET_SPACE, cfg, GatewayConfig(slots=slots))


async def _soak(d, *, slots, n_studies, rounds, n_max, restart_every=None,
                traffic_seed=7):
    """Deterministic random traffic; returns (per-study streams, ticks).

    Each round a random subset of studies asks (concurrently — the asks
    coalesce, and with slots < n_studies they also churn the LRU), then
    tells its result; `restart_every` rounds, the gateway checkpoints at a
    quiescent point, is dropped, and a fresh gateway restores.
    """
    gw = _mk(d, slots, n_max)
    sids = [gw.create_study(name=f"t{i}") for i in range(n_studies)]
    streams = {s: [] for s in sids}
    rng = np.random.default_rng(traffic_seed)

    async def one(s):
        # ask→tell per client task: tells free slots for the asks the
        # tick deferred, so an active set wider than the slot count drains
        tr = await gw.ask(s)
        streams[s].append(np.asarray(tr.unit).copy())
        gw.tell(s, tr, _objective(s, tr.unit))

    for r in range(rounds):
        active = [s for s in sids if rng.random() < 0.6]
        if not active:
            continue
        await asyncio.gather(*(one(s) for s in active))
        await gw.drain()
        if restart_every and (r + 1) % restart_every == 0:
            gw.checkpoint()
            await gw.aclose()
            gw = _mk(d, slots, n_max)
            assert gw.restore(), "soak restore failed"
    ticks = gw._tick_count          # cumulative: rides the registry
    await gw.aclose()
    return streams, ticks


def _assert_identical(a, b):
    for s in a:
        assert len(a[s]) == len(b[s])
        for k, (x, y) in enumerate(zip(a[s], b[s])):
            assert np.array_equal(x, y), \
                f"study {s} suggestion {k} diverged: {x} vs {y}"


def test_soak_determinism_short():
    """Tier-1 mini-soak: eviction churn + two mid-stream restores vs an
    uninterrupted all-resident gateway."""
    async def main(d_a, d_b):
        ref, _ = await _soak(d_a, slots=5, n_studies=5, rounds=18,
                             n_max=24)
        churn, ticks = await _soak(d_b, slots=2, n_studies=5, rounds=18,
                                   n_max=24, restart_every=7)
        assert ticks >= 30
        _assert_identical(ref, churn)
    with tempfile.TemporaryDirectory() as d_a, \
            tempfile.TemporaryDirectory() as d_b:
        asyncio.run(main(d_a, d_b))


@pytest.mark.slow
@pytest.mark.skipif(not os.environ.get("REPRO_SOAK"),
                    reason="500+-tick soak; set REPRO_SOAK=1 (dedicated CI "
                           "job) to run")
def test_soak_determinism_500_ticks():
    """The full soak: 500+ gateway ticks of random traffic over 6 logical
    studies on 3 slots, restored from checkpoint every 40 rounds, bitwise-
    identical to the uninterrupted all-resident run."""
    async def main(d_a, d_b):
        ref, _ = await _soak(d_a, slots=6, n_studies=6, rounds=260,
                             n_max=220)
        churn, ticks = await _soak(d_b, slots=3, n_studies=6, rounds=260,
                                   n_max=220, restart_every=40)
        assert ticks >= 500, f"soak only reached {ticks} ticks"
        _assert_identical(ref, churn)
    with tempfile.TemporaryDirectory() as d_a, \
            tempfile.TemporaryDirectory() as d_b:
        asyncio.run(main(d_a, d_b))
