"""Long-soak determinism: a gateway serving random client traffic with
slot-eviction churn AND periodic kill/restore must produce bitwise-
identical per-study suggestion streams to an uninterrupted gateway with
every study resident — and a FEDERATION under the same traffic plus
periodic shard kill/restore and forced migrations must match an
uninterrupted single-pool run (DESIGN.md §13 single-pool equivalence).

The tier-1 copies run short soaks; the full 500+-tick soaks are
slow-marked and gated behind REPRO_SOAK=1 (a dedicated CI job runs them —
see .github/workflows/ci.yml `soak`).  Traffic generation and the stream
comparison live in tests/_traffic.py (shared with the fault suite).
"""
import asyncio
import os
import tempfile

import pytest

from _traffic import assert_streams_identical, make_cfg, run_traffic
from repro.hpo import (FederatedGateway, FederationConfig, GatewayConfig,
                       StudyGateway)
from repro.hpo.space import RESNET_SPACE


def _mk(d, slots, n_max):
    return StudyGateway(RESNET_SPACE, make_cfg(d, n_max=n_max),
                        GatewayConfig(slots=slots))


async def _soak(d, *, slots, n_studies, rounds, n_max, restart_every=None,
                traffic_seed=7):
    """Deterministic random traffic; returns (per-study streams, ticks).

    `restart_every` rounds, the gateway checkpoints at a quiescent point,
    is dropped, and a fresh gateway restores.
    """
    gw = _mk(d, slots, n_max)
    sids = [gw.create_study(name=f"t{i}") for i in range(n_studies)]

    async def on_round(r, cur):
        if restart_every and (r + 1) % restart_every == 0:
            cur.checkpoint()
            await cur.aclose()
            nxt = _mk(d, slots, n_max)
            assert nxt.restore(), "soak restore failed"
            return nxt
        return None

    streams, gw = await run_traffic(gw, sids, rounds,
                                    traffic_seed=traffic_seed,
                                    on_round=on_round)
    ticks = gw._tick_count          # cumulative: rides the registry
    await gw.aclose()
    return streams, ticks


async def _fed_soak(d, *, n_shards, slots, n_studies, rounds, n_max,
                    kill_every=None, migrate_every=None, traffic_seed=7):
    """Federation under the same seeded traffic, with eviction churn
    (slots < studies per shard), periodic shard kill/restore (checkpointed
    immediately before the kill — a crash at a durable point, so the
    equivalence to the uninterrupted run is exact), and forced round-robin
    migrations.  Returns (streams, fed summary)."""
    cfg = make_cfg(d, n_max=n_max)
    fg = FederatedGateway(RESNET_SPACE, cfg, GatewayConfig(slots=slots),
                          FederationConfig(n_shards=n_shards))
    sids = [fg.create_study(name=f"t{i}") for i in range(n_studies)]
    state = {"kill": 0}

    async def on_round(r, cur):
        if migrate_every and (r + 1) % migrate_every == 0:
            sid = sids[r % len(sids)]
            src = cur.shard_of(sid)
            cur.migrate_study(sid, (src + 1) % n_shards)
        if kill_every and (r + 1) % kill_every == 0:
            cur.checkpoint()
            i = state["kill"] % n_shards
            state["kill"] += 1
            cur.kill_shard(i)
            cur.revive_shard(i)
        return None

    streams, _ = await run_traffic(fg, sids, rounds,
                                   traffic_seed=traffic_seed,
                                   on_round=on_round)
    summary = fg.summary()
    info = {s: fg.study_info(s) for s in sids}
    await fg.aclose()
    return streams, summary, info


def test_soak_determinism_short():
    """Tier-1 mini-soak: eviction churn + two mid-stream restores vs an
    uninterrupted all-resident gateway."""
    async def main(d_a, d_b):
        ref, _ = await _soak(d_a, slots=5, n_studies=5, rounds=18,
                             n_max=24)
        churn, ticks = await _soak(d_b, slots=2, n_studies=5, rounds=18,
                                   n_max=24, restart_every=7)
        assert ticks >= 30
        assert_streams_identical(ref, churn)
    with tempfile.TemporaryDirectory() as d_a, \
            tempfile.TemporaryDirectory() as d_b:
        asyncio.run(main(d_a, d_b))


def test_fed_soak_equals_single_pool_short():
    """Tier-1 federation mini-soak: 2 shards with eviction churn, a shard
    killed+revived twice, and periodic forced migrations serve every study
    the SAME suggestion stream as one uninterrupted all-resident pool."""
    async def main(d_a, d_b):
        ref, _ = await _soak(d_a, slots=6, n_studies=6, rounds=12,
                             n_max=24, traffic_seed=11)
        fed, summary, info = await _fed_soak(
            d_b, n_shards=2, slots=2, n_studies=6, rounds=12, n_max=24,
            kill_every=5, migrate_every=3, traffic_seed=11)
        assert_streams_identical(ref, fed)
        # the churn actually happened: evictions, migrations (restores on
        # the destination shard), and two kill/revive cycles
        assert summary["evictions"] >= 1
        assert summary["epoch"] >= 2
        # final per-study state matches the reference ledgers
        for s, i in info.items():
            assert i["n_obs"] == len(ref[s])
    with tempfile.TemporaryDirectory() as d_a, \
            tempfile.TemporaryDirectory() as d_b:
        asyncio.run(main(d_a, d_b))


@pytest.mark.slow
@pytest.mark.skipif(not os.environ.get("REPRO_SOAK"),
                    reason="500+-tick soak; set REPRO_SOAK=1 (dedicated CI "
                           "job) to run")
def test_soak_determinism_500_ticks():
    """The full soak: 500+ gateway ticks of random traffic over 6 logical
    studies on 3 slots, restored from checkpoint every 40 rounds, bitwise-
    identical to the uninterrupted all-resident run."""
    async def main(d_a, d_b):
        ref, _ = await _soak(d_a, slots=6, n_studies=6, rounds=260,
                             n_max=220)
        churn, ticks = await _soak(d_b, slots=3, n_studies=6, rounds=260,
                                   n_max=220, restart_every=40)
        assert ticks >= 500, f"soak only reached {ticks} ticks"
        assert_streams_identical(ref, churn)
    with tempfile.TemporaryDirectory() as d_a, \
            tempfile.TemporaryDirectory() as d_b:
        asyncio.run(main(d_a, d_b))


@pytest.mark.slow
@pytest.mark.skipif(not os.environ.get("REPRO_SOAK"),
                    reason="500+-tick soak; set REPRO_SOAK=1 (dedicated CI "
                           "job) to run")
def test_fed_soak_500_ticks():
    """The full federation soak: 500+ ticks of random traffic over 8
    studies on 2 shards x 2 slots (heavy eviction churn), a shard killed
    and revived every 25 rounds, a forced migration every 10 — final
    streams and ledgers equal to an uninterrupted single-pool run."""
    async def main(d_a, d_b):
        ref, _ = await _soak(d_a, slots=8, n_studies=8, rounds=220,
                             n_max=220, traffic_seed=13)
        fed, summary, info = await _fed_soak(
            d_b, n_shards=2, slots=2, n_studies=8, rounds=220, n_max=220,
            kill_every=25, migrate_every=10, traffic_seed=13)
        assert summary["ticks"] >= 500, \
            f"soak only reached {summary['ticks']} ticks"
        assert_streams_identical(ref, fed)
        for s, i in info.items():
            assert i["n_obs"] == len(ref[s])
    with tempfile.TemporaryDirectory() as d_a, \
            tempfile.TemporaryDirectory() as d_b:
        asyncio.run(main(d_a, d_b))
