"""StudyGateway tests: coalescing ticks, admission control, slot lifecycle
(LRU eviction + restore-on-demand, exactness), and gateway checkpointing
(DESIGN.md §9)."""
import asyncio
import tempfile

import numpy as np
import pytest

from repro import checkpoint as ckpt_mod
from repro.core import GPCapacityError
from repro.core.acquisition import AcqConfig
from repro.hpo import GatewayConfig, SchedulerConfig, StudyGateway
from repro.hpo.space import LENET_SPACE, RESNET_SPACE


def _cfg(d, n_max=16, **kw):
    kw.setdefault("acq", AcqConfig(restarts=8, ascent_steps=4))
    kw.setdefault("ckpt_every", 10_000)   # cadence off unless a test wants it
    return SchedulerConfig(n_max=n_max, seed=0, ckpt_dir=d, **kw)


def obj(sid, unit):
    c = 0.2 + 0.12 * (sid % 5)
    return float(-np.sum((np.asarray(unit) - c) ** 2))


async def _loop(gw, sid, rounds, out=None):
    for _ in range(rounds):
        tr = await gw.ask(sid)
        if out is not None:
            out.append(np.asarray(tr.unit).copy())
        gw.tell(sid, tr, obj(sid, tr.unit))
    await gw.drain()


def test_gateway_requires_ckpt_dir():
    with pytest.raises(ValueError, match="ckpt_dir"):
        StudyGateway(RESNET_SPACE, SchedulerConfig(n_max=8, ckpt_dir=None))


def test_concurrent_asks_coalesce_into_one_tick():
    """N clients asking at once must be served by ONE fused dispatch."""
    async def main(d):
        gw = StudyGateway(RESNET_SPACE, _cfg(d), GatewayConfig(slots=6))
        sids = [gw.create_study() for _ in range(6)]
        trials = await asyncio.gather(*(gw.ask(s) for s in sids))
        assert len({id(t) for t in trials}) == 6
        assert gw.summary()["ticks"] == 1
        assert gw.stats[-1]["width"] == 6
        for s, tr in zip(sids, trials):
            gw.tell(s, tr, obj(s, tr.unit))
        await gw.drain()
        # the tells coalesced too: one absorb round
        assert gw.summary()["ticks"] == 2
        assert gw.stats[-1]["absorbed"] == 6
        await gw.aclose()
    with tempfile.TemporaryDirectory() as d:
        asyncio.run(main(d))


def test_coalesce_window_gathers_staggered_asks():
    async def main(d):
        gw = StudyGateway(RESNET_SPACE, _cfg(d),
                          GatewayConfig(slots=2, coalesce_ms=150))
        a, b = gw.create_study(), gw.create_study()

        async def late_ask(sid):
            await asyncio.sleep(0.01)
            return await gw.ask(sid)

        t1, t2 = await asyncio.gather(gw.ask(a), late_ask(b))
        assert gw.summary()["ticks"] == 1     # both landed in one window
        gw.tell(a, t1, 0.1)
        gw.tell(b, t2, 0.2)
        await gw.drain()
        await gw.aclose()
    with tempfile.TemporaryDirectory() as d:
        asyncio.run(main(d))


def test_max_batch_caps_tick_width():
    with tempfile.TemporaryDirectory() as d:
        gw = StudyGateway(RESNET_SPACE, _cfg(d),
                          GatewayConfig(slots=4, max_batch=2))
        sids = [gw.create_study() for _ in range(4)]
        for s in sids:
            gw.ask_nowait(s)
        assert gw.tick() == 2 and gw.stats[-1]["width"] == 2
        assert gw.tick() == 2
        assert gw.tick() == 0


def test_one_ask_per_study_per_tick():
    """A second queued ask for the same study waits for the next round."""
    with tempfile.TemporaryDirectory() as d:
        gw = StudyGateway(RESNET_SPACE, _cfg(d),
                          GatewayConfig(slots=2, max_inflight=4))
        s = gw.create_study()
        gw.ask_nowait(s)
        gw.ask_nowait(s)
        assert gw.tick() == 1
        assert gw.tick() == 1


def test_admission_rejects_inflight_and_queue_overflow():
    async def main(d):
        gw = StudyGateway(RESNET_SPACE, _cfg(d),
                          GatewayConfig(slots=2, max_inflight=2, max_queue=3))
        s = gw.create_study()
        t1 = await gw.ask(s)
        t2 = await gw.ask(s)
        with pytest.raises(GPCapacityError, match="in flight"):
            await gw.ask(s)
        gw.tell(s, t1, 0.1)
        gw.tell(s, t2, 0.2)
        await gw.drain()
        await gw.aclose()
        # queue bound (sync path; ticker never runs)
        gw2 = StudyGateway(RESNET_SPACE, _cfg(d + "/q"),
                           GatewayConfig(slots=2, max_queue=3,
                                         max_inflight=8))
        q = gw2.create_study()
        for _ in range(3):
            gw2.ask_nowait(q)
        with pytest.raises(GPCapacityError, match="queue full"):
            gw2.ask_nowait(q)
    with tempfile.TemporaryDirectory() as d:
        asyncio.run(main(d))


def test_capacity_aware_ask_reject_before_training():
    """An ask whose eventual tell cannot fit n_max is refused up front."""
    with tempfile.TemporaryDirectory() as d:
        gw = StudyGateway(RESNET_SPACE, _cfg(d, n_max=3),
                          GatewayConfig(slots=1, max_inflight=8,
                                        escalate=False))
        s = gw.create_study()
        for _ in range(3):
            gw.ask_nowait(s)
            gw.tick()
        # 3 suggestions out == n_max committed: a 4th can never be absorbed
        with pytest.raises(GPCapacityError, match="n_max"):
            gw.ask_nowait(s)


def test_eviction_restore_is_exact_bitwise():
    """THE serving-layer contract: a study evicted to its partial snapshot
    and restored on demand produces bitwise-identical suggestions to the
    same study in a gateway with enough slots to never evict."""
    async def probe(d, slots):
        gw = StudyGateway(RESNET_SPACE, _cfg(d), GatewayConfig(slots=slots))
        sids = [gw.create_study(name=f"t{i}") for i in range(3)]
        out = []
        for _ in range(5):
            tr = await gw.ask(sids[0])
            out.append(np.asarray(tr.unit).copy())
            gw.tell(sids[0], tr, obj(0, tr.unit))
            await gw.drain()
            for s in sids[1:]:    # churn: forces sids[0] out when slots=2
                tr2 = await gw.ask(s)
                gw.tell(s, tr2, obj(s, tr2.unit))
                await gw.drain()
        log = gw._studies[sids[0]]
        await gw.aclose()
        return out, log

    async def main(d1, d2):
        resident, log_a = await probe(d1, slots=3)
        churned, log_b = await probe(d2, slots=2)
        assert not log_a.evicted_ever
        assert log_b.evicted_ever and log_b.version >= 2
        for k, (x, y) in enumerate(zip(resident, churned)):
            assert np.array_equal(x, y), \
                f"suggestion {k} diverged after eviction/restore"
    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2:
        asyncio.run(main(d1, d2))


def test_more_logical_studies_than_slots():
    """The pool serves S_logical > slots via LRU eviction; every study
    makes progress and eviction traffic shows up in the telemetry."""
    async def main(d):
        gw = StudyGateway(RESNET_SPACE, _cfg(d), GatewayConfig(slots=2))
        sids = [gw.create_study() for _ in range(5)]
        await asyncio.gather(*(_loop(gw, s, 3) for s in sids))
        for s in sids:
            assert gw._studies[s].n_obs == 3
        assert gw.summary()["evictions"] >= 3
        # best_value is residency-independent: evicted tenants keep theirs
        for s in sids:
            assert gw.study_info(s)["best_value"] is not None
        # an evicted study transparently restores on its next ask
        evicted = next(s for s in sids if gw._studies[s].slot is None
                       and gw._studies[s].evicted_ever)
        await _loop(gw, evicted, 1)
        assert gw.summary()["restores"] >= 1
        assert gw._studies[evicted].n_obs == 4
        await gw.aclose()
    with tempfile.TemporaryDirectory() as d:
        asyncio.run(main(d))


def test_asks_defer_when_all_slots_pinned():
    """Asks beyond the slot count wait (backpressure), not fail: they are
    served as soon as a tell frees a study."""
    async def main(d):
        gw = StudyGateway(RESNET_SPACE, _cfg(d), GatewayConfig(slots=2))
        a, b, c = (gw.create_study() for _ in range(3))
        ta = await gw.ask(a)
        tb = await gw.ask(b)
        # both slots pinned by in-flight work: c's ask must defer
        ask_c = asyncio.ensure_future(gw.ask(c))
        await asyncio.sleep(0.05)
        assert not ask_c.done()
        gw.tell(a, ta, 0.5)             # frees study a at the next tick
        tc = await asyncio.wait_for(ask_c, timeout=30)
        assert tc is not None
        gw.tell(b, tb, 0.1)
        gw.tell(c, tc, 0.2)
        await gw.drain()
        await gw.aclose()
    with tempfile.TemporaryDirectory() as d:
        asyncio.run(main(d))


def test_tell_failure_without_penalty_unblocks_deferred_ask():
    """tell_failure with failure_penalty=None (the default) frees the
    study's in-flight budget; a deferred ask waiting on that study must be
    re-woken (regression: the wake was only set on the penalty path, so
    the ticker parked forever and the deferred ask hung)."""
    async def main(d):
        gw = StudyGateway(RESNET_SPACE, _cfg(d), GatewayConfig(slots=1))
        a, b = gw.create_study(), gw.create_study()
        ta = await gw.ask(a)
        ask_b = asyncio.ensure_future(gw.ask(b))
        await asyncio.sleep(0.05)
        assert not ask_b.done()      # a's in-flight work pins the only slot
        gw.tell_failure(a, ta, "node lost")   # no penalty tell is queued
        tb = await asyncio.wait_for(ask_b, timeout=30)
        gw.tell(b, tb, 0.1)
        await gw.drain()
        await gw.aclose()
    with tempfile.TemporaryDirectory() as d:
        asyncio.run(main(d))


def test_cancelled_ask_does_not_leak_inflight():
    """A client that cancels its ask before delivery must not pin the
    study: the drawn suggestion is abandoned (ledger-marked failed), not
    counted in flight — a leak would eat max_inflight and make the study
    permanently non-evictable."""
    async def main(d):
        gw = StudyGateway(RESNET_SPACE, _cfg(d),
                          GatewayConfig(slots=2, max_inflight=1))
        s = gw.create_study()
        task = asyncio.ensure_future(gw.ask(s))
        await asyncio.sleep(0)       # ask enqueued; the tick has not fired
        task.cancel()
        with pytest.raises(asyncio.CancelledError):
            await task
        await gw.drain()
        log = gw._studies[s]
        assert log.inflight == 0 and log.pending_asks == 0
        # the max_inflight=1 budget is intact: a fresh ask is admitted
        tr = await asyncio.wait_for(gw.ask(s), timeout=30)
        gw.tell(s, tr, 0.2)
        await gw.drain()
        assert log.n_obs == 1
        await gw.aclose()
    with tempfile.TemporaryDirectory() as d:
        asyncio.run(main(d))


def test_tell_rejects_nonfinite_and_replayed_results():
    """Bad tells fail at the caller, never inside the fused round: NaN
    values (a poisoned posterior would silently stop optimizing) and
    replays of an already-resolved trial (the duplicate row would eat
    n_max budget and double-weight the point)."""
    async def main(d):
        gw = StudyGateway(RESNET_SPACE, _cfg(d), GatewayConfig(slots=1))
        s = gw.create_study()
        tr = await gw.ask(s)
        with pytest.raises(ValueError, match="non-finite"):
            gw.tell(s, tr, float("nan"))
        gw.tell(s, tr, 0.3)
        with pytest.raises(RuntimeError, match="one tell"):
            gw.tell(s, tr, 0.3)          # same-window replay
        await gw.drain()
        with pytest.raises(RuntimeError, match="one tell"):
            gw.tell(s, tr, 0.3)          # replay after absorption
        assert gw._studies[s].n_obs == 1
        await gw.aclose()
    with tempfile.TemporaryDirectory() as d:
        asyncio.run(main(d))


def test_restore_cancels_parked_asks():
    """restore() discards in-flight work; clients parked on pre-restore
    asks must be cancelled, not left awaiting futures nobody will ever
    resolve."""
    async def main(d):
        gw = StudyGateway(RESNET_SPACE, _cfg(d), GatewayConfig(slots=1))
        a, b = gw.create_study(), gw.create_study()
        ta = await gw.ask(a)
        gw.tell(a, ta, 0.1)
        await gw.drain()
        gw.checkpoint()
        ta2 = await gw.ask(a)            # pins the only slot again
        ask_b = asyncio.ensure_future(gw.ask(b))
        await asyncio.sleep(0.05)
        assert not ask_b.done()          # parked, deferred
        assert gw.restore()
        with pytest.raises(asyncio.CancelledError):
            await asyncio.wait_for(ask_b, timeout=10)
        assert ta2 is not None
        await gw.aclose()
    with tempfile.TemporaryDirectory() as d:
        asyncio.run(main(d))


def test_close_study_frees_slot_and_refuses_inflight():
    async def main(d):
        gw = StudyGateway(RESNET_SPACE, _cfg(d), GatewayConfig(slots=2))
        a, b = gw.create_study(), gw.create_study()
        tr = await gw.ask(a)
        with pytest.raises(RuntimeError, match="in flight"):
            gw.close_study(a)
        gw.tell(a, tr, 0.3)
        await gw.drain()
        gw.close_study(a)
        with pytest.raises(RuntimeError, match="closed"):
            await gw.ask(a)
        # the freed slot serves a new tenant
        tr_b = await gw.ask(b)
        gw.tell(b, tr_b, 0.1)
        await gw.drain()
        await gw.aclose()
    with tempfile.TemporaryDirectory() as d:
        asyncio.run(main(d))


def test_closed_studies_leave_registry_and_store():
    """Tenant churn must not grow the registry or the eviction store:
    close_study tombstones the id, drops the record, and the next
    checkpoint COMMIT deletes its snapshot dirs (never before — a crash
    must restore a registry whose studies are all on disk).  Lifetime
    telemetry totals ride the registry across restores."""
    async def main(d):
        gw = StudyGateway(RESNET_SPACE, _cfg(d), GatewayConfig(slots=1))
        a, b = gw.create_study(), gw.create_study()
        await _loop(gw, a, 1)
        await _loop(gw, b, 1)           # evicts a to the store
        assert ckpt_mod.list_studies(d)
        gw.close_study(a)
        assert ckpt_mod.list_studies(d)  # snapshots survive until commit
        gw.checkpoint()
        assert not ckpt_mod.list_studies(d)
        await gw.aclose()

        gw2 = StudyGateway(RESNET_SPACE, _cfg(d), GatewayConfig(slots=1))
        assert gw2.restore()
        assert gw2.study_ids() == [b]
        with pytest.raises(RuntimeError, match="closed"):
            await gw2.ask(a)
        s = gw2.summary()
        assert s["ticks"] > 0 and s["asks_served"] == 2  # lifetime totals
        await gw2.aclose()
    with tempfile.TemporaryDirectory() as d:
        asyncio.run(main(d))


def test_mismatched_space_dim_rejected():
    with tempfile.TemporaryDirectory() as d:
        gw = StudyGateway(RESNET_SPACE, _cfg(d))
        with pytest.raises(ValueError, match="dim"):
            gw.create_study(space=LENET_SPACE)


def test_create_study_default_space_survives_slot_churn():
    """create_study()'s default is the constructor template, NOT whatever
    tenant currently occupies slot 0 (regression: a custom-space tenant in
    slot 0 leaked its bounds into later default-space studies)."""
    from repro.hpo.space import Dim, SearchSpace
    custom = SearchSpace((Dim("a", 5.0, 9.0), Dim("b", 5.0, 9.0),
                          Dim("c", 5.0, 9.0)))
    with tempfile.TemporaryDirectory() as d:
        gw = StudyGateway(RESNET_SPACE, _cfg(d), GatewayConfig(slots=1))
        s0 = gw.create_study(space=custom)
        gw.ask_nowait(s0)
        gw.tick()                    # the custom tenant now owns slot 0
        assert gw._studies[s0].slot == 0
        s1 = gw.create_study()
        assert gw._studies[s1].space is RESNET_SPACE


def test_restore_reapplies_custom_space_to_resident_slots():
    """The pool snapshot carries no spaces; gateway.restore() must push
    each logical study's own space back onto its resident slot (regression:
    restored resident studies mapped suggestions through the constructor's
    template bounds)."""
    from repro.hpo.space import Dim, SearchSpace
    custom = SearchSpace((Dim("c0", 100.0, 200.0), Dim("c1", 100.0, 200.0),
                          Dim("c2", 100.0, 200.0)))

    async def main(d):
        gw = StudyGateway(RESNET_SPACE, _cfg(d), GatewayConfig(slots=2))
        s = gw.create_study(space=custom)
        tr = await gw.ask(s)
        gw.tell(s, tr, 0.1)
        await gw.drain()
        gw.checkpoint()
        await gw.aclose()

        gw2 = StudyGateway(RESNET_SPACE, _cfg(d), GatewayConfig(slots=2))
        assert gw2.restore()
        assert gw2._studies[s].slot is not None     # restored resident
        tr2 = await gw2.ask(s)
        assert set(tr2.hparams) == {"c0", "c1", "c2"}
        assert all(100.0 <= v <= 200.0 for v in tr2.hparams.values())
        gw2.tell(s, tr2, 0.2)
        await gw2.drain()
        await gw2.aclose()
    with tempfile.TemporaryDirectory() as d:
        asyncio.run(main(d))


def test_gateway_checkpoint_restore_roundtrip():
    """A restored gateway resumes registry, slot map, ledgers, and PRNG
    streams; subsequent suggestions match an uninterrupted gateway."""
    async def main(d_a, d_b):
        streams = {}
        for key, dd, interrupt in (("a", d_a, False), ("b", d_b, True)):
            gw = StudyGateway(RESNET_SPACE, _cfg(dd), GatewayConfig(slots=2))
            sids = [gw.create_study(name=f"t{i}") for i in range(3)]
            out = {s: [] for s in sids}
            for s in sids:
                await _loop(gw, s, 2, out[s])
            if interrupt:
                gw.checkpoint()
                await gw.aclose()
                gw = StudyGateway(RESNET_SPACE, _cfg(dd),
                                  GatewayConfig(slots=2))
                assert gw.restore()
                for s in sids:
                    assert gw._studies[s].n_obs == 2
            for s in sids:
                await _loop(gw, s, 2, out[s])
            await gw.aclose()
            streams[key] = out
        for s in streams["a"]:
            for k, (x, y) in enumerate(zip(streams["a"][s],
                                           streams["b"][s])):
                assert np.array_equal(x, y), \
                    f"study {s} suggestion {k} diverged across restore"
    with tempfile.TemporaryDirectory() as d_a, \
            tempfile.TemporaryDirectory() as d_b:
        asyncio.run(main(d_a, d_b))


def test_summary_counts_are_lifetime_not_windowed():
    """asks_served/absorbed/evictions/restores are run totals; only the
    latency/width distributions roll over with the stats window."""
    with tempfile.TemporaryDirectory() as d:
        gw = StudyGateway(RESNET_SPACE, _cfg(d),
                          GatewayConfig(slots=2, stats_window=2))
        s = gw.create_study()
        for _ in range(4):
            gw.ask_nowait(s)
            gw.tick()
        assert len(gw.stats) == 2            # window capped
        assert gw.summary()["asks_served"] == 4   # lifetime total


def test_telemetry_summary_fields():
    async def main(d):
        gw = StudyGateway(RESNET_SPACE, _cfg(d), GatewayConfig(slots=3))
        # zero-traffic summary carries the full key set (consumers index
        # these unconditionally)
        empty = gw.summary()
        assert empty["ticks"] == 0 and empty["asks_served"] == 0
        assert empty["mean_coalesce_width"] == 0.0
        sids = [gw.create_study() for _ in range(3)]
        await asyncio.gather(*(_loop(gw, s, 2) for s in sids))
        s = gw.summary()
        assert s["asks_served"] == 6 and s["absorbed"] == 6
        assert s["mean_coalesce_width"] >= 1.0
        assert s["p50_tick_ms"] > 0 and s["p95_tick_ms"] >= s["p50_tick_ms"]
        assert gw.study_ids() == sids
        info = gw.study_info(sids[0])
        assert info["n_obs"] == 2 and info["resident"]
        assert info["best_value"] is not None
        with pytest.raises(KeyError):
            gw.study_info(999)
        await gw.aclose()
    with tempfile.TemporaryDirectory() as d:
        asyncio.run(main(d))


# ---------------------------------------------------------------------------
# Batched q-suggestion serving (DESIGN.md §12)
# ---------------------------------------------------------------------------
def test_ask_q_serves_batch_coalesced_with_singles():
    """One ask(q=4) returns 4 distinct suggestions, served on the SAME tick
    as the other tenants' q=1 asks; q widths land in the telemetry."""
    async def main(d):
        gw = StudyGateway(RESNET_SPACE, _cfg(d, n_max=32),
                          GatewayConfig(slots=3, max_inflight=8))
        a, b, c = (gw.create_study() for _ in range(3))
        # seed tenant a so its q-ask runs the fantasy path, not random seeds
        tr = await gw.ask(a)
        gw.tell(a, tr, obj(a, tr.unit))
        await gw.drain()
        t0 = gw.summary()["ticks"]
        batch, tb, tc = await asyncio.gather(
            gw.ask(a, q=4), gw.ask(b), gw.ask(c))
        assert gw.summary()["ticks"] == t0 + 1   # one coalesced tick
        assert isinstance(batch, list) and len(batch) == 4
        units = {np.asarray(t.unit).tobytes() for t in batch}
        assert len(units) == 4                   # jointly diverse
        assert gw.stats[-1]["width"] == 3        # 3 asks...
        assert gw.stats[-1]["suggestions"] == 6  # ...6 suggestions
        assert gw._studies[a].inflight == 4
        assert gw.study_info(a)["fantasy_active"] == 4
        for tr in batch:
            gw.tell(a, tr, obj(a, tr.unit))
        gw.tell(b, tb, obj(b, tb.unit))
        gw.tell(c, tc, obj(c, tc.unit))
        await gw.drain()
        assert gw.summary()["fantasy_active"] == 0
        assert gw.summary()["q_width_hist"] == {"1": 3, "4": 1}
        assert gw.study_info(a)["n_obs"] == 5
        await gw.aclose()
    with tempfile.TemporaryDirectory() as d:
        asyncio.run(main(d))


def test_ask_q_admission_rejections():
    """q-aware admission: q > max_inflight is unservable (clear error, not
    a hang), inflight + q over the cap rejects, and committed + q beyond
    n_max rejects — all BEFORE any fantasy row is appended."""
    async def main(d):
        gw = StudyGateway(RESNET_SPACE, _cfg(d, n_max=8),
                          GatewayConfig(slots=1, max_inflight=4,
                                        escalate=False))
        sid = gw.create_study()
        with pytest.raises(GPCapacityError, match="max_inflight"):
            await gw.ask(sid, q=5)     # unservable at any future time
        with pytest.raises(ValueError, match="q"):
            await gw.ask(sid, q=0)
        batch = await gw.ask(sid, q=3)
        with pytest.raises(GPCapacityError, match="in flight"):
            await gw.ask(sid, q=2)     # 3 inflight + 2 > 4
        for tr in batch:
            gw.tell(sid, tr, obj(sid, tr.unit))
        await gw.drain()
        tr = await gw.ask(sid, q=4)    # 3 committed + 4 <= 8: fine
        for t in tr:
            gw.tell(sid, t, obj(sid, t.unit))
        await gw.drain()
        with pytest.raises(GPCapacityError, match="n_max"):
            await gw.ask(sid, q=2)     # 7 committed + 2 > 8
        one = await gw.ask(sid)        # the last row still serves q=1
        gw.tell(sid, one, obj(sid, one.unit))
        await gw.drain()
        await gw.aclose()
    with tempfile.TemporaryDirectory() as d:
        asyncio.run(main(d))


def test_q_telemetry_persists_across_checkpoint_restore():
    """`q_width_hist` and `fantasy_rollbacks` are lifetime totals: they ride
    the checkpoint registry and keep counting after a restore."""
    async def main(d):
        gw = StudyGateway(RESNET_SPACE, _cfg(d, n_max=32),
                          GatewayConfig(slots=1, max_inflight=8))
        sid = gw.create_study()
        tr = await gw.ask(sid)
        gw.tell(sid, tr, obj(sid, tr.unit))
        await gw.drain()
        for tr in await gw.ask(sid, q=2):
            gw.tell(sid, tr, obj(sid, tr.unit))
        await gw.drain()
        s1 = gw.summary()
        assert s1["q_width_hist"] == {"1": 1, "2": 1}
        assert s1["fantasy_rollbacks"] >= 1
        gw.checkpoint()
        await gw.aclose()

        gw2 = StudyGateway(RESNET_SPACE, _cfg(d, n_max=32),
                           GatewayConfig(slots=1, max_inflight=8))
        assert gw2.restore()
        s2 = gw2.summary()
        assert s2["q_width_hist"] == s1["q_width_hist"]
        assert s2["fantasy_rollbacks"] == s1["fantasy_rollbacks"]
        # counters keep accumulating, not reset-and-overwrite
        for tr in await gw2.ask(sid, q=2):
            gw2.tell(sid, tr, obj(sid, tr.unit))
        await gw2.drain()
        s3 = gw2.summary()
        assert s3["q_width_hist"]["2"] == 2
        assert s3["fantasy_rollbacks"] > s2["fantasy_rollbacks"]
        await gw2.aclose()
    with tempfile.TemporaryDirectory() as d:
        asyncio.run(main(d))


# ---------------------------------------------------------------------------
# Pipelined ticks: on/off bitwise equivalence + in-flight faults (§13)
# ---------------------------------------------------------------------------
def _enq(gw, loop, sid, q=1):
    """White-box ask enqueue (no ticker): the returned future resolves when
    a manual tick_begin/tick_flush finishes the tick that served it."""
    fut = loop.create_future()
    gw._studies[sid].pending_asks += q
    gw._asks.append((sid, fut, q))
    return fut


async def _scripted_run(d, pipelined, rounds=10):
    """One deterministic TRACE — rotating 2-study ask subsets over 4
    studies on 2 slots (eviction churn every round), a q=3 fantasy batch
    every third round — driven by tick_begin() when pipelined, plain
    tick() otherwise.  The trace is fixed by ENQUEUE round, not by future
    resolution time: a trial asked at round r is told at the start of
    round r+2 in BOTH modes (pipelined futures resolve one round later
    than serial ones; scheduling tells off resolution time would change
    the event order itself, which no scheduler can be expected to hide)."""
    gw = StudyGateway(RESNET_SPACE, _cfg(d, n_max=48),
                      GatewayConfig(slots=2, max_inflight=8))
    sids = [gw.create_study() for _ in range(4)]
    loop = asyncio.get_running_loop()
    streams = {s: [] for s in sids}
    inflight = []                     # (enqueue_round, sid, future)
    to_tell = []                      # (ready_round, sid, trial)
    step = gw.tick_begin if pipelined else gw.tick

    def collect():
        for item in inflight[:]:
            r0, s, f = item
            if f.done():
                res = f.result()
                for tr in (res if isinstance(res, list) else [res]):
                    streams[s].append(tuple(np.asarray(tr.unit).tolist()))
                    to_tell.append((r0 + 2, s, tr))
                inflight.remove(item)

    overlapped = False
    for r in range(rounds):
        for item in [x for x in to_tell if x[0] <= r]:
            _, s, tr = item
            gw.tell(s, tr, obj(s, tr.unit))
            to_tell.remove(item)
        # two studies per round (never three: a deferral would shift the
        # resolution round); the q-batch rides the first study's ask
        a1, a2 = sids[r % 4], sids[(r + 1) % 4]
        inflight.append((r, a1, _enq(gw, loop, a1, q=3 if r % 3 == 2 else 1)))
        inflight.append((r, a2, _enq(gw, loop, a2)))
        step()
        overlapped = overlapped or gw._pending is not None
        collect()
    # land the tail: flush the staged tick, then serial ticks until the
    # last tell absorbs (both modes converge on the same serial sequence)
    gw.tick_flush()
    while True:
        collect()
        for _rr, s, tr in to_tell:
            gw.tell(s, tr, obj(s, tr.unit))
        to_tell = []
        if not (inflight or gw._tells or gw._asks
                or gw._pending is not None):
            break
        gw.tick()
    assert overlapped == pipelined, \
        "pipelined run never actually overlapped ticks"
    reg = {s: (gw._studies[s].n_obs, gw._studies[s].version,
               gw._studies[s].best_value, gw._studies[s].slot is not None)
           for s in sids}
    from _traffic import slot_bytes
    resident = {s: slot_bytes(gw.pool, gw._studies[s].slot)
                for s in sids if gw._studies[s].slot is not None}
    summary = gw.summary()
    await gw.aclose()
    return streams, reg, resident, summary


def test_pipelined_ticks_bitwise_equal_serial_ticks():
    """Tick pipelining is a SCHEDULING change only: the same scripted
    traffic (eviction churn every round, q=3 fantasy batches outstanding
    across the overlap boundary, tells landing mid-flight) produces
    bitwise-identical suggestion streams, registries, and resident GP
    state with tick_begin/tick_flush as with plain serial tick()."""
    async def main(d1, d2):
        on = await _scripted_run(d1, pipelined=True)
        off = await _scripted_run(d2, pipelined=False)
        assert on[0] == off[0], "suggestion streams diverged"
        assert on[1] == off[1], "study registries diverged"
        assert on[2].keys() == off[2].keys()
        for s in on[2]:
            for leaf in on[2][s]:
                assert on[2][s][leaf] == off[2][s][leaf], \
                    f"study {s} leaf {leaf} differs pipelined vs serial"
        for k in ("ticks", "asks_served", "absorbed", "evictions",
                  "restores", "fantasy_rollbacks", "q_width_hist"):
            assert on[3][k] == off[3][k], f"summary[{k}] diverged"
    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2:
        asyncio.run(main(d1, d2))


def test_async_ticker_pipeline_on_off_identical_streams():
    """The asyncio ticker path: the same concurrent client traffic under
    GatewayConfig(pipeline=True) and pipeline=False serves bitwise-equal
    suggestion streams and absorbs the same telemetry."""
    async def run(d, pipeline):
        gw = StudyGateway(RESNET_SPACE, _cfg(d, n_max=24),
                          GatewayConfig(slots=2, pipeline=pipeline))
        sids = [gw.create_study() for _ in range(3)]
        outs = {s: [] for s in sids}
        for _ in range(3):
            await asyncio.gather(*(_loop(gw, s, 2, outs[s]) for s in sids))
        summary = gw.summary()
        await gw.aclose()
        return outs, summary

    async def main(d1, d2):
        on, s_on = await run(d1, True)
        off, s_off = await run(d2, False)
        assert set(on) == set(off)
        for s in on:
            assert len(on[s]) == len(off[s]) == 6
            for x, y in zip(on[s], off[s]):
                np.testing.assert_array_equal(x, y)
        assert s_on["absorbed"] == s_off["absorbed"]
        assert s_on["asks_served"] == s_off["asks_served"]
    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2:
        asyncio.run(main(d1, d2))


def test_pipelined_inflight_fault_fails_exactly_that_ticks_futures(
        monkeypatch):
    """A device fault surfacing when the IN-FLIGHT tick materializes must
    fail exactly that tick's futures: the next tick — already staged —
    stays staged and serves once the fault clears."""
    import repro.hpo.pool as pool_mod

    async def main(d):
        gw = StudyGateway(RESNET_SPACE, _cfg(d, n_max=24),
                          GatewayConfig(slots=2))
        a, b = gw.create_study(), gw.create_study()
        loop = asyncio.get_running_loop()
        for s in (a, b):              # both resident: no residency hazard
            f = _enq(gw, loop, s)
            gw.tick()
            tr = f.result()
            gw.tell(s, tr, obj(s, tr.unit))
        gw.tick()

        fa = _enq(gw, loop, a)
        assert gw.tick_begin() == 1 and gw._pending is not None
        fb = _enq(gw, loop, b)

        def boom(x):
            raise RuntimeError("device fault")
        monkeypatch.setattr(pool_mod, "_materialize", boom)
        # staging B succeeds (dispatch only); finishing A hits the fault
        with pytest.raises(RuntimeError, match="device fault"):
            gw.tick_begin()
        monkeypatch.undo()
        assert fa.done() and isinstance(fa.exception(), RuntimeError), \
            "the in-flight tick's future did not receive the fault"
        assert not fb.done() and gw._pending is not None, \
            "the fault leaked into the staged-but-not-in-flight tick"
        assert gw.tick_flush() == 1   # fault cleared: B lands untouched
        tr = fb.result()
        gw.tell(b, tr, obj(b, tr.unit))
        gw.tick()
        assert gw.study_info(b)["n_obs"] == 2
        assert gw.study_info(a)["n_obs"] == 1   # A's round died with its tick
        await gw.aclose()
    with tempfile.TemporaryDirectory() as d:
        asyncio.run(main(d))
