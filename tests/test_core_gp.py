"""Unit + property tests for the lazy-GP core (the paper's contribution).

Covers: lazy-vs-naive Cholesky equivalence (Alg. 2 vs Alg. 3), the paper's
well-definedness lemma for d, posterior parity with a textbook GP, identity-
padding invariants, EI closed form, lag policy, and batch (parallel) appends.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (GPConfig, KernelParams, append, append_batch,
                        cholesky_naive, dense_posterior, expected_improvement,
                        gram, init_state, log_marginal_likelihood, matern52,
                        neg_levy, levy, posterior, refactor, refit_params,
                        run_bo, levy_bounds)
from repro.core import cholesky as chol
from repro.core import gp as gp_mod
from repro.core.acquisition import AcqConfig, optimize_acquisition


def _seed_state(key, n0, d, n_max, noise2=1e-6):
    xs = jax.random.uniform(key, (n0, d), minval=-2.0, maxval=2.0)
    ys = jnp.sin(xs.sum(-1)) + 0.1 * xs[:, 0]
    cfg = GPConfig(n_max=n_max, dim=d, noise2=noise2)
    st_ = init_state(cfg)
    st_ = dataclasses.replace(
        st_, x_buf=st_.x_buf.at[:n0].set(xs),
        y_buf=st_.y_buf.at[:n0].set(ys), n=jnp.asarray(n0, jnp.int32))
    return refactor(st_, matern52), xs, ys


# ---------------------------------------------------------------------------
# Paper Alg. 2 (naive) vs XLA
# ---------------------------------------------------------------------------
def test_naive_cholesky_matches_xla():
    key = jax.random.PRNGKey(0)
    x = jax.random.uniform(key, (24, 4))
    k = gram(matern52, x, KernelParams.default())
    np.testing.assert_allclose(np.asarray(cholesky_naive(k)),
                               np.asarray(jnp.linalg.cholesky(k)),
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# Lazy append (Alg. 3) == full refactorization, for any append sequence
# ---------------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(n0=st.integers(2, 8), nadd=st.integers(1, 6), d=st.integers(1, 6),
       seed=st.integers(0, 2**16))
def test_lazy_append_equals_full_refactor(n0, nadd, d, seed):
    key = jax.random.PRNGKey(seed)
    st_, _, _ = _seed_state(key, n0, d, n_max=32)
    new_x = jax.random.uniform(jax.random.fold_in(key, 1), (nadd, d),
                               minval=-2.0, maxval=2.0)
    new_y = jnp.cos(new_x.sum(-1))
    lazy = st_
    for i in range(nadd):
        lazy = append(lazy, matern52, new_x[i], new_y[i])
    full = refactor(lazy, matern52)
    np.testing.assert_allclose(np.asarray(lazy.l_buf), np.asarray(full.l_buf),
                               rtol=2e-3, atol=2e-4)
    np.testing.assert_allclose(np.asarray(lazy.alpha), np.asarray(full.alpha),
                               rtol=2e-2, atol=2e-3)


def test_append_batch_equals_sequential():
    key = jax.random.PRNGKey(42)
    st_, _, _ = _seed_state(key, 5, 3, n_max=32)
    xs = jax.random.uniform(jax.random.fold_in(key, 1), (4, 3))
    ys = jnp.tanh(xs.sum(-1))
    seq = st_
    for i in range(4):
        seq = append(seq, matern52, xs[i], ys[i])
    bat = append_batch(st_, matern52, xs, ys)
    np.testing.assert_allclose(np.asarray(seq.l_buf), np.asarray(bat.l_buf),
                               rtol=1e-5, atol=1e-6)
    assert int(bat.n) == 9


# ---------------------------------------------------------------------------
# Paper lemma: d^2 = c - q^T q > 0 for PD K_{n+1}
# ---------------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(n=st.integers(2, 20), d=st.integers(1, 8), seed=st.integers(0, 2**16))
def test_lemma_d_well_defined(n, d, seed):
    key = jax.random.PRNGKey(seed)
    x = jax.random.uniform(key, (n + 1, d), minval=-3.0, maxval=3.0)
    params = KernelParams(sigma2=1.0, rho=1.0, noise2=1e-4)
    k = gram(matern52, x[:n], params)
    l = jnp.linalg.cholesky(k)
    p = matern52(x[:n], x[n:], params)[:, 0]
    c = matern52(x[n:], x[n:], params)[0, 0] + params.noise2
    q = chol.padded_trsv(l, p)
    d2 = float(c - q @ q)
    assert d2 > 0.0  # Sylvester inertia argument, paper Sec. 3.3


# ---------------------------------------------------------------------------
# Posterior parity with the textbook dense GP (paper Alg. 1)
# ---------------------------------------------------------------------------
@settings(max_examples=15, deadline=None)
@given(n0=st.integers(3, 10), nadd=st.integers(0, 5), seed=st.integers(0, 999))
def test_posterior_matches_dense(n0, nadd, seed):
    d = 3
    key = jax.random.PRNGKey(seed)
    st_, xs, ys = _seed_state(key, n0, d, n_max=32)
    extra_x = jax.random.uniform(jax.random.fold_in(key, 9), (nadd, d),
                                 minval=-2.0, maxval=2.0)
    extra_y = jnp.sin(extra_x.sum(-1)) + 0.1 * extra_x[:, 0] if nadd else \
        jnp.zeros((0,))
    for i in range(nadd):
        st_ = append(st_, matern52, extra_x[i], extra_y[i])
    all_x = jnp.concatenate([xs, extra_x]) if nadd else xs
    all_y = jnp.concatenate([ys, extra_y]) if nadd else ys
    xq = jax.random.uniform(jax.random.fold_in(key, 5), (9, d),
                            minval=-2.0, maxval=2.0)
    m1, v1 = posterior(st_, matern52, xq)
    m2, v2 = dense_posterior(all_x, all_y, xq, matern52, st_.params)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m2), rtol=1e-3,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-2,
                               atol=2e-4)


def test_posterior_interpolates_observations():
    key = jax.random.PRNGKey(1)
    st_, xs, ys = _seed_state(key, 8, 2, n_max=16)
    mean, var = posterior(st_, matern52, xs)
    np.testing.assert_allclose(np.asarray(mean), np.asarray(ys), atol=1e-2)
    assert np.all(np.asarray(var) < 1e-3)  # near-zero at observed points


def test_lml_matches_direct():
    key = jax.random.PRNGKey(2)
    st_, xs, ys = _seed_state(key, 10, 2, n_max=16, noise2=1e-4)
    got = float(log_marginal_likelihood(st_))
    # direct: -1/2 r^T K^{-1} r - 1/2 log|K| - n/2 log 2pi
    k = gram(matern52, xs, st_.params)
    r = ys - ys.mean()
    sign, logdet = jnp.linalg.slogdet(k)
    want = float(-0.5 * r @ jnp.linalg.solve(k, r) - 0.5 * logdet
                 - 0.5 * 10 * jnp.log(2 * jnp.pi))
    assert abs(got - want) < 1e-2 * max(1.0, abs(want))


# ---------------------------------------------------------------------------
# Identity-padding invariant (the TPU adaptation of the paper's realloc)
# ---------------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 12), pad=st.integers(1, 20), seed=st.integers(0, 999))
def test_padded_trsv_exact_for_padded_rhs(n, pad, seed):
    key = jax.random.PRNGKey(seed)
    a = jax.random.normal(key, (n, n))
    k = a @ a.T / n + 2 * jnp.eye(n)
    l = jnp.linalg.cholesky(k)
    l_pad = chol.identity_pad_factor(l, n + pad)
    b = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    b_pad = jnp.zeros(n + pad).at[:n].set(b)
    q_pad = chol.padded_trsv(l_pad, b_pad)
    q = chol.padded_trsv(l, b)
    np.testing.assert_allclose(np.asarray(q_pad[:n]), np.asarray(q),
                               rtol=1e-5, atol=1e-6)
    assert np.allclose(np.asarray(q_pad[n:]), 0.0)


# ---------------------------------------------------------------------------
# Kernel properties
# ---------------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(n=st.integers(2, 16), d=st.integers(1, 6), seed=st.integers(0, 999),
       rho=st.floats(0.3, 3.0))
def test_kernel_gram_psd(n, d, seed, rho):
    key = jax.random.PRNGKey(seed)
    x = jax.random.uniform(key, (n, d), minval=-5.0, maxval=5.0)
    params = KernelParams(sigma2=1.0, rho=rho, noise2=1e-5)
    k = gram(matern52, x, params)
    evals = np.linalg.eigvalsh(np.asarray(k, np.float64))
    assert evals.min() > -1e-5
    # symmetry and unit diagonal (+noise)
    np.testing.assert_allclose(np.asarray(k), np.asarray(k.T), atol=1e-6)


# ---------------------------------------------------------------------------
# Expected improvement (paper Eq. 11)
# ---------------------------------------------------------------------------
def test_ei_closed_form_vs_monte_carlo():
    mean, var, fb = jnp.asarray([0.5]), jnp.asarray([0.8]), jnp.asarray(0.3)
    ei = float(expected_improvement(mean, var, fb, xi=0.0)[0])
    key = jax.random.PRNGKey(0)
    samp = mean + jnp.sqrt(var) * jax.random.normal(key, (200000,))
    mc = float(jnp.mean(jnp.maximum(samp - fb, 0.0)))
    assert abs(ei - mc) < 5e-3


@settings(max_examples=30, deadline=None)
@given(mu=st.floats(-3, 3), sig=st.floats(0.01, 3), fb=st.floats(-3, 3))
def test_ei_nonnegative_and_monotone_in_sigma(mu, sig, fb):
    e1 = float(expected_improvement(jnp.asarray([mu]), jnp.asarray([sig**2]),
                                    jnp.asarray(fb), xi=0.0)[0])
    e2 = float(expected_improvement(jnp.asarray([mu]),
                                    jnp.asarray([(sig * 2) ** 2]),
                                    jnp.asarray(fb), xi=0.0)[0])
    assert e1 >= 0.0 and e2 >= e1 - 1e-6  # EI grows with uncertainty


def test_topt_suggestions_are_distinct():
    key = jax.random.PRNGKey(3)
    st_, _, _ = _seed_state(key, 12, 2, n_max=64)
    lo, hi = jnp.full((2,), -5.0), jnp.full((2,), 5.0)
    pts, vals = optimize_acquisition(st_, matern52, lo, hi,
                                     jax.random.PRNGKey(0),
                                     AcqConfig(restarts=64), top_t=4)
    assert pts.shape == (4, 2)
    assert bool(jnp.all(vals[:-1] >= vals[1:] - 1e-6))  # sorted best-first
    d01 = float(jnp.linalg.norm(pts[0] - pts[1]))
    assert d01 > 1e-3  # distinct basins (dedup radius)


# ---------------------------------------------------------------------------
# Lag policy and refit
# ---------------------------------------------------------------------------
def test_refit_improves_or_keeps_lml():
    key = jax.random.PRNGKey(11)
    st_, _, _ = _seed_state(key, 16, 3, n_max=32)
    before = float(log_marginal_likelihood(st_))
    params = refit_params(st_, matern52)
    after = float(log_marginal_likelihood(refactor(st_, matern52, params)))
    assert after >= before - 1e-4


def test_lag_counter_resets_on_refit():
    key = jax.random.PRNGKey(12)
    st_, _, _ = _seed_state(key, 4, 2, n_max=16)
    for i in range(3):
        st_ = append(st_, matern52,
                     jax.random.uniform(jax.random.fold_in(key, i), (2,)),
                     jnp.asarray(0.1))
    assert int(st_.since_refit) == 3
    st_ = gp_mod.maybe_refit(st_, matern52, lag=3)
    assert int(st_.since_refit) == 0


# ---------------------------------------------------------------------------
# End-to-end BO sanity (paper Sec. 4.1 protocol, tiny scale)
# ---------------------------------------------------------------------------
def test_bo_improves_on_levy_2d():
    obj = lambda x: np.asarray(neg_levy(jnp.asarray(x)))
    lo, hi = levy_bounds(2)
    _, hist = run_bo(obj, lo, hi, iterations=20, dim=2, n_max=64, n_seed=5,
                     seed=0)
    assert hist.best_y[-1] > hist.best_y[4]  # improved beyond seeding
    assert hist.best_y[-1] > -2.0


def test_bo_batch_mode_runs_and_counts_evals():
    obj = lambda x: np.asarray(neg_levy(jnp.asarray(x)))
    lo, hi = levy_bounds(2)
    _, hist = run_bo(obj, lo, hi, iterations=4, dim=2, n_max=64, n_seed=2,
                     seed=1, batch_size=5, lag=3)
    assert len(hist.ys) == 2 + 4 * 5


def test_levy_optimum_is_zero_at_ones():
    x_star = jnp.ones((5,))
    assert abs(float(levy(x_star))) < 1e-9
