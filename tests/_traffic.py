"""Shared traffic-generation + twin-comparison helpers for the gateway
fault/soak suites (deduplicated out of tests/test_faults.py and
tests/test_soak.py; the federation suites reuse them unchanged — a
`FederatedGateway` exposes the same ask/tell/drain surface).

Everything here is deterministic: objectives are pure functions of
(sid, unit), traces are seeded, and the comparisons are bitwise — the
suites assert exact equivalence between runs, never approximate.
"""
import asyncio

import numpy as np

from repro.core.acquisition import AcqConfig
from repro.hpo import SchedulerConfig
from repro.hpo.pool import Trial


def make_cfg(d, n_max=16, **kw):
    """Small-budget SchedulerConfig for fast fault/soak tests (the pool's
    own per-absorb snapshot cadence off unless a test asks)."""
    kw.setdefault("acq", AcqConfig(restarts=8, ascent_steps=4))
    kw.setdefault("ckpt_every", 10_000)
    kw.setdefault("seed", 0)
    return SchedulerConfig(n_max=n_max, ckpt_dir=d, **kw)


def objective(sid, unit):
    """Deterministic per-study objective (optimum seeded by sid)."""
    c = 0.15 + 0.7 * ((sid * 0.37) % 1.0)
    return float(-np.sum((np.asarray(unit) - c) ** 2))


def foreign_trial(unit) -> Trial:
    """An observation told out-of-band (never asked) — the injection
    vector for capacity faults the ask-side admission cannot see, and the
    future-less tell used by synchronous tick scripts."""
    return Trial(10_000, np.asarray(unit, np.float32), {})


def slot_bytes(pool, slot: int) -> dict:
    """Every leaf of one slot's GP state as raw bytes — the comparison is
    BITWISE, not approximate: rollback/restore/migration must leave no
    float dust behind."""
    import jax
    st = pool.engine.study_state(slot)
    return {jax.tree_util.keystr(path): np.asarray(leaf).tobytes()
            for path, leaf in jax.tree_util.tree_flatten_with_path(st)[0]}


def assert_slots_equal(pool_a, slot_a, pool_b, slot_b, ctx=""):
    a, b = slot_bytes(pool_a, slot_a), slot_bytes(pool_b, slot_b)
    assert a.keys() == b.keys()
    for leaf in a:
        assert a[leaf] == b[leaf], f"{leaf} differs {ctx}".rstrip()


def assert_streams_identical(a, b):
    """Two {sid: [unit, ...]} suggestion traces must match bitwise."""
    assert set(a) == set(b)
    for s in a:
        assert len(a[s]) == len(b[s]), \
            f"study {s}: {len(a[s])} vs {len(b[s])} suggestions"
        for k, (x, y) in enumerate(zip(a[s], b[s])):
            assert np.array_equal(x, y), \
                f"study {s} suggestion {k} diverged: {x} vs {y}"


async def run_traffic(gw, sids, rounds, *, streams=None, traffic_seed=7,
                      p_ask=0.6, on_round=None):
    """Seeded random ask→tell traffic; returns ({sid: [unit, ...]}, gw).

    Each round a random subset of `sids` asks concurrently (the asks
    coalesce; with fewer slots than studies they churn the LRU), tells
    its objective value back, and the gateway drains.  `on_round(r, gw)`
    — an async hook called after each round's drain — injects restarts,
    shard kills, migrations, or checkpoints; returning a gateway swaps
    the one being driven (restart-style harnesses).  Works for
    StudyGateway and FederatedGateway alike.
    """
    streams = {s: [] for s in sids} if streams is None else streams
    rng = np.random.default_rng(traffic_seed)

    async def one(s):
        # ask→tell per client task: tells free slots for the asks the
        # tick deferred, so an active set wider than the slot count drains
        tr = await gw.ask(s)
        streams[s].append(np.asarray(tr.unit).copy())
        gw.tell(s, tr, objective(s, tr.unit))

    for r in range(rounds):
        active = [s for s in sids if rng.random() < p_ask]
        if active:
            await asyncio.gather(*(one(s) for s in active))
            await gw.drain()
        if on_round is not None:
            swapped = await on_round(r, gw)
            if swapped is not None:
                gw = swapped
    return streams, gw


async def drive_serial(gw, sids, rounds, streams=None):
    """One ask→tell→drain at a time, every study every round — the fully
    serialized trace the kill/restore equivalence tests replay."""
    streams = {s: [] for s in sids} if streams is None else streams
    for _ in range(rounds):
        for s in sids:
            tr = await gw.ask(s)
            streams[s].append(tuple(np.asarray(tr.unit).tolist()))
            gw.tell(s, tr, objective(s, tr.unit))
            await gw.drain()
    return streams


async def drive_serial_rpc(tf, sids, rounds, streams=None):
    """`drive_serial` for a TransportFederation, whose `tell` is a
    coroutine (it crosses a process boundary).  Identical trace, so the
    two drivers feed the bitwise cross-deployment equivalence tests."""
    streams = {s: [] for s in sids} if streams is None else streams
    for _ in range(rounds):
        for s in sids:
            tr = await tf.ask(s)
            streams[s].append(tuple(np.asarray(tr.unit).tolist()))
            await tf.tell(s, tr, objective(s, tr.unit))
            await tf.drain()
    return streams
