"""Cross-process HPO serving: shard workers behind the socket front end.

    python examples/serve_cluster.py [--studies 8] [--shards 2] \
        [--budget 6] [--latency 0.01] [--kill] [--ckpt-dir /tmp/fed]

The ROADMAP's cross-host deployment shape (DESIGN.md §14): a
`TransportFederation` front end spawns one `repro.hpo.shard_worker`
process per shard (one per host in a real cluster, `TransportConfig.connect`
adopts operator-started workers), and every `ask`/`tell` crosses a socket
as length-prefixed JSON frames.  Routing, migration, and epoch recovery
are the same contracts as the in-memory `FederatedGateway` — the shards
just live in other processes, so their fused tick programs stop sharing
one interpreter.

With `--kill` the demo SIGKILLs shard 0 mid-serve: parked asks on that
shard fail with `ShardConnectionError`, the health sweep marks it dead,
and `revive_shard` respawns a fresh worker that restores from its own
latest committed epoch — clients resume and only the uncommitted round is
lost (re-derived bitwise from the persisted per-study PRNG streams).

With `--ckpt-dir` pointing at a persistent directory a second invocation
restores the whole federation (registry epoch first, then every shard
from its own store) and each tenant resumes exactly where it stopped.
"""
import argparse
import asyncio
import sys
import tempfile
import time

import numpy as np

sys.path.insert(0, "src")

from repro.core.acquisition import AcqConfig  # noqa: E402
from repro.hpo.federation import FederationConfig  # noqa: E402
from repro.hpo.gateway import GatewayConfig  # noqa: E402
from repro.hpo.pool import SchedulerConfig  # noqa: E402
from repro.hpo.space import RESNET_SPACE  # noqa: E402
from repro.hpo.transport import (ShardConnectionError,  # noqa: E402
                                 TransportConfig, TransportFederation)


def make_objective(sid: int, latency: float):
    center = 0.15 + 0.7 * ((sid * 0.37) % 1.0)

    async def objective(unit: np.ndarray) -> float:
        await asyncio.sleep(latency * (1.0 + 0.5 * ((sid + 1) % 3)))
        return float(-np.sum((np.asarray(unit) - center) ** 2))

    return objective


async def client(tf: TransportFederation, sid: int, budget: int,
                 latency: float) -> int:
    """One tenant's serving loop; survives its shard dying mid-ask by
    waiting for the supervisor to revive it."""
    objective = make_objective(sid, latency)
    done = retried = 0
    while done < budget:
        try:
            trial = await tf.ask(sid)
            await tf.tell(sid, trial, await objective(trial.unit))
        except (ShardConnectionError, asyncio.CancelledError,
                RuntimeError):
            # shard died under us (parked asks cancel with kill_shard
            # semantics; calls routed to a down shard fail loudly) —
            # back off and retry once the supervisor revives it
            retried += 1
            if retried > 50:
                raise
            await asyncio.sleep(0.2)
            continue
        done += 1
    return retried


async def supervisor(tf: TransportFederation, kill_after: float) -> None:
    """Checkpoint, SIGKILL shard 0, observe the health sweep declare it
    dead, respawn it from its committed epoch."""
    await asyncio.sleep(kill_after)
    epoch = await tf.checkpoint()
    tf.kill_shard(0)
    print(f"  [supervisor] shard 0 SIGKILLed after epoch {epoch}")
    assert await tf.check_health() == []   # already marked dead by kill
    await tf.revive_shard(0)
    print("  [supervisor] shard 0 respawned + reconciled")


async def serve(args, root: str) -> None:
    cfg = SchedulerConfig(n_max=args.budget + 8, seed=0,
                          ckpt_dir=root, ckpt_every=10 ** 9,
                          acq=AcqConfig(restarts=16, ascent_steps=8))
    tf = TransportFederation(
        RESNET_SPACE, cfg,
        GatewayConfig(slots=max(2, args.studies // args.shards)),
        FederationConfig(n_shards=args.shards),
        TransportConfig(heartbeat_s=0.0))
    restored = await tf.start()
    if restored:
        sids = tf.study_ids()
        print(f"resumed federation: {len(sids)} tenants across "
              f"{args.shards} worker processes")
    else:
        sids = [await tf.create_study(name=f"tenant{i}")
                for i in range(args.studies)]

    tasks = [client(tf, s, args.budget, args.latency) for s in sids]
    if args.kill:
        tasks.append(supervisor(tf, kill_after=args.kill_after))
    t0 = time.perf_counter()
    results = await asyncio.gather(*tasks)
    await tf.drain()
    elapsed = time.perf_counter() - t0

    summary = await tf.summary()
    retries = sum(r for r in results if isinstance(r, int))
    served = args.budget * len(sids)
    await tf.checkpoint()
    print(f"\nserved {served} suggestions for {len(sids)} tenants on "
          f"{args.shards} worker processes in {elapsed:.2f}s "
          f"({served / max(elapsed, 1e-9):.1f} suggestions/s, "
          f"{retries} failover retries)")
    worst_p95 = max((s["p95_tick_ms"]
                     for s in summary["per_shard"].values()), default=0.0)
    print(f"ticks={summary['ticks']} "
          f"evictions={summary['evictions']} "
          f"worst_shard_p95_tick={worst_p95:.1f}ms")
    for s in sids:
        info = await tf.study_info(s)
        line = (f"  {info['name']}: shard {info['shard']} "
                f"n={info['n_obs']}")
        if info["best_value"] is not None:
            line += f" best={info['best_value']:+.4f}"
        print(line)
    await tf.aclose()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--studies", type=int, default=8,
                    help="concurrent logical studies (clients)")
    ap.add_argument("--shards", type=int, default=2,
                    help="worker processes (one per host in production)")
    ap.add_argument("--budget", type=int, default=6,
                    help="observations per study")
    ap.add_argument("--latency", type=float, default=0.01,
                    help="simulated per-trial train time (s)")
    ap.add_argument("--kill", action="store_true",
                    help="SIGKILL + revive shard 0 mid-serve")
    ap.add_argument("--kill-after", type=float, default=1.0,
                    help="seconds before the supervisor kills shard 0")
    ap.add_argument("--ckpt-dir", default=None,
                    help="persistent shared store root: a 2nd run "
                         "resumes every tenant")
    args = ap.parse_args()

    if args.ckpt_dir:
        asyncio.run(serve(args, args.ckpt_dir))
    else:
        with tempfile.TemporaryDirectory() as d:
            asyncio.run(serve(args, d))


if __name__ == "__main__":
    main()
