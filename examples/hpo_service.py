"""Multi-tenant HPO service: a request-driven suggest/report loop over a
StudyPool (the ROADMAP's "serve heavy traffic" shape, in miniature).

    python examples/hpo_service.py [--studies 8] [--budget 12] [--workers 8] \
        [--mesh auto]          # shard the suggest path over a device mesh (§8)
        [--categorical-tenant]  # last tenant optimizes a Categorical space (§10)

S tenants run concurrent HPO studies against one batched lazy-GP engine:
each service round issues ONE fused `advance_round` dispatch — the masked
absorb of every drained completion AND the batched suggest for every
tenant with an open request run in a single jitted program with donated
state buffers (DESIGN.md §8).  Suggestions go to worker threads (the
"trainers"); results are absorbed in completion order, so a slow tenant
never blocks a fast one.  With --mesh the suggest path shards over a
device mesh; with --ckpt-dir the whole pool rides one atomic checkpoint
and a second invocation resumes every tenant's posterior.

Each tenant optimizes its own synthetic objective (a shifted smooth bowl on
the unit cube, distinct optimum per tenant) so per-study convergence is
visible in the final report.  With --categorical-tenant the last tenant
runs a MIXED space (a 3-way categorical choice, same encoded width as the
float tenants' ResNet space) through the very same batched rounds —
heterogeneous type layouts share one stacked program (DESIGN.md §10).
"""
import argparse
import sys
import time
from concurrent.futures import FIRST_COMPLETED, ThreadPoolExecutor, wait

import numpy as np

sys.path.insert(0, "src")

from repro.hpo.pool import SchedulerConfig, StudyPool  # noqa: E402
from repro.hpo.space import (Categorical, RESNET_SPACE,  # noqa: E402
                             SearchSpace)

# Same encoded width (3) as RESNET_SPACE, so both layouts stack in one
# rectangular pool; the engine's per-study type descriptor keeps the
# categorical tenant's suggestions on its one-hot lattice.
CATEGORICAL_SPACE = SearchSpace((
    Categorical("optimizer", ("sgd", "adam", "rmsprop")),
))
CATEGORICAL_SCORE = {"sgd": -0.3, "adam": 0.0, "rmsprop": -0.6}


def make_objective(sid: int, latency: float, space=None):
    """Tenant sid's trainer: smooth bowl with a per-tenant optimum (float
    tenants) or a per-choice score table (the categorical tenant)."""
    center = 0.15 + 0.7 * ((sid * 0.37) % 1.0)

    def objective(unit: np.ndarray) -> float:
        time.sleep(latency * (1.0 + 0.5 * ((sid + 1) % 3)))  # uneven tenants
        if space is not None and space.has_discrete:
            return CATEGORICAL_SCORE[
                space.to_hparams(np.asarray(unit))["optimizer"]]
        return float(-np.sum((np.asarray(unit) - center) ** 2))

    return objective


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--studies", type=int, default=8)
    ap.add_argument("--budget", type=int, default=12,
                    help="observations to absorb per study")
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--latency", type=float, default=0.02,
                    help="simulated per-trial train time (s)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--implementation", default="auto",
                    choices=["auto", "pallas", "xla", "ref"])
    ap.add_argument("--mesh", default="none",
                    help="device mesh for the batched suggest path "
                         "(DESIGN.md §8): none | auto | SxR (e.g. 4x2). "
                         "On CPU, export XLA_FLAGS="
                         "--xla_force_host_platform_device_count=8 first")
    ap.add_argument("--categorical-tenant", action="store_true",
                    help="give the last tenant a mixed (categorical) "
                         "search space (DESIGN.md §10)")
    args = ap.parse_args()

    spaces = [RESNET_SPACE] * args.studies
    if args.categorical_tenant:
        spaces[-1] = CATEGORICAL_SPACE
    cfg = SchedulerConfig(n_max=args.budget + 8, seed=0,
                          implementation=args.implementation,
                          mesh=args.mesh,
                          ckpt_dir=args.ckpt_dir)
    pool = StudyPool(spaces, cfg,
                     names=[f"tenant{i}" for i in range(args.studies)])
    if args.ckpt_dir and pool.restore():
        print("resumed pool: " + ", ".join(
            f"{h.name} n={pool.engine.n(h.study_id)}"
            for h in pool.studies))

    objectives = [make_objective(s, args.latency, spaces[s])
                  for s in range(args.studies)]
    t0 = time.perf_counter()
    suggested = 0
    with ThreadPoolExecutor(args.workers) as workers:
        inflight = {}   # Future -> (study_id, Trial)
        events = []     # drained completions awaiting absorption

        def open_requests():
            """Tenants below budget with no trial in flight this round
            (counting completions about to be absorbed)."""
            busy = {sid for sid, _ in inflight.values()}
            incoming: dict[int, int] = {}
            for sid, _, _ in events:
                incoming[sid] = incoming.get(sid, 0) + 1
            return [s for s in range(args.studies)
                    if s not in busy
                    and pool.engine.n(s) + incoming.get(s, 0) < args.budget]

        while True:
            ready = open_requests()
            if events or ready:
                # ONE fused dispatch absorbs every drained completion and
                # serves every open suggest request (advance_round; tenants
                # at budget absorb without drawing a new trial).
                suggestions = pool.advance_round(events, studies=ready)
                events = []
                for sid, trs in suggestions.items():
                    tr = trs[0]
                    tr.status = "running"
                    tr.started = time.time()
                    fut = workers.submit(objectives[sid], tr.unit)
                    inflight[fut] = (sid, tr)
                    suggested += 1
            if not inflight:
                break
            done, _ = wait(inflight, return_when=FIRST_COMPLETED)
            for fut in done:            # completion order, any tenant mix
                sid, tr = inflight.pop(fut)
                try:
                    events.append((sid, tr, float(fut.result())))
                except Exception as e:  # noqa: BLE001 — tenant fault
                    retry = pool.record_failure(sid, tr,
                                                f"{type(e).__name__}: {e}")
                    if retry is not None:
                        fut2 = workers.submit(objectives[sid], retry.unit)
                        inflight[fut2] = (sid, retry)

    elapsed = time.perf_counter() - t0
    total = sum(pool.engine.n(s) for s in range(args.studies))
    print(f"\nserved {suggested} suggestions / absorbed {total} results "
          f"for {args.studies} tenants in {elapsed:.2f}s "
          f"({total / elapsed:.1f} results/s)")
    for h in pool.studies:
        best = pool.best(h.study_id)
        extra = ""
        if h.space.has_discrete and best is not None:
            hp = h.space.to_hparams(best.unit)
            extra = " " + " ".join(f"{k}={v}" for k, v in hp.items())
        print(f"  {h.name}: n={pool.engine.n(h.study_id)} "
              f"best={best.value:+.4f} "
              f"clamps={pool.engine.clamp_count(h.study_id)}{extra}")


if __name__ == "__main__":
    main()
