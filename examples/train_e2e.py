"""End-to-end training driver: train a small LM for a few hundred steps.

    python examples/train_e2e.py                  # ~15M-param model, 200 steps
    python examples/train_e2e.py --preset 100m    # ~100M params (slow on CPU)
    python examples/train_e2e.py --arch granite-3-2b --reduced

Demonstrates the full substrate: synthetic data pipeline -> sharded
train_step (mesh + logical rules) -> checkpointing -> restart.  Kill it
mid-run and re-run with the same --ckpt-dir: it resumes from the last
committed step with an identical data stream.
"""
import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

from repro.configs import get_config  # noqa: E402
from repro.launch import train as train_mod  # noqa: E402

PRESETS = {
    "15m": dict(num_layers=4, d_model=384, num_heads=8, num_kv_heads=4,
                d_ff=1536, vocab_size=8192),
    "100m": dict(num_layers=12, d_model=768, num_heads=12, num_kv_heads=4,
                 d_ff=3072, vocab_size=16384),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="15m", choices=list(PRESETS))
    ap.add_argument("--arch", default=None,
                    help="use an assigned arch config instead of a preset")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_e2e_ckpt")
    args = ap.parse_args()

    if args.arch:
        argv = ["--arch", args.arch] + (["--reduced"] if args.reduced else [])
    else:
        # register the preset as a patched tiny-lm
        import repro.configs.tiny_lm as tiny
        tiny.CONFIG = dataclasses.replace(get_config("tiny-lm"),
                                          **PRESETS[args.preset])
        argv = ["--arch", "tiny-lm"]
    argv += ["--steps", str(args.steps), "--seq-len", str(args.seq_len),
             "--global-batch", str(args.global_batch), "--lr", str(args.lr),
             "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "50",
             "--log-every", "10"]
    out = train_mod.run(train_mod.parse_args(argv))
    print(f"final loss: {out['final_loss']:.4f} "
          f"(started near ln(vocab) ~ {out['losses'][0]:.2f})")


if __name__ == "__main__":
    main()
