"""Async ask–tell HPO serving: many clients, one coalesced gateway.

    python examples/serve.py [--studies 12] [--slots 4] [--budget 8] \
        [--q 4] [--coalesce-ms 2] [--ckpt-dir /tmp/gw]

The ROADMAP's "serve heavy traffic" shape end-to-end (DESIGN.md §9): N
asynchronous clients each run their own HPO study through the gateway's
`ask`/`tell` API.  Concurrent asks coalesce into ONE fused batched round
per tick; with `--slots` below `--studies` the pool serves more logical
studies than resident GP slots, transparently evicting idle studies to
per-study checkpoints and restoring them on their next ask.  With
--ckpt-dir pointing at a persistent directory a second invocation restores
the whole gateway and every tenant resumes exactly where it stopped.

Each client optimizes its own synthetic objective (a shifted smooth bowl on
the unit cube, distinct optimum per tenant) with a touch of simulated
training latency, so the final report shows per-study convergence plus the
gateway's serving telemetry (coalesce width, tick latency, evictions).

With `--q N` (N > 1) every client asks for a BATCH of N suggestions per
round — one fused qEI fantasy dispatch per ask (DESIGN.md §12) — and
evaluates them concurrently before telling all N back, the worker-farm
shape where each tenant drives several training jobs at once.
"""
import argparse
import asyncio
import sys
import tempfile
import time

import numpy as np

sys.path.insert(0, "src")

from repro.core import GPCapacityError  # noqa: E402
from repro.core.acquisition import AcqConfig  # noqa: E402
from repro.hpo.gateway import GatewayConfig, StudyGateway  # noqa: E402
from repro.hpo.pool import SchedulerConfig  # noqa: E402
from repro.hpo.space import RESNET_SPACE  # noqa: E402


def make_objective(sid: int, latency: float):
    center = 0.15 + 0.7 * ((sid * 0.37) % 1.0)

    async def objective(unit: np.ndarray) -> float:
        await asyncio.sleep(latency * (1.0 + 0.5 * ((sid + 1) % 3)))
        return float(-np.sum((np.asarray(unit) - center) ** 2))

    return objective


async def client(gw: StudyGateway, sid: int, budget: int, latency: float,
                 q: int = 1):
    objective = make_objective(sid, latency)
    done = 0
    while done < budget:
        width = min(q, budget - done)
        try:
            got = await gw.ask(sid, q=width) if width > 1 \
                else await gw.ask(sid)
        except GPCapacityError as e:
            # a resumed study can hit its n_max (the buffers are sized at
            # construction and shape-checked on restore) — report cleanly
            # instead of crashing the whole serving loop
            print(f"  {gw.study_info(sid)['name']}: full ({e})")
            break
        trials = got if isinstance(got, list) else [got]
        # the q suggestions are a worker farm: evaluate concurrently,
        # tell each result back as it lands
        values = await asyncio.gather(*(objective(t.unit) for t in trials))
        for trial, value in zip(trials, values):
            gw.tell(sid, trial, value)
        done += len(trials)
    await gw.drain()


async def serve(args, ckpt_dir: str) -> None:
    cfg = SchedulerConfig(n_max=args.budget + 8, seed=0,
                          implementation=args.implementation,
                          ckpt_dir=ckpt_dir, ckpt_every=10 ** 9,
                          acq=AcqConfig(restarts=16, ascent_steps=8))
    gw = StudyGateway(RESNET_SPACE, cfg,
                      GatewayConfig(slots=args.slots,
                                    coalesce_ms=args.coalesce_ms,
                                    max_inflight=max(4, args.q)))
    # A fresh directory returns False; an INCOMPATIBLE checkpoint (e.g. a
    # --slots or --budget change reshaping the pool) raises ValueError —
    # let it surface rather than silently starting fresh over the old
    # tenants' history.
    restored = gw.restore()
    if restored:
        sids = gw.study_ids()
        print("resumed gateway: " + ", ".join(
            "{name} n={n_obs}".format(**gw.study_info(s)) for s in sids))
    else:
        sids = [gw.create_study(name=f"tenant{i}")
                for i in range(args.studies)]

    served_before = gw.summary()["asks_served"]   # lifetime totals ride
    # the checkpoint registry: report only THIS invocation's traffic
    t0 = time.perf_counter()
    await asyncio.gather(*(client(gw, s, args.budget, args.latency, args.q)
                           for s in sids))
    elapsed = time.perf_counter() - t0
    summary = gw.summary()
    served = summary["asks_served"] - served_before
    gw.checkpoint()
    await gw.aclose()

    total = sum(gw.study_info(s)["n_obs"] for s in sids)
    print(f"\nserved {served} suggestions "
          f"({total} absorbed total) for {len(sids)} tenants on "
          f"{args.slots} slots in {elapsed:.2f}s "
          f"({served / max(elapsed, 1e-9):.1f} suggestions/s)")
    print(f"ticks={summary['ticks']} "
          f"mean_coalesce_width={summary['mean_coalesce_width']:.1f} "
          f"p50_tick={summary['p50_tick_ms']:.1f}ms "
          f"p95_tick={summary['p95_tick_ms']:.1f}ms "
          f"evictions={summary['evictions']} "
          f"restores={summary['restores']}")
    if args.q > 1:
        print(f"q-widths={summary['q_width_hist']} "
              f"fantasy_rollbacks={summary['fantasy_rollbacks']} "
              f"fantasy_active={summary['fantasy_active']}")
    for s in sids:
        info = gw.study_info(s)
        slot = "evicted" if not info["resident"] else f"slot {info['slot']}"
        line = f"  {info['name']}: n={info['n_obs']} ({slot}"
        if info["evictions"]:
            line += f", {info['evictions']} evictions"
        line += ")"
        if info["best_value"] is not None:
            line += f" best={info['best_value']:+.4f}"
        print(line)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--studies", type=int, default=12,
                    help="concurrent logical studies (clients)")
    ap.add_argument("--slots", type=int, default=4,
                    help="resident GP slots (< studies exercises eviction)")
    ap.add_argument("--budget", type=int, default=8,
                    help="observations per study")
    ap.add_argument("--q", type=int, default=1,
                    help="suggestions per ask: q>1 serves each ask with "
                         "one fused qEI fantasy dispatch")
    ap.add_argument("--latency", type=float, default=0.01,
                    help="simulated per-trial train time (s)")
    ap.add_argument("--coalesce-ms", type=float, default=0.0,
                    help="tick gathering window")
    ap.add_argument("--ckpt-dir", default=None,
                    help="persistent dir: a 2nd run resumes every tenant")
    ap.add_argument("--implementation", default="auto",
                    choices=["auto", "pallas", "xla", "ref"])
    args = ap.parse_args()

    if args.ckpt_dir:
        asyncio.run(serve(args, args.ckpt_dir))
    else:
        with tempfile.TemporaryDirectory() as d:
            asyncio.run(serve(args, d))


if __name__ == "__main__":
    main()
