"""Batched serving: prefill a prompt batch, then decode with a KV cache.

    python examples/serve.py [--arch granite-3-2b] [--batch 4] [--new 32]

Uses each arch's real serve path: KV caches for attention stacks, latent
caches for MLA, recurrent states for Mamba2/xLSTM — the same `prefill` /
`decode_step` the multi-pod dry-run lowers at 32k/500k.
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.models import decode_step, init_params, prefill  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)
    if not cfg.supports_decode:
        raise SystemExit(f"{args.arch} is encoder-only: no decode path")
    key = jax.random.PRNGKey(0)
    params, _ = init_params(cfg, key)
    max_len = args.prompt_len + args.new

    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)

    jit_prefill = jax.jit(lambda p, t: prefill(p, cfg, t, max_len))
    jit_decode = jax.jit(lambda p, c, t: decode_step(p, cfg, c, t),
                         donate_argnums=(1,))

    t0 = time.perf_counter()
    logits, cache = jax.block_until_ready(jit_prefill(params, prompts))
    t_prefill = time.perf_counter() - t0

    toks = []
    key_s = key
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    t0 = time.perf_counter()
    for i in range(args.new):
        toks.append(tok)
        logits, cache = jit_decode(params, cache, tok)
        key_s = jax.random.fold_in(key_s, i)
        tok = jax.random.categorical(
            key_s, logits[:, -1] / args.temperature)[:, None].astype(jnp.int32)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0

    out = jnp.concatenate(toks, axis=1)
    print(f"arch={cfg.name}  prefill {args.batch}x{args.prompt_len} tokens "
          f"in {1e3 * t_prefill:.1f} ms")
    print(f"decoded {args.batch}x{args.new} tokens in {1e3 * t_decode:.1f} ms"
          f"  ({args.batch * args.new / t_decode:.0f} tok/s, incl. compile)")
    print("sampled ids (seq 0):", out[0].tolist())


if __name__ == "__main__":
    main()
