"""Parallel HPO of a real trainer with fault injection (paper Sec. 3.4/4.4).

    python examples/parallel_hpo.py [--budget 16] [--parallel 4] [--faults]

t worker lanes train the tiny LM with different (lr, wd, momentum); the lazy
GP suggests the top-t EI local maxima and absorbs results in completion
order (stragglers never block).  With --faults, every 5th trial crashes to
demonstrate the retry + penalized-region path, and the GP checkpoint in
--ckpt-dir lets a second invocation resume the exact posterior.
"""
import argparse
import sys
import threading

import numpy as np

sys.path.insert(0, "src")
sys.path.insert(0, ".")

from benchmarks.bench_nn_hpo import make_objective  # noqa: E402
from repro.hpo.scheduler import SchedulerConfig, TrialScheduler  # noqa: E402
from repro.hpo.space import RESNET_SPACE  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--budget", type=int, default=16)
    ap.add_argument("--parallel", type=int, default=4)
    ap.add_argument("--train-steps", type=int, default=20)
    ap.add_argument("--faults", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--implementation", default="auto",
                    choices=["auto", "pallas", "xla", "ref"],
                    help="linalg substrate for the GP math")
    args = ap.parse_args()

    base = make_objective(steps=args.train_steps)
    counter = {"n": 0}
    lock = threading.Lock()

    def objective(hp: dict) -> float:
        with lock:
            counter["n"] += 1
            n = counter["n"]
        if args.faults and n % 5 == 0:
            raise RuntimeError(f"injected fault in trial call #{n}")
        return float(base(RESNET_SPACE.to_unit(hp))[0])

    sched = TrialScheduler(
        RESNET_SPACE,
        SchedulerConfig(n_max=max(64, args.budget + 16),
                        parallel=args.parallel, seed=0,
                        implementation=args.implementation,
                        max_retries=2, ckpt_dir=args.ckpt_dir))
    if args.ckpt_dir and sched.restore():
        print(f"resumed GP with n={int(sched.state.n)} observations")

    best = sched.run(objective, budget=args.budget, n_seed=4)
    n_fail = sum(t.status == "failed" for t in sched.trials)
    print(f"\nabsorbed {int(sched.state.n)} observations "
          f"({n_fail} injected failures recovered)")
    print(f"best accuracy {best.value:.3f} with:")
    for k, v in best.hparams.items():
        print(f"  {k:14s} = {v:.5g}")


if __name__ == "__main__":
    main()
