"""Quickstart: lazy-GP Bayesian optimization of the 5-D Levy function.

Reproduces the paper's core loop in ~a minute on CPU:

    python examples/quickstart.py [--iterations 120] [--mode lazy|naive]

The lazy GP (paper Alg. 3) does one O(n^2) incremental Cholesky append per
iteration; `--mode naive` refits the kernel and refactorizes fully (O(n^3))
every iteration, which is the baseline the paper beats.
"""
import argparse
import sys

import numpy as np

sys.path.insert(0, "src")

import jax.numpy as jnp  # noqa: E402

from repro.core import levy_bounds, neg_levy, run_bo  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--iterations", type=int, default=120)
    ap.add_argument("--mode", default="lazy", choices=["lazy", "naive"])
    ap.add_argument("--lag", type=int, default=0,
                    help="lazy mode: full kernel refit every LAG steps")
    ap.add_argument("--seeds", type=int, default=5)
    ap.add_argument("--implementation", default="auto",
                    choices=["auto", "pallas", "xla", "ref"],
                    help="linalg substrate: auto picks Pallas on TPU, XLA "
                         "elsewhere")
    args = ap.parse_args()

    objective = lambda x: np.asarray(neg_levy(jnp.asarray(x)))
    lo, hi = levy_bounds(5)
    _, hist = run_bo(objective, lo, hi, args.iterations, dim=5,
                     mode=args.mode, lag=args.lag, n_seed=args.seeds,
                     n_max=args.iterations + args.seeds + 8, seed=0,
                     implementation=args.implementation)

    print(f"\nmode={args.mode} lag={args.lag}")
    for frac in (0.25, 0.5, 0.75, 1.0):
        i = max(0, int(len(hist.best_y) * frac) - 1)
        print(f"  after {i + 1:4d} evals: best = {hist.best_y[i]:9.4f}")
    x, y = hist.best()
    print(f"  optimum found: f = {y:.4f} at x = {np.round(x, 3)}"
          f"   (true optimum: 0 at [1 1 1 1 1])")
    print(f"  mean GP update: {1e3 * np.mean(hist.gp_seconds):.2f} ms; "
          f"mean suggestion: {1e3 * np.mean(hist.acq_seconds):.2f} ms")


if __name__ == "__main__":
    main()
