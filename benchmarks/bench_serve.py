"""Serving-gateway benchmark: coalesced ask–tell vs per-client dispatches.

The gateway claim (DESIGN.md §9): under concurrent ask–tell traffic, one
fused `advance_round` per coalescing tick beats serving each client with
its own routed suggest + absorb dispatches, because per-study device work
is tiny (the paper's O(n^2) append) and program-launch overhead dominates.
This bench measures exactly that at 16 concurrent clients:

  * **coalesced**  — a `StudyGateway` with one slot per client: each round,
    all 16 asks coalesce into ONE fused dispatch (absorb last round's 16
    tells + suggest 16 next points), driven by asyncio clients.
  * **serialized** — the same `StudyPool` shape served naively: every
    client's ask is its own routed `suggest` dispatch and every tell its
    own routed `absorb` dispatch (2 x 16 programs per round).

Both sides run identical GP shapes, acquisition budgets, observation
counts, and substrate.  Emits `name,us_per_call,derived` CSV rows for
`benchmarks.run` and writes `BENCH_serve.json` with suggestions/sec both
ways, the speedup (the acceptance floor is >= 2x), and gateway tick
telemetry.
"""
from __future__ import annotations

import asyncio
import json
import tempfile
import time

import numpy as np

from repro.core.acquisition import AcqConfig
from repro.hpo.gateway import GatewayConfig, StudyGateway
from repro.hpo.pool import SchedulerConfig, StudyPool
from repro.hpo.space import RESNET_SPACE

JSON_PATH = "BENCH_serve.json"

CLIENTS = 16


def _objective(sid: int, unit: np.ndarray) -> float:
    c = 0.2 + 0.6 * (sid % 7) / 7.0
    return float(-np.sum((np.asarray(unit) - c) ** 2))


def _cfg(n_max: int, ckpt_dir: str | None = None) -> SchedulerConfig:
    # Small acquisition budget: the bench measures serving overhead, not
    # ascent quality.  Identical on both sides.
    return SchedulerConfig(n_max=n_max, seed=0, ckpt_dir=ckpt_dir,
                           ckpt_every=10 ** 9,
                           acq=AcqConfig(restarts=16, ascent_steps=8))


def _bench_coalesced(d: str, n_max: int, warmup: int,
                     rounds: int) -> tuple[float, dict]:
    gw = StudyGateway(RESNET_SPACE, _cfg(n_max, d),
                      GatewayConfig(slots=CLIENTS))
    sids = [gw.create_study() for _ in range(CLIENTS)]

    async def round_all():
        trials = await asyncio.gather(*(gw.ask(s) for s in sids))
        for s, tr in zip(sids, trials):
            gw.tell(s, tr, _objective(s, tr.unit))
        await gw.drain()

    async def main():
        for _ in range(warmup):
            await round_all()
        gw.stats.clear()   # telemetry from measured ticks only: the first
        # warmup tick is the jit compile (~seconds) and would own the p95
        t0 = time.perf_counter()
        for _ in range(rounds):
            await round_all()
        dt = time.perf_counter() - t0
        await gw.aclose()
        return dt

    dt = asyncio.run(main())
    return dt, gw.summary()


def _bench_serialized(n_max: int, warmup: int, rounds: int) -> float:
    pool = StudyPool([RESNET_SPACE] * CLIENTS, _cfg(n_max))

    def round_all():
        # one routed suggest + one routed absorb PER CLIENT: the naive
        # service loop the gateway's coalescing replaces
        trials = [pool.suggest(s, 1)[0] for s in range(CLIENTS)]
        for s, tr in enumerate(trials):
            pool.absorb(s, tr, _objective(s, tr.unit))

    for _ in range(warmup):
        round_all()
    t0 = time.perf_counter()
    for _ in range(rounds):
        round_all()
    return time.perf_counter() - t0


def run(full: bool = False, json_path: str = JSON_PATH):
    n_max = 128
    warmup, rounds = (3, 12) if full else (2, 8)
    with tempfile.TemporaryDirectory() as d:
        co_s, summary = _bench_coalesced(d, n_max, warmup, rounds)
    ser_s = _bench_serialized(n_max, warmup, rounds)
    ops = CLIENTS * rounds
    rec = {
        "clients": CLIENTS,
        "n_max": n_max,
        "rounds": rounds,
        "coalesced_suggestions_per_sec": ops / co_s,
        "serialized_suggestions_per_sec": ops / ser_s,
        "coalesced_round_ms": 1e3 * co_s / rounds,
        "serialized_round_ms": 1e3 * ser_s / rounds,
        "speedup": ser_s / co_s,
        "mean_coalesce_width": summary["mean_coalesce_width"],
        "p50_tick_ms": summary["p50_tick_ms"],
        "p95_tick_ms": summary["p95_tick_ms"],
    }
    import jax
    payload = {"backend": jax.default_backend(), "results": [rec]}
    with open(json_path, "w") as f:
        json.dump(payload, f, indent=2)
    return [
        f"serve_coalesced,{1e6 * co_s / ops:.0f},"
        f"suggest_per_s={rec['coalesced_suggestions_per_sec']:.1f} "
        f"width={rec['mean_coalesce_width']:.1f}",
        f"serve_serialized,{1e6 * ser_s / ops:.0f},"
        f"suggest_per_s={rec['serialized_suggestions_per_sec']:.1f}",
        f"serve_speedup,,{rec['speedup']:.2f}x_at_{CLIENTS}_clients",
        f"serve_json,,path={json_path}",
    ]


if __name__ == "__main__":
    print("\n".join(run()))
