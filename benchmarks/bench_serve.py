"""Serving-gateway benchmark: coalesced ask–tell vs per-client dispatches.

The gateway claim (DESIGN.md §9): under concurrent ask–tell traffic, one
fused `advance_round` per coalescing tick beats serving each client with
its own routed suggest + absorb dispatches, because per-study device work
is tiny (the paper's O(n^2) append) and program-launch overhead dominates.
This bench measures exactly that at 16 concurrent clients:

  * **coalesced**  — a `StudyGateway` with one slot per client: each round,
    all 16 asks coalesce into ONE fused dispatch (absorb last round's 16
    tells + suggest 16 next points), driven by asyncio clients.
  * **serialized** — the same `StudyPool` shape served naively: every
    client's ask is its own routed `suggest` dispatch and every tell its
    own routed `absorb` dispatch (2 x 16 programs per round).

Both sides run identical GP shapes, acquisition budgets, observation
counts, and substrate.  Emits `name,us_per_call,derived` CSV rows for
`benchmarks.run` and writes `BENCH_serve.json` with suggestions/sec both
ways, the speedup (the acceptance floor is >= 2x), and gateway tick
telemetry.

The q-sweep cells measure the OTHER serving shape (DESIGN.md §12): ONE
tenant driving a farm of 8 workers.  At q=1 the per-study
one-ask-per-tick rule serializes the farm — 8 workers asking the same
study take 8 consecutive ticks (the pinned baseline).  At q=8/q=32 one
`ask(sid, q=N)` delivers the whole batch from a single fused qEI fantasy
dispatch.  Acceptance floor: q=8 >= 3x the q=1 serialized-tick baseline.

The federation cells measure HORIZONTAL scale (DESIGN.md §13): 256
simulated clients, one study each, on 1/2/4 shards of a fixed per-shard
slot budget.  One shard (the pinned single-pool baseline) holds 144 slots
for 256 tenants, so every round thrashes the eviction store; 2 shards
double the resident set and the churn disappears — on a single-device
host the win is CAPACITY scaling (eviction-churn elimination), not
parallel compute.  Each cell runs the same per-client trial budget.
Acceptance floor: 2 shards >= 1.6x the single-pool baseline's sustained
suggestions/sec.

The transport cells measure the CROSS-PROCESS deployment (DESIGN.md
§14): the same 2-shard federation served by 2 real worker PROCESSES
behind the socket RPC front end (`repro.hpo.transport`), against the
in-process `FederatedGateway` at the identical shape.  In-process, the
two shard tickers time-slice one interpreter; over the transport their
fused rounds can overlap in wall-clock on separate cores.  The cells
run a REALISTIC acquisition budget (restarts=128, ascent_steps=32,
n_max=64 — unlike the deliberately tiny budget of the scheduling-bound
cells above), so per-round device work dominates and the per-suggestion
RPC cost — micro-batched frames, base64 unit buffers — amortizes to
noise.  Acceptance floor: the 2-process cell's aggregate
suggestions/sec >= the in-process 2-shard baseline at the same shape
(parity on a single-core host, where cross-process rounds cannot
physically overlap; strictly better with one core per worker).
"""
from __future__ import annotations

import asyncio
import json
import tempfile
import time

import numpy as np

from repro.core.acquisition import AcqConfig
from repro.hpo.federation import FederatedGateway, FederationConfig
from repro.hpo.gateway import GatewayConfig, StudyGateway
from repro.hpo.pool import SchedulerConfig, StudyPool
from repro.hpo.space import RESNET_SPACE
from repro.hpo.transport import TransportConfig, TransportFederation

JSON_PATH = "BENCH_serve.json"

CLIENTS = 16
FARM_WORKERS = 8
FARM_QS = (1, 8, 32)
FED_CLIENTS = 256
FED_SLOTS = 144           # per shard: 1 shard churns 256 tenants, 2+ don't
FED_SHARDS = (1, 2, 4)
TX_CLIENTS = 256          # transport cells: the FED shape, resident on
TX_SLOTS = 144            # 2 shards (no churn) at a realistic acquisition
TX_SHARDS = 2             # budget — per-round device work dominates, so
TX_N_MAX = 64             # the cross-process hop is measured against real
TX_ACQ = AcqConfig(restarts=128, ascent_steps=32)  # serving work


def _objective(sid: int, unit: np.ndarray) -> float:
    c = 0.2 + 0.6 * (sid % 7) / 7.0
    return float(-np.sum((np.asarray(unit) - c) ** 2))


def _cfg(n_max: int, ckpt_dir: str | None = None,
         acq: AcqConfig | None = None) -> SchedulerConfig:
    # Small acquisition budget by default: most cells measure serving
    # overhead, not ascent quality.  Identical on both sides of a pair.
    return SchedulerConfig(n_max=n_max, seed=0, ckpt_dir=ckpt_dir,
                           ckpt_every=10 ** 9,
                           acq=acq or AcqConfig(restarts=16,
                                                ascent_steps=8))


def _bench_coalesced(d: str, n_max: int, warmup: int,
                     rounds: int) -> tuple[float, dict]:
    gw = StudyGateway(RESNET_SPACE, _cfg(n_max, d),
                      GatewayConfig(slots=CLIENTS))
    sids = [gw.create_study() for _ in range(CLIENTS)]

    async def round_all():
        trials = await asyncio.gather(*(gw.ask(s) for s in sids))
        for s, tr in zip(sids, trials):
            gw.tell(s, tr, _objective(s, tr.unit))
        await gw.drain()

    async def main():
        for _ in range(warmup):
            await round_all()
        gw.stats.clear()   # telemetry from measured ticks only: the first
        # warmup tick is the jit compile (~seconds) and would own the p95
        t0 = time.perf_counter()
        for _ in range(rounds):
            await round_all()
        dt = time.perf_counter() - t0
        await gw.aclose()
        return dt

    dt = asyncio.run(main())
    return dt, gw.summary()


def _bench_serialized(n_max: int, warmup: int, rounds: int) -> float:
    pool = StudyPool([RESNET_SPACE] * CLIENTS, _cfg(n_max))

    def round_all():
        # one routed suggest + one routed absorb PER CLIENT: the naive
        # service loop the gateway's coalescing replaces
        trials = [pool.suggest(s, 1)[0] for s in range(CLIENTS)]
        for s, tr in enumerate(trials):
            pool.absorb(s, tr, _objective(s, tr.unit))

    for _ in range(warmup):
        round_all()
    t0 = time.perf_counter()
    for _ in range(rounds):
        round_all()
    return time.perf_counter() - t0


def _bench_farm(d: str, q: int, per_round: int, n_max: int, warmup: int,
                rounds: int) -> tuple[float, int, dict]:
    """Single tenant, a worker farm draining `per_round` trials per round
    in asks of width q.

    q=1: every worker asks individually — the one-ask-per-study-per-tick
    rule serializes them into `per_round` consecutive ticks per round
    (the serialized-tick baseline the q-path is measured against).
    q>1: `per_round // q` asks, each ONE fused qEI fantasy dispatch.
    A cell and its baseline share `per_round` and `n_max`, so both sides
    absorb the identical observation trajectory (same ledger growth, same
    lag-refit boundaries) and differ ONLY in ask width.
    """
    gw = StudyGateway(RESNET_SPACE, _cfg(n_max, d),
                      GatewayConfig(slots=1,
                                    max_inflight=2 * per_round))
    sid = gw.create_study()

    async def round_all():
        if q == 1:
            trials = await asyncio.gather(
                *(gw.ask(sid) for _ in range(per_round)))
        else:
            packs = await asyncio.gather(
                *(gw.ask(sid, q=q) for _ in range(per_round // q)))
            trials = [tr for pack in packs for tr in pack]
        for tr in trials:
            gw.tell(sid, tr, _objective(sid, tr.unit))
        await gw.drain()

    async def main():
        for _ in range(warmup):
            await round_all()
        gw.stats.clear()
        t0 = time.perf_counter()
        for _ in range(rounds):
            await round_all()
        dt = time.perf_counter() - t0
        await gw.aclose()
        return dt

    dt = asyncio.run(main())
    return dt, per_round * rounds, gw.summary()


def _bench_federation(root: str, n_shards: int, n_max: int, warmup: int,
                      rounds: int, clients: int = FED_CLIENTS,
                      slots: int = FED_SLOTS,
                      acq: AcqConfig | None = None) -> tuple[float, dict]:
    """`clients` concurrent ask-tell clients over an N-shard federation
    (the 1-shard cell IS the pinned single-pool baseline: same gateway,
    same slot budget, everything routed to one pool)."""
    fg = FederatedGateway(RESNET_SPACE, _cfg(n_max, root, acq),
                          GatewayConfig(slots=slots),
                          FederationConfig(n_shards=n_shards))
    sids = [fg.create_study() for _ in range(clients)]

    async def one(s):
        tr = await fg.ask(s)
        fg.tell(s, tr, _objective(s, tr.unit))

    async def round_all():
        await asyncio.gather(*(one(s) for s in sids))
        await fg.drain()

    async def main():
        for _ in range(warmup):
            await round_all()
        for _i, gw in fg._live_shards():
            gw.stats.clear()   # p95 over measured ticks, not the compile
        ev0 = fg.summary()["evictions"]
        t0 = time.perf_counter()
        for _ in range(rounds):
            await round_all()
        dt = time.perf_counter() - t0
        summary = fg.summary()
        summary["measured_evictions"] = summary["evictions"] - ev0
        await fg.aclose()
        return dt, summary

    return asyncio.run(main())


def _bench_transport(root: str, n_shards: int, n_max: int, warmup: int,
                     rounds: int, clients: int = TX_CLIENTS,
                     slots: int = TX_SLOTS,
                     acq: AcqConfig | None = None) -> tuple[float, dict]:
    """The same federation shape served by `n_shards` REAL worker
    processes behind the socket RPC front end — per-shard fused rounds
    can overlap in wall-clock instead of time-slicing one interpreter."""
    async def main():
        tf = TransportFederation(RESNET_SPACE, _cfg(n_max, root, acq),
                                 GatewayConfig(slots=slots),
                                 FederationConfig(n_shards=n_shards),
                                 TransportConfig(heartbeat_s=0.0))
        await tf.start()
        sids = []
        for _ in range(clients):
            sids.append(await tf.create_study())

        async def one(s):
            tr = await tf.ask(s)
            await tf.tell(s, tr, _objective(s, tr.unit))

        async def round_all():
            await asyncio.gather(*(one(s) for s in sids))
            await tf.drain()

        for _ in range(warmup):
            await round_all()
        ev0 = (await tf.summary())["evictions"]
        t0 = time.perf_counter()
        for _ in range(rounds):
            await round_all()
        dt = time.perf_counter() - t0
        summary = await tf.summary()
        summary["measured_evictions"] = summary["evictions"] - ev0
        await tf.aclose()
        return dt, summary

    return asyncio.run(main())


def run(full: bool = False, json_path: str = JSON_PATH):
    n_max = 128
    warmup, rounds = (3, 12) if full else (2, 8)
    with tempfile.TemporaryDirectory() as d:
        co_s, summary = _bench_coalesced(d, n_max, warmup, rounds)
    ser_s = _bench_serialized(n_max, warmup, rounds)
    farm_cells = []
    # warmup >= 2: round 0 serves host-side seeds (the study is empty), so
    # the first REAL fused q-ask — and its jit compile — happens in round 1
    f_warm, f_rounds = (3, 10) if full else (2, 6)

    def _run_cell(q: int, per_round: int, nm: int) -> dict:
        with tempfile.TemporaryDirectory() as d:
            dt, sug, fsum = _bench_farm(d, q, per_round, nm,
                                        f_warm, f_rounds)
        return {"q": q, "per_round": per_round, "n_max": nm,
                "suggestions_per_sec": sug / dt,
                "round_ms": 1e3 * dt / f_rounds,
                "fantasy_rollbacks": fsum["fantasy_rollbacks"]}

    # Wider cells drain more trials per round, so their ledgers (and
    # buffers) grow faster: each cell is compared against a q=1
    # serialized-tick baseline with the SAME per-round trial count and
    # n_max — identical observation trajectory, ask width is the only
    # difference (a cross-shape ratio would conflate batching with
    # buffer size and refit cadence).
    cell_shape = {q: (max(q, FARM_WORKERS),
                      max(q, FARM_WORKERS) * (f_warm + f_rounds) + 16)
                  for q in FARM_QS}
    base_cells = {shape: _run_cell(1, *shape)
                  for shape in sorted(set(cell_shape.values()))}
    for q in FARM_QS:
        shape = cell_shape[q]
        cell = dict(base_cells[shape] if q == 1
                    else _run_cell(q, *shape))
        base = base_cells[shape]["suggestions_per_sec"]
        cell["baseline_suggestions_per_sec"] = base
        cell["speedup_vs_q1"] = cell["suggestions_per_sec"] / base
        farm_cells.append(cell)
    q1_base = base_cells[cell_shape[8]]["suggestions_per_sec"]

    # federation cells: same per-client budget on every shard count; the
    # 1-shard cell is the pinned single-pool baseline
    fed_warm, fed_rounds = (2, 4) if full else (1, 3)
    fed_n_max = 16
    fed_cells = []
    for n_shards in FED_SHARDS:
        with tempfile.TemporaryDirectory() as d:
            dt, fsum = _bench_federation(d, n_shards, fed_n_max,
                                         fed_warm, fed_rounds)
        sug = FED_CLIENTS * fed_rounds
        fed_cells.append({
            "n_shards": n_shards,
            "clients": FED_CLIENTS,
            "slots_per_shard": FED_SLOTS,
            "suggestions_per_sec": sug / dt,
            "round_ms": 1e3 * dt / fed_rounds,
            "measured_evictions": fsum["measured_evictions"],
            "p95_tick_ms": max(s["p95_tick_ms"]
                               for s in fsum["per_shard"].values()),
            "per_shard_p95_tick_ms": {i: s["p95_tick_ms"] for i, s in
                                      sorted(fsum["per_shard"].items())},
        })
    fed_base = fed_cells[0]["suggestions_per_sec"]
    for cell in fed_cells:
        cell["speedup_vs_single_pool"] = \
            cell["suggestions_per_sec"] / fed_base

    # transport cells: the identical 2-shard shape in-process vs behind
    # 2 real worker processes (acceptance floor: transport >= in-process
    # at the same shard count).  warmup >= 2: the first rounds carry the
    # jit compile on each side and would otherwise own the measurement.
    tx_warm, tx_rounds = (2, 6) if full else (2, 4)
    tx_sug = TX_CLIENTS * tx_rounds
    with tempfile.TemporaryDirectory() as d:
        in_dt, _ = _bench_federation(d, TX_SHARDS, TX_N_MAX, tx_warm,
                                     tx_rounds, clients=TX_CLIENTS,
                                     slots=TX_SLOTS, acq=TX_ACQ)
    with tempfile.TemporaryDirectory() as d:
        tx_dt, tsum = _bench_transport(d, TX_SHARDS, TX_N_MAX, tx_warm,
                                       tx_rounds, acq=TX_ACQ)
    tx_cells = [{
        "n_shards": TX_SHARDS,
        "clients": TX_CLIENTS,
        "slots_per_shard": TX_SLOTS,
        "n_max": TX_N_MAX,
        "restarts": TX_ACQ.restarts,
        "suggestions_per_sec": tx_sug / tx_dt,
        "round_ms": 1e3 * tx_dt / tx_rounds,
        "measured_evictions": tsum["measured_evictions"],
        "inproc_suggestions_per_sec": tx_sug / in_dt,
        "speedup_vs_inproc": in_dt / tx_dt,
    }]

    ops = CLIENTS * rounds
    rec = {
        "clients": CLIENTS,
        "n_max": n_max,
        "rounds": rounds,
        "coalesced_suggestions_per_sec": ops / co_s,
        "serialized_suggestions_per_sec": ops / ser_s,
        "coalesced_round_ms": 1e3 * co_s / rounds,
        "serialized_round_ms": 1e3 * ser_s / rounds,
        "speedup": ser_s / co_s,
        "mean_coalesce_width": summary["mean_coalesce_width"],
        "p50_tick_ms": summary["p50_tick_ms"],
        "p95_tick_ms": summary["p95_tick_ms"],
        # single-tenant 8-worker farm q-sweep; the pinned q=1 serialized-
        # tick baseline shares the q=8 cell's shape (acceptance floor:
        # q=8 >= 3x it)
        "farm_workers": FARM_WORKERS,
        "farm_q1_baseline_suggestions_per_sec": q1_base,
        "farm_cells": farm_cells,
        # horizontal scale-out: 256 clients over 1/2/4 shards (acceptance
        # floor: 2 shards >= 1.6x the 1-shard single-pool baseline)
        "fed_clients": FED_CLIENTS,
        "fed_slots_per_shard": FED_SLOTS,
        "fed_baseline_suggestions_per_sec": fed_base,
        "fed_cells": fed_cells,
        # cross-process deployment: 2 real shard workers over socket RPC
        # vs the in-process federation at the identical shape (acceptance
        # floor: transport >= in-process at the same shard count)
        "tx_clients": TX_CLIENTS,
        "tx_slots_per_shard": TX_SLOTS,
        "tx_cells": tx_cells,
    }
    import jax
    payload = {"backend": jax.default_backend(), "results": [rec]}
    with open(json_path, "w") as f:
        json.dump(payload, f, indent=2)
    rows = [
        f"serve_coalesced,{1e6 * co_s / ops:.0f},"
        f"suggest_per_s={rec['coalesced_suggestions_per_sec']:.1f} "
        f"width={rec['mean_coalesce_width']:.1f}",
        f"serve_serialized,{1e6 * ser_s / ops:.0f},"
        f"suggest_per_s={rec['serialized_suggestions_per_sec']:.1f}",
        f"serve_speedup,,{rec['speedup']:.2f}x_at_{CLIENTS}_clients",
    ]
    for cell in farm_cells:
        rows.append(
            f"serve_farm_q{cell['q']},"
            f"{1e6 / cell['suggestions_per_sec']:.0f},"
            f"suggest_per_s={cell['suggestions_per_sec']:.1f} "
            f"speedup_vs_q1={cell['speedup_vs_q1']:.2f}x")
    for cell in fed_cells:
        rows.append(
            f"serve_fed_{cell['n_shards']}shard,"
            f"{1e6 / cell['suggestions_per_sec']:.0f},"
            f"suggest_per_s={cell['suggestions_per_sec']:.1f} "
            f"speedup={cell['speedup_vs_single_pool']:.2f}x "
            f"p95_tick_ms={cell['p95_tick_ms']:.1f} "
            f"evictions={cell['measured_evictions']}")
    for cell in tx_cells:
        rows.append(
            f"serve_tx_{cell['n_shards']}proc,"
            f"{1e6 / cell['suggestions_per_sec']:.0f},"
            f"suggest_per_s={cell['suggestions_per_sec']:.1f} "
            f"speedup_vs_inproc={cell['speedup_vs_inproc']:.2f}x")
    rows.append(f"serve_json,,path={json_path}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
