"""Mixed-space benchmark: discrete HPO through the serving stack.

The paper only exercises all-continuous spaces; this bench pins the
beyond-paper mixed workload (DESIGN.md §10) end to end:

  * **Optimization** — a mixed synthetic objective (Levy over 2 floats +
    1 int + a 3-way categorical branch, global optimum 0 at
    x1 = x2 = 1, k = 1, branch = "b") served through `StudyGateway`
    ask–tell traffic.  Acceptance: the study reaches the known optimum
    *cell* (k = 1, branch = "b") within the trial budget; the JSON
    records the first-hit trial index and the final best value.
  * **Gram parity** — the mixed kernel must match the ref substrate to
    ≤ 1e-5 on all three substrates, at 1 device (inline) AND at 8
    virtual devices (subprocess, the CI mesh environment), where the
    sharded mixed suggest round must also agree with mesh="none".
  * **Throughput** — the mixed suggest round vs an all-continuous round
    of the same encoded width (the projection + categorical factor
    overhead, S = 8 studies).

Emits `name,us_per_call,derived` CSV rows for `benchmarks.run` and writes
`BENCH_mixed.json` (rendered into README.md by `benchmarks.report`).
"""
from __future__ import annotations

import asyncio
import json
import os
import subprocess
import sys
import tempfile
import time

JSON_PATH = "BENCH_mixed.json"
ENV_DEVICES = 8
BUDGET = 48             # gateway tells for the optimization section
PARITY_POINTS = 48      # gram sample size for the parity section
BRANCH_OFFSET = {"a": -4.0, "b": 0.0, "c": -2.0}
# The discrete optimum cell of the objective below (Levy optimum at the
# all-ones vector -> k = 1; branch "b" has the zero offset).  Rendered
# into the README by report.py, so it lives in the JSON, not in the table
# template.
OPTIMUM_CELL = {"k": 1, "branch": "b"}


def _mixed_space():
    from repro.hpo.space import Categorical, Dim, Int, SearchSpace
    return SearchSpace((
        Dim("x1", -10.0, 10.0),
        Dim("x2", -10.0, 10.0),
        Int("k", -3, 3),                       # third Levy coordinate
        Categorical("branch", ("a", "b", "c")),
    ))


def _objective(hp) -> float:
    import numpy as np

    from repro.core.levy import levy
    x = np.asarray([hp["x1"], hp["x2"], float(hp["k"])], np.float32)
    return float(-levy(x)) + BRANCH_OFFSET[hp["branch"]]


def _optimize_cell(seed: int = 0) -> dict:
    """Drive the mixed study through StudyGateway ask–tell traffic."""
    from repro.core.acquisition import AcqConfig
    from repro.hpo.gateway import GatewayConfig, StudyGateway
    from repro.hpo.pool import SchedulerConfig

    space = _mixed_space()
    with tempfile.TemporaryDirectory() as td:
        cfg = SchedulerConfig(
            n_max=BUDGET + 8, seed=seed, ckpt_dir=td,
            acq=AcqConfig(restarts=32, ascent_steps=16))
        gw = StudyGateway(space, cfg, GatewayConfig(slots=1))

        async def drive():
            sid = gw.create_study(name="mixed-levy")
            best, hit_at = -float("inf"), None
            t0 = time.perf_counter()
            for i in range(BUDGET):
                tr = await gw.ask(sid)
                hp = space.to_hparams(tr.unit)
                val = _objective(hp)
                gw.tell(sid, tr, val)
                in_cell = all(hp[k] == v for k, v in OPTIMUM_CELL.items())
                if in_cell and hit_at is None:
                    hit_at = i
                best = max(best, val)
            await gw.drain()
            return best, hit_at, time.perf_counter() - t0

        best, hit_at, elapsed = asyncio.run(drive())
    return {
        "budget": BUDGET,
        "best_value": best,
        "optimum_cell_hit": hit_at is not None,
        "first_cell_hit_trial": hit_at,
        "elapsed_s": elapsed,
        "tells_per_sec": BUDGET / elapsed,
    }


def _gram_parity() -> list[dict]:
    """Max |mixed_gram(impl) - mixed_gram(ref)| on a feasible sample —
    runs under whatever device count the calling process pinned."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels import ops

    space = _mixed_space()
    desc = space.descriptor()
    rng = np.random.default_rng(0)
    x = jnp.asarray(space.sample(rng, PARITY_POINTS))
    want = np.asarray(ops.mixed_gram(x, x, 1.0, 0.4, desc.cont_mask,
                                     desc.cat_mask, implementation="ref"))
    rows = []
    for impl in ("ref", "xla", "pallas"):
        got = np.asarray(ops.mixed_gram(x, x, 1.0, 0.4, desc.cont_mask,
                                        desc.cat_mask, implementation=impl))
        rows.append({
            "implementation": impl,
            "devices": len(jax.devices()),
            "max_abs_err": float(np.abs(got - want).max()),
            "pass_1e5": bool(np.abs(got - want).max() <= 1e-5),
        })
    return rows


def _sharded_round_parity() -> dict:
    """mesh='auto' vs mesh='none' mixed advance rounds (8-device cell).

    Gates, across device layouts: (a) every sharded suggestion is a
    FEASIBLE lattice point, (b) a given mesh spec is bitwise
    DETERMINISTIC run-to-run, (c) the sharded round's chosen suggestions
    score the same acquisition VALUE as the unsharded round's, and
    (d) cell IDENTITY — since the selection tie-break quantization in
    `optimize_acquisition`, restarts whose EI values differ only by
    cross-layout ulps collapse into one quantization bucket, so every
    layout picks the same winning restart and `identical_suggestion_frac`
    must be 1.0 (it was informational before that fix: exactly-tied
    local maxima at small n used to flip cells across layouts).
    """
    import jax
    import numpy as np

    from repro.core.acquisition import AcqConfig
    from repro.hpo.pool import SchedulerConfig, StudyPool

    space = _mixed_space()

    def drive(mesh: str) -> tuple[np.ndarray, np.ndarray]:
        cfg = SchedulerConfig(n_max=16, seed=0, mesh=mesh,
                              acq=AcqConfig(restarts=16, ascent_steps=8))
        pool = StudyPool([space] * 8, cfg)
        out = pool.advance_round([])
        pool.absorb_many([(s, out[s][0],
                           float(-np.sum(out[s][0].unit ** 2)))
                          for s in range(8)])
        units, vals = pool.engine.suggest_all(
            jax.vmap(jax.random.PRNGKey)(np.arange(8)), top_t=1)
        return np.asarray(units)[:, 0, :], np.asarray(vals)[:, 0]

    u_none, v_none = drive("none")
    u_auto, v_auto = drive("auto")
    u_auto2, v_auto2 = drive("auto")
    feasible = bool(np.allclose(space.project(u_auto), u_auto, atol=1e-6))
    deterministic = bool((u_auto == u_auto2).all()
                         and (v_auto == v_auto2).all())
    value_err = float(np.abs(v_none - v_auto).max())
    agree = float((np.abs(u_none - u_auto).max(axis=1) < 1e-5).mean())
    return {
        "feasible": feasible,
        "deterministic": deterministic,
        "acq_value_max_err": value_err,
        "acq_value_pass_1e4": value_err <= 1e-4,
        "identical_suggestion_frac": agree,
        # Hard gate (layout-stable top-t selection): every study's sharded
        # cell must match the unsharded one.
        "cell_identity_pass": bool(agree == 1.0),
    }


def _throughput() -> dict:
    """Mixed vs all-continuous suggest round at the same encoded width."""
    import jax
    import numpy as np

    from repro.core.acquisition import AcqConfig
    from repro.hpo.pool import SchedulerConfig, StudyPool
    from repro.hpo.space import Dim, SearchSpace

    mixed = _mixed_space()
    cont = SearchSpace(tuple(Dim(f"f{i}", 0.0, 1.0)
                             for i in range(mixed.dim)))

    def time_rounds(space) -> float:
        cfg = SchedulerConfig(n_max=64, seed=0,
                              acq=AcqConfig(restarts=16, ascent_steps=16))
        pool = StudyPool([space] * 8, cfg)
        out = pool.advance_round([])
        times = []
        for r in range(12):
            ev = [(s, out[s][0], float(-np.sum(out[s][0].unit ** 2)))
                  for s in range(8)]
            t0 = time.perf_counter()
            out = pool.advance_round(ev)
            jax.block_until_ready(pool.engine.state.l_buf)
            times.append(time.perf_counter() - t0)
        times.sort()
        return times[len(times) // 2]          # median; first rounds warm

    mixed_s = time_rounds(mixed)
    cont_s = time_rounds(cont)
    return {
        "n_studies": 8,
        "mixed_round_us": 1e6 * mixed_s,
        "continuous_round_us": 1e6 * cont_s,
        "mixed_overhead": mixed_s / cont_s,
    }


def _cell_8dev() -> dict:
    """The 8-virtual-device parity cell (runs inside the subprocess)."""
    return {"gram_parity": _gram_parity(),
            "sharded_round": _sharded_round_parity()}


def _run_8dev_subprocess() -> dict:
    env = dict(os.environ)
    kept = [f for f in env.get("XLA_FLAGS", "").split()
            if not f.startswith("--xla_force_host_platform_device_count")]
    env["XLA_FLAGS"] = " ".join(
        [f"--xla_force_host_platform_device_count={ENV_DEVICES}"] + kept)
    code = ("import json, benchmarks.bench_mixed as b;"
            "print('CELL::' + json.dumps(b._cell_8dev()))")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    for line in out.stdout.splitlines():
        if line.startswith("CELL::"):
            return json.loads(line[len("CELL::"):])
    raise RuntimeError(
        f"8-device mixed cell produced no result (exit {out.returncode}): "
        f"{out.stderr[-500:]}")


def run(full: bool = False, json_path: str = JSON_PATH):
    del full  # budgets are already tier-1-sized
    opt = _optimize_cell()
    parity_1 = _gram_parity()
    cell8 = _run_8dev_subprocess()
    thr = _throughput()
    payload = {
        "space": "levy2f + int[-3,3] + cat3 (encoded width 7)",
        "budget": BUDGET,
        "optimum_cell": ", ".join(f"{k} = {v}"
                                  for k, v in OPTIMUM_CELL.items()),
        "optimize": opt,
        "gram_parity_1dev": parity_1,
        "gram_parity_8dev": cell8["gram_parity"],
        "sharded_round_8dev": cell8["sharded_round"],
        "throughput": thr,
    }
    with open(json_path, "w") as f:
        json.dump(payload, f, indent=2)
    worst = max(r["max_abs_err"]
                for r in parity_1 + cell8["gram_parity"])
    sh = cell8["sharded_round"]
    return [
        f"mixed_gateway_levy,,best={opt['best_value']:.3f} "
        f"cell_hit={opt['optimum_cell_hit']} "
        f"first_hit_trial={opt['first_cell_hit_trial']}",
        f"mixed_gram_parity,,max_err={worst:.2e} (floor 1e-5, 1+8 devices)",
        f"mixed_sharded_round,,feasible={sh['feasible']} "
        f"deterministic={sh['deterministic']} "
        f"acq_value_err={sh['acq_value_max_err']:.2e} "
        f"cell_identity={sh['cell_identity_pass']} "
        f"identical_frac={sh['identical_suggestion_frac']:.2f}",
        f"mixed_round,{thr['mixed_round_us']:.0f},"
        f"overhead_vs_continuous={thr['mixed_overhead']:.2f}x",
        f"mixed_json,,path={json_path}",
    ]


if __name__ == "__main__":
    print("\n".join(run(full="--full" in sys.argv)))
