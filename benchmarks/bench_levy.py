"""Paper Tab. 1: 5-D Levy convergence, naive vs lazy, 1 seed vs 100 seeds.

Reproduces the paper's protocol: maximize -Levy_5D on [-10, 10]^5; record
the iterations at which the running best crosses accuracy milestones, plus
wall-clock split (GP factorization vs acquisition time).  Paper's qualita-
tive claims under test:
  * lazy reaches near-optimum without getting trapped (1-seed: paper -0.01
    at iter 611 of 1000);
  * naive per-iteration cost explodes (its accuracy may be fine — the
    paper's own Tab. 1 shows naive trapped at -4.x with 1 seed);
  * lazy GP time per iteration stays ~flat.
"""
from __future__ import annotations

import numpy as np

from repro.core import levy, run_bo

MILESTONES = (-5.0, -2.0, -1.0, -0.5, -0.25, -0.1, -0.05, -0.01)


def _milestones(hist):
    out = {}
    for m in MILESTONES:
        it = hist.iterations_to(m)
        if it is not None:
            out[m] = it
    return out


def run(iterations: int = 300, full: bool = False,
        implementation: str = "auto"):
    import jax.numpy as jnp

    from repro.core import levy_bounds, neg_levy
    iterations = 1000 if full else iterations
    obj = lambda x: np.asarray(neg_levy(jnp.asarray(x)))
    lo, hi = levy_bounds(5)

    out = []
    for mode, lag, rho0 in (("naive", 1, 0.25), ("lazy", 0, 1.0),
                            ("lazy", 0, 0.25)):
        for n_seed in (1, 100):
            tag = f"levy5d_{mode}_rho{rho0}_seed{n_seed}"
            budget = iterations if mode == "lazy" else max(
                iterations // 3, 100)  # naive's O(n^3) refits are slow
            _, hist = run_bo(obj, lo, hi, budget, dim=5, mode=mode,
                             n_seed=n_seed, n_max=budget + n_seed + 8,
                             seed=0, rho0=rho0,
                             implementation=implementation)
            ms = _milestones(hist)
            gp_us = 1e6 * float(np.mean(hist.gp_seconds))
            best = hist.best()[1]
            out.append(
                f"{tag},{gp_us:.0f},best={best:.3f}"
                f" milestones={'|'.join(f'{k}:{v}' for k, v in ms.items())}")
    return out


if __name__ == "__main__":
    print("\n".join(run()))
