"""Append the final §Dry-run / §Roofline / §Perf tables to EXPERIMENTS.md
from the dry-run result files.  Run after the final matrix:

    python -m benchmarks.finalize_experiments
"""
from __future__ import annotations

import json
import os

from benchmarks.roofline import analyse, format_table

RESULTS = "results"
OUT = "EXPERIMENTS.md"


def load(path):
    recs = []
    if os.path.exists(path):
        with open(path) as f:
            recs = [json.loads(l) for l in f if l.strip()]
    return recs


def main():
    single = load(f"{RESULTS}/final_single.jsonl")
    multi = load(f"{RESULTS}/final_multi.jsonl")

    lines = ["\n---\n\n## Final state (optimized framework)\n"]

    # --- dry-run summary -------------------------------------------------
    for name, recs in (("16x16 single-pod", single),
                       ("2x16x16 multi-pod", multi)):
        ok = [r for r in recs if r["status"] == "ok"]
        sk = [r for r in recs if r["status"] == "skipped"]
        er = [r for r in recs if r["status"] == "error"]
        lines.append(f"**{name}**: {len(ok)} cells compiled, "
                     f"{len(sk)} documented skips, {len(er)} errors.")
        if er:
            for r in er:
                lines.append(f"  * ERROR {r['arch']} {r['shape']}: "
                             f"{r['error'][:160]}")
    lines.append("")

    # --- per-cell memory table (both meshes) ------------------------------
    lines.append("### §Dry-run: per-device memory (GB) and compile time\n")
    lines.append("| arch | shape | 16x16 peak GB | 2x16x16 peak GB | "
                 "compile s (single) |")
    lines.append("|---|---|---|---|---|")
    multi_idx = {(r["arch"], r["shape"]): r for r in multi
                 if r["status"] == "ok"}
    for r in sorted(single, key=lambda r: (r["arch"], r["shape"])):
        if r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | — skip: "
                         f"{r['reason']} | — | — |")
            continue
        if r["status"] != "ok":
            continue
        m = multi_idx.get((r["arch"], r["shape"]))
        mm = (f"{m['memory']['peak_per_device_bytes'] / 1e9:.2f}"
              if m else "?")
        lines.append(
            f"| {r['arch']} | {r['shape']} | "
            f"{r['memory']['peak_per_device_bytes'] / 1e9:.2f} | {mm} | "
            f"{r['compile_seconds']} |")
    lines.append("")

    # --- roofline table ----------------------------------------------------
    lines.append("### §Roofline: final single-pod table\n")
    lines.append("(terms in seconds/step at v5e constants; `useful` = "
                 "MODEL_FLOPS/HLO_FLOPs; `roofl.` = useful-compute time over "
                 "the dominant bound)\n")
    rows = [analyse(r) for r in single]
    rows = [r for r in rows if r]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    lines.append("```")
    lines.append(format_table(rows))
    lines.append("```\n")

    # --- dominant-term summaries -------------------------------------------
    lines.append("Per-cell dominant bottleneck + the one-line lever:\n")
    from benchmarks.roofline import whats_limiting
    for r in rows:
        lines.append(f"* `{r['arch']} x {r['shape']}`: {r['dominant']}-bound "
                     f"(bound {r['bound_s']:.3f}s, roofline fraction "
                     f"{r['roofline_fraction']:.3f}) — {whats_limiting(r)}")
    lines.append("")

    with open(OUT, "a") as f:
        f.write("\n".join(lines) + "\n")
    print(f"appended final tables to {OUT} ({len(rows)} roofline rows)")


if __name__ == "__main__":
    main()
