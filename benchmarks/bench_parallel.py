"""Paper Tab. 4 / Sec. 3.4: parallel batch BO via top-t EI local maxima.

Compares sequential lazy BO against the parallel scheduler (t suggestions
per round, absorbed as t O(n^2) appends) on the 5-D Levy objective —
the paper's parallel ResNet experiment used t = 20 over 20 GPUs; here the
"cluster" is simulated by evaluating the batch in one vectorized call, and
the metric is *rounds* (wall-clock analogue) and total evaluations to reach
the target accuracy.
"""
from __future__ import annotations

import numpy as np

from repro.core import levy_bounds, neg_levy, run_bo

TARGET = -0.5


def run(rounds: int = 60, full: bool = False, implementation: str = "auto"):
    import jax.numpy as jnp
    rounds = 150 if full else rounds
    obj = lambda x: np.asarray(neg_levy(jnp.asarray(x)))
    lo, hi = levy_bounds(5)

    out = []
    for t in (1, 5, 20):
        n_rounds = rounds if t == 1 else max(rounds // t * 2, 15)
        _, hist = run_bo(obj, lo, hi, n_rounds, dim=5, mode="lazy",
                         batch_size=t, n_seed=5,
                         n_max=n_rounds * t + 16, seed=0,
                         implementation=implementation)
        # round index at which target first reached
        evals_to = hist.iterations_to(TARGET)
        rounds_to = None if evals_to is None else max(
            0, (evals_to - 5 + t - 1)) // t + 1
        gp_us = 1e6 * float(np.mean(hist.gp_seconds))
        out.append(
            f"parallel_t{t},{gp_us:.0f},rounds_to_{TARGET}={rounds_to} "
            f"evals_to={evals_to} best={hist.best()[1]:.3f} "
            f"total_evals={len(hist.ys)}")
    return out


if __name__ == "__main__":
    print("\n".join(run()))
