"""Paper Fig. 1 + Tabs. 2/3 analog: HPO of a real network trainer.

The paper tunes LeNet5/MNIST and ResNet32/CIFAR10 (lr, weight decay,
momentum, dropout keeps).  No image datasets ship offline, so the stand-in
objective is the framework's own trainer on `tiny-lm` with the synthetic
token pipeline, tuned over the paper's ResNet-style space (lr, wd,
momentum; SGD-momentum optimizer) — the HPO mechanics (expensive black-box
trial + GP overhead share) are identical.

Measured: per-iteration split of trial-training time vs GP time (the
paper's Fig. 1 overhead comparison), and the accuracy trajectory
(iterations at which the best validation accuracy improves — Tabs. 2/3).
"""
from __future__ import annotations

import numpy as np

from repro.core import run_bo
from repro.hpo.space import RESNET_SPACE


def make_objective(steps: int = 25, seq_len: int = 64, batch: int = 8):
    import jax

    from repro.configs import get_config
    from repro.data import DataConfig, DataIterator
    from repro.optim import OptimizerConfig, init_opt_state
    from repro.training import make_eval_step, make_train_step

    cfg = get_config("tiny-lm", reduced=True)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=seq_len,
                      global_batch=batch, seed=7)
    from repro.models import init_params
    params0, _ = init_params(cfg, jax.random.PRNGKey(1))
    eval_step = jax.jit(make_eval_step(cfg))
    eval_batch = DataIterator(dcfg, start_step=10_000).__next__()

    # One jitted train step per hyper-parameter setting would recompile per
    # trial; close over hparams as *arrays* instead so all trials share one
    # executable (standard trick for HPO over continuous optimizer knobs).
    import jax.numpy as jnp

    from repro.optim.optimizers import clip_by_global_norm

    def sgdm_step(params, mu, batch, lr, wd, mom):
        def loss_fn(p):
            from repro.models import lm_loss
            loss, m = lm_loss(p, cfg, batch)
            return loss, m

        (loss, m), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        grads, _ = clip_by_global_norm(
            jax.tree.map(lambda g: g.astype(jnp.float32), grads), 1.0)
        mu = jax.tree.map(lambda a, g: mom * a + g, mu, grads)
        params = jax.tree.map(
            lambda p, a: (p.astype(jnp.float32)
                          - lr * (a + wd * p.astype(jnp.float32))
                          ).astype(p.dtype), params, mu)
        return params, mu, loss

    jit_step = jax.jit(sgdm_step)

    def objective(units: np.ndarray) -> np.ndarray:
        outs = []
        for u in np.atleast_2d(units):
            hp = RESNET_SPACE.to_hparams(u)
            params = jax.tree.map(lambda x: x, params0)
            mu = jax.tree.map(lambda x: jnp.zeros_like(x), params)
            it = DataIterator(dcfg)
            for _ in range(steps):
                params, mu, _ = jit_step(
                    params, mu, next(it),
                    jnp.asarray(hp["lr"], jnp.float32),
                    jnp.asarray(hp["weight_decay"], jnp.float32),
                    jnp.asarray(hp["momentum"], jnp.float32))
            metrics = eval_step(params, eval_batch)
            outs.append(float(metrics["accuracy"]))
        return np.asarray(outs)

    return objective


def run(iterations: int = 40, full: bool = False,
        implementation: str = "auto"):
    iterations = 120 if full else iterations
    obj = make_objective()
    lo = np.zeros(RESNET_SPACE.dim)
    hi = np.ones(RESNET_SPACE.dim)

    out = []
    for mode in ("lazy", "naive"):
        budget = iterations if mode == "lazy" else max(iterations // 2, 10)
        _, hist = run_bo(lambda u: obj(u), lo, hi, budget, dim=RESNET_SPACE.dim,
                         mode=mode, n_seed=4, n_max=budget + 12, seed=0,
                         implementation=implementation)
        train_s = float(np.mean(hist.obj_seconds))
        gp_s = float(np.mean(hist.gp_seconds))
        overhead = gp_s / max(train_s + gp_s, 1e-9)
        # accuracy improvement trajectory (Tab. 2/3 format)
        traj, best = [], -np.inf
        for i, y in enumerate(hist.ys):
            if y > best:
                best = y
                traj.append((i, round(y, 3)))
        out.append(
            f"nn_hpo_{mode},{1e6 * gp_s:.0f},"
            f"train_s_per_iter={train_s:.3f} gp_overhead_frac={overhead:.3f} "
            f"best_acc={hist.best()[1]:.3f} "
            f"traj={'|'.join(f'{i}:{a}' for i, a in traj[-6:])}")
    return out


if __name__ == "__main__":
    print("\n".join(run()))
