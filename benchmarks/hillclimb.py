"""§Perf hillclimb driver: run named variants of the three chosen cells.

Each variant is a (hypothesis, change) pair from EXPERIMENTS.md §Perf; this
script lowers+compiles the cell per variant and prints the three roofline
terms so the before/after lands in the iteration log.

    python -m benchmarks.hillclimb --cell qwen3 --out results/hc_qwen3.jsonl
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import argparse
import json

CELLS = {
    "qwen3": ("qwen3-moe-30b-a3b", "train_4k"),
    "granite-moe": ("granite-moe-3b-a800m", "train_4k"),
    "deepseek": ("deepseek-coder-33b", "train_4k"),
    "minicpm3-decode": ("minicpm3-4b", "decode_32k"),
}

# variant -> (cfg_overrides, train_overrides, seq_parallel)
VARIANTS = {
    # paper-order baseline for the cell (SP on: the no-SP ablation OOMs)
    "base": ({}, {}, True),
    "no-sp": ({}, {}, False),
    "moe-cumsum": ({"moe_dispatch": "cumsum"}, {}, True),
    "bf16-grads": ({}, {"bf16_grads": True}, True),
    "remat-dots": ({"remat_policy": "dots"}, {}, True),
    "bf16+dots": ({"remat_policy": "dots"}, {"bf16_grads": True}, True),
    "bf16+dots+ef": ({"remat_policy": "dots"},
                     {"bf16_grads": True}, True),   # + compress_grads below
}
OPT_VARIANTS = {"bf16+dots+ef": {"compress_grads": True}}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, choices=list(CELLS))
    ap.add_argument("--variants", default=None,
                    help="comma list; default = sensible set per cell")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    from benchmarks.roofline import analyse
    from repro.launch.dryrun import run_cell

    arch, shape = CELLS[args.cell]
    if args.variants:
        names = args.variants.split(",")
    elif "moe" in arch:
        names = ["base", "moe-cumsum", "bf16-grads", "bf16+dots"]
    else:
        names = ["base", "bf16-grads", "remat-dots", "bf16+dots"]

    for name in names:
        cfg_o, train_o, sp = VARIANTS[name]
        rec = run_cell(arch, shape, False, seq_parallel=sp,
                       cfg_overrides=cfg_o, train_overrides=train_o,
                       opt_overrides=OPT_VARIANTS.get(name))
        rec["variant"] = name
        row = analyse(rec) if rec["status"] == "ok" else None
        if row:
            print(f"{name:14s} comp={row['t_compute_s']:.3f}s "
                  f"mem={row['t_memory_s']:.3f}s "
                  f"coll={row['t_collective_s']:.3f}s "
                  f"dom={row['dominant']:10s} "
                  f"roofline={row['roofline_fraction']:.4f} "
                  f"peakGB={row['peak_mem_gb']:.2f}", flush=True)
        else:
            print(f"{name:14s} {rec['status']}: "
                  f"{rec.get('error', '')[:160]}", flush=True)
        if args.out:
            with open(args.out, "a") as f:
                f.write(json.dumps(rec) + "\n")


if __name__ == "__main__":
    main()
