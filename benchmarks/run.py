"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

  * bench_cholesky — Fig. 5 (naive O(n^3) vs lazy O(n^2) factorization)
  * bench_levy     — Tab. 1 (5-D Levy convergence, 1 vs 100 seeds)
  * bench_lag      — Fig. 6 (lagging-factor sweep)
  * bench_nn_hpo   — Fig. 1 + Tabs. 2/3 (network-trainer HPO overhead)
  * bench_parallel — Tab. 4 (top-t parallel suggestions)
  * bench_substrate — one BO step per (mode x linalg implementation),
                      emits BENCH_substrate.json
  * bench_pool     — multi-tenant StudyPool vs S sequential schedulers,
                      emits BENCH_pool.json
  * bench_shard    — device-mesh suggest-round scaling at 1/2/4/8 devices,
                      emits BENCH_shard.json
  * bench_serve    — coalesced ask–tell gateway vs per-client dispatches
                      at 16 concurrent clients, emits BENCH_serve.json
  * bench_mixed    — mixed (float/int/categorical) space through the
                      gateway + mixed-gram substrate parity at 1 and 8
                      virtual devices, emits BENCH_mixed.json
  * bench_tier     — saturation escalation tier: suggest latency past
                      n_max (lazy-GP quadratic vs flat neural-basis) and
                      EI-per-unit-cost vs plain EI at a fixed evaluation
                      cost budget, emits BENCH_tier.json

`python -m benchmarks.run [--full] [--only NAME]`.  The roofline analysis
(§Roofline) is separate: `python -m benchmarks.roofline results/*.jsonl`
over the dry-run output.
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale iteration counts (slow)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from benchmarks import (bench_cholesky, bench_lag, bench_levy,
                            bench_mixed, bench_nn_hpo, bench_parallel,
                            bench_pool, bench_serve, bench_shard,
                            bench_substrate, bench_tier)
    suites = {
        "cholesky": lambda: bench_cholesky.run(full=args.full),
        "levy": lambda: bench_levy.run(full=args.full),
        "lag": lambda: bench_lag.run(full=args.full),
        "nn_hpo": lambda: bench_nn_hpo.run(full=args.full),
        "parallel": lambda: bench_parallel.run(full=args.full),
        "substrate": lambda: bench_substrate.run(full=args.full),
        "pool": lambda: bench_pool.run(full=args.full),
        "shard": lambda: bench_shard.run(full=args.full),
        "serve": lambda: bench_serve.run(full=args.full),
        "mixed": lambda: bench_mixed.run(full=args.full),
        "tier": lambda: bench_tier.run(full=args.full),
    }
    print("name,us_per_call,derived")
    for name, fn in suites.items():
        if args.only and name != args.only:
            continue
        t0 = time.time()
        try:
            for row in fn():
                print(row, flush=True)
        except Exception as e:  # pragma: no cover
            print(f"{name}_FAILED,,{type(e).__name__}: {e}", flush=True)
            raise
        print(f"# {name} suite: {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
