"""Paper Fig. 5: per-iteration Cholesky cost, naive O(n^3) vs lazy O(n^2).

Simulates the BO loop's factorization work at growing n:
  * naive  — rebuild K and fully refactorize (XLA cholesky) every iteration
             (the paper's baseline; its reference code used a scalar loop,
             which is also measured once at small n as `alg2_literal`).
  * lazy   — one incremental row append (padded trsv + row write).

Reports per-iteration microseconds, the cumulative-time speedup over the
sweep, and fitted growth exponents.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cholesky as chol
from repro.core.kernels import KernelParams, matern52
from repro.kernels import ops


def _time(fn, *args, reps=5) -> float:
    fn(*args)  # compile
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def run(n_max: int = 1024, step: int = 128, full: bool = False,
        implementation: str = "auto"):
    params = KernelParams.default()
    key = jax.random.PRNGKey(0)
    xs = jax.random.uniform(key, (n_max + 1, 5))
    rows = []

    naive_fn = jax.jit(
        lambda k: ops.cholesky(k, implementation=implementation))
    append_fn = jax.jit(
        lambda l, p, c, n: chol.lazy_append_row(
            l, p, c, n, n_max=n_max, implementation=implementation),
        static_argnames=())

    sizes = list(range(step, n_max + 1, step))
    cum_naive = cum_lazy = 0.0
    for n in sizes:
        k_n = matern52(xs[:n], xs[:n], params) + 1e-6 * jnp.eye(n)
        t_naive = _time(naive_fn, k_n)

        l_pad = chol.identity_pad_factor(naive_fn(k_n), n_max)
        p_pad = jnp.zeros((n_max,)).at[:n].set(
            matern52(xs[:n], xs[n:n + 1], params)[:, 0])
        c = matern52(xs[n:n + 1], xs[n:n + 1], params)[0, 0] + 1e-6
        t_lazy = _time(append_fn, l_pad, p_pad, c, jnp.asarray(n, jnp.int32))

        cum_naive += t_naive
        cum_lazy += t_lazy
        rows.append((n, t_naive * 1e6, t_lazy * 1e6))

    # growth exponents from the last half of the sweep
    ns = np.array([r[0] for r in rows], float)
    tn = np.array([r[1] for r in rows], float)
    tl = np.array([r[2] for r in rows], float)
    half = len(ns) // 2
    exp_naive = np.polyfit(np.log(ns[half:]), np.log(tn[half:]), 1)[0]
    exp_lazy = np.polyfit(np.log(ns[half:]), np.log(tl[half:]), 1)[0]

    out = []
    for n, a, b in rows:
        out.append(f"cholesky_naive_n{n},{a:.1f},")
        out.append(f"cholesky_lazy_n{n},{b:.1f},speedup={a / b:.1f}x")
    out.append(f"cholesky_cumulative,,"
               f"speedup={cum_naive / cum_lazy:.1f}x")
    out.append(f"cholesky_growth_exponents,,naive~n^{exp_naive:.2f}"
               f" lazy~n^{exp_lazy:.2f}")

    if full:
        # the paper's literal Alg. 2 scalar loop, small n (it is slow)
        n = 256
        k_n = matern52(xs[:n], xs[:n], params) + 1e-6 * jnp.eye(n)
        t_lit = _time(jax.jit(chol.cholesky_naive), k_n, reps=2)
        out.append(f"cholesky_alg2_literal_n{n},{t_lit * 1e6:.1f},")
    return out


if __name__ == "__main__":
    print("\n".join(run()))
