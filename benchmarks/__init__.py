"""Benchmark suites: one per paper table/figure, plus roofline + hillclimb."""
