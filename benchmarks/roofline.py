"""Roofline analysis over dry-run results (EXPERIMENTS.md §Roofline).

Reads the JSONL written by `repro.launch.dryrun` and derives, per cell:

    compute term    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = collective_link_bytes_per_device / link_bw

(The dry-run records the *per-partition* HLO module, so the three terms are
per-chip already; dividing global totals by chip count gives the same
numbers.)  Hardware constants are TPU v5e per the assignment:
197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.

Also reports MODEL_FLOPS = 6*N*D (train) or 2*N_active*D (decode/prefill
fwd-only) and the MODEL_FLOPS / HLO_FLOPs usefulness ratio that catches
remat/causal-masking/redundancy waste.
"""
from __future__ import annotations

import argparse
import json
import sys

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
LINK_BW = 50e9               # bytes/s / link (ICI)

SHAPE_TOKENS = {
    "train_4k": 4096 * 256,
    "prefill_32k": 32768 * 32,
    "decode_32k": 128,       # one new token per sequence
    "long_500k": 1,
}
SHAPE_FACTOR = {             # useful FLOPs per param per token
    "train_4k": 6.0,         # fwd 2 + bwd 4
    "prefill_32k": 2.0,      # fwd only
    "decode_32k": 2.0,
    "long_500k": 2.0,
}


def analyse(record: dict) -> dict | None:
    if record.get("status") != "ok" or "cost" not in record:
        return None
    n_dev = record["n_devices"]
    flops_dev = record["cost"]["flops_per_device"]
    bytes_dev = record["cost"]["bytes_accessed_per_device"]
    link_dev = record["collectives"]["total_link_bytes"]

    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_collective = link_dev / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_collective}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())

    shape = record["shape"]
    n_active = record["model"]["n_active_params"]
    model_flops = (SHAPE_FACTOR[shape] * n_active * SHAPE_TOKENS[shape])
    model_flops_dev = model_flops / n_dev
    useful_ratio = model_flops_dev / max(flops_dev, 1.0)
    # roofline fraction: time the chip would spend doing useful model math at
    # peak, over the bound imposed by the dominant term.
    t_useful = model_flops_dev / PEAK_FLOPS
    roofline_frac = t_useful / max(bound, 1e-12)

    return {
        "arch": record["arch"],
        "shape": shape,
        "mesh": record["mesh"],
        "seq_parallel": record.get("seq_parallel", False),
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_collective,
        "dominant": dominant,
        "bound_s": bound,
        "model_flops_per_device": model_flops_dev,
        "hlo_flops_per_device": flops_dev,
        "useful_ratio": useful_ratio,
        "roofline_fraction": roofline_frac,
        "peak_mem_gb": record["memory"]["peak_per_device_bytes"] / 1e9,
    }


def whats_limiting(row: dict) -> str:
    d = row["dominant"]
    if d == "collective":
        return ("shrink/overlap the TP+DP collectives (SP activations, "
                "reduce-scatter grads, bf16 payloads, 2D sharding)")
    if d == "memory":
        return ("cut HBM traffic: larger fusion blocks, bf16 intermediates, "
                "avoid materialized score/logit buffers, better remat policy")
    return ("raise MXU utilization: remove causal-mask waste, pad-free "
            "shapes, reduce remat recompute")


def format_table(rows: list[dict]) -> str:
    hdr = (f"| {'arch':22s} | {'shape':11s} | {'mesh':7s} | comp s | mem s  "
           f"| coll s | dominant   | useful | roofl. | mem GB |")
    sep = "|" + "-" * (len(hdr) - 2) + "|"
    lines = [hdr, sep]
    for r in rows:
        lines.append(
            f"| {r['arch']:22s} | {r['shape']:11s} | {r['mesh']:7s} "
            f"| {r['t_compute_s']:6.3f} | {r['t_memory_s']:6.3f} "
            f"| {r['t_collective_s']:6.3f} | {r['dominant']:10s} "
            f"| {r['useful_ratio']:6.3f} | {r['roofline_fraction']:6.3f} "
            f"| {r['peak_mem_gb']:6.2f} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("jsonl", nargs="+")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()
    rows = []
    for path in args.jsonl:
        with open(path) as f:
            for line in f:
                if not line.strip():
                    continue
                row = analyse(json.loads(line))
                if row:
                    rows.append(row)
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    print(format_table(rows))
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
