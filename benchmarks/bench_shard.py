"""Device-mesh scaling benchmark: the paper's parallel-environment figure.

The paper's final claim is a further speedup from running the lazy-GP
optimizer "in a parallel environment".  This bench measures the repro's
version of that figure: **suggest-round throughput of the sharded engine
at 1/2/4/8 devices** for S in {8, 64} concurrent studies.

Method (see DESIGN.md §8):

  * The environment is FIXED at 8 virtual devices
    (``XLA_FLAGS=--xla_force_host_platform_device_count=8``, the CI
    recipe); the scaling variable is how many of them the mesh uses —
    exactly how a pod-scaling benchmark uses 1/2/4/8 chips of a slice.
    Each cell runs in its own subprocess because the device count must be
    pinned before jax initializes.
  * Every cell drives the same code path: `StudyEngine.advance` — the
    fused masked-absorb + batched-suggest serving round with donated
    state.  The 1-device cell resolves ``mesh="auto"`` to the unsharded
    program (mesh=none), so the baseline is the production single-device
    path, not a 1-device shard_map curiosity.
  * Rounds are timed individually (blocking); the per-cell statistic is
    the median round of the faster of two subprocess runs (hyperfine-style
    best-of-N, applied identically to every cell) — robust to the
    noisy-neighbor phases a shared host produces.

Emits `name,us_per_call,derived` CSV rows for `benchmarks.run` and writes
`BENCH_shard.json` with the full scaling table plus `speedup_8v1_S64`,
the headline ratio (acceptance: >= 2x on a machine with >= 2 cores; on a
real 8-accelerator mesh the expected ratio is near the device count).

Numerical parity of mesh=none vs the sharded path is a test, not a bench
(`tests/test_shard.py`, all three substrates).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

JSON_PATH = "BENCH_shard.json"
ENV_DEVICES = 8
MESH_SIZES = (1, 2, 4, 8)
STUDY_SIZES = (64, 8)
CELL_REPEATS = 2        # subprocess runs per cell; keep the faster median
SETTLE_S = 3.0          # pause between cells (allocator/cache settle)

# The workload (chosen so a 64-study round is compute-meaningful but each
# device shard stays cache-resident on CPU hosts; see DESIGN.md §8):
N_MAX = 128
DIM = 3
RESTARTS = 16
ASCENT_STEPS = 16
N0 = 64           # observations prefilled per study before timing
TOP_T = 1         # suggestions per study per round


def _cell(n_studies: int, mesh_devices: int, rounds: int) -> dict:
    """One (S, device-count) measurement; runs inside the subprocess."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import gp as gp_mod
    from repro.core.acquisition import AcqConfig
    from repro.hpo import mesh as mesh_mod
    from repro.hpo.engine import StudyEngine
    from repro.hpo.pool import SchedulerConfig

    devices = jax.devices()[:mesh_devices]
    hpo_mesh = mesh_mod.build("auto", n_studies, RESTARTS, devices=devices)
    spec = (f"{hpo_mesh.study_shards}x{hpo_mesh.restart_shards}"
            if hpo_mesh else "none")
    cfg = SchedulerConfig(n_max=N_MAX, seed=0, mesh=spec,
                          acq=AcqConfig(restarts=RESTARTS,
                                        ascent_steps=ASCENT_STEPS))
    engine = StudyEngine(DIM, cfg, n_studies)

    # Untimed prefill: N0 observations per study through the batched append.
    rng = np.random.default_rng(0)
    xs = jnp.asarray(rng.uniform(size=(n_studies, N0, DIM)), jnp.float32)
    ys = jnp.asarray(rng.uniform(size=(n_studies, N0)), jnp.float32)
    engine.state = engine.place(
        gp_mod.append_batch(engine.state, engine.kernel, xs, ys,
                            implementation=cfg.implementation))
    jax.block_until_ready(engine.state.l_buf)

    # The timed quantity is the device-side suggest round: absorb last
    # round's values, suggest the next point for all S studies.  Suggested
    # units stay device-resident between rounds (the sharded output feeds
    # the next round's absorb directly); the per-round host traffic is the
    # trainer values + flags, pre-staged outside the timer.  Host-side
    # trial materialization is a constant measured by bench_pool.
    keys = jax.random.split(jax.random.PRNGKey(0), n_studies)
    sharding = hpo_mesh.study_sharding() if hpo_mesh else None
    if sharding is not None:
        keys = jax.device_put(keys, sharding)
    flags = np.ones((n_studies,), bool)
    units = jnp.asarray(rng.uniform(size=(n_studies, DIM)), jnp.float32)
    if sharding is not None:
        units = jax.device_put(units, sharding)
    all_vals = [jnp.asarray(rng.uniform(size=(n_studies,)), jnp.float32)
                for _ in range(rounds + 2)]
    if sharding is not None:
        all_vals = [jax.device_put(v, sharding) for v in all_vals]

    def one_round(units, vals):
        u, _ = engine.advance(flags, units, vals, keys, top_t=TOP_T)
        u = u[:, 0, :]
        jax.block_until_ready(u)
        return u

    for r in range(2):                       # compile + first-exec warmup
        units = one_round(units, all_vals[r])
    times = []
    for r in range(rounds):
        t0 = time.perf_counter()
        units = one_round(units, all_vals[2 + r])
        times.append(time.perf_counter() - t0)
    times.sort()
    med = times[len(times) // 2]
    return {
        "n_studies": n_studies,
        "mesh_devices": mesh_devices,
        "mesh": spec,
        "rounds": rounds,
        "round_us_median": 1e6 * med,
        "round_us_p25": 1e6 * times[len(times) // 4],
        "rounds_per_sec": 1.0 / med,
        "suggestions_per_sec": n_studies / med,
    }


def _run_cell_subprocess(n_studies: int, mesh_devices: int,
                         rounds: int) -> dict:
    """Pin the virtual device count before jax init: one process per cell."""
    env = dict(os.environ)
    kept = [f for f in env.get("XLA_FLAGS", "").split()
            if not f.startswith("--xla_force_host_platform_device_count")]
    env["XLA_FLAGS"] = " ".join(
        [f"--xla_force_host_platform_device_count={ENV_DEVICES}"] + kept)
    code = (
        "import json, benchmarks.bench_shard as b;"
        f"print('CELL::' + json.dumps(b._cell({n_studies}, {mesh_devices}, "
        f"{rounds})))")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    for line in out.stdout.splitlines():
        if line.startswith("CELL::"):
            return json.loads(line[len("CELL::"):])
    raise RuntimeError(
        f"bench cell S={n_studies} d={mesh_devices} produced no result "
        f"(exit {out.returncode}): {out.stderr[-500:]}")


def run(full: bool = False, json_path: str = JSON_PATH):
    rounds = 40 if full else 30
    cells = []
    out = []
    for s in STUDY_SIZES:
        for nd in MESH_SIZES:
            runs = []
            for _ in range(CELL_REPEATS):
                time.sleep(SETTLE_S)
                runs.append(_run_cell_subprocess(s, nd, rounds))
            rec = min(runs, key=lambda r: r["round_us_median"])
            cells.append(rec)
            out.append(
                f"shard_S{s}_d{nd},{rec['round_us_median']:.0f},"
                f"mesh={rec['mesh']} "
                f"suggest_per_s={rec['suggestions_per_sec']:.1f}")
    by = {(c["n_studies"], c["mesh_devices"]): c for c in cells}
    speedup = (by[(64, 1)]["round_us_median"] /
               by[(64, 8)]["round_us_median"])
    payload = {
        "env_devices": ENV_DEVICES,
        "n_max": N_MAX,
        "dim": DIM,
        "restarts": RESTARTS,
        "ascent_steps": ASCENT_STEPS,
        "top_t": TOP_T,
        "n0": N0,
        "rounds": rounds,
        "results": cells,
        "speedup_8v1_S64": speedup,
        "speedup_8v1_S8": (by[(8, 1)]["round_us_median"] /
                           by[(8, 8)]["round_us_median"]),
    }
    with open(json_path, "w") as f:
        json.dump(payload, f, indent=2)
    out.append(f"shard_speedup_S64,,8dev_vs_1dev={speedup:.2f}x")
    out.append(f"shard_json,,path={json_path}")
    return out


if __name__ == "__main__":
    print("\n".join(run(full="--full" in sys.argv)))
