"""Substrate benchmark: one BO step per (mode x implementation).

Times a full BO round (suggest -> absorb -> lag policy) for every
factorization mode ("lazy" | "naive") against every linalg substrate the
current backend supports ("xla" | "ref" always; "pallas" only where the
kernels compile natively, i.e. TPU — interpret mode on CPU is a correctness
harness, not a benchmark), plus the "auto" policy the configs default to.

For the lazy mode each substrate row also carries the fused-vs-unfused
acquisition cell (the DESIGN.md §11 megakernel forced on vs. forced off via
`AcqConfig.fused`) and a per-phase split of one EI-ascent iteration at the
ascent's own (restarts, d) batch shape: cross-gram build, posterior
mean/var, the fused EI value+gradient step, and the selection argmax.

Emits the rows in the standard `name,us_per_call,derived` CSV format for
`benchmarks.run`, and writes the machine-readable `BENCH_substrate.json`.
The PR-5 (pre-megakernel) lazy `acq_us` baselines are committed alongside
the fresh numbers so the fused speedup is measured against a pinned
reference in the same artifact.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import BayesOpt, BOConfig, BOHistory, levy_bounds, neg_levy
from repro.core.acquisition import AcqConfig

JSON_PATH = "BENCH_substrate.json"

# Lazy-mode `acq_us` as committed by PR 5 (unfused ascent: autodiff through
# the posterior, one dispatch chain per restart per step).  Pinned here so
# BENCH_substrate.json always carries the reference the megakernel's
# acceptance criterion (>= 2x) is measured against.
PR5_BASELINE_ACQ_US = {
    "lazy/auto": 6318.7,
    "lazy/xla": 6277.8,
    "lazy/ref": 6244.5,
}


def _implementations() -> list[str]:
    impls = ["auto", "xla", "ref"]
    if jax.default_backend() == "tpu":
        impls.append("pallas")
    return impls


def _time_step(mode: str, implementation: str, *, n0: int, n_max: int,
               dim: int = 5, reps: int = 3, fused: str = "auto") -> dict:
    """Average one BO step (suggest + evaluate + absorb) at n ~ n0."""
    obj = lambda x: np.asarray(neg_levy(jnp.asarray(x)))
    lo, hi = levy_bounds(dim)
    cfg = BOConfig(dim=dim, n_max=n_max, mode=mode, seed=0,
                   implementation=implementation,
                   acq=AcqConfig(fused=fused))
    bo = BayesOpt(cfg, lo, hi)

    key = jax.random.PRNGKey(0)
    key, sub = jax.random.split(key)
    x0 = np.asarray(lo) + (np.asarray(hi) - np.asarray(lo)) * np.asarray(
        jax.random.uniform(sub, (n0, dim)))
    state = bo.init(jnp.asarray(x0), jnp.asarray(obj(x0), jnp.float32))

    hist = BOHistory()
    key, sub = jax.random.split(key)
    state = bo.step(state, sub, obj, hist)        # compile + warm-up
    hist = BOHistory()
    t0 = time.perf_counter()
    for _ in range(reps):
        key, sub = jax.random.split(key)
        state = bo.step(state, sub, obj, hist)
    total = (time.perf_counter() - t0) / reps
    return {
        "mode": mode,
        "implementation": implementation,
        "n0": n0,
        "n_max": n_max,
        "step_us": 1e6 * total,
        "gp_us": 1e6 * float(np.mean(hist.gp_seconds)),
        "acq_us": 1e6 * float(np.mean(hist.acq_seconds)),
        "clamp_count": int(state.clamp_count),
    }


def _acq_phases(implementation: str, *, n0: int, n_max: int, dim: int = 5,
                restarts: int = 64, reps: int = 30) -> dict:
    """Per-phase split of one EI-ascent iteration (DESIGN.md §11).

    Each phase runs as its own jitted call at the ascent's (restarts, d)
    candidate batch shape against a lazy state seeded to n0 active rows:
    the cross-gram build, the posterior mean/var through the maintained
    inverse, the fused EI value+gradient megakernel step (which subsumes
    the first two plus the analytic gradient in one dispatch), and the
    tie-break-quantized selection argmax.  Times are us per call (best of
    `reps`).
    """
    from repro.core import acquisition as acq_mod
    from repro.core import gp as gp_mod
    from repro.core.kernels import matern52
    from repro.kernels import ops

    key = jax.random.PRNGKey(0)
    gcfg = gp_mod.GPConfig(n_max=n_max, dim=dim,
                           implementation=implementation)
    st = gp_mod.init_state(gcfg)
    xs = jax.random.uniform(key, (n0, dim))
    ys = jnp.sin(3.0 * xs.sum(-1))
    st = gp_mod.append_batch(st, matern52, xs, ys,
                             implementation=implementation)

    x_cand = jax.random.uniform(jax.random.fold_in(key, 1), (restarts, dim))
    amask = (jnp.arange(n_max) < st.n).astype(jnp.float32)
    a_buf = st.li_buf.T @ st.li_buf
    shift = gp_mod._ymean(st) - acq_mod._f_best(st) - 0.01

    gram = jax.jit(lambda x: ops.kernel_gram(
        matern52, st.x_buf, x, st.params, implementation=implementation))
    post = jax.jit(lambda x: gp_mod.posterior(
        st, matern52, x, implementation=implementation))
    ei_grad = jax.jit(lambda x: ops.fused_ei_grad(
        x, st.x_buf, amask, st.alpha, a_buf, st.params.sigma2,
        st.params.rho, shift, implementation=implementation))
    argmax = jax.jit(
        lambda v: jnp.argmax(acq_mod._quantize_for_tiebreak(v)))
    vals = ei_grad(x_cand)[0]

    def best_of(fn, arg) -> float:
        jax.block_until_ready(fn(arg))            # compile + warm up
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(arg))
            best = min(best, time.perf_counter() - t0)
        return 1e6 * best

    return {
        "gram_us": best_of(gram, x_cand),
        "posterior_us": best_of(post, x_cand),
        "ei_grad_fused_us": best_of(ei_grad, x_cand),
        "argmax_us": best_of(argmax, vals),
    }


def run(full: bool = False, json_path: str = JSON_PATH):
    n0 = 512 if full else 128
    n_max = n0 + 16
    records = []
    out = []
    for mode in ("lazy", "naive"):
        for impl in _implementations():
            rec = _time_step(mode, impl, n0=n0, n_max=n_max)
            if mode == "lazy":
                fused_on = _time_step(mode, impl, n0=n0, n_max=n_max,
                                      fused="on")
                fused_off = _time_step(mode, impl, n0=n0, n_max=n_max,
                                       fused="off")
                rec["acq_fused_us"] = fused_on["acq_us"]
                rec["acq_unfused_us"] = fused_off["acq_us"]
                rec["acq_fused_speedup"] = (fused_off["acq_us"]
                                            / fused_on["acq_us"])
                rec["acq_phase_us"] = _acq_phases(impl, n0=n0, n_max=n_max)
            records.append(rec)
            extra = ""
            if mode == "lazy":
                extra = (f" fused_us={rec['acq_fused_us']:.0f}"
                         f" unfused_us={rec['acq_unfused_us']:.0f}"
                         f" fused_speedup={rec['acq_fused_speedup']:.2f}x")
            out.append(
                f"substrate_{mode}_{impl},{rec['step_us']:.0f},"
                f"gp_us={rec['gp_us']:.0f} acq_us={rec['acq_us']:.0f} "
                f"n={n0} clamps={rec['clamp_count']}" + extra)
    payload = {
        "backend": jax.default_backend(),
        "n0": n0,
        "n_max": n_max,
        "results": records,
        "pr5_baseline_acq_us": dict(PR5_BASELINE_ACQ_US),
    }
    with open(json_path, "w") as f:
        json.dump(payload, f, indent=2)
    out.append(f"substrate_json,,path={json_path}")
    return out


if __name__ == "__main__":
    print("\n".join(run()))
