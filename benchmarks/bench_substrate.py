"""Substrate benchmark: one BO step per (mode x implementation).

Times a full BO round (suggest -> absorb -> lag policy) for every
factorization mode ("lazy" | "naive") against every linalg substrate the
current backend supports ("xla" | "ref" always; "pallas" only where the
kernels compile natively, i.e. TPU — interpret mode on CPU is a correctness
harness, not a benchmark), plus the "auto" policy the configs default to.

Emits the rows in the standard `name,us_per_call,derived` CSV format for
`benchmarks.run`, and writes the machine-readable `BENCH_substrate.json`
with the per-phase split (suggest vs GP update) per combination.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import BayesOpt, BOConfig, BOHistory, levy_bounds, neg_levy

JSON_PATH = "BENCH_substrate.json"


def _implementations() -> list[str]:
    impls = ["auto", "xla", "ref"]
    if jax.default_backend() == "tpu":
        impls.append("pallas")
    return impls


def _time_step(mode: str, implementation: str, *, n0: int, n_max: int,
               dim: int = 5, reps: int = 3) -> dict:
    """Average one BO step (suggest + evaluate + absorb) at n ~ n0."""
    obj = lambda x: np.asarray(neg_levy(jnp.asarray(x)))
    lo, hi = levy_bounds(dim)
    cfg = BOConfig(dim=dim, n_max=n_max, mode=mode, seed=0,
                   implementation=implementation)
    bo = BayesOpt(cfg, lo, hi)

    key = jax.random.PRNGKey(0)
    key, sub = jax.random.split(key)
    x0 = np.asarray(lo) + (np.asarray(hi) - np.asarray(lo)) * np.asarray(
        jax.random.uniform(sub, (n0, dim)))
    state = bo.init(jnp.asarray(x0), jnp.asarray(obj(x0), jnp.float32))

    hist = BOHistory()
    key, sub = jax.random.split(key)
    state = bo.step(state, sub, obj, hist)        # compile + warm-up
    hist = BOHistory()
    t0 = time.perf_counter()
    for _ in range(reps):
        key, sub = jax.random.split(key)
        state = bo.step(state, sub, obj, hist)
    total = (time.perf_counter() - t0) / reps
    return {
        "mode": mode,
        "implementation": implementation,
        "n0": n0,
        "n_max": n_max,
        "step_us": 1e6 * total,
        "gp_us": 1e6 * float(np.mean(hist.gp_seconds)),
        "acq_us": 1e6 * float(np.mean(hist.acq_seconds)),
        "clamp_count": int(state.clamp_count),
    }


def run(full: bool = False, json_path: str = JSON_PATH):
    n0 = 512 if full else 128
    n_max = n0 + 16
    records = []
    out = []
    for mode in ("lazy", "naive"):
        for impl in _implementations():
            rec = _time_step(mode, impl, n0=n0, n_max=n_max)
            records.append(rec)
            out.append(
                f"substrate_{mode}_{impl},{rec['step_us']:.0f},"
                f"gp_us={rec['gp_us']:.0f} acq_us={rec['acq_us']:.0f} "
                f"n={n0} clamps={rec['clamp_count']}")
    payload = {
        "backend": jax.default_backend(),
        "n0": n0,
        "n_max": n_max,
        "results": records,
    }
    with open(json_path, "w") as f:
        json.dump(payload, f, indent=2)
    out.append(f"substrate_json,,path={json_path}")
    return out


if __name__ == "__main__":
    print("\n".join(run()))
