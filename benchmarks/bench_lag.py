"""Paper Fig. 6: effect of the lagging factor l on time and convergence.

Sweeps l over {1, 2, 3, 5, 10, 25, inf} on the 5-D Levy function with 200
seed points (paper's setup), recording wall-clock GP time and the iteration
at which a fixed accuracy (-0.25) is reached.  Expected shape (paper):
time falls monotonically with l (fewer O(n^3) refits); iterations-to-
accuracy grows slowly; l ~ 3 is the sweet spot.
"""
from __future__ import annotations

import numpy as np

from repro.core import levy_bounds, neg_levy, run_bo

TARGET = -0.25


def run(iterations: int = 200, n_seed: int = 200, full: bool = False,
        implementation: str = "auto"):
    import jax.numpy as jnp
    iterations = 400 if full else iterations
    obj = lambda x: np.asarray(neg_levy(jnp.asarray(x)))
    lo, hi = levy_bounds(5)
    out = []
    for lag in (1, 2, 3, 5, 10, 25, 0):     # 0 = never refit (l = inf)
        _, hist = run_bo(obj, lo, hi, iterations, dim=5, mode="lazy",
                         lag=lag, n_seed=n_seed,
                         n_max=iterations + n_seed + 8, seed=0,
                         implementation=implementation)
        gp_s = float(np.sum(hist.gp_seconds))
        acq_s = float(np.sum(hist.acq_seconds))
        it = hist.iterations_to(TARGET)
        tag = f"lag_{'inf' if lag == 0 else lag}"
        out.append(f"{tag},{1e6 * gp_s / iterations:.0f},"
                   f"gp_total={gp_s:.2f}s acq_total={acq_s:.2f}s "
                   f"iters_to_{TARGET}={it} best={hist.best()[1]:.3f}")
    return out


if __name__ == "__main__":
    print("\n".join(run()))
