"""Multi-tenant StudyPool benchmark: batched vs S-sequential suggest+absorb.

The multi-tenant claim (DESIGN.md §7): one jitted, vmapped program advancing
S posteriors beats S sequential single-study dispatches, because per-study
device work is tiny (the paper's O(n^2) append) and dispatch overhead
dominates.  This bench measures exactly that:

  * **pool**       — one `StudyPool` over S studies: each round is ONE
    `suggest_all` dispatch + ONE masked `absorb_many` dispatch.
  * **sequential** — S one-study pools (the `TrialScheduler` degenerate
    case, same engine code path): each round loops the S studies through
    single suggest + routed absorb dispatches.

Both sides run identical GP shapes, acquisition budgets, and substrate, and
both are warmed up before timing.  Emits `name,us_per_call,derived` CSV rows
for `benchmarks.run` and writes `BENCH_pool.json` with suggestions/sec,
absorb latency, and the pool-vs-sequential speedup per S ∈ {1, 4, 16, 64}.
"""
from __future__ import annotations

import json
import time

import numpy as np

from repro.core.acquisition import AcqConfig
from repro.hpo.pool import SchedulerConfig, StudyPool
from repro.hpo.space import RESNET_SPACE

JSON_PATH = "BENCH_pool.json"

SIZES = (1, 4, 16, 64)


def _objective(sid: int, unit: np.ndarray) -> float:
    c = 0.2 + 0.6 * (sid % 7) / 7.0
    return float(-np.sum((np.asarray(unit) - c) ** 2))


def _cfg(n_max: int) -> SchedulerConfig:
    # Small acquisition budget: the bench measures dispatch/batching
    # overhead, not ascent quality.  Identical on both sides.
    return SchedulerConfig(n_max=n_max, seed=0,
                           acq=AcqConfig(restarts=16, ascent_steps=8))


def _prefill(pool: StudyPool, n0: int, rng: np.random.Generator) -> None:
    """Seed every study with n0 observations (untimed setup)."""
    dim = pool.studies[0].space.dim
    for _ in range(n0):
        events = []
        for s in range(pool.n_studies):
            u = rng.uniform(size=dim).astype(np.float32)
            events.append((s, pool._make_trial(s, u), _objective(s, u)))
        pool.absorb_many(events)


def _pool_rounds(pool: StudyPool, rounds: int) -> tuple[float, float]:
    """Timed batched rounds; returns (suggest_s, absorb_s) totals."""
    suggest_s = absorb_s = 0.0
    for _ in range(rounds):
        t0 = time.perf_counter()
        suggestions = pool.suggest_all(t=1)
        t1 = time.perf_counter()
        events = [(s, trs[0], _objective(s, trs[0].unit))
                  for s, trs in suggestions.items()]
        t2 = time.perf_counter()
        pool.absorb_many(events)
        t3 = time.perf_counter()
        suggest_s += t1 - t0
        absorb_s += t3 - t2
    return suggest_s, absorb_s


def _sequential_rounds(pools: list[StudyPool],
                       rounds: int) -> tuple[float, float]:
    """Timed S-sequential rounds over one-study pools (same engine path)."""
    suggest_s = absorb_s = 0.0
    for _ in range(rounds):
        trials = []
        t0 = time.perf_counter()
        for sid, p in enumerate(pools):
            trials.append(p.suggest(0, 1)[0])
        t1 = time.perf_counter()
        values = [_objective(sid, tr.unit)
                  for sid, tr in enumerate(trials)]
        t2 = time.perf_counter()
        for p, tr, val in zip(pools, trials, values):
            p.absorb(0, tr, val)
        t3 = time.perf_counter()
        suggest_s += t1 - t0
        absorb_s += t3 - t2
    return suggest_s, absorb_s


def _bench_size(s: int, *, n_max: int, n0: int, rounds: int) -> dict:
    rng = np.random.default_rng(0)
    pool = StudyPool([RESNET_SPACE] * s, _cfg(n_max))
    _prefill(pool, n0, rng)
    _pool_rounds(pool, 1)                                   # warm-up/compile
    pool_suggest, pool_absorb = _pool_rounds(pool, rounds)

    rng = np.random.default_rng(0)
    seq = [StudyPool([RESNET_SPACE], _cfg(n_max)) for _ in range(s)]
    for _ in range(n0):
        for sid, p in enumerate(seq):
            u = rng.uniform(size=RESNET_SPACE.dim).astype(np.float32)
            p.absorb(0, p._make_trial(0, u), _objective(sid, u))
    _sequential_rounds(seq, 1)                              # warm-up/compile
    seq_suggest, seq_absorb = _sequential_rounds(seq, rounds)

    ops = s * rounds
    pool_total = pool_suggest + pool_absorb
    seq_total = seq_suggest + seq_absorb
    return {
        "n_studies": s,
        "n_max": n_max,
        "n0": n0,
        "rounds": rounds,
        "pool_suggestions_per_sec": ops / pool_suggest,
        "seq_suggestions_per_sec": ops / seq_suggest,
        "pool_absorb_latency_us": 1e6 * pool_absorb / ops,
        "seq_absorb_latency_us": 1e6 * seq_absorb / ops,
        "pool_round_us": 1e6 * pool_total / rounds,
        "seq_round_us": 1e6 * seq_total / rounds,
        "speedup": seq_total / pool_total,
    }


def run(full: bool = False, json_path: str = JSON_PATH):
    n_max = 256 if full else 128
    n0 = 12 if full else 8
    rounds = 8 if full else 5
    records, out = [], []
    for s in SIZES:
        rec = _bench_size(s, n_max=n_max, n0=n0, rounds=rounds)
        records.append(rec)
        out.append(
            f"pool_S{s},{rec['pool_round_us']:.0f},"
            f"seq_round_us={rec['seq_round_us']:.0f} "
            f"suggest_per_s={rec['pool_suggestions_per_sec']:.1f} "
            f"absorb_us={rec['pool_absorb_latency_us']:.0f} "
            f"speedup={rec['speedup']:.2f}x")
    import jax
    payload = {
        "backend": jax.default_backend(),
        "n_max": n_max,
        "n0": n0,
        "rounds": rounds,
        "results": records,
    }
    with open(json_path, "w") as f:
        json.dump(payload, f, indent=2)
    out.append(f"pool_json,,path={json_path}")
    return out


if __name__ == "__main__":
    print("\n".join(run()))
