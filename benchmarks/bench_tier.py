"""Saturation-tier benchmark: serving cost past n_max (DESIGN.md §15).

Two claims, one JSON:

  * **Flat suggest latency past n_max.**  The lazy GP's per-suggest cost
    grows with the ledger (the acquisition ascent solves against an
    O(n^2) posterior); the escalated neural-basis tier's posterior is
    GEMMs against an m x m feature Gram (m = basis width), so its
    per-suggest latency is flat in n.  The bench measures one routed
    `StudyPool.suggest` at matched observation counts: the GP lane
    re-provisioned with n_max = n per checkpoint (padded buffers make
    per-suggest cost track the PROVISIONED size — to keep serving at n
    observations a GP pool must pay the quadratic at n), the NB lane
    promoted once at a small n_max and grown through the SAME counts.

  * **EI-per-unit-cost reaches the target cheaper.**  On a synthetic
    objective whose evaluation cost climbs along x0 (the FABOLAS shape:
    cheap evaluations carry information about the expensive optimum), an
    escalated study running `ei_per_cost` acquisition (EI divided by the
    predicted cost from the learned log-cost head) is measured against
    plain EI at the SAME evaluation-cost budget: cost-to-target and best
    value at budget.

Emits `name,us_per_call,derived` CSV rows for `benchmarks.run` and
writes `BENCH_tier.json`.
"""
from __future__ import annotations

import json
import time

import numpy as np

from repro.core import NeuralConfig
from repro.core.acquisition import AcqConfig
from repro.hpo.pool import SchedulerConfig, StudyPool
from repro.hpo.space import RESNET_SPACE, Dim, SearchSpace

JSON_PATH = "BENCH_tier.json"

NB_CFG = NeuralConfig()          # the production default (DESIGN.md §15)
NB_NMAX = 32                     # promotion point of the NB lane
CHECKPOINTS = (64, 192, 576)     # observation counts measured, all > n_max

COST_SPACE = SearchSpace((Dim("x0", 0.0, 1.0), Dim("x1", 0.0, 1.0)))
COST_SEED_N = 8                  # shared seed trials before the BO loop
COST_TARGET = -0.002             # best value to reach (optimum is 0.0)


def _rng_obs(rng: np.random.RandomState, d: int) -> tuple[np.ndarray, float]:
    u = rng.rand(d).astype(np.float32)
    return u, float(-np.sum((u - 0.37) ** 2))


def _grow_to(pool: StudyPool, rng: np.random.RandomState, n: int) -> None:
    d = pool.studies[0].space.dim
    while pool.n_real(0) < n:
        u, v = _rng_obs(rng, d)
        pool.absorb(0, pool._make_trial(0, u), v)


def _suggest_us(pool: StudyPool, warmup: int, reps: int) -> float:
    for _ in range(warmup):
        pool.suggest(0, 1)
    t0 = time.perf_counter()
    for _ in range(reps):
        pool.suggest(0, 1)       # Trial units land on host: synced
    return 1e6 * (time.perf_counter() - t0) / reps


def _cfg(n_max: int, acq: AcqConfig | None = None) -> SchedulerConfig:
    return SchedulerConfig(n_max=n_max, seed=0, ckpt_every=10 ** 9,
                           neural=NB_CFG,
                           acq=acq or AcqConfig(restarts=16,
                                                ascent_steps=8))


def _bench_latency(warmup: int, reps: int) -> list[dict]:
    """Per-suggest latency at each checkpoint, GP lane vs NB lane.

    The lazy GP computes over its PADDED buffer, so per-suggest cost
    tracks the provisioned n_max, not the live count: a GP that must
    keep serving at n observations has to be provisioned with n_max >= n
    and pays the quadratic posterior at that size.  The GP lane therefore
    re-provisions n_max = n per checkpoint; the NB lane is promoted once
    at NB_NMAX and grown through the same counts — flat in n."""
    nb = StudyPool([RESNET_SPACE], _cfg(NB_NMAX))
    rng_nb = np.random.RandomState(5)
    _grow_to(nb, rng_nb, NB_NMAX)
    nb.promote(0)
    cells = []
    for n in CHECKPOINTS:
        gp = StudyPool([RESNET_SPACE], _cfg(n))
        _grow_to(gp, np.random.RandomState(5), n)
        _grow_to(nb, rng_nb, n)
        cells.append({"n": n,
                      "gp_suggest_us": _suggest_us(gp, warmup, reps),
                      "nb_suggest_us": _suggest_us(nb, warmup, reps)})
    return cells


def _cost_fn(u: np.ndarray) -> float:
    # evaluation cost climbs steeply along x0; the optimum sits mid-cheap
    return float(0.2 + 3.0 * u[0] ** 2)


def _cost_obj(u: np.ndarray) -> float:
    return float(-np.sum((np.asarray(u) - (0.25, 0.7)) ** 2))


def _bench_cost_mode(name: str, budget: float) -> dict:
    """Drive one escalated study to an evaluation-cost budget."""
    pool = StudyPool([COST_SPACE],
                     _cfg(COST_SEED_N, AcqConfig(name=name, restarts=24,
                                                 ascent_steps=10)))
    rng = np.random.RandomState(17)
    for _ in range(COST_SEED_N):   # identical seed design in both modes
        u = rng.rand(2).astype(np.float32)
        pool.absorb(0, pool._make_trial(0, u), _cost_obj(u),
                    cost=_cost_fn(u))
    pool.promote(0)
    spent, best, trials = 0.0, -np.inf, 0
    cost_to_target = None
    while spent < budget:
        tr = pool.suggest(0, 1)[0]
        c, v = _cost_fn(tr.unit), _cost_obj(tr.unit)
        pool.absorb(0, tr, v, cost=c)
        spent += c
        trials += 1
        best = max(best, v)
        if cost_to_target is None and best >= COST_TARGET:
            cost_to_target = spent
    return {"acq": name, "cost_budget": budget, "trials": trials,
            "best_value": best, "mean_cost_per_trial": spent / trials,
            "cost_to_target": cost_to_target}


def run(full: bool = False, json_path: str = JSON_PATH):
    warmup, reps = (3, 20) if full else (2, 8)
    budget = 40.0 if full else 18.0
    cells = _bench_latency(warmup, reps)
    first, last = cells[0], cells[-1]
    gp_growth = last["gp_suggest_us"] / first["gp_suggest_us"]
    nb_growth = last["nb_suggest_us"] / first["nb_suggest_us"]
    out = []
    for c in cells:
        out.append(f"tier_n{c['n']},{c['nb_suggest_us']:.0f},"
                   f"gp_us={c['gp_suggest_us']:.0f} "
                   f"nb_over_gp={c['nb_suggest_us'] / c['gp_suggest_us']:.2f}")
    modes = {m: _bench_cost_mode(m, budget) for m in ("ei", "ei_per_cost")}
    for m, rec in modes.items():
        ctt = rec["cost_to_target"]
        out.append(f"tier_{m},,trials={rec['trials']} "
                   f"best={rec['best_value']:.4f} "
                   f"mean_cost={rec['mean_cost_per_trial']:.2f} "
                   f"cost_to_target={'-' if ctt is None else f'{ctt:.1f}'}")
    import jax
    payload = {
        "backend": jax.default_backend(),
        "nb_n_max": NB_NMAX,
        "neural": {"hidden": NB_CFG.hidden, "features": NB_CFG.features},
        "latency_cells": cells,
        # growth of per-suggest latency from the first to the last
        # checkpoint (9x the observations): the GP lane grows with its
        # ledger, the escalated lane stays flat
        "gp_latency_growth": gp_growth,
        "nb_latency_growth": nb_growth,
        "cost_modes": list(modes.values()),
    }
    with open(json_path, "w") as f:
        json.dump(payload, f, indent=2)
    out.append(f"tier_json,,path={json_path}")
    return out


if __name__ == "__main__":
    print("\n".join(run()))
