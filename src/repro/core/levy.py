"""Synthetic objectives: the d-dimensional Levy function (paper Sec. 4.1).

The paper maximizes the *negative* Levy function on [-10, 10]^d; the global
maximum is 0 at x* = (1, ..., 1).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def levy(x: Array) -> Array:
    """Levy function (paper Eq. 19). x: (..., d)."""
    w = 1.0 + (x - 1.0) / 4.0
    term1 = jnp.sin(jnp.pi * w[..., 0]) ** 2
    wi = w[..., :-1]
    term2 = jnp.sum((wi - 1.0) ** 2
                    * (1.0 + 10.0 * jnp.sin(jnp.pi * wi + 1.0) ** 2), axis=-1)
    wd = w[..., -1]
    term3 = (wd - 1.0) ** 2 * (1.0 + jnp.sin(2.0 * jnp.pi * wd) ** 2)
    return term1 + term2 + term3


def neg_levy(x: Array) -> Array:
    """The paper's maximization target: max_x -f_L(x), optimum 0 at 1-vector."""
    return -levy(x)


def levy_bounds(dim: int) -> tuple[Array, Array]:
    lo = jnp.full((dim,), -10.0)
    hi = jnp.full((dim,), 10.0)
    return lo, hi


def levy_1d(x: Array) -> Array:
    """1-D special case used in the paper's Fig. 2/3 illustration (Eq. 7)."""
    w = 1.0 + (x - 1.0) / 4.0
    return jnp.sin(jnp.pi * w) ** 2 + (w - 1.0) ** 2 * (
        1.0 + jnp.sin(2.0 * jnp.pi * w) ** 2)
