"""Type descriptor for mixed (float / int / categorical / conditional) spaces.

The GP always sees the encoded unit cube (DESIGN.md §10): every search-space
dimension contributes one or more unit-cube *coordinates* — floats and ints
one each, categoricals a one-hot block.  The `TypeDescriptor` is the
per-coordinate record of that encoding: which coordinates take gradient
steps (continuous block), which form one-hot blocks (categorical factor of
the mixed kernel), the integer lattice resolution, and the parent-gating
wiring of conditional dimensions.

It is deliberately an **array pytree, not Python structure**: per-study
descriptors stack to `(S, d)` leaves and ride through `vmap`/`shard_map`
exactly like the stacked `LazyGPState` (DESIGN.md §7/§8), so a pool whose
studies have *different* type layouts still advances in one jitted program.
`project_units` is the round-and-repair projection the acquisition ascent
interleaves with its gradient steps — pure masked arithmetic, no Python
branching on types, so it traces once for any layout.

Layering: this module is `repro.core`-level (the acquisition optimizer and
the kernels consume it); `repro.hpo.space` *builds* descriptors from typed
`SearchSpace` definitions.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TypeDescriptor:
    """Per-coordinate typing of an encoded search space (all leaves `(d,)`;
    stacked per-study descriptors carry `(S, d)` leaves).

    Invariants (established by `repro.hpo.space.SearchSpace.descriptor`):
      * `cont_mask + cat_mask` is 1 everywhere (every coordinate is either
        a gradient coordinate or a one-hot coordinate);
      * `levels > 0` only on integer coordinates (`levels` = lattice size,
        so `levels == 1` pins the coordinate to 0);
      * `group[c]` is the index of the first coordinate of c's one-hot
        block (a valid segment id < d), or -1 off the categorical block;
      * `parent[c]` is the one-hot coordinate whose value gates c (the
        parent choice's coordinate), or -1 for unconditional coordinates.
        Parents are themselves unconditional, so one gating pass suffices.
    """

    cont_mask: Array   # (d,) f32: 1.0 on gradient (float + int) coordinates
    cat_mask: Array    # (d,) f32: 1.0 on one-hot (categorical) coordinates
    levels: Array      # (d,) f32: integer lattice size (0.0 = not an int)
    group: Array       # (d,) i32: one-hot segment id (-1 = not categorical)
    parent: Array      # (d,) i32: gating coordinate index (-1 = always on)

    @property
    def dim(self) -> int:
        return self.cont_mask.shape[-1]

    @property
    def is_batched(self) -> bool:
        return self.cont_mask.ndim == 2

    @property
    def has_discrete(self) -> bool:
        """Host-side: any int / categorical / conditional coordinate?

        Only meaningful on concrete (non-traced) descriptors — it decides
        which closures an engine builds, never anything inside a trace.
        """
        return bool(np.any(np.asarray(self.cat_mask) > 0)
                    or np.any(np.asarray(self.levels) > 0)
                    or np.any(np.asarray(self.parent) >= 0))


def all_continuous(dim: int) -> TypeDescriptor:
    """The degenerate all-float descriptor (projection is the identity)."""
    return TypeDescriptor(
        cont_mask=jnp.ones((dim,), jnp.float32),
        cat_mask=jnp.zeros((dim,), jnp.float32),
        levels=jnp.zeros((dim,), jnp.float32),
        group=jnp.full((dim,), -1, jnp.int32),
        parent=jnp.full((dim,), -1, jnp.int32),
    )


def stack_descriptors(descs: "list[TypeDescriptor]") -> TypeDescriptor:
    """Stack per-study descriptors into `(S, d)` leaves (shared width)."""
    widths = {d.dim for d in descs}
    if len(widths) != 1:
        raise ValueError(f"descriptors must share one width, got {widths}")
    return jax.tree.map(lambda *xs: jnp.stack(xs), *descs)


def index_descriptor(desc: TypeDescriptor, i) -> TypeDescriptor:
    """Single-study view of a stacked descriptor (traced index ok)."""
    return jax.tree.map(
        lambda a: jax.lax.dynamic_index_in_dim(a, i, keepdims=False), desc)


def project_units(u: Array, desc: TypeDescriptor) -> Array:
    """Round-and-repair projection onto the feasible lattice (jit-safe).

    Three masked passes over a `(d,)` unit vector, no type branching:

      1. **int snap** — coordinates with `levels = L > 0` round to the
         uniform lattice `{k / (L-1)}` (L = 1 pins to 0);
      2. **one-hot argmax** — each categorical block keeps a single 1 at
         its largest coordinate (first index wins ties, so the projection
         is deterministic and idempotent);
      3. **parent gating** — conditional coordinates multiply by their
         parent choice's (now 0/1) coordinate, so inactive children sit
         at the neutral encoding 0.

    Continuous coordinates pass through untouched; on an all-continuous
    descriptor the whole function is the identity.  Batched form: `(n, d)`
    units project row-wise (the descriptor is shared unless it is itself
    stacked `(S, d)`, in which case rows pair with studies).
    """
    if u.ndim == 2:
        if desc.is_batched:
            return jax.vmap(project_units)(u, desc)
        return jax.vmap(lambda uu: project_units(uu, desc))(u)
    d = u.shape[0]
    # 1. integer lattice snap
    lev = desc.levels
    snapped = jnp.round(u * (lev - 1.0)) / jnp.maximum(lev - 1.0, 1.0)
    u = jnp.where(lev > 0, snapped, u)
    # 2. per-group one-hot argmax (segment ids are first-coordinate
    # indices, so num_segments = d covers every group)
    gid = desc.group
    is_cat = gid >= 0
    seg = jnp.where(is_cat, gid, 0)
    scores = jnp.where(is_cat, u, -jnp.inf)
    gmax = jax.ops.segment_max(scores, seg, num_segments=d)
    at_max = is_cat & (u >= gmax[seg])
    idx = jnp.arange(d)
    first = jax.ops.segment_min(jnp.where(at_max, idx, d), seg,
                                num_segments=d)
    u = jnp.where(is_cat, (idx == first[seg]).astype(u.dtype), u)
    # 3. conditional gating by the (projected) parent coordinate
    par = desc.parent
    gate = u[jnp.clip(par, 0, d - 1)]
    return jnp.where(par >= 0, u * gate, u)
