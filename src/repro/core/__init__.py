"""Lazy Gaussian-process Bayesian optimization (the paper's contribution).

Public API:
  * kernels: Matérn-2.5/1.5, RBF — `repro.core.kernels`
  * lazy Cholesky: `repro.core.cholesky` (Alg. 2 naive, Alg. 3 incremental)
  * GP state machine: `repro.core.gp`
  * acquisition + top-t local maxima: `repro.core.acquisition`
  * BO driver: `repro.core.bayesopt`
  * synthetic objectives: `repro.core.levy`
"""
from repro.core.acquisition import AcqConfig, expected_improvement, optimize_acquisition
from repro.core.bayesopt import BayesOpt, BOConfig, BOHistory, run_bo
from repro.core.cholesky import (cholesky_naive, cholesky_xla, lazy_append_row,
                                 lazy_full_refactor, padded_trsv)
from repro.core.descriptor import (TypeDescriptor, all_continuous,
                                   project_units, stack_descriptors)
from repro.core.gp import (BackpressureError, GPCapacityError, GPConfig,
                           LazyGPState, StudySaturatedError, append,
                           append_batch, dense_posterior, ensure_capacity,
                           init_pool_state, init_state,
                           log_marginal_likelihood, maybe_refit, posterior,
                           refactor, refit_params, stack_states,
                           unstack_state)
from repro.core.neural_basis import (NeuralBasisState, NeuralConfig,
                                     nb_from_data, nb_posterior)
from repro.core.kernels import (KERNELS, KernelParams, gram,
                                make_mixed_kernel, matern32, matern52,
                                mixed_matern52, rbf)
from repro.core.levy import levy, levy_1d, levy_bounds, neg_levy

__all__ = [
    "AcqConfig", "BackpressureError", "BayesOpt", "BOConfig", "BOHistory",
    "GPCapacityError",
    "GPConfig", "KERNELS",
    "KernelParams", "LazyGPState", "NeuralBasisState", "NeuralConfig",
    "StudySaturatedError", "TypeDescriptor", "all_continuous",
    "nb_from_data", "nb_posterior",
    "append", "append_batch", "cholesky_naive",
    "cholesky_xla", "dense_posterior", "ensure_capacity",
    "expected_improvement", "gram",
    "init_pool_state", "init_state", "lazy_append_row", "lazy_full_refactor",
    "log_marginal_likelihood", "make_mixed_kernel", "matern32", "matern52",
    "maybe_refit", "mixed_matern52",
    "optimize_acquisition", "padded_trsv", "posterior", "project_units",
    "rbf", "refactor",
    "refit_params", "run_bo", "stack_descriptors", "stack_states",
    "unstack_state",
    "levy", "levy_1d", "levy_bounds", "neg_levy",
]
