"""DNGO-style neural-basis surrogate: the saturation escalation tier.

The paper's lazy GP serves a study beautifully until its padded buffers
fill: at ``n == n_max`` every append path is a terminal
`StudySaturatedError`.  This module is what a saturated study escalates
TO (DESIGN.md §15): an adaptive-basis model in the style of "Scalable
Bayesian Optimization Using Deep Neural Networks" (Snoek et al., DNGO) —
a small MLP feature map phi(x) trained on the study's full ledger, with
an **exact Bayesian linear-regression head** on top.  The posterior is
two GEMMs against cached Gram factors:

    A      = Phi^T Phi + sigma^2 I          (m+1, m+1), cached Cholesky
    mean   = y_mean + phi(x)^T w,   w = A^{-1} Phi^T (y - y_mean)
    var    = s^2 * phi(x)^T A^{-1} phi(x)

so suggest cost is O(m^2) per candidate — FLAT in n, vs the lazy GP's
O(n^2).  Appends are a rank-1 factor update + one O(m^3) re-Cholesky
(m is tens, not thousands).  The MLP itself refits on a cadence
(`NeuralConfig.refit_every`, the analogue of the GP's `lag`): a few
hundred Adam steps of full-ledger regression through a throwaway linear
output layer, after which the Bayes head is rebuilt exactly from the new
features.

A second linear head on the SAME features learns **log cost** from the
`cost=` values threaded through tells (FABOLAS-style, Klein et al.), so
the acquisition can run in EI-per-unit-cost mode
(`AcqConfig(name="ei_per_cost")` + `acquisition.cost_scaled`): cheap
probes dominate while expensive regions must promise proportionally more
improvement.

Unlike the GP's fixed buffers the ledger here GROWS: capacity doubles
when full (`nb_grow`, host-side), so recompiles happen O(log n) times.
Everything is a plain float32 array pytree — eviction snapshots,
checkpoints, and the wire all round-trip it bitwise (`nb_to_json` /
`nb_from_json` carry raw base64 bytes, never decimal reprs).
"""
from __future__ import annotations

import base64
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import acquisition as acq_mod
from repro.core import descriptor as desc_mod

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class NeuralConfig:
    """Shape + training knobs of the neural-basis tier (static: baked into
    the jitted programs, hashable, rides SchedulerConfig into worker
    specs)."""

    hidden: int = 32        # MLP hidden width
    features: int = 16      # m: basis features (head dims m+1 with bias)
    refit_every: int = 32   # appends between MLP refits (the tier's `lag`)
    refit_steps: int = 200  # Adam steps per refit
    refit_lr: float = 3e-3
    noise2: float = 1e-4    # ridge sigma^2 of the Bayes head
    cap0: int = 64          # minimum initial ledger capacity


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class NeuralBasisState:
    """Padded ledger + MLP params + cached Bayes-head factors.

    `x_buf/y_buf/c_buf` rows beyond `n` are zero padding (masked out of
    every reduction).  `c_buf` holds LOG cost.  The factor cache
    (`ptp/pty/ptc/pt1`, `chol`, `w_y/w_c`) is always consistent with the
    ledger prefix and the current MLP params — appends update it
    incrementally, refits rebuild it exactly.
    """

    x_buf: Array        # (cap, d) observed points (unit space)
    y_buf: Array        # (cap,) observations
    c_buf: Array        # (cap,) log cost per observation
    n: Array            # () int32 active count
    since_refit: Array  # () int32 appends since the last MLP refit
    w1: Array           # (d, h) MLP layer 1
    b1: Array           # (h,)
    w2: Array           # (h, m) MLP layer 2 (its tanh output is the basis)
    b2: Array           # (m,)
    w3: Array           # (m,) throwaway linear output head (refit only)
    b3: Array           # ()
    ptp: Array          # (m+1, m+1) Phi^T Phi (bias feature appended)
    pty: Array          # (m+1,) Phi^T y
    ptc: Array          # (m+1,) Phi^T log-cost
    pt1: Array          # (m+1,) Phi^T 1 (for centering)
    chol: Array         # (m+1, m+1) lower Cholesky of ptp + noise2 I
    w_y: Array          # (m+1,) Bayes-head weights on centered y
    w_c: Array          # (m+1,) log-cost-head weights on centered c
    y_mean: Array       # () ledger mean of y at the last refit
    c_mean: Array       # () ledger mean of log cost at the last refit
    s2: Array           # () residual variance scale for the posterior

    @property
    def cap(self) -> int:
        return self.x_buf.shape[0]

    @property
    def dim(self) -> int:
        return self.x_buf.shape[1]


# -- features + posterior -----------------------------------------------------
def _features(state: NeuralBasisState, x: Array) -> Array:
    """phi(x): (…, m+1) — two tanh layers + a constant bias feature."""
    h = jnp.tanh(x @ state.w1 + state.b1)
    f = jnp.tanh(h @ state.w2 + state.b2)
    one = jnp.ones(f.shape[:-1] + (1,), f.dtype)
    return jnp.concatenate([f, one], axis=-1)


def nb_posterior(state: NeuralBasisState, x: Array
                 ) -> tuple[Array, Array]:
    """Posterior mean/var at `x (r, d)` — two GEMMs, O(m^2) per point."""
    phi = _features(state, x)                       # (r, m+1)
    mean = state.y_mean + phi @ state.w_y
    sol = jax.scipy.linalg.cho_solve((state.chol, True), phi.T)  # (m+1, r)
    var = state.s2 * jnp.sum(phi * sol.T, axis=-1)
    return mean, jnp.maximum(var, 1e-10)


def nb_log_cost(state: NeuralBasisState, x: Array) -> Array:
    """Predicted log cost at `x (…, d)` (the FABOLAS cost head)."""
    return state.c_mean + _features(state, x) @ state.w_c


def _active_mask(state: NeuralBasisState) -> Array:
    return jnp.arange(state.cap) < state.n


def _f_best(state: NeuralBasisState) -> Array:
    m = _active_mask(state)
    return jnp.max(jnp.where(m, state.y_buf, -jnp.inf))


# -- head solve (shared by append + refit) ------------------------------------
def _solve_heads(ncfg: NeuralConfig, ptp: Array, pty: Array, ptc: Array,
                 pt1: Array, y_mean: Array, c_mean: Array
                 ) -> tuple[Array, Array, Array]:
    a = ptp + ncfg.noise2 * jnp.eye(ptp.shape[0], dtype=ptp.dtype)
    chol = jax.scipy.linalg.cholesky(a, lower=True)
    w_y = jax.scipy.linalg.cho_solve((chol, True), pty - y_mean * pt1)
    w_c = jax.scipy.linalg.cho_solve((chol, True), ptc - c_mean * pt1)
    return chol, w_y, w_c


def _rebuild_cache(state: NeuralBasisState, ncfg: NeuralConfig
                   ) -> NeuralBasisState:
    """Exact factor rebuild from the full (masked) ledger — refit/init."""
    mask = _active_mask(state)
    nf = jnp.maximum(state.n.astype(state.y_buf.dtype), 1.0)
    phi = _features(state, state.x_buf) * mask[:, None]  # (cap, m+1)
    y_mean = jnp.sum(jnp.where(mask, state.y_buf, 0.0)) / nf
    c_mean = jnp.sum(jnp.where(mask, state.c_buf, 0.0)) / nf
    ptp = phi.T @ phi
    pty = phi.T @ jnp.where(mask, state.y_buf, 0.0)
    ptc = phi.T @ jnp.where(mask, state.c_buf, 0.0)
    pt1 = jnp.sum(phi, axis=0)
    chol, w_y, w_c = _solve_heads(ncfg, ptp, pty, ptc, pt1, y_mean, c_mean)
    # Residual variance of the new head on the ledger: the posterior's
    # scale.  Floored at noise2 so a perfectly interpolated ledger still
    # admits exploration.
    pred = y_mean + phi @ w_y
    resid = jnp.where(mask, state.y_buf - pred, 0.0)
    s2 = jnp.maximum(jnp.sum(resid * resid) / nf, ncfg.noise2)
    return dataclasses.replace(state, ptp=ptp, pty=pty, ptc=ptc, pt1=pt1,
                               chol=chol, w_y=w_y, w_c=w_c, y_mean=y_mean,
                               c_mean=c_mean, s2=s2,
                               since_refit=jnp.int32(0))


# -- append (rank-1) ----------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("ncfg",))
def nb_append(state: NeuralBasisState, x: Array, y: Array, logc: Array,
              *, ncfg: NeuralConfig) -> NeuralBasisState:
    """One observation: ledger row write + rank-1 factor update + O(m^3)
    re-Cholesky.  Flat in n — the whole point of the tier."""
    phi = _features(state, x)                        # (m+1,)
    ptp = state.ptp + jnp.outer(phi, phi)
    pty = state.pty + phi * y
    ptc = state.ptc + phi * logc
    pt1 = state.pt1 + phi
    chol, w_y, w_c = _solve_heads(ncfg, ptp, pty, ptc, pt1,
                                  state.y_mean, state.c_mean)
    return dataclasses.replace(
        state,
        x_buf=jax.lax.dynamic_update_slice(state.x_buf, x[None, :],
                                           (state.n, 0)),
        y_buf=jax.lax.dynamic_update_slice(state.y_buf,
                                           y[None].astype(state.y_buf.dtype),
                                           (state.n,)),
        c_buf=jax.lax.dynamic_update_slice(
            state.c_buf, logc[None].astype(state.c_buf.dtype), (state.n,)),
        n=state.n + 1, since_refit=state.since_refit + 1,
        ptp=ptp, pty=pty, ptc=ptc, pt1=pt1, chol=chol, w_y=w_y, w_c=w_c)


# -- refit (MLP training + exact cache rebuild) -------------------------------
@functools.partial(jax.jit, static_argnames=("ncfg",))
def nb_refit(state: NeuralBasisState, *, ncfg: NeuralConfig
             ) -> NeuralBasisState:
    """Retrain the feature map on the full ledger, then rebuild the Bayes
    head exactly.  DNGO training: full-batch Adam on the MSE of a
    throwaway linear output head; the trained hidden activations become
    the basis."""
    mask = _active_mask(state)
    nf = jnp.maximum(state.n.astype(state.y_buf.dtype), 1.0)
    y_mean = jnp.sum(jnp.where(mask, state.y_buf, 0.0)) / nf
    targets = jnp.where(mask, state.y_buf - y_mean, 0.0)

    def loss(params):
        w1, b1, w2, b2, w3, b3 = params
        h = jnp.tanh(state.x_buf @ w1 + b1)
        f = jnp.tanh(h @ w2 + b2)
        pred = f @ w3 + b3
        err = jnp.where(mask, pred - targets, 0.0)
        return jnp.sum(err * err) / nf

    params = (state.w1, state.b1, state.w2, state.b2, state.w3, state.b3)
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    b1_, b2_, eps = 0.9, 0.999, 1e-8

    def step(carry, t):
        params, m, v = carry
        g = jax.grad(loss)(params)
        m = jax.tree_util.tree_map(
            lambda a, b: b1_ * a + (1 - b1_) * b, m, g)
        v = jax.tree_util.tree_map(
            lambda a, b: b2_ * a + (1 - b2_) * b * b, v, g)
        tf = t.astype(state.y_buf.dtype) + 1.0
        params = jax.tree_util.tree_map(
            lambda p, mm, vv: p - ncfg.refit_lr
            * (mm / (1 - b1_ ** tf)) / (jnp.sqrt(vv / (1 - b2_ ** tf)) + eps),
            params, m, v)
        return (params, m, v), None

    (params, _, _), _ = jax.lax.scan(step, (params, zeros, zeros),
                                     jnp.arange(ncfg.refit_steps))
    w1, b1, w2, b2, w3, b3 = params
    state = dataclasses.replace(state, w1=w1, b1=b1, w2=w2, b2=b2,
                                w3=w3, b3=b3)
    return _rebuild_cache(state, ncfg)


# -- init / promotion ---------------------------------------------------------
def nb_init(d: int, cap: int, key: Array, ncfg: NeuralConfig
            ) -> NeuralBasisState:
    """Empty state with MLP params drawn from `key` (scaled normal)."""
    h, m = ncfg.hidden, ncfg.features
    k1, k2, k3 = jax.random.split(key, 3)
    f32 = jnp.float32
    z = functools.partial(jnp.zeros, dtype=f32)
    m1 = m + 1
    return NeuralBasisState(
        x_buf=z((cap, d)), y_buf=z((cap,)), c_buf=z((cap,)),
        n=jnp.int32(0), since_refit=jnp.int32(0),
        w1=(jax.random.normal(k1, (d, h), f32) / np.sqrt(d)),
        b1=z((h,)),
        w2=(jax.random.normal(k2, (h, m), f32) / np.sqrt(h)),
        b2=z((m,)),
        w3=(jax.random.normal(k3, (m,), f32) / np.sqrt(m)),
        b3=jnp.float32(0.0),
        ptp=z((m1, m1)), pty=z((m1,)), ptc=z((m1,)), pt1=z((m1,)),
        chol=jnp.eye(m1, dtype=f32) * np.sqrt(ncfg.noise2),
        w_y=z((m1,)), w_c=z((m1,)),
        y_mean=jnp.float32(0.0), c_mean=jnp.float32(0.0),
        s2=jnp.float32(1.0))


def nb_capacity(n0: int, ncfg: NeuralConfig) -> int:
    """Initial ledger capacity for a promotion at n0 rows: the next power
    of two with at least n0 rows of headroom (>= cap0)."""
    cap = max(int(ncfg.cap0), 1)
    while cap < 2 * n0:
        cap *= 2
    return cap


def nb_from_data(xs, ys, logcs, key: Array, ncfg: NeuralConfig,
                 cap: int | None = None) -> NeuralBasisState:
    """Promotion entry point: train the tier on a study's full ledger.

    `xs (n0, d)` / `ys (n0,)` are the saturated GP's active buffers,
    `logcs (n0,)` the log of the costs threaded through its tells.  The
    ledger lands padded to `cap` (default `nb_capacity`), the MLP inits
    from `key` and trains immediately (one `nb_refit`), so the first
    escalated suggestion already sees a fitted basis.
    """
    xs = np.asarray(xs, np.float32)
    ys = np.asarray(ys, np.float32)
    logcs = np.asarray(logcs, np.float32)
    n0, d = xs.shape
    cap = int(cap) if cap is not None else nb_capacity(n0, ncfg)
    if cap < n0:
        raise ValueError(f"nb_from_data: cap={cap} < n0={n0}")
    state = nb_init(d, cap, key, ncfg)
    pad = cap - n0
    state = dataclasses.replace(
        state,
        x_buf=jnp.asarray(np.pad(xs, ((0, pad), (0, 0)))),
        y_buf=jnp.asarray(np.pad(ys, (0, pad))),
        c_buf=jnp.asarray(np.pad(logcs, (0, pad))),
        n=jnp.int32(n0))
    return nb_refit(state, ncfg=ncfg)


def nb_grow(state: NeuralBasisState, ncfg: NeuralConfig
            ) -> NeuralBasisState:
    """Double the ledger capacity (host-side pad; factors untouched).
    Called when n == cap — O(log n) recompiles over a study's life."""
    del ncfg
    cap = state.cap
    return dataclasses.replace(
        state,
        x_buf=jnp.asarray(np.pad(np.asarray(state.x_buf),
                                 ((0, cap), (0, 0)))),
        y_buf=jnp.asarray(np.pad(np.asarray(state.y_buf), (0, cap))),
        c_buf=jnp.asarray(np.pad(np.asarray(state.c_buf), (0, cap))))


# -- suggest / fantasize ------------------------------------------------------
def _make_eval_batch(state: NeuralBasisState, acq: acq_mod.AcqConfig,
                     f_best: Array):
    def value(x):
        mean, var = nb_posterior(state, x[None, :])
        fn = acq_mod.ACQUISITIONS[acq.name]
        val = fn(mean, var, f_best, acq.xi)[0]
        if acq.name == "ei_per_cost":
            val = acq_mod.cost_scaled(val, nb_log_cost(state, x))
        return val
    return jax.vmap(jax.value_and_grad(value))


@functools.partial(jax.jit, static_argnames=("acq", "top_t"))
def nb_suggest(state: NeuralBasisState, key: Array, desc=None, *,
               acq: acq_mod.AcqConfig, top_t: int = 1
               ) -> tuple[Array, Array]:
    """Multi-start acquisition ascent against the neural-basis posterior
    over the unit box — the same shared core (`ascend_acquisition`) and
    tie-break law as the lazy-GP tier, so selection is layout-stable.
    With `acq.name == "ei_per_cost"` the surface is EI over predicted
    cost (the learned log-cost head)."""
    d = state.dim
    lo = jnp.zeros((d,), state.x_buf.dtype)
    hi = jnp.ones((d,), state.x_buf.dtype)
    eval_batch = _make_eval_batch(state, acq, _f_best(state))
    project = ((lambda u: desc_mod.project_units(u, desc))
               if desc is not None else None)
    return acq_mod.ascend_acquisition(eval_batch, lo, hi, key, acq, top_t,
                                      project=project,
                                      dtype=state.x_buf.dtype)


def nb_fantasy_value(state: NeuralBasisState, x: Array, liar: str) -> Array:
    """Liar observation for a fantasy row — mirrors gp.fantasy_values."""
    if liar == "pessimistic":
        m = _active_mask(state)
        worst = jnp.max(jnp.where(m, state.y_buf, -jnp.inf))
        return jnp.where(state.n > 0, worst, 0.0)
    mean, _ = nb_posterior(state, x[None, :])
    return mean[0]


@functools.partial(jax.jit, static_argnames=("ncfg", "liar"))
def nb_fantasize(state: NeuralBasisState, xs: Array, *,
                 ncfg: NeuralConfig, liar: str = "mean"
                 ) -> NeuralBasisState:
    """Append `xs (q, d)` as fantasy rows (liar observations, predicted
    log cost).  Fantasies are ordinary rank-1 appends here — rollback is
    NOT a truncation but a state-snapshot restore (the factor updates are
    not bitwise-reversible), which the pool manages (DESIGN.md §15)."""
    def step(st, x):
        y = nb_fantasy_value(st, x, liar)
        return nb_append(st, x, y, nb_log_cost(st, x[None, :])[0],
                         ncfg=ncfg), None
    state, _ = jax.lax.scan(step, state, xs)
    return state


@functools.partial(jax.jit, static_argnames=("ncfg", "acq", "q", "liar"))
def nb_ask_q(state: NeuralBasisState, key: Array, desc=None, *,
             ncfg: NeuralConfig, acq: acq_mod.AcqConfig, q: int,
             liar: str = "mean"
             ) -> tuple[Array, Array, NeuralBasisState]:
    """Sequential-fantasy q-suggestion on the neural-basis tier — the qEI
    recursion of `acquisition.suggest_q` against the O(m^2) posterior.
    Returns `(xs (q, d), vals (q,), fantasized state)`."""
    keys = jax.random.split(key, q)

    def step(st, k):
        x, v = nb_suggest(st, k, desc, acq=acq, top_t=1)
        st = nb_fantasize(st, x, ncfg=ncfg, liar=liar)
        return st, (x[0], v[0])

    st, (xs, vals) = jax.lax.scan(step, state, keys)
    return xs, vals, st


# -- bitwise serialization ----------------------------------------------------
def nb_to_json(state: NeuralBasisState) -> dict:
    """JSON-safe dict: every leaf as base64 of its raw buffer + dtype +
    shape.  Bitwise round-trip — escalated studies ride eviction
    snapshots, checkpoints, and migration records through this."""
    out = {}
    for f in dataclasses.fields(state):
        a = np.asarray(getattr(state, f.name))
        raw = np.ascontiguousarray(a)  # promotes 0-d to (1,): keep a.shape
        out[f.name] = {"b64": base64.b64encode(raw.tobytes()).decode("ascii"),
                       "dtype": a.dtype.str, "shape": list(a.shape)}
    return out


def nb_from_json(d: dict) -> NeuralBasisState:
    kw = {}
    for f in dataclasses.fields(NeuralBasisState):
        spec = d[f.name]
        a = np.frombuffer(base64.b64decode(spec["b64"]),
                          np.dtype(spec["dtype"])).reshape(spec["shape"])
        kw[f.name] = jnp.asarray(a)
    return NeuralBasisState(**kw)
