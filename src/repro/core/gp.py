"""Lazy Gaussian process regression (the paper's surrogate model).

State machine per DESIGN.md §4: fixed-shape padded buffers hold the observed
points, observations, and the identity-padded Cholesky factor; `append` is the
paper's O(n^2) Alg. 3 step; `refit` is the lag-event full refactorization with
kernel hyper-parameter re-estimation via log-marginal-likelihood.

Everything here is shape-static and jit-able; the BO loop compiles once.
All linear algebra dispatches through the substrate (`repro.kernels.ops`) via
the `implementation` knob ("auto" | "pallas" | "xla" | "ref", DESIGN.md §5);
this module owns the padded-state policy only.

**Batched study axis** (DESIGN.md §7): every transition here is
rank-polymorphic.  A `LazyGPState` whose buffers carry a leading study axis
— `x_buf (S, n_max, d)`, `n (S,)` int32, params leaves `(S,)` — represents S
independent studies with *per-study* heterogeneous active counts, lag
counters, and clamp telemetry; `append`/`append_batch`/`posterior`/
`refactor`/`refit_params` detect the extra axis and vmap the single-study
path, so one jitted program advances all S posteriors at once.  A single
study is the S=1 degenerate case.  Build stacked states with
`init_pool_state`/`stack_states`; slice views with `unstack_state`.
"""
from __future__ import annotations

import dataclasses
import jax
import jax.numpy as jnp

from repro.core import cholesky as chol
from repro.core import descriptor as desc_mod
from repro.core.kernels import (KERNELS, KernelFn, KernelParams,
                                make_mixed_kernel)
from repro.kernels import ops

Array = jax.Array


class GPCapacityError(RuntimeError):
    """Base of the capacity-rejection taxonomy (kept as the catch-all for
    back-compat: every admission rejection still `isinstance`-matches it).

    Two subclasses carry the distinction a client needs to react correctly
    — `retryable` says whether waiting and retrying the SAME call can ever
    succeed:

      * `StudySaturatedError` — terminal: the study's lazy-GP slot is at
        `n_max` (pre-escalation).  Retrying never helps; the study must be
        promoted to the neural-basis tier (or its budget is spent).
      * `BackpressureError` — transient: queue depth / in-flight caps /
        slot contention.  Retry after the next tick or after results come
        back.

    The transport layer preserves the concrete type over the wire
    (repro.hpo.transport._WIRE_ERRORS) so remote clients see the same
    taxonomy as in-process ones.
    """

    retryable = False


class StudySaturatedError(GPCapacityError):
    """Terminal: an append/ask can never fit the study's fixed (n_max, …)
    buffers.  Without this guard the row write at index n == n_max would
    clamp and silently corrupt the last row of the factor."""

    retryable = False


class BackpressureError(GPCapacityError):
    """Transient admission rejection (queue full, in-flight cap, every slot
    busy): the same call can succeed after the next tick — retry."""

    retryable = True


def ensure_capacity(n: int, n_max: int, incoming: int = 1) -> None:
    """Host-side capacity guard: fail loudly *before* the buffer overflows."""
    if n + incoming > n_max:
        raise StudySaturatedError(
            f"GP buffer full: n={n} + {incoming} incoming observation(s) "
            f"exceeds n_max={n_max}; raise n_max (GPConfig/BOConfig/"
            f"SchedulerConfig) or stop absorbing")


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class LazyGPState:
    """Padded, fixed-shape GP state (see DESIGN.md §4).

    May carry a leading study axis (DESIGN.md §7): all buffer shapes below
    gain a leading S and the scalars become (S,) vectors.  `is_batched`
    distinguishes the two ranks.
    """

    x_buf: Array        # (n_max, d) observed points
    y_buf: Array        # (n_max,) observations
    l_buf: Array        # (n_max, n_max) identity-padded factor of K + noise I
    li_buf: Array       # (n_max, n_max) identity-padded inverse factor L^{-1},
    # maintained incrementally by the bordered-inverse append (DESIGN.md §4)
    # so every posterior/append is matmul-only (batchable, MXU-friendly)
    alpha: Array        # (n_max,) (K + noise I)^{-1} (y - mean), zero-padded
    n: Array            # () int32 active count
    since_refit: Array  # () int32 appends since last full refactor
    clamp_count: Array  # () int32 appends whose d^2 hit the conditioning floor
    params: KernelParams

    @property
    def is_batched(self) -> bool:
        return self.x_buf.ndim == 3

    @property
    def n_studies(self) -> int:
        return self.x_buf.shape[0] if self.is_batched else 1

    @property
    def n_max(self) -> int:
        return self.x_buf.shape[-2]

    @property
    def dim(self) -> int:
        return self.x_buf.shape[-1]


@dataclasses.dataclass(frozen=True)
class GPConfig:
    n_max: int = 1024
    dim: int = 5
    kernel: str = "matern52"
    lag: int = 0           # 0 = never refit (the fully lazy GP of the paper)
    noise2: float = 1e-6
    rho0: float = 0.25     # initial length scale on the unit box.  The paper
    # fixes rho = 1; on a normalized domain that over-smooths multimodal
    # targets, so the framework default is 0.25 (beyond-paper).  Paper-repro
    # benchmarks pass rho0 = 1.0 explicitly.
    implementation: str = "auto"   # linalg substrate (DESIGN.md §5)
    desc: desc_mod.TypeDescriptor | None = None  # mixed-space type
    # descriptor (DESIGN.md §10): when it carries discrete coordinates,
    # `kernel_fn` becomes the mixed Matérn x categorical kernel over the
    # encoded unit cube.  Travels from the typed SearchSpace through
    # BOConfig / StudyEngine exactly like the `implementation` knob.
    dtype: jnp.dtype = jnp.float32

    def __post_init__(self):
        ops.check_implementation(self.implementation)
        if self.desc is not None and self.desc.has_discrete \
                and self.kernel != "matern52":
            raise ValueError(
                f"mixed spaces require kernel='matern52' (the mixed kernel "
                f"is its Matérn x categorical product), got {self.kernel!r}")

    @property
    def kernel_fn(self) -> KernelFn:
        if self.desc is not None and self.desc.has_discrete:
            return make_mixed_kernel(self.desc.cont_mask, self.desc.cat_mask)
        return KERNELS[self.kernel]


def init_state(cfg: GPConfig, params: KernelParams | None = None) -> LazyGPState:
    params = params or KernelParams(sigma2=1.0, rho=cfg.rho0, noise2=cfg.noise2)
    return LazyGPState(
        x_buf=jnp.zeros((cfg.n_max, cfg.dim), cfg.dtype),
        y_buf=jnp.zeros((cfg.n_max,), cfg.dtype),
        l_buf=jnp.eye(cfg.n_max, dtype=cfg.dtype),
        li_buf=jnp.eye(cfg.n_max, dtype=cfg.dtype),
        alpha=jnp.zeros((cfg.n_max,), cfg.dtype),
        n=jnp.asarray(0, jnp.int32),
        since_refit=jnp.asarray(0, jnp.int32),
        clamp_count=jnp.asarray(0, jnp.int32),
        params=KernelParams(*[jnp.asarray(v, cfg.dtype)
                              for v in (params.sigma2, params.rho, params.noise2)]),
    )


# ---------------------------------------------------------------------------
# Batched study axis (DESIGN.md §7): stacked-state constructors and views.
# ---------------------------------------------------------------------------

def init_pool_state(cfg: GPConfig, n_studies: int,
                    params: KernelParams | None = None) -> LazyGPState:
    """Stacked state for `n_studies` independent studies (leading S axis).

    Every study starts empty with identical kernel params; per-study params
    diverge at lag events (`refit_params` on the stacked state returns
    `(S,)`-leaved params).
    """
    if n_studies < 1:
        raise ValueError(f"n_studies must be >= 1, got {n_studies}")
    st = init_state(cfg, params)
    return jax.tree.map(
        lambda a: jnp.repeat(a[None], n_studies, axis=0), st)


def stack_states(states: "list[LazyGPState]") -> LazyGPState:
    """Stack single-study states into one batched state (shared n_max/dim)."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *states)


def unstack_state(state: LazyGPState, study: int) -> LazyGPState:
    """Single-study view of a stacked state (static index)."""
    return jax.tree.map(lambda a: a[study], state)


def _vmap_states(fn, state: LazyGPState, *batched_args):
    """Apply the single-study transition `fn` across the study axis."""
    return jax.vmap(fn)(state, *batched_args)


def _active_mask(state: LazyGPState) -> Array:
    return jnp.arange(state.n_max) < state.n


def _ymean(state: LazyGPState) -> Array:
    """Mean of the active observations (GP prior mean = running mean)."""
    m = _active_mask(state)
    cnt = jnp.maximum(state.n, 1)
    return jnp.sum(jnp.where(m, state.y_buf, 0.0)) / cnt


def _recompute_alpha(state: LazyGPState,
                     implementation: str = "auto") -> Array:
    """alpha = (K + noise I)^{-1} (y - mean) = L^{-T} (L^{-1} r).

    Two matvecs against the maintained inverse factor (padding-exact: rows
    >= n of `li_buf` are identity against a zero-padded residual).
    """
    del implementation  # matmul-only against the maintained inverse
    resid = jnp.where(_active_mask(state), state.y_buf - _ymean(state), 0.0)
    z = state.li_buf @ resid
    return jnp.where(_active_mask(state), z @ state.li_buf, 0.0)


def _cov_column(state: LazyGPState, kernel: KernelFn, x_new: Array,
                implementation: str = "auto") -> tuple[Array, Array]:
    """(p_pad, c): covariances of x_new against actives (padded) and itself."""
    p = ops.kernel_gram(kernel, state.x_buf, x_new[None, :], state.params,
                        implementation=implementation)[:, 0]
    p_pad = jnp.where(_active_mask(state), p, 0.0)
    c = kernel(x_new[None, :], x_new[None, :], state.params)[0, 0] + state.params.noise2
    return p_pad, c


def _append_row_only(state: LazyGPState, kernel: KernelFn, x_new: Array,
                     y_new: Array, implementation: str) -> LazyGPState:
    """Row append with a *stale* alpha — the deferred-alpha batch path.

    Callers must refresh alpha (`_recompute_alpha`) before the state is used
    for posterior queries; `append_batch` does so once per batch.
    """
    p_pad, c = _cov_column(state, kernel, x_new, implementation)
    l_buf, li_buf, _, clamped = ops.padded_append_row(
        state.l_buf, state.li_buf, p_pad, c, state.n,
        implementation=implementation)
    x_buf = jax.lax.dynamic_update_slice(state.x_buf, x_new[None, :], (state.n, 0))
    y_buf = jax.lax.dynamic_update_slice(state.y_buf, y_new[None], (state.n,))
    return dataclasses.replace(
        state, x_buf=x_buf, y_buf=y_buf, l_buf=l_buf, li_buf=li_buf,
        n=state.n + 1, since_refit=state.since_refit + 1,
        clamp_count=state.clamp_count + clamped)


def append(state: LazyGPState, kernel: KernelFn, x_new: Array,
           y_new: Array, *, implementation: str = "auto") -> LazyGPState:
    """Absorb one observation in O(n_max^2) (paper Alg. 3).

    Traced-shape safe: can run under jit with n as a traced value.  Uses the
    substrate's fused append — the row solve and the alpha refresh share one
    factor residency (two passes instead of three independent solves).

    Batched: stacked state + `x_new (S, d)`, `y_new (S,)` appends one row to
    every study in one dispatch (per-study heterogeneous n).
    """
    if state.is_batched:
        return _vmap_states(
            lambda st, x, y: append(st, kernel, x, y,
                                    implementation=implementation),
            state, x_new, y_new)
    n_max = state.n_max
    p_pad, c = _cov_column(state, kernel, x_new, implementation)
    x_buf = jax.lax.dynamic_update_slice(state.x_buf, x_new[None, :], (state.n, 0))
    y_buf = jax.lax.dynamic_update_slice(state.y_buf, y_new[None], (state.n,))
    n_new = state.n + 1
    mask_new = jnp.arange(n_max) < n_new
    ymean = jnp.sum(jnp.where(mask_new, y_buf, 0.0)) / jnp.maximum(n_new, 1)
    resid = jnp.where(mask_new, y_buf - ymean, 0.0)
    l_buf, li_buf, alpha, _, clamped = ops.lazy_append(
        state.l_buf, state.li_buf, p_pad, c, resid, state.n,
        implementation=implementation)
    return dataclasses.replace(
        state, x_buf=x_buf, y_buf=y_buf, l_buf=l_buf, li_buf=li_buf,
        alpha=alpha, n=n_new, since_refit=state.since_refit + 1,
        clamp_count=state.clamp_count + clamped)


def append_batch(state: LazyGPState, kernel: KernelFn, xs: Array,
                 ys: Array, *, implementation: str = "auto") -> LazyGPState:
    """Absorb t observations as t sequential O(n^2) appends (paper Sec. 3.4).

    Under a frozen kernel the appends commute up to row order, so the HPO
    scheduler may feed results in *completion* order (async absorption).

    The alpha refresh is deferred to once per batch: each row append is a
    single forward solve, and the two alpha solves run once at the end —
    cutting 2(t-1) O(n_max^2) solves per parallel round vs. refreshing after
    every row.  The result is numerically equivalent (to solver round-off)
    to t sequential `append` calls: alpha depends only on the final factor
    and residual, though the fused sequential path accumulates rounding
    differently than the final two-solve refresh.

    Batched: stacked state + `xs (S, t, d)`, `ys (S, t)` absorbs t rows per
    study in one dispatch.
    """
    if state.is_batched:
        return _vmap_states(
            lambda st, x, y: append_batch(st, kernel, x, y,
                                          implementation=implementation),
            state, xs, ys)

    def body(i, st):
        return _append_row_only(st, kernel, xs[i], ys[i], implementation)

    st = jax.lax.fori_loop(0, xs.shape[0], body, state)
    return dataclasses.replace(
        st, alpha=_recompute_alpha(st, implementation))


# ---------------------------------------------------------------------------
# Fantasy rows: the q-suggestion protocol (DESIGN.md §12).
# ---------------------------------------------------------------------------

FANTASY_LIARS = ("mean", "pessimistic")


@dataclasses.dataclass(frozen=True)
class FantasyConfig:
    """Liar policy for pending-trial fantasies (Snoek et al. 2012).

    * "mean"        — kriging believer: the liar value is the posterior mean
                      at the fantasy point, so the mean surface is (nearly)
                      unchanged and only the variance collapses there.
    * "pessimistic" — constant liar: the worst (max) active observation, so
                      the fantasized point actively repels later suggestions.
    """

    liar: str = "mean"

    def __post_init__(self):
        if self.liar not in FANTASY_LIARS:
            raise ValueError(
                f"unknown fantasy liar {self.liar!r}; "
                f"expected one of {FANTASY_LIARS}")


def fantasy_values(state: LazyGPState, kernel: KernelFn, xs: Array,
                   liar: str = "mean", *,
                   implementation: str = "auto") -> Array:
    """Liar observations for fantasy points `xs (q, d)` against `state`.

    Computed against the *input* state for the whole batch (believer values
    do not see each other — exact for q = 1, the per-step path of the
    q-suggest loop; a constant-liar-per-batch approximation for the q > 1
    replay path, which is fine because fantasy rows are scratch state that
    never survives a tell).
    """
    if liar == "pessimistic":
        m = _active_mask(state)
        worst = jnp.max(jnp.where(m, state.y_buf, -jnp.inf))
        worst = jnp.where(state.n > 0, worst, 0.0)
        return jnp.full((xs.shape[0],), worst, state.y_buf.dtype)
    mean, _ = posterior(state, kernel, xs, implementation=implementation)
    return mean


def fantasize(state: LazyGPState, kernel: KernelFn, xs: Array,
              liar: str = "mean", *,
              implementation: str = "auto") -> LazyGPState:
    """Append q fantasy rows in ONE `lazy_append_rows` dispatch.

    Fantasy rows are full bordered appends — the factor, inverse, and alpha
    all see them, so EI ascent against the fantasized state is the ordinary
    ascent — but they deliberately do NOT touch `since_refit` or
    `clamp_count`: fantasies are scratch state (they must never trigger a
    lag-event refit, and their rollback must not have to un-count
    telemetry).  Rollback is `truncate(state, n_real)`.

    Batched: stacked state + `xs (S, q, d)` fantasizes q rows per study in
    one dispatch.
    """
    if state.is_batched:
        return _vmap_states(
            lambda st, x: fantasize(st, kernel, x, liar,
                                    implementation=implementation),
            state, xs)
    q = xs.shape[0]
    n_max = state.n_max
    ys = fantasy_values(state, kernel, xs, liar,
                        implementation=implementation)
    x_buf = jax.lax.dynamic_update_slice(state.x_buf, xs, (state.n, 0))
    y_buf = jax.lax.dynamic_update_slice(state.y_buf, ys, (state.n,))
    idx = jnp.arange(n_max)
    n_new = state.n + q
    # Column i covers actives + earlier fantasy rows: rows idx < n + i of
    # the final point buffer.
    p_all = ops.kernel_gram(kernel, x_buf, xs, state.params,
                            implementation=implementation)   # (n_max, q)
    cols = jnp.where(idx[:, None] < (state.n + jnp.arange(q))[None, :],
                     p_all, 0.0)
    cs = jax.vmap(lambda x: kernel(x[None, :], x[None, :],
                                   state.params)[0, 0])(xs) \
        + state.params.noise2
    mask_new = idx < n_new
    ymean = jnp.sum(jnp.where(mask_new, y_buf, 0.0)) / jnp.maximum(n_new, 1)
    resid = jnp.where(mask_new, y_buf - ymean, 0.0)
    l_buf, li_buf, alpha, _, _ = ops.lazy_append_rows(
        state.l_buf, state.li_buf, cols.T, cs, resid, state.n,
        implementation=implementation)
    return dataclasses.replace(
        state, x_buf=x_buf, y_buf=y_buf, l_buf=l_buf, li_buf=li_buf,
        alpha=alpha, n=n_new)


def truncate(state: LazyGPState, n_real: Array) -> LazyGPState:
    """Roll back every row >= n_real to the identity-padded empty state.

    Bitwise-exact by the padding invariant (DESIGN.md §3/§12): appends only
    ever write row n of `l_buf`/`li_buf` and row n of `x_buf`/`y_buf`, and
    before the rows being rolled back were appended, those rows were exactly
    identity (factor/inverse) and exactly zero (points/observations).
    Restoring the constants therefore restores the pre-append buffers bit
    for bit — no arithmetic is undone, rows are simply re-padded.  Alpha is
    recomputed against the restored inverse; any real append that follows
    (the tell replay) recomputes it again through the ordinary fused path,
    so the post-replay state is bitwise-identical to a never-fantasized run.

    `since_refit`/`clamp_count` are untouched because `fantasize` never
    advanced them.  Batched: `n_real (S,)` truncates every study in one
    dispatch.
    """
    if state.is_batched:
        return _vmap_states(truncate, state, n_real)
    n_max = state.n_max
    idx = jnp.arange(n_max)
    pad = idx[:, None] >= n_real
    eye = jnp.eye(n_max, dtype=state.l_buf.dtype)
    st = dataclasses.replace(
        state,
        x_buf=jnp.where(pad, 0.0, state.x_buf),
        y_buf=jnp.where(idx >= n_real, 0.0, state.y_buf),
        l_buf=jnp.where(pad, eye, state.l_buf),
        li_buf=jnp.where(pad, eye, state.li_buf),
        n=jnp.asarray(n_real, jnp.int32))
    return dataclasses.replace(st, alpha=_recompute_alpha(st))


def posterior(state: LazyGPState, kernel: KernelFn, x_star: Array,
              *, implementation: str = "auto",
              ymean: Array | None = None) -> tuple[Array, Array]:
    """Posterior mean and variance at query points x_star (m, d).

    mean = k_*^T alpha + ymean ; var = k_** - v^T v with v = L^{-1} k_*
    (paper Alg. 1 lines 3-6), on padded buffers.

    `ymean` is the active-observation mean; it is recomputed from the state
    when omitted.  Callers that query one frozen state many times (the EI
    ascent: steps x restarts posteriors per suggest call) hoist `_ymean`
    once and pass it in — the loop-invariant reduction then runs once per
    call instead of once per posterior (pinned by a trace-count test).

    Batched: stacked state + `x_star (S, m, d)` returns `(S, m)` mean/var
    (`ymean`, if hoisted, is the matching `(S,)` vector).
    """
    if state.is_batched:
        if ymean is None:
            return _vmap_states(
                lambda st, xq: posterior(st, kernel, xq,
                                         implementation=implementation),
                state, x_star)
        return jax.vmap(
            lambda st, xq, ym: posterior(st, kernel, xq,
                                         implementation=implementation,
                                         ymean=ym))(state, x_star, ymean)
    if ymean is None:
        ymean = _ymean(state)
    k_star = ops.kernel_gram(kernel, state.x_buf, x_star, state.params,
                             implementation=implementation)   # (n_max, m)
    k_star = jnp.where(_active_mask(state)[:, None], k_star, 0.0)
    mean = k_star.T @ state.alpha + ymean
    # v = L^{-1} k_* as a matmul against the maintained inverse (exact on
    # the padded buffers: k_* is zero beyond n).  Matmul-only keeps the EI
    # ascent batchable over the study axis (DESIGN.md §7).
    v = state.li_buf @ k_star                                 # (n_max, m)
    k_ss = kernel(x_star, x_star, state.params)
    var = jnp.maximum(jnp.diag(k_ss) - jnp.sum(v * v, axis=0), 1e-12)
    return mean, var


def log_marginal_likelihood(state: LazyGPState) -> Array:
    """log p(y | X) = -1/2 y^T alpha - sum log L_ii - n/2 log 2pi (Alg. 1 l.7).

    Identity padding contributes log(1) = 0 to the diagonal sum, so the padded
    computation is exact.  Batched: returns `(S,)` per-study LMLs.
    """
    if state.is_batched:
        return _vmap_states(log_marginal_likelihood, state)
    m = _active_mask(state)
    resid = jnp.where(m, state.y_buf - _ymean(state), 0.0)
    quad = resid @ state.alpha
    logdet = jnp.sum(jnp.where(m, jnp.log(jnp.diagonal(state.l_buf)), 0.0))
    return -0.5 * quad - logdet - 0.5 * state.n * jnp.log(2.0 * jnp.pi)


# ---------------------------------------------------------------------------
# Lag-event refit (paper Sec. 4.1, the lagging factor l).
# ---------------------------------------------------------------------------

def refactor(state: LazyGPState, kernel: KernelFn,
             params: KernelParams | None = None,
             *, implementation: str = "auto") -> LazyGPState:
    """Full O(n^3) refactorization (optionally with new kernel params).

    Routed through the substrate's blocked factorization on the identity-
    padded Gram buffer.

    Batched: refactors every study in one dispatch; `params`, if given, must
    carry `(S,)` leaves (per-study hyper-parameters).
    """
    if state.is_batched:
        if params is None:
            return _vmap_states(
                lambda st: refactor(st, kernel,
                                    implementation=implementation), state)
        return _vmap_states(
            lambda st, p: refactor(st, kernel, p,
                                   implementation=implementation),
            state, params)
    params = params or state.params
    st = dataclasses.replace(state, params=params)
    k_pad = ops.masked_gram(st.x_buf, st.n, kernel, params,
                            implementation=implementation)
    l_buf = chol.lazy_full_refactor(k_pad, st.n, n_max=st.n_max,
                                    implementation=implementation)
    # Rebuild the maintained inverse from scratch (the one place a
    # triangular solve runs; lag-amortized like the factorization itself).
    li_buf = ops.padded_tri_inverse(l_buf, implementation=implementation)
    st = dataclasses.replace(st, l_buf=l_buf, li_buf=li_buf,
                             since_refit=jnp.asarray(0, jnp.int32))
    return dataclasses.replace(
        st, alpha=_recompute_alpha(st, implementation))


def _lml_for(state: LazyGPState, kernel: KernelFn, params: KernelParams,
             implementation: str = "auto") -> Array:
    """LML under candidate params (full rebuild; only used at lag events)."""
    st = refactor(state, kernel, params, implementation=implementation)
    return log_marginal_likelihood(st)


def refit_params(state: LazyGPState, kernel: KernelFn,
                 rho_grid: Array | None = None,
                 sigma2_grid: Array | None = None,
                 *, implementation: str = "auto") -> KernelParams:
    """Multi-restart (grid) LML maximization over (sigma2, rho).

    The paper refits "at reasonable intervals"; a coarse grid is robust, jits
    to a fixed program, and costs l-amortized O(G n^3).

    Batched: returns per-study `KernelParams` with `(S,)` leaves.
    """
    if state.is_batched:
        return _vmap_states(
            lambda st: refit_params(st, kernel, rho_grid, sigma2_grid,
                                    implementation=implementation), state)
    if rho_grid is None:
        # Unit-box length scales (inputs are normalized by the BO driver).
        rho_grid = jnp.asarray([0.05, 0.1, 0.2, 0.4, 0.8, 1.6],
                               state.x_buf.dtype)
    if sigma2_grid is None:
        sigma2_grid = jnp.asarray([0.25, 1.0, 4.0], state.x_buf.dtype)

    rr, ss = jnp.meshgrid(rho_grid, sigma2_grid, indexing="ij")
    cand = jnp.stack([ss.ravel(), rr.ravel()], axis=-1)  # (G, 2) [sigma2, rho]

    def score(c):
        p = KernelParams(sigma2=c[0], rho=c[1], noise2=state.params.noise2)
        return _lml_for(state, kernel, p, implementation)

    lmls = jax.lax.map(score, cand)
    best = jnp.argmax(lmls)
    return KernelParams(sigma2=cand[best, 0], rho=cand[best, 1],
                        noise2=state.params.noise2)


def maybe_refit(state: LazyGPState, kernel: KernelFn, lag: int,
                *, implementation: str = "auto") -> LazyGPState:
    """Apply the lag policy: every `lag` appends, refit params + refactor.

    lag <= 0 means never (the fully lazy GP); lag == 1 reproduces the standard
    per-iteration refit (the paper's baseline semantics).
    """
    if lag <= 0:
        return state

    def do_refit(st):
        params = refit_params(st, kernel, implementation=implementation)
        return refactor(st, kernel, params, implementation=implementation)

    return jax.lax.cond(state.since_refit >= lag, do_refit, lambda s: s, state)


# ---------------------------------------------------------------------------
# Reference (non-lazy) GP for parity tests and the naive baseline.
# ---------------------------------------------------------------------------

def dense_posterior(x: Array, y: Array, x_star: Array, kernel: KernelFn,
                    params: KernelParams,
                    implementation: str = "auto") -> tuple[Array, Array]:
    """Textbook GP posterior with a fresh full factorization (paper Alg. 1)."""
    n = x.shape[0]
    k = kernel(x, x, params) + params.noise2 * jnp.eye(n, dtype=x.dtype)
    l = ops.cholesky(k, implementation=implementation)
    ymean = jnp.mean(y)
    resid = y - ymean
    k_star = kernel(x, x_star, params)
    k_ss_diag = jnp.diag(kernel(x_star, x_star, params))
    mean, var = ops.gp_posterior_solve(l, resid, k_star, k_ss_diag,
                                       implementation=implementation)
    return mean + ymean, var
