"""Acquisition functions and their optimizers.

Expected Improvement (paper Sec. 3.2.1) plus a jit-able multi-start optimizer
that returns either the single argmax (sequential BO) or the top-t *local
maxima* (paper Sec. 3.4's parallel strategy: "not only use the maximal
expected improvement ... but the t best local maxima").

Local maxima are approximated by multi-start projected gradient ascent from R
random restarts followed by spatial deduplication: ascended points that
converge to the same basin collapse to one representative, and the t best
distinct basins are returned.  This is fixed-shape (R restarts, S ascent
steps) so the whole suggestion step compiles once.

The EI ascent runs on the **fused megakernel** (DESIGN.md §11) wherever the
substrate covers it: every step evaluates EI value + analytic gradient for
the whole (R, d) restart batch in one dispatch (`ops.fused_ei_grad`), with
the loop-invariant pieces — f_best, the active-observation mean,
`A = li_buf^T li_buf`, and the active mask — hoisted once per suggest call.
`AcqConfig.fused` controls the path: "auto" (default) uses it for every
substrate except "ref", which stays on the generic autodiff ascent as the
independent oracle the parity suite compares against.

Restart selection quantizes the acquisition values (low-mantissa clearing)
before the argmax / top-t sort, so substrate- and layout-level round-off
(mesh="none" vs. a sharded ascent) never flips which restart wins a
numerical tie — the chosen cell is identical across layouts.  Reported
values stay exact.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import descriptor as desc_mod
from repro.core import gp as gp_mod
from repro.core.kernels import KernelFn
from repro.kernels import ops

Array = jax.Array

_SQRT2 = 1.4142135623730951


def _norm_pdf(z: Array) -> Array:
    return jnp.exp(-0.5 * z * z) / jnp.sqrt(2.0 * jnp.pi)


def _norm_cdf(z: Array) -> Array:
    return 0.5 * (1.0 + jax.lax.erf(z / _SQRT2))


def expected_improvement(mean: Array, var: Array, f_best: Array,
                         xi: float = 0.01) -> Array:
    """EI(x) = gamma Phi(Z) + sigma phi(Z)  (paper Eq. 11, maximization form).

    gamma = mu(x) - f_best - xi ; Z = gamma / sigma.  xi trades exploration
    for exploitation.
    """
    sigma = jnp.sqrt(var)
    gamma = mean - f_best - xi
    z = jnp.where(sigma > 0, gamma / jnp.maximum(sigma, 1e-12), 0.0)
    ei = gamma * _norm_cdf(z) + sigma * _norm_pdf(z)
    return jnp.where(sigma > 0, jnp.maximum(ei, 0.0), 0.0)


def upper_confidence_bound(mean: Array, var: Array, f_best: Array,
                           beta: float = 2.0) -> Array:
    del f_best
    return mean + beta * jnp.sqrt(var)


ACQUISITIONS: dict[str, Callable[..., Array]] = {
    "ei": expected_improvement,
    # EI-per-unit-cost (FABOLAS-style): the posterior term is plain EI; the
    # division by the predicted cost happens in `_acq_value` when the caller
    # supplies a `log_cost_fn` (a learned log-cost head — see
    # repro.core.neural_basis).  Without one it degrades to plain EI, so a
    # study configured for cost-aware acquisition still serves on tiers
    # that carry no cost model.
    "ei_per_cost": expected_improvement,
    "ucb": upper_confidence_bound,
}

# Predicted log-cost is clipped before exponentiation so a wild early cost
# head can never zero out (or explode) the acquisition surface.
_LOG_COST_CLIP = 20.0


def cost_scaled(value: Array, log_cost: Array) -> Array:
    """acq / exp(log_cost): EI per unit of predicted cost (FABOLAS)."""
    return value * jnp.exp(-jnp.clip(log_cost, -_LOG_COST_CLIP,
                                     _LOG_COST_CLIP))


FUSED_MODES = ("auto", "on", "off")


@dataclasses.dataclass(frozen=True)
class AcqConfig:
    name: str = "ei"
    xi: float = 0.01
    restarts: int = 64          # R multi-start seeds
    ascent_steps: int = 25      # S projected-gradient steps per seed
    lr: float = 0.05            # in units of the box width
    dedup_radius: float = 0.08  # basin-merge radius, units of box width
    fused: str = "auto"         # fused EI megakernel (DESIGN.md §11):
    # "auto" = fused wherever the substrate covers it except "ref" (the
    # autodiff oracle), "on" = force fused (parity tests), "off" = never.


def _acq_value(state: gp_mod.LazyGPState, kernel: KernelFn, x: Array,
               f_best: Array, cfg: AcqConfig,
               implementation: str = "auto",
               ymean: Array | None = None,
               log_cost_fn: Callable[[Array], Array] | None = None) -> Array:
    mean, var = gp_mod.posterior(state, kernel, x[None, :],
                                 implementation=implementation, ymean=ymean)
    fn = ACQUISITIONS[cfg.name]
    val = fn(mean, var, f_best, cfg.xi)[0]
    if cfg.name == "ei_per_cost" and log_cost_fn is not None:
        val = cost_scaled(val, log_cost_fn(x))
    return val


def _f_best(state: gp_mod.LazyGPState) -> Array:
    m = jnp.arange(state.n_max) < state.n
    return jnp.max(jnp.where(m, state.y_buf, -jnp.inf))


# Mantissa bits cleared by the selection tie-break: values within ~2^-11
# relative distance collapse to one bucket — orders of magnitude wider than
# substrate/layout round-off (a few ulps), orders of magnitude tighter than
# any real EI difference between distinct basins.
_TIEBREAK_MANTISSA_BITS = 12


def _quantize_for_tiebreak(vals: Array) -> Array:
    """Scale-free float32 quantization used ONLY for restart selection.

    Clearing low mantissa bits is monotone (never reorders values beyond
    collapsing near-ties), so argmax / the stable descending sort pick the
    same (first) restart index under mesh="none" and any sharded layout
    even when the two layouts' arithmetic differs by ulps.  Reported
    acquisition values stay exact — this never touches them.
    """
    bits = jax.lax.bitcast_convert_type(vals.astype(jnp.float32), jnp.uint32)
    bits = bits & jnp.uint32((0xFFFFFFFF << _TIEBREAK_MANTISSA_BITS)
                             & 0xFFFFFFFF)
    return jax.lax.bitcast_convert_type(bits, jnp.float32)


def _use_fused(cfg: AcqConfig, kernel: KernelFn, implementation: str) -> bool:
    """Host-side fused-path policy (baked into the jitted program)."""
    if cfg.fused not in FUSED_MODES:
        raise ValueError(f"unknown AcqConfig.fused {cfg.fused!r}; "
                         f"expected one of {FUSED_MODES}")
    if cfg.fused == "off" or not ops.fused_supported(kernel, cfg.name):
        return False
    return cfg.fused == "on" or implementation != "ref"


def _make_eval_batch(state: gp_mod.LazyGPState, kernel: KernelFn,
                     cfg: AcqConfig, implementation: str, fused: bool,
                     f_best: Array, ymean: Array, tune_s: int,
                     log_cost_fn: Callable[[Array], Array] | None = None):
    """Build `eval(X (r, d)) -> (vals (r,), grads (r, d))` for the ascent.

    Fused: hoists the loop-invariant precompute — the active mask,
    `A = li_buf^T li_buf` (one GEMM amortized over every ascent step), and
    the scalar shift `ymean - f_best - xi` — and closes over it, so each
    step is a single `ops.fused_ei_grad` dispatch for the whole batch.

    Unfused: the generic autodiff path (any acquisition, any kernel),
    with `f_best`/`ymean` still hoisted out of the jitted restart loop.
    """
    if fused:
        amask = (jnp.arange(state.n_max) < state.n).astype(state.x_buf.dtype)
        a_buf = state.li_buf.T @ state.li_buf
        shift = ymean - f_best - cfg.xi
        cont_mask = getattr(kernel, "cont_mask", None)
        cat_mask = getattr(kernel, "cat_mask", None)

        def eval_batch(x):
            return ops.fused_ei_grad(
                x, state.x_buf, amask, state.alpha, a_buf,
                state.params.sigma2, state.params.rho, shift,
                cont_mask=cont_mask, cat_mask=cat_mask,
                implementation=implementation, tune_s=tune_s)

        return eval_batch
    value = lambda x: _acq_value(state, kernel, x, f_best, cfg,
                                 implementation, ymean=ymean,
                                 log_cost_fn=log_cost_fn)
    return jax.vmap(jax.value_and_grad(value))


def ei_value_and_grad(state: gp_mod.LazyGPState, kernel: KernelFn,
                      x: Array, cfg: AcqConfig | None = None, *,
                      implementation: str = "auto", fused: bool = True,
                      tune_s: int = 1) -> tuple[Array, Array]:
    """Acquisition value + gradient for a whole (r, d) candidate batch.

    `fused=True` runs the megakernel step (DESIGN.md §11); `fused=False`
    runs the generic autodiff oracle on the same hoisted invariants.  One
    ascent iteration evaluates exactly this — exposed so the parity suite
    and the phase benchmarks exercise the real step in isolation.
    Single-study states; vmap over a stacked state for the batched form.
    """
    cfg = cfg or AcqConfig()
    eval_batch = _make_eval_batch(
        state, kernel, cfg, implementation, fused,
        _f_best(state), gp_mod._ymean(state), tune_s)
    return eval_batch(x)


def ascend_acquisition(eval_batch, lo: Array, hi: Array, key: Array,
                       cfg: AcqConfig, top_t: int = 1,
                       *, project=None,
                       restart_axis: str | None = None,
                       restart_shards: int = 1,
                       dtype=jnp.float32) -> tuple[Array, Array]:
    """Model-free multi-start ascent + layout-stable selection core.

    `eval_batch(X (r, d)) -> (vals (r,), grads (r, d))` is the acquisition
    oracle; everything else — seed generation, projected-gradient ascent,
    restart sharding, tie-break-quantized argmax / greedy top-t dedup with
    jittered backfill — is model-independent and shared between the
    lazy-GP tier (`optimize_acquisition` builds the oracle from a
    `LazyGPState`) and the neural-basis tier (repro.core.neural_basis
    builds it from the Bayesian linear head, optionally cost-scaled).
    `project` (optional) repairs each iterate onto a feasible lattice
    (mixed spaces, DESIGN.md §10).
    """
    if cfg.restarts % restart_shards:
        raise ValueError(
            f"restart shards ({restart_shards}) must divide "
            f"cfg.restarts ({cfg.restarts})")
    lo = jnp.asarray(lo)
    hi = jnp.asarray(hi)
    d = lo.shape[-1]
    width = hi - lo

    seeds = lo + (hi - lo) * jax.random.uniform(key, (cfg.restarts, d),
                                                dtype=dtype)

    point_project = project if project is not None else (lambda u: u)
    project_rows = ((lambda u: jax.vmap(point_project)(u))
                    if project is not None else (lambda u: u))

    def ascend_batch(x):
        # Whole-batch ascent: every step evaluates the (r, d) candidate
        # matrix in one fused dispatch (or one vmapped autodiff pass on
        # the unfused path).  Mixed ascent: gradient step on the
        # continuous coordinates (the categorical factor carries no
        # gradient), then round-and-repair back onto the int/categorical
        # lattice — every iterate, and the seed itself, is feasible.
        def step(_, x):
            _, g = eval_batch(x)
            gn = jnp.linalg.norm(g, axis=-1, keepdims=True)
            g = jnp.where(gn > 0, g / jnp.maximum(gn, 1e-12), 0.0)
            return project_rows(jnp.clip(x + cfg.lr * width * g, lo, hi))
        finals = jax.lax.fori_loop(0, cfg.ascent_steps, step,
                                   project_rows(x))
        vals, _ = eval_batch(finals)
        return finals, vals

    if restart_axis is not None and restart_shards > 1:
        # Ascend only this shard's contiguous slice of the seeds, then
        # reassemble: all_gather(tiled) concatenates in axis-index order,
        # restoring the exact unsharded restart order.
        r_local = cfg.restarts // restart_shards
        idx = jax.lax.axis_index(restart_axis)
        local = jax.lax.dynamic_slice_in_dim(seeds, idx * r_local, r_local)
        finals, vals = ascend_batch(local)          # (R/shards, d), (R/shards,)
        finals = jax.lax.all_gather(finals, restart_axis, tiled=True)
        vals = jax.lax.all_gather(vals, restart_axis, tiled=True)
    else:
        finals, vals = ascend_batch(seeds)              # (R, d), (R,)

    # Selection runs on tie-break-quantized values (layout-stable winner);
    # the returned values are the exact ones.
    qvals = _quantize_for_tiebreak(vals)
    if top_t == 1:
        # Fast path: the greedy dedup below returns the plain argmax when
        # only one suggestion is requested, so skip its R-iteration loop.
        best = jnp.argmax(qvals)
        return finals[best][None, :], vals[best][None]

    # Spatial dedup: greedy pick best, suppress all restarts within radius.
    order = jnp.argsort(-qvals)
    finals = finals[order]
    vals = vals[order]
    radius = cfg.dedup_radius * jnp.linalg.norm(width)

    def pick(i, carry):
        chosen, chosen_vals, suppressed, count = carry
        is_free = ~suppressed[i] & (count < top_t)
        chosen = jax.lax.cond(
            is_free,
            lambda c: jax.lax.dynamic_update_slice(c, finals[i][None, :],
                                                   (count, 0)),
            lambda c: c, chosen)
        chosen_vals = jax.lax.cond(
            is_free,
            lambda c: jax.lax.dynamic_update_slice(c, vals[i][None], (count,)),
            lambda c: c, chosen_vals)
        dist = jnp.linalg.norm(finals - finals[i], axis=-1)
        suppressed = jnp.where(is_free, suppressed | (dist < radius), suppressed)
        count = count + jnp.where(is_free, 1, 0)
        return chosen, chosen_vals, suppressed, count

    chosen0 = jnp.zeros((top_t, d), finals.dtype)
    vals0 = jnp.full((top_t,), -jnp.inf, vals.dtype)
    suppressed0 = jnp.zeros((cfg.restarts,), bool)
    chosen, chosen_vals, _, count = jax.lax.fori_loop(
        0, cfg.restarts, pick, (chosen0, vals0, suppressed0, 0))

    # If fewer than top_t distinct basins exist, back-fill with jittered
    # copies of the best point so the batch shape stays fixed (re-projected
    # so mixed-space backfills stay on the feasible lattice).
    jitter = 0.01 * width * jax.random.normal(
        jax.random.fold_in(key, 1), (top_t, d), dtype=finals.dtype)
    fallback = jax.vmap(point_project)(jnp.clip(chosen[0] + jitter, lo, hi))
    filled = jnp.arange(top_t) < count
    chosen = jnp.where(filled[:, None], chosen, fallback)
    chosen_vals = jnp.where(filled, chosen_vals, chosen_vals[0])
    return chosen, chosen_vals


def optimize_acquisition(state: gp_mod.LazyGPState, kernel: KernelFn,
                         lo: Array, hi: Array, key: Array,
                         cfg: AcqConfig, top_t: int = 1,
                         *, implementation: str = "auto",
                         restart_axis: str | None = None,
                         restart_shards: int = 1,
                         desc: desc_mod.TypeDescriptor | None = None,
                         log_cost_fn: Callable[[Array], Array] | None = None,
                         _tune_s: int = 1) -> tuple[Array, Array]:
    """Return (points (top_t, d), acq values (top_t,)), best first.

    top_t = 1 is standard sequential BO; top_t = t implements the paper's
    parallel suggestion of the t best distinct local maxima.  `implementation`
    selects the linalg substrate for the posterior solves inside the ascent.

    Batched (DESIGN.md §7): a stacked state (leading study axis S) returns
    `((S, top_t, d), (S, top_t))` — one vmapped dispatch suggests for every
    study at once.  `key` may be a single key (split per study) or `(S,)`
    stacked keys; `lo`/`hi` may be shared `(d,)` or per-study `(S, d)`.

    Sharded (DESIGN.md §8): inside a `shard_map` whose mesh carries a
    `restart_axis` of size `restart_shards`, each shard ascends only its
    R/restart_shards slice of the seeds and an `all_gather` reassembles the
    full (R,) candidate set before dedup — every shard then computes the
    identical result (replicated outputs).  Seeds are generated from the
    full `key` on every shard and sliced by `axis_index`, so the sharded
    ascent sees exactly the seeds the unsharded path would.

    Mixed spaces (DESIGN.md §10): with a `TypeDescriptor`, every ascent
    step interleaves the projected-gradient update on the continuous
    coordinates with `descriptor.project_units` round-and-repair onto the
    int/categorical lattice, so candidates are always feasible.  The
    projection is masked arithmetic on the descriptor arrays — batched
    states may carry a stacked `(S, d)`-leaved descriptor (studies with
    *different* type layouts vmap together), but then `kernel` must itself
    be layout-correct per study (the engine builds per-study closures; a
    shared `(d,)` descriptor works with one shared kernel).
    """
    if state.is_batched:
        n_studies = state.x_buf.shape[0]
        keys = key if key.ndim == 2 else jax.random.split(key, n_studies)
        lo = jnp.asarray(lo)
        hi = jnp.asarray(hi)
        d_ax = 0 if desc is not None and desc.is_batched else None
        return jax.vmap(
            lambda st, k, l, h, dc: optimize_acquisition(
                st, kernel, l, h, k, cfg, top_t,
                implementation=implementation, restart_axis=restart_axis,
                restart_shards=restart_shards, desc=dc,
                log_cost_fn=log_cost_fn, _tune_s=n_studies),
            in_axes=(0, 0,
                     0 if lo.ndim == 2 else None,
                     0 if hi.ndim == 2 else None,
                     d_ax))(state, keys, lo, hi, desc)
    # Loop-invariant hoist: f_best and the active-observation mean are
    # computed once per suggest call and closed over — never re-reduced
    # inside the jitted restart loop (pinned by a trace-count test).
    f_best = _f_best(state)
    ymean = gp_mod._ymean(state)

    fused = _use_fused(cfg, kernel, implementation)
    eval_batch = _make_eval_batch(state, kernel, cfg, implementation, fused,
                                  f_best, ymean, _tune_s, log_cost_fn)
    project = ((lambda u: desc_mod.project_units(u, desc))
               if desc is not None else None)
    return ascend_acquisition(eval_batch, lo, hi, key, cfg, top_t,
                              project=project, restart_axis=restart_axis,
                              restart_shards=restart_shards,
                              dtype=state.x_buf.dtype)


def suggest_q(state: gp_mod.LazyGPState, kernel: KernelFn,
              lo: Array, hi: Array, key: Array, cfg: AcqConfig, q: int,
              *, liar: str = "mean", implementation: str = "auto",
              desc: desc_mod.TypeDescriptor | None = None,
              _tune_s: int = 1
              ) -> tuple[Array, Array, gp_mod.LazyGPState]:
    """Sequential-fantasy q-suggestion (qEI, DESIGN.md §12).

    One `lax.scan` of q steps over a single-study state: each step ascends
    the acquisition against the *current* (fantasized) posterior, then
    appends the chosen point as a fantasy row (`gp.fantasize`: liar
    observation, one bordered `li_buf` row, no refit counters), so step
    i + 1 suggests against a posterior whose variance has collapsed at the
    first i picks.  The whole loop is one jitted program — a q = 32 ask is
    ONE dispatch, not 32 serialized suggest ticks.

    The liar value per step is computed against the current fantasized
    state, so "mean" is the exact kriging-believer recursion and
    "pessimistic" is Snoek et al.'s constant liar.

    Returns `(xs (q, d), vals (q,), fantasized state)` — the caller decides
    whether the fantasized state persists (the serving protocol keeps it
    until the tell-time rollback) or is discarded.
    """
    keys = jax.random.split(key, q)

    def step(st, k):
        x, v = optimize_acquisition(
            st, kernel, lo, hi, k, cfg, 1,
            implementation=implementation, desc=desc, _tune_s=_tune_s)
        st = gp_mod.fantasize(st, kernel, x, liar,
                              implementation=implementation)
        return st, (x[0], v[0])

    st, (xs, vals) = jax.lax.scan(step, state, keys)
    return xs, vals, st
