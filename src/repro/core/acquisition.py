"""Acquisition functions and their optimizers.

Expected Improvement (paper Sec. 3.2.1) plus a jit-able multi-start optimizer
that returns either the single argmax (sequential BO) or the top-t *local
maxima* (paper Sec. 3.4's parallel strategy: "not only use the maximal
expected improvement ... but the t best local maxima").

Local maxima are approximated by multi-start projected gradient ascent from R
random restarts followed by spatial deduplication: ascended points that
converge to the same basin collapse to one representative, and the t best
distinct basins are returned.  This is fixed-shape (R restarts, S ascent
steps) so the whole suggestion step compiles once.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import descriptor as desc_mod
from repro.core import gp as gp_mod
from repro.core.kernels import KernelFn

Array = jax.Array

_SQRT2 = 1.4142135623730951


def _norm_pdf(z: Array) -> Array:
    return jnp.exp(-0.5 * z * z) / jnp.sqrt(2.0 * jnp.pi)


def _norm_cdf(z: Array) -> Array:
    return 0.5 * (1.0 + jax.lax.erf(z / _SQRT2))


def expected_improvement(mean: Array, var: Array, f_best: Array,
                         xi: float = 0.01) -> Array:
    """EI(x) = gamma Phi(Z) + sigma phi(Z)  (paper Eq. 11, maximization form).

    gamma = mu(x) - f_best - xi ; Z = gamma / sigma.  xi trades exploration
    for exploitation.
    """
    sigma = jnp.sqrt(var)
    gamma = mean - f_best - xi
    z = jnp.where(sigma > 0, gamma / jnp.maximum(sigma, 1e-12), 0.0)
    ei = gamma * _norm_cdf(z) + sigma * _norm_pdf(z)
    return jnp.where(sigma > 0, jnp.maximum(ei, 0.0), 0.0)


def upper_confidence_bound(mean: Array, var: Array, f_best: Array,
                           beta: float = 2.0) -> Array:
    del f_best
    return mean + beta * jnp.sqrt(var)


ACQUISITIONS: dict[str, Callable[..., Array]] = {
    "ei": expected_improvement,
    "ucb": upper_confidence_bound,
}


@dataclasses.dataclass(frozen=True)
class AcqConfig:
    name: str = "ei"
    xi: float = 0.01
    restarts: int = 64          # R multi-start seeds
    ascent_steps: int = 25      # S projected-gradient steps per seed
    lr: float = 0.05            # in units of the box width
    dedup_radius: float = 0.08  # basin-merge radius, units of box width


def _acq_value(state: gp_mod.LazyGPState, kernel: KernelFn, x: Array,
               f_best: Array, cfg: AcqConfig,
               implementation: str = "auto") -> Array:
    mean, var = gp_mod.posterior(state, kernel, x[None, :],
                                 implementation=implementation)
    fn = ACQUISITIONS[cfg.name]
    return fn(mean, var, f_best, cfg.xi)[0]


def _f_best(state: gp_mod.LazyGPState) -> Array:
    m = jnp.arange(state.n_max) < state.n
    return jnp.max(jnp.where(m, state.y_buf, -jnp.inf))


def optimize_acquisition(state: gp_mod.LazyGPState, kernel: KernelFn,
                         lo: Array, hi: Array, key: Array,
                         cfg: AcqConfig, top_t: int = 1,
                         *, implementation: str = "auto",
                         restart_axis: str | None = None,
                         restart_shards: int = 1,
                         desc: desc_mod.TypeDescriptor | None = None
                         ) -> tuple[Array, Array]:
    """Return (points (top_t, d), acq values (top_t,)), best first.

    top_t = 1 is standard sequential BO; top_t = t implements the paper's
    parallel suggestion of the t best distinct local maxima.  `implementation`
    selects the linalg substrate for the posterior solves inside the ascent.

    Batched (DESIGN.md §7): a stacked state (leading study axis S) returns
    `((S, top_t, d), (S, top_t))` — one vmapped dispatch suggests for every
    study at once.  `key` may be a single key (split per study) or `(S,)`
    stacked keys; `lo`/`hi` may be shared `(d,)` or per-study `(S, d)`.

    Sharded (DESIGN.md §8): inside a `shard_map` whose mesh carries a
    `restart_axis` of size `restart_shards`, each shard ascends only its
    R/restart_shards slice of the seeds and an `all_gather` reassembles the
    full (R,) candidate set before dedup — every shard then computes the
    identical result (replicated outputs).  Seeds are generated from the
    full `key` on every shard and sliced by `axis_index`, so the sharded
    ascent sees exactly the seeds the unsharded path would.

    Mixed spaces (DESIGN.md §10): with a `TypeDescriptor`, every ascent
    step interleaves the projected-gradient update on the continuous
    coordinates with `descriptor.project_units` round-and-repair onto the
    int/categorical lattice, so candidates are always feasible.  The
    projection is masked arithmetic on the descriptor arrays — batched
    states may carry a stacked `(S, d)`-leaved descriptor (studies with
    *different* type layouts vmap together), but then `kernel` must itself
    be layout-correct per study (the engine builds per-study closures; a
    shared `(d,)` descriptor works with one shared kernel).
    """
    if state.is_batched:
        n_studies = state.x_buf.shape[0]
        keys = key if key.ndim == 2 else jax.random.split(key, n_studies)
        lo = jnp.asarray(lo)
        hi = jnp.asarray(hi)
        d_ax = 0 if desc is not None and desc.is_batched else None
        return jax.vmap(
            lambda st, k, l, h, dc: optimize_acquisition(
                st, kernel, l, h, k, cfg, top_t,
                implementation=implementation, restart_axis=restart_axis,
                restart_shards=restart_shards, desc=dc),
            in_axes=(0, 0,
                     0 if lo.ndim == 2 else None,
                     0 if hi.ndim == 2 else None,
                     d_ax))(state, keys, lo, hi, desc)
    if cfg.restarts % restart_shards:
        raise ValueError(
            f"restart shards ({restart_shards}) must divide "
            f"cfg.restarts ({cfg.restarts})")
    d = state.dim
    f_best = _f_best(state)
    width = hi - lo

    seeds = lo + (hi - lo) * jax.random.uniform(key, (cfg.restarts, d),
                                                dtype=state.x_buf.dtype)

    value = lambda x: _acq_value(state, kernel, x, f_best, cfg, implementation)
    grad = jax.grad(value)
    project = ((lambda u: desc_mod.project_units(u, desc))
               if desc is not None else (lambda u: u))

    def ascend(x):
        # Mixed ascent: gradient step on the continuous coordinates (the
        # kernel's categorical factor carries no gradient), then
        # round-and-repair back onto the int/categorical lattice — every
        # iterate, and the seed itself, is a feasible point.
        def step(_, x):
            g = grad(x)
            gn = jnp.linalg.norm(g)
            g = jnp.where(gn > 0, g / jnp.maximum(gn, 1e-12), 0.0)
            return project(jnp.clip(x + cfg.lr * width * g, lo, hi))
        return jax.lax.fori_loop(0, cfg.ascent_steps, step, project(x))

    if restart_axis is not None and restart_shards > 1:
        # Ascend only this shard's contiguous slice of the seeds, then
        # reassemble: all_gather(tiled) concatenates in axis-index order,
        # restoring the exact unsharded restart order.
        r_local = cfg.restarts // restart_shards
        idx = jax.lax.axis_index(restart_axis)
        local = jax.lax.dynamic_slice_in_dim(seeds, idx * r_local, r_local)
        finals = jax.vmap(ascend)(local)                # (R/shards, d)
        vals = jax.vmap(value)(finals)                  # (R/shards,)
        finals = jax.lax.all_gather(finals, restart_axis, tiled=True)
        vals = jax.lax.all_gather(vals, restart_axis, tiled=True)
    else:
        finals = jax.vmap(ascend)(seeds)                # (R, d)
        vals = jax.vmap(value)(finals)                  # (R,)

    if top_t == 1:
        # Fast path: the greedy dedup below returns the plain argmax when
        # only one suggestion is requested, so skip its R-iteration loop.
        best = jnp.argmax(vals)
        return finals[best][None, :], vals[best][None]

    # Spatial dedup: greedy pick best, suppress all restarts within radius.
    order = jnp.argsort(-vals)
    finals = finals[order]
    vals = vals[order]
    radius = cfg.dedup_radius * jnp.linalg.norm(width)

    def pick(i, carry):
        chosen, chosen_vals, suppressed, count = carry
        is_free = ~suppressed[i] & (count < top_t)
        chosen = jax.lax.cond(
            is_free,
            lambda c: jax.lax.dynamic_update_slice(c, finals[i][None, :],
                                                   (count, 0)),
            lambda c: c, chosen)
        chosen_vals = jax.lax.cond(
            is_free,
            lambda c: jax.lax.dynamic_update_slice(c, vals[i][None], (count,)),
            lambda c: c, chosen_vals)
        dist = jnp.linalg.norm(finals - finals[i], axis=-1)
        suppressed = jnp.where(is_free, suppressed | (dist < radius), suppressed)
        count = count + jnp.where(is_free, 1, 0)
        return chosen, chosen_vals, suppressed, count

    chosen0 = jnp.zeros((top_t, d), finals.dtype)
    vals0 = jnp.full((top_t,), -jnp.inf, vals.dtype)
    suppressed0 = jnp.zeros((cfg.restarts,), bool)
    chosen, chosen_vals, _, count = jax.lax.fori_loop(
        0, cfg.restarts, pick, (chosen0, vals0, suppressed0, 0))

    # If fewer than top_t distinct basins exist, back-fill with jittered
    # copies of the best point so the batch shape stays fixed (re-projected
    # so mixed-space backfills stay on the feasible lattice).
    jitter = 0.01 * width * jax.random.normal(
        jax.random.fold_in(key, 1), (top_t, d), dtype=finals.dtype)
    fallback = jax.vmap(project)(jnp.clip(chosen[0] + jitter, lo, hi))
    filled = jnp.arange(top_t) < count
    chosen = jnp.where(filled[:, None], chosen, fallback)
    chosen_vals = jnp.where(filled, chosen_vals, chosen_vals[0])
    return chosen, chosen_vals
