"""Bayesian optimization driver (paper Alg. 1 + Sec. 3.3/3.4).

Two factorization policies:
  * ``mode="naive"``  — the paper's baseline: every iteration rebuilds K and
    runs a full O(n^3) Cholesky factorization (kernel params refit each step).
  * ``mode="lazy"``   — the paper's contribution: frozen kernel params, O(n^2)
    incremental row appends, optional lag-l full refits.

And two suggestion policies:
  * ``batch_size=1``  — sequential BO (argmax EI).
  * ``batch_size=t``  — parallel BO over the t best EI local maxima
    (paper Sec. 3.4); observations are absorbed as t O(n^2) appends and may
    arrive in any order (async-friendly).

The driver is a Python loop around jitted suggestion/append steps so that the
objective can be an arbitrary black box (e.g. a distributed training run);
per-phase wall times are recorded for the paper's Fig. 1/5 benchmarks.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import acquisition as acq_mod
from repro.core import descriptor as desc_mod
from repro.core import gp as gp_mod

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class BOConfig:
    dim: int
    n_max: int = 1024
    kernel: str = "matern52"
    mode: str = "lazy"            # "lazy" | "naive"
    lag: int = 0                  # lazy mode: full refit every `lag` appends
    inv_refresh: int = 128        # fully-lazy mode (lag=0): rebuild factor +
    # maintained inverse from the Gram every `inv_refresh` appends, under the
    # current params — re-anchors incremental li_buf float32 drift (0 = never;
    # lag > 0 supersedes it, matching StudyEngine; DESIGN.md §4)
    batch_size: int = 1           # t parallel suggestions (paper Sec. 3.4)
    noise2: float = 1e-6
    rho0: float = 0.25            # initial length scale (unit box); paper: 1.0
    implementation: str = "auto"  # linalg substrate (auto|pallas|xla|ref)
    desc: desc_mod.TypeDescriptor | None = None  # mixed-space descriptor
    # (DESIGN.md §10): switches on the mixed kernel + the acquisition's
    # round-and-repair lattice projection; the driver then works on the
    # ENCODED unit cube (pass lo = zeros, hi = ones and decode suggestions
    # with the owning SearchSpace)
    acq: acq_mod.AcqConfig = dataclasses.field(default_factory=acq_mod.AcqConfig)
    seed: int = 0


@dataclasses.dataclass
class BOHistory:
    xs: list = dataclasses.field(default_factory=list)
    ys: list = dataclasses.field(default_factory=list)
    best_y: list = dataclasses.field(default_factory=list)
    gp_seconds: list = dataclasses.field(default_factory=list)   # factor+append
    acq_seconds: list = dataclasses.field(default_factory=list)  # suggestion
    obj_seconds: list = dataclasses.field(default_factory=list)  # evaluations
    clamp_counts: list = dataclasses.field(default_factory=list)  # cumulative
    # d^2 conditioning-floor hits after each round (ill-conditioning telemetry)

    def best(self) -> tuple[np.ndarray, float]:
        i = int(np.argmax(self.ys))
        return np.asarray(self.xs[i]), float(self.ys[i])

    def iterations_to(self, target: float) -> int | None:
        """First iteration whose running best reaches `target` (maximization)."""
        for i, b in enumerate(self.best_y):
            if b >= target:
                return i
        return None


class BayesOpt:
    """Stateful convenience wrapper; all heavy math is jitted & fixed-shape.

    Inputs are normalized to the unit box internally (the paper fixes rho=1,
    which only makes sense on a normalized search space — its HPO domains
    like lr in [1e-4, 1e-1] are unit-scaled); suggestions are denormalized
    before hitting the objective.
    """

    def __init__(self, cfg: BOConfig, lo: Array, hi: Array):
        self.cfg = cfg
        self.lo = jnp.asarray(lo, jnp.float32)
        self.hi = jnp.asarray(hi, jnp.float32)
        self._unit_lo = jnp.zeros_like(self.lo)
        self._unit_hi = jnp.ones_like(self.hi)
        gcfg = gp_mod.GPConfig(n_max=cfg.n_max, dim=cfg.dim, kernel=cfg.kernel,
                               lag=cfg.lag, noise2=cfg.noise2, rho0=cfg.rho0,
                               implementation=cfg.implementation,
                               desc=cfg.desc)
        self.kernel = gcfg.kernel_fn  # mixed closure when desc is discrete
        self.gp_cfg = gcfg
        self._suggest = jax.jit(self._suggest_impl,
                                static_argnames=("top_t",))
        self._append_batch = jax.jit(self._append_batch_impl)
        self._refit = jax.jit(self._refit_impl)
        self._reanchor = jax.jit(self._reanchor_impl)

    def _to_unit(self, x: Array) -> Array:
        return (x - self.lo) / (self.hi - self.lo)

    def _from_unit(self, u: Array) -> Array:
        return self.lo + u * (self.hi - self.lo)

    # -- jitted pieces ------------------------------------------------------
    # `implementation` is a Python constant captured from the config, so each
    # closure compiles once for the selected substrate.
    def _suggest_impl(self, state, key, *, top_t: int):
        return acq_mod.optimize_acquisition(
            state, self.kernel, self._unit_lo, self._unit_hi, key,
            self.cfg.acq, top_t, implementation=self.cfg.implementation,
            desc=self.cfg.desc)

    def _append_batch_impl(self, state, xs, ys):
        return gp_mod.append_batch(state, self.kernel, xs, ys,
                                   implementation=self.cfg.implementation)

    def _refit_impl(self, state):
        params = gp_mod.refit_params(
            state, self.kernel, implementation=self.cfg.implementation)
        return gp_mod.refactor(state, self.kernel, params,
                               implementation=self.cfg.implementation)

    def _reanchor_impl(self, state):
        # Params-preserving refactor: rebuild L and L^{-1} from the Gram.
        return gp_mod.refactor(state, self.kernel,
                               implementation=self.cfg.implementation)

    # -- public API ---------------------------------------------------------
    def init(self, x0: Array, y0: Array) -> gp_mod.LazyGPState:
        """Seed the GP with initial observations (one full factorization —
        the paper's 'first iteration computes a complete decomposition').

        x0 is in *objective* coordinates; stored normalized.
        """
        gp_mod.ensure_capacity(0, self.cfg.n_max, x0.shape[0])
        state = gp_mod.init_state(self.gp_cfg)
        u0 = self._to_unit(jnp.asarray(x0, jnp.float32))
        state = dataclasses.replace(
            state,
            x_buf=state.x_buf.at[: x0.shape[0]].set(u0),
            y_buf=state.y_buf.at[: y0.shape[0]].set(jnp.asarray(y0)),
            n=jnp.asarray(x0.shape[0], jnp.int32),
        )
        return self._refit(state) if self.cfg.mode == "naive" else \
            gp_mod.refactor(state, self.kernel,
                            implementation=self.cfg.implementation)

    def step(self, state: gp_mod.LazyGPState, key: Array,
             objective: Callable[[np.ndarray], np.ndarray],
             history: BOHistory) -> gp_mod.LazyGPState:
        """One BO round: suggest (t points) -> evaluate -> absorb -> lag."""
        # Guard before the (possibly hours-long) objective evaluations: a
        # full round must not be computed only to be discarded on overflow.
        gp_mod.ensure_capacity(int(state.n), self.cfg.n_max,
                               self.cfg.batch_size)
        t0 = time.perf_counter()
        us, _ = self._suggest(state, key, top_t=self.cfg.batch_size)
        us = jax.block_until_ready(us)
        xs = self._from_unit(us)
        t1 = time.perf_counter()

        ys = np.asarray(objective(np.asarray(xs))).reshape(-1)
        t2 = time.perf_counter()

        state = self._append_batch(state, us, jnp.asarray(ys, jnp.float32))
        if self.cfg.mode == "naive":
            state = self._refit(state)
        elif self.cfg.lag > 0:
            # Host-side lag check avoids tracing the refit when not due.
            if int(state.since_refit) >= self.cfg.lag:
                state = self._refit(state)
        elif self.cfg.inv_refresh > 0 and \
                int(state.since_refit) >= self.cfg.inv_refresh:
            # Fully-lazy drift guard: the maintained inverse factor li_buf
            # accumulates bordered-update rounding; re-anchor it from the
            # Gram without touching the kernel params.
            state = self._reanchor(state)
        state = jax.block_until_ready(state)
        t3 = time.perf_counter()

        for x, y in zip(np.asarray(xs), ys):
            history.xs.append(x)
            history.ys.append(float(y))
            history.best_y.append(max(history.ys))
        history.acq_seconds.append(t1 - t0)
        history.obj_seconds.append(t2 - t1)
        history.gp_seconds.append(t3 - t2)
        history.clamp_counts.append(int(state.clamp_count))
        return state

    def run(self, objective: Callable[[np.ndarray], np.ndarray],
            iterations: int, n_seed: int = 1,
            x0: Array | None = None, y0: Array | None = None,
            ) -> tuple[gp_mod.LazyGPState, BOHistory]:
        """Full BO loop (paper Sec. 4 protocol: n_seed random seeds, then
        `iterations` suggestion rounds)."""
        key = jax.random.PRNGKey(self.cfg.seed)
        if x0 is None:
            key, sub = jax.random.split(key)
            x0 = self.lo + (self.hi - self.lo) * jax.random.uniform(
                sub, (n_seed, self.cfg.dim))
            if self.cfg.desc is not None:
                # Mixed spaces: seed on the feasible lattice, like every
                # later suggestion.
                x0 = self._from_unit(desc_mod.project_units(
                    self._to_unit(x0), self.cfg.desc))
            y0 = jnp.asarray(objective(np.asarray(x0)), jnp.float32).reshape(-1)
        state = self.init(x0, y0)

        history = BOHistory()
        for x, y in zip(np.asarray(x0), np.asarray(y0)):
            history.xs.append(x)
            history.ys.append(float(y))
            history.best_y.append(max(history.ys))

        for it in range(iterations):
            key, sub = jax.random.split(key)
            state = self.step(state, sub, objective, history)
        return state, history


def run_bo(objective: Callable[[np.ndarray], np.ndarray], lo, hi,
           iterations: int, *, dim: int, mode: str = "lazy", lag: int = 0,
           batch_size: int = 1, n_seed: int = 1, n_max: int = 1024,
           seed: int = 0, kernel: str = "matern52", rho0: float = 0.25,
           implementation: str = "auto",
           desc: desc_mod.TypeDescriptor | None = None,
           acq: acq_mod.AcqConfig | None = None,
           ) -> tuple[gp_mod.LazyGPState, BOHistory]:
    """One-call functional API (used by examples and benchmarks)."""
    cfg = BOConfig(dim=dim, n_max=n_max, kernel=kernel, mode=mode, lag=lag,
                   batch_size=batch_size, seed=seed, rho0=rho0,
                   implementation=implementation, desc=desc,
                   acq=acq or acq_mod.AcqConfig())
    bo = BayesOpt(cfg, lo, hi)
    return bo.run(objective, iterations, n_seed=n_seed)
