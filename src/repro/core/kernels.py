"""Covariance kernel functions for the lazy Gaussian process.

The paper (Sec. 3.2) uses a Matérn-2.5 kernel with fixed length scale rho=1
between lag events; we implement Matérn-1.5/2.5 and squared-exponential, all
vectorized so a full (n x n) covariance build is a single MXU-friendly
pairwise-distance computation (|x|^2 + |y|^2 - 2 x.y^T).

All kernels take `theta = KernelParams(sigma2, rho, noise2)` so that the lag
policy can refit them as a unit.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

Array = jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class KernelParams:
    """Kernel hyper-parameters (the quantities frozen between lag events)."""

    sigma2: Array | float  # signal variance sigma^2
    rho: Array | float  # length scale
    noise2: Array | float  # observation noise sigma_n^2 (jitter)

    @staticmethod
    def default() -> "KernelParams":
        # Paper fixes rho = 1 (Sec. 3.2); noise2 is the numerical jitter that
        # plays the role of sigma^2 I in K_y = k(x, x) + sigma^2 I.
        return KernelParams(sigma2=1.0, rho=1.0, noise2=1e-6)


def pairwise_sqdist(x: Array, y: Array) -> Array:
    """Squared Euclidean distances between rows of x (n,d) and y (m,d).

    Uses the expansion |x-y|^2 = |x|^2 + |y|^2 - 2 x.y^T so the dominant cost
    is one (n,d)x(d,m) matmul — this is the form the Pallas kernel tiles.
    """
    x = jnp.asarray(x)
    y = jnp.asarray(y)
    xx = jnp.sum(x * x, axis=-1)[:, None]
    yy = jnp.sum(y * y, axis=-1)[None, :]
    cross = x @ y.T
    return jnp.maximum(xx + yy - 2.0 * cross, 0.0)


def matern52(x: Array, y: Array, params: KernelParams) -> Array:
    """Matérn-2.5 kernel matrix (paper Eq. 3, with the exponent sign fixed)."""
    d = jnp.sqrt(pairwise_sqdist(x, y) + 1e-36)
    z = jnp.sqrt(5.0) * d / params.rho
    return params.sigma2 * (1.0 + z + z * z / 3.0) * jnp.exp(-z)


def matern32(x: Array, y: Array, params: KernelParams) -> Array:
    d = jnp.sqrt(pairwise_sqdist(x, y) + 1e-36)
    z = jnp.sqrt(3.0) * d / params.rho
    return params.sigma2 * (1.0 + z) * jnp.exp(-z)


def rbf(x: Array, y: Array, params: KernelParams) -> Array:
    sq = pairwise_sqdist(x, y)
    return params.sigma2 * jnp.exp(-0.5 * sq / (params.rho * params.rho))


KernelFn = Callable[[Array, Array, KernelParams], Array]

# Explicit substrate tag: kernels with a tiled Pallas gram build advertise it
# here, and `repro.kernels.ops.kernel_gram` dispatches on the attribute (a
# name match would silently break for wrapped/renamed kernels).
matern52.pallas_gram = "matern52"

KERNELS: dict[str, KernelFn] = {
    "matern52": matern52,
    "matern32": matern32,
    "rbf": rbf,
}


# --- mixed (continuous x categorical) spaces, DESIGN.md §10 ----------------

def mixed_matern52(x: Array, y: Array, params: KernelParams,
                   cont_mask: Array, cat_mask: Array) -> Array:
    """Mixed-space kernel: Matérn-2.5 over the continuous (float + int)
    coordinates x an exchangeable factor over the one-hot block.

    The categorical factor is `exp(-d²_cat / 2 rho)` — on feasible one-hot
    encodings `d²_cat` is twice the number of differing groups, so this is
    the Hamming-exponential kernel `exp(-h / rho)`; off the lattice it is
    an RBF in the one-hot embedding, PSD everywhere either way, and the
    product with the Matérn term stays PSD.  It carries **no gradient**
    (stop_gradient): the acquisition moves one-hot coordinates by
    round-and-repair projection, never by gradient steps, matching the
    Pallas kernel's continuous-block-only VJP.
    """
    xc, yc = x * cont_mask, y * cont_mask
    d = jnp.sqrt(pairwise_sqdist(xc, yc) + 1e-36)
    z = jnp.sqrt(5.0) * d / params.rho
    sqk = pairwise_sqdist(x * cat_mask, y * cat_mask)
    cat = jax.lax.stop_gradient(jnp.exp(-0.5 * sqk / params.rho))
    return params.sigma2 * (1.0 + z + z * z / 3.0) * jnp.exp(-z) * cat


def make_mixed_kernel(cont_mask: Array, cat_mask: Array) -> KernelFn:
    """Close a `KernelFn` over a space's type masks (from its
    `TypeDescriptor`).  The masks may be concrete `(d,)` arrays or traced
    values (the batched engine builds one closure per study inside its
    vmapped closures); the `pallas_gram = "mixed"` tag routes the gram
    build through the substrate's fused mixed kernel.
    """
    def mixed(x: Array, y: Array, params: KernelParams) -> Array:
        return mixed_matern52(x, y, params, cont_mask, cat_mask)

    mixed.pallas_gram = "mixed"
    mixed.cont_mask = cont_mask
    mixed.cat_mask = cat_mask
    return mixed


def gram(kernel: KernelFn, x: Array, params: KernelParams) -> Array:
    """K_y = k(X, X) + noise2 * I (paper's K + sigma^2 I)."""
    k = kernel(x, x, params)
    return k + params.noise2 * jnp.eye(x.shape[0], dtype=k.dtype)
