"""Padded-state policy layer over the linalg substrate (`repro.kernels.ops`).

This module is the heart of the paper: Alg. 2 (full O(n^3/3) factorization)
vs. Alg. 3 (the O(n^2) rank-one append that reuses the previous factor).

TPU adaptation (DESIGN.md §3): XLA needs static shapes, so the factor lives in
a fixed (n_max, n_max) buffer whose active top-left (n, n) block is the true
factor and whose remainder is the identity.  With identity padding, a padded
triangular solve over the full buffer is *exact* for padded right-hand sides
(rows >= n have zeros left of a unit diagonal), which lets the whole append be
one fixed-shape jitted program — no recompilation as n grows.

All linear algebra dispatches through `repro.kernels.ops` (the Pallas / XLA /
ref substrate); this layer owns only the padded-buffer *policy* — what shape
the state takes, where rows land, how padding is maintained.  The one
exception is `cholesky_naive`, the literal scalar-loop port of the paper's
Alg. 2 kept as a benchmark baseline.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops

Array = jax.Array


# ---------------------------------------------------------------------------
# Naive full factorization (paper Alg. 2) — the baseline we compare against.
# ---------------------------------------------------------------------------

def cholesky_naive(k: Array) -> Array:
    """Row-by-row Cholesky–Banachiewicz factorization, O(n^3/3).

    A literal JAX port of the paper's Alg. 2 (loop-based), used as the
    reference baseline in benchmarks.  The substrate's blocked/XLA
    factorization (`ops.cholesky`) is used everywhere performance matters;
    this exists so the benchmark's "naive" column measures the same algorithm
    the paper measured.
    """
    n = k.shape[0]

    def row_body(i, l):
        def col_body(j, l):
            # l[i, j] = (k[i, j] - sum_{t<j} l[i,t] l[j,t]) / l[j, j]
            t = jnp.arange(n)
            mask = t < j
            s = jnp.sum(jnp.where(mask, l[i] * l[j], 0.0))
            val = (k[i, j] - s) / l[j, j]
            return l.at[i, j].set(jnp.where(j < i, val, l[i, j]))

        l = jax.lax.fori_loop(0, i, col_body, l)
        t = jnp.arange(n)
        mask = t < i
        diag = jnp.sqrt(k[i, i] - jnp.sum(jnp.where(mask, l[i] * l[i], 0.0)))
        return l.at[i, i].set(diag)

    l0 = jnp.zeros_like(k)
    return jax.lax.fori_loop(0, n, row_body, l0)


def cholesky_xla(k: Array, implementation: str = "xla") -> Array:
    """Full factorization through the substrate — the production 'naive' path."""
    return ops.cholesky(k, implementation=implementation)


# ---------------------------------------------------------------------------
# Lazy incremental factorization (paper Alg. 3) on padded buffers.
# ---------------------------------------------------------------------------

def identity_pad_factor(l_active: Array, n_max: int) -> Array:
    """Embed an (n, n) factor into an identity-padded (n_max, n_max) buffer."""
    n = l_active.shape[0]
    buf = jnp.eye(n_max, dtype=l_active.dtype)
    return buf.at[:n, :n].set(l_active)


def padded_trsv(l_buf: Array, b: Array, *, lower: bool = True,
                trans: bool = False, implementation: str = "auto") -> Array:
    """Triangular solve on the identity-padded buffer.

    Exact for right-hand sides that are zero beyond the active block — the
    property the lazy append and the posterior solves rely on.  Dispatches
    through the substrate (`implementation`: auto | pallas | xla | ref).
    """
    assert lower, "the padded GP state stores lower factors only"
    return ops.padded_trsv(l_buf, b, trans=trans,
                           implementation=implementation)


def lazy_append_row(l_buf: Array, p_pad: Array, c: Array, n: Array,
                    *, n_max: int, implementation: str = "auto"
                    ) -> tuple[Array, Array]:
    """Paper Alg. 3 inner step: extend the factor by one row, O(n_max^2).

    Args:
      l_buf: (n_max, n_max) identity-padded factor of K_n + noise I.
      p_pad: (n_max,) new covariance column k(X, x_new), zero beyond n.
      c: scalar k(x_new, x_new) + noise.
      n: current active count (traced int32); the new row is written at index n.

    Returns (new l_buf, d) where d is the new diagonal entry.

    The paper's lemma (Sylvester inertia) guarantees c - q^T q > 0 in exact
    arithmetic for PD K_{n+1}; float32 can undershoot so the clamp floor is
    `ops.CLAMP_EPS` (the GP state machine counts hits, DESIGN.md §6).

    This is the *literal* solve-based Alg. 3 (q = L^{-1} p via triangular
    substitution) kept as the benchmark baseline; the production state
    machine appends through `ops.padded_append_row`/`ops.lazy_append`, which
    compute the same q as a matvec against the maintained inverse factor
    (DESIGN.md §4/§7).
    """
    assert n_max == l_buf.shape[0], (n_max, l_buf.shape)
    q = ops.padded_trsv(l_buf, p_pad, implementation=implementation)
    d = jnp.sqrt(jnp.maximum(c - q @ q, ops.CLAMP_EPS))
    return ops.write_append_row(l_buf, q, d, n), d


def lazy_append_block(l_buf: Array, p_block: Array, c_block: Array,
                      n: Array, *, n_max: int,
                      implementation: str = "auto") -> Array:
    """Absorb t new points (paper Sec. 3.4 parallel case) as t row appends.

    p_block: (t, n_max) covariance columns vs. existing actives (zero-padded
      beyond n, and beyond n+i for the i-th append its cross terms vs. the
      earlier new points are included by construction — callers build
      p_block[i] = k(x_all, x_new_i) padded to n_max with actives = n + i).
    c_block: (t,) self-covariances (+ noise).

    Cost: t * O(n_max^2) — the paper's t O(n^2) batch synchronization.
    """
    t = p_block.shape[0]

    def body(i, carry):
        l_buf, n = carry
        l_buf, _ = lazy_append_row(l_buf, p_block[i], c_block[i], n,
                                   n_max=n_max, implementation=implementation)
        return l_buf, n + 1

    l_buf, _ = jax.lax.fori_loop(0, t, body, (l_buf, n))
    return l_buf


def lazy_full_refactor(k_active_pad: Array, n: Array, *, n_max: int,
                       implementation: str = "auto") -> Array:
    """Lag-event full refactorization on the padded buffer.

    k_active_pad must be the padded Gram matrix with *identity* beyond the
    active block, so the padded factor is the padded-identity factor of the
    active block.  O(n_max^3), routed through the substrate's blocked
    factorization — amortized by the lagging factor l.
    """
    del n, n_max
    return ops.padded_cholesky(k_active_pad, implementation=implementation)


def pad_gram(k_active: Array, n_max: int) -> Array:
    """Embed an (n, n) Gram matrix with identity padding (for refactor).

    The traced-n (fixed-shape) variant lives in the substrate as
    `ops.masked_gram`, which is what `gp.refactor` dispatches through.
    """
    n = k_active.shape[0]
    buf = jnp.eye(n_max, dtype=k_active.dtype)
    return buf.at[:n, :n].set(k_active)
