"""Mixture-of-Experts FFN: top-k token-choice routing, capacity-bounded.

Design (TPU/EP-native, scales to the production mesh):
  * routing + dispatch are *per sequence row* (vmapped over batch), so when
    the batch axis is data-sharded all scatter/gather traffic is local to a
    data shard — the cross-device movement is exactly the expert-parallel
    einsum over the (B, E, C, d) buffer, which GSPMD lowers to the usual
    all-to-all pattern with E on the "model" axis.
  * dispatch uses scatter-by-slot (slot = expert * C + position), NOT the
    GShard (T, E, C) one-hot einsum — the one-hot dispatch tensor is O(T^2)
    at global batch and cannot exist at 1M tokens/step.
  * capacity C = ceil(S * top_k / E * capacity_factor); overflow tokens drop
    to the residual path (Switch-style), counted in the aux metrics.
  * load-balance auxiliary loss (Switch eq. 4) is returned alongside.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.common import init_dense, split_tree

Array = jax.Array


# ---------------------------------------------------------------------------
# Dispatch/combine primitives with dtype-pinned backward passes.
#
# Autodiff of scatter/gather under GSPMD promoted the (B, E*C, d) cotangent
# buffers to f32 and inserted duplicate-index resolution machinery — at
# qwen3 scale that was an 8.6 GB all-reduce per layer (§Perf forensics).
# The custom VJPs below are the exact gradients (slots are unique by
# construction) with cotangents pinned to the activation dtype.
# ---------------------------------------------------------------------------

@jax.custom_vjp
def scatter_rows(buf: Array, idx: Array, rows: Array) -> Array:
    """buf.at[idx].set(rows) with unique in-bounds idx (OOB drops)."""
    return buf.at[idx].set(rows, mode="drop", unique_indices=True)


def _scatter_rows_fwd(buf, idx, rows):
    return scatter_rows(buf, idx, rows), (idx, buf.shape[0])


def _scatter_rows_bwd(res, g):
    idx, n = res
    g_rows = g.at[idx].get(mode="fill", fill_value=0)
    # slots written by rows contribute nothing to dbuf
    dbuf = g.at[idx].set(jnp.zeros_like(g_rows), mode="drop",
                         unique_indices=True)
    return dbuf, None, g_rows.astype(g.dtype)


scatter_rows.defvjp(_scatter_rows_fwd, _scatter_rows_bwd)


@jax.custom_vjp
def gather_rows(flat: Array, idx: Array) -> Array:
    """flat[idx] with OOB indices returning zeros."""
    return flat.at[idx].get(mode="fill", fill_value=0)


def _gather_rows_fwd(flat, idx):
    return gather_rows(flat, idx), (idx, flat.shape[0])


def _gather_rows_bwd(res, g):
    idx, n = res
    dflat = jnp.zeros((n,) + g.shape[1:], g.dtype)
    # combine gathers each slot at most top_k times with distinct tokens;
    # scatter-add resolves the (rare) duplicate slot reads exactly.
    dflat = dflat.at[idx].add(g, mode="drop")
    return dflat, None


gather_rows.defvjp(_gather_rows_fwd, _gather_rows_bwd)


def init_moe_params(key, d_model: int, d_ff: int, num_experts: int, dtype,
                    num_experts_padded: int | None = None):
    """Router covers `num_experts`; weight tables may be padded to
    `num_experts_padded` (zero-init dummy rows that never receive tokens)
    so the expert dim divides the model mesh axis."""
    e_pad = num_experts_padded or num_experts
    ks = jax.random.split(key, 4)
    tree = {
        "router": init_dense(ks[0], (d_model, num_experts),
                             ("embed", "expert"), dtype),
        "wi": init_dense(ks[1], (e_pad, d_model, d_ff),
                         ("expert", "embed", "mlp"), dtype),
        "wg": init_dense(ks[2], (e_pad, d_model, d_ff),
                         ("expert", "embed", "mlp"), dtype),
        "wo": init_dense(ks[3], (e_pad, d_ff, d_model),
                         ("expert", "mlp", "embed"), dtype),
    }
    return split_tree(tree)


def _capacity(seq: int, top_k: int, num_experts: int, cf: float) -> int:
    c = max(1, -(-seq * top_k * cf // num_experts).__int__())
    # lane-align when large enough to matter
    return min(seq, ((c + 7) // 8) * 8) if c > 8 else c


def _positions_cumsum(expert_idx: Array, e: int) -> Array:
    """Position of each (token, choice) within its expert, via the GShard
    one-hot cumsum.  O(S*k*E) HBM traffic — kept as the ablation baseline."""
    onehot = jax.nn.one_hot(expert_idx.reshape(-1), e, dtype=jnp.int32)
    pos = (jnp.cumsum(onehot, axis=0) - onehot)                # (S*k, E)
    return jnp.take_along_axis(pos, expert_idx.reshape(-1, 1), axis=1)[:, 0]


def _positions_sort(expert_idx: Array, e: int) -> Array:
    """Same positions via stable argsort ranking: O(S*k log) compare traffic
    instead of O(S*k*E) one-hot cumsum (hillclimb M2: at qwen3 scale the
    cumsum alone moves 134 MB/layer/pass).

    rank-within-expert = sorted position - start offset of the expert.
    """
    flat = expert_idx.reshape(-1)                              # (S*k,)
    n = flat.shape[0]
    order = jnp.argsort(flat, stable=True)                     # (S*k,)
    counts = jnp.bincount(flat, length=e)
    starts = jnp.cumsum(counts) - counts                       # (E,)
    ranks_sorted = jnp.arange(n) - starts[flat[order]]
    pos = jnp.zeros((n,), jnp.int32).at[order].set(
        ranks_sorted.astype(jnp.int32))
    return pos


def _route_row(x_row: Array, router: Array, top_k: int, capacity: int,
               dispatch: str = "sort"):
    """Per-row routing: returns (slots (S,k), gates (S,k), aux stats)."""
    s, d = x_row.shape
    e = router.shape[1]
    # Router matmul in the activation dtype (its dx cotangent is (S, d)-
    # sized; doing this matmul in f32 promoted that whole buffer to f32 —
    # §Perf T1), softmax in f32 for routing stability.
    logits = (x_row @ router.astype(x_row.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                    # (S, E)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)        # (S, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    pos_fn = _positions_sort if dispatch == "sort" else _positions_cumsum
    pos = pos_fn(expert_idx, e).reshape(s, top_k)
    keep = pos < capacity
    slots = jnp.where(keep, expert_idx * capacity + pos, e * capacity)

    density = jax.nn.one_hot(expert_idx[:, 0], e, dtype=jnp.float32).mean(0)
    aux = e * jnp.sum(density * probs.mean(0))
    dropped = 1.0 - keep.mean()
    return slots, gate_vals.astype(x_row.dtype), aux, dropped


def _constrain(x, axes):
    from repro.launch.sharding import constrain
    return constrain(x, axes)


def _shard_ctx():
    from repro.launch.sharding import _CTX
    return _CTX.get()


# ---------------------------------------------------------------------------
# Expert-parallel dispatch via shard_map (§Perf M6).
#
# GSPMD cannot shard a flat (E*C, d) dispatch buffer that a scatter writes
# and a gather reads with arbitrary slots: it replicates it and pays an
# (E*C, d)-sized all-reduce/all-gather per layer per pass (forensics in
# EXPERIMENTS.md).  The shard_map formulation makes the data flow explicit:
#
#   * routing runs replicated on every model shard (identical, cheap),
#   * each shard scatters only the tokens routed to its OWN E/n experts
#     (out-of-range slots drop) — zero dispatch collectives,
#   * expert GEMMs are local (FSDP all-gather of the weight shard inside),
#   * combine gathers from the local buffer (non-local slots read 0) and
#     psums the (S, d) partial outputs — the only per-layer collective.
#
# Used when the expert count divides the model axis; otherwise the GSPMD
# path above (capacity-sharded) remains.
# ---------------------------------------------------------------------------

def _moe_shard_map(params, x: Array, *, top_k: int, capacity: int,
                   dispatch: str, ctx) -> tuple[Array, Array]:
    from jax.sharding import PartitionSpec as P

    mesh = ctx.mesh
    rules = ctx.rules
    b, s, d = x.shape
    e = params["router"].shape[1]
    e_pad = params["wi"].shape[0]    # padded tables; routing stays over e
    n_model = mesh.shape["model"]
    e_local = e_pad // n_model
    batch_axes = rules.get("batch")
    embed_axes = rules.get("embed")          # FSDP axes of the weights

    def fsdp_gather(w, axis):
        if embed_axes is None:
            return w
        names = embed_axes if isinstance(embed_axes, tuple) else (embed_axes,)
        for name in names:
            w = jax.lax.all_gather(w, name, axis=axis, tiled=True)
        return w

    wspec_e = P("model", embed_axes, None)   # (E, d, f) expert weights
    wspec_o = P("model", None, embed_axes)   # (E, f, d)
    rspec = P(embed_axes, None)              # router (d, E)
    xspec = P(batch_axes, None, None)

    def shard_fn(x_blk, router, wi, wg, wo):
        # x_blk: (B_loc, S, d) replicated over model; w*: local expert shard
        router = fsdp_gather(router, 0)
        wi = fsdp_gather(wi, 1)
        wg = fsdp_gather(wg, 1)
        wo_f = fsdp_gather(wo, 2)
        shard = jax.lax.axis_index("model")
        offset = shard * e_local * capacity

        def one_row(x_row):
            slots, gates, aux, dropped = _route_row(
                x_row, router, top_k, capacity, dispatch)
            # Slots owned by other shards map to a positive OOB sentinel
            # (negative indices would WRAP in jax indexing, not drop).
            span = e_local * capacity
            local = jnp.where((slots >= offset) & (slots < offset + span),
                              slots - offset, span)
            buf = jnp.zeros((span, d), x_row.dtype)
            for j in range(top_k):
                buf = scatter_rows(buf, local[:, j], x_row)
            return buf.reshape(e_local, capacity, d), local, gates, aux

        buf, local, gates, aux = jax.vmap(one_row)(x_blk)
        hidden = jnp.einsum("becd,edf->becf", buf, wi)
        gate_h = jnp.einsum("becd,edf->becf", buf, wg)
        hidden = jax.nn.silu(gate_h) * hidden
        expert_out = jnp.einsum("becf,efd->becd", hidden, wo_f)

        def combine_row(buf_out, local_row, gates_row):
            flat = buf_out.reshape(e_local * capacity, d)
            picked = gather_rows(flat, local_row.reshape(-1))
            picked = picked.reshape(s, top_k, d)
            return (picked * gates_row[..., None]).sum(1)

        partial = jax.vmap(combine_row)(expert_out, local, gates)
        out = jax.lax.psum(partial, "model")
        return out, aux.mean().reshape(1, 1)

    sm = jax.shard_map(
        shard_fn, mesh=mesh,
        in_specs=(xspec, rspec, wspec_e, wspec_e, wspec_o),
        out_specs=(xspec, P(batch_axes, "model")),
        check_vma=False)
    out, aux = sm(x, params["router"], params["wi"], params["wg"],
                  params["wo"])
    return out.astype(x.dtype), aux.mean().astype(jnp.float32)


def moe_ffn(params, x: Array, *, top_k: int, capacity_factor: float,
            dispatch: str = "sort") -> tuple[Array, Array]:
    """x: (B, S, D) -> (out (B, S, D), aux load-balance loss ())."""
    b, s, d = x.shape
    e = params["router"].shape[1]
    e_pad = params["wi"].shape[0]
    capacity = _capacity(s, top_k, e, capacity_factor)

    ctx = _shard_ctx()
    if (ctx is not None and "model" in ctx.mesh.axis_names
            and e_pad % ctx.mesh.shape["model"] == 0
            and ctx.mesh.shape["model"] > 1):
        return _moe_shard_map(params, x, top_k=top_k, capacity=capacity,
                              dispatch=dispatch, ctx=ctx)

    # GSPMD fallback (single device, or expert count not divisible by the
    # model axis — granite-moe's 40e: the capacity dim carries the sharding).
    # Under sequence-parallel rules the incoming x is seq-sharded; scattering
    # seq-sharded updates into the dispatch buffer makes GSPMD all-reduce the
    # whole (E*C, d) buffer per layer (§Perf M5 forensics: 8.6 GB/layer at
    # qwen3 scale).  Gather the sequence FIRST — an (S, d) all-gather is
    # ~8x smaller — then dispatch locally.
    x = _constrain(x, ("batch", None, None))

    def dispatch_row(x_row):
        slots, gates, aux, dropped = _route_row(
            x_row, params["router"], top_k, capacity, dispatch)
        buf = jnp.zeros((e * capacity + 1, d), x_row.dtype)
        # Each kept (token, choice) owns a unique slot; k scatter-sets avoid
        # materializing the (S*k, d) repeat (hillclimb M3).  scatter_rows
        # pins the backward to the activation dtype and skips duplicate-
        # index resolution (hillclimb M4).
        for j in range(top_k):
            buf = scatter_rows(buf, slots[:, j], x_row)
        return buf[:-1].reshape(e, capacity, d), slots, gates, aux, dropped

    buf, slots, gates, aux, dropped = jax.vmap(dispatch_row)(x)
    # Expert GEMMs over the (B, E, C, d) buffer: B data-sharded, E
    # model-sharded -> local compute after GSPMD's all-to-all.  For archs
    # whose expert count doesn't divide the model axis (granite-moe: 40e on
    # a 16-way axis) the "capacity" logical axis carries the sharding
    # instead (see launch/sharding.ARCH_OVERRIDES) — without it the whole
    # (B, E, C, d) buffer replicates per device (measured 167 GB/device).
    buf = _constrain(buf, ("batch", "expert", "capacity", None))
    hidden = jnp.einsum("becd,edf->becf", buf, params["wi"][:e])
    gate_h = jnp.einsum("becd,edf->becf", buf, params["wg"][:e])
    hidden = jax.nn.silu(gate_h) * hidden
    hidden = _constrain(hidden, ("batch", "expert", "capacity", "mlp"))
    expert_out = jnp.einsum("becf,efd->becd", hidden, params["wo"][:e])
    expert_out = _constrain(expert_out, ("batch", "expert", "capacity", None))

    def combine_row(buf_out, slots_row, gates_row):
        flat = buf_out.reshape(e * capacity, d)
        flat = jnp.concatenate([flat, jnp.zeros((1, d), flat.dtype)], 0)
        picked = gather_rows(flat, slots_row.reshape(-1))
        picked = picked.reshape(s, top_k, d)
        return (picked * gates_row[..., None]).sum(1)

    out = jax.vmap(combine_row)(expert_out, slots, gates)
    aux_loss = aux.mean() + 0.0 * dropped.mean()
    return out.astype(x.dtype), aux_loss.astype(jnp.float32)
