"""Mamba2 (SSD) blocks: chunked training scan + O(1)-state decode.

Implements the state-space-duality algorithm of Mamba-2 [arXiv:2405.21060]:
within a chunk the recurrence is computed in quadratic "attention-like" form
(MXU-friendly); across chunks a (heads, head_dim, state) carry propagates via
`lax.scan`.  The decode path is the literal per-token recurrence, giving the
sub-quadratic serving path the assignment requires for `long_500k`.

A naive per-token recurrent reference (`ssd_recurrent_ref`) backs the
equivalence tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import init_dense, split_tree

Array = jax.Array


def init_mamba_params(key, d_model: int, *, expand: int, state: int,
                      head_dim: int, groups: int, dtype, conv_width: int = 4):
    din = expand * d_model
    nheads = din // head_dim
    proj_out = 2 * din + 2 * groups * state + nheads
    conv_dim = din + 2 * groups * state
    ks = jax.random.split(key, 5)
    tree = {
        "in_proj": init_dense(ks[0], (d_model, proj_out), ("embed", "mlp"),
                              dtype),
        "conv_w": init_dense(ks[1], (conv_width, conv_dim), ("layers_none", "mlp"),
                             dtype, scale=0.5),
        "conv_b": (jnp.zeros((conv_dim,), dtype), ("mlp",)),
        "a_log": (jnp.log(jnp.linspace(1.0, 16.0, nheads)).astype(dtype),
                  ("heads",)),
        "dt_bias": (jnp.zeros((nheads,), dtype), ("heads",)),
        "d_skip": (jnp.ones((nheads,), dtype), ("heads",)),
        "norm_scale": (jnp.ones((din,), dtype), ("mlp",)),
        "out_proj": init_dense(ks[4], (din, d_model), ("mlp", "embed"), dtype),
    }
    return split_tree(tree)


def _causal_conv(x: Array, w: Array, b: Array) -> Array:
    """Depthwise causal conv1d.  x: (B, L, C); w: (W, C)."""
    width = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(width):
        out = out + xp[:, i:i + x.shape[1], :] * w[i]
    return out + b


def _segsum(a: Array) -> Array:
    """Lower-triangular pairwise decay exponents: out[t, s] = sum_{s<u<=t} a[u].

    a: (..., Q).  Returns (..., Q, Q) with -inf above the diagonal.
    """
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]          # sum_(s, t]
    mask = jnp.tril(jnp.ones((q, q), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x: Array, dt: Array, a: Array, b_mat: Array, c_mat: Array,
                *, chunk: int, h0: Array | None = None,
                return_final_state: bool = False):
    """SSD scan.  x: (B, L, H, P); dt: (B, L, H); a: (H,) (negative);
    b_mat/c_mat: (B, L, G, N) with H % G == 0.

    Returns y (B, L, H, P) [and final state (B, H, P, N)].
    """
    bsz, l, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    rep = h // g
    chunk = min(chunk, l)
    l_orig = l
    if l % chunk:
        # Zero-pad to a chunk multiple: dt=0 => decay 1 and zero input, so
        # padded steps are exact no-ops for both outputs and the final state.
        pad = chunk - l % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        l = l + pad
    nc = l // chunk

    # Broadcast groups to heads.
    bh = jnp.repeat(b_mat, rep, axis=2)                 # (B, L, H, N)
    ch = jnp.repeat(c_mat, rep, axis=2)

    xc = x.reshape(bsz, nc, chunk, h, p)
    dtc = dt.reshape(bsz, nc, chunk, h)
    bc = bh.reshape(bsz, nc, chunk, h, n)
    cc = ch.reshape(bsz, nc, chunk, h, n)
    ac = (dtc * a[None, None, None, :]).astype(jnp.float32)  # (B, nc, Q, H)

    acs = jnp.cumsum(ac, axis=2)                        # inclusive cumsum
    seg = _segsum(ac.transpose(0, 1, 3, 2))             # (B, nc, H, Q, Q)
    decay_mat = jnp.exp(seg)

    # Intra-chunk (quadratic) term.
    scores = jnp.einsum("bzqhn,bzshn->bzhqs", cc, bc,
                        preferred_element_type=jnp.float32)
    scores = scores * decay_mat * dtc.transpose(0, 1, 3, 2)[:, :, :, None, :]
    y_intra = jnp.einsum("bzhqs,bzshp->bzqhp", scores.astype(x.dtype), xc)

    # Per-chunk final state contribution: sum_s exp(acs[Q-1]-acs[s]) dt_s B_s x_s.
    decay_to_end = jnp.exp(acs[:, :, -1:, :] - acs)     # (B, nc, Q, H)
    dtb = (dtc * decay_to_end).astype(x.dtype)
    chunk_states = jnp.einsum("bzshn,bzshp,bzsh->bzhpn", bc, xc, dtb)
    chunk_decay = jnp.exp(acs[:, :, -1, :])             # (B, nc, H)

    # Inter-chunk recurrence.
    if h0 is None:
        h0 = jnp.zeros((bsz, h, p, n), jnp.float32)

    def scan_fn(hprev, inp):
        cstate, cdecay = inp                            # (B,H,P,N), (B,H)
        hnew = hprev * cdecay[..., None, None] + cstate.astype(jnp.float32)
        return hnew, hprev

    (h_final, h_prevs) = jax.lax.scan(
        scan_fn, h0.astype(jnp.float32),
        (chunk_states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)          # (B, nc, H, P, N)

    # Inter-chunk output: C_t . h_prev, decayed from chunk start to t.
    decay_from_start = jnp.exp(acs)                     # (B, nc, Q, H)
    y_inter = jnp.einsum("bzqhn,bzhpn->bzqhp", cc,
                         h_prevs.astype(cc.dtype))
    y_inter = y_inter * decay_from_start[..., None].astype(x.dtype)

    y = (y_intra + y_inter).reshape(bsz, l, h, p)[:, :l_orig]
    if return_final_state:
        return y, h_final
    return y


def ssd_recurrent_ref(x, dt, a, b_mat, c_mat, h0=None):
    """Naive per-token recurrence (oracle for tests)."""
    bsz, l, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    rep = h // g
    bh = jnp.repeat(b_mat, rep, axis=2)
    ch = jnp.repeat(c_mat, rep, axis=2)
    if h0 is None:
        h0 = jnp.zeros((bsz, h, p, n), jnp.float32)

    def step(hprev, inp):
        xt, dtt, bt, ct = inp                 # (B,H,P), (B,H), (B,H,N) x2
        decay = jnp.exp(dtt * a[None, :])     # (B,H)
        hnew = (hprev * decay[..., None, None]
                + (dtt[..., None, None] * xt[..., None] * bt[:, :, None, :]))
        y = jnp.einsum("bhn,bhpn->bhp", ct, hnew)
        return hnew, y

    inputs = (x.transpose(1, 0, 2, 3), dt.transpose(1, 0, 2),
              bh.transpose(1, 0, 2, 3), ch.transpose(1, 0, 2, 3))
    h_final, ys = jax.lax.scan(step, h0.astype(jnp.float32), inputs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), h_final


# ---------------------------------------------------------------------------
# Block-level forward (train / prefill) and decode step
# ---------------------------------------------------------------------------

def _split_proj(proj, din, groups, state, nheads):
    z, xin, b, c, dt = jnp.split(
        proj, [din, 2 * din, 2 * din + groups * state,
               2 * din + 2 * groups * state], axis=-1)
    return z, xin, b, c, dt


def mamba_block(params, x: Array, cfg, *, return_state: bool = False):
    """Full-sequence Mamba2 mixer.  x: (B, L, D) -> (B, L, D).

    With return_state=True also returns the decode state pytree (conv tail +
    final SSD carry), so prefill gets serving state for free.
    """
    from repro.models.common import rms_norm  # local import to avoid cycle
    bsz, l, d = x.shape
    din = cfg.ssm_expand * d
    nheads = din // cfg.ssm_head_dim
    g, n = cfg.ssm_groups, cfg.ssm_state

    proj = x @ params["in_proj"]
    z, xin, b, c, dt_raw = _split_proj(proj, din, g, n, nheads)
    conv_in = jnp.concatenate([xin, b, c], axis=-1)
    conv_out = jax.nn.silu(_causal_conv(conv_in, params["conv_w"],
                                        params["conv_b"]))
    xin, b, c = jnp.split(conv_out, [din, din + g * n], axis=-1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    xh = xin.reshape(bsz, l, nheads, cfg.ssm_head_dim)
    bm = b.reshape(bsz, l, g, n)
    cm = c.reshape(bsz, l, g, n)
    y, h_final = ssd_chunked(xh, dt, a, bm, cm, chunk=cfg.ssm_chunk,
                             return_final_state=True)
    y = y + xh * params["d_skip"][None, None, :, None]
    y = y.reshape(bsz, l, din)
    y = rms_norm(y * jax.nn.silu(z), params["norm_scale"], cfg.norm_eps)
    out = y @ params["out_proj"]
    if return_state:
        width = params["conv_w"].shape[0]
        state = {"conv": conv_in[:, l - (width - 1):, :], "ssm": h_final}
        return out, state
    return out


def mamba_init_state(params, batch: int, cfg, d_model: int, dtype):
    din = cfg.ssm_expand * d_model
    nheads = din // cfg.ssm_head_dim
    conv_dim = din + 2 * cfg.ssm_groups * cfg.ssm_state
    width = params["conv_w"].shape[0]
    return {
        "conv": jnp.zeros((batch, width - 1, conv_dim), dtype),
        "ssm": jnp.zeros((batch, nheads, cfg.ssm_head_dim, cfg.ssm_state),
                         jnp.float32),
    }


def mamba_decode_step(params, x: Array, state: dict, cfg):
    """One-token recurrence.  x: (B, 1, D) -> (y (B, 1, D), new state)."""
    from repro.models.common import rms_norm
    bsz, _, d = x.shape
    din = cfg.ssm_expand * d
    nheads = din // cfg.ssm_head_dim
    g, n = cfg.ssm_groups, cfg.ssm_state

    proj = x[:, 0] @ params["in_proj"]
    z, xin, b, c, dt_raw = _split_proj(proj, din, g, n, nheads)
    conv_in = jnp.concatenate([xin, b, c], axis=-1)     # (B, conv_dim)
    hist = jnp.concatenate([state["conv"], conv_in[:, None, :]], axis=1)
    w = params["conv_w"]
    conv_out = jax.nn.silu(
        jnp.einsum("bwc,wc->bc", hist, w) + params["conv_b"])
    xin, b, c = jnp.split(conv_out, [din, din + g * n], axis=-1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))  # (B, H)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    xh = xin.reshape(bsz, nheads, cfg.ssm_head_dim)
    bm = jnp.repeat(b.reshape(bsz, g, n), nheads // g, axis=1)
    cm = jnp.repeat(c.reshape(bsz, g, n), nheads // g, axis=1)

    decay = jnp.exp(dt * a[None, :])                    # (B, H)
    h = (state["ssm"] * decay[..., None, None]
         + dt[..., None, None] * xh[..., None] * bm[:, :, None, :])
    y = jnp.einsum("bhn,bhpn->bhp", cm, h).astype(x.dtype)
    y = y + xh * params["d_skip"][None, :, None]
    y = y.reshape(bsz, din)
    y = rms_norm(y * jax.nn.silu(z), params["norm_scale"], cfg.norm_eps)
    out = (y @ params["out_proj"])[:, None, :]
    return out, {"conv": hist[:, 1:], "ssm": h}
