"""Unified model configuration covering all assigned architecture families.

One dataclass describes dense GQA, MLA, sliding-window, MoE, Mamba2-hybrid,
mLSTM, encoder-only and early-fusion-VLM stacks; per-arch files in
`repro/configs/` instantiate it with the exact published numbers.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

import jax.numpy as jnp

Family = Literal["dense", "moe", "hybrid", "vlm", "audio", "ssm"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // num_heads

    # --- attention flavour ---------------------------------------------
    attention: str = "gqa"            # "gqa" | "mla" | "none"
    causal: bool = True               # False -> bidirectional encoder
    is_encoder: bool = False          # encoder-only (no decode path)
    sliding_window: int = 0           # 0 -> full attention
    global_every: int = 0             # >0: every k-th layer is global (gemma3)
    qk_norm: bool = False             # chameleon-style qk RMSNorm
    rope: bool = True
    rope_theta: float = 10_000.0

    # --- MLA (minicpm3 / deepseek-style latent attention) ---------------
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # --- MoE -------------------------------------------------------------
    num_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    moe_dispatch: str = "sort"        # "sort" (argsort ranks) | "cumsum"
    expert_pad_to: int = 16           # pad expert WEIGHT tables to a multiple
    # (routing stays over num_experts; dummy experts never receive tokens —
    # lets a 40-expert arch use the shard_map EP path on a 16-way axis)

    # --- SSM / recurrent blocks ------------------------------------------
    # block_pattern: per-layer block kind; "attn", "mamba", "mlstm", or a
    # pattern like "mamba*5+shared_attn" handled by the per-arch stacks.
    block_pattern: str = "attn"
    ssm_state: int = 0                # Mamba2 N
    ssm_heads: int = 0                # Mamba2 H (0 -> d_model*expand/headdim)
    ssm_head_dim: int = 64            # Mamba2 P
    ssm_expand: int = 2
    ssm_groups: int = 1               # B/C groups (G)
    ssm_chunk: int = 128              # SSD chunk length
    shared_attn_every: int = 0        # zamba2: shared attn block period
    mlstm_heads: int = 0              # xLSTM heads (conv/backbone width)
    mlstm_pf: float = 2.0             # mLSTM up-projection factor

    # --- stub frontends ----------------------------------------------------
    # "none": token ids.  "frames": precomputed frame embeddings (audio).
    # VLM early fusion shares the token vocabulary ("none").
    frontend: str = "none"

    # --- numerics ----------------------------------------------------------
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"           # activation dtype
    param_dtype: str = "float32"      # master parameter dtype
    tie_embeddings: bool = False
    remat: bool = True                # activation checkpoint each block
    remat_policy: str = "nothing"     # "nothing" | "dots" (save matmul outs)
    unroll_layers: bool = False       # python-loop the stack instead of scan
    # (scan = O(1) compile time, the production default; unroll = exact
    # per-layer HLO cost_analysis, used by the dry-run since XLA's
    # HloCostAnalysis does not multiply while-loop bodies by trip count)
    vocab_round: int = 256            # pad vocab to a multiple (TP-friendly)

    # ------------------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def vocab_padded(self) -> int:
        """Embedding/LM-head table rows: vocab rounded up so the vocab dim
        TP-shards evenly (padded logits are masked out of the loss)."""
        r = self.vocab_round
        return ((self.vocab_size + r - 1) // r) * r

    @property
    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def parameter_dtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def num_experts_padded(self) -> int:
        r = max(self.expert_pad_to, 1)
        return ((self.num_experts + r - 1) // r) * r

    @property
    def supports_decode(self) -> bool:
        return not self.is_encoder

    @property
    def supports_long_context(self) -> bool:
        """True if the arch has a sub-quadratic serving path (assignment:
        long_500k only runs for SSM / hybrid / windowed-attention archs)."""
        if self.block_pattern in ("mamba", "mlstm"):
            return True
        if self.shared_attn_every > 0:     # hybrid: SSM backbone
            return True
        return self.sliding_window > 0      # windowed attention

    def n_params(self) -> int:
        """Approximate parameter count (embedding + blocks + head)."""
        d, h = self.d_model, self.head_dim_
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.block_pattern in ("attn",):
            if self.attention == "mla":
                qdim = self.num_heads * (self.qk_nope_dim + self.qk_rope_dim)
                per_layer += d * self.q_lora_rank + self.q_lora_rank * qdim
                per_layer += d * (self.kv_lora_rank + self.qk_rope_dim)
                per_layer += self.kv_lora_rank * self.num_heads * (
                    self.qk_nope_dim + self.v_head_dim)
                per_layer += self.num_heads * self.v_head_dim * d
            else:
                per_layer += d * self.num_heads * h
                per_layer += 2 * d * self.num_kv_heads * h
                per_layer += self.num_heads * h * d
            if self.is_moe:
                per_layer += d * self.num_experts  # router
                per_layer += self.num_experts * 3 * d * self.d_ff
            else:
                per_layer += 3 * d * self.d_ff
        elif self.block_pattern == "mamba":
            din = self.ssm_expand * d
            nheads = self.ssm_heads or din // self.ssm_head_dim
            conv_dim = din + 2 * self.ssm_groups * self.ssm_state
            per_layer += d * (2 * din + 2 * self.ssm_groups * self.ssm_state
                              + nheads)
            per_layer += 4 * conv_dim
            per_layer += din * d
        elif self.block_pattern == "mlstm":
            dv = int(self.mlstm_pf * d)
            per_layer += d * 2 * dv          # up projections
            per_layer += dv * (2 * dv // 2)  # q,k (half width) ~
            per_layer += dv * dv             # v
            per_layer += 3 * dv              # gates (approx)
            per_layer += dv * d              # down
        total = emb + self.num_layers * per_layer
        if self.shared_attn_every > 0:
            # one shared attention block (+ its mlp) reused across the stack
            total += (d * self.num_heads * h * 2
                      + 2 * d * self.num_kv_heads * h + 3 * d * self.d_ff)
        return int(total)

    def n_active_params(self) -> int:
        """Active parameters per token (MoE: only top_k experts count)."""
        if not self.is_moe:
            return self.n_params()
        d = self.d_model
        dense_expert = 3 * d * self.d_ff
        inactive = (self.num_experts - self.top_k) * dense_expert
        return int(self.n_params() - self.num_layers * inactive)
