"""Model zoo: unified stack covering all assigned architectures."""
from repro.models.config import ModelConfig
from repro.models.model import (decode_step, forward, init_cache, init_params,
                                lm_loss, logits_from_hidden, prefill)

__all__ = ["ModelConfig", "decode_step", "forward", "init_cache",
           "init_params", "lm_loss", "logits_from_hidden", "prefill"]
