"""Model assembly: blocks, scan-over-layers stacks, LM head, serve paths.

One generic decoder/encoder stack covers all 10 assigned architectures:

  * block kinds: "attn" (GQA or MLA, optional qk-norm / sliding window /
    bidirectional), "mamba" (Mamba2/SSD), "mlstm" (xLSTM).
  * layers are stacked along a leading axis and driven by `jax.lax.scan`
    (O(1) compile time in depth — essential for 62-layer dry-runs on a
    512-device mesh).  Per-layer heterogeneity (gemma3's 5:1 local:global
    pattern, zamba2's every-6th shared attention) rides along as scanned
    flag arrays + `lax.cond`, keeping the stack homogeneous.
  * zamba2's shared attention block has ONE param set applied at several
    depths (weight sharing) with its own KV-cache slot per application.

Activation-sharding hints are emitted through `repro.launch.sharding.constrain`
(logical axes), a no-op outside a mesh context.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models.common import (apply_rope, cast_tree, init_dense, init_embed,
                                 init_scale, rms_norm, sinusoidal_positions,
                                 split_tree, stack_layer_params, stacked_specs)
from repro.models.config import ModelConfig

Array = jax.Array


def constrain(x: Array, axes: tuple) -> Array:
    from repro.launch.sharding import constrain as _c
    return _c(x, axes)


# ---------------------------------------------------------------------------
# Parameter initialization
# ---------------------------------------------------------------------------

def _init_attn_params(key, cfg: ModelConfig):
    d, h, kv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
    ks = jax.random.split(key, 8)
    dt = cfg.parameter_dtype
    if cfg.attention == "mla":
        qdim = cfg.qk_nope_dim + cfg.qk_rope_dim
        tree = {
            "wdq": init_dense(ks[0], (d, cfg.q_lora_rank), ("embed", "mlp"), dt),
            "q_norm": init_scale(cfg.q_lora_rank, dt),
            "wuq": init_dense(ks[1], (cfg.q_lora_rank, h * qdim),
                              ("mlp", "heads"), dt),
            "wdkv": init_dense(ks[2], (d, cfg.kv_lora_rank + cfg.qk_rope_dim),
                               ("embed", "mlp"), dt),
            "kv_norm": init_scale(cfg.kv_lora_rank, dt),
            "wuk": init_dense(ks[3], (cfg.kv_lora_rank, h * cfg.qk_nope_dim),
                              ("mlp", "heads"), dt),
            "wuv": init_dense(ks[4], (cfg.kv_lora_rank, h * cfg.v_head_dim),
                              ("mlp", "heads"), dt),
            "wo": init_dense(ks[5], (h * cfg.v_head_dim, d),
                             ("heads", "embed"), dt),
        }
    else:
        tree = {
            "wq": init_dense(ks[0], (d, h * dh), ("embed", "heads"), dt),
            "wk": init_dense(ks[1], (d, kv * dh), ("embed", "kv_heads"), dt),
            "wv": init_dense(ks[2], (d, kv * dh), ("embed", "kv_heads"), dt),
            "wo": init_dense(ks[3], (h * dh, d), ("heads", "embed"), dt),
        }
        if cfg.qk_norm:
            tree["qn"] = init_scale(dh, dt)
            tree["kn"] = init_scale(dh, dt)
    return tree


def _init_mlp_params(key, cfg: ModelConfig):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    dt = cfg.parameter_dtype
    return {
        "wi": init_dense(ks[0], (d, f), ("embed", "mlp"), dt),
        "wg": init_dense(ks[1], (d, f), ("embed", "mlp"), dt),
        "wo": init_dense(ks[2], (f, d), ("mlp", "embed"), dt),
    }


def _init_block_params(key, cfg: ModelConfig, kind: str):
    ks = jax.random.split(key, 4)
    dt = cfg.parameter_dtype
    if kind == "attn":
        tree = {
            "ln1": init_scale(cfg.d_model, dt),
            "attn": _init_attn_params(ks[0], cfg),
            "ln2": init_scale(cfg.d_model, dt),
        }
        if cfg.is_moe:
            p, s = moe_mod.init_moe_params(
                ks[1], cfg.d_model, cfg.d_ff, cfg.num_experts, dt,
                num_experts_padded=cfg.num_experts_padded)
            tree["moe"] = (p, s)  # pre-split pair; flatten below
        else:
            tree["mlp"] = _init_mlp_params(ks[1], cfg)
    elif kind == "mamba":
        p, s = ssm_mod.init_mamba_params(
            ks[0], cfg.d_model, expand=cfg.ssm_expand, state=cfg.ssm_state,
            head_dim=cfg.ssm_head_dim, groups=cfg.ssm_groups, dtype=dt)
        tree = {"ln": init_scale(cfg.d_model, dt), "mixer": (p, s)}
    elif kind == "mlstm":
        p, s = xlstm_mod.init_mlstm_params(
            ks[0], cfg.d_model, heads=cfg.mlstm_heads or cfg.num_heads,
            pf=cfg.mlstm_pf, dtype=dt)
        tree = {"ln": init_scale(cfg.d_model, dt), "mixer": (p, s)}
    else:
        raise ValueError(kind)
    return _split_nested(tree)


def _split_nested(tree):
    """split_tree that tolerates pre-split (params, specs) sub-pairs."""
    params, specs = {}, {}
    for k, v in tree.items():
        if isinstance(v, dict):
            params[k], specs[k] = _split_nested(v)
        elif isinstance(v, tuple) and len(v) == 2 and isinstance(v[0], dict):
            params[k], specs[k] = v
        else:
            params[k], specs[k] = v
    return params, specs


def block_kind(cfg: ModelConfig) -> str:
    return {"attn": "attn", "mamba": "mamba", "mlstm": "mlstm"}[
        cfg.block_pattern]


def layer_windows(cfg: ModelConfig) -> jnp.ndarray:
    """Per-layer sliding window (0 = full/global attention)."""
    if cfg.sliding_window <= 0:
        return jnp.zeros((cfg.num_layers,), jnp.int32)
    if cfg.global_every <= 0:
        return jnp.full((cfg.num_layers,), cfg.sliding_window, jnp.int32)
    idx = jnp.arange(cfg.num_layers)
    is_global = (idx % cfg.global_every) == (cfg.global_every - 1)
    return jnp.where(is_global, 0, cfg.sliding_window).astype(jnp.int32)


def shared_attn_flags(cfg: ModelConfig) -> jnp.ndarray:
    """zamba2: apply the shared attention block after every k-th layer."""
    if cfg.shared_attn_every <= 0:
        return jnp.zeros((cfg.num_layers,), jnp.int32)
    idx = jnp.arange(cfg.num_layers)
    flag = (idx % cfg.shared_attn_every) == (cfg.shared_attn_every - 1)
    # slot index for the shared KV cache = cumulative application count
    return jnp.where(flag, jnp.cumsum(flag.astype(jnp.int32)), 0).astype(
        jnp.int32)  # 0 = no application; k>0 = k-th application


def layer_windows_py(cfg: ModelConfig) -> list:
    """Python-int version of layer_windows (static dispatch when unrolled)."""
    if cfg.sliding_window <= 0:
        return [0] * cfg.num_layers
    if cfg.global_every <= 0:
        return [cfg.sliding_window] * cfg.num_layers
    return [0 if (i % cfg.global_every) == (cfg.global_every - 1)
            else cfg.sliding_window for i in range(cfg.num_layers)]


def shared_slots_py(cfg: ModelConfig) -> list:
    if cfg.shared_attn_every <= 0:
        return [0] * cfg.num_layers
    out, count = [], 0
    for i in range(cfg.num_layers):
        fire = (i % cfg.shared_attn_every) == (cfg.shared_attn_every - 1)
        count += int(fire)
        out.append(count if fire else 0)
    return out


def num_shared_apps(cfg: ModelConfig) -> int:
    if cfg.shared_attn_every <= 0:
        return 0
    return cfg.num_layers // cfg.shared_attn_every


def init_params(cfg: ModelConfig, key: Array):
    """Returns (params, logical-axis specs)."""
    ks = jax.random.split(key, cfg.num_layers + 8)
    kind = block_kind(cfg)
    per_layer = [_init_block_params(ks[i], cfg, kind)
                 for i in range(cfg.num_layers)]
    blocks = stack_layer_params([p for p, _ in per_layer])
    block_specs = stacked_specs(per_layer[0][1])

    tree: dict[str, Any] = {"blocks": (blocks, block_specs)}
    if cfg.frontend == "frames":
        tree["frame_proj"] = init_dense(ks[-1], (cfg.d_model, cfg.d_model),
                                        ("embed", "mlp"), cfg.parameter_dtype)
    else:
        tree["embed"] = init_embed(ks[-1], cfg.vocab_padded, cfg.d_model,
                                   cfg.parameter_dtype)
    tree["final_norm"] = init_scale(cfg.d_model, cfg.parameter_dtype)
    if not cfg.tie_embeddings:
        tree["lm_head"] = init_dense(ks[-2], (cfg.d_model, cfg.vocab_padded),
                                     ("embed", "vocab"), cfg.parameter_dtype)
    if num_shared_apps(cfg) > 0:
        shared_cfg = dataclasses.replace(cfg, block_pattern="attn",
                                         num_experts=0)
        tree["shared_attn"] = _init_block_params(ks[-3], shared_cfg, "attn")
    params, specs = _split_nested(tree)
    return params, specs


# ---------------------------------------------------------------------------
# Attention block application
# ---------------------------------------------------------------------------

def _gqa_qkv(p, cfg: ModelConfig, x: Array, positions: Array):
    b, s, d = x.shape
    h, kv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
    q = (x @ p["wq"]).reshape(b, s, h, dh)
    k = (x @ p["wk"]).reshape(b, s, kv, dh)
    v = (x @ p["wv"]).reshape(b, s, kv, dh)
    if cfg.qk_norm:
        q = rms_norm(q, p["qn"], cfg.norm_eps)
        k = rms_norm(k, p["kn"], cfg.norm_eps)
    if cfg.rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_block_forward(p, cfg: ModelConfig, x: Array, window,
                       positions: Array):
    """Full-sequence attention sublayer (train / prefill).

    `window` may be a traced scalar; global (0) vs. local dispatch happens
    via lax.cond with the static config window used in the banded branch.
    Returns (out, (k, v)) so prefill can build the cache.
    """
    h = cfg.num_heads
    xn = rms_norm(x, p["ln1"], cfg.norm_eps)
    if cfg.attention == "mla":
        out, kv_pair = _mla_forward(p["attn"], cfg, xn, positions)
    else:
        q, k, v = _gqa_qkv(p["attn"], cfg, xn, positions)
        q = constrain(q, ("batch", "seq", "heads", None))
        k = constrain(k, ("batch", "seq", "kv_heads", None))
        v = constrain(v, ("batch", "seq", "kv_heads", None))

        if cfg.sliding_window > 0 and cfg.global_every > 0:
            s = x.shape[1]

            def local_branch(qkv):
                q_, k_, v_ = qkv
                if s <= cfg.sliding_window:
                    return attn_mod.full_attention(
                        q_, k_, v_, causal=cfg.causal,
                        window=cfg.sliding_window)
                return attn_mod.banded_attention(
                    q_, k_, v_, window=cfg.sliding_window)

            def global_branch(qkv):
                q_, k_, v_ = qkv
                return attn_mod.dispatch_attention(q_, k_, v_,
                                                   causal=cfg.causal)

            if isinstance(window, int):     # static layer type (unrolled)
                out = (local_branch if window > 0 else global_branch)(
                    (q, k, v))
            else:
                out = jax.lax.cond(window > 0, local_branch, global_branch,
                                   (q, k, v))
        elif cfg.sliding_window > 0:
            out = attn_mod.dispatch_attention(q, k, v, causal=cfg.causal,
                                              window=cfg.sliding_window)
        else:
            out = attn_mod.dispatch_attention(q, k, v, causal=cfg.causal)
        kv_pair = (k, v)
        out = out.reshape(*x.shape[:2], h * cfg.head_dim_)
        out = out @ p["attn"]["wo"]
    return x + out, kv_pair


def _mla_forward(p, cfg: ModelConfig, xn: Array, positions: Array):
    """MLA train/prefill path: materialize per-head K/V; cache latents."""
    b, s, _ = xn.shape
    h = cfg.num_heads
    nope, rdim, vdim = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    cq = rms_norm(xn @ p["wdq"], p["q_norm"], cfg.norm_eps)
    q = (cq @ p["wuq"]).reshape(b, s, h, nope + rdim)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    ckv_full = xn @ p["wdkv"]                              # (b,s,kvr+rdim)
    c_kv = rms_norm(ckv_full[..., : cfg.kv_lora_rank], p["kv_norm"],
                    cfg.norm_eps)
    k_rope = apply_rope(ckv_full[..., cfg.kv_lora_rank:][:, :, None, :],
                        positions, cfg.rope_theta)          # (b,s,1,rdim)
    k_nope = (c_kv @ p["wuk"]).reshape(b, s, h, nope)
    v = (c_kv @ p["wuv"]).reshape(b, s, h, vdim)
    k = jnp.concatenate([k_nope,
                         jnp.broadcast_to(k_rope, (b, s, h, rdim))], -1)
    q_full = jnp.concatenate([q_nope, q_rope], -1)
    out = attn_mod.dispatch_attention(q_full, k, v, causal=cfg.causal)
    out = out.reshape(b, s, h * vdim) @ p["wo"]
    return out, (c_kv, k_rope[:, :, 0, :])                 # latent cache


def mlp_forward(p, cfg: ModelConfig, x: Array):
    xn = rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.is_moe:
        out, aux = moe_mod.moe_ffn(p["moe"], xn, top_k=cfg.top_k,
                                   capacity_factor=cfg.capacity_factor,
                                   dispatch=cfg.moe_dispatch)
        return x + out, aux
    # Sequence-parallel MLP: the GEMMs run on seq-sharded activations with
    # the weights FSDP-gathered per layer; the weight-grad partial sums
    # all-reduce over the model axis.  The Megatron-SP alternative (gather
    # seq, TP on mlp, reduce-scatter out) was measured WORSE at deepseek
    # width (coll 18.8 -> 27.2 s: activations outweigh weights there), so
    # GSPMD's strategy is kept — see EXPERIMENTS.md §Perf D3 (refuted).
    h = jax.nn.silu(xn @ p["mlp"]["wg"]) * (xn @ p["mlp"]["wi"])
    h = constrain(h, ("batch", "seq", "mlp"))
    return x + h @ p["mlp"]["wo"], jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# Full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------

def forward(params, cfg: ModelConfig, tokens: Array,
            collect_cache: bool = False):
    """tokens: (B, S) int32 ids, or (B, S, D) frames for `frontend='frames'`.

    Returns (hidden (B,S,D), aux_loss, per-layer cache pytree or None).
    The cache pytree has a leading layer axis (scan-stacked): KV pairs for
    attention stacks, decode-state dicts for recurrent stacks, plus the
    shared-attention KV when present.
    """
    act = cfg.activation_dtype
    if cfg.frontend == "frames":
        x = tokens.astype(act) @ params["frame_proj"].astype(act)
        x = x + sinusoidal_positions(x.shape[1], cfg.d_model).astype(act)
    else:
        x = params["embed"].astype(act)[tokens]
    b, s = x.shape[:2]
    x = constrain(x, ("batch", "seq", "embed"))
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))

    kind = block_kind(cfg)
    windows = layer_windows(cfg)
    shared_slots = shared_attn_flags(cfg)
    shared = params.get("shared_attn")

    def body(x, scanned):
        layer_p, window, shared_slot = scanned
        layer_p = cast_tree(layer_p, act)
        aux = jnp.zeros((), jnp.float32)
        if kind == "attn":
            x, state = attn_block_forward(layer_p, cfg, x, window, positions)
            x, aux = mlp_forward(layer_p, cfg, x)
        elif kind == "mamba":
            xn = rms_norm(x, layer_p["ln"], cfg.norm_eps)
            y, state = ssm_mod.mamba_block(layer_p["mixer"], xn, cfg,
                                           return_state=True)
            x = x + y
        else:  # mlstm
            xn = rms_norm(x, layer_p["ln"], cfg.norm_eps)
            y, state = xlstm_mod.mlstm_block(layer_p["mixer"], xn, cfg,
                                             return_state=True)
            x = x + y
        if shared is not None:
            def apply_shared(x):
                sp = cast_tree(shared, act)
                x2, skv = attn_block_forward(sp, cfg, x, 0, positions)
                x2, _ = mlp_forward(sp, cfg, x2)
                return x2, skv

            def no_shared(x):
                return x, _shared_kv_zeros(cfg, b, s, act)

            if isinstance(shared_slot, int):   # static (unrolled)
                x, skv = (apply_shared if shared_slot > 0 else no_shared)(x)
            else:
                x, skv = jax.lax.cond(shared_slot > 0, apply_shared,
                                      no_shared, x)
        else:
            skv = None
        x = constrain(x, ("batch", "seq", "embed"))
        outs = (state, skv) if collect_cache else None
        return x, (outs, aux)

    if cfg.remat:
        policy = (jax.checkpoint_policies.nothing_saveable
                  if cfg.remat_policy == "nothing"
                  else jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        body = jax.checkpoint(body, policy=policy)

    if cfg.unroll_layers:
        # Python-loop unroll: identical math, exact HLO cost accounting
        # (XLA's HloCostAnalysis counts while-loop bodies once), and static
        # per-layer dispatch (no dead cond branches polluting the count).
        win_py, slot_py = layer_windows_py(cfg), shared_slots_py(cfg)
        outs_list, aux_total = [], jnp.zeros((), jnp.float32)
        for i in range(cfg.num_layers):
            layer_p = jax.tree.map(lambda a, i=i: a[i], params["blocks"])
            x, (outs, aux_i) = body(x, (layer_p, win_py[i], slot_py[i]))
            outs_list.append(outs)
            aux_total = aux_total + aux_i
        if collect_cache:
            cache_parts = jax.tree.map(lambda *xs: jnp.stack(xs, 0),
                                       *outs_list)
        else:
            cache_parts = None
        x = rms_norm(x, params["final_norm"].astype(act), cfg.norm_eps)
        return x, aux_total, cache_parts

    x, (cache_parts, aux) = jax.lax.scan(
        body, x, (params["blocks"], windows, shared_slots))
    x = rms_norm(x, params["final_norm"].astype(act), cfg.norm_eps)
    return x, aux.sum(), cache_parts


def _shared_kv_zeros(cfg, b, s, act):
    kv, dh = cfg.num_kv_heads, cfg.head_dim_
    return (jnp.zeros((b, s, kv, dh), act), jnp.zeros((b, s, kv, dh), act))


def logits_from_hidden(params, cfg: ModelConfig, x: Array) -> Array:
    act = cfg.activation_dtype
    if cfg.tie_embeddings:
        head = params["embed"].astype(act).T
    else:
        head = params["lm_head"].astype(act)
    logits = x @ head
    return constrain(logits, ("batch", "seq", "vocab"))


def lm_loss(params, cfg: ModelConfig, batch) -> tuple[Array, dict]:
    """Next-token (or frame-label) cross entropy + MoE aux."""
    inputs = batch["inputs"]
    targets = batch["targets"]
    mask = batch.get("mask")
    x, aux, _ = forward(params, cfg, inputs)
    logits = logits_from_hidden(params, cfg, x).astype(jnp.float32)
    if cfg.vocab_padded != cfg.vocab_size:
        pad_mask = jnp.arange(cfg.vocab_padded) < cfg.vocab_size
        logits = jnp.where(pad_mask, logits, -1e30)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        mask = jnp.ones_like(nll)
    denom = jnp.maximum(mask.sum(), 1.0)
    ce = (nll * mask).sum() / denom
    loss = ce + cfg.router_aux_weight * aux
    acc = ((logits.argmax(-1) == targets) * mask).sum() / denom
    return loss, {"ce": ce, "aux": aux, "accuracy": acc}


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------

def init_cache(params, cfg: ModelConfig, batch: int, max_len: int):
    """Decode-state pytree (shape depends on block kind)."""
    act = cfg.activation_dtype
    kind = block_kind(cfg)
    nl = cfg.num_layers
    cache: dict[str, Any] = {"pos": jnp.zeros((), jnp.int32)}
    if kind == "attn":
        if cfg.attention == "mla":
            cache["c_kv"] = jnp.zeros((nl, batch, max_len, cfg.kv_lora_rank),
                                      act)
            cache["k_rope"] = jnp.zeros((nl, batch, max_len, cfg.qk_rope_dim),
                                        act)
        else:
            kv, dh = cfg.num_kv_heads, cfg.head_dim_
            cache["k"] = jnp.zeros((nl, batch, max_len, kv, dh), act)
            cache["v"] = jnp.zeros((nl, batch, max_len, kv, dh), act)
    elif kind == "mamba":
        one = ssm_mod.mamba_init_state(
            _layer0(params["blocks"])["mixer"], batch, cfg, cfg.d_model, act)
        cache["mamba"] = jax.tree.map(
            lambda z: jnp.zeros((nl,) + z.shape, z.dtype), one)
    else:
        one = xlstm_mod.mlstm_init_state(
            _layer0(params["blocks"])["mixer"], batch, cfg, cfg.d_model, act)
        cache["mlstm"] = jax.tree.map(
            lambda z: jnp.zeros((nl,) + z.shape, z.dtype), one)
    napps = num_shared_apps(cfg)
    if napps > 0:
        kv, dh = cfg.num_kv_heads, cfg.head_dim_
        cache["shared_k"] = jnp.zeros((napps, batch, max_len, kv, dh), act)
        cache["shared_v"] = jnp.zeros((napps, batch, max_len, kv, dh), act)
    return cache


def _layer0(blocks):
    return jax.tree.map(lambda x: x[0], blocks)


def prefill(params, cfg: ModelConfig, tokens: Array, max_len: int):
    """Process the prompt; returns (last-position logits, cache).

    Serving state falls out of the same scan as the forward pass: attention
    stacks emit per-layer KV (or MLA latents); recurrent stacks emit their
    final chunk states.
    """
    b, s = tokens.shape[:2]
    x, _, cache_parts = forward(params, cfg, tokens, collect_cache=True)
    logits = logits_from_hidden(params, cfg, x[:, -1:, :])
    cache = init_cache(params, cfg, b, max_len)
    cache["pos"] = jnp.asarray(s, jnp.int32)
    kind = block_kind(cfg)
    states, skv = cache_parts
    if kind == "attn":
        if cfg.attention == "mla":
            c_kv, k_rope = states         # (L, b, s, r), (L, b, s, rdim)
            cache["c_kv"] = cache["c_kv"].at[:, :, :s].set(c_kv)
            cache["k_rope"] = cache["k_rope"].at[:, :, :s].set(k_rope)
        else:
            k, v = states
            cache["k"] = cache["k"].at[:, :, :s].set(k)
            cache["v"] = cache["v"].at[:, :, :s].set(v)
    elif kind == "mamba":
        cache["mamba"] = states
    else:
        cache["mlstm"] = states
    if skv is not None and "shared_k" in cache:
        sk, sv = skv                       # (L, b, s, kv, dh), zeros where
        period = cfg.shared_attn_every     # the shared block didn't fire
        app_layers = [i for i in range(cfg.num_layers)
                      if (i % period) == period - 1]
        cache["shared_k"] = cache["shared_k"].at[:, :, :s].set(
            sk[jnp.asarray(app_layers)])
        cache["shared_v"] = cache["shared_v"].at[:, :, :s].set(
            sv[jnp.asarray(app_layers)])
    return logits, cache


def decode_step(params, cfg: ModelConfig, cache, token: Array):
    """One decode step.  token: (B, 1) ids (or (B, 1, D) frames).

    Returns (logits (B, 1, V), updated cache).  The layer scan threads the
    shared-attention KV through its carry (zamba2).
    """
    act = cfg.activation_dtype
    if cfg.frontend == "frames":
        x = token.astype(act) @ params["frame_proj"].astype(act)
    else:
        x = params["embed"].astype(act)[token]
    b = x.shape[0]
    pos = cache["pos"]
    positions = jnp.full((b, 1), pos, jnp.int32)

    kind = block_kind(cfg)
    windows = layer_windows(cfg)
    shared_slots = shared_attn_flags(cfg)
    shared = params.get("shared_attn")
    new_cache = dict(cache)

    def layer_apply(x, layer_p, window, layer_state):
        if kind == "attn":
            layer_p = cast_tree(layer_p, act)
            if cfg.attention == "mla":
                xo, layer_state = _mla_decode(layer_p, cfg, x, layer_state,
                                              pos, positions)
                x, _ = mlp_forward(layer_p, cfg, xo)
                return x, layer_state
            k_c, v_c = layer_state
            xn = rms_norm(x, layer_p["ln1"], cfg.norm_eps)
            q, k1, v1 = _gqa_qkv(layer_p["attn"], cfg, xn, positions)
            k_c = jax.lax.dynamic_update_slice_in_dim(k_c, k1, pos, axis=1)
            v_c = jax.lax.dynamic_update_slice_in_dim(v_c, v1, pos, axis=1)
            out = attn_mod.decode_attention(q, k_c, v_c, pos, window=window)
            out = out.reshape(b, 1, cfg.num_heads * cfg.head_dim_)
            x = x + out @ layer_p["attn"]["wo"]
            x, _ = mlp_forward(layer_p, cfg, x)
            return x, (k_c, v_c)
        layer_p = cast_tree(layer_p, act)
        xn = rms_norm(x, layer_p["ln"], cfg.norm_eps)
        if kind == "mamba":
            y, layer_state = ssm_mod.mamba_decode_step(layer_p["mixer"], xn,
                                                       layer_state, cfg)
        else:
            y, layer_state = xlstm_mod.mlstm_decode_step(layer_p["mixer"], xn,
                                                         layer_state, cfg)
        return x + y, layer_state

    if kind == "attn":
        if cfg.attention == "mla":
            per_layer_state = (cache["c_kv"], cache["k_rope"])
        else:
            per_layer_state = (cache["k"], cache["v"])
    elif kind == "mamba":
        per_layer_state = cache["mamba"]
    else:
        per_layer_state = cache["mlstm"]

    def scan_body(carry, scanned):
        x, sk, sv = carry
        layer_p, window, slot, layer_state = scanned
        x, new_state = layer_apply(x, layer_p, window, layer_state)
        if shared is not None:
            def apply_shared(args):
                x, sk, sv = args
                sp = cast_tree(shared, act)
                xn = rms_norm(x, sp["ln1"], cfg.norm_eps)
                q, k1, v1 = _gqa_qkv(sp["attn"], cfg, xn, positions)
                app = slot - 1
                skl = jax.lax.dynamic_index_in_dim(sk, app, 0, keepdims=False)
                svl = jax.lax.dynamic_index_in_dim(sv, app, 0, keepdims=False)
                skl = jax.lax.dynamic_update_slice_in_dim(skl, k1, pos, axis=1)
                svl = jax.lax.dynamic_update_slice_in_dim(svl, v1, pos, axis=1)
                out = attn_mod.decode_attention(q, skl, svl, pos)
                out = out.reshape(b, 1, cfg.num_heads * cfg.head_dim_)
                x = x + out @ sp["attn"]["wo"]
                x, _ = mlp_forward(sp, cfg, x)
                sk = jax.lax.dynamic_update_slice_in_dim(sk, skl[None], app,
                                                         axis=0)
                sv = jax.lax.dynamic_update_slice_in_dim(sv, svl[None], app,
                                                         axis=0)
                return x, sk, sv

            if isinstance(slot, int):       # static (unrolled)
                if slot > 0:
                    x, sk, sv = apply_shared((x, sk, sv))
            else:
                x, sk, sv = jax.lax.cond(slot > 0, apply_shared, lambda a: a,
                                         (x, sk, sv))
        return (x, sk, sv), new_state

    if shared is not None:
        carry0 = (x, cache["shared_k"], cache["shared_v"])
    else:
        zero = jnp.zeros((0,), act)
        carry0 = (x, zero, zero)

    if cfg.unroll_layers:
        win_py, slot_py = layer_windows_py(cfg), shared_slots_py(cfg)
        carry, states_list = carry0, []
        for i in range(cfg.num_layers):
            layer_p = jax.tree.map(lambda a, i=i: a[i], params["blocks"])
            state_i = jax.tree.map(lambda a, i=i: a[i], per_layer_state)
            carry, new_state = scan_body(
                carry, (layer_p, win_py[i], slot_py[i], state_i))
            states_list.append(new_state)
        (x, sk, sv) = carry
        new_states = jax.tree.map(lambda *xs: jnp.stack(xs, 0), *states_list)
    else:
        (x, sk, sv), new_states = jax.lax.scan(
            scan_body, carry0,
            (params["blocks"], windows, shared_slots, per_layer_state))

    if kind == "attn":
        if cfg.attention == "mla":
            new_cache["c_kv"], new_cache["k_rope"] = new_states
        else:
            new_cache["k"], new_cache["v"] = new_states
    elif kind == "mamba":
        new_cache["mamba"] = new_states
    else:
        new_cache["mlstm"] = new_states
    if shared is not None:
        new_cache["shared_k"] = sk
        new_cache["shared_v"] = sv

    x = rms_norm(x, params["final_norm"].astype(act), cfg.norm_eps)
    logits = logits_from_hidden(params, cfg, x)
    new_cache["pos"] = pos + 1
    return logits, new_cache


def _mla_decode(p, cfg: ModelConfig, x, caches, pos, positions):
    """Absorbed-projection MLA decode: attention in the latent space."""
    ckv_c, krope_c = caches                      # (b, S, r), (b, S, rdim)
    b = x.shape[0]
    h = cfg.num_heads
    nope, rdim = cfg.qk_nope_dim, cfg.qk_rope_dim
    r = cfg.kv_lora_rank
    ap = p["attn"]
    xn = rms_norm(x, p["ln1"], cfg.norm_eps)

    cq = rms_norm(xn @ ap["wdq"], ap["q_norm"], cfg.norm_eps)
    q = (cq @ ap["wuq"]).reshape(b, 1, h, nope + rdim)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    ckv_full = xn @ ap["wdkv"]
    c_new = rms_norm(ckv_full[..., :r], ap["kv_norm"], cfg.norm_eps)
    krope_new = apply_rope(ckv_full[..., r:][:, :, None, :], positions,
                           cfg.rope_theta)[:, :, 0, :]
    ckv_c = jax.lax.dynamic_update_slice_in_dim(ckv_c, c_new, pos, axis=1)
    krope_c = jax.lax.dynamic_update_slice_in_dim(krope_c, krope_new, pos,
                                                  axis=1)

    # Absorb W_uk into q: q_abs (b, 1, h, r)
    wuk = ap["wuk"].reshape(r, h, nope)
    q_abs = jnp.einsum("bqhn,rhn->bqhr", q_nope, wuk)
    scores = (jnp.einsum("bqhr,bsr->bhqs", q_abs, ckv_c,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bqhd,bsd->bhqs", q_rope, krope_c,
                           preferred_element_type=jnp.float32))
    scores = scores / ((nope + rdim) ** 0.5)
    s_len = ckv_c.shape[1]
    mask = jnp.arange(s_len) <= pos
    scores = jnp.where(mask[None, None, None, :], scores, attn_mod.NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    o_latent = jnp.einsum("bhqs,bsr->bqhr", probs, ckv_c)
    wuv = ap["wuv"].reshape(r, h, cfg.v_head_dim)
    o = jnp.einsum("bqhr,rhv->bqhv", o_latent, wuv)
    out = o.reshape(b, 1, h * cfg.v_head_dim) @ ap["wo"]
    return x + out, (ckv_c, krope_c)
