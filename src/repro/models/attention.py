"""Attention variants: GQA (full / chunked / banded / decode) and MLA.

Memory regimes (chosen by the caller based on sequence length):
  * full     — one masked einsum; scores materialize (train_4k scale).
  * chunked  — flash-style online softmax, lax.scan over KV blocks inside a
               scan over Q blocks; O(S * block) live memory (prefill_32k+).
  * banded   — sliding-window attention via explicit KV window slices; exact
               and O(S * (window + chunk)) compute (gemma3 local layers).
  * decode   — one-token query against a KV cache (serve_step).

GQA never materializes repeated KV heads: Q is reshaped to
(batch, q_per_kv, kv_heads, ...) and contracted group-wise.

MLA (MiniCPM3/DeepSeek-style latent attention) provides a train path that
materializes per-head K/V and a decode path that keeps the cache in the
compressed latent space with the absorbed-projection trick.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array

NEG_INF = -1e30


def _group(q: Array, kv_heads: int) -> Array:
    """(B, S, H, d) -> (B, S, kv, g, d)."""
    b, s, h, d = q.shape
    return q.reshape(b, s, kv_heads, h // kv_heads, d)


def _scale(dh: int) -> float:
    return 1.0 / (dh ** 0.5)


# ---------------------------------------------------------------------------
# Full (masked-einsum) attention
# ---------------------------------------------------------------------------

def full_attention(q: Array, k: Array, v: Array, *, causal: bool,
                   window: int = 0) -> Array:
    """q: (B,S,H,dh); k/v: (B,S,KV,dh).  Returns (B,S,H,dh)."""
    b, s, h, dh = q.shape
    kv = k.shape[2]
    qg = _group(q, kv) * _scale(dh)
    # (B, kv, g, Sq, Sk)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                        preferred_element_type=jnp.float32)
    qi = jnp.arange(s)[:, None]
    kj = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= kj <= qi
    if window > 0:
        mask &= kj > qi - window
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(b, s, h, v.shape[-1])


# ---------------------------------------------------------------------------
# Chunked flash-style attention (online softmax) with a flash BACKWARD.
#
# A plain scan-over-blocks forward autodiffs into a backward that saves every
# block's probabilities (scan residuals) — measured +20 GB/device at
# train_4k.  The custom VJP below implements the FlashAttention backward:
# save only (q, k, v, out, lse), recompute each block's probabilities from
# lse inside the backward sweep.  Live memory is O(S * block), both ways.
# ---------------------------------------------------------------------------

def _block_mask(qi, ki, q_chunk, kv_chunk, causal, window):
    qpos = qi * q_chunk + jnp.arange(q_chunk)[:, None]
    kpos = ki * kv_chunk + jnp.arange(kv_chunk)[None, :]
    mask = jnp.ones((q_chunk, kv_chunk), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    return mask


def _flash_fwd_impl(q, k, v, causal, window, q_chunk, kv_chunk):
    b, s, h, dh = q.shape
    kv_heads = k.shape[2]
    g = h // kv_heads
    dv = v.shape[-1]
    nq, nk = s // q_chunk, s // kv_chunk
    qg = (_group(q, kv_heads) * _scale(dh)).astype(q.dtype)
    qg = qg.reshape(b, nq, q_chunk, kv_heads, g, dh)
    kc = k.reshape(b, nk, kv_chunk, kv_heads, dh)
    vc = v.reshape(b, nk, kv_chunk, kv_heads, dv)

    def q_block(qi):
        q_blk = qg[:, qi]
        m0 = jnp.full((b, kv_heads, g, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kv_heads, g, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, q_chunk, kv_heads, g, dv), jnp.float32)

        def kv_block(carry, ki):
            m, l, acc = carry
            kb, vb = kc[:, ki], vc[:, ki]
            scores = jnp.einsum("bqkgd,bskd->bkgqs", q_blk, kb,
                                preferred_element_type=jnp.float32)
            mask = _block_mask(qi, ki, q_chunk, kv_chunk, causal, window)
            scores = jnp.where(mask, scores, NEG_INF)
            m_new = jnp.maximum(m, scores.max(axis=-1))
            p = jnp.exp(scores - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc = acc * corr.transpose(0, 3, 1, 2)[..., None] + jnp.einsum(
                "bkgqs,bskd->bqkgd", p.astype(q.dtype), vb,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc), None

        (m, l, acc), _ = jax.lax.scan(kv_block, (m0, l0, a0), jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30).transpose(0, 3, 1, 2)[..., None]
        lse = m + jnp.log(jnp.maximum(l, 1e-30))        # (b, kv, g, q_chunk)
        return out.astype(q.dtype), lse

    outs, lses = jax.lax.map(q_block, jnp.arange(nq))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, s, h, dv)
    lse = lses.transpose(1, 2, 3, 0, 4).reshape(b, kv_heads, g, s)
    return out, lse


def _flash_bwd_impl(q, k, v, out, lse, dout, causal, window, q_chunk,
                    kv_chunk):
    b, s, h, dh = q.shape
    kv_heads = k.shape[2]
    g = h // kv_heads
    dv = v.shape[-1]
    nq, nk = s // q_chunk, s // kv_chunk
    scale = _scale(dh)
    qg = _group(q, kv_heads).reshape(b, nq, q_chunk, kv_heads, g, dh)
    kc = k.reshape(b, nk, kv_chunk, kv_heads, dh)
    vc = v.reshape(b, nk, kv_chunk, kv_heads, dv)
    dog = _group(dout, kv_heads).reshape(b, nq, q_chunk, kv_heads, g, dv)
    lseg = lse.reshape(b, kv_heads, g, nq, q_chunk)
    # delta_i = rowsum(dout * out)  (b, kv, g, nq, q_chunk)
    delta = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32), -1)
    delta = _group(delta[..., None], kv_heads)[..., 0]      # (b, s, kv, g)
    delta = delta.reshape(b, nq, q_chunk, kv_heads, g)

    def q_block(qi):
        """dq for block qi + this block's (dk, dv) contributions."""
        q_blk = qg[:, qi]                                   # (b,Q,kv,g,dh)
        do_blk = dog[:, qi]
        lse_blk = lseg[:, :, :, qi]                         # (b,kv,g,Q)
        dlt_blk = delta[:, qi]                              # (b,Q,kv,g)

        def kv_block(carry, ki):
            dq_acc, dk_all, dv_all = carry
            kb, vb = kc[:, ki], vc[:, ki]
            scores = jnp.einsum("bqkgd,bskd->bkgqs", q_blk, kb,
                                preferred_element_type=jnp.float32) * scale
            mask = _block_mask(qi, ki, q_chunk, kv_chunk, causal, window)
            p = jnp.where(mask, jnp.exp(scores - lse_blk[..., None]), 0.0)
            # dv_j += p^T do
            dv_blk = jnp.einsum("bkgqs,bqkgd->bskd", p.astype(dout.dtype),
                                do_blk, preferred_element_type=jnp.float32)
            # dp = do v^T ; ds = p * (dp - delta) * scale
            dp = jnp.einsum("bqkgd,bskd->bkgqs", do_blk, vb,
                            preferred_element_type=jnp.float32)
            ds = p * (dp - dlt_blk.transpose(0, 2, 3, 1)[..., None]) * scale
            dsq = ds.astype(q.dtype)
            dq_acc = dq_acc + jnp.einsum("bkgqs,bskd->bqkgd", dsq, kb,
                                         preferred_element_type=jnp.float32)
            dk_blk = jnp.einsum("bkgqs,bqkgd->bskd", dsq, q_blk,
                                preferred_element_type=jnp.float32)
            dk_all = jax.lax.dynamic_update_slice_in_dim(
                dk_all, dk_blk.astype(dk_all.dtype), ki * kv_chunk, axis=1)
            dv_all = jax.lax.dynamic_update_slice_in_dim(
                dv_all, dv_blk.astype(dv_all.dtype), ki * kv_chunk, axis=1)
            return (dq_acc, dk_all, dv_all), None

        dq0 = jnp.zeros((b, q_chunk, kv_heads, g, dh), jnp.float32)
        dk0 = jnp.zeros((b, s, kv_heads, dh), jnp.float32)
        dv0 = jnp.zeros((b, s, kv_heads, dv), jnp.float32)
        (dq_acc, dk_all, dv_all), _ = jax.lax.scan(
            kv_block, (dq0, dk0, dv0), jnp.arange(nk))
        return dq_acc, dk_all, dv_all

    dqs, dks, dvs = jax.lax.map(q_block, jnp.arange(nq))
    # ds already carries the scale factor; dq = ds @ k needs no extra scale.
    dq = dqs.transpose(1, 0, 2, 3, 4, 5).reshape(b, s, h, dh)
    dk = dks.sum(0).astype(k.dtype)
    dvv = dvs.sum(0).astype(v.dtype)
    return dq.astype(q.dtype), dk, dvv


import functools as _functools


@_functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash(q, k, v, causal, window, q_chunk, kv_chunk):
    out, _ = _flash_fwd_impl(q, k, v, causal, window, q_chunk, kv_chunk)
    return out


def _flash_fwd_rule(q, k, v, causal, window, q_chunk, kv_chunk):
    out, lse = _flash_fwd_impl(q, k, v, causal, window, q_chunk, kv_chunk)
    return out, (q, k, v, out, lse)


def _flash_bwd_rule(causal, window, q_chunk, kv_chunk, res, dout):
    q, k, v, out, lse = res
    return _flash_bwd_impl(q, k, v, out, lse, dout, causal, window, q_chunk,
                           kv_chunk)


_flash.defvjp(_flash_fwd_rule, _flash_bwd_rule)


def chunked_attention(q: Array, k: Array, v: Array, *, causal: bool,
                      window: int = 0, q_chunk: int = 1024,
                      kv_chunk: int = 1024) -> Array:
    """Flash attention in pure JAX: O(S * block) live memory forward AND
    backward (custom VJP; probabilities recomputed from the saved lse).

    Masked blocks are still computed (fixed-shape scan) — the causal 2x FLOP
    overhead shows up in the roofline's MODEL_FLOPS / HLO_FLOPs ratio and is
    a known hillclimb target (see EXPERIMENTS.md §Perf).
    """
    s = q.shape[1]
    q_chunk = min(q_chunk, s)
    kv_chunk = min(kv_chunk, s)
    assert s % q_chunk == 0 and s % kv_chunk == 0, (s, q_chunk, kv_chunk)
    return _flash(q, k, v, causal, window, q_chunk, kv_chunk)


# ---------------------------------------------------------------------------
# Banded (sliding-window) attention via window slices — exact, no waste.
# ---------------------------------------------------------------------------

def banded_attention(q: Array, k: Array, v: Array, *, window: int,
                     q_chunk: int = 1024) -> Array:
    """Causal sliding-window attention, O(S * (window + chunk)) compute.

    For each Q chunk, slice the KV band [start - window, start + chunk) once
    (padding the front), so no masked-out block is ever computed beyond the
    band edges.
    """
    b, s, h, dh = q.shape
    kv_heads = k.shape[2]
    q_chunk = min(q_chunk, s)
    assert s % q_chunk == 0
    nq = s // q_chunk
    band = window + q_chunk

    kp = jnp.pad(k, ((0, 0), (window, 0), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (window, 0), (0, 0), (0, 0)))
    qg = (_group(q, kv_heads) * _scale(dh))
    qg = qg.reshape(b, nq, q_chunk, kv_heads, h // kv_heads, dh)

    def q_block(qi):
        q_blk = qg[:, qi]
        start = qi * q_chunk            # position in padded coords
        kb = jax.lax.dynamic_slice_in_dim(kp, start, band, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(vp, start, band, axis=1)
        scores = jnp.einsum("bqkgd,bskd->bkgqs", q_blk, kb,
                            preferred_element_type=jnp.float32)
        qpos = qi * q_chunk + jnp.arange(q_chunk)[:, None]      # global q idx
        kpos = start + jnp.arange(band)[None, :] - window        # global k idx
        mask = (kpos <= qpos) & (kpos > qpos - window) & (kpos >= 0)
        scores = jnp.where(mask, scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        out = jnp.einsum("bkgqs,bskd->bqkgd", probs, vb)
        return out.reshape(b, q_chunk, h, v.shape[-1])

    outs = jax.lax.map(q_block, jnp.arange(nq))
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, s, h, v.shape[-1])


# ---------------------------------------------------------------------------
# Decode attention (one new token vs. the KV cache)
# ---------------------------------------------------------------------------

def decode_attention(q: Array, k_cache: Array, v_cache: Array, pos: Array,
                     window: Array | int = 0) -> Array:
    """q: (B,1,H,dh); caches: (B,S,KV,dh); pos: () current write index.

    Attends to cache positions [0, pos] (or the trailing `window` of them).
    `window` may be a *traced* scalar (per-layer window arrays ride through
    the layer scan); window <= 0 means unbounded.
    """
    b, _, h, dh = q.shape
    kv_heads = k_cache.shape[2]
    s = k_cache.shape[1]
    qg = _group(q, kv_heads) * _scale(dh)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k_cache,
                        preferred_element_type=jnp.float32)
    kj = jnp.arange(s)
    window = jnp.asarray(window, jnp.int32)
    mask = (kj <= pos) & ((window <= 0) | (kj > pos - window))
    scores = jnp.where(mask[None, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v_cache)
    return out.reshape(b, 1, h, v_cache.shape[-1])


def dispatch_attention(q: Array, k: Array, v: Array, *, causal: bool,
                       window: int = 0, full_threshold: int = 1024) -> Array:
    """Pick the cheapest exact implementation for the sequence length.

    Above `full_threshold` the flash-style chunked path is used even when
    the (S, S) scores would fit: materializing f32 scores at train_4k costs
    ~10x the HBM traffic of the online-softmax form (measured in §Perf).
    """
    s = q.shape[1]
    if window > 0 and s > window:
        return banded_attention(q, k, v, window=window,
                                q_chunk=min(1024, s))
    if s <= full_threshold:
        return full_attention(q, k, v, causal=causal, window=window)
    return chunked_attention(q, k, v, causal=causal, window=window)
