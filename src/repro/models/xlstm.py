"""xLSTM mLSTM blocks [arXiv:2405.04517]: chunkwise-parallel train scan and
O(1)-state recurrent decode.

The mLSTM cell keeps a matrix memory C (dh x dh), normalizer n (dh) and a
log-space stabilizer m per head, with exponential input gates and sigmoid
forget gates:

  m_t = max(log f_t + m_{t-1}, log i_t)
  C_t = e^{log f_t + m_{t-1} - m_t} C_{t-1} + e^{log i_t - m_t} v_t k_t^T
  n_t = (same decays) n_{t-1} + e^{log i_t - m_t} k_t
  h_t = (C_t q_t) / max(|n_t^T q_t|, e^{-m_t})

The chunkwise form evaluates the intra-chunk part as a decay-masked
attention-like product and carries (C, n, m) across chunks — structurally
the same schedule as Mamba-2's SSD, so the same sharding applies.  The
per-token recurrence (`mlstm_recurrent_ref` / decode path) is the oracle.

Per the assigned config (d_ff = 0), blocks carry an internal up-projection
(pf = 2) instead of a separate FFN, matching the xLSTM paper's mLSTM block.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import init_dense, rms_norm, split_tree

Array = jax.Array


def init_mlstm_params(key, d_model: int, *, heads: int, pf: float, dtype,
                      conv_width: int = 4):
    dv = int(pf * d_model)
    ks = jax.random.split(key, 8)
    tree = {
        "up_proj": init_dense(ks[0], (d_model, 2 * dv), ("embed", "mlp"),
                              dtype),
        "conv_w": init_dense(ks[1], (conv_width, dv), ("layers_none", "mlp"),
                             dtype, scale=0.5),
        "conv_b": (jnp.zeros((dv,), dtype), ("mlp",)),
        "wq": init_dense(ks[2], (dv, dv), ("mlp", "heads"), dtype),
        "wk": init_dense(ks[3], (dv, dv), ("mlp", "heads"), dtype),
        "wv": init_dense(ks[4], (dv, dv), ("mlp", "heads"), dtype),
        "w_gates": init_dense(ks[5], (dv, 2 * heads), ("mlp", "heads"), dtype,
                              scale=0.01),
        "b_gates": (jnp.concatenate([jnp.zeros((heads,)),
                                     jnp.linspace(3.0, 6.0, heads)]
                                    ).astype(dtype), ("heads",)),
        "norm_scale": (jnp.ones((dv,), dtype), ("mlp",)),
        "down_proj": init_dense(ks[7], (dv, d_model), ("mlp", "embed"), dtype),
    }
    return split_tree(tree)


def _qkv_gates(params, x_up: Array, heads: int):
    """x_up: (B, L, dv) (post-conv for q/k, raw for v)."""
    b, l, dv = x_up.shape
    dh = dv // heads
    conv = jax.nn.silu(_causal_conv(x_up, params["conv_w"], params["conv_b"]))
    q = (conv @ params["wq"]).reshape(b, l, heads, dh)
    k = (conv @ params["wk"]).reshape(b, l, heads, dh) / (dh ** 0.5)
    v = (x_up @ params["wv"]).reshape(b, l, heads, dh)
    gates = conv @ params["w_gates"] + params["b_gates"]
    logi = gates[..., :heads].astype(jnp.float32)              # (B, L, H)
    logf = jax.nn.log_sigmoid(gates[..., heads:].astype(jnp.float32))
    return q, k, v, logi, logf


def _causal_conv(x: Array, w: Array, b: Array) -> Array:
    width = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(width):
        out = out + xp[:, i:i + x.shape[1], :] * w[i]
    return out + b


def mlstm_chunked(q, k, v, logi, logf, *, chunk: int, state=None,
                  return_final_state: bool = False):
    """Chunkwise-parallel stabilized mLSTM.

    q/k/v: (B, L, H, dh); logi/logf: (B, L, H).  state: (C, n, m) with
    C (B, H, dh, dh), n (B, H, dh), m (B, H).
    """
    bsz, l, h, dh = q.shape
    chunk = min(chunk, l)
    l_orig = l
    if l % chunk:
        # Pad with no-op steps: f=1 (logf=0), i=exp(-inf)=0, zero q/k/v.
        pad = chunk - l % chunk
        zpad4 = ((0, 0), (0, pad), (0, 0), (0, 0))
        zpad3 = ((0, 0), (0, pad), (0, 0))
        q = jnp.pad(q, zpad4)
        k = jnp.pad(k, zpad4)
        v = jnp.pad(v, zpad4)
        logf = jnp.pad(logf, zpad3)
        logi = jnp.pad(logi, zpad3, constant_values=-1e30)
        l = l + pad
    nc = l // chunk

    qc = q.reshape(bsz, nc, chunk, h, dh)
    kc = k.reshape(bsz, nc, chunk, h, dh)
    vc = v.reshape(bsz, nc, chunk, h, dh)
    lic = logi.reshape(bsz, nc, chunk, h)
    lfc = logf.reshape(bsz, nc, chunk, h)

    fcs = jnp.cumsum(lfc, axis=2)                        # inclusive (B,nc,Q,H)
    # intra decay exponent: D[t,s] = fcs[t] - fcs[s] + logi[s], s <= t
    dmat = (fcs[:, :, :, None, :] - fcs[:, :, None, :, :]
            + lic[:, :, None, :, :])                     # (B,nc,Q,S,H)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    dmat = jnp.where(tri[None, None, :, :, None], dmat, -jnp.inf)
    intra_max = dmat.max(axis=3)                         # (B,nc,Q,H)

    if state is None:
        c0 = jnp.zeros((bsz, h, dh, dh), jnp.float32)
        n0 = jnp.zeros((bsz, h, dh), jnp.float32)
        m0 = jnp.full((bsz, h), -jnp.inf, jnp.float32)
    else:
        c0, n0, m0 = state

    # --- inter-chunk carry: scan over chunks ------------------------------
    # end-of-chunk contributions: sum_s exp(fcs[Q-1]-fcs[s]+logi[s]-m_new) kv
    def scan_fn(carry, inp):
        c_prev, n_prev, m_prev = carry
        kb, vb, li, lf, fcs_b = inp       # (B,Q,H,dh) x2, (B,Q,H) x3
        fend = fcs_b[:, -1, :]                                 # (B, H)
        to_end = fend[:, None, :] - fcs_b + li                 # (B, Q, H)
        m_local = to_end.max(axis=1)                           # (B, H)
        m_new = jnp.maximum(fend + m_prev, m_local)
        decay_carry = jnp.exp(fend + m_prev - m_new)           # (B, H)
        w = jnp.exp(to_end - m_new[:, None, :])                # (B, Q, H)
        c_new = (c_prev * decay_carry[..., None, None]
                 + jnp.einsum("bqhv,bqhk,bqh->bhvk", vb, kb, w))
        n_new = (n_prev * decay_carry[..., None]
                 + jnp.einsum("bqhk,bqh->bhk", kb, w))
        return (c_new, n_new, m_new), (c_prev, n_prev, m_prev)

    inputs = (kc.transpose(1, 0, 2, 3, 4), vc.transpose(1, 0, 2, 3, 4),
              lic.transpose(1, 0, 2, 3), lfc.transpose(1, 0, 2, 3),
              fcs.transpose(1, 0, 2, 3))
    (c_f, n_f, m_f), (c_prevs, n_prevs, m_prevs) = jax.lax.scan(
        scan_fn, (c0.astype(jnp.float32), n0.astype(jnp.float32),
                  m0.astype(jnp.float32)), inputs)
    c_prevs = c_prevs.transpose(1, 0, 2, 3, 4)           # (B,nc,H,dh,dh)
    n_prevs = n_prevs.transpose(1, 0, 2, 3)              # (B,nc,H,dh)
    m_prevs = m_prevs.transpose(1, 0, 2)                 # (B,nc,H)

    # --- combine intra + inter with a joint stabilizer --------------------
    inter_exp = fcs + m_prevs[:, :, None, :]             # (B,nc,Q,H)
    m_t = jnp.maximum(intra_max, inter_exp)              # per-position stab
    m_t = jnp.where(jnp.isfinite(m_t), m_t, 0.0)

    w_intra = jnp.exp(dmat - m_t[:, :, :, None, :])      # (B,nc,Q,S,H)
    w_intra = jnp.where(tri[None, None, :, :, None], w_intra, 0.0)
    scores = jnp.einsum("bzqhd,bzshd->bzqsh", qc, kc,
                        preferred_element_type=jnp.float32)
    num_intra = jnp.einsum("bzqsh,bzqsh,bzshd->bzqhd", scores, w_intra,
                           vc.astype(jnp.float32))
    den_intra = jnp.einsum("bzqsh,bzqsh->bzqh", scores, w_intra)

    w_inter = jnp.exp(inter_exp - m_t)                   # (B,nc,Q,H)
    qf = qc.astype(jnp.float32)
    num_inter = jnp.einsum("bzqhd,bzhvd->bzqhv", qf,
                           c_prevs.astype(jnp.float32).transpose(0, 1, 2, 3, 4))
    num_inter = num_inter * w_inter[..., None]
    den_inter = jnp.einsum("bzqhd,bzhd->bzqh", qf, n_prevs) * w_inter

    num = num_intra + num_inter
    den = den_intra + den_inter
    denom = jnp.maximum(jnp.abs(den), jnp.exp(-m_t))
    y = (num / denom[..., None]).reshape(bsz, l, h, dh)[:, :l_orig]
    y = y.astype(q.dtype)
    if return_final_state:
        return y, (c_f, n_f, m_f)
    return y


def mlstm_recurrent_ref(q, k, v, logi, logf, state=None):
    """Per-token recurrence (oracle + decode path)."""
    bsz, l, h, dh = q.shape
    if state is None:
        state = (jnp.zeros((bsz, h, dh, dh), jnp.float32),
                 jnp.zeros((bsz, h, dh), jnp.float32),
                 jnp.full((bsz, h), -jnp.inf, jnp.float32))

    def step(carry, inp):
        c, n, m = carry
        qt, kt, vt, li, lf = inp
        m_new = jnp.maximum(lf + m, li)
        fdec = jnp.exp(lf + m - m_new)
        iexp = jnp.exp(li - m_new)
        c = c * fdec[..., None, None] + iexp[..., None, None] * (
            vt[..., :, None] * kt[..., None, :]).astype(jnp.float32)
        n = n * fdec[..., None] + iexp[..., None] * kt.astype(jnp.float32)
        num = jnp.einsum("bhvd,bhd->bhv", c, qt.astype(jnp.float32))
        den = jnp.einsum("bhd,bhd->bh", n, qt.astype(jnp.float32))
        denom = jnp.maximum(jnp.abs(den), jnp.exp(-m_new))
        y = num / denom[..., None]
        return (c, n, m_new), y

    inputs = (q.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
              v.transpose(1, 0, 2, 3), logi.transpose(1, 0, 2),
              logf.transpose(1, 0, 2))
    (c, n, m), ys = jax.lax.scan(step, state, inputs)
    return ys.transpose(1, 0, 2, 3).astype(q.dtype), (c, n, m)


# ---------------------------------------------------------------------------
# Block-level forward / decode
# ---------------------------------------------------------------------------

def mlstm_block(params, x: Array, cfg, *, return_state: bool = False):
    """x: (B, L, D) -> (B, L, D).  Optionally returns the decode state."""
    heads = cfg.mlstm_heads or cfg.num_heads
    up = x @ params["up_proj"]
    dv = up.shape[-1] // 2
    u, z = up[..., :dv], up[..., dv:]
    q, k, v, logi, logf = _qkv_gates(params, u, heads)
    y, (c, n, m) = mlstm_chunked(q, k, v, logi, logf,
                                 chunk=cfg.ssm_chunk or 128,
                                 return_final_state=True)
    y = y.reshape(*x.shape[:2], dv)
    y = rms_norm(y, params["norm_scale"], cfg.norm_eps) * jax.nn.silu(z)
    out = y @ params["down_proj"]
    if return_state:
        width = params["conv_w"].shape[0]
        state = {"conv": u[:, x.shape[1] - (width - 1):, :],
                 "c": c, "n": n, "m": m}
        return out, state
    return out


def mlstm_init_state(params, batch: int, cfg, d_model: int, dtype):
    heads = cfg.mlstm_heads or cfg.num_heads
    dv = int(cfg.mlstm_pf * d_model)
    dh = dv // heads
    width = params["conv_w"].shape[0]
    return {
        "conv": jnp.zeros((batch, width - 1, dv), dtype),
        "c": jnp.zeros((batch, heads, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, heads, dh), jnp.float32),
        "m": jnp.full((batch, heads), -jnp.inf, jnp.float32),
    }


def mlstm_decode_step(params, x: Array, state: dict, cfg):
    """x: (B, 1, D) -> (y (B, 1, D), new state)."""
    heads = cfg.mlstm_heads or cfg.num_heads
    b = x.shape[0]
    up = x[:, 0] @ params["up_proj"]
    dv = up.shape[-1] // 2
    u, z = up[..., :dv], up[..., dv:]
    dh = dv // heads

    hist = jnp.concatenate([state["conv"], u[:, None, :]], axis=1)
    conv = jax.nn.silu(jnp.einsum("bwc,wc->bc", hist, params["conv_w"])
                       + params["conv_b"])
    q = (conv @ params["wq"]).reshape(b, 1, heads, dh)
    k = ((conv @ params["wk"]) / (dh ** 0.5)).reshape(b, 1, heads, dh)
    v = (u @ params["wv"]).reshape(b, 1, heads, dh)
    gates = conv @ params["w_gates"] + params["b_gates"]
    logi = gates[..., :heads].astype(jnp.float32)[:, None, :]
    logf = jax.nn.log_sigmoid(
        gates[..., heads:].astype(jnp.float32))[:, None, :]

    y, (c, n, m) = mlstm_recurrent_ref(
        q, k, v, logi, logf, (state["c"], state["n"], state["m"]))
    y = y.reshape(b, dv)
    y = rms_norm(y, params["norm_scale"], cfg.norm_eps) * jax.nn.silu(z)
    out = (y @ params["down_proj"])[:, None, :]
    return out, {"conv": hist[:, 1:], "c": c, "n": n, "m": m}
