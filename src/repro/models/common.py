"""Shared building blocks: norms, RoPE, initializers, logical-axis params.

Params are plain pytrees of arrays.  Every initializer returns a matching
pytree of *logical axis names* (e.g. ("embed", "heads")) used by
`repro.launch.sharding` to build NamedShardings — the MaxText pattern, kept
framework-free.
"""
from __future__ import annotations

import math
from typing import Any, Sequence

import jax
import jax.numpy as jnp

Array = jax.Array
Params = Any
Specs = Any


def rms_norm(x: Array, scale: Array, eps: float = 1e-5) -> Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dt) * scale


def init_dense(key, shape: Sequence[int], axes: Sequence[str],
               dtype, scale: float | None = None):
    """Truncated-normal fan-in init + logical axes."""
    fan_in = shape[0] if len(shape) > 1 else shape[-1]
    std = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    w = (jax.random.truncated_normal(key, -2.0, 2.0, tuple(shape), jnp.float32)
         * std).astype(dtype)
    return w, tuple(axes)


def init_embed(key, vocab: int, d: int, dtype):
    w = (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)
    return w, ("vocab", "embed")


def init_scale(d: int, dtype):
    return jnp.ones((d,), dtype), ("norm",)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    half = x.shape[-1] // 2
    freqs = rope_freqs(x.shape[-1], theta)                      # (half,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., s, half)
    cos = jnp.cos(angles)[..., :, None, :]                      # (..., s, 1, half)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    return jnp.concatenate([o1, o2], axis=-1).astype(x.dtype)


def sinusoidal_positions(seq: int, d: int) -> Array:
    """Fixed sinusoidal embeddings (encoder stacks without RoPE)."""
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10000.0, dim / d)
    out = jnp.zeros((seq, d), jnp.float32)
    out = out.at[:, 0::2].set(jnp.sin(angle))
    out = out.at[:, 1::2].set(jnp.cos(angle[:, : (d - d // 2)]))
    return out


# ---------------------------------------------------------------------------
# Param-tree utilities
# ---------------------------------------------------------------------------

def split_tree(d: dict) -> tuple[dict, dict]:
    """Split a dict-of-(value, axes) into (params, specs), recursively."""
    params, specs = {}, {}
    for k, v in d.items():
        if isinstance(v, dict):
            params[k], specs[k] = split_tree(v)
        else:
            params[k], specs[k] = v
    return params, specs


def stack_layer_params(per_layer: list[Params]) -> Params:
    """Stack a list of identical param trees along a leading 'layers' axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *per_layer)


def stacked_specs(specs: Specs) -> Specs:
    """Prepend the (unsharded) 'layers' logical axis to every leaf spec."""
    return jax.tree.map(lambda ax: ("layers",) + tuple(ax), specs,
                        is_leaf=lambda x: isinstance(x, tuple))


def cast_tree(tree: Params, dtype) -> Params:
    return jax.tree.map(lambda x: x.astype(dtype)
                        if jnp.issubdtype(x.dtype, jnp.floating) else x, tree)


def count_params(tree: Params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(tree))
