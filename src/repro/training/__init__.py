"""Train / serve step builders."""
from repro.training.steps import (TrainConfig, init_train_state,
                                  make_decode_step, make_eval_step,
                                  make_prefill_step, make_train_step)
__all__ = ["TrainConfig", "init_train_state", "make_decode_step",
           "make_eval_step", "make_prefill_step", "make_train_step"]
