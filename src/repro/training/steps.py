"""Train / prefill / decode step builders (what launch/dryrun lowers).

`make_train_step` closes over (ModelConfig, OptimizerConfig) and returns the
pure function pjit compiles:

    train_step(params, opt_state, batch) -> (params, opt_state, metrics)

Microbatching (gradient accumulation) runs as a `lax.scan` over microbatch
slices, which also pipelines the DP gradient reduction behind the next
microbatch's compute under XLA's latency-hiding scheduler.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import lm_loss
from repro.models.config import ModelConfig
from repro.optim.optimizers import (OptimizerConfig, OptState, apply_updates,
                                    init_opt_state)

Array = jax.Array
PyTree = Any


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1     # gradient-accumulation steps per update
    loss_dtype: str = "float32"
    bf16_grads: bool = False  # differentiate wrt a bf16 copy of the params:
    # gradients (and their DP reductions) become bf16 — halves the dominant
    # grad-reduction collective payload; the f32 master update is unchanged
    # (§Perf hillclimb D1).


def make_loss_fn(cfg: ModelConfig) -> Callable:
    def loss_fn(params, batch):
        return lm_loss(params, cfg, batch)
    return loss_fn


def make_train_step(cfg: ModelConfig, opt_cfg: OptimizerConfig,
                    train_cfg: TrainConfig | None = None) -> Callable:
    train_cfg = train_cfg or TrainConfig()
    loss_fn = make_loss_fn(cfg)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def single_step(params, opt_state: OptState, batch):
        if train_cfg.bf16_grads:
            from repro.models.common import cast_tree
            (loss, metrics), grads = grad_fn(
                cast_tree(params, jnp.bfloat16), batch)
        else:
            (loss, metrics), grads = grad_fn(params, batch)
        params, opt_state, opt_metrics = apply_updates(
            opt_cfg, params, grads, opt_state)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return params, opt_state, metrics

    if train_cfg.microbatches <= 1:
        return single_step

    m = train_cfg.microbatches

    def accum_step(params, opt_state: OptState, batch):
        def slice_micro(i, x):
            mb = x.shape[0] // m
            return jax.lax.dynamic_slice_in_dim(x, i * mb, mb, axis=0)

        def body(carry, i):
            gsum, lsum = carry
            micro = jax.tree.map(lambda x: slice_micro(i, x), batch)
            (loss, _), grads = grad_fn(params, micro)
            gsum = jax.tree.map(jnp.add, gsum, grads)
            return (gsum, lsum + loss), None

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (gsum, lsum), _ = jax.lax.scan(body, (zeros, jnp.zeros(())),
                                       jnp.arange(m))
        grads = jax.tree.map(lambda g: g / m, gsum)
        params, opt_state, opt_metrics = apply_updates(
            opt_cfg, params, grads, opt_state)
        metrics = dict(loss=lsum / m, **opt_metrics)
        return params, opt_state, metrics

    return accum_step


def make_eval_step(cfg: ModelConfig) -> Callable:
    loss_fn = make_loss_fn(cfg)

    def eval_step(params, batch):
        loss, metrics = loss_fn(params, batch)
        return dict(metrics, loss=loss)

    return eval_step


def make_prefill_step(cfg: ModelConfig, max_len: int) -> Callable:
    from repro.models import prefill

    def prefill_step(params, tokens):
        return prefill(params, cfg, tokens, max_len)

    return prefill_step


def make_decode_step(cfg: ModelConfig) -> Callable:
    from repro.models import decode_step as _decode

    def serve_step(params, cache, token):
        return _decode(params, cfg, cache, token)

    return serve_step


def init_train_state(cfg: ModelConfig, opt_cfg: OptimizerConfig, key):
    from repro.models import init_params
    params, specs = init_params(cfg, key)
    opt_state = init_opt_state(opt_cfg, params)
    return params, opt_state, specs
