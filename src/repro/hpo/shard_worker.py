"""Entry point for a federation shard worker process.

    python -m repro.hpo.shard_worker --ckpt-dir <root>/shard-<i> \
        [--spec spec.json] [--host 0.0.0.0] [--port 7341]

Kept separate from `repro.hpo.transport` (which `repro.hpo` imports at
package load) so `-m` never re-executes an already-imported module.
See `repro.hpo.transport` for the protocol and `DESIGN.md` §14 for the
deployment shape (one worker per host, every store under one shared
root).
"""
import sys

from repro.hpo.transport import main

if __name__ == "__main__":
    sys.exit(main())
