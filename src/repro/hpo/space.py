"""Hyper-parameter search spaces (the paper's Sec. 4.2/4.3 domains).

Each dimension has a range and a scale ("linear" | "log"); the GP always
sees the unit cube (the BO driver normalizes), and `to_hparams` maps a unit
vector back to named values.  The paper's LeNet space (dropout keep probs,
lr, weight decay, momentum) and ResNet space (lr, wd, momentum) ship as
presets, plus the LM space the framework's own trainer exposes.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np


@dataclasses.dataclass(frozen=True)
class Dim:
    name: str
    lo: float
    hi: float
    scale: str = "linear"   # "linear" | "log"

    def to_value(self, u: float) -> float:
        u = min(max(float(u), 0.0), 1.0)
        if self.scale == "log":
            llo, lhi = math.log(self.lo), math.log(self.hi)
            return math.exp(llo + u * (lhi - llo))
        return self.lo + u * (self.hi - self.lo)

    def to_unit(self, v: float) -> float:
        if self.scale == "log":
            llo, lhi = math.log(self.lo), math.log(self.hi)
            return (math.log(v) - llo) / (lhi - llo)
        return (v - self.lo) / (self.hi - self.lo)


@dataclasses.dataclass(frozen=True)
class SearchSpace:
    dims: tuple[Dim, ...]

    @property
    def names(self) -> list[str]:
        return [d.name for d in self.dims]

    @property
    def dim(self) -> int:
        return len(self.dims)

    def to_hparams(self, u: np.ndarray) -> dict[str, float]:
        return {d.name: d.to_value(u[i]) for i, d in enumerate(self.dims)}

    def to_unit(self, hparams: dict[str, float]) -> np.ndarray:
        return np.asarray([d.to_unit(hparams[d.name]) for d in self.dims],
                          np.float32)

    def sample(self, rng: np.random.Generator, n: int) -> np.ndarray:
        return rng.uniform(0.0, 1.0, (n, self.dim)).astype(np.float32)


# --- presets (paper Sec. 4.2 / 4.3) ---------------------------------------

LENET_SPACE = SearchSpace((
    Dim("dropout1", 0.01, 1.0),
    Dim("dropout2", 0.01, 1.0),
    Dim("lr", 1e-4, 1e-1, "log"),
    Dim("weight_decay", 1e-6, 1e-3, "log"),
    Dim("momentum", 0.0, 0.99),
))

RESNET_SPACE = SearchSpace((
    Dim("lr", 1e-4, 1e-1, "log"),
    Dim("weight_decay", 1e-6, 1e-3, "log"),
    Dim("momentum", 0.0, 0.99),
))

LM_SPACE = SearchSpace((
    Dim("lr", 1e-4, 3e-2, "log"),
    Dim("weight_decay", 1e-4, 0.3, "log"),
    Dim("warmup_frac", 0.01, 0.4),
    Dim("b2", 0.9, 0.999),
))
