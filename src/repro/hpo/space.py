"""Hyper-parameter search spaces: typed dimensions over one unit cube.

The GP always sees the **encoded unit cube** (DESIGN.md §10): every
dimension contributes `width` unit coordinates —

  * `Float` (alias `Dim`) — one coordinate, "linear" or "log" scale (the
    paper's Sec. 4.2/4.3 domains are all Floats);
  * `Int` — one coordinate on the uniform lattice `{k / (L-1)}` for the
    L integer values `lo..hi` (linear scale);
  * `Categorical` — a one-hot block of `len(choices)` coordinates;
  * `Conditional` — wraps any of the above, active only when a parent
    `Categorical` takes a given choice; inactive children encode to the
    neutral 0-vector (the "collapse" convention, so the kernel sees no
    spurious distance between two points that both lack the child).

`SearchSpace.to_hparams` decodes an encoded unit vector to named values
(inactive conditionals decode to None); `to_unit` is the vectorized inverse
and **clamps** out-of-range values instead of extrapolating — a restored or
externally produced trial whose value sits at `hi + eps` must map to the
cube edge, not outside it.  `sample` draws *feasible* points (ints on the
lattice, exact one-hots, conditionals gated); `descriptor()` exports the
static per-coordinate `repro.core.descriptor.TypeDescriptor` the mixed
kernel and the acquisition's round-and-repair projection consume.

The paper's LeNet/ResNet presets and the framework's LM space ship below.
"""
from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp
import numpy as np

from repro.core import descriptor as desc_mod


def _clamp01(u: float) -> float:
    return min(max(float(u), 0.0), 1.0)


@dataclasses.dataclass(frozen=True)
class Dim:
    """A continuous dimension (the paper's only kind).  `Float` aliases it."""

    name: str
    lo: float
    hi: float
    scale: str = "linear"   # "linear" | "log"

    @property
    def width(self) -> int:
        return 1

    def to_value(self, u: float) -> float:
        u = _clamp01(u)
        if self.scale == "log":
            llo, lhi = math.log(self.lo), math.log(self.hi)
            return math.exp(llo + u * (lhi - llo))
        return self.lo + u * (self.hi - self.lo)

    def to_unit(self, v: float) -> float:
        # Clamp exactly like to_value: a value at hi + eps (float spill from
        # a restored/external trial) must map to the cube edge, not outside
        # it — an out-of-cube unit aborts the gateway's coalesced tell tick.
        v = min(max(float(v), self.lo), self.hi)
        if self.scale == "log":
            llo, lhi = math.log(self.lo), math.log(self.hi)
            return _clamp01((math.log(v) - llo) / (lhi - llo))
        return _clamp01((v - self.lo) / (self.hi - self.lo))

    def encode(self, v) -> np.ndarray:
        return np.asarray([self.to_unit(v)], np.float32)

    def decode(self, u: np.ndarray):
        return self.to_value(float(u[0]))


Float = Dim


@dataclasses.dataclass(frozen=True)
class Int:
    """An integer dimension `lo..hi` inclusive, encoded on the uniform unit
    lattice `{k / (L-1)}` (linear scale; L = hi - lo + 1 levels)."""

    name: str
    lo: int
    hi: int

    def __post_init__(self):
        if int(self.lo) != self.lo or int(self.hi) != self.hi:
            raise ValueError(f"Int {self.name}: bounds must be integers")
        if self.hi < self.lo:
            raise ValueError(f"Int {self.name}: hi {self.hi} < lo {self.lo}")

    @property
    def width(self) -> int:
        return 1

    @property
    def levels(self) -> int:
        return int(self.hi) - int(self.lo) + 1

    def to_value(self, u: float) -> int:
        u = _clamp01(u)
        return int(self.lo) + int(round(u * (self.levels - 1)))

    def to_unit(self, v) -> float:
        k = min(max(int(round(float(v))), int(self.lo)), int(self.hi))
        if self.levels == 1:
            return 0.0
        return (k - int(self.lo)) / (self.levels - 1)

    def encode(self, v) -> np.ndarray:
        return np.asarray([self.to_unit(v)], np.float32)

    def decode(self, u: np.ndarray) -> int:
        return self.to_value(float(u[0]))


@dataclasses.dataclass(frozen=True)
class Categorical:
    """An unordered choice, encoded one-hot (`width = len(choices)`).

    Decoding takes the argmax of the block (first index wins ties — the
    same deterministic rule as the acquisition's projection)."""

    name: str
    choices: tuple

    def __post_init__(self):
        if len(self.choices) < 2:
            raise ValueError(
                f"Categorical {self.name}: needs >= 2 choices")
        if len(set(self.choices)) != len(self.choices):
            raise ValueError(f"Categorical {self.name}: duplicate choices")
        # Choices must survive the JSON round-trip of the gateway registry
        # (a tuple choice would serialize as a list and make the committed
        # checkpoint unrestorable) — fail at construction, not at recovery.
        for c in self.choices:
            if not isinstance(c, (str, int, float, bool)):
                raise ValueError(
                    f"Categorical {self.name}: choice {c!r} is not a JSON "
                    "primitive (str/int/float/bool); composite choices "
                    "would not survive a checkpoint round-trip")

    @property
    def width(self) -> int:
        return len(self.choices)

    def encode(self, v) -> np.ndarray:
        u = np.zeros((self.width,), np.float32)
        u[self.choices.index(v)] = 1.0
        return u

    def decode(self, u: np.ndarray):
        return self.choices[int(np.argmax(u))]


@dataclasses.dataclass(frozen=True)
class Conditional:
    """A dimension active only when `parent` (a Categorical appearing
    earlier in the space) equals `when`; inactive values decode to None and
    encode to the neutral 0-vector."""

    inner: "Dim | Int | Categorical"
    parent: str
    when: object

    def __post_init__(self):
        if isinstance(self.inner, Conditional):
            raise ValueError("Conditional dims cannot nest (one-level "
                             "parent gating only)")

    @property
    def name(self) -> str:
        return self.inner.name

    @property
    def width(self) -> int:
        return self.inner.width

    def encode(self, v) -> np.ndarray:
        if v is None:
            return np.zeros((self.width,), np.float32)
        return self.inner.encode(v)

    def decode(self, u: np.ndarray):
        return self.inner.decode(u)


AnyDim = "Dim | Int | Categorical | Conditional"


# --- serialization (the gateway registry rides the pool checkpoint) --------

_DIM_TYPES = {"float": Dim, "int": Int, "categorical": Categorical,
              "conditional": Conditional}


def dim_to_dict(d) -> dict:
    """JSON-serializable form of any dim (inverse: `dim_from_dict`)."""
    if isinstance(d, Conditional):
        return {"type": "conditional", "parent": d.parent, "when": d.when,
                "inner": dim_to_dict(d.inner)}
    if isinstance(d, Categorical):
        return {"type": "categorical", "name": d.name,
                "choices": list(d.choices)}
    if isinstance(d, Int):
        return {"type": "int", "name": d.name, "lo": int(d.lo),
                "hi": int(d.hi)}
    return {"type": "float", "name": d.name, "lo": d.lo, "hi": d.hi,
            "scale": d.scale}


def dim_from_dict(rec: dict):
    """Rebuild a dim from its dict form.  Dicts without a "type" tag are
    pre-typed-space checkpoints: plain float Dims."""
    kind = rec.get("type", "float")
    if kind == "conditional":
        return Conditional(dim_from_dict(rec["inner"]), rec["parent"],
                           rec["when"])
    if kind == "categorical":
        return Categorical(rec["name"], tuple(rec["choices"]))
    if kind == "int":
        return Int(rec["name"], rec["lo"], rec["hi"])
    return Dim(rec["name"], rec["lo"], rec["hi"],
               rec.get("scale", "linear"))


def space_to_dicts(space: "SearchSpace") -> list[dict]:
    return [dim_to_dict(d) for d in space.dims]


def space_from_dicts(recs: list[dict]) -> "SearchSpace":
    return SearchSpace(tuple(dim_from_dict(r) for r in recs))


@dataclasses.dataclass(frozen=True)
class SearchSpace:
    dims: tuple

    def __post_init__(self):
        names = [d.name for d in self.dims]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate dim names: {names}")
        cats: dict[str, Categorical] = {}
        for d in self.dims:
            if isinstance(d, Conditional):
                parent = cats.get(d.parent)
                if parent is None:
                    raise ValueError(
                        f"Conditional {d.name}: parent {d.parent!r} must be "
                        "an (unconditional) Categorical appearing earlier "
                        "in the space")
                if d.when not in parent.choices:
                    raise ValueError(
                        f"Conditional {d.name}: {d.when!r} is not a choice "
                        f"of {d.parent!r} {parent.choices}")
            elif isinstance(d, Categorical):
                cats[d.name] = d

    @property
    def names(self) -> list[str]:
        return [d.name for d in self.dims]

    @property
    def dim(self) -> int:
        """Width of the encoded unit cube (what the GP sees)."""
        return sum(d.width for d in self.dims)

    @property
    def has_discrete(self) -> bool:
        return any(not isinstance(d, Dim) for d in self.dims)

    def _offsets(self) -> list[int]:
        offs, o = [], 0
        for d in self.dims:
            offs.append(o)
            o += d.width
        return offs

    def to_hparams(self, u: np.ndarray) -> dict:
        """Decode an encoded unit vector to {name: value}.  Inactive
        conditional dims decode to None (every name is always a key)."""
        u = np.asarray(u)
        out: dict = {}
        for d, o in zip(self.dims, self._offsets()):
            if isinstance(d, Conditional) and out.get(d.parent) != d.when:
                out[d.name] = None
            else:
                out[d.name] = d.decode(u[o:o + d.width])
        return out

    def to_unit(self, hparams: dict) -> np.ndarray:
        """Encode named values to the unit cube (vectorized inverse of
        `to_hparams`; clamps out-of-range values — see module docstring).
        Conditional dims whose parent choice doesn't match (or that are
        absent/None) encode to the neutral 0-block."""
        parts = []
        for d in self.dims:
            if isinstance(d, Conditional):
                v = hparams.get(d.name)
                if hparams.get(d.parent) != d.when:
                    v = None
                parts.append(d.encode(v))
            else:
                parts.append(d.encode(hparams[d.name]))
        return np.concatenate(parts).astype(np.float32)

    def sample(self, rng: np.random.Generator, n: int) -> np.ndarray:
        """n feasible encoded points.  One uniform draw over the encoded
        cube (bit-identical to the pre-typed-space stream on all-Float
        spaces) followed by the host-side round-and-repair projection."""
        u = rng.uniform(0.0, 1.0, (n, self.dim)).astype(np.float32)
        return self.project(u)

    def project(self, u: np.ndarray) -> np.ndarray:
        """Host-side (numpy) round-and-repair onto the feasible lattice —
        the same three passes as `descriptor.project_units`, so device and
        host agree on what "feasible" means."""
        u = np.asarray(u, np.float32)
        batched = u.ndim == 2
        u = np.atleast_2d(u).copy()
        for d, o in zip(self.dims, self._offsets()):
            inner = d.inner if isinstance(d, Conditional) else d
            sl = slice(o, o + d.width)
            if isinstance(inner, Int):
                lev = inner.levels
                u[:, o] = np.round(u[:, o] * (lev - 1)) / max(lev - 1, 1)
            elif isinstance(inner, Categorical):
                best = np.argmax(u[:, sl], axis=1)
                u[:, sl] = 0.0
                u[np.arange(u.shape[0]), o + best] = 1.0
        for d, o in zip(self.dims, self._offsets()):
            if isinstance(d, Conditional):
                po, _ = self._parent_coord(d)
                u[:, o:o + d.width] *= u[:, po:po + 1]
        return u if batched else u[0]

    def _parent_coord(self, d: Conditional) -> tuple[int, Categorical]:
        """Encoded index of the parent choice's one-hot coordinate."""
        for p, o in zip(self.dims, self._offsets()):
            if isinstance(p, Categorical) and p.name == d.parent:
                return o + p.choices.index(d.when), p
        raise ValueError(f"no Categorical parent {d.parent!r}")  # unreachable

    def descriptor(self) -> desc_mod.TypeDescriptor:
        """The static per-coordinate type descriptor (DESIGN.md §10)."""
        dim = self.dim
        cont = np.ones((dim,), np.float32)
        cat = np.zeros((dim,), np.float32)
        levels = np.zeros((dim,), np.float32)
        group = np.full((dim,), -1, np.int32)
        parent = np.full((dim,), -1, np.int32)
        for d, o in zip(self.dims, self._offsets()):
            inner = d.inner if isinstance(d, Conditional) else d
            if isinstance(inner, Int):
                levels[o] = inner.levels
            elif isinstance(inner, Categorical):
                cont[o:o + d.width] = 0.0
                cat[o:o + d.width] = 1.0
                group[o:o + d.width] = o
            if isinstance(d, Conditional):
                parent[o:o + d.width] = self._parent_coord(d)[0]
        return desc_mod.TypeDescriptor(
            cont_mask=jnp.asarray(cont), cat_mask=jnp.asarray(cat),
            levels=jnp.asarray(levels), group=jnp.asarray(group),
            parent=jnp.asarray(parent))


# --- presets (paper Sec. 4.2 / 4.3) ---------------------------------------

LENET_SPACE = SearchSpace((
    Dim("dropout1", 0.01, 1.0),
    Dim("dropout2", 0.01, 1.0),
    Dim("lr", 1e-4, 1e-1, "log"),
    Dim("weight_decay", 1e-6, 1e-3, "log"),
    Dim("momentum", 0.0, 0.99),
))

RESNET_SPACE = SearchSpace((
    Dim("lr", 1e-4, 1e-1, "log"),
    Dim("weight_decay", 1e-6, 1e-3, "log"),
    Dim("momentum", 0.0, 0.99),
))

LM_SPACE = SearchSpace((
    Dim("lr", 1e-4, 3e-2, "log"),
    Dim("weight_decay", 1e-4, 0.3, "log"),
    Dim("warmup_frac", 0.01, 0.4),
    Dim("b2", 0.9, 0.999),
))

# A mixed-space exemplar (beyond-paper, DESIGN.md §10): real HPO traffic is
# dominated by integer and categorical choices (Snoek et al. 2012).
MIXED_DEMO_SPACE = SearchSpace((
    Dim("lr", 1e-4, 1e-1, "log"),
    Int("depth", 2, 8),
    Categorical("optimizer", ("sgd", "adam", "rmsprop")),
    Conditional(Dim("momentum", 0.0, 0.99), parent="optimizer", when="sgd"),
))
