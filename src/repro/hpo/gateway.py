"""Async ask–tell serving gateway: many concurrent clients, one fused round.

`StudyGateway` is the traffic-facing layer of the stack (DESIGN.md §9): it
multiplexes an unbounded population of *logical* studies onto one
`StudyPool`/`StudyEngine` with a fixed number of resident *slots* in the
stacked `(S, …)` state.  Three mechanisms make that serve:

  * **coalescing tick** — concurrent `ask()`s (and queued `tell()`s) are
    gathered for a configurable window and served by ONE fused
    `pool.advance_round` dispatch: the masked absorb of every queued
    completion and the batched EI suggest for every asking study run in a
    single jitted program per tick, not one program per caller.  Batched
    `ask(sid, q=N)` requests coalesce with the same tick: each is served
    by one fused q-suggestion dispatch (`pool.ask_q` — the qEI fantasy
    scan of DESIGN.md §12) right after the round's absorbs, so a q=32 ask
    costs one dispatch, not 32 ticks.  Fantasy rows pin their study
    resident until every suggestion is told back (rollback is exact, but
    eviction snapshots must see only real observations).
  * **slot lifecycle** — `create_study` registers a logical study without
    claiming a slot; the first `ask` allocates one (free-list).  When slots
    run out, the least-recently-used *idle* resident study (nothing in
    flight, nothing queued) is evicted to a per-study partial snapshot
    (`checkpoint.save_study`) and transparently restored on its next `ask`
    — the pool serves more logical studies than resident slots.  Eviction
    is exact: the slot swap is an elementwise scatter and the vmapped lanes
    are independent, so an evicted-and-restored study produces bitwise-
    identical suggestions to one that stayed resident (test-enforced).
  * **admission control** — bounded ask queue, per-study in-flight caps,
    and a capacity-aware reject: an `ask` whose eventual `tell` could not
    fit the study's `(n_max, …)` buffers is refused up front with
    `GPCapacityError` (the same error the absorb path raises), never after
    the client has already trained a model.

`tell` routes through the existing masked-absorb path (`advance_round` /
`absorb_many`), so the all-or-nothing capacity contract and the per-study
PRNG persistence of PRs 1–3 carry over unchanged; per-study random streams
are seeded by *logical* id, so what a tenant is suggested never depends on
which slot it lands in.

The gateway is asyncio-native and single-threaded: `ask` is a coroutine,
`tell` a plain enqueue, and one background ticker task drives the rounds.
Synchronous callers (tests, benchmarks) can instead call `tick()` directly
for deterministic control.  Telemetry per tick (coalesce width, queue
depth, latency, evictions) accumulates in `gateway.stats`.
"""
from __future__ import annotations

import asyncio
import dataclasses
import json
import time
from collections import deque
from typing import Sequence

import numpy as np

from repro import checkpoint as ckpt_mod
from repro.core.gp import (BackpressureError, GPCapacityError,
                           StudySaturatedError)
from repro.hpo.pool import SchedulerConfig, StudyPool, Trial
from repro.hpo.space import SearchSpace, space_from_dicts, space_to_dicts

__all__ = ["GatewayConfig", "StudyGateway"]


@dataclasses.dataclass(frozen=True)
class GatewayConfig:
    """Serving-layer knobs (the GP/pool shape comes from SchedulerConfig)."""

    slots: int = 8            # resident studies (the stacked S axis)
    coalesce_ms: float = 0.0  # tick gathering window; 0 = one event-loop
    # yield (everything already enqueued by runnable clients coalesces)
    max_batch: int = 0        # asks served per tick (0 = no cap)
    max_queue: int = 1024     # queued asks across all studies (admission)
    max_inflight: int = 4     # per-study suggestions outstanding (ask - tell)
    stats_window: int = 4096  # per-tick telemetry records retained
    ckpt_every_ticks: int = 0  # whole-gateway snapshot cadence (0 = only
    # explicit checkpoint() calls).  The pool's own per-absorb cadence is
    # disabled under a gateway: a bare pool snapshot has no gateway
    # registry and could shadow a restorable one.
    pipeline: bool = True     # double-buffer the ticker (DESIGN.md §13):
    # stage tick t+1's host-side gather/validation/dispatch while tick t's
    # fused round is still in flight on the device, finishing t afterwards.
    # Residency changes, q>1 asks, and checkpoints flush the pipeline first
    # (they would otherwise race the donated dispatch); the staged device
    # program stream is identical either way, so pipeline on/off produce
    # bitwise-identical pool state for the same traffic trace
    # (test-enforced).  Off = every tick is served start-to-finish like
    # the sync tick().
    escalate: bool = True     # saturation escalation (DESIGN.md §15): when
    # a study's lazy-GP slot fills (committed == n_max), promote it to the
    # neural-basis tier (MLP feature map + exact Bayesian linear head,
    # flat per-append cost) instead of rejecting every further ask with
    # StudySaturatedError.  Off = the pre-§15 terminal-capacity contract.


@dataclasses.dataclass
class _Logical:
    """Gateway-side record of one logical study (resident or evicted)."""

    sid: int
    name: str
    space: SearchSpace
    seed: int
    slot: int | None = None   # resident slot, None = evicted / never placed
    n_obs: int = 0            # absorbed observations (survives eviction)
    best_value: float | None = None  # max told value (residency-independent
    # — the resident ledger leaves with the study on eviction)
    inflight: int = 0         # suggestions handed out, not yet told back
    pending_asks: int = 0
    pending_tells: int = 0
    last_tick: int = 0        # LRU stamp
    version: int = 0          # eviction snapshot counter (monotonic)
    evicted_ever: bool = False
    tier: int = 0             # 0 = lazy GP, 1 = neural basis (escalated
    # past n_max, DESIGN.md §15).  Mirrors the pool/engine tier tag but
    # survives eviction: the NB state itself rides the study's partial
    # snapshot metadata.


@dataclasses.dataclass
class _PendingTick:
    """A staged-but-unfinished coalesced tick (pipelined serving, §13).

    Holds everything `_tick_finish` needs to commit the round once the
    in-flight device program materializes: the popped queues, the slot
    placements, and the pool's pending round handle.
    """

    round: object                 # pool._PendingRound
    tells: list                   # (sid, Trial, value) popped this tick
    take: list                    # (sid, fut, q) being served this tick
    events: list                  # (slot, Trial, value) placed tells
    ask_slots: dict               # sid -> slot
    deferred: int                 # asks that could not place (requeued)
    t0: float
    evictions: int
    restores: int

    @property
    def size(self) -> int:
        return len(self.take) + len(self.events)


class StudyGateway:
    """Asynchronous ask–tell front end over one multi-tenant StudyPool."""

    def __init__(self, template_space: SearchSpace, cfg: SchedulerConfig,
                 gw: GatewayConfig | None = None):
        self.gw = gw or GatewayConfig()
        if self.gw.slots < 1:
            raise ValueError("GatewayConfig.slots must be >= 1")
        if cfg.ckpt_dir is None:
            # Eviction needs somewhere to put the partial snapshots; the
            # whole-pool cadence can still be disabled via ckpt_every.
            raise ValueError(
                "StudyGateway needs SchedulerConfig.ckpt_dir (the eviction "
                "store for per-study partial snapshots)")
        self.cfg = cfg
        self._template_space = template_space  # default for create_study;
        # slot 0's handle can't serve as the template — reset/import
        # overwrite it with whatever tenant lands there
        # The pool's per-absorb snapshot cadence is disabled: its snapshots
        # would lack the gateway registry (see GatewayConfig.ckpt_every_ticks
        # for the gateway-level cadence).
        self.pool = StudyPool(
            [template_space] * self.gw.slots,
            dataclasses.replace(cfg, ckpt_every=10 ** 9))
        self._free: list[int] = list(range(self.gw.slots - 1, -1, -1))
        self._owner: list[int | None] = [None] * self.gw.slots
        self._studies: dict[int, _Logical] = {}
        self._closed_sids: set[int] = set()   # tombstones: closed studies
        # leave the registry (and, at the next checkpoint commit, the
        # eviction store) so tenant churn doesn't grow either unboundedly
        self._closed_gc: list[str] = []       # snapshot dirs to drop at
        # the next checkpoint COMMIT (never before — a crash must restore
        # a registry whose studies are all still on disk)
        self._next_sid = 0
        self._asks: deque[tuple[int, asyncio.Future | None, int]] = deque()
        self._tells: list[tuple[int, Trial, float]] = []
        self._tick_count = 0
        self.stats: deque[dict] = deque(maxlen=self.gw.stats_window)
        # lifetime counters: the stats deque is a WINDOW (stats_window
        # ticks) — run totals must not silently shrink past it.  The
        # q-width histogram maps str(q) -> asks served at that width
        # (string keys so it round-trips the JSON registry unchanged);
        # fantasy_rollbacks mirrors the pool's counter into a lifetime
        # total that survives checkpoint/restore.
        self._totals = {"asks_served": 0, "absorbed": 0,
                        "evictions": 0, "restores": 0,
                        "fantasy_rollbacks": 0, "q_width_hist": {}}
        self._pool_rollbacks_seen = 0
        self._wake: asyncio.Event | None = None
        self._tick_done: asyncio.Event | None = None  # pulsed per tick
        # attempt so drain() waiters re-check instead of busy-polling
        self._ticker: asyncio.Task | None = None
        self._closed = False
        self._restores_this_tick = 0
        self._evictions_this_tick = 0
        self._retry_absorb = False
        self._pending: _PendingTick | None = None  # at most ONE staged
        # tick in flight (depth-1 double buffering, DESIGN.md §13)
        # Tells that can never be absorbed (study at capacity) land here
        # instead of poisoning the queue forever; the trial records the
        # error.
        self.dead_tells: list[tuple[int, Trial, float]] = []

    # -- lifecycle ----------------------------------------------------------
    def create_study(self, space: SearchSpace | None = None,
                     name: str | None = None, sid: int | None = None) -> int:
        """Register a logical study; no slot is claimed until its first ask.

        Random streams are seeded `cfg.seed + logical_id`, so two gateways
        with the same creation order serve identical suggestion streams
        regardless of slot churn.  A federation front end passes an
        explicit `sid` from its GLOBAL id space (DESIGN.md §13): shards
        then seed by global identity, so WHERE a study is routed never
        changes WHAT it is suggested — the single-pool-equivalence
        contract.  Explicit sids must be fresh (never used or closed on
        this shard).
        """
        space = space if space is not None else self._template_space
        if space.dim != self.pool.engine.gp_cfg.dim:
            raise ValueError(
                f"space dim {space.dim} != gateway dim "
                f"{self.pool.engine.gp_cfg.dim} (the stacked buffers are "
                "rectangular)")
        if space.has_discrete and not self.pool.engine.mixed:
            raise ValueError(
                "space has int/categorical dims but the gateway was built "
                "without mixed-space closures; construct it with a mixed "
                "template space or SchedulerConfig(mixed=True)")
        if sid is None:
            sid = self._next_sid
        elif sid in self._studies or sid in self._closed_sids:
            raise ValueError(f"study id {sid} already used on this gateway")
        self._next_sid = max(self._next_sid, sid + 1)
        self._studies[sid] = _Logical(
            sid, name if name is not None else f"study{sid}", space,
            seed=self.cfg.seed + sid)
        return sid

    def close_study(self, sid: int) -> None:
        """Release a study's slot and drop it from the registry.  Refuses
        while work is in flight.  Its snapshots are deleted at the next
        checkpoint commit (not before: a crash must restore a registry
        whose studies are all still on disk)."""
        log = self._require(sid)
        if log.inflight or log.pending_asks or log.pending_tells:
            raise RuntimeError(
                f"study {sid} has work in flight "
                f"(inflight={log.inflight}, asks={log.pending_asks}, "
                f"tells={log.pending_tells}); tell or drain first")
        if log.slot is not None:
            self._owner[log.slot] = None
            self._free.append(log.slot)
            log.slot = None
        self._closed_sids.add(sid)
        if log.evicted_ever:
            self._closed_gc.append(self._study_key(log))
        del self._studies[sid]
        if self._wake is not None:
            self._wake.set()  # the freed slot may unblock a deferred ask

    def _require(self, sid: int) -> _Logical:
        if sid in self._closed_sids:
            raise RuntimeError(f"study {sid} is closed")
        log = self._studies.get(sid)
        if log is None:
            raise KeyError(f"unknown study id {sid}")
        return log

    # -- admission control --------------------------------------------------
    def _admit_ask(self, log: _Logical, q: int = 1) -> None:
        if self._closed:
            raise RuntimeError("gateway is shut down")
        if q < 1:
            raise ValueError(f"ask q must be >= 1, got {q}")
        if q > self.gw.max_inflight:
            # Reject the impossible width HERE, loudly: queueing it would
            # hand the client a future that can never be woken (the
            # in-flight budget can't clear below zero to make room).
            raise GPCapacityError(
                f"ask(q={q}) exceeds the per-study in-flight cap "
                f"max_inflight={self.gw.max_inflight}: such an ask could "
                "never be served; lower q or raise "
                "GatewayConfig.max_inflight")
        if len(self._asks) >= self.gw.max_queue:
            raise BackpressureError(
                f"gateway ask queue full ({self.gw.max_queue} queued); "
                "backpressure — retry after the next tick")
        if log.inflight + log.pending_asks + q > self.gw.max_inflight:
            raise BackpressureError(
                f"study {log.sid} ({log.name}): ask(q={q}) with "
                f"{log.inflight + log.pending_asks} suggestions already "
                f"in flight exceeds max_inflight={self.gw.max_inflight}; "
                "tell() results back before asking again")
        # Capacity-aware reject: every outstanding suggestion implies a
        # future observation (a q-ask implies q of them, each shadowed by
        # a fantasy row until told).  Refuse the ask now rather than fail
        # the tell after the client has spent a training run on it.
        # Escalated studies (and, with `escalate` on, studies that WILL be
        # promoted when this ask is served — see `_needs_escalation`) have
        # no n_max: the NB ledger doubles instead of filling.  Promotion
        # needs at least one real observation to train on, so a study that
        # never absorbed anything keeps the terminal contract.
        if log.tier:
            return
        committed = (log.n_obs + log.inflight + log.pending_asks
                     + log.pending_tells)
        if committed + q > self.cfg.n_max and not (
                self.gw.escalate and log.n_obs > 0):
            raise StudySaturatedError(
                f"study {log.sid} ({log.name}): n={log.n_obs} absorbed + "
                f"{committed - log.n_obs} outstanding + q={q} would exceed "
                f"n_max={self.cfg.n_max}")

    # -- ask / tell ---------------------------------------------------------
    async def ask(self, sid: int, q: int = 1) -> Trial | list[Trial]:
        """Request suggestions; resolves at the next coalesced tick.

        `q=1` (the default) returns one Trial.  `q>1` returns a list of q
        jointly-diverse Trials from ONE fused qEI fantasy dispatch: each
        suggestion is made against a posterior that pretends the previous
        ones were already observed (constant/believer liar per
        `SchedulerConfig.fantasy`), so the batch spreads instead of
        stacking q copies of the same argmax.  The fantasy rows roll back
        bitwise-exactly as the real tells arrive."""
        log = self._require(sid)
        self._admit_ask(log, q)
        loop = asyncio.get_running_loop()
        self._ensure_ticker(loop)
        fut: asyncio.Future = loop.create_future()
        self._asks.append((sid, fut, q))
        log.pending_asks += q
        self._wake.set()
        return await fut

    def ask_nowait(self, sid: int, q: int = 1) -> None:
        """Queue an ask without a future (drive with `tick()`; the
        suggestions land in the study's ledger).  For sync callers/tests."""
        log = self._require(sid)
        self._admit_ask(log, q)
        self._asks.append((sid, None, q))
        log.pending_asks += q
        if self._wake is not None:
            self._wake.set()

    def _check_unit(self, trial: Trial, space: SearchSpace) -> None:
        """Validate a told trial's unit vector at the caller, not inside
        the fused round: a malformed unit raising mid-dispatch would abort
        the whole coalesced tick for every study in it.  Mixed spaces also
        require the unit to sit on the study's feasible lattice (exact
        one-hots, ints on their grid) — an off-lattice row would teach the
        GP covariances no suggestion can ever reproduce."""
        unit = np.asarray(trial.unit)
        dim = self.pool.engine.gp_cfg.dim
        if unit.shape != (dim,):
            raise ValueError(
                f"trial unit shape {unit.shape} != ({dim},)")
        if not np.all(np.isfinite(unit)) or unit.min() < 0.0 \
                or unit.max() > 1.0:
            raise ValueError(
                f"trial unit must be finite in [0, 1]^{dim}, got {unit}")
        if space.has_discrete:
            repaired = space.project(unit)
            if not np.allclose(repaired, unit, atol=1e-5):
                raise ValueError(
                    f"trial unit {unit} is off the feasible lattice of its "
                    f"mixed space (round-and-repair gives {repaired}); "
                    "encode values with space.to_unit")

    def tell(self, sid: int, trial: Trial, value: float,
             cost: float = 1.0) -> None:
        """Report a result; absorbed by the next tick's fused round.

        `cost` (default 1.0) is the observation's evaluation cost (wall
        seconds, GPU-hours — any positive unit, consistent per study): it
        rides the trial into the ledger and trains the escalated tier's
        log-cost head, the denominator of EI-per-unit-cost acquisition
        (DESIGN.md §15).

        Rejected at the caller (never inside the fused round, where one bad
        input would abort the whole tick): wrong-dim units, non-finite
        values (report divergence via `tell_failure` instead — a NaN row
        would silently poison the posterior), and replays of a trial that
        already resolved (each suggestion takes exactly one tell)."""
        log = self._require(sid)
        if trial.status not in ("pending", "running"):
            raise RuntimeError(
                f"trial {trial.trial_id} of study {sid} was already told "
                f"({trial.status}); each suggestion takes exactly one tell")
        self._check_unit(trial, log.space)
        value = float(value)
        if not np.isfinite(value):
            raise ValueError(
                f"non-finite objective value {value!r}; report crashes "
                "and divergence via tell_failure()")
        cost = float(cost)
        if not np.isfinite(cost) or cost <= 0.0:
            raise ValueError(
                f"tell cost must be a positive finite number, got {cost!r}")
        trial.cost = cost
        # "told" blocks a same-window replay (the absorb flips it to
        # "done" once the append commits)
        trial.status = "told"
        self._tells.append((sid, trial, value))
        log.pending_tells += 1
        log.inflight = max(0, log.inflight - 1)
        if self._wake is not None:
            self._wake.set()

    def tell_failure(self, sid: int, trial: Trial, error: str) -> None:
        """Report a failed trial.  The ledger records the fault; with
        `cfg.failure_penalty` set, a penalty pseudo-observation is queued
        through the same coalesced absorb path (keeping EI away from the
        crashing region).  Retry policy is the client's: ask again."""
        log = self._require(sid)
        if self.cfg.failure_penalty is not None:
            self._check_unit(trial, log.space)
        trial.status = "failed"
        trial.error = error
        trial.finished = time.time()
        log.inflight = max(0, log.inflight - 1)
        if self.cfg.failure_penalty is None and log.slot is not None:
            # No penalty tell will ever come for this trial: if it was a
            # q-ask suggestion its fantasy row must be released now, or it
            # would pin the study non-evictable (and hold buffer capacity)
            # forever.  With a penalty configured, the penalty tell's
            # absorb performs the same rollback through the normal path.
            self.pool.release_fantasies(log.slot,
                                        [np.asarray(trial.unit)])
        if self.cfg.failure_penalty is not None:
            penalty = Trial(trial.trial_id, trial.unit, trial.hparams,
                            cost=trial.cost)
            # the error tag marks this as a pseudo-observation: it enters
            # the GP through the normal absorb path but must never be
            # reported as the study's best (failure_penalty=0.0 would beat
            # every genuine negative objective)
            penalty.error = f"failure penalty ({error})"
            self._tells.append((sid, penalty, self.cfg.failure_penalty))
            log.pending_tells += 1
        if self._wake is not None:
            # wake even without a penalty tell: the freed in-flight budget
            # may make this study evictable and unblock a deferred ask
            self._wake.set()

    # -- slot residency / eviction ------------------------------------------
    def _study_key(self, log: _Logical) -> str:
        return f"study{log.sid:06d}"

    def _evictable(self, log: _Logical) -> bool:
        # fantasy-pinned: pending fantasy rows mean suggestions are still
        # outstanding from a q-ask — export_study would refuse anyway
        # (snapshots must hold only real observations), so such a study
        # is never an eviction candidate
        return (log.slot is not None and not log.inflight
                and not log.pending_asks and not log.pending_tells
                and not self.pool.fantasy_active(log.slot))

    def _evict_lru(self) -> int:
        """Evict the least-recently-used *idle* resident study, returning
        its slot.  Studies with anything in flight or queued this tick are
        never candidates (their pending counters pin them resident)."""
        # scan the SLOT map, not the whole logical registry: candidates
        # are resident by definition, so this is O(slots) regardless of
        # how many logical studies have ever been created
        candidates = [self._studies[sid] for sid in self._owner
                      if sid is not None
                      and self._evictable(self._studies[sid])]
        if not candidates:
            raise GPCapacityError(
                f"all {self.gw.slots} slots are busy (studies with work in "
                "flight cannot be evicted); raise GatewayConfig.slots or "
                "tell() outstanding results back")
        victim = min(candidates, key=lambda l: (l.last_tick, l.sid))
        return self._evict(victim)

    def _evict(self, log: _Logical) -> int:
        """Snapshot one resident study to the eviction store, free its slot.

        The snapshot commits BEFORE any bookkeeping changes: a failed write
        raises with the study still resident and serving (and any prior
        committed snapshot still the restore target)."""
        slot = log.slot
        snap = self.pool.export_study(slot)
        ckpt_mod.save_study(self.cfg.ckpt_dir, self._study_key(log),
                            log.version + 1, snap["tree"],
                            metadata={"handle": json.dumps(snap["meta"]),
                                      "sid": log.sid, "n_obs": log.n_obs})
        log.version += 1
        log.slot = None
        log.evicted_ever = True
        self._owner[slot] = None
        # lifetime total counts here, not at tick commit: the snapshot is
        # a durable side effect even if the tick later aborts
        self._evictions_this_tick += 1
        self._totals["evictions"] += 1
        return slot

    def _ensure_resident(self, sid: int) -> int:
        """Give study `sid` a slot: free-list pop, else LRU eviction; then
        restore-on-demand from its latest partial snapshot (or a blank
        state if it never held one)."""
        log = self._require(sid)
        if log.slot is not None:
            return log.slot
        slot = self._free.pop() if self._free else self._evict_lru()
        if log.evicted_ever:
            like = dataclasses.asdict(self.pool.engine.study_state(slot))
            # version-exact: after a crash/restore, snapshots NEWER than the
            # registry's version exist (written by the lost timeline) and
            # must not leak future state into the recovered one
            out = ckpt_mod.restore_study(self.cfg.ckpt_dir,
                                         self._study_key(log), like,
                                         version=log.version)
            if out is None:
                raise RuntimeError(
                    f"study {sid} was evicted but snapshot version "
                    f"{log.version} is not committed under "
                    f"{self.cfg.ckpt_dir}")
            _, tree, meta = out
            self.pool.import_study(slot, tree,
                                   json.loads(meta["handle"]),
                                   space=log.space)
            self._restores_this_tick += 1
            self._totals["restores"] += 1
        else:
            self.pool.reset_study(slot, space=log.space, name=log.name,
                                  seed=log.seed)
        log.slot = slot
        self._owner[slot] = sid
        return slot

    def _try_resident(self, sid: int) -> int | None:
        """Best-effort residency: None when every slot is pinned (the ask
        defers to a later tick instead of failing)."""
        try:
            return self._ensure_resident(sid)
        except GPCapacityError:
            return None

    # -- saturation escalation (DESIGN.md §15) ------------------------------
    def _needs_escalation(self, log: _Logical, q: int) -> bool:
        """True when serving a q-wide ask for this study could not fit its
        lazy-GP buffers: every absorbed row, outstanding suggestion (each
        shadowed by a fantasy row), and queued tell claims a row, and the
        ask adds q more."""
        return (self.gw.escalate and log.tier == 0 and log.n_obs > 0
                and (log.n_obs + log.inflight + log.pending_tells + q
                     > self.cfg.n_max))

    def _promote(self, log: _Logical) -> None:
        """Escalate a resident study to the neural-basis tier: the pool
        retrains the full real ledger (+ tell costs) into the NB model and
        re-fantasizes any outstanding q-ask rows against it.  The tier tag
        follows the study through eviction snapshots, checkpoints, and
        migration records."""
        self.pool.promote(log.slot)
        log.tier = 1

    # -- federation support (DESIGN.md §13/§14) -----------------------------
    # The federation front end (in-memory FederatedGateway or the socket
    # RPC TransportFederation) sees shards ONLY through this public
    # surface: quiescence, portable registry records, global-id sync, and
    # the migrate/adopt/detach/expel protocol.  Privates don't cross
    # process boundaries — anything the front end needs must live here.

    def is_quiescent(self, sid: int) -> bool:
        """True when the study exists and has NOTHING in motion: no
        suggestions outstanding, no queued asks or tells, no q-ask fantasy
        rows pinning its slot.  The public gate for migration/rebalance
        candidate scans (unknown or closed sids are simply not quiescent);
        `detach_study` and `export_for_migration` enforce the same
        predicate, so the in-memory and RPC paths can never drift."""
        log = self._studies.get(sid)
        if log is None:
            return False
        return (not log.inflight and not log.pending_asks
                and not log.pending_tells
                and not (log.slot is not None
                         and self.pool.fantasy_active(log.slot)))

    def registry_record(self, sid: int) -> dict:
        """Portable (JSON-safe) registry record of one study — the
        federation's fallback record and the migration manifest.  Pure
        read: unlike `export_for_migration` it neither quiesces nor
        evicts, so `record["version"]` only names a restorable snapshot
        when the study is non-resident (`evicted_ever` + not resident)."""
        log = self._require(sid)
        return {
            "sid": log.sid, "name": log.name, "seed": log.seed,
            "dims": space_to_dicts(log.space), "n_obs": log.n_obs,
            "best_value": log.best_value, "version": log.version,
            "evicted_ever": log.evicted_ever, "tier": log.tier,
            "key": self._study_key(log),
        }

    def sync_registry(self, next_sid: int | None = None,
                      closed_sids: Sequence[int] = ()) -> None:
        """Merge global-id bookkeeping pushed down by a federation front
        end: the global sid watermark (fresh-sid collisions with studies
        created elsewhere must be impossible) and globally closed sids
        (tombstones, so a stale shard can't resurrect a closed study)."""
        if next_sid is not None:
            self._next_sid = max(self._next_sid, int(next_sid))
        for sid in closed_sids:
            self._closed_sids.add(int(sid))

    def abandon(self) -> None:
        """Crash semantics WITHOUT a checkpoint (the in-memory analogue of
        SIGKILL, used by `FederatedGateway.kill_shard`): stop the ticker,
        cancel every parked ask future — a real crash severs those client
        connections the same way — and discard the staged tick.  The
        object must not be used afterwards; uncommitted work is lost."""
        self._closed = True
        if self._wake is not None:
            self._wake.set()
        pending = list(self._asks)
        if self._pending is not None:
            pending += self._pending.take
        self._pending = None
        for _sid, fut, _q in pending:
            if fut is not None and not fut.done():
                fut.cancel()

    def export_for_migration(self, sid: int) -> dict:
        """Quiesce one study and hand back a portable registry record.

        The study must be idle (nothing in flight or queued); if resident
        it is evicted first, so its latest state sits in THIS gateway's
        eviction store as a committed snapshot at `record["version"]`.
        The federation front end then copies that snapshot to the
        destination store (`checkpoint.copy_study_version`), adopts the
        record there, and finally `detach_study` here — a fault anywhere
        before the detach leaves the study fully intact on this shard.
        """
        self.tick_flush()
        log = self._require(sid)
        if not self.is_quiescent(sid):
            raise RuntimeError(
                f"study {sid} has work in flight "
                f"(inflight={log.inflight}, asks={log.pending_asks}, "
                f"tells={log.pending_tells}, fantasies="
                f"{self.pool.fantasy_active(log.slot) if log.slot is not None else 0}"
                "); drain before migrating")
        if log.slot is not None:
            self._free.append(self._evict(log))
        return self.registry_record(sid)

    def adopt_study(self, record: dict, *,
                    require_snapshot: bool = True) -> None:
        """Register a study exported from another shard.

        With `require_snapshot` (migration): the record's snapshot version
        must already be committed in THIS gateway's eviction store, or the
        adoption refuses — all-or-nothing, the source keeps the study.
        Without it (crash-recovery reconcile, where the snapshot may have
        lived only on the lost timeline): a missing snapshot degrades to a
        fresh study — its uncommitted observations are lost, never
        silently replayed."""
        sid = int(record["sid"])
        if sid in self._studies:
            raise ValueError(f"study id {sid} already lives on this shard")
        if sid in self._closed_sids:
            raise ValueError(f"study id {sid} was closed on this shard")
        space = space_from_dicts(record["dims"])
        if space.dim != self.pool.engine.gp_cfg.dim:
            raise ValueError(
                f"space dim {space.dim} != gateway dim "
                f"{self.pool.engine.gp_cfg.dim}")
        if space.has_discrete and not self.pool.engine.mixed:
            raise ValueError(
                "record has int/categorical dims but this shard was built "
                "without mixed-space closures")
        log = _Logical(sid, record["name"], space, int(record["seed"]),
                       n_obs=int(record["n_obs"]),
                       best_value=record.get("best_value"),
                       last_tick=self._tick_count,
                       version=int(record["version"]),
                       evicted_ever=bool(record["evicted_ever"]),
                       tier=int(record.get("tier", 0)))
        if log.evicted_ever and log.version not in \
                ckpt_mod.study_versions(self.cfg.ckpt_dir,
                                        self._study_key(log)):
            if require_snapshot:
                raise RuntimeError(
                    f"study {sid} snapshot version {log.version} is not "
                    f"committed under {self.cfg.ckpt_dir}; copy it before "
                    "adopting (all-or-nothing migration)")
            log.n_obs = 0
            log.best_value = None
            log.version = 0
            log.evicted_ever = False
            log.tier = 0
        self._studies[sid] = log
        self._next_sid = max(self._next_sid, sid + 1)
        if self._wake is not None:
            self._wake.set()

    def detach_study(self, sid: int) -> None:
        """Drop a migrated-away study from the registry WITHOUT a
        tombstone: the sid stays globally valid (it lives on another shard
        now, and may even migrate back).  This shard's copy of its
        snapshots is reclaimed at the next checkpoint commit."""
        log = self._require(sid)
        if log.slot is not None or not self.is_quiescent(sid):
            raise RuntimeError(
                f"study {sid} is not quiescent; export_for_migration first")
        if log.evicted_ever:
            self._closed_gc.append(self._study_key(log))
        del self._studies[sid]

    def expel_study(self, sid: int) -> None:
        """Remove a study this shard no longer owns (federation restore
        reconcile: the federation registry is newer than this shard's
        restored one — the study closed or migrated away on a timeline
        this shard lost).  Nothing is in flight after a restore, so this
        is pure registry surgery; snapshot files are left for the owning
        shard's GC."""
        log = self._studies.pop(sid, None)
        if log is None:
            return
        if log.slot is not None:
            self._owner[log.slot] = None
            self._free.append(log.slot)

    # -- the coalescing tick ------------------------------------------------
    def tick(self) -> int:
        """Serve one coalesced round synchronously; returns the number of
        asks served plus tells absorbed (0 = no progress).

        Gathers every queued tell and up to `max_batch` queued asks (at
        most one ask per study per tick — a second ask for the same study
        waits for the next round), makes the involved studies resident,
        and issues ONE fused `advance_round` dispatch.  Asks that cannot
        get a slot this tick (every slot pinned by in-flight work) stay
        queued and are retried when a tell frees a study; tells always
        place, or the tick fails without absorbing anything.

        `tick()` == `_tick_stage()` + `_tick_finish()` back to back (no
        overlap); the pipelined ticker drives the same two halves with one
        staged tick left in flight (`tick_begin`/`tick_flush`, §13).
        """
        self.tick_flush()
        staged = self._tick_stage()
        if staged is None:
            return 0
        return self._tick_finish(staged)

    def tick_begin(self) -> int:
        """Stage one coalesced round, finishing the PREVIOUSLY staged one
        after the new round's dispatch is issued — the pipelined tick:
        while tick t runs on the device, the host pops/validates/places
        tick t+1 and then commits t's results.  Returns the staged round's
        size (asks taken + tells placed; 0 = nothing to stage).

        Pipeline hazards flush first (inside `_tick_stage`): residency
        changes and q>1 asks must not be staged over an in-flight round.
        q-ask ticks are additionally barriers on their OWN finish — their
        fused fantasy dispatches must run against this tick's posterior,
        before any later round is staged.
        """
        staged = self._tick_stage()
        if staged is None:
            return 0
        if any(q > 1 for _sid, _fut, q in staged.take):
            # the residency/q hazard check already flushed the previous
            # tick; finishing this one immediately keeps its ask_q
            # dispatches ordered before the next staged round
            self._tick_finish(staged)
            return staged.size
        prev, self._pending = self._pending, staged
        if prev is not None:
            self._tick_finish(prev)
        return staged.size

    def tick_flush(self) -> int:
        """Finish the staged in-flight tick, if any (pipeline drain)."""
        prev, self._pending = self._pending, None
        if prev is None:
            return 0
        return self._tick_finish(prev)

    def _tick_stage(self) -> _PendingTick | None:
        """Pop the queues, place the involved studies, dispatch the fused
        round — everything up to (but not including) materialization."""
        tells, self._tells = self._tells, []
        # one ask per study per tick; respect max_batch; keep queue order
        take: list[tuple[int, asyncio.Future | None, int]] = []
        requeue: deque = deque()
        seen: set[int] = set()
        limit = self.gw.max_batch or len(self._asks)
        while self._asks:
            sid, fut, q = self._asks.popleft()
            if sid in seen or len(take) >= limit:
                requeue.append((sid, fut, q))
            else:
                seen.add(sid)
                take.append((sid, fut, q))
        self._asks = requeue
        if not tells and not take:
            # nothing new to stage — let the in-flight tick (if any) land
            self.tick_flush()
            return None
        if self._pending is not None and (
                any(q > 1 for _sid, _fut, q in take)
                or any(self._studies[sid].slot is None
                       for sid, _fut, _q in take)
                or any(self._studies[sid].slot is None
                       for sid, _tr, _val in tells)
                or any(self._needs_escalation(self._studies[sid], q)
                       for sid, _fut, q in take)):
            # pipeline hazards (§13): residency changes re-rank the LRU and
            # snapshot engine state, q>1 asks append fantasy rows whose
            # rollback bookkeeping the next round's staging reads, and tier
            # promotion rebuilds a slot's model — none may overlap an
            # unfinished tick.  Flush it first.
            try:
                self.tick_flush()
            except BaseException:
                self._tells = tells + self._tells
                self._asks.extendleft(reversed(take))
                raise
        self._restores_this_tick = 0
        self._evictions_this_tick = 0
        t0 = time.perf_counter()
        # Tells MUST place (their observation has nowhere else to go); their
        # pending counters pin them against the evictions they trigger.
        try:
            events = [(self._ensure_resident(sid), tr, val)
                      for sid, tr, val in tells]
        except GPCapacityError as e:
            # every slot pinned by other in-flight work: nothing was
            # absorbed (placement precedes the dispatch) — requeue the
            # tells untouched, fail this tick's asks loudly
            self._tells = tells + self._tells
            for sid, fut, q in take:
                self._studies[sid].pending_asks -= q
                if fut is not None and not fut.done():
                    fut.set_exception(e)
            raise
        except Exception:
            # IO fault in the eviction store: nothing was dispatched —
            # requeue the whole tick untouched and surface the error
            self._tells = tells + self._tells
            self._asks.extendleft(reversed(take))
            raise
        # Asks place best-effort: the overflow defers to the next tick.
        ask_slots: dict[int, int] = {}
        deferred: list[tuple[int, asyncio.Future | None, int]] = []
        served: list[tuple[int, asyncio.Future | None, int]] = []
        try:
            for sid, fut, q in take:
                slot = self._try_resident(sid)
                if slot is None:
                    deferred.append((sid, fut, q))
                else:
                    ask_slots[sid] = slot
                    served.append((sid, fut, q))
        except Exception:
            # IO fault placing an ask (eviction snapshot failed): requeue
            # everything untouched — already-placed asks keep their slots
            # and replace them idempotently next tick — and surface.
            self._tells = tells + self._tells
            self._asks.extendleft(reversed(take))
            raise
        self._asks.extendleft(reversed(deferred))
        take = served
        if not events and not take:
            return None
        # Saturation escalation (DESIGN.md §15): a served ask that could
        # not fit the study's GP buffers promotes it to the NB tier BEFORE
        # the fused round — this tick's tells for it then take the routed
        # NB absorb, and its q-ask (if any) runs against the escalated
        # posterior with no capacity guard to trip mid-fantasy.
        for sid, _fut, q in take:
            log = self._studies[sid]
            if self._needs_escalation(log, q):
                self._promote(log)
        one_slots = sorted(ask_slots[sid] for sid, _f, q in take if q == 1)
        try:
            round_ = self.pool.advance_round_begin(
                events, t=1, studies=one_slots)
        except GPCapacityError as e:
            # advance_round capacity-checks the WHOLE round before mutating
            # any ledger or GP buffer (all-or-nothing), so the queues can be
            # rebuilt exactly: absorbable tells are requeued, unabsorbable
            # ones dead-letter (their trial records the error), and this
            # tick's asks fail loudly at their futures.
            self._retry_absorb = self._unwind_capacity_failure(tells, take, e)
            raise
        except Exception as e:
            # unexpected fault inside the fused dispatch (units are
            # validated at tell(), so this is an engine/runtime error):
            # observations must not vanish and clients must not hang.
            self._fail_tick(tells, take, e)
            raise
        return _PendingTick(round=round_, tells=tells, take=take,
                            events=events, ask_slots=ask_slots,
                            deferred=len(deferred), t0=t0,
                            evictions=self._evictions_this_tick,
                            restores=self._restores_this_tick)

    def _fail_tick(self, tells, take, err) -> None:
        """Settle a failed tick so observations don't vanish and clients
        don't hang.  The pool flips a trial's status to "done" only AFTER
        its append committed to the GP, so requeue exactly the uncommitted
        tells — re-absorbing a committed one would silently duplicate its
        row — and settle the committed ones' counters here.  The tick's
        asks fail at their futures; the caller re-raises so the operator
        sees the error."""
        requeue = []
        for sid, tr, val in tells:
            log = self._studies[sid]
            if tr.status == "done":
                log.pending_tells -= 1
                log.n_obs += 1
                if tr.error is None and (log.best_value is None
                                         or val > log.best_value):
                    log.best_value = val
            else:
                requeue.append((sid, tr, val))
        self._tells = requeue + self._tells
        for sid, fut, q in take:
            self._studies[sid].pending_asks -= q
            if fut is not None and not fut.done():
                fut.set_exception(err)

    def _tick_finish(self, p: _PendingTick) -> int:
        """Materialize a staged round and commit it: settle ledgers,
        resolve futures, record telemetry, run the checkpoint cadence."""
        tells, take, ask_slots = p.tells, p.take, p.ask_slots
        try:
            suggestions = p.round.finish()
        except Exception as e:  # noqa: BLE001 — partitioned by status
            self._fail_tick(tells, take, e)
            raise
        # q>1 asks: one fused qEI fantasy dispatch per study, issued after
        # the round so each batch conditions on this tick's absorbs.  A
        # per-ask failure (capacity stolen by a foreign tell between
        # admission and serve) fails only that future, not the tick.
        q_results: dict[int, list[Trial] | Exception] = {}
        for sid, _fut, q in take:
            if q == 1:
                continue
            try:
                q_results[sid] = self.pool.ask_q(ask_slots[sid], q)
            except Exception as e:  # noqa: BLE001 — meted to the future
                q_results[sid] = e
        latency_ms = 1e3 * (time.perf_counter() - p.t0)
        self._tick_count += 1
        for sid, tr, val in tells:
            log = self._studies[sid]
            log.pending_tells -= 1
            log.n_obs += 1
            log.last_tick = self._tick_count
            if tr.error is None and (log.best_value is None
                                     or val > log.best_value):
                log.best_value = val
        n_suggested = 0
        for sid, fut, q in take:
            log = self._studies[sid]
            log.pending_asks -= q
            log.last_tick = self._tick_count
            hist = self._totals["q_width_hist"]
            hist[str(q)] = hist.get(str(q), 0) + 1
            if q == 1:
                trials = [suggestions[ask_slots[sid]][0]]
            else:
                res = q_results[sid]
                if isinstance(res, Exception):
                    if fut is not None and not fut.done():
                        fut.set_exception(res)
                    continue
                trials = res
            n_suggested += q
            if fut is not None and fut.cancelled():
                # the client is gone: nobody holds these suggestions, so
                # no tell will ever come back — counting them in flight
                # would pin the study non-evictable and eat its
                # max_inflight budget forever, and a q-ask's fantasy rows
                # would hold buffer capacity with no tell to release them
                for tr in trials:
                    tr.status = "failed"
                    tr.error = "ask cancelled before delivery"
                if q > 1:
                    self.pool.release_fantasies(
                        ask_slots[sid],
                        [np.asarray(tr.unit) for tr in trials])
                continue
            log.inflight += q
            for tr in trials:
                tr.status = "running"
                tr.started = time.time()
            if fut is not None:
                fut.set_result(trials if q > 1 else trials[0])
        self._sync_fantasy_totals()
        self.stats.append({
            "tick": self._tick_count,
            "width": len(take),
            "suggestions": n_suggested,
            "absorbed": len(p.events),
            "deferred": p.deferred,
            "queued_after": len(self._asks),
            "latency_ms": latency_ms,
            "evictions": p.evictions,
            "restores": p.restores,
        })
        self._totals["asks_served"] += n_suggested
        self._totals["absorbed"] += len(p.events)
        if self.gw.ckpt_every_ticks and \
                self._tick_count % self.gw.ckpt_every_ticks == 0:
            self.checkpoint()
        return p.size

    def _unwind_capacity_failure(self, tells, take, err) -> bool:
        """Rebuild the queues after an all-or-nothing capacity abort.

        Returns True when absorbable tells were requeued — their retry
        round is guaranteed to fit (the overflow was dead-lettered and the
        coalesced asks removed), so the ticker may re-wake once."""
        keep, counts = [], {}
        for sid, tr, val in tells:
            log = self._studies[sid]
            counts[sid] = counts.get(sid, 0) + 1
            # escalated studies can never be the raiser (their ledger
            # doubles instead of filling) — their tells always requeue
            if log.tier == 0 and log.n_obs + counts[sid] > self.cfg.n_max:
                # can never fit — dead-letter instead of poisoning the queue
                log.pending_tells -= 1
                counts[sid] -= 1
                tr.status = "failed"
                tr.error = f"dropped at capacity: {err}"
                self.dead_tells.append((sid, tr, val))
            else:
                keep.append((sid, tr, val))
        self._tells = keep + self._tells
        for sid, fut, q in take:
            self._studies[sid].pending_asks -= q
            if fut is not None and not fut.done():
                fut.set_exception(err)
        return bool(keep)

    async def drain(self) -> None:
        """Wait until every queued ask/tell has been served (or the ticker
        has died — its exception re-raises here).  Parks on the per-tick
        event instead of busy-polling: a waiter re-checks only after the
        ticker attempts a round (or exits)."""
        while self._asks or self._tells or self._pending is not None or (
                self._wake is not None and self._wake.is_set()):
            if self._ticker is None:
                break  # nothing will ever serve; sync callers drive tick()
            if self._ticker.done():
                if not self._ticker.cancelled() and \
                        self._ticker.exception() is not None:
                    raise self._ticker.exception()
                break
            self._tick_done.clear()
            # re-check after the clear: a tick that completed between the
            # loop condition and the clear must not be waited out
            if not (self._asks or self._tells or self._wake.is_set()
                    or self._pending is not None):
                break
            await self._tick_done.wait()

    def _ensure_ticker(self, loop: asyncio.AbstractEventLoop) -> None:
        if self._wake is None:
            self._wake = asyncio.Event()
        if self._tick_done is None:
            self._tick_done = asyncio.Event()
        if self._ticker is None or self._ticker.done():
            self._ticker = loop.create_task(self._run_ticker())

    async def _run_ticker(self) -> None:
        try:
            while not self._closed:
                await self._wake.wait()
                self._wake.clear()
                if self._closed:
                    break
                if self.gw.coalesce_ms > 0:
                    await asyncio.sleep(self.gw.coalesce_ms / 1e3)
                else:
                    # One cooperative yield: every client task already
                    # runnable gets to enqueue before the round fires.
                    await asyncio.sleep(0)
                progressed = 0
                self._retry_absorb = False
                try:
                    if self.gw.pipeline:
                        progressed = self.tick_begin()
                        if progressed and self._pending is not None:
                            # one cooperative yield: clients woken by the
                            # round that just finished enqueue NOW, so the
                            # next begin can stage them while this round is
                            # still in flight — without it the staged round
                            # always drains at the tail below and nothing
                            # ever overlaps
                            await asyncio.sleep(0)
                        if self._pending is not None and not (
                                self._asks or self._tells):
                            # pipeline tail: no new traffic arrived — land
                            # the staged round so its clients aren't parked
                            # behind an idle gateway
                            progressed += self.tick_flush()
                            await asyncio.sleep(0)
                    else:
                        progressed = self.tick()
                except GPCapacityError:
                    # already meted out to the affected futures/queues;
                    # retry once when absorbable tells were requeued (their
                    # round is guaranteed to fit now).  A staged tick can't
                    # be the raiser (capacity is checked at stage), but it
                    # must still land or its clients park forever.
                    if self._pending is not None:
                        self.tick_flush()
                    if self._retry_absorb:
                        self._wake.set()
                except Exception as e:
                    # non-capacity fault (e.g. eviction-store IO): the tick
                    # requeued everything untouched, but dying silently
                    # would park every client awaiting ask() forever —
                    # fail their futures loudly instead.  Tells stay
                    # queued (observations are never dropped); the next
                    # ask() re-creates the ticker and retries them.
                    if self._pending is not None:
                        try:
                            self.tick_flush()
                        except Exception:  # noqa: BLE001 — already failing
                            pass
                    while self._asks:
                        sid, fut, q = self._asks.popleft()
                        self._studies[sid].pending_asks -= q
                        if fut is not None and not fut.done():
                            fut.set_exception(e)
                    raise
                # Re-wake only on progress: deferred asks that could not
                # place wait for the external event (a tell freeing a
                # study) instead of spinning the loop.
                if progressed and (self._asks or self._tells):
                    self._wake.set()
                self._tick_done.set()
        finally:
            # wake drain() waiters on ANY exit (aclose, tick exception) so
            # they observe the dead ticker instead of parking forever
            if self._tick_done is not None:
                self._tick_done.set()

    async def aclose(self) -> None:
        """Stop the ticker (queued asks are abandoned; tells stay queued
        until a final explicit `tick()`)."""
        self._closed = True
        if self._wake is not None:
            self._wake.set()
        if self._ticker is not None:
            try:
                await self._ticker
            except asyncio.CancelledError:
                pass
        self.tick_flush()  # land any round the ticker left in flight
        for sid, fut, q in self._asks:
            if fut is not None and not fut.done():
                fut.cancel()
            self._studies[sid].pending_asks -= q
        self._asks.clear()

    # -- telemetry / checkpointing ------------------------------------------
    def _sync_fantasy_totals(self) -> None:
        """Fold the pool's rollback counter into the lifetime total.  The
        pool counter is a live monotonic tally that does not persist; the
        gateway total rides the checkpoint registry like every other
        lifetime counter, so the delta since the last sync is folded in
        and the watermark advanced."""
        cur = self.pool.fantasy_rollbacks
        self._totals["fantasy_rollbacks"] += cur - self._pool_rollbacks_seen
        self._pool_rollbacks_seen = cur

    def study_ids(self) -> list[int]:
        """Open logical study ids (closed studies leave the registry)."""
        return sorted(self._studies)

    def study_info(self, sid: int) -> dict:
        """Public view of one logical study's serving state: name, absorbed
        count, residency, eviction count, and the best genuine observation
        (residency-independent; penalty pseudo-observations excluded) — the
        stable surface examples and dashboards read instead of the private
        registry."""
        log = self._studies.get(sid)
        if log is None:
            raise KeyError(f"unknown study id {sid}")
        return {
            "sid": log.sid, "name": log.name, "n_obs": log.n_obs,
            "slot": log.slot, "resident": log.slot is not None,
            "inflight": log.inflight, "evictions": log.version,
            "best_value": log.best_value,
            "fantasy_active": (self.pool.fantasy_active(log.slot)
                               if log.slot is not None else 0),
            # saturation observability (DESIGN.md §15): the tier tag and
            # whether the study has ever hit its GP buffer boundary; both
            # survive eviction and checkpoint/restore with the registry
            "tier": log.tier,
            "saturated": bool(log.tier or log.n_obs >= self.cfg.n_max),
        }

    def summary(self) -> dict:
        """Serving telemetry: counts are LIFETIME totals (including the
        fantasy rollback count and the q-width histogram, which survive
        checkpoint/restore); `fantasy_active` is the LIVE number of
        fantasy rows across resident slots; latency/width distributions
        cover the retained window (`stats_window` ticks)."""
        self._sync_fantasy_totals()
        out = {"ticks": self._tick_count, **self._totals,
               "fantasy_active": sum(self.pool.fantasy_active(s)
                                     for s in range(self.gw.slots)),
               # saturation gauges (DESIGN.md §15): escalated = studies on
               # the NB tier; saturated = studies at/past their GP buffer
               # boundary (escalated ones included).  Derived from the
               # registry, so they persist across checkpoint/restore and
               # sum across federation shards.
               "escalated": sum(1 for log in self._studies.values()
                                if log.tier),
               "saturated": sum(1 for log in self._studies.values()
                                if log.tier
                                or log.n_obs >= self.cfg.n_max),
               "mean_coalesce_width": 0.0,
               "p50_tick_ms": 0.0, "p95_tick_ms": 0.0}
        if self.stats:
            lat = sorted(s["latency_ms"] for s in self.stats)
            # width over ask-serving ticks only: tell-only drain ticks
            # have width 0 and would understate the coalescing achieved
            widths = [s["width"] for s in self.stats if s["width"]]
            if widths:
                out["mean_coalesce_width"] = float(np.mean(widths))
            out["p50_tick_ms"] = lat[len(lat) // 2]
            out["p95_tick_ms"] = lat[min(len(lat) - 1,
                                         int(0.95 * len(lat)))]
        return out

    def checkpoint(self) -> str | None:
        """Whole-gateway snapshot: evicted studies already sit in their
        partial snapshots; the pool snapshot covers the resident slots and
        the logical registry rides the pool metadata.  In-flight asks and
        un-told suggestions do NOT survive a crash — clients re-ask, and
        the persistent per-study PRNG streams guarantee the retried round
        never replays a pre-crash batch.  Fantasy rows never reach disk:
        `pool.checkpoint` rolls every fantasy-active slot back to real
        observations before snapshotting and re-fantasizes after."""
        # a staged tick is half-committed state: land it before snapshotting
        # (no-op when the cadence fires from _tick_finish — the pending
        # record was popped before finish ran)
        self.tick_flush()
        self._sync_fantasy_totals()
        registry = {
            "next_sid": self._next_sid,
            "tick_count": self._tick_count,
            "totals": dict(self._totals),
            "closed_sids": sorted(self._closed_sids),
            "studies": [{
                "sid": log.sid, "name": log.name, "seed": log.seed,
                "slot": log.slot, "n_obs": log.n_obs,
                "best_value": log.best_value,
                "last_tick": log.last_tick, "version": log.version,
                "evicted_ever": log.evicted_ever, "tier": log.tier,
                "dims": space_to_dicts(log.space),
            } for log in self._studies.values()],
        }
        path = self.pool.checkpoint(extra={"gateway": json.dumps(registry)})
        if path is not None:
            # the committed registry references each study's CURRENT
            # version; older partial snapshots are now unreachable
            ckpt_mod.prune_studies(self.cfg.ckpt_dir, {
                self._study_key(log): log.version
                for log in self._studies.values() if log.evicted_ever})
            # studies closed since the last commit are now unreferenced by
            # any restorable registry — their snapshot dirs can go.  A key
            # that came BACK (study migrated away and returned before this
            # commit) is live again and must keep its files.
            live = {self._study_key(log) for log in self._studies.values()}
            ckpt_mod.drop_studies(self.cfg.ckpt_dir,
                                  [k for k in self._closed_gc
                                   if k not in live])
            self._closed_gc = []
        return path

    def restore(self) -> bool:
        """Resume from the latest pool snapshot + its gateway registry.

        Pending/in-flight work is reset (those clients are gone); absorbed
        state, ledgers, PRNG streams, slot map, and LRU/eviction bookkeeping
        come back exactly as checkpointed.
        """
        self.tick_flush()  # resolve any staged round on the old timeline
        if not self.pool.restore():
            return False
        meta = self.pool.last_restore_meta or {}
        if "gateway" not in meta:
            raise ValueError("checkpoint has no gateway registry "
                             "(written by a bare StudyPool?)")
        registry = json.loads(meta["gateway"])
        self._next_sid = int(registry["next_sid"])
        self._tick_count = int(registry["tick_count"])
        self._totals.update(registry.get("totals", {}))
        # pool.restore() cleared every fantasy row (snapshots hold only
        # real state); re-arm the rollback watermark at the pool's live
        # counter so only post-restore rollbacks accrue on top of the
        # persisted lifetime total
        self._pool_rollbacks_seen = self.pool.fantasy_rollbacks
        self._closed_sids = set(registry.get("closed_sids", []))
        self._closed_gc = []
        self._studies = {}
        self._owner = [None] * self.gw.slots
        # clients parked on pre-restore asks belong to the discarded
        # timeline: cancel their futures (dropping them silently would
        # hang those tasks forever — aclose() does the same)
        for _sid, fut, _q in self._asks:
            if fut is not None and not fut.done():
                fut.cancel()
        self._asks.clear()
        self._tells = []
        for rec in registry["studies"]:
            space = space_from_dicts(rec["dims"])
            log = _Logical(rec["sid"], rec["name"], space, rec["seed"],
                           slot=rec["slot"], n_obs=rec["n_obs"],
                           best_value=rec.get("best_value"),
                           last_tick=rec["last_tick"],
                           version=rec["version"],
                           evicted_ever=rec["evicted_ever"],
                           tier=int(rec.get("tier", 0)))
            self._studies[log.sid] = log
            if log.slot is not None:
                self._owner[log.slot] = log.sid
                # pool.restore() rebuilds slot handles from the pool
                # snapshot, which carries no spaces — re-apply the logical
                # study's own (possibly custom) space AND its type
                # descriptor, or its resident suggestions map through the
                # template's bounds/layout
                self.pool.studies[log.slot].space = log.space
                if self.pool.engine.mixed or log.space.has_discrete:
                    self.pool.engine.set_desc(log.slot,
                                              log.space.descriptor())
        self._free = [s for s in range(self.gw.slots - 1, -1, -1)
                      if self._owner[s] is None]
        return True
