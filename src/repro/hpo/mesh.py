"""Device-mesh execution layer for the HPO stack (DESIGN.md §8).

The paper's final scaling claim is a further speedup from running the
lazy-GP optimizer "in a parallel environment": one suggest round is
embarrassingly parallel over both the **study** axis (S independent
posteriors, PR 2's batch dimension) and the **restart** axis (R
independent EI ascents per study).  This module owns the mapping from
those logical axes onto a physical `jax.sharding.Mesh`:

  * axis ``"study"`` — shards the leading S axis of the stacked
    `LazyGPState` (every leaf: `x_buf (S, n_max, d)`, `li_buf
    (S, n_max, n_max)`, per-study scalars `(S,)`, params leaves `(S,)`).
    No collective ever crosses this axis: studies are independent, so the
    sharded suggest/absorb programs are pure SPMD with zero communication.
  * axis ``"restart"`` — when S is smaller than the device count, the
    spare factor shards each study's R-restart EI ascent (the dominant
    per-round cost).  The state is *replicated* across this axis
    (including `li_buf` — see DESIGN.md §8 for why the maintained inverse
    must ride along), each shard ascends its restart slice, and one
    `all_gather` per suggest reassembles the (R,) candidate set so the
    basin dedup sees every restart.

`build(spec, n_studies, restarts)` turns the `SchedulerConfig.mesh` knob
into an `HPOMesh` (or None for the unsharded degenerate case):

  * ``"none"``  — no mesh; the plain single-program path (the default).
  * ``"auto"``  — factor the available devices into study x restart
    shards that divide S and R; collapses to None on a single device.
  * ``"SxR"``   — explicit shard counts, e.g. ``"4x2"`` = 4-way study
    sharding x 2-way restart sharding (must divide S and R and fit the
    device count).  ``"8"`` is shorthand for ``"8x1"``.

Validated on CPU via ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
(the CI recipe); on a TPU slice the same specs place shards on real chips.
`benchmarks/bench_shard.py` measures the scaling curve this enables.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

STUDY_AXIS = "study"
RESTART_AXIS = "restart"


def _largest_divisor_leq(n: int, cap: int) -> int:
    for c in range(min(n, cap), 0, -1):
        if n % c == 0:
            return c
    return 1


@dataclasses.dataclass(frozen=True)
class HPOMesh:
    """A (study x restart) device mesh plus the placement helpers.

    `study_shards * restart_shards` devices participate; the leading study
    axis of every stacked array is split `study_shards` ways and replicated
    across the restart axis.
    """

    mesh: Mesh
    study_shards: int
    restart_shards: int

    @property
    def n_devices(self) -> int:
        return self.study_shards * self.restart_shards

    def study_sharding(self) -> NamedSharding:
        """Sharding for stacked `(S, ...)` arrays: split S, replicate rest."""
        return NamedSharding(self.mesh, P(STUDY_AXIS))

    def place(self, tree):
        """Put a pytree of stacked `(S, ...)` leaves onto the mesh."""
        return jax.device_put(tree, self.study_sharding())

    def shard(self, body, n_in: int):
        """`shard_map` a stacked-state transition over the mesh.

        `body` maps `n_in` leading-S-axis pytrees to leading-S-axis pytrees
        (out_specs is a pytree prefix, so one spec covers any output
        arity); each shard sees the local `(S/study_shards, ...)` slice.
        Outputs must be replicated across the restart axis (each restart
        shard computes them identically after its `all_gather`), which
        `check_rep=False` asserts by fiat rather than proof.
        """
        return shard_map(body, self.mesh,
                         in_specs=(P(STUDY_AXIS),) * n_in,
                         out_specs=P(STUDY_AXIS), check_rep=False)


def parse_spec(spec: str) -> tuple[int, int] | str | None:
    """``"none"`` -> None, ``"auto"`` -> "auto", ``"SxR"``/``"S"`` -> ints."""
    s = (spec or "none").strip().lower()
    if s in ("none", ""):
        return None
    if s == "auto":
        return "auto"
    parts = s.split("x")
    try:
        if len(parts) == 1:
            return int(parts[0]), 1
        if len(parts) == 2:
            return int(parts[0]), int(parts[1])
    except ValueError:
        pass
    raise ValueError(
        f"bad mesh spec {spec!r}: expected 'none', 'auto', 'S' or 'SxR' "
        "(study shards x restart shards, e.g. '4x2')")


def build(spec: str, n_studies: int, restarts: int,
          devices=None) -> HPOMesh | None:
    """Resolve a mesh spec against the study/restart extents and devices.

    Shard counts must divide their axis extents exactly: a study shard owns
    `S / study_shards` whole studies and a restart shard ascends
    `R / restart_shards` whole seeds, so non-divisible specs are rejected
    rather than padded (GSPMD padding would silently waste lanes).
    """
    parsed = parse_spec(spec)
    if parsed is None:
        return None
    devices = list(devices if devices is not None else jax.devices())
    if parsed == "auto":
        if len(devices) == 1:
            return None  # the unsharded path IS the one-device case
        s = _largest_divisor_leq(n_studies, len(devices))
        r = _largest_divisor_leq(restarts, len(devices) // s)
        parsed = (s, r)
    s, r = parsed
    if s < 1 or r < 1:
        raise ValueError(f"mesh shards must be >= 1, got {s}x{r}")
    if s * r > len(devices):
        raise ValueError(
            f"mesh {s}x{r} needs {s * r} devices, have {len(devices)} "
            "(on CPU set XLA_FLAGS=--xla_force_host_platform_device_count=8 "
            "before importing jax)")
    if n_studies % s:
        raise ValueError(
            f"study shards ({s}) must divide n_studies ({n_studies})")
    if restarts % r:
        raise ValueError(
            f"restart shards ({r}) must divide acq.restarts ({restarts})")
    mesh = Mesh(np.asarray(devices[:s * r]).reshape(s, r),
                (STUDY_AXIS, RESTART_AXIS))
    return HPOMesh(mesh=mesh, study_shards=s, restart_shards=r)
