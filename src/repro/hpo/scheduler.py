"""HPO trial scheduler — the paper's parallel lazy-GP loop, production shape.

The paper's Sec. 3.4 insight: with O(n^2) GP updates, synchronization stops
being the bottleneck, so you can (a) suggest the top-t EI local maxima and
train t models concurrently, and (b) absorb results as *row appends* that
commute under the frozen kernel.  This scheduler turns that into the
1000-node orchestration contract:

  * **async absorption** — results are appended in *completion* order; a
    straggler never blocks the GP or the next suggestion round (suggestions
    can be issued from the current posterior at any time).
  * **fault tolerance** — a failed trial (node crash, NaN loss) produces no
    observation; the scheduler re-suggests from the posterior (optionally
    recording a penalized pseudo-observation so EI avoids a crashing
    region), and the GP state checkpoints with the trial ledger so a
    restarted controller resumes with the identical posterior.
  * **elasticity** — the parallel width t is re-read every round, so the
    suggestion batch tracks however many pod-slices are currently healthy.
  * **lag policy** — every `lag` absorbed results, kernel params are refit
    and the factor rebuilt (paper Fig. 6), amortizing the O(n^3) cost.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
import traceback
from concurrent.futures import FIRST_COMPLETED, Future, ThreadPoolExecutor, wait
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import acquisition as acq_mod
from repro.core import gp as gp_mod
from repro.core.kernels import KERNELS
from repro.hpo.space import SearchSpace
from repro import checkpoint as ckpt_mod


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    n_max: int = 512
    kernel: str = "matern52"
    lag: int = 0                 # 0 = fully lazy (paper's main mode)
    parallel: int = 1            # t (elastic; re-read each round)
    rho0: float = 0.25
    noise2: float = 1e-5
    seed: int = 0
    implementation: str = "auto"  # linalg substrate (auto|pallas|xla|ref)
    failure_penalty: float | None = None  # None: drop; else pseudo-y
    max_retries: int = 1
    ckpt_dir: str | None = None
    acq: acq_mod.AcqConfig = dataclasses.field(
        default_factory=lambda: acq_mod.AcqConfig(restarts=48,
                                                  ascent_steps=20))


@dataclasses.dataclass
class Trial:
    trial_id: int
    unit: np.ndarray
    hparams: dict
    status: str = "pending"      # pending | running | done | failed
    value: float | None = None
    error: str | None = None
    started: float = 0.0
    finished: float = 0.0
    retries: int = 0
    clamp_count: int | None = None  # cumulative GP conditioning-floor hits
    # at absorb time (ill-conditioning telemetry, DESIGN.md §6)


class TrialScheduler:
    """Drives `objective(hparams) -> float (maximize)` through the lazy GP."""

    def __init__(self, space: SearchSpace, cfg: SchedulerConfig):
        self.space = space
        self.cfg = cfg
        self.kernel = KERNELS[cfg.kernel]
        gcfg = gp_mod.GPConfig(n_max=cfg.n_max, dim=space.dim,
                               kernel=cfg.kernel, noise2=cfg.noise2,
                               rho0=cfg.rho0,
                               implementation=cfg.implementation)
        self.state = gp_mod.init_state(gcfg)
        self.trials: list[Trial] = []
        self._next_id = 0
        self._key = jax.random.PRNGKey(cfg.seed)
        self._lo = jnp.zeros((space.dim,))
        self._hi = jnp.ones((space.dim,))
        self._suggest = jax.jit(self._suggest_impl,
                                static_argnames=("top_t",))
        # The substrate knob is a Python constant inside the jitted closures:
        # one compilation per configured implementation.
        self._append = jax.jit(
            lambda st, x, y: gp_mod.append(
                st, self.kernel, x, y,
                implementation=self.cfg.implementation))
        self._refit = jax.jit(self._refit_impl)

    # ------------------------------------------------------------------
    def _suggest_impl(self, state, key, *, top_t):
        return acq_mod.optimize_acquisition(
            state, self.kernel, self._lo, self._hi, key, self.cfg.acq, top_t,
            implementation=self.cfg.implementation)

    def _refit_impl(self, state):
        params = gp_mod.refit_params(
            state, self.kernel, implementation=self.cfg.implementation)
        return gp_mod.refactor(state, self.kernel, params,
                               implementation=self.cfg.implementation)

    def _split(self):
        self._key, sub = jax.random.split(self._key)
        return sub

    # ------------------------------------------------------------------
    def seed_trials(self, n: int) -> list[Trial]:
        rng = np.random.default_rng(self.cfg.seed)
        units = self.space.sample(rng, n)
        return [self._make_trial(u) for u in units]

    def suggest(self, t: int | None = None) -> list[Trial]:
        """Top-t distinct EI local maxima from the current posterior."""
        t = t or self.cfg.parallel
        if int(self.state.n) == 0:
            return self.seed_trials(t)
        units, _ = self._suggest(self.state, self._split(), top_t=t)
        return [self._make_trial(np.asarray(u)) for u in units]

    def _make_trial(self, unit: np.ndarray) -> Trial:
        tr = Trial(self._next_id, unit.astype(np.float32),
                   self.space.to_hparams(unit))
        self._next_id += 1
        self.trials.append(tr)
        return tr

    # ------------------------------------------------------------------
    def absorb(self, trial: Trial, value: float) -> None:
        """O(n^2) row append (order-independent under the frozen kernel)."""
        gp_mod.ensure_capacity(int(self.state.n), self.cfg.n_max)
        trial.status = "done"
        trial.value = float(value)
        trial.finished = time.time()
        self.state = self._append(self.state, jnp.asarray(trial.unit),
                                  jnp.asarray(value, jnp.float32))
        trial.clamp_count = int(self.state.clamp_count)
        if self.cfg.lag > 0 and int(self.state.since_refit) >= self.cfg.lag:
            self.state = self._refit(self.state)
        self._maybe_checkpoint()

    def record_failure(self, trial: Trial, error: str) -> Trial | None:
        """Failed trial: retry (fresh suggestion) or penalize the region."""
        trial.status = "failed"
        trial.error = error
        trial.finished = time.time()
        if self.cfg.failure_penalty is not None:
            # Pseudo-observation keeps EI away from a crashing region.
            gp_mod.ensure_capacity(int(self.state.n), self.cfg.n_max)
            self.state = self._append(
                self.state, jnp.asarray(trial.unit),
                jnp.asarray(self.cfg.failure_penalty, jnp.float32))
            trial.clamp_count = int(self.state.clamp_count)
        if trial.retries < self.cfg.max_retries:
            nxt = self.suggest(1)[0]
            nxt.retries = trial.retries + 1
            return nxt
        return None

    # ------------------------------------------------------------------
    def best(self) -> Trial | None:
        done = [t for t in self.trials if t.status == "done"]
        return max(done, key=lambda t: t.value) if done else None

    def history(self) -> list[dict]:
        return [dataclasses.asdict(t) | {"unit": t.unit.tolist()}
                for t in self.trials]

    # ------------------------------------------------------------------
    def _maybe_checkpoint(self):
        if not self.cfg.ckpt_dir:
            return
        n_done = sum(t.status == "done" for t in self.trials)
        ckpt_mod.save(self.cfg.ckpt_dir, n_done,
                      dataclasses.asdict(self.state),
                      metadata={"trials": json.dumps(self.history()),
                                "next_id": self._next_id})

    def restore(self) -> bool:
        if not self.cfg.ckpt_dir:
            return False
        out = ckpt_mod.restore_latest(self.cfg.ckpt_dir,
                                      dataclasses.asdict(self.state))
        if out is None:
            return False
        _, tree, meta = out
        from repro.core.kernels import KernelParams
        tree["params"] = KernelParams(**tree["params"])
        self.state = gp_mod.LazyGPState(**tree)
        self._next_id = int(meta["next_id"])
        self.trials = []
        for rec in json.loads(meta["trials"]):
            tr = Trial(rec["trial_id"], np.asarray(rec["unit"], np.float32),
                       rec["hparams"], rec["status"], rec["value"],
                       rec["error"], rec["started"], rec["finished"],
                       rec["retries"], rec.get("clamp_count"))
            self.trials.append(tr)
        return True

    # ------------------------------------------------------------------
    def run(self, objective: Callable[[dict], float], budget: int,
            n_seed: int = 1, executor: ThreadPoolExecutor | None = None,
            parallel: Callable[[], int] | None = None) -> Trial | None:
        """Run until `budget` observations have been absorbed.

        `parallel` is an optional callable re-read each round — the elastic
        width (e.g. the number of currently-healthy pod slices).
        """
        own_pool = executor is None and self.cfg.parallel > 1
        pool = executor or (ThreadPoolExecutor(self.cfg.parallel)
                            if own_pool else None)
        width_fn = parallel or (lambda: self.cfg.parallel)

        def launch(pool, trial):
            trial.status = "running"
            trial.started = time.time()
            fut = pool.submit(objective, trial.hparams)
            fut.trial = trial
            return fut

        try:
            if pool is None:
                # Sequential mode (t = 1).
                for tr in self.seed_trials(n_seed):
                    self._run_one(objective, tr)
                while sum(t.status == "done" for t in self.trials) < budget:
                    tr = self.suggest(1)[0]
                    self._run_one(objective, tr)
                return self.best()

            pending: set[Future] = set()
            for tr in self.seed_trials(max(n_seed, 1)):
                pending.add(launch(pool, tr))
            absorbed = 0
            while absorbed < budget:
                done, pending = wait(pending, return_when=FIRST_COMPLETED)
                for fut in done:       # async absorption, completion order
                    tr = fut.trial
                    try:
                        val = float(fut.result())
                        if not np.isfinite(val):
                            raise FloatingPointError(
                                f"objective returned {val}")
                    except Exception as e:  # noqa: BLE001 — trial fault
                        retry = self.record_failure(
                            tr, f"{type(e).__name__}: {e}")
                        if retry is not None:
                            pending.add(launch(pool, retry))
                    else:
                        # Scheduler-side errors (capacity, checkpoint IO)
                        # propagate: they are not trial faults to retry.
                        self.absorb(tr, val)
                        absorbed += 1
                width = max(1, width_fn())
                while len(pending) < width and absorbed + len(pending) < budget:
                    for tr in self.suggest(1):
                        pending.add(launch(pool, tr))
            return self.best()
        finally:
            if own_pool and pool is not None:
                pool.shutdown(wait=False, cancel_futures=True)

    def _run_one(self, objective, trial: Trial):
        trial.status = "running"
        trial.started = time.time()
        try:
            val = float(objective(trial.hparams))
            if not np.isfinite(val):
                raise FloatingPointError(f"objective returned {val}")
        except Exception as e:  # noqa: BLE001 — trial fault only
            retry = self.record_failure(trial, traceback.format_exc()[-500:]
                                        if not isinstance(e, FloatingPointError)
                                        else str(e))
            if retry is not None:
                self._run_one(objective, retry)
        else:
            # Absorb outside the trial-fault net: a scheduler-side error
            # (GP capacity, checkpoint IO) must propagate, not masquerade as
            # a failed trial and spin the retry loop.
            self.absorb(trial, val)
