"""HPO trial scheduler: the single-study objective execution loop.

The paper's Sec. 3.4 insight: with O(n^2) GP updates, synchronization stops
being the bottleneck, so you can (a) suggest the top-t EI local maxima and
train t models concurrently, and (b) absorb results as *row appends* that
commute under the frozen kernel.

`TrialScheduler` is the S = 1 degenerate case of
`repro.hpo.pool.StudyPool` (DESIGN.md §7): suggest, absorb, fault policy,
lag policy, and checkpointing all delegate to a one-study pool, so the
scheduler and the multi-tenant pool share exactly one suggest/absorb code
path — the `StudyEngine` jitted closures, sharded over a device mesh when
`SchedulerConfig.mesh` is set (DESIGN.md §8).  What lives HERE is only the
objective execution loop wrapped around that pool:

  * **async absorption** — `run` feeds completed futures to the pool in
    *completion* order; a straggler never blocks the GP or the next
    suggestion round.
  * **fault handling** — a failed trial (exception, non-finite loss) is
    routed to the pool's retry/penalty policy; scheduler-side errors
    (capacity, checkpoint IO) propagate instead of masquerading as trial
    faults.
  * **elasticity** — the parallel width t is re-read every round, so the
    suggestion batch tracks however many workers are currently healthy.
  * **resume** — a scheduler restored from a pool checkpoint goes straight
    to EI suggestions; it never re-runs its random seed trials.
"""
from __future__ import annotations

import time
import traceback
from concurrent.futures import FIRST_COMPLETED, Future, ThreadPoolExecutor, wait
from typing import Callable

import numpy as np

from repro.core import gp as gp_mod
from repro.hpo.pool import SchedulerConfig, StudyPool, Trial
from repro.hpo.space import SearchSpace

__all__ = ["SchedulerConfig", "Trial", "TrialScheduler"]


class TrialScheduler:
    """Drives `objective(hparams) -> float (maximize)` through the lazy GP."""

    def __init__(self, space: SearchSpace, cfg: SchedulerConfig):
        self.space = space
        self.cfg = cfg
        self.pool = StudyPool([space], cfg, names=["study0"])

    # -- delegation to the shared one-study pool ----------------------------
    @property
    def state(self) -> gp_mod.LazyGPState:
        return self.pool.state(0)

    @property
    def trials(self) -> list[Trial]:
        return self.pool.studies[0].trials

    def seed_trials(self, n: int) -> list[Trial]:
        return self.pool.seed_trials(0, n)

    def suggest(self, t: int | None = None) -> list[Trial]:
        """Top-t distinct EI local maxima from the current posterior."""
        return self.pool.suggest(0, t)

    def _make_trial(self, unit: np.ndarray) -> Trial:
        return self.pool._make_trial(0, unit)

    def absorb(self, trial: Trial, value: float) -> None:
        """O(n^2) row append (order-independent under the frozen kernel)."""
        self.pool.absorb(0, trial, value)

    def record_failure(self, trial: Trial, error: str) -> Trial | None:
        """Failed trial: retry (fresh suggestion) or penalize the region."""
        return self.pool.record_failure(0, trial, error)

    def best(self) -> Trial | None:
        return self.pool.best(0)

    def history(self) -> list[dict]:
        return self.pool.history(0)

    def restore(self) -> bool:
        return self.pool.restore()

    # -- objective execution loop -------------------------------------------
    def run(self, objective: Callable[[dict], float], budget: int,
            n_seed: int = 1, executor: ThreadPoolExecutor | None = None,
            parallel: Callable[[], int] | None = None) -> Trial | None:
        """Run until `budget` observations have been absorbed.

        `parallel` is an optional callable re-read each round — the elastic
        width (e.g. the number of currently-healthy pod slices).

        `budget` counts observations absorbed in THIS call (seed trials
        included), in both sequential and parallel modes: a resumed run
        absorbs `budget` *more* on top of the restored posterior.

        A scheduler resumed from a checkpoint (`restore()`, state.n > 0)
        does NOT run its random seed trials again: the restored posterior
        already contains them, so seeding would absorb duplicate points and
        skew the ledger.  Resumed runs go straight to EI suggestions.
        """
        own_pool = executor is None and self.cfg.parallel > 1
        pool = executor or (ThreadPoolExecutor(self.cfg.parallel)
                            if own_pool else None)
        width_fn = parallel or (lambda: self.cfg.parallel)
        resumed = int(self.state.n) > 0 or \
            any(t.status == "done" for t in self.trials)

        try:
            if pool is None:
                # Sequential mode (t = 1).
                done0 = sum(t.status == "done" for t in self.trials)
                if not resumed:
                    # Seeds count toward the per-call budget, so never seed
                    # past it.
                    for tr in self.seed_trials(min(n_seed, budget)):
                        self._run_one(objective, tr)
                while sum(t.status == "done"
                          for t in self.trials) - done0 < budget:
                    tr = self.suggest(1)[0]
                    self._run_one(objective, tr)
                return self.best()

            inflight: dict[Future, Trial] = {}

            def launch(trial: Trial) -> None:
                trial.status = "running"
                trial.started = time.time()
                inflight[pool.submit(objective, trial.hparams)] = trial

            if not resumed:
                for tr in self.seed_trials(min(max(n_seed, 1), budget)):
                    launch(tr)
            absorbed = 0
            while absorbed < budget:
                width = max(1, width_fn())
                while len(inflight) < width and \
                        absorbed + len(inflight) < budget:
                    for tr in self.suggest(1):
                        launch(tr)
                if not inflight:
                    break
                done, _ = wait(inflight, return_when=FIRST_COMPLETED)
                for fut in done:       # async absorption, completion order
                    tr = inflight.pop(fut)
                    try:
                        val = float(fut.result())
                        if not np.isfinite(val):
                            raise FloatingPointError(
                                f"objective returned {val}")
                    except Exception as e:  # noqa: BLE001 — trial fault
                        retry = self.record_failure(
                            tr, f"{type(e).__name__}: {e}")
                        if retry is not None:
                            launch(retry)
                    else:
                        # Scheduler-side errors (capacity, checkpoint IO)
                        # propagate: they are not trial faults to retry.
                        self.absorb(tr, val)
                        absorbed += 1
            return self.best()
        finally:
            if own_pool and pool is not None:
                pool.shutdown(wait=False, cancel_futures=True)

    def _run_one(self, objective, trial: Trial):
        trial.status = "running"
        trial.started = time.time()
        try:
            val = float(objective(trial.hparams))
            if not np.isfinite(val):
                raise FloatingPointError(f"objective returned {val}")
        except Exception as e:  # noqa: BLE001 — trial fault only
            retry = self.record_failure(trial, traceback.format_exc()[-500:]
                                        if not isinstance(e, FloatingPointError)
                                        else str(e))
            if retry is not None:
                self._run_one(objective, retry)
        else:
            # Absorb outside the trial-fault net: a scheduler-side error
            # (GP capacity, checkpoint IO) must propagate, not masquerade as
            # a failed trial and spin the retry loop.
            self.absorb(trial, val)
