"""HPO trial scheduler — the paper's parallel lazy-GP loop, production shape.

The paper's Sec. 3.4 insight: with O(n^2) GP updates, synchronization stops
being the bottleneck, so you can (a) suggest the top-t EI local maxima and
train t models concurrently, and (b) absorb results as *row appends* that
commute under the frozen kernel.  This scheduler turns that into the
1000-node orchestration contract:

  * **async absorption** — results are appended in *completion* order; a
    straggler never blocks the GP or the next suggestion round (suggestions
    can be issued from the current posterior at any time).
  * **fault tolerance** — a failed trial (node crash, NaN loss) produces no
    observation; the scheduler re-suggests from the posterior (optionally
    recording a penalized pseudo-observation so EI avoids a crashing
    region), and the GP state checkpoints with the trial ledger so a
    restarted controller resumes with the identical posterior — and does
    NOT re-run its random seed trials.
  * **elasticity** — the parallel width t is re-read every round, so the
    suggestion batch tracks however many pod-slices are currently healthy.
  * **lag policy** — every `lag` absorbed results, kernel params are refit
    and the factor rebuilt (paper Fig. 6), amortizing the O(n^3) cost.

Since the batched-study refactor (DESIGN.md §7) the scheduler is the S = 1
degenerate case of `repro.hpo.pool.StudyPool`: suggest/absorb/fault/
checkpoint all delegate to a one-study pool, so the scheduler and the
multi-tenant pool share exactly one suggest/absorb code path (the
`StudyEngine` jitted closures).  This module keeps only the objective
execution loop (threads, retries, elastic width).
"""
from __future__ import annotations

import time
import traceback
from concurrent.futures import FIRST_COMPLETED, Future, ThreadPoolExecutor, wait
from typing import Callable

import numpy as np

from repro.core import gp as gp_mod
from repro.hpo.pool import SchedulerConfig, StudyPool, Trial
from repro.hpo.space import SearchSpace

__all__ = ["SchedulerConfig", "Trial", "TrialScheduler"]


class TrialScheduler:
    """Drives `objective(hparams) -> float (maximize)` through the lazy GP."""

    def __init__(self, space: SearchSpace, cfg: SchedulerConfig):
        self.space = space
        self.cfg = cfg
        self.pool = StudyPool([space], cfg, names=["study0"])

    # -- delegation to the shared one-study pool ----------------------------
    @property
    def state(self) -> gp_mod.LazyGPState:
        return self.pool.state(0)

    @property
    def trials(self) -> list[Trial]:
        return self.pool.studies[0].trials

    def seed_trials(self, n: int) -> list[Trial]:
        return self.pool.seed_trials(0, n)

    def suggest(self, t: int | None = None) -> list[Trial]:
        """Top-t distinct EI local maxima from the current posterior."""
        return self.pool.suggest(0, t)

    def _make_trial(self, unit: np.ndarray) -> Trial:
        return self.pool._make_trial(0, unit)

    def absorb(self, trial: Trial, value: float) -> None:
        """O(n^2) row append (order-independent under the frozen kernel)."""
        self.pool.absorb(0, trial, value)

    def record_failure(self, trial: Trial, error: str) -> Trial | None:
        """Failed trial: retry (fresh suggestion) or penalize the region."""
        return self.pool.record_failure(0, trial, error)

    def best(self) -> Trial | None:
        return self.pool.best(0)

    def history(self) -> list[dict]:
        return self.pool.history(0)

    def restore(self) -> bool:
        return self.pool.restore()

    # -- objective execution loop -------------------------------------------
    def run(self, objective: Callable[[dict], float], budget: int,
            n_seed: int = 1, executor: ThreadPoolExecutor | None = None,
            parallel: Callable[[], int] | None = None) -> Trial | None:
        """Run until `budget` observations have been absorbed.

        `parallel` is an optional callable re-read each round — the elastic
        width (e.g. the number of currently-healthy pod slices).

        `budget` counts observations absorbed in THIS call (seed trials
        included), in both sequential and parallel modes: a resumed run
        absorbs `budget` *more* on top of the restored posterior.

        A scheduler resumed from a checkpoint (`restore()`, state.n > 0)
        does NOT run its random seed trials again: the restored posterior
        already contains them, so seeding would absorb duplicate points and
        skew the ledger.  Resumed runs go straight to EI suggestions.
        """
        own_pool = executor is None and self.cfg.parallel > 1
        pool = executor or (ThreadPoolExecutor(self.cfg.parallel)
                            if own_pool else None)
        width_fn = parallel or (lambda: self.cfg.parallel)
        resumed = int(self.state.n) > 0 or \
            any(t.status == "done" for t in self.trials)

        try:
            if pool is None:
                # Sequential mode (t = 1).
                done0 = sum(t.status == "done" for t in self.trials)
                if not resumed:
                    # Seeds count toward the per-call budget, so never seed
                    # past it.
                    for tr in self.seed_trials(min(n_seed, budget)):
                        self._run_one(objective, tr)
                while sum(t.status == "done"
                          for t in self.trials) - done0 < budget:
                    tr = self.suggest(1)[0]
                    self._run_one(objective, tr)
                return self.best()

            inflight: dict[Future, Trial] = {}

            def launch(trial: Trial) -> None:
                trial.status = "running"
                trial.started = time.time()
                inflight[pool.submit(objective, trial.hparams)] = trial

            if not resumed:
                for tr in self.seed_trials(min(max(n_seed, 1), budget)):
                    launch(tr)
            absorbed = 0
            while absorbed < budget:
                width = max(1, width_fn())
                while len(inflight) < width and \
                        absorbed + len(inflight) < budget:
                    for tr in self.suggest(1):
                        launch(tr)
                if not inflight:
                    break
                done, _ = wait(inflight, return_when=FIRST_COMPLETED)
                for fut in done:       # async absorption, completion order
                    tr = inflight.pop(fut)
                    try:
                        val = float(fut.result())
                        if not np.isfinite(val):
                            raise FloatingPointError(
                                f"objective returned {val}")
                    except Exception as e:  # noqa: BLE001 — trial fault
                        retry = self.record_failure(
                            tr, f"{type(e).__name__}: {e}")
                        if retry is not None:
                            launch(retry)
                    else:
                        # Scheduler-side errors (capacity, checkpoint IO)
                        # propagate: they are not trial faults to retry.
                        self.absorb(tr, val)
                        absorbed += 1
            return self.best()
        finally:
            if own_pool and pool is not None:
                pool.shutdown(wait=False, cancel_futures=True)

    def _run_one(self, objective, trial: Trial):
        trial.status = "running"
        trial.started = time.time()
        try:
            val = float(objective(trial.hparams))
            if not np.isfinite(val):
                raise FloatingPointError(f"objective returned {val}")
        except Exception as e:  # noqa: BLE001 — trial fault only
            retry = self.record_failure(trial, traceback.format_exc()[-500:]
                                        if not isinstance(e, FloatingPointError)
                                        else str(e))
            if retry is not None:
                self._run_one(objective, retry)
        else:
            # Absorb outside the trial-fault net: a scheduler-side error
            # (GP capacity, checkpoint IO) must propagate, not masquerade as
            # a failed trial and spin the retry loop.
            self.absorb(trial, val)
