"""Cross-host federation transport: shard workers behind socket RPC.

`FederatedGateway` (DESIGN.md §13) time-slices its N shard tickers on one
event loop — horizontal in bookkeeping, vertical in wall-clock.  This
module is the cross-host deployment of the SAME federation core
(DESIGN.md §14): one `StudyGateway` per *worker process*, each hosting
its own ticker, jit cache, and checkpoint store, fronted by a
`TransportFederation` that routes every call over a socket instead of a
method call.  Per-shard rounds finally overlap in wall-clock — the
paper's parallel strong-scaling shape (fleet-scale BO serving à la
Snoek et al.), with the surrogate distributed by study.

Layers:

  * **frame codec** — length-prefixed JSON frames (4-byte big-endian
    size + UTF-8 JSON body).  Everything on the wire is JSON-safe by
    construction: registry records, trial dicts (`unit` as a list), and
    config specs.  A truncated frame is a connection error, never a
    half-parsed request.  Requests and replies posted within one event-
    loop pass coalesce into a single `{"batch": [...]}` frame (one
    syscall carries a whole round of asks or a tick's worth of replies)
    — the wire-level twin of the gateway's coalescing tick, and the
    reason per-suggestion RPC overhead amortizes with round width.
  * **`ShardServer` / worker** — `python -m repro.hpo.shard_worker
    --ckpt-dir DIR` builds a StudyGateway from `DIR/spec.json`, restores
    from ITS latest epoch, then serves the public gateway surface as
    RPC ops.  `ask`/`drain` run as per-request asyncio tasks (they park
    on the ticker), so one connection multiplexes many concurrent asks —
    the coalescing tick sees the same concurrency as in-process clients.
    The bind address is published to `DIR/endpoint.json` (written
    atomically AFTER the server is listening and the gateway restored).
  * **`ShardClient`** — request-id multiplexed caller.  When the
    connection dies (EOF, reset, or the front end marks the shard dead
    on missed heartbeats), parked `ask` futures are CANCELLED — the
    exact `kill_shard` semantics of the in-memory federation — while
    control-plane calls fail loudly with `ShardConnectionError`.
  * **`TransportFederation`** — `FederationBase` applied over RPC.
    Shard workers are spawned as subprocesses, or adopted with
    `TransportConfig.connect = ("host:port", ...)` for workers started
    by an operator on other hosts.  All stores live under ONE shared
    root (NFS or equivalent): migration is the committed-snapshot
    protocol unchanged — export (quiesce + evict) on the source over
    RPC, `copy_study_version` across the shared root by the front end,
    adopt on the destination, detach from the source, in that order.
    Failover is health-check driven: `miss_limit` missed pings mark a
    shard dead; `revive_shard` kills any zombie process first (a
    half-dead writer must never touch the store again), respawns, lets
    the worker restore from its own epoch, and reconciles it against
    the federation registry over RPC — identical recovery law to
    `FederatedGateway.revive_shard`.

Trial identity over the wire: the worker keeps every suggestion it
handed out in an `(sid, trial_id)` outstanding map; a `tell` resolves
against that map (so the absorb sees the exact object the ticker
produced), moves the key to a resolved set (replays are rejected with
the same "exactly one tell" error as in-process), and tells for trials
this worker never handed out (foreign results, cf. DESIGN.md §9) are
reconstructed from their wire form and validated by the normal path.
"""
from __future__ import annotations

import argparse
import asyncio
import base64
import dataclasses
import hashlib
import inspect
import json
import os
import struct
import subprocess
import sys
import time

import numpy as np

from repro import checkpoint as ckpt_mod
from repro.core import acquisition as acq_mod
from repro.core import gp as gp_mod
from repro.core import neural_basis as nb_mod
from repro.hpo.federation import (FederationBase, FederationConfig)
from repro.hpo.gateway import GatewayConfig, StudyGateway
from repro.hpo.pool import SchedulerConfig, Trial
from repro.hpo.space import SearchSpace, space_from_dicts, space_to_dicts

__all__ = ["TransportConfig", "TransportFederation", "ShardServer",
           "ShardClient", "TransportError", "ShardConnectionError",
           "encode_frame", "read_frame", "build_spec", "gateway_from_spec"]

_MAX_FRAME = 64 << 20  # 64 MiB: larger is a protocol bug, not a payload
ENDPOINT_FILE = "endpoint.json"
SPEC_FILE = "spec.json"


class TransportError(RuntimeError):
    """Malformed traffic on a shard connection (oversized/garbled frame,
    unknown op, worker failed to come up)."""


class ShardConnectionError(TransportError):
    """The connection to a shard worker is gone (EOF/reset, or the front
    end marked the shard dead on missed heartbeats).  Parked asks are
    cancelled instead — see `ShardClient`."""


# -- frame codec -------------------------------------------------------------
def encode_frame(obj: dict) -> bytes:
    """4-byte big-endian length + compact-JSON body."""
    body = json.dumps(obj, separators=(",", ":")).encode()
    if len(body) > _MAX_FRAME:
        raise TransportError(f"frame of {len(body)} bytes exceeds the "
                             f"{_MAX_FRAME}-byte cap")
    return struct.pack(">I", len(body)) + body


async def read_frame(reader: asyncio.StreamReader) -> dict:
    """One complete frame or an exception — never a partial parse.
    Truncation surfaces as `asyncio.IncompleteReadError` (the peer died
    mid-frame); an oversized or non-JSON body is a `TransportError` (the
    stream is desynchronized and the connection must drop)."""
    hdr = await reader.readexactly(4)
    (size,) = struct.unpack(">I", hdr)
    if size > _MAX_FRAME:
        raise TransportError(
            f"incoming frame claims {size} bytes (cap {_MAX_FRAME}); "
            "stream is desynchronized")
    body = await reader.readexactly(size)
    try:
        return json.loads(body)
    except ValueError as e:
        raise TransportError(f"undecodable frame body: {e}") from None


# Errors re-raised client-side with their original type where the type is
# part of the gateway's contract (admission control raises GPCapacityError,
# unknown sids raise KeyError, ...).  Anything else degrades to
# TransportError with the worker-side type in the message.
_WIRE_ERRORS = {
    "GPCapacityError": gp_mod.GPCapacityError,
    # the capacity taxonomy (DESIGN.md §15) crosses the wire intact:
    # clients distinguish a terminal saturation (stop asking / escalate)
    # from retryable backpressure by TYPE, not by message parsing
    "StudySaturatedError": gp_mod.StudySaturatedError,
    "BackpressureError": gp_mod.BackpressureError,
    "KeyError": KeyError,
    "ValueError": ValueError,
    "RuntimeError": RuntimeError,
    "FileNotFoundError": FileNotFoundError,
}


def _decode_error(msg: dict) -> Exception:
    etype = msg.get("etype", "")
    text = msg.get("error", "")
    cls = _WIRE_ERRORS.get(etype)
    if cls is KeyError:
        # KeyError reprs with quotes; the worker sent str(e) which is the
        # quoted message — strip one level so the text round-trips
        return KeyError(text.strip("'\""))
    if cls is not None:
        return cls(text)
    return TransportError(f"shard worker raised {etype}: {text}")


def study_state_digest(pool, slot: int) -> str:
    """sha256 over every leaf of one slot's GP state (leaf-path sorted).
    The wire-safe BITWISE comparison surface: two gateways serving the
    same study identically must agree on this digest exactly — the
    equivalence suites compare it across process boundaries where the
    raw buffers can't travel."""
    import jax
    st = pool.engine.study_state(slot)
    leaves = {jax.tree_util.keystr(path): np.asarray(leaf).tobytes()
              for path, leaf in
              jax.tree_util.tree_flatten_with_path(st)[0]}
    h = hashlib.sha256()
    for k in sorted(leaves):
        h.update(k.encode())
        h.update(leaves[k])
    return h.hexdigest()


# -- trial wire form ---------------------------------------------------------
def trial_to_wire(tr: Trial) -> dict:
    # unit travels as base64 of the raw float32 buffer: exact bit
    # round-trip (the equivalence suites compare BITWISE) and far cheaper
    # than per-float decimal repr on the per-suggestion hot path
    unit = np.ascontiguousarray(np.asarray(tr.unit, np.float32))
    return {"trial_id": tr.trial_id,
            "unit_b64": base64.b64encode(unit.tobytes()).decode("ascii"),
            "hparams": tr.hparams, "status": tr.status,
            "value": tr.value, "error": tr.error, "cost": tr.cost}


def trial_from_wire(d: dict) -> Trial:
    if "unit_b64" in d:
        unit = np.frombuffer(base64.b64decode(d["unit_b64"]),
                             np.float32).copy()
    else:  # hand-built wire dicts (tests, foreign tells) may use a list
        unit = np.asarray(d["unit"], np.float32)
    return Trial(int(d["trial_id"]), unit,
                 d.get("hparams") or {}, d.get("status", "pending"),
                 d.get("value"), d.get("error"),
                 cost=float(d.get("cost", 1.0)))


# -- config spec (front end -> worker) ---------------------------------------
def build_spec(space: SearchSpace, cfg: SchedulerConfig,
               gw: GatewayConfig | None = None) -> dict:
    """JSON-safe worker spec: the template space plus both config
    dataclasses.  `ckpt_dir` is intentionally dropped — each worker's
    store is its own `--ckpt-dir` (the shard dir under the shared root),
    never a value serialized on another host."""
    sched = dataclasses.asdict(cfg)
    sched.pop("ckpt_dir")
    return {"space": space_to_dicts(space), "scheduler": sched,
            "gateway": dataclasses.asdict(gw or GatewayConfig())}


def gateway_from_spec(spec: dict, ckpt_dir: str) -> StudyGateway:
    sched = dict(spec["scheduler"])
    sched["acq"] = acq_mod.AcqConfig(**sched["acq"])
    sched["fantasy"] = gp_mod.FantasyConfig(**sched["fantasy"])
    if "neural" in sched:   # older front ends predate the escalation tier
        sched["neural"] = nb_mod.NeuralConfig(**sched["neural"])
    cfg = SchedulerConfig(ckpt_dir=ckpt_dir, **sched)
    space = space_from_dicts(spec["space"])
    return StudyGateway(space, cfg, GatewayConfig(**spec["gateway"]))


def _write_json_atomic(path: str, obj: dict) -> None:
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(obj, f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


# -- wire-level micro-batching -----------------------------------------------
class _BatchWriter:
    """Coalesce every message posted within one event-loop pass into a
    single `{"batch": [...]}` frame (one write syscall).

    `post()` is synchronous: a burst of replies resolved by one tick
    finish — or a round of asks submitted by one `gather` — lands in the
    buffer before the flusher task runs, so the whole burst travels as
    one frame.  Connection errors are swallowed here and surface on the
    reader side (`read_frame` EOF), which owns connection teardown."""

    def __init__(self, writer: asyncio.StreamWriter,
                 on_error=None) -> None:
        self._writer = writer
        self._buf: list[dict] = []
        self._task: asyncio.Task | None = None
        self._on_error = on_error

    def post(self, msg: dict) -> None:
        self._buf.append(msg)
        if self._task is None or self._task.done():
            self._task = asyncio.ensure_future(self._flush())

    async def _flush(self) -> None:
        try:
            while self._buf:
                out, self._buf = self._buf, []
                frame = out[0] if len(out) == 1 else {"batch": out}
                self._writer.write(encode_frame(frame))
                await self._writer.drain()
        except (ConnectionError, OSError) as e:
            self._buf = []
            if self._on_error is not None:
                self._on_error(e)

    async def aflush(self) -> None:
        """Wait for everything already posted to hit the socket (used
        before an orderly connection close, e.g. after a shutdown op)."""
        while self._task is not None and not self._task.done():
            await asyncio.shield(self._task)


def _unbatch(msg: dict) -> list[dict]:
    batch = msg.get("batch")
    return batch if isinstance(batch, list) else [msg]


# -- the worker-side server --------------------------------------------------
class ShardServer:
    """Serve one StudyGateway's public surface as RPC ops.

    `ask` and `drain` park on the gateway ticker, so they run as
    per-request tasks — many asks multiplex on one connection and
    coalesce in the worker's tick exactly like in-process clients.
    Control-plane ops run inline, preserving per-connection order (a
    migration's export/adopt/detach sequence must not reorder).
    Dropping a connection cancels its in-flight ask tasks; the gateway
    already tolerates externally-cancelled ask futures (their
    suggestions are released at serve time)."""

    _TASK_OPS = frozenset({"ask", "drain"})

    def __init__(self, gateway: StudyGateway, host: str = "127.0.0.1",
                 port: int = 0):
        self.gw = gateway
        self._host, self._port = host, port
        self._server: asyncio.AbstractServer | None = None
        self._stop = asyncio.Event()
        # suggestions handed out but not yet resolved, by global identity
        self._outstanding: dict[tuple[int, int], Trial] = {}
        self._resolved: set[tuple[int, int]] = set()

    @property
    def address(self) -> tuple[str, int]:
        assert self._server is not None, "server not started"
        host, port = self._server.sockets[0].getsockname()[:2]
        return host, port

    async def start(self) -> tuple[str, int]:
        self._server = await asyncio.start_server(
            self._serve_conn, self._host, self._port)
        return self.address

    async def serve_until_shutdown(self) -> None:
        await self._stop.wait()
        self._server.close()
        await self._server.wait_closed()
        await self.gw.aclose()

    # -- connection loop --
    async def _serve_conn(self, reader: asyncio.StreamReader,
                          writer: asyncio.StreamWriter) -> None:
        out = _BatchWriter(writer)
        tasks: set[asyncio.Task] = set()
        try:
            while True:
                try:
                    frame = await read_frame(reader)
                except (asyncio.IncompleteReadError, TransportError,
                        ConnectionError, OSError):
                    break  # truncated/garbled/dropped: this conn is done
                shutdown = False
                for req in _unbatch(frame):
                    if req.get("op") in self._TASK_OPS:
                        t = asyncio.ensure_future(self._handle(req, out))
                        tasks.add(t)
                        t.add_done_callback(tasks.discard)
                    else:
                        await self._handle(req, out)
                        if req.get("op") == "shutdown":
                            shutdown = True
                if shutdown:
                    await out.aflush()  # the ack must beat the close
                    break
        finally:
            for t in tasks:  # cancel parked asks; the gateway releases
                t.cancel()   # their suggestions at serve time
            writer.close()
            try:
                await writer.wait_closed()
            except (ConnectionError, OSError):
                pass

    async def _handle(self, req: dict, out: _BatchWriter) -> None:
        rid = req.get("id")
        op = req.get("op", "")
        try:
            fn = getattr(self, f"_op_{op}", None)
            if fn is None:
                raise TransportError(f"unknown op {op!r}")
            res = fn(**(req.get("args") or {}))
            if inspect.isawaitable(res):
                res = await res
            reply = {"id": rid, "ok": True, "result": res}
        except asyncio.CancelledError:
            raise
        except Exception as e:  # noqa: BLE001 — every gateway error maps
            reply = {"id": rid, "ok": False,
                     "etype": type(e).__name__, "error": str(e)}
        out.post(reply)

    # -- tell identity --
    def _resolve_told(self, sid: int, wire: dict) -> Trial:
        key = (sid, int(wire["trial_id"]))
        if key in self._resolved:
            raise RuntimeError(
                f"trial {key[1]} of study {sid} was already told; "
                "each suggestion takes exactly one tell")
        tr = self._outstanding.get(key)
        if tr is None:
            # a result this worker never suggested (foreign trial):
            # reconstruct and let the normal validation path judge it
            return trial_from_wire(wire)
        return tr

    def _mark_resolved(self, sid: int, wire: dict) -> None:
        key = (sid, int(wire["trial_id"]))
        if self._outstanding.pop(key, None) is not None:
            self._resolved.add(key)

    # -- ops --
    def _op_ping(self) -> dict:
        return {"t": time.time(), "studies": len(self.gw.study_ids())}

    def _op_create_study(self, dims=None, name=None, sid=None):
        space = space_from_dicts(dims) if dims is not None else None
        return self.gw.create_study(space, name, sid=sid)

    def _op_close_study(self, sid):
        self.gw.close_study(sid)

    async def _op_ask(self, sid, q=1):
        res = await self.gw.ask(sid, q)
        trials = res if isinstance(res, list) else [res]
        for tr in trials:
            self._outstanding[(sid, tr.trial_id)] = tr
        return [trial_to_wire(tr) for tr in trials]

    def _op_tell(self, sid, trial, value, cost=1.0):
        tr = self._resolve_told(sid, trial)
        self.gw.tell(sid, tr, value, cost)
        self._mark_resolved(sid, trial)  # only after tell() accepted

    def _op_tell_failure(self, sid, trial, error):
        tr = self._resolve_told(sid, trial)
        self.gw.tell_failure(sid, tr, error)
        self._mark_resolved(sid, trial)

    async def _op_drain(self):
        await self.gw.drain()

    def _op_study_ids(self):
        return self.gw.study_ids()

    def _op_study_info(self, sid):
        return self.gw.study_info(sid)

    def _op_summary(self):
        return self.gw.summary()

    def _op_is_quiescent(self, sid):
        return self.gw.is_quiescent(sid)

    def _op_registry_record(self, sid):
        return self.gw.registry_record(sid)

    def _op_registry_records(self):
        return {str(sid): self.gw.registry_record(sid)
                for sid in self.gw.study_ids()}

    def _op_export_for_migration(self, sid):
        return self.gw.export_for_migration(sid)

    def _op_adopt_study(self, record, require_snapshot=True):
        self.gw.adopt_study(record, require_snapshot=require_snapshot)

    def _op_detach_study(self, sid):
        self.gw.detach_study(sid)

    def _op_expel_study(self, sid):
        self.gw.expel_study(sid)

    def _op_sync_registry(self, next_sid=None, closed_sids=()):
        self.gw.sync_registry(next_sid, closed_sids)

    def _op_checkpoint(self):
        return self.gw.checkpoint() is not None

    def _op_ledger(self, sid):
        """Resident ledger rows (bitwise-comparison surface for the
        equivalence tests); None when the study is evicted — its ledger
        lives in the snapshot."""
        info = self.gw.study_info(sid)
        if info["slot"] is None:
            return None
        return self.gw.pool.history(info["slot"])

    def _op_state_digest(self, sid):
        """Bitwise GP-state digest of a RESIDENT study (None when
        evicted) — see `study_state_digest`."""
        info = self.gw.study_info(sid)
        if info["slot"] is None:
            return None
        return study_state_digest(self.gw.pool, info["slot"])

    def _op_shutdown(self):
        self._stop.set()
        return True


# -- worker entry point ------------------------------------------------------
async def _worker_main(ckpt_dir: str, spec_path: str, host: str,
                       port: int) -> None:
    with open(spec_path) as f:
        spec = json.load(f)
    gw = gateway_from_spec(spec, ckpt_dir)
    restored = gw.restore()
    server = ShardServer(gw, host, port)
    bound_host, bound_port = await server.start()
    # publish the endpoint LAST — its existence means "restored and
    # accepting"; atomic so the front end never reads a partial file
    _write_json_atomic(os.path.join(ckpt_dir, ENDPOINT_FILE),
                       {"host": bound_host, "port": bound_port,
                        "pid": os.getpid(), "restored": restored})
    print(f"[shard-worker pid={os.getpid()}] serving "
          f"{bound_host}:{bound_port} store={ckpt_dir} "
          f"restored={restored}", file=sys.stderr, flush=True)
    await server.serve_until_shutdown()


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        description="repro federation shard worker: one StudyGateway "
                    "behind length-prefixed JSON-frame RPC")
    ap.add_argument("--ckpt-dir", required=True,
                    help="this shard's checkpoint store (a shard dir "
                         "under the shared federation root)")
    ap.add_argument("--spec", default=None,
                    help="gateway spec JSON (default <ckpt-dir>/spec.json)")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="0 = ephemeral (published in endpoint.json)")
    args = ap.parse_args(argv)
    spec = args.spec or os.path.join(args.ckpt_dir, SPEC_FILE)
    asyncio.run(_worker_main(args.ckpt_dir, spec, args.host, args.port))
    return 0


# -- the front-end client ----------------------------------------------------
class ShardClient:
    """One multiplexed connection to a shard worker.

    Requests carry monotonically increasing ids; a reader task resolves
    response futures out of order (many asks park server-side while
    control calls keep flowing).  Death semantics mirror the in-memory
    federation's `kill_shard`: when the connection is lost or the front
    end marks the shard dead, parked `ask` futures are CANCELLED (those
    clients re-ask elsewhere/later; the per-study PRNG streams make the
    retried round fresh), while every other pending call fails with
    `ShardConnectionError` — a migration step must abort loudly, not
    silently vanish."""

    def __init__(self, reader: asyncio.StreamReader,
                 writer: asyncio.StreamWriter, host: str, port: int):
        self._reader, self._writer = reader, writer
        self.host, self.port = host, port
        self._out = _BatchWriter(writer, on_error=self._send_failed)
        self._next_id = 0
        self._pending: dict[int, tuple[str, asyncio.Future]] = {}
        self._dead: str | None = None
        self._reader_task = asyncio.ensure_future(self._read_loop())

    @classmethod
    async def connect(cls, host: str, port: int,
                      timeout: float = 10.0) -> "ShardClient":
        reader, writer = await asyncio.wait_for(
            asyncio.open_connection(host, port), timeout)
        return cls(reader, writer, host, port)

    @property
    def alive(self) -> bool:
        return self._dead is None

    async def _read_loop(self) -> None:
        try:
            while True:
                frame = await read_frame(self._reader)
                for msg in _unbatch(frame):
                    ent = self._pending.pop(msg.get("id"), None)
                    if ent is None:
                        continue  # late reply for a timed-out/cancelled call
                    _op, fut = ent
                    if fut.done():
                        continue
                    if msg.get("ok"):
                        fut.set_result(msg.get("result"))
                    else:
                        fut.set_exception(_decode_error(msg))
        except (asyncio.IncompleteReadError, TransportError,
                ConnectionError, OSError) as e:
            self._fail_pending(
                f"connection to shard worker {self.host}:{self.port} "
                f"lost ({type(e).__name__}: {e})")
        except asyncio.CancelledError:
            self._fail_pending(self._dead or "shard connection closed")
            raise

    def _send_failed(self, exc: Exception) -> None:
        self._fail_pending(
            f"connection to shard worker {self.host}:{self.port} "
            f"lost mid-send ({type(exc).__name__}: {exc})")

    def _fail_pending(self, reason: str) -> None:
        if self._dead is None:
            self._dead = reason
        pending, self._pending = self._pending, {}
        for op, fut in pending.values():
            if fut.done():
                continue
            if op == "ask":
                fut.cancel()  # kill_shard semantics for parked clients
            else:
                fut.set_exception(ShardConnectionError(reason))

    async def call(self, op: str, _timeout: float | None = None, **args):
        if self._dead is not None:
            raise ShardConnectionError(self._dead)
        rid = self._next_id
        self._next_id += 1
        fut: asyncio.Future = asyncio.get_running_loop().create_future()
        self._pending[rid] = (op, fut)
        # posted, not written: every call issued in the same loop pass
        # (a gather'd round of asks, a burst of tells) rides ONE frame.
        # A send failure surfaces through `_fail_pending` on every
        # pending future, this one included.
        self._out.post({"id": rid, "op": op, "args": args})
        if _timeout is None:
            return await fut
        try:
            return await asyncio.wait_for(fut, _timeout)
        finally:
            self._pending.pop(rid, None)

    def close(self, reason: str = "shard connection closed") -> None:
        self._fail_pending(reason)
        self._reader_task.cancel()
        try:
            self._writer.close()
        except (ConnectionError, OSError):
            pass


# -- the front end -----------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class TransportConfig:
    """Cross-host deployment knobs (routing/registry shape still comes
    from `FederationConfig`)."""

    heartbeat_s: float = 0.0      # background health-check period; 0 =
    # no background task, call check_health() explicitly (tests drive
    # failover deterministically this way)
    heartbeat_timeout_s: float = 1.0  # per-ping reply deadline
    miss_limit: int = 3           # consecutive missed pings -> dead
    spawn_timeout_s: float = 180.0  # worker import+restore+bind budget
    connect: tuple = ()           # adopt operator-started workers: one
    # "host:port" per shard index ("" = spawn that shard locally).
    # Adopted workers must already serve --ckpt-dir <root>/shard-<i> on
    # the SAME shared store root the front end mounts.
    python: str = sys.executable  # interpreter for spawned workers


class TransportFederation(FederationBase):
    """`FederatedGateway` over sockets: same routing, same epochs, same
    recovery law — the shards just live in other processes (one worker
    per host in a real deployment).  The whole surface is async (every
    call may cross a machine boundary), including `tell`."""

    def __init__(self, template_space: SearchSpace, cfg: SchedulerConfig,
                 gw: GatewayConfig | None = None,
                 fed: FederationConfig | None = None,
                 transport: TransportConfig | None = None):
        super().__init__(template_space, cfg, gw, fed)
        self.transport = transport or TransportConfig()
        if self.transport.connect and \
                len(self.transport.connect) != self.fed.n_shards:
            raise ValueError(
                f"TransportConfig.connect has "
                f"{len(self.transport.connect)} entries for "
                f"{self.fed.n_shards} shards (use '' to spawn a shard)")
        self.clients: list[ShardClient | None] = [None] * self.fed.n_shards
        self.procs: list[subprocess.Popen | None] = [None] * self.fed.n_shards
        self._misses = [0] * self.fed.n_shards
        self._health_task: asyncio.Task | None = None
        self._started = False

    # -- lifecycle --
    async def start(self) -> bool:
        """Bring the federation up: load the latest federation epoch if
        one exists (fail-fast on an n_shards mismatch), spawn/adopt every
        shard worker (each restores from ITS own epoch), and reconcile
        restored shards against the registry.  Returns True when a
        federation epoch was restored."""
        restored = self._load_epoch()
        for i in range(self.fed.n_shards):
            await self._start_shard(i)
        if restored:
            for i in range(self.fed.n_shards):
                await self._reconcile_shard_rpc(i)
        if self.transport.heartbeat_s > 0:
            self._health_task = asyncio.ensure_future(self._health_loop())
        self._started = True
        return restored

    async def _start_shard(self, i: int) -> None:
        endpoint = self.transport.connect[i] \
            if self.transport.connect else ""
        if endpoint:
            host, port = endpoint.rsplit(":", 1)
            self.clients[i] = await ShardClient.connect(host, int(port))
        else:
            self.clients[i] = await self._spawn_shard(i)
        self._misses[i] = 0

    async def _spawn_shard(self, i: int) -> ShardClient:
        d = self.shard_dir(i)
        os.makedirs(d, exist_ok=True)
        _write_json_atomic(os.path.join(d, SPEC_FILE),
                           build_spec(self._template_space, self.cfg,
                                      self.gw))
        ep_path = os.path.join(d, ENDPOINT_FILE)
        if os.path.exists(ep_path):
            os.unlink(ep_path)
        # the worker must import `repro` however the front end did (the
        # parent's sys.path does not inherit): prepend the package root
        import repro
        pkg_root = os.path.dirname(os.path.dirname(repro.__file__))
        env = dict(os.environ)
        env["PYTHONPATH"] = pkg_root + os.pathsep + env.get("PYTHONPATH", "")
        proc = subprocess.Popen(
            [self.transport.python, "-m", "repro.hpo.shard_worker",
             "--ckpt-dir", d], env=env)
        self.procs[i] = proc
        loop = asyncio.get_running_loop()
        deadline = loop.time() + self.transport.spawn_timeout_s
        while not os.path.exists(ep_path):
            if proc.poll() is not None:
                raise TransportError(
                    f"shard {i} worker exited rc={proc.returncode} "
                    "before publishing its endpoint")
            if loop.time() > deadline:
                proc.kill()
                raise TransportError(
                    f"shard {i} worker did not publish {ep_path} within "
                    f"{self.transport.spawn_timeout_s}s")
            await asyncio.sleep(0.05)
        with open(ep_path) as f:
            info = json.load(f)
        return await ShardClient.connect(info["host"], info["port"])

    async def aclose(self) -> None:
        if self._health_task is not None:
            self._health_task.cancel()
            self._health_task = None
        for i, c in enumerate(self.clients):
            if c is None:
                continue
            try:
                await c.call("shutdown", _timeout=10.0)
            except (TransportError, asyncio.TimeoutError,
                    asyncio.CancelledError):
                pass
            c.close()
            self.clients[i] = None
        for i, p in enumerate(self.procs):
            if p is None:
                continue
            try:
                p.wait(timeout=15.0)
            except subprocess.TimeoutExpired:
                p.kill()
                p.wait()
            self.procs[i] = None

    # -- routing plumbing --
    def _live(self, i: int) -> ShardClient:
        c = self.clients[i]
        if c is None:
            raise RuntimeError(f"shard {i} is down; "
                               "revive_shard to restore it from its epoch")
        return c

    def _client_for(self, sid: int) -> ShardClient:
        return self._live(self.shard_of(sid))

    def _live_clients(self) -> list[tuple[int, ShardClient]]:
        return [(i, c) for i, c in enumerate(self.clients) if c is not None]

    # -- study surface --
    async def create_study(self, space: SearchSpace | None = None,
                           name: str | None = None) -> int:
        sid = self._next_sid
        shard = self.route(sid)
        dims = space_to_dicts(space) if space is not None else None
        await self._live(shard).call("create_study", dims=dims, name=name,
                                     sid=sid)
        self._next_sid = sid + 1
        self._placement[sid] = shard
        return sid

    async def close_study(self, sid: int) -> None:
        await self._client_for(sid).call("close_study", sid=sid)
        self._placement.pop(sid, None)
        self._records.pop(sid, None)
        self._closed_sids.add(sid)

    async def ask(self, sid: int, q: int = 1) -> Trial | list[Trial]:
        wires = await self._client_for(sid).call("ask", sid=sid, q=q)
        trials = [trial_from_wire(w) for w in wires]
        return trials if q > 1 else trials[0]

    @staticmethod
    def _tell_wire(trial: Trial) -> dict:
        # tells resolve server-side by (sid, trial_id) against the
        # worker's outstanding map — hparams are derived state the worker
        # recomputes for foreign trials, so don't pay their encode cost
        # on the per-result hot path
        wire = trial_to_wire(trial)
        wire["hparams"] = {}
        return wire

    async def tell(self, sid: int, trial: Trial, value: float,
                   cost: float = 1.0) -> None:
        if trial.status not in ("pending", "running"):
            # same replay law as the in-memory path, without a round trip
            raise RuntimeError(
                f"trial {trial.trial_id} of study {sid} was already told "
                f"({trial.status}); each suggestion takes exactly one tell")
        await self._client_for(sid).call(
            "tell", sid=sid, trial=self._tell_wire(trial),
            value=float(value), cost=float(cost))
        trial.status = "told"  # the worker's copy is authoritative

    async def tell_failure(self, sid: int, trial: Trial,
                           error: str) -> None:
        await self._client_for(sid).call(
            "tell_failure", sid=sid, trial=self._tell_wire(trial),
            error=str(error))
        trial.status = "failed"
        trial.error = str(error)

    async def drain(self) -> None:
        await asyncio.gather(*(c.call("drain")
                               for _i, c in self._live_clients()))

    # -- introspection --
    async def study_info(self, sid: int) -> dict:
        info = await self._client_for(sid).call("study_info", sid=sid)
        info["shard"] = self.shard_of(sid)
        return info

    async def summary(self) -> dict:
        per_shard = {}
        for i, c in self._live_clients():
            per_shard[i] = await c.call("summary")
        return self._merge_summaries(
            per_shard, [i for i, c in enumerate(self.clients) if c is None])

    # -- migration / rebalancing --
    async def migrate_study(self, sid: int, dst: int) -> None:
        """The committed-snapshot migration over RPC.  The front end does
        the store-to-store copy itself (it mounts the shared root), so
        the protocol and its all-or-nothing guarantee are unchanged:
        export evicts into the source shard's store, the copy publishes
        COMMITTED-last into the destination store, adoption refuses
        without that committed version, and only then does the source
        detach.  A front-end crash mid-sequence leaves at worst a
        benign double-registration that the next restore reconciles
        (placement still names the source, so the destination's copy is
        expelled — see DESIGN.md §14)."""
        src = self.shard_of(sid)
        if dst == src:
            return
        src_c, dst_c = self._live(src), self._live(dst)
        record = await src_c.call("export_for_migration", sid=sid)
        if record["evicted_ever"]:
            ckpt_mod.copy_study_version(self.shard_dir(src),
                                        self.shard_dir(dst),
                                        record["key"], record["version"])
        await dst_c.call("adopt_study", record=record,
                         require_snapshot=True)
        await src_c.call("detach_study", sid=sid)
        self._placement[sid] = dst
        self._records[sid] = dict(record, shard=dst)

    async def rebalance(self) -> list[tuple[int, int, int]]:
        moves: list[tuple[int, int, int]] = []
        live = [i for i, c in enumerate(self.clients) if c is not None]
        if len(live) < 2:
            return moves
        while True:
            counts = {i: sum(1 for s in self._placement.values() if s == i)
                      for i in live}
            src = max(live, key=lambda i: (counts[i], i))
            dst = min(live, key=lambda i: (counts[i], i))
            if counts[src] - counts[dst] <= 1:
                return moves
            movable = []
            for sid, s in sorted(self._placement.items()):
                if s == src and await self._live(src).call(
                        "is_quiescent", sid=sid):
                    movable.append(sid)
                    break  # lowest sid wins; no need to scan the rest
            if not movable:
                return moves
            await self.migrate_study(movable[0], dst)
            moves.append((movable[0], src, dst))

    # -- epochs: checkpoint / failover / restore --
    async def _collect_records(self) -> dict[int, dict]:
        by_shard: dict[int, dict] = {}
        for i, c in self._live_clients():
            by_shard[i] = await c.call("registry_records")
        records: dict[int, dict] = {}
        for sid, shard in sorted(self._placement.items()):
            rec = by_shard.get(shard, {}).get(str(sid))
            if rec is not None:
                records[sid] = dict(rec, shard=shard)
            elif sid in self._records:
                records[sid] = self._records[sid]
        return records

    async def checkpoint(self) -> int:
        """Federation epoch over RPC: registry commits FIRST (front-end
        write to the shared root), then each live shard snapshots its own
        store.  Dead shards are skipped — their fallback records ride
        the registry."""
        epoch = self._save_epoch(await self._collect_records())
        for _i, c in self._live_clients():
            await c.call("checkpoint")
        return epoch

    def _mark_dead(self, i: int, reason: str) -> None:
        c = self.clients[i]
        self.clients[i] = None
        if c is not None:
            c.close(reason)

    async def check_health(self) -> list[int]:
        """One ping sweep; marks shards dead at `miss_limit` consecutive
        misses and returns the indices that died THIS sweep."""
        died = []
        for i, c in self._live_clients():
            try:
                await c.call("ping",
                             _timeout=self.transport.heartbeat_timeout_s)
                self._misses[i] = 0
            except (TransportError, asyncio.TimeoutError):
                self._misses[i] += 1
                if self._misses[i] >= self.transport.miss_limit:
                    self._mark_dead(
                        i, f"shard {i} missed {self._misses[i]} "
                           "heartbeats; marked dead")
                    died.append(i)
        return died

    async def _health_loop(self) -> None:
        while True:
            await asyncio.sleep(self.transport.heartbeat_s)
            await self.check_health()

    def kill_shard(self, i: int) -> None:
        """SIGKILL a spawned worker (adopted workers are just marked
        dead — the front end cannot signal across hosts) and sever its
        connection: parked asks cancel, control calls fail."""
        p = self.procs[i]
        if p is not None and p.poll() is None:
            p.kill()
            p.wait()
        self._mark_dead(i, f"shard {i} killed")

    async def revive_shard(self, i: int) -> None:
        """Respawn a dead shard and fold it back in: kill any zombie
        first (a half-dead writer must never touch the store again), let
        the fresh worker restore from ITS latest epoch, then reconcile
        its restored registry against the federation's over RPC — the
        same recovery law as the in-memory `revive_shard`."""
        if self.clients[i] is not None:
            raise RuntimeError(f"shard {i} is already live")
        p = self.procs[i]
        if p is not None and p.poll() is None:
            p.kill()
            p.wait()
        await self._start_shard(i)
        await self._reconcile_shard_rpc(i)

    async def _reconcile_shard_rpc(self, i: int) -> None:
        c = self._live(i)
        present = set(await c.call("study_ids"))
        expel, missing = self._reconcile_plan(i, present)
        for sid in expel:
            await c.call("expel_study", sid=sid)
        for sid in missing:
            rec = self._records.get(sid)
            if rec is None:
                await c.call("create_study",
                             dims=space_to_dicts(self._template_space),
                             sid=sid)
            else:
                await c.call("adopt_study", record=rec,
                             require_snapshot=False)
        await c.call("sync_registry", next_sid=self._next_sid,
                     closed_sids=sorted(self._closed_sids))
        for sid in await c.call("study_ids"):
            if self._placement.get(sid) == i:
                self._records[sid] = dict(
                    await c.call("registry_record", sid=sid), shard=i)


if __name__ == "__main__":
    sys.exit(main())
