"""HPO orchestration: search spaces, the single-study scheduler, the
multi-tenant StudyPool, and the async ask–tell StudyGateway — all sharing
one batched suggest/absorb engine (DESIGN.md §7), optionally sharded over a
device mesh via `repro.hpo.mesh` (DESIGN.md §8, `SchedulerConfig.mesh`);
the gateway serving semantics are DESIGN.md §9, `FederatedGateway` shards
the study population over N gateways with pipelined ticks (DESIGN.md §13),
and `TransportFederation` (repro.hpo.transport) deploys the same federation
over one shard worker process per host (DESIGN.md §14)."""
from repro.hpo.engine import StudyEngine
from repro.hpo.federation import (FederatedGateway, FederationBase,
                                  FederationConfig, rendezvous_shard)
from repro.hpo.gateway import GatewayConfig, StudyGateway
from repro.hpo.pool import SchedulerConfig, StudyPool, Trial
from repro.hpo.scheduler import TrialScheduler
from repro.hpo.space import (LENET_SPACE, LM_SPACE, MIXED_DEMO_SPACE,
                             RESNET_SPACE, Categorical, Conditional, Dim,
                             Float, Int, SearchSpace, space_from_dicts,
                             space_to_dicts)
from repro.hpo.transport import (ShardClient, ShardConnectionError,
                                 ShardServer, TransportConfig,
                                 TransportError, TransportFederation)

__all__ = [
    "Categorical", "Conditional", "Dim", "FederatedGateway",
    "FederationBase", "FederationConfig", "Float", "GatewayConfig", "Int",
    "LENET_SPACE", "LM_SPACE", "MIXED_DEMO_SPACE", "RESNET_SPACE",
    "SchedulerConfig", "SearchSpace", "ShardClient",
    "ShardConnectionError", "ShardServer", "StudyEngine", "StudyGateway",
    "StudyPool", "TransportConfig", "TransportError",
    "TransportFederation", "Trial", "TrialScheduler", "rendezvous_shard",
    "space_from_dicts", "space_to_dicts",
]
