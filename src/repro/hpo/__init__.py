"""HPO orchestration: search spaces, the single-study scheduler, and the
multi-tenant StudyPool — all sharing one batched suggest/absorb engine
(DESIGN.md §7), optionally sharded over a device mesh via `repro.hpo.mesh`
(DESIGN.md §8, `SchedulerConfig.mesh`)."""
from repro.hpo.engine import StudyEngine
from repro.hpo.pool import SchedulerConfig, StudyPool, Trial
from repro.hpo.scheduler import TrialScheduler
from repro.hpo.space import (LENET_SPACE, LM_SPACE, RESNET_SPACE, Dim,
                             SearchSpace)

__all__ = [
    "Dim", "LENET_SPACE", "LM_SPACE", "RESNET_SPACE", "SchedulerConfig",
    "SearchSpace", "StudyEngine", "StudyPool", "Trial", "TrialScheduler",
]
