"""The batched GP suggest/absorb engine shared by every HPO orchestrator.

`StudyEngine` owns ONE stacked `LazyGPState` with a leading study axis
(DESIGN.md §7) and the jitted closures that advance it.  It is the single
suggest/absorb compute path: `TrialScheduler` drives it with S = 1 (the
degenerate case) and `StudyPool` multiplexes S concurrent studies over the
same closures — there is no separate single-study math anywhere above the
policy layer.

Dispatch shapes (all jitted once per configuration):

  * `suggest_all`    — vmapped acquisition over every study: one program
    advances S EI optimizations at once (the multi-tenant hot path).
  * `suggest_at`     — dynamic-index one study out of the stack, run the
    single-study acquisition (used for routed, per-study requests; `i` is
    traced, so any study id hits the same compilation).
  * `append_at`      — completion-order absorb routed to the owning study:
    extract study i, fused O(n_max^2) lazy append, scatter back.
  * `append_masked`  — one vmapped dispatch absorbing at most one new
    observation per study (flagged), for draining a completion queue in
    rounds instead of S sequential dispatches.
  * `advance_all`    — the fused serving round: masked absorb of last
    round's completions + batched suggest from the updated posteriors in
    ONE jitted program (state buffers donated, so the stacked factors are
    updated in place instead of copied every round).
  * `refit_at`       — lag-event hyper-parameter refit + refactor of a
    single study (rare, O(G n^3); per-study lag counters decide when).

**Device mesh** (DESIGN.md §8): with `cfg.mesh` set ("auto" or "SxR"),
the stacked state is placed on a (study x restart) `jax.sharding.Mesh`
(`repro.hpo.mesh`) and the batched closures (`suggest_all`,
`append_masked`, `advance_all`) become `shard_map` programs — studies
split across devices, restarts split within a study when shards remain.
`mesh="none"` (default) is the degenerate unsharded case of the same
closures; the routed single-study paths (`suggest_at`/`append_at`/
`refit_at`) stay plain jit and read the sharded state through GSPMD.

**Mixed spaces** (DESIGN.md §10): when any study's space carries discrete
dims (or `cfg.mixed` forces it), every closure additionally threads the
stacked per-study `TypeDescriptor` — array DATA, vmapped/sharded along the
study axis with the state — and builds the mixed Matérn x categorical
kernel per study inside the vmap, so stacked studies with *different*
type layouts advance in one program and a gateway slot swap to a new
layout is a descriptor row write (`set_desc`), never a re-trace.
All-continuous engines build the exact pre-§10 closures.

Host-side per-study telemetry: `n` and `since_refit` are mirrored in host
numpy arrays (they evolve deterministically with the appends the engine
itself dispatches), so capacity guards and the lag policy never sync the
device state; `clamp_count` is data-dependent and reads the device
(`clamp_counts()` fetches all studies in one transfer).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import acquisition as acq_mod
from repro.core import descriptor as desc_mod
from repro.core import gp as gp_mod
from repro.core import neural_basis as nb_mod
from repro.core.kernels import KERNELS, make_mixed_kernel
from repro.hpo import mesh as mesh_mod

Array = jax.Array


def _index_state(state: gp_mod.LazyGPState, i: Array) -> gp_mod.LazyGPState:
    """Single-study view at a *traced* index (dynamic gather per leaf)."""
    return jax.tree.map(
        lambda a: jax.lax.dynamic_index_in_dim(a, i, keepdims=False), state)


def _write_state(state: gp_mod.LazyGPState, i: Array,
                 sub: gp_mod.LazyGPState) -> gp_mod.LazyGPState:
    """Scatter a single-study state back into the stack at a traced index."""
    return jax.tree.map(
        lambda a, v: jax.lax.dynamic_update_index_in_dim(a, v, i, axis=0),
        state, sub)


class StudyEngine:
    """Stacked lazy-GP state + the jitted batched suggest/absorb closures.

    `cfg` is duck-typed (SchedulerConfig works): needs n_max, kernel, lag,
    rho0, noise2, implementation, acq; optionally mesh (default "none").
    """

    def __init__(self, dim: int, cfg, n_studies: int,
                 descs: "list[desc_mod.TypeDescriptor] | None" = None):
        if n_studies < 1:
            raise ValueError(f"n_studies must be >= 1, got {n_studies}")
        self.cfg = cfg
        self.n_studies = n_studies
        # Mixed-space mode (DESIGN.md §10): enabled when any study's space
        # has discrete dims, or forced by cfg.mixed so a gateway built on
        # an all-continuous template can still admit discrete tenants
        # later (the closures are traced once, at construction).
        self.mixed = bool(getattr(cfg, "mixed", False)) or (
            descs is not None and any(d.has_discrete for d in descs))
        if self.mixed and cfg.kernel != "matern52":
            raise ValueError(
                "mixed spaces require kernel='matern52', got "
                f"{cfg.kernel!r}")
        self.kernel = KERNELS[cfg.kernel]
        self.gp_cfg = gp_mod.GPConfig(
            n_max=cfg.n_max, dim=dim, kernel=cfg.kernel, lag=cfg.lag,
            noise2=cfg.noise2, rho0=cfg.rho0,
            implementation=cfg.implementation)
        self.mesh = mesh_mod.build(getattr(cfg, "mesh", "none"),
                                   n_studies, cfg.acq.restarts)
        self.state = self.place(gp_mod.init_pool_state(self.gp_cfg,
                                                       n_studies))
        # Stacked per-study type descriptor: DATA, not a closure constant —
        # a gateway slot swap (new tenant, different layout) is an array
        # row update, never a re-trace.  None in the all-continuous case,
        # where the closures below collapse to the exact pre-mixed trace.
        if self.mixed:
            if descs is None:
                descs = [desc_mod.all_continuous(dim)] * n_studies
            if len(descs) != n_studies:
                raise ValueError(
                    f"got {len(descs)} descriptors for {n_studies} studies")
            self.desc = self.place(desc_mod.stack_descriptors(list(descs)))
        else:
            self.desc = None
        self._lo = jnp.zeros((dim,))
        self._hi = jnp.ones((dim,))
        # The substrate knob is a Python constant inside the jitted closures:
        # one compilation per configured implementation.  Likewise the mesh:
        # the shard_map wrapping happens at trace time, once per top_t.
        impl = cfg.implementation
        mixed = self.mixed
        hpo_mesh = self.mesh
        r_shards = hpo_mesh.restart_shards if hpo_mesh else 1
        r_axis = mesh_mod.RESTART_AXIS if r_shards > 1 else None

        def kern_for(dsc):
            # Per-study kernel: inside the vmapped closures `dsc` is one
            # study's (d,) descriptor row (traced), so stacked studies
            # with different type layouts share one program.
            if not mixed:
                return self.kernel
            return make_mixed_kernel(dsc.cont_mask, dsc.cat_mask)

        def suggest_one(st, dsc, key, top_t, sharded):
            return acq_mod.optimize_acquisition(
                st, kern_for(dsc), self._lo, self._hi, key, cfg.acq, top_t,
                implementation=impl,
                restart_axis=r_axis if sharded else None,
                restart_shards=r_shards if sharded else 1,
                desc=dsc if mixed else None)

        def append_one(st, dsc, x, y):
            return gp_mod.append(st, kern_for(dsc), x, y,
                                 implementation=impl)

        def masked_append_one(st, dsc, x, y, flag):
            new = append_one(st, dsc, x, y)
            return jax.tree.map(lambda o, n_: jnp.where(flag, n_, o), st, new)

        def advance_one(st, dsc, x, y, flag, key, top_t, sharded):
            # Fused serving round: masked absorb, then suggest from the
            # updated posterior — one program residency for both.
            st = masked_append_one(st, dsc, x, y, flag)
            units, vals = suggest_one(st, dsc, key, top_t, sharded)
            return st, units, vals

        def refit_one(st, dsc):
            kern = kern_for(dsc)
            params = gp_mod.refit_params(st, kern, implementation=impl)
            return gp_mod.refactor(st, kern, params, implementation=impl)

        def reanchor_one(st, dsc):
            # Fully-lazy drift guard: rebuild factor + maintained inverse
            # from the Gram under the CURRENT params (no grid refit).
            return gp_mod.refactor(st, kern_for(dsc), implementation=impl)

        # Fantasy protocol (DESIGN.md §12): liar policy is a Python
        # constant inside the jitted q-ask closures (one compilation per
        # configured liar, exactly like the substrate knob).
        fantasy_liar = getattr(cfg, "fantasy", gp_mod.FantasyConfig()).liar

        def ask_q_one(st, dsc, key, q):
            return acq_mod.suggest_q(
                st, kern_for(dsc), self._lo, self._hi, key, cfg.acq, q,
                liar=fantasy_liar, implementation=impl,
                desc=dsc if mixed else None)

        def fantasize_one(st, dsc, xs):
            return gp_mod.fantasize(st, kern_for(dsc), xs, fantasy_liar,
                                    implementation=impl)

        # In mixed mode every jitted closure takes the stacked descriptor
        # as a runtime argument right after the state (vmapped/sharded
        # along the study axis with it); otherwise the argument is absent
        # and the traces are identical to the all-continuous stack.
        if hpo_mesh is None:
            if mixed:
                self._suggest_all = jax.jit(
                    lambda state, dsc, keys, *, top_t: jax.vmap(
                        lambda st, dc, k: suggest_one(
                            st, dc, k, top_t, False))(state, dsc, keys),
                    static_argnames=("top_t",))
                self._append_masked = jax.jit(jax.vmap(masked_append_one))
                self._advance_all = jax.jit(
                    lambda state, dsc, xs, ys, flags, keys, *, top_t:
                    jax.vmap(
                        lambda st, dc, x, y, f, k: advance_one(
                            st, dc, x, y, f, k, top_t, False))(
                        state, dsc, xs, ys, flags, keys),
                    static_argnames=("top_t",), donate_argnums=(0,))
            else:
                self._suggest_all = jax.jit(
                    lambda state, keys, *, top_t: jax.vmap(
                        lambda st, k: suggest_one(
                            st, None, k, top_t, False))(state, keys),
                    static_argnames=("top_t",))
                self._append_masked = jax.jit(jax.vmap(
                    lambda st, x, y, f: masked_append_one(st, None, x, y,
                                                          f)))
                self._advance_all = jax.jit(
                    lambda state, xs, ys, flags, keys, *, top_t: jax.vmap(
                        lambda st, x, y, f, k: advance_one(
                            st, None, x, y, f, k, top_t, False))(
                        state, xs, ys, flags, keys),
                    static_argnames=("top_t",), donate_argnums=(0,))
        else:
            # Sharded variants: studies split over the mesh's study axis,
            # restarts split over the restart axis inside each suggest.
            if mixed:
                self._suggest_all = jax.jit(
                    lambda state, dsc, keys, *, top_t: hpo_mesh.shard(
                        lambda st, dc, ks: jax.vmap(
                            lambda s, d_, k: suggest_one(
                                s, d_, k, top_t, True))(st, dc, ks),
                        n_in=3)(state, dsc, keys),
                    static_argnames=("top_t",))
                self._append_masked = jax.jit(hpo_mesh.shard(
                    lambda st, dc, x, y, f: jax.vmap(masked_append_one)(
                        st, dc, x, y, f), n_in=5))
                self._advance_all = jax.jit(
                    lambda state, dsc, xs, ys, flags, keys, *, top_t:
                    hpo_mesh.shard(
                        lambda st, dc, x, y, f, k: jax.vmap(
                            lambda s, d_, x_, y_, f_, k_: advance_one(
                                s, d_, x_, y_, f_, k_, top_t, True))(
                            st, dc, x, y, f, k),
                        n_in=6)(state, dsc, xs, ys, flags, keys),
                    static_argnames=("top_t",), donate_argnums=(0,))
            else:
                self._suggest_all = jax.jit(
                    lambda state, keys, *, top_t: hpo_mesh.shard(
                        lambda st, ks: jax.vmap(
                            lambda s, k: suggest_one(
                                s, None, k, top_t, True))(st, ks),
                        n_in=2)(state, keys),
                    static_argnames=("top_t",))
                self._append_masked = jax.jit(hpo_mesh.shard(
                    lambda st, x, y, f: jax.vmap(
                        lambda s, x_, y_, f_: masked_append_one(
                            s, None, x_, y_, f_))(st, x, y, f),
                    n_in=4))
                self._advance_all = jax.jit(
                    lambda state, xs, ys, flags, keys, *, top_t:
                    hpo_mesh.shard(
                        lambda st, x, y, f, k: jax.vmap(
                            lambda s, x_, y_, f_, k_: advance_one(
                                s, None, x_, y_, f_, k_, top_t, True))(
                            st, x, y, f, k),
                        n_in=5)(state, xs, ys, flags, keys),
                    static_argnames=("top_t",), donate_argnums=(0,))
        # Routed single-study paths: plain jit; with a mesh active the
        # sharded state flows through GSPMD's auto-partitioner (these are
        # the rare paths — lag events and per-study routing).  The mixed
        # variants index the stacked descriptor at the same traced index.
        def ask_q_route(state, dsc, i, key, q):
            # q-suggestion fast path, routed to one slot: extract, run the
            # scan-of-(suggest + fantasize) program, scatter the fantasized
            # state back.  q is static — one compilation per distinct q.
            xs, vals, sub = ask_q_one(_index_state(state, i), dsc, key, q)
            return xs, vals, _write_state(state, i, sub)

        if mixed:
            self._suggest_at = jax.jit(
                lambda state, dsc, i, key, *, top_t: suggest_one(
                    _index_state(state, i),
                    desc_mod.index_descriptor(dsc, i), key, top_t, False),
                static_argnames=("top_t",))
            self._append_at = jax.jit(
                lambda state, dsc, i, x, y: _write_state(
                    state, i, append_one(
                        _index_state(state, i),
                        desc_mod.index_descriptor(dsc, i), x, y)))
            self._ask_q_at = jax.jit(
                lambda state, dsc, i, key, *, q: ask_q_route(
                    state, desc_mod.index_descriptor(dsc, i), i, key, q),
                static_argnames=("q",))
            self._refantasize_at = jax.jit(
                lambda state, dsc, i, xs: _write_state(
                    state, i, fantasize_one(
                        _index_state(state, i),
                        desc_mod.index_descriptor(dsc, i), xs)))
            self._refit_at = jax.jit(
                lambda state, dsc, i: _write_state(
                    state, i, refit_one(
                        _index_state(state, i),
                        desc_mod.index_descriptor(dsc, i))))
            self._reanchor_at = jax.jit(
                lambda state, dsc, i: _write_state(
                    state, i, reanchor_one(
                        _index_state(state, i),
                        desc_mod.index_descriptor(dsc, i))))
        else:
            self._suggest_at = jax.jit(
                lambda state, i, key, *, top_t: suggest_one(
                    _index_state(state, i), None, key, top_t, False),
                static_argnames=("top_t",))
            self._append_at = jax.jit(
                lambda state, i, x, y: _write_state(
                    state, i, append_one(_index_state(state, i), None,
                                         x, y)))
            self._ask_q_at = jax.jit(
                lambda state, i, key, *, q: ask_q_route(
                    state, None, i, key, q),
                static_argnames=("q",))
            self._refantasize_at = jax.jit(
                lambda state, i, xs: _write_state(
                    state, i, fantasize_one(_index_state(state, i), None,
                                            xs)))
            self._refit_at = jax.jit(
                lambda state, i: _write_state(
                    state, i, refit_one(_index_state(state, i), None)))
            self._reanchor_at = jax.jit(
                lambda state, i: _write_state(
                    state, i, reanchor_one(_index_state(state, i), None)))
        # Fantasy rollback: re-pad every row >= n_real of one slot (kernel-
        # free, descriptor-free — identical trace in mixed mode).
        self._truncate_at = jax.jit(
            lambda state, i, n_real: _write_state(
                state, i, gp_mod.truncate(_index_state(state, i), n_real)))
        # Slot-level state swap (the gateway's evict/restore hook): scatter a
        # single-study state into the stack at a traced index — any slot hits
        # the same compilation, so serving-time restores never re-trace.
        self._load_at = jax.jit(_write_state)
        # -- saturation escalation tier (DESIGN.md §15) ----------------------
        # Per-slot tier tag: 0 = lazy GP (the stacked state above), 1 =
        # neural basis.  Like the descriptors, the tag is per-slot DATA —
        # heterogeneous tenants share one program per tier (the nb_* jitted
        # programs are cached by (cap, d) shape + the static NeuralConfig /
        # AcqConfig, never re-traced per slot).  Escalated slots keep their
        # frozen GP lane in the stack (it rides the batched programs as
        # dead weight and is exported untouched); their live model is the
        # NeuralBasisState held here.
        self.neural = getattr(cfg, "neural", None) or nb_mod.NeuralConfig()
        self._fantasy_liar = fantasy_liar
        self._tier = np.zeros((n_studies,), np.int8)
        self._nb: dict[int, nb_mod.NeuralBasisState] = {}
        # Pre-fantasy snapshots: the NB tier's rank-1 factor updates are
        # not bitwise-reversible, so fantasy rollback is a state-snapshot
        # restore (O(m^2) floats + the ledger views — cheap, exact).
        self._nb_shadow: dict[int, nb_mod.NeuralBasisState] = {}
        self._nb_n: dict[int, int] = {}   # host mirror incl. fantasy rows
        # Per-row observation costs (tell `cost=`, default 1.0) for the GP
        # tier — the training set of the promotion-time log-cost head.
        self._cost_host = np.ones((n_studies, cfg.n_max), np.float32)

    def place(self, state: gp_mod.LazyGPState) -> gp_mod.LazyGPState:
        """Put a stacked state onto the configured mesh (identity if none)."""
        return self.mesh.place(state) if self.mesh else state

    # -- state + host-side counter mirrors ----------------------------------
    # `n` and `since_refit` evolve deterministically (+1 per append, refits
    # reset since_refit), so the engine mirrors them in host numpy arrays:
    # the hot paths (capacity guards, lag policy, the pool's seed-vs-EI
    # routing) never sync the device state — on a sharded mesh a single
    # `int(state.n[s])` read is a cross-device gather, and S of them per
    # round would dominate the round itself.  Assigning `engine.state`
    # re-syncs the mirrors from the device (restore, tests, prefill).

    @property
    def state(self) -> gp_mod.LazyGPState:
        return self._state

    @state.setter
    def state(self, st: gp_mod.LazyGPState) -> None:
        self._state = st
        self._n_host = np.asarray(st.n).copy()
        self._sr_host = np.asarray(st.since_refit).copy()

    # -- per-study telemetry (host-side) ------------------------------------
    def n(self, study: int) -> int:
        return int(self._n_host[study])

    def since_refit(self, study: int) -> int:
        return int(self._sr_host[study])

    def clamp_count(self, study: int) -> int:
        return int(self.state.clamp_count[study])

    def clamp_counts(self) -> np.ndarray:
        """All studies' conditioning-floor counters in one transfer."""
        return np.asarray(self.state.clamp_count)

    def sync(self) -> None:
        """Block until every dispatched program has committed to the state.

        The pipelined serving layer (DESIGN.md §13) leaves fused rounds in
        flight while the host stages the next tick; timing code and
        migration/export paths call this to pin a quiescent point.
        """
        jax.block_until_ready(self._state)

    def study_state(self, study: int) -> gp_mod.LazyGPState:
        """Unstacked single-study view (static index)."""
        return gp_mod.unstack_state(self.state, study)

    # -- slot-level state swap (gateway evict/restore, DESIGN.md §9) --------
    def load_slot(self, slot: int, sub: gp_mod.LazyGPState) -> None:
        """Swap a single-study state INTO stack slot `slot`.

        One jitted scatter at a traced index (no re-trace per slot); the
        host mirrors are patched for that slot only, so loading a study
        never syncs the other S-1 lanes off the device.  The write is
        elementwise, so the restored lane is bitwise-identical to the
        exported one — the evict/restore-exactness contract.
        """
        self._state = self.place(self._load_at(
            self.state, jnp.asarray(slot, jnp.int32), sub))
        self._n_host[slot] = int(sub.n)
        self._sr_host[slot] = int(sub.since_refit)

    def reset_slot(self, slot: int) -> None:
        """Blank a slot for a new tenant (fresh empty single-study state)."""
        self.load_slot(slot, gp_mod.init_state(self.gp_cfg))
        self.clear_nb_slot(slot)

    def set_desc(self, slot: int, desc: desc_mod.TypeDescriptor) -> None:
        """Install a (possibly different) type layout for one slot.

        A row write into the stacked descriptor DATA — the closures take
        the descriptor as a runtime argument, so a tenant swap with a new
        layout never re-traces.  No-op outside mixed mode (where every
        slot is all-continuous by construction)."""
        if self.desc is None:
            if desc.has_discrete:
                raise ValueError(
                    "engine was built without mixed-space support; "
                    "construct it with a discrete space or cfg.mixed=True")
            return
        updated = jax.tree.map(lambda a, v: a.at[slot].set(v),
                               self.desc, desc)
        self.desc = self.place(updated)

    # -- suggest ------------------------------------------------------------
    def _desc_args(self) -> tuple:
        """The stacked descriptor, when the closures take it (mixed mode)."""
        return (self.desc,) if self.mixed else ()

    def suggest(self, study: int, key: Array,
                top_t: int = 1) -> tuple[Array, Array]:
        """Top-t EI local maxima for one study: ((top_t, d), (top_t,))."""
        return self._suggest_at(self.state, *self._desc_args(),
                                jnp.asarray(study, jnp.int32),
                                key, top_t=top_t)

    def suggest_all(self, keys: Array, top_t: int = 1) -> tuple[Array, Array]:
        """Batched suggestion for every study: ((S, top_t, d), (S, top_t))."""
        return self._suggest_all(self.state, *self._desc_args(), keys,
                                 top_t=top_t)

    # -- absorb -------------------------------------------------------------
    def absorb(self, study: int, x, y, cost: float = 1.0) -> None:
        """Routed completion-order absorb (+ per-study lag policy)."""
        gp_mod.ensure_capacity(self.n(study), self.cfg.n_max)
        self._cost_host[study, self.n(study)] = cost
        self._state = self._append_at(
            self.state, *self._desc_args(), jnp.asarray(study, jnp.int32),
            jnp.asarray(x, jnp.float32),
            jnp.asarray(y, jnp.float32))
        self._n_host[study] += 1
        self._sr_host[study] += 1
        self._refit_flagged([study])

    def absorb_round(self, flags, xs, ys, costs=None) -> None:
        """Masked batched absorb: at most one new observation per study.

        `flags (S,)` bool selects which studies actually append; `xs (S, d)`
        / `ys (S,)` carry the observations (ignored where flag is False).
        One dispatch replaces up to S routed appends.  `costs (S,)`
        (optional) records each flagged observation's tell cost.
        """
        flags = np.asarray(flags, bool)
        flagged = np.flatnonzero(flags)
        for s in flagged:
            gp_mod.ensure_capacity(self.n(s), self.cfg.n_max)
        self._record_costs(flagged, costs)
        self._state = self._append_masked(
            self.state, *self._desc_args(),
            jnp.asarray(xs, jnp.float32),
            jnp.asarray(ys, jnp.float32),
            jnp.asarray(flags))
        self._n_host[flagged] += 1
        self._sr_host[flagged] += 1
        self._refit_flagged(flagged)

    def _record_costs(self, flagged, costs) -> None:
        if costs is None:
            costs = np.ones((self.n_studies,), np.float32)
        costs = np.asarray(costs, np.float32)
        for s in flagged:
            self._cost_host[s, self.n(s)] = costs[s]

    # -- fused serving round ------------------------------------------------
    def advance(self, flags, xs, ys, keys,
                top_t: int = 1, costs=None) -> tuple[Array, Array]:
        """Masked absorb + batched suggest in ONE jitted dispatch.

        Absorbs at most one flagged observation per study (exactly like
        `absorb_round`), then suggests top-t points for EVERY study from
        the updated posteriors, returning `((S, top_t, d), (S, top_t))`.
        This is the serving-loop hot path: one program per round instead of
        an absorb dispatch + a suggest dispatch, with the stacked state
        buffers donated (updated in place, not copied).

        The previous `self.state` is consumed by donation — callers must
        not hold references to its buffers across this call.  Pipelined
        callers (DESIGN.md §13) may defer fetching the RETURNED arrays —
        those are fresh outputs, not donated — but any host read of
        `self.state` leaves (or a copy taken for later, like the pool's
        clamp vector) must be a new dispatch output, never a buffer that a
        subsequent `advance` will donate.
        """
        flags = np.asarray(flags, bool)
        flagged = np.flatnonzero(flags)
        for s in flagged:
            gp_mod.ensure_capacity(self.n(s), self.cfg.n_max)
        self._record_costs(flagged, costs)
        self._state, units, vals = self._advance_all(
            self.state, *self._desc_args(),
            jnp.asarray(xs, jnp.float32),
            jnp.asarray(ys, jnp.float32),
            jnp.asarray(flags), keys, top_t=top_t)
        self._n_host[flagged] += 1
        self._sr_host[flagged] += 1
        self._refit_flagged(flagged)
        return units, vals

    # -- fantasy protocol (q-suggestion serving, DESIGN.md §12) -------------
    # Fantasy rows live in the same stacked buffers as real observations —
    # the host `n` mirror therefore tracks the *fantasized* count; callers
    # (StudyPool) own the real-ledger count and drive the rollback.

    def ask_q(self, study: int, key: Array, q: int) -> tuple[Array, Array]:
        """q-suggestion fast path: ((q, d) points, (q,) acq values).

        ONE jitted dispatch runs q rounds of suggest-then-fantasize against
        slot `study` (DESIGN.md §12) and leaves the slot *fantasized* (its
        device/host n grows by q).  The caller must roll the fantasy rows
        back (`truncate_slot`) before any real append lands.
        """
        gp_mod.ensure_capacity(self.n(study), self.cfg.n_max, q)
        xs, vals, self._state = self._ask_q_at(
            self.state, *self._desc_args(), jnp.asarray(study, jnp.int32),
            key, q=q)
        self._n_host[study] += q
        return xs, vals

    def truncate_slot(self, study: int, n_real: int) -> None:
        """Roll slot `study` back to its first `n_real` (real) rows.

        Bitwise-exact re-padding (`gp.truncate`): the factor/inverse rows
        being dropped are replaced by the identity rows they overwrote, so
        the slot is restored bit for bit to its pre-fantasy buffers.
        """
        self._state = self._truncate_at(
            self.state, jnp.asarray(study, jnp.int32),
            jnp.asarray(n_real, jnp.int32))
        self._n_host[study] = int(n_real)

    def refantasize(self, study: int, xs) -> None:
        """Re-append pending fantasy points in ONE `lazy_append_rows` dispatch.

        The tell-time replay: after `truncate_slot` + the real absorb, the
        still-pending fantasy points (q, d) are re-fantasized against the
        updated posterior — fresher liar values, one batched dispatch.
        """
        xs = jnp.asarray(xs, jnp.float32)
        gp_mod.ensure_capacity(self.n(study), self.cfg.n_max, xs.shape[0])
        self._state = self._refantasize_at(
            self.state, *self._desc_args(), jnp.asarray(study, jnp.int32),
            xs)
        self._n_host[study] += xs.shape[0]

    # -- neural-basis tier (saturation escalation, DESIGN.md §15) -----------
    def tier(self, study: int) -> int:
        """0 = lazy GP, 1 = neural basis (escalated)."""
        return int(self._tier[study])

    def cost_row(self, study: int) -> np.ndarray:
        """The GP tier's per-row tell costs (rides eviction snapshots so a
        near-saturation study promoted after a restore still trains its
        cost head on the full ledger)."""
        return self._cost_host[study].copy()

    def set_cost_row(self, study: int, costs) -> None:
        self._cost_host[study] = np.asarray(costs, np.float32)

    def promote_slot(self, slot: int, key: Array) -> None:
        """Escalate a saturated GP slot to the neural-basis tier.

        The NB model trains on the slot's FULL active ledger (the exact
        rows the GP absorbed, plus their tell costs) — the caller must
        have rolled back any fantasy rows first.  The GP lane stays
        frozen in the stack: exports keep round-tripping it bitwise, and
        its buffers are never touched again.
        """
        if self._tier[slot]:
            raise RuntimeError(f"slot {slot} is already escalated")
        n0 = self.n(slot)
        if n0 < 1:
            raise RuntimeError("cannot promote an empty slot")
        st = self.study_state(slot)
        xs = np.asarray(st.x_buf)[:n0]
        ys = np.asarray(st.y_buf)[:n0]
        logcs = np.log(np.maximum(self._cost_host[slot, :n0], 1e-12))
        self._nb[slot] = nb_mod.nb_from_data(xs, ys, logcs, key,
                                             self.neural)
        self._tier[slot] = 1
        self._nb_n[slot] = n0
        self._nb_shadow.pop(slot, None)

    def clear_nb_slot(self, slot: int) -> None:
        """Drop the escalated model (new tenant / detach): back to tier 0."""
        self._tier[slot] = 0
        self._nb.pop(slot, None)
        self._nb_shadow.pop(slot, None)
        self._nb_n.pop(slot, None)
        self._cost_host[slot] = 1.0

    def nb_state(self, slot: int) -> nb_mod.NeuralBasisState:
        return self._nb[slot]

    def load_nb_slot(self, slot: int, state: nb_mod.NeuralBasisState
                     ) -> None:
        """Install a restored/imported NB state (tier tag follows)."""
        self._tier[slot] = 1
        self._nb[slot] = state
        self._nb_n[slot] = int(state.n)
        self._nb_shadow.pop(slot, None)

    def nb_n(self, slot: int) -> int:
        """Fantasized row count of an escalated slot (host mirror)."""
        return self._nb_n[slot]

    def _nb_room(self, slot: int, incoming: int
                 ) -> nb_mod.NeuralBasisState:
        st = self._nb[slot]
        while self._nb_n[slot] + incoming > st.cap:
            st = nb_mod.nb_grow(st, self.neural)
        return st

    def nb_absorb(self, slot: int, x, y, cost: float = 1.0) -> None:
        """Escalated absorb: rank-1 append (ledger grows, never full) +
        the MLP refit cadence (`NeuralConfig.refit_every`, the tier's
        `lag`).  Must only run with no fantasy rows active (the pool rolls
        back first — same protocol as the GP tier)."""
        st = self._nb_room(slot, 1)
        st = nb_mod.nb_append(
            st, jnp.asarray(x, jnp.float32), jnp.float32(y),
            jnp.float32(np.log(max(float(cost), 1e-12))),
            ncfg=self.neural)
        if int(st.since_refit) >= self.neural.refit_every:
            st = nb_mod.nb_refit(st, ncfg=self.neural)
        self._nb[slot] = st
        self._nb_n[slot] += 1

    def _nb_desc(self, slot: int):
        if not self.mixed:
            return None
        return desc_mod.index_descriptor(self.desc,
                                         jnp.asarray(slot, jnp.int32))

    def nb_suggest(self, slot: int, key: Array,
                   top_t: int = 1) -> tuple[Array, Array]:
        """Escalated suggest: acquisition ascent against the O(m^2)
        neural-basis posterior — flat in n."""
        return nb_mod.nb_suggest(self._nb[slot], key, self._nb_desc(slot),
                                 acq=self.cfg.acq, top_t=top_t)

    def nb_ask_q(self, slot: int, key: Array, q: int
                 ) -> tuple[Array, Array]:
        """Escalated q-suggestion: snapshot the pre-fantasy state, then the
        qEI suggest-and-fantasize scan.  Rollback = `nb_rollback`."""
        if slot not in self._nb_shadow:
            self._nb_shadow[slot] = self._nb[slot]
        st = self._nb_room(slot, q)
        xs, vals, st = nb_mod.nb_ask_q(st, key, self._nb_desc(slot),
                                       ncfg=self.neural, acq=self.cfg.acq,
                                       q=q, liar=self._fantasy_liar)
        self._nb[slot] = st
        self._nb_n[slot] += q
        return xs, vals

    def nb_rollback(self, slot: int) -> None:
        """Drop every fantasy row of an escalated slot: restore the
        pre-fantasy snapshot — bitwise-exact by construction."""
        sh = self._nb_shadow.pop(slot, None)
        if sh is not None:
            self._nb[slot] = sh
            self._nb_n[slot] = int(sh.n)

    def nb_refantasize(self, slot: int, xs) -> None:
        """Re-append still-pending fantasy points against the updated
        posterior (tell-time replay, same protocol as `refantasize`)."""
        xs = jnp.asarray(xs, jnp.float32)
        self._nb_shadow[slot] = self._nb[slot]
        st = self._nb_room(slot, xs.shape[0])
        st = nb_mod.nb_fantasize(st, xs, ncfg=self.neural,
                                 liar=self._fantasy_liar)
        self._nb[slot] = st
        self._nb_n[slot] += int(xs.shape[0])

    def _refit_flagged(self, flagged) -> None:
        """Apply the per-study lag policy after an absorb (host mirrors).

        lag > 0: full hyper-parameter refit + refactor every `lag` appends.
        lag <= 0 (the paper's fully-lazy mode): no param refit, but every
        `inv_refresh` appends the factor and its maintained inverse are
        rebuilt from the Gram under the current params — re-anchoring the
        float32 drift the incremental bordered-inverse updates accumulate
        (DESIGN.md §4).  Both events are rare O(n_max^3) dispatches; the
        check itself reads only the host-side counter mirrors.
        """
        lag = self.cfg.lag
        inv_refresh = getattr(self.cfg, "inv_refresh", 0)
        if lag <= 0 and inv_refresh <= 0:
            return
        for s in flagged:
            if lag > 0:
                if self.since_refit(s) >= lag:
                    self._state = self._refit_at(
                        self.state, *self._desc_args(),
                        jnp.asarray(s, jnp.int32))
                    self._sr_host[s] = 0
            elif self.since_refit(s) >= inv_refresh:
                self._state = self._reanchor_at(
                    self.state, *self._desc_args(),
                    jnp.asarray(s, jnp.int32))
                self._sr_host[s] = 0
