"""The batched GP suggest/absorb engine shared by every HPO orchestrator.

`StudyEngine` owns ONE stacked `LazyGPState` with a leading study axis
(DESIGN.md §7) and the jitted closures that advance it.  It is the single
suggest/absorb compute path: `TrialScheduler` drives it with S = 1 (the
degenerate case) and `StudyPool` multiplexes S concurrent studies over the
same closures — there is no separate single-study math anywhere above the
policy layer.

Dispatch shapes (all jitted once per configuration):

  * `suggest_all`    — vmapped acquisition over every study: one program
    advances S EI optimizations at once (the multi-tenant hot path).
  * `suggest_at`     — dynamic-index one study out of the stack, run the
    single-study acquisition (used for routed, per-study requests; `i` is
    traced, so any study id hits the same compilation).
  * `append_at`      — completion-order absorb routed to the owning study:
    extract study i, fused O(n_max^2) lazy append, scatter back.
  * `append_masked`  — one vmapped dispatch absorbing at most one new
    observation per study (flagged), for draining a completion queue in
    rounds instead of S sequential dispatches.
  * `refit_at`       — lag-event hyper-parameter refit + refactor of a
    single study (rare, O(G n^3); per-study lag counters decide when).

Host-side per-study telemetry (`n`, `since_refit`, `clamp_count`) reads
slice straight out of the stacked scalars.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import acquisition as acq_mod
from repro.core import gp as gp_mod
from repro.core.kernels import KERNELS

Array = jax.Array


def _index_state(state: gp_mod.LazyGPState, i: Array) -> gp_mod.LazyGPState:
    """Single-study view at a *traced* index (dynamic gather per leaf)."""
    return jax.tree.map(
        lambda a: jax.lax.dynamic_index_in_dim(a, i, keepdims=False), state)


def _write_state(state: gp_mod.LazyGPState, i: Array,
                 sub: gp_mod.LazyGPState) -> gp_mod.LazyGPState:
    """Scatter a single-study state back into the stack at a traced index."""
    return jax.tree.map(
        lambda a, v: jax.lax.dynamic_update_index_in_dim(a, v, i, axis=0),
        state, sub)


class StudyEngine:
    """Stacked lazy-GP state + the jitted batched suggest/absorb closures.

    `cfg` is duck-typed (SchedulerConfig works): needs n_max, kernel, lag,
    rho0, noise2, implementation, acq.
    """

    def __init__(self, dim: int, cfg, n_studies: int):
        if n_studies < 1:
            raise ValueError(f"n_studies must be >= 1, got {n_studies}")
        self.cfg = cfg
        self.n_studies = n_studies
        self.kernel = KERNELS[cfg.kernel]
        self.gp_cfg = gp_mod.GPConfig(
            n_max=cfg.n_max, dim=dim, kernel=cfg.kernel, lag=cfg.lag,
            noise2=cfg.noise2, rho0=cfg.rho0,
            implementation=cfg.implementation)
        self.state = gp_mod.init_pool_state(self.gp_cfg, n_studies)
        self._lo = jnp.zeros((dim,))
        self._hi = jnp.ones((dim,))
        # The substrate knob is a Python constant inside the jitted closures:
        # one compilation per configured implementation.
        impl = cfg.implementation

        def suggest_one(st, key, top_t):
            return acq_mod.optimize_acquisition(
                st, self.kernel, self._lo, self._hi, key, cfg.acq, top_t,
                implementation=impl)

        def append_one(st, x, y):
            return gp_mod.append(st, self.kernel, x, y, implementation=impl)

        def masked_append_one(st, x, y, flag):
            new = append_one(st, x, y)
            return jax.tree.map(lambda o, n_: jnp.where(flag, n_, o), st, new)

        def refit_one(st):
            params = gp_mod.refit_params(st, self.kernel,
                                         implementation=impl)
            return gp_mod.refactor(st, self.kernel, params,
                                   implementation=impl)

        def reanchor_one(st):
            # Fully-lazy drift guard: rebuild factor + maintained inverse
            # from the Gram under the CURRENT params (no grid refit).
            return gp_mod.refactor(st, self.kernel, implementation=impl)

        self._suggest_all = jax.jit(
            lambda state, keys, *, top_t: jax.vmap(
                lambda st, k: suggest_one(st, k, top_t))(state, keys),
            static_argnames=("top_t",))
        self._suggest_at = jax.jit(
            lambda state, i, key, *, top_t: suggest_one(
                _index_state(state, i), key, top_t),
            static_argnames=("top_t",))
        self._append_at = jax.jit(
            lambda state, i, x, y: _write_state(
                state, i, append_one(_index_state(state, i), x, y)))
        self._append_masked = jax.jit(jax.vmap(masked_append_one))
        self._refit_at = jax.jit(
            lambda state, i: _write_state(
                state, i, refit_one(_index_state(state, i))))
        self._reanchor_at = jax.jit(
            lambda state, i: _write_state(
                state, i, reanchor_one(_index_state(state, i))))

    # -- per-study telemetry (host-side) ------------------------------------
    def n(self, study: int) -> int:
        return int(self.state.n[study])

    def since_refit(self, study: int) -> int:
        return int(self.state.since_refit[study])

    def clamp_count(self, study: int) -> int:
        return int(self.state.clamp_count[study])

    def study_state(self, study: int) -> gp_mod.LazyGPState:
        """Unstacked single-study view (static index)."""
        return gp_mod.unstack_state(self.state, study)

    # -- suggest ------------------------------------------------------------
    def suggest(self, study: int, key: Array,
                top_t: int = 1) -> tuple[Array, Array]:
        """Top-t EI local maxima for one study: ((top_t, d), (top_t,))."""
        return self._suggest_at(self.state, jnp.asarray(study, jnp.int32),
                                key, top_t=top_t)

    def suggest_all(self, keys: Array, top_t: int = 1) -> tuple[Array, Array]:
        """Batched suggestion for every study: ((S, top_t, d), (S, top_t))."""
        return self._suggest_all(self.state, keys, top_t=top_t)

    # -- absorb -------------------------------------------------------------
    def absorb(self, study: int, x, y) -> None:
        """Routed completion-order absorb (+ per-study lag policy)."""
        gp_mod.ensure_capacity(self.n(study), self.cfg.n_max)
        self.state = self._append_at(
            self.state, jnp.asarray(study, jnp.int32),
            jnp.asarray(x, self.state.x_buf.dtype),
            jnp.asarray(y, self.state.y_buf.dtype))
        self._maybe_refit(study)

    def absorb_round(self, flags, xs, ys) -> None:
        """Masked batched absorb: at most one new observation per study.

        `flags (S,)` bool selects which studies actually append; `xs (S, d)`
        / `ys (S,)` carry the observations (ignored where flag is False).
        One dispatch replaces up to S routed appends.
        """
        for s in range(self.n_studies):
            if bool(flags[s]):
                gp_mod.ensure_capacity(self.n(s), self.cfg.n_max)
        self.state = self._append_masked(
            self.state,
            jnp.asarray(xs, self.state.x_buf.dtype),
            jnp.asarray(ys, self.state.y_buf.dtype),
            jnp.asarray(flags, bool))
        for s in range(self.n_studies):
            if bool(flags[s]):
                self._maybe_refit(s)

    def _maybe_refit(self, study: int) -> None:
        """Per-study lag policy (host-side check; both events are rare).

        lag > 0: full hyper-parameter refit + refactor every `lag` appends.
        lag <= 0 (the paper's fully-lazy mode): no param refit, but every
        `inv_refresh` appends the factor and its maintained inverse are
        rebuilt from the Gram under the current params — re-anchoring the
        float32 drift the incremental bordered-inverse updates accumulate
        (DESIGN.md §4).  `refactor` resets `since_refit`, so one counter
        drives both cadences.
        """
        if self.cfg.lag > 0:
            if self.since_refit(study) >= self.cfg.lag:
                self.state = self._refit_at(self.state,
                                            jnp.asarray(study, jnp.int32))
            return
        inv_refresh = getattr(self.cfg, "inv_refresh", 0)
        if inv_refresh > 0 and self.since_refit(study) >= inv_refresh:
            self.state = self._reanchor_at(self.state,
                                           jnp.asarray(study, jnp.int32))
