"""Multi-pool federation: N StudyGateway shards behind one front end.

`FederatedGateway` is the horizontal-scaling layer of the serving stack
(DESIGN.md §13).  Each shard is a full `StudyGateway` + `StudyPool` with
its own slots, ticker, and checkpoint store under `<root>/shard-<i>/`; the
front end owns the GLOBAL study id space and routes every ask/tell to the
shard that currently holds the study:

  * **routing** — rendezvous (highest-random-weight) hashing over
    `sha256(f"{shard}:{sid}")`: deterministic across processes (no
    PYTHONHASHSEED dependence), stable under a fixed shard count, and
    minimal-movement if the count ever changes.  Placement is the ring
    position until a migration overrides it.
  * **single-pool equivalence** — shards seed per-study PRNG streams by
    GLOBAL sid (`create_study(sid=...)`), and a study's suggestions depend
    only on its own absorbed rows + its own stream, so WHERE a study is
    routed never changes WHAT it is suggested: a federation serves every
    study the same suggestions as one big pool given the same per-study
    event order (test-enforced, tests/test_properties.py).
  * **migration** — built on the bitwise-exact eviction snapshots:
    quiesce + evict on the source (committed snapshot at version v), copy
    that one version to the destination store
    (`checkpoint.copy_study_version`, atomic COMMITTED-last publish),
    adopt the registry record there, then detach from the source.  Any
    fault before the detach leaves the study fully intact on its source
    shard — all-or-nothing.  `rebalance()` applies the same move to drain
    a saturated shard.
  * **epochs** — `checkpoint()` writes the federation registry (placement
    map + a fallback record per study) as its own committed epoch under
    `<root>/fed/` FIRST, then checkpoints each shard.  Shards crash and
    restore independently from their own latest epoch;
    `revive_shard`/`restore` reconcile a restored shard against the
    federation registry — studies the shard forgot (created or migrated
    in after its epoch) are re-adopted from the fallback records, studies
    it no longer owns are expelled.  Committed observations survive;
    uncommitted ones are lost, never replayed (per-study PRNG streams
    persist in the snapshots, so a retried round never repeats a
    pre-crash batch).

The routing/registry/reconcile core lives in `FederationBase` and is
shared with the cross-host deployment: `FederatedGateway` applies it with
in-memory method calls (every shard ticker on one event loop — the
degenerate single-process case), while `repro.hpo.transport`'s
`TransportFederation` applies the SAME core over a socket RPC connection
per shard process (DESIGN.md §14).  Shards are only ever touched through
the public `StudyGateway` federation surface (`is_quiescent`,
`registry_record`, `sync_registry`, `adopt_study`/`detach_study`/
`expel_study`, `abandon`) — privates don't cross process boundaries.
"""
from __future__ import annotations

import asyncio
import dataclasses
import hashlib
import json
import os

from repro import checkpoint as ckpt_mod
from repro.hpo.gateway import GatewayConfig, StudyGateway
from repro.hpo.pool import SchedulerConfig, Trial
from repro.hpo.space import SearchSpace

__all__ = ["FederationConfig", "FederationBase", "FederatedGateway",
           "rendezvous_shard"]


def rendezvous_shard(sid: int, n_shards: int) -> int:
    """Rendezvous (HRW) ring position of study `sid` over `n_shards`."""
    best, best_w = 0, b""
    for shard in range(n_shards):
        w = hashlib.sha256(f"{shard}:{sid}".encode()).digest()
        if w > best_w:
            best, best_w = shard, w
    return best


@dataclasses.dataclass(frozen=True)
class FederationConfig:
    """Federation-level knobs (each shard's shape comes from the shared
    SchedulerConfig/GatewayConfig)."""

    n_shards: int = 2
    ckpt_dir: str | None = None  # federation root; shard i stores under
    # <root>/shard-<i>/, the federation registry under <root>/fed/.
    # None = SchedulerConfig.ckpt_dir is the root.


class FederationBase:
    """Routing + registry + reconcile core of a shard federation.

    Owns everything that is a pure function of the front end's own state:
    the global sid space, the placement map, the fallback records, the
    epoch registry payload (build/parse/validate), and the reconcile plan
    for a restored shard.  Subclasses apply the plans to their shards —
    `FederatedGateway` with in-memory method calls, `TransportFederation`
    (repro.hpo.transport) with socket RPCs — so the two deployments can
    never drift on routing or recovery semantics.
    """

    def __init__(self, template_space: SearchSpace, cfg: SchedulerConfig,
                 gw: GatewayConfig | None = None,
                 fed: FederationConfig | None = None):
        self.fed = fed or FederationConfig()
        if self.fed.n_shards < 1:
            raise ValueError("FederationConfig.n_shards must be >= 1")
        root = self.fed.ckpt_dir or cfg.ckpt_dir
        if root is None:
            raise ValueError(
                "a federation needs a checkpoint root "
                "(FederationConfig.ckpt_dir or SchedulerConfig.ckpt_dir)")
        self._root = root
        self._fed_dir = os.path.join(root, "fed")
        self._template_space = template_space
        self.cfg = cfg
        self.gw = gw or GatewayConfig()
        self._placement: dict[int, int] = {}   # sid -> shard index
        self._records: dict[int, dict] = {}    # last-known fallback record
        # per study (kept fresh at checkpoint; serves studies whose shard
        # is dead when the next epoch is written)
        self._closed_sids: set[int] = set()
        self._next_sid = 0
        self._epoch = 0

    # -- routing ------------------------------------------------------------
    def route(self, sid: int) -> int:
        """Ring position of a study: rendezvous hash over the shard set."""
        return rendezvous_shard(sid, self.fed.n_shards)

    def shard_of(self, sid: int) -> int:
        """Current placement (ring position unless migrated)."""
        if sid in self._closed_sids:
            raise RuntimeError(f"study {sid} is closed")
        if sid not in self._placement:
            raise KeyError(f"unknown study id {sid}")
        return self._placement[sid]

    def shard_dir(self, i: int) -> str:
        """Shard i's checkpoint store under the shared federation root."""
        return os.path.join(self._root, f"shard-{i}")

    def study_ids(self) -> list[int]:
        return sorted(self._placement)

    # -- the epoch registry (build / persist / parse) -----------------------
    def _registry_payload(self, records: dict[int, dict]) -> dict:
        """Federation registry payload: placement + one fallback record
        per study so a shard restored from an older epoch can re-adopt
        studies it forgot."""
        return {
            "epoch": self._epoch,
            "n_shards": self.fed.n_shards,
            "next_sid": self._next_sid,
            "closed_sids": sorted(self._closed_sids),
            "placement": {str(s): sh for s, sh in
                          sorted(self._placement.items())},
            "records": {str(s): r for s, r in sorted(records.items())},
        }

    def _save_epoch(self, records: dict[int, dict]) -> int:
        """Commit epoch N of the federation registry under `<root>/fed/`.
        Must be written BEFORE the shard checkpoints (it may never
        reference shard state newer than itself)."""
        self._epoch += 1
        self._records.update(records)
        ckpt_mod.save(self._fed_dir, self._epoch, {},
                      metadata={"federation":
                                json.dumps(self._registry_payload(records))},
                      keep=3)
        return self._epoch

    def _load_epoch(self) -> bool:
        """Parse the latest committed federation epoch into the front
        end's bookkeeping; False when none exists.

        Fails fast when the recorded shard count disagrees with the live
        `FederationConfig`: with FEWER live shards, placements recorded on
        the missing shards would strand every routed call on an
        out-of-range index; with MORE, `route()` sends NEW sids onto
        shards the old placements know nothing about while existing
        studies stay put — two silently different topologies.  Resizing a
        federation is a migration (move the studies, then re-checkpoint),
        not a restore-time reinterpretation.
        """
        out = ckpt_mod.restore_latest(self._fed_dir, {})
        if out is None:
            return False
        _epoch, _tree, meta = out
        reg = json.loads(meta["federation"])
        saved_shards = int(reg["n_shards"])
        if saved_shards != self.fed.n_shards:
            raise ValueError(
                f"federation registry under {self._fed_dir} was written "
                f"with n_shards={saved_shards} but the live "
                f"FederationConfig has n_shards={self.fed.n_shards}; "
                "restore with the recorded shard count (resizing is a "
                "migration, not a restore)")
        self._epoch = int(reg["epoch"])
        self._next_sid = int(reg["next_sid"])
        self._closed_sids = set(int(s) for s in reg["closed_sids"])
        self._placement = {int(s): int(sh)
                           for s, sh in reg["placement"].items()}
        self._records = {int(s): r for s, r in reg["records"].items()}
        return True

    def _merge_summaries(self, per_shard: dict[int, dict],
                         dead: list[int]) -> dict:
        """Federation-wide telemetry from per-shard summaries: lifetime
        counters summed, q-width histograms merged."""
        out = {"ticks": 0, "asks_served": 0, "absorbed": 0,
               "evictions": 0, "restores": 0, "fantasy_rollbacks": 0,
               "fantasy_active": 0, "escalated": 0, "saturated": 0,
               "q_width_hist": {},
               "n_shards": self.fed.n_shards,
               "dead_shards": sorted(dead),
               "studies": len(self._placement),
               "epoch": self._epoch}
        for i in sorted(per_shard):
            s = per_shard[i]
            for k in ("ticks", "asks_served", "absorbed", "evictions",
                      "restores", "fantasy_rollbacks", "fantasy_active"):
                out[k] += s[k]
            for k in ("escalated", "saturated"):
                # saturation gauges (DESIGN.md §15); .get so a newer front
                # end keeps merging summaries from an older remote shard
                out[k] += s.get(k, 0)
            for w, n in s["q_width_hist"].items():
                out["q_width_hist"][w] = out["q_width_hist"].get(w, 0) + n
        out["per_shard"] = {str(i): s for i, s in sorted(per_shard.items())}
        return out

    # -- reconcile planning -------------------------------------------------
    def _reconcile_plan(self, i: int, present: set[int]
                        ) -> tuple[list[int], list[int]]:
        """What a just-restored shard `i` must change, given the study ids
        `present` in its restored registry: (expel, missing) — `expel` are
        studies it no longer owns (closed or migrated away on a timeline
        it lost), `missing` are studies the federation placed on it after
        its epoch (re-adopt from the fallback record, or recreate empty
        when none exists — same seed law as create_study)."""
        owned = {sid for sid, shard in self._placement.items()
                 if shard == i}
        return sorted(present - owned), sorted(owned - present)


class FederatedGateway(FederationBase):
    """Route one global study population across N in-process StudyGateway
    shards — the single-process degenerate case of the federation (every
    shard ticker shares this process's event loop); the cross-host
    deployment is `repro.hpo.transport.TransportFederation` over the same
    `FederationBase` core."""

    def __init__(self, template_space: SearchSpace, cfg: SchedulerConfig,
                 gw: GatewayConfig | None = None,
                 fed: FederationConfig | None = None):
        super().__init__(template_space, cfg, gw, fed)
        self.shards: list[StudyGateway | None] = [
            self._build_shard(i) for i in range(self.fed.n_shards)]

    def _build_shard(self, i: int) -> StudyGateway:
        cfg = dataclasses.replace(self.cfg, ckpt_dir=self.shard_dir(i))
        return StudyGateway(self._template_space, cfg, self.gw)

    def _live(self, i: int) -> StudyGateway:
        gw = self.shards[i]
        if gw is None:
            raise RuntimeError(f"shard {i} is down (kill_shard); "
                               "revive_shard to restore it from its epoch")
        return gw

    def _gw_for(self, sid: int) -> StudyGateway:
        return self._live(self.shard_of(sid))

    def _live_shards(self) -> list[tuple[int, StudyGateway]]:
        return [(i, gw) for i, gw in enumerate(self.shards)
                if gw is not None]

    # -- lifecycle ----------------------------------------------------------
    def create_study(self, space: SearchSpace | None = None,
                     name: str | None = None) -> int:
        """Register a study on its ring shard; global sids keep per-study
        suggestion streams identical to a single pool's."""
        sid = self._next_sid
        shard = self.route(sid)
        self._live(shard).create_study(space, name, sid=sid)
        self._next_sid = sid + 1
        self._placement[sid] = shard
        return sid

    def close_study(self, sid: int) -> None:
        self._gw_for(sid).close_study(sid)
        self._placement.pop(sid, None)
        self._records.pop(sid, None)
        self._closed_sids.add(sid)

    # -- ask / tell ---------------------------------------------------------
    async def ask(self, sid: int, q: int = 1) -> Trial | list[Trial]:
        """Routed ask; admission (queue depth, per-study in-flight cap,
        n_max headroom, q-width) is enforced by the owning shard."""
        return await self._gw_for(sid).ask(sid, q)

    def ask_nowait(self, sid: int, q: int = 1) -> None:
        self._gw_for(sid).ask_nowait(sid, q)

    def tell(self, sid: int, trial: Trial, value: float,
             cost: float = 1.0) -> None:
        self._gw_for(sid).tell(sid, trial, value, cost)

    def tell_failure(self, sid: int, trial: Trial, error: str) -> None:
        self._gw_for(sid).tell_failure(sid, trial, error)

    async def drain(self) -> None:
        await asyncio.gather(*(gw.drain() for _i, gw in
                               self._live_shards()))

    def tick(self) -> int:
        """Drive one synchronous tick on every live shard (tests/sync
        callers; the asyncio path runs each shard's own ticker)."""
        return sum(gw.tick() for _i, gw in self._live_shards())

    async def aclose(self) -> None:
        for _i, gw in self._live_shards():
            await gw.aclose()

    # -- introspection ------------------------------------------------------
    def study_info(self, sid: int) -> dict:
        info = self._gw_for(sid).study_info(sid)
        info["shard"] = self.shard_of(sid)
        return info

    def summary(self) -> dict:
        """Federation-wide telemetry: lifetime counters summed across live
        shards, q-width histograms merged, plus the per-shard summaries."""
        return self._merge_summaries(
            {i: gw.summary() for i, gw in self._live_shards()},
            [i for i, gw in enumerate(self.shards) if gw is None])

    # -- migration / rebalancing --------------------------------------------
    def migrate_study(self, sid: int, dst: int) -> None:
        """Move one quiescent study to shard `dst` — evict-here /
        restore-there on the bitwise-exact snapshot machinery.

        All-or-nothing: export evicts on the source (the snapshot commits
        in the source store), the copy publishes atomically on the
        destination, adoption refuses unless the copied version is
        committed — any fault up to the final detach leaves the study
        intact (and restorable) on its source shard."""
        src = self.shard_of(sid)
        if dst == src:
            return
        src_gw, dst_gw = self._live(src), self._live(dst)
        record = src_gw.export_for_migration(sid)
        if record["evicted_ever"]:
            ckpt_mod.copy_study_version(src_gw.cfg.ckpt_dir,
                                        dst_gw.cfg.ckpt_dir,
                                        record["key"], record["version"])
        dst_gw.adopt_study(record)
        src_gw.detach_study(sid)
        self._placement[sid] = dst
        self._records[sid] = dict(record, shard=dst)

    def rebalance(self) -> list[tuple[int, int, int]]:
        """Even out study counts across live shards by migrating quiescent
        studies from the fullest shard to the emptiest (lowest sid first —
        deterministic).  Returns the moves as (sid, src, dst)."""
        moves: list[tuple[int, int, int]] = []
        live = [i for i, gw in enumerate(self.shards) if gw is not None]
        if len(live) < 2:
            return moves
        while True:
            counts = {i: sum(1 for s in self._placement.values() if s == i)
                      for i in live}
            src = max(live, key=lambda i: (counts[i], i))
            dst = min(live, key=lambda i: (counts[i], i))
            if counts[src] - counts[dst] <= 1:
                return moves
            movable = sorted(
                sid for sid, s in self._placement.items()
                if s == src and self.shards[src].is_quiescent(sid))
            if not movable:
                return moves
            sid = movable[0]
            self.migrate_study(sid, dst)
            moves.append((sid, src, dst))

    # -- epochs: checkpoint / crash / restore -------------------------------
    def _collect_records(self) -> dict[int, dict]:
        """One fallback record per placed study: fresh from its live
        shard, else the last one seen (its shard is dead right now)."""
        records: dict[int, dict] = {}
        for sid, shard in sorted(self._placement.items()):
            gw = self.shards[shard]
            if gw is not None and sid in set(gw.study_ids()):
                records[sid] = dict(gw.registry_record(sid), shard=shard)
            elif sid in self._records:
                records[sid] = self._records[sid]
        return records

    def checkpoint(self) -> int:
        """Write federation epoch N: the federation registry commits FIRST
        (it must never reference shard state newer than itself), then each
        live shard checkpoints.  A crash between the two restores shards
        from their previous epoch and reconciles against this registry —
        committed observations survive either way.  Dead shards are
        skipped (their fallback records ride the registry).  Returns the
        epoch number."""
        epoch = self._save_epoch(self._collect_records())
        for _i, gw in self._live_shards():
            gw.checkpoint()
        return epoch

    def kill_shard(self, i: int) -> None:
        """Simulate a shard crash: the in-memory gateway is discarded
        WITHOUT a checkpoint (its uncommitted work is lost, like a
        SIGKILL).  Parked clients' futures are cancelled — a real crash
        severs their connections the same way."""
        gw = self.shards[i]
        self.shards[i] = None
        if gw is not None:
            gw.abandon()

    def revive_shard(self, i: int) -> None:
        """Bring a dead shard back from ITS latest committed epoch and
        reconcile it against the federation registry: nothing pre-crash
        replays (PRNG streams persist in the snapshots), no committed
        tell is lost, studies the shard's epoch predates are re-adopted
        from the fallback records (their uncommitted observations are
        gone), and studies it no longer owns are expelled."""
        if self.shards[i] is not None:
            raise RuntimeError(f"shard {i} is already live")
        gw = self._build_shard(i)
        gw.restore()  # False (fresh) when the shard never checkpointed
        self.shards[i] = gw
        self._reconcile_shard(i)

    def _reconcile_shard(self, i: int) -> None:
        gw = self.shards[i]
        expel, missing = self._reconcile_plan(i, set(gw.study_ids()))
        for sid in expel:
            gw.expel_study(sid)
        for sid in missing:
            rec = self._records.get(sid)
            if rec is None:
                # never checkpointed anywhere: recreate empty from the
                # global id (same seed law as create_study)
                gw.create_study(self._template_space, sid=sid)
            else:
                gw.adopt_study(rec, require_snapshot=False)
        gw.sync_registry(self._next_sid, self._closed_sids)
        # refresh fallback records from the authoritative shard registry
        for sid in gw.study_ids():
            if self._placement.get(sid) == i:
                self._records[sid] = dict(gw.registry_record(sid), shard=i)

    def restore(self) -> bool:
        """Resume the whole federation: latest federation epoch for the
        registry, each shard from ITS latest epoch, then reconcile.
        Refuses a registry whose recorded shard count differs from the
        live config (see `FederationBase._load_epoch`)."""
        if not self._load_epoch():
            return False
        self.shards = [None] * self.fed.n_shards
        for i in range(self.fed.n_shards):
            gw = self._build_shard(i)
            gw.restore()
            self.shards[i] = gw
            self._reconcile_shard(i)
        return True
