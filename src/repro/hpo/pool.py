"""Multi-tenant StudyPool: S concurrent HPO studies on one accelerator.

The paper's O(n^2) lazy append makes a *single* study cheap enough that the
device idles between absorptions; the next scaling axis (ROADMAP: serve
heavy traffic) is running **many concurrent studies**.  `StudyPool`
multiplexes S studies over one `StudyEngine` (a stacked `LazyGPState`,
DESIGN.md §7):

  * **batched suggest** — `suggest_all` advances every study's EI
    optimization in ONE jitted vmapped dispatch instead of S sequential
    program launches (the multi-tenant throughput win, `bench_pool`).
  * **completion-order absorb** — results are routed to the owning study as
    they arrive (`absorb`), or drained in masked batched rounds
    (`absorb_many`) of at most one observation per study per dispatch.
  * **fused serving rounds** — `advance_round` absorbs the last round's
    completions AND suggests the next batch in ONE jitted program with
    donated state buffers (the request-driven service hot path).
  * **device mesh** — with `cfg.mesh` set, the batched rounds run as
    `shard_map` programs over a (study x restart) mesh (DESIGN.md §8);
    `mesh="none"` is the degenerate single-device case of the same code.
  * **per-study everything** — trial ledgers, PRNG streams, capacity
    guards, fault policy (retry / penalized pseudo-observation), lag
    counters, and clamp telemetry are tracked per tenant; one study filling
    up or crashing never corrupts a neighbor.
  * **pool checkpointing** — the stacked GP state and every study's ledger
    ride one atomic `checkpoint.store` snapshot; a restarted pool resumes
    all S posteriors identically.

`TrialScheduler` is the S = 1 degenerate case: it wraps a one-study pool,
so the scheduler and the pool share exactly one suggest/absorb code path.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt_mod
from repro.core import acquisition as acq_mod
from repro.core import gp as gp_mod
from repro.core import neural_basis as nb_mod
from repro.core.kernels import KernelParams
from repro.hpo.engine import StudyEngine
from repro.hpo.space import SearchSpace


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    """Shared study/pool configuration (one GP shape for every tenant)."""

    n_max: int = 512
    kernel: str = "matern52"
    lag: int = 0                 # 0 = fully lazy (paper's main mode)
    parallel: int = 1            # t (elastic; re-read each round)
    rho0: float = 0.25
    noise2: float = 1e-5
    seed: int = 0
    implementation: str = "auto"  # linalg substrate (auto|pallas|xla|ref)
    mixed: bool = False          # force mixed-space closures (DESIGN.md
    # §10) even when every constructor space is all-continuous — a gateway
    # that must admit int/categorical tenants later sets this; pools whose
    # constructor spaces already carry discrete dims enable it implicitly
    mesh: str = "none"           # device mesh for the batched suggest path
    # (DESIGN.md §8): "none" = single program on one device (default);
    # "auto" = factor all visible devices into study x restart shards;
    # "SxR" (e.g. "4x2") = explicit shard counts.  Threaded to StudyEngine
    # exactly like `implementation`; "none" is the degenerate case of the
    # same closures.
    failure_penalty: float | None = None  # None: drop; else pseudo-y
    max_retries: int = 1
    ckpt_dir: str | None = None
    ckpt_every: int = 1          # absorptions between pool checkpoints; a
    # many-tenant pool should raise this — every snapshot serializes the
    # whole stacked state (2 S n_max^2 floats) plus all S ledgers
    inv_refresh: int = 128       # fully-lazy mode (lag=0): rebuild the
    # factor + maintained inverse from the Gram every `inv_refresh` appends
    # per study, re-anchoring float32 drift without touching the kernel
    # params (0 = never; lag > 0 supersedes it — see DESIGN.md §4)
    acq: acq_mod.AcqConfig = dataclasses.field(
        default_factory=lambda: acq_mod.AcqConfig(restarts=48,
                                                  ascent_steps=20))
    fantasy: gp_mod.FantasyConfig = dataclasses.field(
        default_factory=gp_mod.FantasyConfig)  # liar policy for q-asks
    # (DESIGN.md §12): "mean" = kriging believer, "pessimistic" = constant
    # liar.  A Python constant inside the engine's q-ask closures.
    neural: nb_mod.NeuralConfig = dataclasses.field(
        default_factory=nb_mod.NeuralConfig)  # escalated-tier model
    # (DESIGN.md §15): MLP feature width/depth, Bayesian-linear-head noise,
    # and the refit cadence (the NB tier's `lag`) used when a saturated
    # study is promoted off the fixed-shape lazy GP.


@dataclasses.dataclass
class Trial:
    trial_id: int
    unit: np.ndarray
    hparams: dict
    status: str = "pending"      # pending | running | told | done | failed
    value: float | None = None
    error: str | None = None
    started: float = 0.0
    finished: float = 0.0
    retries: int = 0
    clamp_count: int | None = None  # cumulative GP conditioning-floor hits
    # at absorb time (ill-conditioning telemetry, DESIGN.md §6)
    cost: float = 1.0            # tell-time observation cost (DESIGN.md
    # §15): training-set row of the escalated tier's log-cost head and the
    # denominator of EI-per-unit-cost acquisition


def _trial_from_dict(t: dict) -> Trial:
    """Rebuild a ledger Trial from its checkpoint/export dict form."""
    return Trial(t["trial_id"], np.asarray(t["unit"], np.float32),
                 t["hparams"], t["status"], t["value"], t["error"],
                 t["started"], t["finished"], t["retries"],
                 t.get("clamp_count"), t.get("cost", 1.0))


def _materialize(x) -> np.ndarray:
    """Host fetch of a staged round's device outputs.

    Module-level so fault tests can inject a device-side failure at the
    materialization boundary — the point where a pipelined tick's in-flight
    runtime error actually surfaces to the host.
    """
    return np.asarray(x)


class _PendingRound:
    """A dispatched-but-unmaterialized fused serving round.

    `advance_round(...)` == `advance_round_begin(...).finish()`.  Every
    device dispatch is ISSUED at begin time in exactly the serial order
    (fantasy rollback, overflow drain, fused advance, clamp copy,
    refantasize), so the device program stream — and therefore the final
    state bits — are identical whether or not the host defers `finish()`.
    `finish()` only does host work: materialize the suggestions, flip the
    absorbed trials' ledger status, and mint the ledger Trial objects.

    The pending record holds ONLY fresh dispatch outputs (`units`, a
    copied clamp vector) — never a reference into `engine.state`, whose
    buffers the NEXT staged round consumes by donation.
    """

    __slots__ = ("_pool", "_first", "_ids", "_need_seed", "_t",
                 "_units", "_clamps", "_nb_units", "_finished")

    def __init__(self, pool: "StudyPool", first: dict, ids: list,
                 need_seed: set, t: int, units, clamps, nb_units=None):
        self._pool = pool
        self._first = first
        self._ids = ids
        self._need_seed = need_seed
        self._t = t
        self._units = units
        self._clamps = clamps
        self._nb_units = nb_units or {}
        self._finished = False

    def finish(self) -> dict[int, list[Trial]]:
        """Materialize the round: commit ledger flips, mint suggestions."""
        if self._finished:
            raise RuntimeError("pending round already finished")
        self._finished = True
        pool = self._pool
        units = None if self._units is None else _materialize(self._units)
        if self._first:
            clamps = np.asarray(self._clamps)
            # "done" only after the fused round committed (see absorb())
            for sid, (tr, val) in self._first.items():
                tr.status = "done"
                tr.value = float(val)
                tr.finished = time.time()
                tr.clamp_count = int(clamps[sid])
            pool._n_done += len(self._first)
        out: dict[int, list[Trial]] = {}
        for s in self._ids:
            if s in self._need_seed:
                out[s] = pool.seed_trials(s, self._t)
            elif s in self._nb_units:
                # escalated tenants: their suggestions come off the NB
                # posterior's own staged dispatch, not the GP stack's lane
                out[s] = [pool._make_trial(s, u)
                          for u in _materialize(self._nb_units[s])]
            else:
                out[s] = [pool._make_trial(s, u) for u in units[s]]
        pool._maybe_checkpoint()
        return out


@dataclasses.dataclass
class StudyHandle:
    """Host-side per-tenant record: ledger, id counter, PRNG streams."""

    study_id: int
    space: SearchSpace
    name: str
    trials: list[Trial] = dataclasses.field(default_factory=list)
    next_id: int = 0
    key: jax.Array | None = None
    rng: np.random.Generator | None = None  # seed-trial stream; persistent
    # so repeated seeding draws fresh points, never the same batch twice


class StudyPool:
    """S concurrent studies multiplexed over one batched lazy-GP engine.

    All studies share the GP shape (`cfg.n_max`, `space.dim`) — the stacked
    buffers are one rectangular block — but own independent posteriors,
    ledgers, and fault state.  Spaces may differ per study as long as their
    dimensionality matches.
    """

    def __init__(self, spaces: Sequence[SearchSpace], cfg: SchedulerConfig,
                 names: Sequence[str] | None = None):
        spaces = list(spaces)
        if not spaces:
            raise ValueError("StudyPool needs at least one study")
        dims = {sp.dim for sp in spaces}
        if len(dims) != 1:
            raise ValueError(
                f"all studies must share one dimensionality, got {dims} "
                "(the stacked (S, n_max, d) buffers are rectangular)")
        names = list(names) if names is not None else [
            f"study{i}" for i in range(len(spaces))]
        if len(names) != len(spaces):
            raise ValueError("len(names) != len(spaces)")
        self.cfg = cfg
        # Descriptors are only materialized (S x 5 device arrays) when the
        # engine will actually thread them — all-continuous pools keep the
        # pre-§10 constructor cost.
        descs = [sp.descriptor() for sp in spaces] \
            if cfg.mixed or any(sp.has_discrete for sp in spaces) else None
        self.engine = StudyEngine(spaces[0].dim, cfg, len(spaces),
                                  descs=descs)
        self.studies = [
            StudyHandle(i, sp, names[i],
                        key=jax.random.PRNGKey(cfg.seed + i),
                        rng=np.random.default_rng(cfg.seed + i))
            for i, sp in enumerate(spaces)]
        self._done_at_last_ckpt = 0
        self._n_done = 0  # absorptions ever (ckpt cadence + monotonic step;
        # counts absorbs into since-evicted slots, unlike total_done())
        self.last_restore_meta: dict | None = None  # set by restore()
        # Fantasy protocol (DESIGN.md §12): per-slot pending fantasy points,
        # in append order.  The slot's device n exceeds its real ledger by
        # exactly len(self._fantasies[slot]); every real absorb first rolls
        # the fantasy rows back (bitwise truncate), then re-fantasizes the
        # survivors.  `fantasy_rollbacks` counts truncations performed.
        self._fantasies: list[list[np.ndarray]] = [[] for _ in spaces]
        self.fantasy_rollbacks = 0

    @property
    def n_studies(self) -> int:
        return len(self.studies)

    # -- ledger -------------------------------------------------------------
    def _make_trial(self, study_id: int, unit: np.ndarray) -> Trial:
        h = self.studies[study_id]
        tr = Trial(h.next_id, unit.astype(np.float32),
                   h.space.to_hparams(unit))
        h.next_id += 1
        h.trials.append(tr)
        return tr

    def _split(self, study_id: int) -> jax.Array:
        h = self.studies[study_id]
        h.key, sub = jax.random.split(h.key)
        return sub

    def _split_many(self, ids: Sequence[int]) -> np.ndarray:
        """Advance several studies' PRNG streams in ONE vmapped dispatch.

        Returns the subkeys as a host `(len(ids), 2)` uint32 array; values
        are bit-identical to per-study `_split` calls (threefry is
        elementwise), so batched and routed suggest paths draw the same
        streams.
        """
        if not ids:
            return np.zeros((0, 2), np.uint32)
        stacked = jnp.stack([self.studies[s].key for s in ids])
        new = np.asarray(jax.vmap(jax.random.split)(stacked))
        for j, s in enumerate(ids):
            self.studies[s].key = jnp.asarray(new[j, 0])
        return new[:, 1]

    def state(self, study_id: int) -> gp_mod.LazyGPState:
        """Unstacked single-study GP view."""
        return self.engine.study_state(study_id)

    # -- saturation escalation (DESIGN.md §15) ------------------------------
    def tier(self, study_id: int) -> int:
        """0 = lazy GP, 1 = neural basis (escalated past n_max)."""
        return self.engine.tier(study_id)

    def promote(self, study_id: int) -> None:
        """Escalate a saturated study to the neural-basis tier.

        Pending fantasy rows are first rolled back (bitwise GP truncate) so
        the NB model trains on the REAL ledger + tell costs only; the
        survivors are then re-fantasized against the escalated posterior —
        outstanding q-asks keep repelling their regions across the
        promotion, exactly as they would across a tell.
        """
        pend = self._fantasies[study_id]
        if pend:
            self.engine.truncate_slot(
                study_id, self.engine.n(study_id) - len(pend))
            self.fantasy_rollbacks += 1
        self.engine.promote_slot(study_id, self._split(study_id))
        if pend:
            self.engine.nb_refantasize(study_id, np.stack(pend))

    # -- suggest ------------------------------------------------------------
    def seed_trials(self, study_id: int, n: int) -> list[Trial]:
        h = self.studies[study_id]
        return [self._make_trial(study_id, u)
                for u in h.space.sample(h.rng, n)]

    def suggest(self, study_id: int, t: int | None = None) -> list[Trial]:
        """Top-t distinct EI local maxima from one study's posterior."""
        t = t or self.cfg.parallel
        if self.engine.tier(study_id):
            units, _ = self.engine.nb_suggest(study_id,
                                              self._split(study_id), top_t=t)
        elif self.engine.n(study_id) == 0:
            return self.seed_trials(study_id, t)
        else:
            units, _ = self.engine.suggest(study_id, self._split(study_id),
                                           top_t=t)
        return [self._make_trial(study_id, np.asarray(u)) for u in units]

    # -- fantasy protocol: batched q-suggestion (DESIGN.md §12) -------------
    def fantasy_active(self, study_id: int) -> int:
        """Pending fantasy rows currently appended to this slot's factor."""
        return len(self._fantasies[study_id])

    def n_real(self, study_id: int) -> int:
        """Real-ledger active count (model n minus pending fantasy rows)."""
        n = self.engine.nb_n(study_id) if self.engine.tier(study_id) \
            else self.engine.n(study_id)
        return n - len(self._fantasies[study_id])

    def ask_q(self, study_id: int, q: int) -> list[Trial]:
        """q distinct suggestions through the fantasy fast path.

        ONE jitted dispatch (engine `ask_q`) runs q rounds of
        suggest-then-fantasize; the q fantasy rows PERSIST in the slot's
        factor — later asks (any width) see the collapsed variance at the
        outstanding points — until a real observation arrives and the
        absorb paths roll them back (bitwise truncate + replay).  Studies
        still empty of observations get q random seed trials instead
        (host-side, mirroring `suggest`).
        """
        if q < 1:
            raise ValueError(f"q must be >= 1, got {q}")
        if self.engine.tier(study_id):
            # escalated tier: the NB ledger doubles instead of filling, so
            # q-asks never hit a capacity guard
            units, _ = self.engine.nb_ask_q(study_id,
                                            self._split(study_id), q)
        else:
            if self.engine.n(study_id) == 0:
                return self.seed_trials(study_id, q)
            gp_mod.ensure_capacity(self.engine.n(study_id),
                                   self.cfg.n_max, q)
            units, _ = self.engine.ask_q(study_id, self._split(study_id), q)
        units = np.asarray(units)
        self._fantasies[study_id].extend(u.copy() for u in units)
        return [self._make_trial(study_id, u) for u in units]

    def _rollback_for_events(
            self, events: Sequence[tuple[int, Trial, float]]) -> None:
        """Truncate every fantasy-active study named in `events` back to its
        real ledger (bitwise — `engine.truncate_slot`), dropping each told
        trial's point from that study's pending list.  Told points that were
        never fantasies (plain `suggest` trials, foreign tells) trigger the
        same rollback: the real append must never land on fantasized rows.
        """
        by_sid: dict[int, list[Trial]] = {}
        for sid, tr, _ in events:
            by_sid.setdefault(sid, []).append(tr)
        for sid, trs in by_sid.items():
            pend = self._fantasies[sid]
            if not pend:
                continue
            if self.engine.tier(sid):
                # NB rank-1 updates are not bitwise-reversible: rollback is
                # a pre-fantasy snapshot restore (exact by construction)
                self.engine.nb_rollback(sid)
            else:
                self.engine.truncate_slot(sid,
                                          self.engine.n(sid) - len(pend))
            self.fantasy_rollbacks += 1
            for tr in trs:
                for i, u in enumerate(pend):
                    if np.array_equal(u, tr.unit):
                        del pend[i]
                        break

    def release_fantasies(self, study_id: int, units) -> int:
        """Drop abandoned fantasy rows (failed or cancelled asks whose tell
        will never come): one bitwise truncate + one batched replay of the
        survivors.  Each unit releases at most one pending row; unknown
        units are ignored.  Returns the number of rows released."""
        pend = self._fantasies[study_id]
        if not pend:
            return 0
        drop: list[int] = []
        for u in units:
            for i, p in enumerate(pend):
                if i not in drop and np.array_equal(p, u):
                    drop.append(i)
                    break
        if not drop:
            return 0
        if self.engine.tier(study_id):
            self.engine.nb_rollback(study_id)
        else:
            self.engine.truncate_slot(
                study_id, self.engine.n(study_id) - len(pend))
        self.fantasy_rollbacks += 1
        self._fantasies[study_id] = [
            p for i, p in enumerate(pend) if i not in drop]
        self._refantasize_pending([study_id])
        return len(drop)

    def _refantasize_pending(self, sids) -> None:
        """Re-append each study's surviving fantasy points in ONE batched
        `lazy_append_rows` dispatch per study (liar values recomputed
        against the now-updated real posterior — fresher than the originals,
        which is fine: fantasy rows are scratch)."""
        for sid in sorted(set(sids)):
            pend = self._fantasies[sid]
            if pend:
                if self.engine.tier(sid):
                    self.engine.nb_refantasize(sid, np.stack(pend))
                else:
                    self.engine.refantasize(sid, np.stack(pend))

    def _check_capacity(self,
                        events: Sequence[tuple[int, Trial, float]]) -> None:
        """All-or-nothing capacity contract: validate the WHOLE queue
        (per-study multiplicity included) BEFORE mutating any ledger, so a
        `GPCapacityError` from one full study never leaves a neighbor's
        trial marked done without its observation absorbed.  Surviving
        fantasy rows count against capacity too: they are re-appended after
        the absorb, so `n_real + events + pending` must fit (callers run
        the fantasy rollback first, making `engine.n` the real count)."""
        counts: dict[int, int] = {}
        for sid, _, _ in events:
            counts[sid] = counts.get(sid, 0) + 1
        for sid, c in counts.items():
            if self.engine.tier(sid):
                continue   # escalated ledgers double instead of filling
            gp_mod.ensure_capacity(self.engine.n(sid), self.cfg.n_max,
                                   incoming=c + len(self._fantasies[sid]))

    def _staged_keys(self, ei_ids: Sequence[int]) -> jax.Array:
        """(S, 2) key batch: fresh subkeys for `ei_ids` (their streams
        advance, one batched split), dummy zeros for everyone else (their
        lane computes alongside but the result is discarded)."""
        subs = self._split_many(list(ei_ids))
        keys_np = np.zeros((self.n_studies, 2), np.uint32)
        keys_np[list(ei_ids)] = subs
        return jnp.asarray(keys_np)

    def suggest_all(self, t: int = 1,
                    studies: Sequence[int] | None = None
                    ) -> dict[int, list[Trial]]:
        """Batched suggestion round: ONE vmapped dispatch for all studies.

        Studies still empty of observations get random seed trials instead
        (host-side); everyone else shares the single batched EI program.
        Returns {study_id: [t trials]} for the requested studies (default
        all).
        """
        ids = list(studies) if studies is not None else \
            list(range(self.n_studies))
        nb_set = {s for s in ids if self.engine.tier(s)}
        need_ei = sorted(s for s in ids
                         if s not in nb_set and self.engine.n(s) > 0)
        ei_set = set(need_ei)
        units_all = None
        if need_ei:
            units_all = np.asarray(self.engine.suggest_all(
                self._staged_keys(need_ei), top_t=t)[0])
        out: dict[int, list[Trial]] = {}
        for s in ids:
            if s in ei_set:
                out[s] = [self._make_trial(s, u) for u in units_all[s]]
            elif s in nb_set:
                # escalated tenants route through their own NB dispatch
                # (cached by shape + static config, never re-traced per slot)
                units, _ = self.engine.nb_suggest(s, self._split(s), top_t=t)
                out[s] = [self._make_trial(s, u)
                          for u in np.asarray(units)]
            else:
                out[s] = self.seed_trials(s, t)
        return out

    def advance_round_begin(self,
                            events: Sequence[tuple[int, Trial, float]],
                            t: int = 1,
                            studies: Sequence[int] | None = None
                            ) -> _PendingRound:
        """Stage a fused serving round: dispatch everything, defer commits.

        Issues the round's whole device program stream (fantasy rollback,
        overflow drain, fused donated advance, refantasize) in the serial
        order and returns a `_PendingRound` whose `finish()` performs the
        host-side half — materialize suggestions, flip told trials to
        "done", mint ledger Trials.  The pipelined gateway stages tick t+1
        while tick t's program is still in flight on the device; calling
        `finish()` immediately is exactly `advance_round`.

        All-or-nothing guards run at STAGE time: a capacity error raises
        here with no ledger or buffer mutated (beyond the fantasy rollback,
        which is bitwise-restorable by re-fantasizing).  Once staged, the
        only failure left is a device runtime fault, which surfaces at
        `finish()` before any ledger flip.
        """
        ids = list(studies) if studies is not None else \
            list(range(self.n_studies))
        nb_set = {s for s in range(self.n_studies) if self.engine.tier(s)}
        if not events:
            # deferred suggest_all: same stream staging and seed routing,
            # with the materialization/minting left to finish()
            need_ei = sorted(s for s in ids
                             if s not in nb_set and self.engine.n(s) > 0)
            units = None
            if need_ei:
                units = self.engine.suggest_all(self._staged_keys(need_ei),
                                                top_t=t)[0]
            nb_units = {s: self.engine.nb_suggest(s, self._split(s),
                                                  top_t=t)[0]
                        for s in ids if s in nb_set}
            return _PendingRound(self, {}, ids,
                                 set(ids) - set(need_ei) - nb_set,
                                 t, units, None, nb_units)
        if not ids:
            self.absorb_many(events)
            return _PendingRound(self, {}, [], set(), t, None, None)
        # Escalated tenants' completions take the routed NB absorb (their
        # ledger doubles instead of filling — no fused GP lane to share);
        # the GP-tier events keep the one-per-study fused-round split.
        nb_events = [e for e in events if e[0] in nb_set]
        gp_events = [e for e in events if e[0] not in nb_set]
        first: dict[int, tuple[Trial, float]] = {}
        overflow = []
        for sid, tr, val in gp_events:
            if sid in first:
                overflow.append((sid, tr, val))
            else:
                first[sid] = (tr, val)
        # Fantasy rollback BEFORE the capacity check and any absorb: told
        # studies are truncated to their real ledger (bitwise), so every
        # append below lands exactly where a never-fantasized run would
        # put it; survivors are re-fantasized after the round.
        self._rollback_for_events(events)
        self._check_capacity(events)
        if nb_events:
            self.absorb_many(nb_events, _fantasies_handled=True)
        if overflow:
            self.absorb_many(overflow, _fantasies_handled=True)
        dim = self.engine.gp_cfg.dim
        flags = np.zeros((self.n_studies,), bool)
        xs = np.zeros((self.n_studies, dim), np.float32)
        ys = np.zeros((self.n_studies,), np.float32)
        costs = np.ones((self.n_studies,), np.float32)
        for sid, (tr, val) in first.items():
            flags[sid] = True
            xs[sid] = tr.unit
            ys[sid] = float(val)
            costs[sid] = tr.cost
        # Studies that will still be empty after this absorb get seed
        # trials; only requested non-seed studies advance their streams.
        need_seed = {s for s in ids if s not in nb_set
                     and self.engine.n(s) == 0 and not flags[s]}
        ei_ids = [s for s in ids if s not in need_seed and s not in nb_set]
        units, _ = self.engine.advance(flags, xs, ys,
                                       self._staged_keys(ei_ids), top_t=t,
                                       costs=costs)
        # Clamp telemetry is copied into a FRESH device array before the
        # refantasize (serial read point) — holding `state.clamp_count`
        # itself would break when the next staged round donates it.
        clamps = self.engine.state.clamp_count + 0
        nb_units = {s: self.engine.nb_suggest(s, self._split(s), top_t=t)[0]
                    for s in ids if s in nb_set}
        self._refantasize_pending(sid for sid, _, _ in events)
        return _PendingRound(self, first, ids, need_seed, t, units, clamps,
                             nb_units)

    def advance_round(self, events: Sequence[tuple[int, Trial, float]],
                      t: int = 1,
                      studies: Sequence[int] | None = None
                      ) -> dict[int, list[Trial]]:
        """Fused serving round: absorb completions + suggest in ONE dispatch.

        The hot path of a request-driven service (`examples/hpo_service.py`,
        `benchmarks/bench_shard.py`): one jitted program absorbs at most
        one completed trial per study and suggests the next t points from
        the updated posteriors (state buffers donated — no copy of the
        stacked factors per round).  Suggestions are materialized as ledger
        trials only for `studies` (default all) — e.g. tenants that hit
        their budget absorb results without drawing new trials.  Events
        beyond one per study fall back to an `absorb_many` drain first;
        studies still empty after the absorb get host-side seed trials
        instead of their EI lane's output, exactly like `suggest_all`.
        Rounds with nothing to absorb skip the absorb half and delegate to
        `suggest_all`; rounds with nobody to suggest for delegate to
        `absorb_many`.

        Implemented as `advance_round_begin(...).finish()` — the pipelined
        gateway (DESIGN.md §13) drives the two halves separately.
        """
        return self.advance_round_begin(events, t=t, studies=studies).finish()

    # -- absorb -------------------------------------------------------------
    def absorb(self, study_id: int, trial: Trial, value: float,
               cost: float | None = None) -> None:
        """Completion-order absorb routed to the owning study."""
        if cost is not None:
            trial.cost = float(cost)
        self._rollback_for_events([(study_id, trial, value)])
        if self.engine.tier(study_id):
            self.engine.nb_absorb(study_id, trial.unit, float(value),
                                  cost=trial.cost)
        else:
            gp_mod.ensure_capacity(
                self.engine.n(study_id), self.cfg.n_max,
                incoming=1 + len(self._fantasies[study_id]))
            self.engine.absorb(study_id, jnp.asarray(trial.unit),
                               jnp.asarray(value, jnp.float32),
                               cost=trial.cost)
        # status flips to "done" only once the append committed: callers
        # (the gateway's fault unwind) rely on it to mean "in the GP"
        trial.status = "done"
        trial.value = float(value)
        trial.finished = time.time()
        trial.clamp_count = self.engine.clamp_count(study_id)
        self._refantasize_pending([study_id])
        self._n_done += 1
        self._maybe_checkpoint()

    def absorb_many(self,
                    events: Sequence[tuple[int, Trial, float]],
                    _fantasies_handled: bool = False) -> None:
        """Drain a completion queue in masked batched rounds.

        Events may arrive in any completion order and any per-study
        multiplicity; each round takes at most one event per study and runs
        ONE vmapped masked append, so k completions across S studies cost
        ceil(max per-study count) dispatches instead of k.

        `_fantasies_handled` is the `advance_round` overflow path: the
        caller already rolled fantasy rows back for every event and will
        re-fantasize after its own fused round — this drain must not
        re-append pending rows mid-protocol.
        """
        queue = list(events)
        dim = self.engine.gp_cfg.dim
        if not _fantasies_handled:
            self._rollback_for_events(queue)
        self._check_capacity(queue)
        # Escalated tenants drain through the routed NB absorb (rank-1
        # append, flat in n) — they have no lane in the masked GP round.
        nb_queue = [e for e in queue if self.engine.tier(e[0])]
        queue = [e for e in queue if not self.engine.tier(e[0])]
        for sid, tr, val in nb_queue:
            self.engine.nb_absorb(sid, tr.unit, float(val), cost=tr.cost)
            tr.status = "done"
            tr.value = float(val)
            tr.finished = time.time()
            tr.clamp_count = self.engine.clamp_count(sid)
            self._n_done += 1
        while queue:
            round_events: dict[int, tuple[Trial, float]] = {}
            rest = []
            for sid, tr, val in queue:
                if sid in round_events:
                    rest.append((sid, tr, val))
                else:
                    round_events[sid] = (tr, val)
            queue = rest
            flags = np.zeros((self.n_studies,), bool)
            xs = np.zeros((self.n_studies, dim), np.float32)
            ys = np.zeros((self.n_studies,), np.float32)
            costs = np.ones((self.n_studies,), np.float32)
            for sid, (tr, val) in round_events.items():
                flags[sid] = True
                xs[sid] = tr.unit
                ys[sid] = float(val)
                costs[sid] = tr.cost
            self.engine.absorb_round(flags, xs, ys, costs)
            clamps = self.engine.clamp_counts()   # one transfer for all S
            # "done" only after the round committed (see absorb())
            for sid, (tr, val) in round_events.items():
                tr.status = "done"
                tr.value = float(val)
                tr.finished = time.time()
                tr.clamp_count = int(clamps[sid])
            self._n_done += len(round_events)
        if not _fantasies_handled:
            self._refantasize_pending(sid for sid, _, _ in events)
        self._maybe_checkpoint()

    def record_failure(self, study_id: int, trial: Trial,
                       error: str) -> Trial | None:
        """Failed trial: retry (fresh suggestion) or penalize the region."""
        trial.status = "failed"
        trial.error = error
        trial.finished = time.time()
        if self.cfg.failure_penalty is not None:
            # Pseudo-observation keeps EI away from a crashing region.
            self._rollback_for_events([(study_id, trial, 0.0)])
            if self.engine.tier(study_id):
                self.engine.nb_absorb(study_id, trial.unit,
                                      float(self.cfg.failure_penalty),
                                      cost=trial.cost)
            else:
                gp_mod.ensure_capacity(
                    self.engine.n(study_id), self.cfg.n_max,
                    incoming=1 + len(self._fantasies[study_id]))
                self.engine.absorb(study_id, jnp.asarray(trial.unit),
                                   jnp.asarray(self.cfg.failure_penalty,
                                               jnp.float32),
                                   cost=trial.cost)
            trial.clamp_count = self.engine.clamp_count(study_id)
            self._refantasize_pending([study_id])
        elif any(np.array_equal(u, trial.unit)
                 for u in self._fantasies[study_id]):
            # No pseudo-observation lands, but the failed trial's fantasy
            # row must still be released: truncate + replay the survivors
            # so the slot stops repelling a region nobody is evaluating.
            self._rollback_for_events([(study_id, trial, 0.0)])
            self._refantasize_pending([study_id])
        if trial.retries < self.cfg.max_retries:
            nxt = self.suggest(study_id, 1)[0]
            nxt.retries = trial.retries + 1
            return nxt
        return None

    # -- inspection ---------------------------------------------------------
    def best(self, study_id: int) -> Trial | None:
        done = [t for t in self.studies[study_id].trials
                if t.status == "done"]
        return max(done, key=lambda t: t.value) if done else None

    def history(self, study_id: int) -> list[dict]:
        return [dataclasses.asdict(t) | {"unit": t.unit.tolist()}
                for t in self.studies[study_id].trials]

    def total_done(self) -> int:
        return sum(t.status == "done"
                   for h in self.studies for t in h.trials)

    # -- slot lifecycle (the gateway's evict/restore/reuse hooks, §9) -------
    def export_study(self, slot: int) -> dict:
        """Host-side snapshot of ONE slot: GP sub-state + handle metadata.

        The returned dict round-trips through `import_study` (and through
        `checkpoint.save_study`) bitwise: float32 buffers are exported as
        numpy arrays and re-written into the stack elementwise, so an
        evicted-and-restored study continues exactly where it left off.

        Fantasy-pinned slots refuse to export: snapshots must hold only
        real state (DESIGN.md §12) — the gateway keeps such studies
        non-evictable, so reaching this guard means a protocol bug.
        """
        if self._fantasies[slot]:
            raise RuntimeError(
                f"slot {slot} has {len(self._fantasies[slot])} active "
                "fantasy rows; eviction snapshots must see only real state "
                "(resolve or roll back the pending q-ask first)")
        h = self.studies[slot]
        tree = jax.tree.map(np.asarray,
                            dataclasses.asdict(self.engine.study_state(slot)))
        meta = {"name": h.name, "next_id": h.next_id,
                "trials": self.history(slot),
                "key": np.asarray(h.key).tolist(),
                "rng_state": h.rng.bit_generator.state,
                # escalation tier (DESIGN.md §15): the tag, the per-row
                # tell costs (float32 -> float64 -> JSON is exact), and —
                # for escalated slots — the NB state itself.  These ride
                # the snapshot as metadata because the checkpoint store
                # shape-validates `tree` against the fixed GP layout.
                "tier": self.engine.tier(slot),
                "costs": self.engine.cost_row(slot).tolist()}
        if self.engine.tier(slot):
            meta["nb"] = nb_mod.nb_to_json(self.engine.nb_state(slot))
        return {"tree": tree, "meta": meta}

    def import_study(self, slot: int, tree: dict, meta: dict,
                     space: SearchSpace | None = None) -> None:
        """Load an exported study into `slot` (inverse of `export_study`)."""
        tree = dict(tree)
        tree["params"] = KernelParams(**tree["params"])
        self.engine.load_slot(slot, gp_mod.LazyGPState(**tree))
        self.engine.clear_nb_slot(slot)
        if "costs" in meta:          # after clear (clear resets the row)
            self.engine.set_cost_row(slot, meta["costs"])
        if meta.get("tier"):
            self.engine.load_nb_slot(slot, nb_mod.nb_from_json(meta["nb"]))
        self._fantasies[slot] = []   # snapshots hold only real state
        h = self.studies[slot]
        if space is not None:
            h.space = space
            if self.engine.mixed or space.has_discrete:
                # (the has_discrete arm lets a non-mixed engine raise the
                # explanatory set_desc error instead of mis-serving)
                self.engine.set_desc(slot, space.descriptor())
        h.name = meta["name"]
        h.next_id = int(meta["next_id"])
        h.key = jnp.asarray(np.asarray(meta["key"], np.uint32))
        h.rng = np.random.default_rng()
        h.rng.bit_generator.state = meta["rng_state"]
        h.trials = [_trial_from_dict(t) for t in meta["trials"]]

    def reset_study(self, slot: int, space: SearchSpace | None = None,
                    name: str | None = None, seed: int | None = None) -> None:
        """Blank a slot for a new tenant: fresh GP state, ledger, PRNGs.

        `seed` defaults to the constructor's `cfg.seed + slot`; the gateway
        passes `cfg.seed + logical_id` instead, so a tenant's random streams
        are a function of WHO it is, not of which slot it lands in.
        """
        if space is not None and space.dim != self.engine.gp_cfg.dim:
            raise ValueError(
                f"space dim {space.dim} != pool dim {self.engine.gp_cfg.dim}")
        self.engine.reset_slot(slot)
        self._fantasies[slot] = []
        h = self.studies[slot]
        seed = self.cfg.seed + slot if seed is None else seed
        if space is not None:
            h.space = space
            if self.engine.mixed or space.has_discrete:
                # descriptor arrays are only built when the engine threads
                # them — all-continuous slot churn stays transfer-free
                self.engine.set_desc(slot, space.descriptor())
        h.name = name if name is not None else f"study{slot}"
        h.trials = []
        h.next_id = 0
        h.key = jax.random.PRNGKey(seed)
        h.rng = np.random.default_rng(seed)

    # -- checkpointing (the whole pool rides one atomic snapshot) -----------
    def _maybe_checkpoint(self) -> None:
        """Snapshot every `ckpt_every` absorptions (each snapshot serializes
        the full stacked state + every ledger, so many-tenant pools batch)."""
        if not self.cfg.ckpt_dir:
            return
        if self._n_done - self._done_at_last_ckpt >= max(1, self.cfg.ckpt_every):
            self.checkpoint()

    def checkpoint(self, extra: dict | None = None) -> str | None:
        """Atomic whole-pool snapshot; `extra` metadata (JSON-serializable)
        rides along and comes back in `last_restore_meta` — the gateway
        stores its logical-study registry there.

        Checkpoints see only real state (DESIGN.md §12): fantasy-active
        slots are truncated to their real ledger (bitwise) for the
        snapshot and re-fantasized right after — a restored pool holds the
        exact never-fantasized buffers, and the crash-orphaned pending
        asks are re-served by the gateway, never replayed from disk."""
        if not self.cfg.ckpt_dir:
            return None
        active = [s for s in range(self.n_studies) if self._fantasies[s]]
        for sid in active:
            if self.engine.tier(sid):
                self.engine.nb_rollback(sid)
            else:
                self.engine.truncate_slot(
                    sid, self.engine.n(sid) - len(self._fantasies[sid]))
            self.fantasy_rollbacks += 1
        self._done_at_last_ckpt = self._n_done
        meta = {
            "n_studies": self.n_studies,
            "studies": json.dumps([
                {"study_id": h.study_id, "name": h.name,
                 "next_id": h.next_id, "trials": self.history(h.study_id),
                 # per-study PRNG streams ride the snapshot so a restored
                 # pool never re-draws batches it already drew pre-crash
                 "key": np.asarray(h.key).tolist(),
                 "rng_state": h.rng.bit_generator.state}
                for h in self.studies]),
            # Escalated-tier state (DESIGN.md §15) rides the snapshot as
            # metadata: the store shape-validates the main tree against
            # the fixed GP layout, and NB ledgers have per-study caps.
            "escalated": json.dumps({
                str(s): nb_mod.nb_to_json(self.engine.nb_state(s))
                for s in range(self.n_studies) if self.engine.tier(s)}),
            "cost_rows": json.dumps({
                str(s): self.engine.cost_row(s).tolist()
                for s in range(self.n_studies)}),
        }
        if extra:
            meta.update(extra)
        path = ckpt_mod.save(self.cfg.ckpt_dir, self._n_done,
                             dataclasses.asdict(self.engine.state),
                             metadata=meta)
        self._refantasize_pending(active)
        return path

    def restore(self) -> bool:
        if not self.cfg.ckpt_dir:
            return False
        out = ckpt_mod.restore_latest(self.cfg.ckpt_dir,
                                      dataclasses.asdict(self.engine.state))
        if out is None:
            return False
        step, tree, meta = out
        self.last_restore_meta = meta
        if int(meta.get("n_studies", -1)) != self.n_studies:
            raise ValueError(
                f"checkpoint holds {meta.get('n_studies')} studies, "
                f"pool has {self.n_studies}")
        tree["params"] = KernelParams(**tree["params"])
        # Re-place on the configured device mesh: a restored pool resumes
        # with the same sharding layout the closures were built for.
        self.engine.state = self.engine.place(gp_mod.LazyGPState(**tree))
        # Snapshots hold only real state; pending q-asks died with the
        # crash and are re-served upstream, so no fantasy rows survive.
        self._fantasies = [[] for _ in range(self.n_studies)]
        esc = json.loads(meta.get("escalated", "{}"))
        rows = json.loads(meta.get("cost_rows", "{}"))
        for s in range(self.n_studies):
            self.engine.clear_nb_slot(s)
            if str(s) in rows:
                self.engine.set_cost_row(s, rows[str(s)])
            if str(s) in esc:
                self.engine.load_nb_slot(s, nb_mod.nb_from_json(esc[str(s)]))
        for rec in json.loads(meta["studies"]):
            h = self.studies[rec["study_id"]]
            h.name = rec["name"]
            h.next_id = int(rec["next_id"])
            if "key" in rec:
                h.key = jnp.asarray(np.asarray(rec["key"], np.uint32))
            if "rng_state" in rec:
                h.rng = np.random.default_rng()
                h.rng.bit_generator.state = rec["rng_state"]
            h.trials = [_trial_from_dict(t) for t in rec["trials"]]
        # The step counter resumes from the snapshot's own step, NOT from
        # total_done(): under a gateway, absorbed trials of evicted studies
        # live in per-study partial snapshots rather than any resident
        # ledger, so total_done() under-counts — a later checkpoint would
        # then be written at a LOWER step than the one just restored and be
        # shadowed by it forever (restore_latest picks the max step).
        self._n_done = int(step)
        self._done_at_last_ckpt = self._n_done
        return True
