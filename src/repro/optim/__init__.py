"""Optimizers, schedules, gradient compression."""
from repro.optim.optimizers import (OptimizerConfig, OptState, apply_updates,
                                    clip_by_global_norm, ef_compress_grads,
                                    global_norm, init_opt_state, schedule)
__all__ = ["OptimizerConfig", "OptState", "apply_updates",
           "clip_by_global_norm", "ef_compress_grads", "global_norm",
           "init_opt_state", "schedule"]
