"""Optimizers, schedules, clipping and gradient compression — pure JAX.

AdamW and SGD-momentum (the paper tunes lr/weight-decay/momentum for its
LeNet/ResNet targets; these are the same knobs the HPO layer exposes here),
a warmup-cosine schedule, global-norm clipping, and error-feedback int8
gradient compression (1000-node-scale trick: compress the DP all-reduce
payload 4x; the residual buffer keeps the update unbiased over time).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array
Params = Any


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"            # "adamw" | "sgdm"
    lr: float = 3e-4
    weight_decay: float = 0.01
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    momentum: float = 0.9          # sgdm
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    compress_grads: bool = False   # error-feedback int8 DP compression


class OptState(NamedTuple):
    step: Array
    mu: Params          # first moment / momentum
    nu: Params | None   # second moment (adamw)
    ef_residual: Params | None  # error-feedback buffer


def schedule(cfg: OptimizerConfig, step: Array) -> Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def init_opt_state(cfg: OptimizerConfig, params: Params) -> OptState:
    zeros = jax.tree.map(jnp.zeros_like, params)
    return OptState(
        step=jnp.zeros((), jnp.int32),
        mu=zeros,
        nu=jax.tree.map(jnp.zeros_like, params) if cfg.name == "adamw"
        else None,
        ef_residual=(jax.tree.map(jnp.zeros_like, params)
                     if cfg.compress_grads else None),
    )


def global_norm(tree: Params) -> Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads: Params, max_norm: float) -> tuple[Params, Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


# ---------------------------------------------------------------------------
# Error-feedback int8 compression (for the DP all-reduce payload)
# ---------------------------------------------------------------------------

def _compress_int8(x: Array) -> tuple[Array, Array]:
    absmax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12)
    scale = absmax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _decompress_int8(q: Array, scale: Array) -> Array:
    return q.astype(jnp.float32) * scale


def ef_compress_grads(grads: Params, residual: Params
                      ) -> tuple[Params, Params]:
    """Error-feedback int8: g' = Q(g + r); r' = (g + r) - g'.

    Under pjit the quantized tensor is what crosses the DP axis (XLA reduces
    the dequantized f32, but the HBM<->ICI payload planning sees int8 when
    compression is wired into a shard_map collective — see launch/train.py's
    `--compress-grads`, and EXPERIMENTS.md §Perf for measured effect).
    """
    def one(g, r):
        corrected = g.astype(jnp.float32) + r
        q, scale = _compress_int8(corrected)
        deq = _decompress_int8(q, scale)
        return deq.astype(g.dtype), corrected - deq

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (jax.tree.unflatten(tdef, [o[0] for o in out]),
            jax.tree.unflatten(tdef, [o[1] for o in out]))


# ---------------------------------------------------------------------------
# Updates
# ---------------------------------------------------------------------------

def apply_updates(cfg: OptimizerConfig, params: Params, grads: Params,
                  state: OptState) -> tuple[Params, OptState, dict]:
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    if cfg.compress_grads and state.ef_residual is not None:
        grads, new_residual = ef_compress_grads(grads, state.ef_residual)
    else:
        new_residual = state.ef_residual
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    lr = schedule(cfg, state.step)
    step = state.step + 1

    if cfg.name == "adamw":
        mu = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g,
                          state.mu, grads)
        nu = jax.tree.map(lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g,
                          state.nu, grads)
        bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
        bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

        def upd(p, m, v):
            mhat = m / bc1
            vhat = v / bc2
            return (p.astype(jnp.float32)
                    - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                            + cfg.weight_decay * p.astype(jnp.float32))
                    ).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, nu)
        new_state = OptState(step, mu, nu, new_residual)
    elif cfg.name == "sgdm":
        mu = jax.tree.map(lambda m, g: cfg.momentum * m + g, state.mu, grads)

        def upd(p, m):
            return (p.astype(jnp.float32)
                    - lr * (m + cfg.weight_decay * p.astype(jnp.float32))
                    ).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu)
        new_state = OptState(step, mu, None, new_residual)
    else:
        raise ValueError(cfg.name)
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}
