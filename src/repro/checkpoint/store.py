"""Checkpointing: atomic, shard-friendly save/restore for fault tolerance.

Layout (one directory per step):
    ckpt_dir/
      step_000123/
        manifest.json        # tree structure, dtypes, shapes, metadata
        arrays.npz           # flattened leaves (host-local / replicated view)
        COMMITTED            # atomicity marker, written last

Restart semantics (the fault-tolerance contract used by launch/train.py and
the HPO orchestrator):
  * `latest_step` ignores directories without COMMITTED (a crash mid-save
    leaves a garbage dir that is skipped and later garbage-collected),
  * the data iterator state and the GP state ride in the same manifest, so a
    restarted job resumes mid-epoch with an identical token stream and an
    identical surrogate posterior.

At 1000-node scale each host would write its own `arrays-{host}.npz` shard
of its addressable set; the single-host layout here is the degenerate case
of the same protocol (`shard_id` field in the manifest).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any

import jax
import numpy as np

PyTree = Any

_COMMIT = "COMMITTED"


def _flatten_with_paths(tree: PyTree):
    # jax.tree_util spelling: present across all supported jax versions
    # (jax.tree.flatten_with_path only landed later).
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return names, leaves, treedef


def save(ckpt_dir: str, step: int, tree: PyTree,
         metadata: dict | None = None, shard_id: int = 0,
         keep: int = 3) -> str:
    """Atomically save `tree` at `step`; returns the checkpoint path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:09d}")
    tmp = tempfile.mkdtemp(prefix=".tmp_ckpt_", dir=ckpt_dir)
    try:
        names, leaves, _ = _flatten_with_paths(tree)
        arrays, dtypes = {}, []
        for i, x in enumerate(leaves):
            arr = np.asarray(x)
            dtypes.append(str(arr.dtype))
            if arr.dtype.kind == "V" or str(arr.dtype) in (
                    "bfloat16", "float8_e4m3fn", "float8_e5m2"):
                # npz can't round-trip ml_dtypes; store the bit pattern.
                arr = arr.view(np.uint16 if arr.dtype.itemsize == 2
                               else np.uint8)
            arrays[f"a{i}"] = arr
        np.savez(os.path.join(tmp, f"arrays-{shard_id}.npz"), **arrays)
        manifest = {
            "step": step,
            "names": names,
            "dtypes": dtypes,
            "num_leaves": len(leaves),
            "shard_id": shard_id,
            "metadata": metadata or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        with open(os.path.join(tmp, _COMMIT), "w") as f:
            f.write("ok")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = committed_steps(ckpt_dir)
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:09d}"),
                      ignore_errors=True)
    # drop uncommitted debris
    for d in os.listdir(ckpt_dir):
        p = os.path.join(ckpt_dir, d)
        if d.startswith("step_") and not os.path.exists(
                os.path.join(p, _COMMIT)):
            shutil.rmtree(p, ignore_errors=True)


def committed_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and os.path.exists(
                os.path.join(ckpt_dir, d, _COMMIT)):
            out.append(int(d[len("step_"):]))
    return sorted(out)


def latest_step(ckpt_dir: str) -> int | None:
    steps = committed_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, step: int, like: PyTree,
            shard_id: int = 0) -> tuple[PyTree, dict]:
    """Restore into the structure of `like`; returns (tree, metadata)."""
    path = os.path.join(ckpt_dir, f"step_{step:09d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, f"arrays-{shard_id}.npz"))
    names, leaves, treedef = _flatten_with_paths(like)
    if names != manifest["names"]:
        raise ValueError(
            "checkpoint tree mismatch: "
            f"{set(manifest['names']) ^ set(names)}")
    import ml_dtypes  # ships with jax
    new_leaves = []
    for i, ref in enumerate(leaves):
        arr = data[f"a{i}"]
        saved_dtype = manifest["dtypes"][i]
        if str(arr.dtype) != saved_dtype:
            arr = arr.view(np.dtype(getattr(ml_dtypes, saved_dtype, None)
                                    or saved_dtype))
        if hasattr(ref, "dtype") and arr.dtype != ref.dtype:
            arr = arr.astype(ref.dtype)
        new_leaves.append(arr)
    return jax.tree.unflatten(treedef, new_leaves), manifest["metadata"]


def restore_latest(ckpt_dir: str, like: PyTree,
                   shard_id: int = 0) -> tuple[int, PyTree, dict] | None:
    step = latest_step(ckpt_dir)
    if step is None:
        return None
    tree, meta = restore(ckpt_dir, step, like, shard_id)
    return step, tree, meta
