"""Checkpointing: atomic, shard-friendly save/restore for fault tolerance.

Layout (one directory per step):
    ckpt_dir/
      step_000123/
        manifest.json        # tree structure, dtypes, shapes, metadata
        arrays.npz           # flattened leaves (host-local / replicated view)
        COMMITTED            # atomicity marker, written last

Restart semantics (the fault-tolerance contract used by launch/train.py and
the HPO orchestrator):
  * `latest_step` ignores directories without COMMITTED (a crash mid-save
    leaves a garbage dir that is skipped and later garbage-collected),
  * the data iterator state and the GP state ride in the same manifest, so a
    restarted job resumes mid-epoch with an identical token stream and an
    identical surrogate posterior.

At 1000-node scale each host would write its own `arrays-{host}.npz` shard
of its addressable set; the single-host layout here is the degenerate case
of the same protocol (`shard_id` field in the manifest).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import time
from typing import Any

import jax
import numpy as np

PyTree = Any

_COMMIT = "COMMITTED"

# Writers stage into `.tmp_ckpt_*` (save) / `.tmp_migrate_*`
# (copy_study_version) dirs that an atomic rename publishes; a SIGKILLed
# writer leaves its tmp dir behind forever.  `sweep_tmp` reclaims that
# debris with an age guard: another shard process may be mid-write in the
# same store right now, and its fresh tmp dir (every file write bumps the
# dir mtime) must never be swept out from under it.  One hour is ~5 orders
# of magnitude above any real save; REPRO_CKPT_TMP_TTL overrides (seconds).
_TMP_PREFIXES = (".tmp_ckpt_", ".tmp_migrate_")
_TMP_TTL_S = 3600.0


def _tmp_ttl() -> float:
    return float(os.environ.get("REPRO_CKPT_TMP_TTL", _TMP_TTL_S))


def sweep_tmp(ckpt_dir: str, ttl_s: float | None = None) -> list[str]:
    """Remove stale writer-staging tmp dirs under `ckpt_dir` (non-recursive).

    Only dirs older than `ttl_s` (mtime) go — a concurrent writer from
    another shard process keeps its in-flight tmp dir.  Returns the swept
    paths (tests assert on them)."""
    ttl = _tmp_ttl() if ttl_s is None else ttl_s
    if not os.path.isdir(ckpt_dir):
        return []
    now = time.time()
    swept = []
    for d in os.listdir(ckpt_dir):
        if not d.startswith(_TMP_PREFIXES):
            continue
        p = os.path.join(ckpt_dir, d)
        try:
            age = now - os.path.getmtime(p)
        except OSError:
            continue  # the owning writer just published or cleaned it up
        if age > ttl:
            shutil.rmtree(p, ignore_errors=True)
            swept.append(p)
    return swept


def _flatten_with_paths(tree: PyTree):
    # jax.tree_util spelling: present across all supported jax versions
    # (jax.tree.flatten_with_path only landed later).
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return names, leaves, treedef


def save(ckpt_dir: str, step: int, tree: PyTree,
         metadata: dict | None = None, shard_id: int = 0,
         keep: int = 3) -> str:
    """Atomically save `tree` at `step`; returns the checkpoint path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:09d}")
    tmp = tempfile.mkdtemp(prefix=".tmp_ckpt_", dir=ckpt_dir)
    try:
        names, leaves, _ = _flatten_with_paths(tree)
        arrays, dtypes = {}, []
        for i, x in enumerate(leaves):
            arr = np.asarray(x)
            dtypes.append(str(arr.dtype))
            if arr.dtype.kind == "V" or str(arr.dtype) in (
                    "bfloat16", "float8_e4m3fn", "float8_e5m2"):
                # npz can't round-trip ml_dtypes; store the bit pattern.
                arr = arr.view(np.uint16 if arr.dtype.itemsize == 2
                               else np.uint8)
            arrays[f"a{i}"] = arr
        np.savez(os.path.join(tmp, f"arrays-{shard_id}.npz"), **arrays)
        manifest = {
            "step": step,
            "names": names,
            "dtypes": dtypes,
            "num_leaves": len(leaves),
            "shard_id": shard_id,
            "metadata": metadata or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        with open(os.path.join(tmp, _COMMIT), "w") as f:
            f.write("ok")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = committed_steps(ckpt_dir)
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:09d}"),
                      ignore_errors=True)
    # drop uncommitted debris
    for d in os.listdir(ckpt_dir):
        p = os.path.join(ckpt_dir, d)
        if d.startswith("step_") and not os.path.exists(
                os.path.join(p, _COMMIT)):
            shutil.rmtree(p, ignore_errors=True)
    # ... and the tmp staging dirs a SIGKILLed writer never published
    # (age-guarded: a concurrent writer's in-flight tmp dir stays)
    sweep_tmp(ckpt_dir)


def committed_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and os.path.exists(
                os.path.join(ckpt_dir, d, _COMMIT)):
            out.append(int(d[len("step_"):]))
    return sorted(out)


def latest_step(ckpt_dir: str) -> int | None:
    steps = committed_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, step: int, like: PyTree,
            shard_id: int = 0) -> tuple[PyTree, dict]:
    """Restore into the structure of `like`; returns (tree, metadata)."""
    path = os.path.join(ckpt_dir, f"step_{step:09d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, f"arrays-{shard_id}.npz"))
    names, leaves, treedef = _flatten_with_paths(like)
    if names != manifest["names"]:
        raise ValueError(
            "checkpoint tree mismatch: "
            f"{set(manifest['names']) ^ set(names)}")
    import ml_dtypes  # ships with jax
    new_leaves = []
    for i, ref in enumerate(leaves):
        arr = data[f"a{i}"]
        saved_dtype = manifest["dtypes"][i]
        if str(arr.dtype) != saved_dtype:
            arr = arr.view(np.dtype(getattr(ml_dtypes, saved_dtype, None)
                                    or saved_dtype))
        if tuple(arr.shape) != tuple(np.shape(ref)):
            # names alone don't catch a resized buffer (e.g. a pool rebuilt
            # with a different n_max): restoring it would silently clamp
            # out-of-bounds appends onto the last row instead of erroring
            raise ValueError(
                f"checkpoint shape mismatch at {names[i]}: saved "
                f"{tuple(arr.shape)}, expected {tuple(np.shape(ref))} "
                "(was the state rebuilt with a different n_max, dim, or "
                "number of studies?)")
        if hasattr(ref, "dtype") and arr.dtype != ref.dtype:
            arr = arr.astype(ref.dtype)
        new_leaves.append(arr)
    return jax.tree.unflatten(treedef, new_leaves), manifest["metadata"]


def restore_latest(ckpt_dir: str, like: PyTree,
                   shard_id: int = 0) -> tuple[int, PyTree, dict] | None:
    step = latest_step(ckpt_dir)
    if step is None:
        return None
    tree, meta = restore(ckpt_dir, step, like, shard_id)
    return step, tree, meta


# ---------------------------------------------------------------------------
# Per-study partial snapshots (the gateway's eviction store, DESIGN.md §9).
#
# A whole-pool snapshot serializes the full stacked state; evicting ONE
# study must not.  Each study gets its own step-versioned directory under
# `ckpt_dir/studies/<study>/` using the exact same atomic save/restore
# protocol (COMMITTED marker, keep-N gc), so partial snapshots coexist with
# whole-pool `step_*` snapshots in one checkpoint root: the pool-level gc
# only touches `step_*` entries and never descends into `studies/`.
# ---------------------------------------------------------------------------

def study_dir(ckpt_dir: str, study: str) -> str:
    if "/" in study or study.startswith("."):
        raise ValueError(f"bad study key {study!r}")
    return os.path.join(ckpt_dir, "studies", study)


def save_study(ckpt_dir: str, study: str, version: int, tree: PyTree,
               metadata: dict | None = None) -> str:
    """Atomically snapshot one study at `version` (monotonic per study).

    No garbage collection happens here: a whole-pool snapshot's registry
    references exact versions, so versions may only be pruned once a newer
    pool snapshot commits (`prune_studies`) — otherwise a crash after two
    evictions of the same study would leave the registry pointing at a
    gc'd version.
    """
    return save(study_dir(ckpt_dir, study), version, tree,
                metadata=metadata, keep=10 ** 9)


def restore_study(ckpt_dir: str, study: str, like: PyTree,
                  version: int | None = None
                  ) -> tuple[int, PyTree, dict] | None:
    """One study's committed snapshot: exact `version`, or latest if None.

    Crash recovery MUST pass the version its registry recorded — snapshots
    written after that registry was checkpointed contain future state.
    """
    d = study_dir(ckpt_dir, study)
    if version is None:
        return restore_latest(d, like)
    if version not in committed_steps(d):
        return None
    tree, meta = restore(d, version, like)
    return version, tree, meta


def study_versions(ckpt_dir: str, study: str) -> list[int]:
    """Committed snapshot versions of one study (empty if none)."""
    return committed_steps(study_dir(ckpt_dir, study))


def copy_study_version(src_dir: str, dst_dir: str, study: str,
                       version: int) -> str:
    """Copy ONE committed study snapshot between checkpoint stores —
    the transport primitive of study migration between federation shards
    (DESIGN.md §13).

    Same all-or-nothing protocol as `save`: files land in a temp dir, the
    COMMITTED marker is written last, and an atomic rename publishes the
    version on the destination.  A fault mid-copy leaves the destination
    without the version and never touches the source, so the migration
    orchestrator can abort with the study fully intact on its source
    shard."""
    src = os.path.join(study_dir(src_dir, study), f"step_{version:09d}")
    if not os.path.exists(os.path.join(src, _COMMIT)):
        raise FileNotFoundError(
            f"study {study!r} version {version} is not committed under "
            f"{src_dir}")
    dst_root = study_dir(dst_dir, study)
    os.makedirs(dst_root, exist_ok=True)
    # a SIGKILLed copier (front-end crash mid-migration) leaves its
    # `.tmp_migrate_*` staging dir here; the retry is the natural sweep
    # point (study dirs see no regular `save` traffic after adoption)
    sweep_tmp(dst_root)
    final = os.path.join(dst_root, f"step_{version:09d}")
    if os.path.exists(os.path.join(final, _COMMIT)):
        return final  # a retried migration finds it already published
    tmp = tempfile.mkdtemp(prefix=".tmp_migrate_", dir=dst_root)
    try:
        for name in os.listdir(src):
            if name != _COMMIT:
                shutil.copy2(os.path.join(src, name),
                             os.path.join(tmp, name))
        with open(os.path.join(tmp, _COMMIT), "w") as f:
            f.write("ok")
        if os.path.exists(final):
            shutil.rmtree(final)  # uncommitted debris from a prior crash
        os.replace(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


def prune_studies(ckpt_dir: str, keep_from: dict[str, int]) -> None:
    """Drop per-study snapshot versions below each study's floor.

    Called after a whole-pool snapshot commits: its registry references
    `keep_from[study]`, so everything older is unreachable from the latest
    recovery point."""
    for study, floor in keep_from.items():
        d = study_dir(ckpt_dir, study)
        for s in committed_steps(d):
            if s < floor:
                shutil.rmtree(os.path.join(d, f"step_{s:09d}"),
                              ignore_errors=True)


def drop_studies(ckpt_dir: str, studies: list[str]) -> None:
    """Delete whole per-study snapshot directories (closed tenants).

    Like `prune_studies`, only call this AFTER a whole-pool snapshot that
    no longer references the studies has committed — a crash before that
    commit restores a registry that still expects them on disk."""
    for study in studies:
        shutil.rmtree(study_dir(ckpt_dir, study), ignore_errors=True)


def list_studies(ckpt_dir: str) -> list[str]:
    root = os.path.join(ckpt_dir, "studies")
    if not os.path.isdir(root):
        return []
    return sorted(d for d in os.listdir(root)
                  if committed_steps(os.path.join(root, d)))
