"""Atomic checkpoint store (fault-tolerance substrate)."""
from repro.checkpoint.store import (committed_steps, drop_studies,
                                    latest_step, list_studies,
                                    prune_studies, restore,
                                    restore_latest, restore_study, save,
                                    save_study, study_dir)
__all__ = ["committed_steps", "drop_studies", "latest_step",
           "list_studies",
           "prune_studies", "restore", "restore_latest", "restore_study",
           "save", "save_study", "study_dir"]
