"""Atomic checkpoint store (fault-tolerance substrate)."""
from repro.checkpoint.store import (committed_steps, latest_step, restore,
                                    restore_latest, save)
__all__ = ["committed_steps", "latest_step", "restore", "restore_latest",
           "save"]
