"""Atomic checkpoint store (fault-tolerance substrate)."""
from repro.checkpoint.store import (committed_steps, copy_study_version,
                                    drop_studies, latest_step,
                                    list_studies, prune_studies, restore,
                                    restore_latest, restore_study, save,
                                    save_study, study_dir, study_versions,
                                    sweep_tmp)
__all__ = ["committed_steps", "copy_study_version", "drop_studies",
           "latest_step", "list_studies",
           "prune_studies", "restore", "restore_latest", "restore_study",
           "save", "save_study", "study_dir", "study_versions",
           "sweep_tmp"]
