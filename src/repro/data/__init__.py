"""Deterministic synthetic data pipeline."""
from repro.data.pipeline import DataConfig, DataIterator, host_local_batch, synth_tokens
__all__ = ["DataConfig", "DataIterator", "host_local_batch", "synth_tokens"]
