"""Deterministic synthetic token pipeline, shardable to the production mesh.

No datasets ship offline, so the pipeline synthesizes structured token
streams (a mixture of Zipfian unigrams and deterministic n-gram patterns) —
enough signal that a small LM's loss decreases, which is what the HPO-layer
objectives need.  Every batch is a pure function of (seed, step), so:

  * restarts resume mid-epoch exactly (fault tolerance: the data iterator's
    state is just an integer),
  * every data-parallel host can materialize its own shard without any
    cross-host coordination (`host_local_batch`).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_alpha: float = 1.1
    pattern_frac: float = 0.5   # fraction of positions forced to n-gram rule
    frontend: str = "none"      # "frames" -> synthetic frame embeddings
    d_model: int = 0


def _zipf_logits(vocab: int, alpha: float) -> Array:
    ranks = jnp.arange(1, vocab + 1, dtype=jnp.float32)
    return -alpha * jnp.log(ranks)


def synth_tokens(cfg: DataConfig, step: int | Array,
                 batch: int | None = None) -> dict[str, Array]:
    """Batch at `step`: dict(inputs, targets, mask), deterministic."""
    batch = batch or cfg.global_batch
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
    k1, k2, k3 = jax.random.split(key, 3)
    logits = _zipf_logits(cfg.vocab_size, cfg.zipf_alpha)
    toks = jax.random.categorical(
        k1, jnp.broadcast_to(logits, (batch, cfg.seq_len + 1,
                                      cfg.vocab_size)))
    # Learnable structure: with prob pattern_frac, token t+1 is a fixed
    # affine function of token t (so next-token prediction has signal).
    nxt = (toks[:, :-1] * 31 + 7) % cfg.vocab_size
    use_pat = jax.random.bernoulli(k2, cfg.pattern_frac,
                                   (batch, cfg.seq_len))
    targets = jnp.where(use_pat, nxt, toks[:, 1:]).astype(jnp.int32)
    inputs = toks[:, :-1].astype(jnp.int32)
    if cfg.frontend == "frames":
        frames = jax.random.normal(k3, (batch, cfg.seq_len, cfg.d_model),
                                   jnp.float32)
        # frame labels follow a projection rule of the frame content
        lab = (jnp.argmax(frames[..., : min(cfg.d_model, 32)], -1)
               % cfg.vocab_size).astype(jnp.int32)
        return {"inputs": frames, "targets": lab,
                "mask": jnp.ones((batch, cfg.seq_len), jnp.float32)}
    return {"inputs": inputs, "targets": targets,
            "mask": jnp.ones((batch, cfg.seq_len), jnp.float32)}


def host_local_batch(cfg: DataConfig, step: int, host_id: int,
                     num_hosts: int) -> dict[str, Array]:
    """The shard of the global batch owned by `host_id` (disjoint fold-in
    streams per host; concatenation over hosts == the global batch)."""
    assert cfg.global_batch % num_hosts == 0
    local = cfg.global_batch // num_hosts
    sub = dataclasses.replace(cfg, seed=cfg.seed * 1_000_003 + host_id)
    return synth_tokens(sub, step, batch=local)


class DataIterator:
    """Stateful wrapper whose entire state is the step counter."""

    def __init__(self, cfg: DataConfig, start_step: int = 0):
        self.cfg = cfg
        self.step = start_step
        self._fn = jax.jit(lambda s: synth_tokens(cfg, s))

    def __next__(self):
        batch = self._fn(self.step)
        self.step += 1
        return batch

    def state_dict(self) -> dict:
        return {"step": self.step}

    def load_state_dict(self, state: dict) -> None:
        self.step = int(state["step"])
