"""repro — Scalable HPO with Lazy Gaussian Processes, as a multi-pod JAX framework.

Subpackages:
  core/       lazy-GP Bayesian optimization (the paper's contribution)
  kernels/    Pallas TPU kernels for the GP hot spots
  hpo/        trial scheduler: parallel suggestions, async absorption, fault tolerance
  models/     assigned-architecture model zoo (dense/MoE/MLA/SSM/xLSTM/...)
  data/       deterministic synthetic token pipeline
  optim/      optimizers, schedules, gradient compression
  training/   train/prefill/decode steps (remat, microbatching)
  checkpoint/ save/restore for fault tolerance
  configs/    one config per assigned architecture
  launch/     production meshes, sharding rules, dry-run, train CLI
"""
__version__ = "1.0.0"
