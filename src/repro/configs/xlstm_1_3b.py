"""xlstm-1.3b — xLSTM with mLSTM blocks.

[ssm] 48L d_model=2048 4H d_ff=0 vocab=50304 [arXiv:2405.04517; unverified].
d_ff=0 per the spec: mLSTM blocks carry an internal projection pair instead
of a separate FFN, matching the xLSTM paper's mLSTM block (the 1.3B-scale
xLSTM[7:1] is approximated as an all-mLSTM stack; sLSTM omission noted in
DESIGN.md).  The projection factor is 1.0 here so the total lands at the
published ~1.3-1.4B for 48L x 2048d (pf=2 with full-width qkv would be ~3B).
Recurrent state -> no KV cache; long_500k runs.
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    block_pattern="mlstm",
    mlstm_heads=4,
    mlstm_pf=1.0,
    ssm_chunk=256,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="xlstm-reduced", num_layers=2, d_model=64, num_heads=2,
        num_kv_heads=2, vocab_size=256, mlstm_heads=2, ssm_chunk=32,
        remat=False)
