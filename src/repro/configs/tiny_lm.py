"""tiny-lm — the end-to-end example/HPO target model (~15M params default).

Not an assigned architecture: this is the trainable-on-CPU workload the
examples and the paper-repro NN-HPO benchmarks tune (the LeNet/ResNet32
stand-in, since no image datasets ship offline).
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="tiny-lm",
    family="dense",
    num_layers=4,
    d_model=256,
    num_heads=8,
    num_kv_heads=4,
    d_ff=1024,
    vocab_size=4096,
    remat=False,
    dtype="float32",
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="tiny-lm-reduced", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256)
