"""gemma3-4b — Gemma-3 with 5:1 local:global attention, 128k context.

[dense] 34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144
[hf:google/gemma-3-1b-pt; unverified].  Local layers use a 1024-token
sliding window; every 6th layer is global.  head_dim=256 per the Gemma-3
releases (d_model/num_heads would be 320).

long_500k note (DESIGN.md §5): the sliding-window layers are O(window);
the 1-in-6 global layers keep full-cache decode attention, which at 500k is
O(S) per token — still linear, so the cell runs (memory sized by batch=1).
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b",
    family="dense",
    num_layers=34,
    d_model=2560,
    num_heads=8,
    num_kv_heads=4,
    d_ff=10240,
    vocab_size=262144,
    head_dim=256,
    sliding_window=1024,
    global_every=6,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="gemma3-reduced", num_layers=4, d_model=64, num_heads=4,
        num_kv_heads=2, d_ff=128, vocab_size=256, head_dim=16,
        sliding_window=8, global_every=2, remat=False)
