"""minicpm3-4b — dense model with Multi-head Latent Attention (MLA).

[dense] 62L d_model=2560 40H d_ff=6400 vocab=73448 [hf:openbmb/MiniCPM3-4B].
The assignment's "GQA kv=40" is the degenerate per-head view; MiniCPM3's
actual attention is MLA with a compressed latent KV cache — implemented as
such (q_lora 768, kv_lora 256, nope 64, rope 32, v 64 per the release),
which is what makes its decode shapes interesting.
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    family="dense",
    num_layers=62,
    d_model=2560,
    num_heads=40,
    num_kv_heads=40,
    d_ff=6400,
    vocab_size=73448,
    attention="mla",
    q_lora_rank=768,
    kv_lora_rank=256,
    qk_nope_dim=64,
    qk_rope_dim=32,
    v_head_dim=64,
    head_dim=96,   # nope + rope (query/key working dim)
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="minicpm3-reduced", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=256,
        q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=8, qk_rope_dim=8,
        v_head_dim=8, head_dim=16, remat=False)
