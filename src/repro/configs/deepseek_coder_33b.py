"""deepseek-coder-33b — dense llama-architecture coder model.

[dense] 62L d_model=7168 56H (GQA kv=8) d_ff=19200 vocab=32256
[arXiv:2401.14196; hf].
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b",
    family="dense",
    num_layers=62,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=19200,
    vocab_size=32256,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="deepseek-coder-reduced", num_layers=2, d_model=128,
        num_heads=8, num_kv_heads=2, d_ff=256, vocab_size=256, remat=False)
