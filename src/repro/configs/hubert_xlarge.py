"""hubert-xlarge — encoder-only audio transformer (wav2vec2 architecture).

[audio] 48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504
[arXiv:2106.07447; unverified].  Encoder-only: bidirectional attention, no
decode path (decode_32k / long_500k cells are skipped per DESIGN.md §5).
The conv feature extractor is a STUB: input_specs() provides precomputed
frame embeddings (batch, frames, d_model); the loss is masked-frame
prediction over the 504-unit codebook.
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    causal=False,
    is_encoder=True,
    rope=False,
    frontend="frames",
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="hubert-reduced", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=4, d_ff=128, vocab_size=32, remat=False)
