"""zamba2-1.2b — Mamba2 backbone + shared attention blocks.

[hybrid] 38L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=32000 ssm_state=64
[arXiv:2411.15242; hf].  The Mamba2 mixer uses expand=2 (d_inner 4096),
head_dim 64 (64 SSD heads), 1 B/C group.  One *shared* full-attention block
(weights reused) fires after every 6th mamba layer — 6 applications — per
the Zamba2 shared-block design (simplified: no LoRA adaptation per depth,
noted in DESIGN.md).
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    block_pattern="mamba",
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_groups=1,
    ssm_chunk=256,
    shared_attn_every=6,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="zamba2-reduced", num_layers=4, d_model=64, num_heads=4,
        num_kv_heads=4, d_ff=128, vocab_size=256, ssm_state=16,
        ssm_head_dim=16, ssm_chunk=32, shared_attn_every=2, remat=False)
