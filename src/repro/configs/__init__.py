"""Architecture registry: one module per assigned architecture.

Usage:
    from repro.configs import get_config, REGISTRY
    cfg = get_config("granite-3-2b")            # full published config
    cfg = get_config("granite-3-2b", reduced=True)   # CPU smoke config

Every module exposes `CONFIG` (the exact published numbers from the
assignment) and `reduced()` (same family, tiny dims, for CPU smoke tests).
"""
from __future__ import annotations

import importlib

_ARCHS = {
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "minicpm3-4b": "minicpm3_4b",
    "granite-3-2b": "granite_3_2b",
    "gemma3-4b": "gemma3_4b",
    "zamba2-1.2b": "zamba2_1_2b",
    "chameleon-34b": "chameleon_34b",
    "hubert-xlarge": "hubert_xlarge",
    "xlstm-1.3b": "xlstm_1_3b",
    # the paper's own HPO targets (LeNet/ResNet stand-ins, see bench_nn_hpo)
    "tiny-lm": "tiny_lm",
}

ARCH_IDS = [a for a in _ARCHS if a != "tiny-lm"]


def get_module(arch: str):
    if arch not in _ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCHS)}")
    return importlib.import_module(f"repro.configs.{_ARCHS[arch]}")


def get_config(arch: str, reduced: bool = False):
    mod = get_module(arch)
    return mod.reduced() if reduced else mod.CONFIG


REGISTRY = _ARCHS
