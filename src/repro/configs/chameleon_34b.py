"""chameleon-34b — early-fusion vision-language model.

[vlm] 48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536
[arXiv:2405.09818; unverified].  Early fusion: VQ image tokens share the
65536-entry vocabulary, so the backbone is a standard decoder with
Chameleon's qk-norm for stability.  The VQ-VAE image tokenizer is a STUB per
the assignment: input_specs() provides token ids (text + image tokens).
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    num_layers=48,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22016,
    vocab_size=65536,
    qk_norm=True,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="chameleon-reduced", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256, remat=False)
